"""Benchmark-regression gate: current BENCH_*.json vs committed baselines.

For every baseline file ``benchmarks/baselines/BENCH_<name>.json`` the
matching ``BENCH_<name>.json`` from the current run (cwd by default) is
checked key-by-key:

* throughput keys (``*_per_s``) must not drop more than ``--tolerance``
  (default 25%) below the baseline value;
* compile-count keys (``*recompiles*`` / ``*compiles*``) must not
  exceed the baseline -- any increase is a regression (the "no
  re-synthesis" property, enforced);
* exactness keys (``*_exact``) must stay true if the baseline says true;
* ratio keys (``*_on_off_ratio``) must not fall below the baseline,
  which is a *policy floor* (e.g. telemetry-on must keep >= 0.9x the
  telemetry-off ticks/s -- the <10% overhead budget); ``--refresh``
  preserves the committed floor instead of snapshotting the run;
* latency keys (``*_ttft_s``) are *ceilings*: the current value must
  not exceed the baseline by more than ``--tolerance`` (serving p99
  TTFT is a contract, not a nice-to-have; ``--refresh`` snapshots
  ``value / headroom`` so the committed ceiling sits well above run
  noise);
* win-ratio keys (``*_win_vs_*``) are policy floors the same way: the
  event backend's ticks/s advantage over each dense backend at every
  sparse grid point.  The committed floors (>= 1.0 against jnp) ARE
  the ROADMAP "event wins everywhere it should" contract -- a policy
  regression that hands the lead back to a dense backend fails CI even
  if every absolute rate got faster;
* a key present in the baseline but missing from the current run fails
  (a silently dropped metric is not a pass).

On a pass the gate prints a one-line-per-metric delta table (baseline
vs current), so CI logs show how much headroom each floor has left.

Baselines are *floors you refresh deliberately*, not last-run snapshots:
commit conservative values (CI runners vary ~2x in wall-clock) and bump
them via ``--refresh`` after a real speedup lands (see README "CI &
benchmarks").

  PYTHONPATH=src python benchmarks/check_regression.py [--tolerance 0.25]
  PYTHONPATH=src python benchmarks/check_regression.py --refresh  # rewrite baselines
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "baselines")

# Throughput floor: headroom applied when --refresh snapshots a run, so a
# committed baseline is well under the observed rate and the 25% gate only
# trips on real regressions, not CI-runner speed variance (hosted runners
# differ several-x from dev machines on interpret-mode kernels).
REFRESH_HEADROOM = 0.33


def _is_rate_key(k: str) -> bool:
    return k.endswith("_per_s")


def _is_compile_key(k: str) -> bool:
    return "recompile" in k or k.endswith("compiles")


def _is_exact_key(k: str) -> bool:
    return k.endswith("_exact")


def _is_ratio_key(k: str) -> bool:
    """Policy-floor keys: gated as hard floors, preserved by --refresh.

    ``*_efficiency`` covers the sharded weak-scaling floor
    (``sharded_n*_weak_scaling_efficiency`` >= 0.6): aggregate synaptic
    throughput the mesh partition must retain vs a single device doing
    the same per-device work."""
    return (k.endswith("_on_off_ratio") or "_win_vs_" in k
            or k.endswith("_efficiency"))


def _is_latency_key(k: str) -> bool:
    """Latency keys: gated as ceilings (lower is better)."""
    return k.endswith("_ttft_s")


def check_one(
    name: str, baseline: Dict, current: Dict, tolerance: float,
) -> List[str]:
    """Returns a list of human-readable failures (empty == pass)."""
    failures = []
    for k, base in baseline.items():
        if k.startswith("_"):
            continue
        if k not in current:
            failures.append(f"{name}: metric {k!r} missing from current run")
            continue
        cur = current[k]
        if _is_compile_key(k):
            if float(cur) > float(base):
                failures.append(
                    f"{name}: {k} increased {base} -> {cur} (any increase fails)")
        elif _is_rate_key(k):
            floor = float(base) * (1.0 - tolerance)
            if float(cur) < floor:
                failures.append(
                    f"{name}: {k} dropped {base} -> {cur} "
                    f"(>{tolerance:.0%} below baseline, floor {floor:.1f})")
        elif _is_exact_key(k):
            if bool(base) and not bool(cur):
                failures.append(f"{name}: {k} regressed True -> {cur}")
        elif _is_ratio_key(k):
            if float(cur) < float(base):
                failures.append(
                    f"{name}: {k} fell below the policy floor "
                    f"{base} -> {cur}")
        elif _is_latency_key(k):
            ceiling = float(base) * (1.0 + tolerance)
            if float(cur) > ceiling:
                failures.append(
                    f"{name}: {k} rose {base} -> {cur} "
                    f"(>{tolerance:.0%} above baseline, ceiling "
                    f"{ceiling:.4f})")
    return failures


def _delta_table(baseline: Dict, current: Dict) -> List[str]:
    """One line per gated metric: baseline vs current, with slack."""
    rows = []
    for k in sorted(baseline):
        if k.startswith("_") or k not in current:
            continue
        base, cur = baseline[k], current[k]
        if _is_rate_key(k) or _is_ratio_key(k):
            slack = (float(cur) - float(base)) / max(1e-9, abs(float(base)))
            rows.append(f"    {k}: {base} -> {cur} ({slack:+.0%} vs floor)")
        elif _is_compile_key(k):
            rows.append(f"    {k}: {base} -> {cur} (ceiling {base})")
        elif _is_latency_key(k):
            slack = (float(base) - float(cur)) / max(1e-9, abs(float(base)))
            rows.append(
                f"    {k}: {base} -> {cur} ({slack:+.0%} under ceiling)")
        elif _is_exact_key(k):
            rows.append(f"    {k}: {base} -> {cur}")
    return rows


def _load_pairs(current_dir: str) -> List[Tuple[str, Dict, Dict]]:
    pairs = []
    if not os.path.isdir(BASELINE_DIR):
        raise SystemExit(f"no baseline dir at {BASELINE_DIR}")
    for fname in sorted(os.listdir(BASELINE_DIR)):
        if not (fname.startswith("BENCH_") and fname.endswith(".json")):
            continue
        with open(os.path.join(BASELINE_DIR, fname)) as f:
            baseline = json.load(f)
        cur_path = os.path.join(current_dir, fname)
        if not os.path.exists(cur_path):
            pairs.append((fname, baseline, None))
            continue
        with open(cur_path) as f:
            pairs.append((fname, baseline, json.load(f)))
    return pairs


def refresh(current_dir: str) -> None:
    """Rewrite each baseline from the current run, with headroom on rates.

    Gated keys are taken from the *union* of baseline and current run, so
    a metric a bench newly emits starts being gated on refresh. Two
    refusals keep a refresh from weakening the gate: a gated baseline key
    missing from the current run (a silently dropped metric must be
    deleted from the baseline by hand, not by accident), and a current
    run that is itself regressed (exact=false, or a compile count above
    the old baseline) -- snapshotting that would disable the gate forever.
    """
    errors = []
    staged = []  # validate every file first; write only if ALL pass
    for fname, baseline, current in _load_pairs(current_dir):
        if current is None:
            errors.append(f"{fname}: no current run in {current_dir}")
            continue
        gated_current = {
            k for k in current
            if not k.startswith("_")
            and (_is_rate_key(k) or _is_compile_key(k) or _is_exact_key(k)
                 or _is_ratio_key(k) or _is_latency_key(k))}
        gated_base = {k for k in baseline if not k.startswith("_")}
        for k in sorted(gated_base - set(current)):
            errors.append(
                f"{fname}: baseline metric {k!r} missing from current run "
                "(delete it from the baseline by hand if retired)")
        fresh = {}
        for k in sorted(gated_base | gated_current):
            if k not in current:
                continue
            v = current[k]
            if _is_exact_key(k) and not bool(v):
                errors.append(f"{fname}: refusing to baseline {k}={v} "
                              "(would disable the parity gate)")
            if _is_compile_key(k) and float(v) > float(baseline.get(k, 0)):
                errors.append(f"{fname}: refusing to baseline {k}={v} "
                              f"(above old floor {baseline.get(k, 0)})")
            if _is_rate_key(k):
                v = round(float(v) * REFRESH_HEADROOM, 1)
            if _is_latency_key(k):
                # Ceilings get the inverse headroom: the committed bound
                # sits ~3x above the observed latency, so the 25% gate
                # only trips on a real p99 blow-up, not runner jitter.
                v = round(float(v) / REFRESH_HEADROOM, 4)
            if _is_ratio_key(k):
                # Policy floors, not snapshots: refresh keeps the committed
                # floor; a brand-new ratio key starts 10% under its run
                # (40% for win ratios -- wall-clock ratios on shared CI
                # runners are noisier than the on/off pair measurement;
                # hand-tighten the committed floor to the policy line,
                # e.g. 1.0 for the event-beats-jnp contract).
                slack = 0.6 if "_win_vs_" in k else 0.9
                v = baseline.get(k, round(float(v) * slack, 3))
            fresh[k] = v
        staged.append((fname, fresh))
    if errors:
        for e in errors:
            print(f"REFRESH REFUSED: {e}", file=sys.stderr)
        raise SystemExit(1)
    for fname, fresh in staged:
        path = os.path.join(BASELINE_DIR, fname)
        with open(path, "w") as f:
            json.dump(fresh, f, indent=2)
            f.write("\n")
        print(f"refreshed {path} ({len(fresh)} gated metrics)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current-dir", default=".",
                    help="where the run's BENCH_*.json files live")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_GATE_TOLERANCE", 0.25)),
                    help="allowed fractional ticks/sec drop (default 0.25)")
    ap.add_argument("--refresh", action="store_true",
                    help="rewrite baselines from the current run (with "
                         f"{REFRESH_HEADROOM:g}x headroom on rate keys)")
    args = ap.parse_args(argv)

    if args.refresh:
        refresh(args.current_dir)
        return 0

    all_failures = []
    checked = 0
    for fname, baseline, current in _load_pairs(args.current_dir):
        if current is None:
            all_failures.append(f"{fname}: current run file not found in "
                                f"{args.current_dir}")
            continue
        fails = check_one(fname, baseline, current, args.tolerance)
        n_keys = sum(1 for k in baseline if not k.startswith("_"))
        checked += n_keys
        status = "FAIL" if fails else "ok"
        print(f"[{status}] {fname}: {n_keys} gated metrics, "
              f"{len(fails)} regressions")
        for row in _delta_table(baseline, current):
            print(row)
        all_failures += fails

    for f in all_failures:
        print(f"REGRESSION: {f}", file=sys.stderr)
    if all_failures:
        print(f"\nbench gate FAILED: {len(all_failures)} regression(s) "
              f"across {checked} gated metrics", file=sys.stderr)
        return 1
    print(f"bench gate passed: {checked} gated metrics within tolerance "
          f"{args.tolerance:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
