"""Paper Table II analogue: the Iris system, end to end.

The paper reports post-implementation utilization + power (not accuracy
numbers); on CPU we report the analogous system-level quantities our
adaptation exposes: classification correctness through the full register
path, reprogram cost under both timing models, the tick-latency model,
and the compute cost per inference.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.configs import get_bundle
from repro.core import classifier, encoding
from repro.core.registers import TimingModel
from repro.data import iris


def run() -> Dict:
    cfg = get_bundle("iris-snn").model
    x, y = iris.load(seed=0)
    levels = np.asarray(encoding.level_encode(iris.normalize(x), levels=4))
    (xtr, ytr), (xte, yte) = iris.train_test_split(levels, y, test_frac=0.3)

    t0 = time.time()
    model = classifier.train(xtr, ytr, cfg)
    train_s = time.time() - t0

    dep = classifier.deploy(model, n_neurons=cfg.n_neurons)
    acc_tr = classifier.accuracy(classifier.predict_int(dep, xtr), ytr)
    acc_te = classifier.accuracy(classifier.predict_int(dep, xte), yte)

    t0 = time.time()
    for _ in range(10):
        classifier.predict_int(dep, xte)
    infer_us = (time.time() - t0) / 10 / len(xte) * 1e6

    bd = dep.bank.breakdown()
    # paper latency model: 1 cycle input sampling + 2 cycles/layer x 2 layers
    cycles = 1 + 2 * 2
    return {
        "bench": "iris (paper Table II analogue)",
        "n_neurons": dep.bank.n,
        "train_acc_int": acc_tr,
        "test_acc_int": acc_te,
        "reprogram_bytes": bd.total,
        "reprogram_ms_paper_model": bd.time_s(TimingModel.PAPER) * 1e3,
        "reprogram_ms_wire_8n1": bd.time_s(TimingModel.WIRE_8N1) * 1e3,
        "inference_latency_cycles@100MHz": cycles,
        "inference_latency_ns@100MHz": cycles * 10,
        "cpu_infer_us_per_sample": infer_us,
        "train_s": train_s,
    }


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
