"""The paper's UART transaction table (§III.B), reproduced exactly, plus
the scaling the paper's future-work section motivates.

Units and timing models are explicit in every key, because the two
readouts differ by a deliberate 10x and used to look like a bug
(``74n_time_ms_paper = 93.54`` vs ``74n_time_ms_wire8n1 = 935.4``):

* ``*_papermodel_*`` -- the paper's own arithmetic: ONE 9600-baud bit
  time (104.17 us) per byte transaction.  That is what §III.B's 93.54 ms
  figure works out to, so we reproduce it verbatim.
* ``*_wire8n1_*`` -- the bit-accurate physical framing: a byte on a
  9600-8N1 wire occupies start + 8 data + stop = TEN bit times
  (1.0417 ms/byte).  Exactly 10x the paper model, by construction.

``framing_bits_per_txn`` records the reconciliation: the paper model
charges 1 bit per transaction where the wire charges 10 -- the figures
are two models of the same transaction count, not an inconsistency in
the count itself (the count, 898, is shared and exact).
"""
from __future__ import annotations

from typing import Dict

from repro.core import uart
from repro.core.registers import (
    BAUD, BIT_TIME_S, BYTE_TIME_8N1_S, TimingModel, transaction_breakdown,
)


def run() -> Dict:
    bd74 = transaction_breakdown(74)
    bd1 = transaction_breakdown(1)
    out = {
        "bench": "uart reprogram cost (paper §III.B)",
        "baud": BAUD,
        "bit_time_us": round(BIT_TIME_S * 1e6, 2),              # 104.17
        "framing_bits_per_txn_papermodel": 1,                   # paper's charge
        "framing_bits_per_txn_wire8n1": 10,                     # start+8+stop
        "wire8n1_vs_papermodel_ratio": BYTE_TIME_8N1_S / BIT_TIME_S,  # 10.0
        "74n_cl_txns": bd74.connection_list,          # paper: 740
        "74n_threshold_txns": bd74.thresholds,        # paper: 74
        "74n_weight_txns": bd74.weights,              # paper: 74
        "74n_impulse_txns": bd74.impulses,            # paper: 10
        "74n_total_txns": bd74.total,                 # paper: 898
        # paper model: 1 bit-time per transaction (reproduces §III.B 93.54 ms)
        "74n_time_ms_papermodel": bd74.time_s(TimingModel.PAPER) * 1e3,
        "1n_total_txns": bd1.total,                   # paper: 4
        "1n_time_us_papermodel": bd1.time_s(TimingModel.PAPER) * 1e6,  # 416.68
        # physical 8N1 framing: 10 bit-times per byte (10x the paper model)
        "74n_time_ms_wire8n1": bd74.time_s(TimingModel.WIRE_8N1) * 1e3,
    }
    # Scaling: the CL register dominates O(N^2/8); show the paper's
    # bottleneck growing, and the modern-link replacement cost.  All
    # wall-clock columns here use the physical wire-8N1 model.
    for n in (74, 256, 1024, 65536):
        bd = transaction_breakdown(n)
        out[f"{n}n_total_bytes"] = bd.total
        out[f"{n}n_uart_wire8n1_s"] = bd.time_s(TimingModel.WIRE_8N1)
        out[f"{n}n_pcie16GBps_s"] = uart.scaled_reprogram_time(bd.total)
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
