"""The paper's UART transaction table (§III.B), reproduced exactly, plus
the scaling the paper's future-work section motivates."""
from __future__ import annotations

from typing import Dict

from repro.core import uart
from repro.core.registers import TimingModel, transaction_breakdown


def run() -> Dict:
    bd74 = transaction_breakdown(74)
    bd1 = transaction_breakdown(1)
    out = {
        "bench": "uart reprogram cost (paper §III.B)",
        "74n_cl_txns": bd74.connection_list,          # paper: 740
        "74n_threshold_txns": bd74.thresholds,        # paper: 74
        "74n_weight_txns": bd74.weights,              # paper: 74
        "74n_impulse_txns": bd74.impulses,            # paper: 10
        "74n_total_txns": bd74.total,                 # paper: 898
        "74n_time_ms_paper": bd74.time_s(TimingModel.PAPER) * 1e3,   # 93.54
        "1n_total_txns": bd1.total,                   # paper: 4
        "1n_time_us_paper": bd1.time_s(TimingModel.PAPER) * 1e6,     # 416.68
        "74n_time_ms_wire8n1": bd74.time_s(TimingModel.WIRE_8N1) * 1e3,
    }
    # Scaling: the CL register dominates O(N^2/8); show the paper's
    # bottleneck growing, and the modern-link replacement cost.
    for n in (74, 256, 1024, 65536):
        bd = transaction_breakdown(n)
        out[f"{n}n_total_bytes"] = bd.total
        out[f"{n}n_uart_s"] = bd.time_s(TimingModel.WIRE_8N1)
        out[f"{n}n_pcie16GBps_s"] = uart.scaled_reprogram_time(bd.total)
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
