"""Paper Table III analogue: the 74-neuron MNIST-8x8 system."""
from __future__ import annotations

import time
from typing import Dict


from repro.configs import get_bundle
from repro.core import classifier
from repro.core.registers import TimingModel, transaction_breakdown
from repro.data import mnist


def run() -> Dict:
    cfg = get_bundle("mnist-snn").model
    x, y = mnist.load(n_per_class=40, seed=0)
    s = mnist.to_spikes(x)
    n_test = len(y) // 5
    xtr, ytr, xte, yte = s[n_test:], y[n_test:], s[:n_test], y[:n_test]

    t0 = time.time()
    model = classifier.train(xtr, ytr, cfg)
    train_s = time.time() - t0

    dep = classifier.deploy(model, n_neurons=cfg.n_neurons)
    pred = classifier.predict_int(dep, xte)
    acc = classifier.accuracy(pred, yte)
    per_class = {d: float((pred[yte == d] == d).mean()) for d in range(10)}

    # The paper's §III.B register-update cost for this exact system:
    bd_paper = transaction_breakdown(74)  # per-neuron weight layout: 898
    return {
        "bench": "mnist-8x8 (paper Table III analogue)",
        "n_neurons": 74,
        "test_acc_int": acc,
        "all_classes_recognized": all(v > 0 for v in per_class.values()),
        "per_class_acc": per_class,
        "paper_txn_total": bd_paper.total,
        "paper_reprogram_ms": bd_paper.time_s(TimingModel.PAPER) * 1e3,
        "wire_8n1_reprogram_ms": bd_paper.time_s(TimingModel.WIRE_8N1) * 1e3,
        "per_synapse_reprogram_bytes": dep.bank.breakdown().total,
        "inference_latency_cycles@100MHz": 5,
        "train_s": train_s,
    }


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
