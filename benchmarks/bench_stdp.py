"""Learning-tick vs inference-tick throughput (plasticity subsystem cost).

The FLOP model: an inference tick is one masked matmul (2*B*K*N); a
learning tick adds the two batch-contracted outer products of pair STDP
(2 * 2*B*K*N) plus elementwise trace/clip work -- a ~3x FLOP multiplier,
but the fused kernel keeps it to one extra HBM round-trip for (w, elig),
so the *measured* overhead on real hardware should sit well under 3x for
the bandwidth-bound small-N regime (the FPGA's regime; NeuroCoreX charges
zero extra cycles by co-locating the MAC with the synapse cell).

CPU wall-times here are structure, not speed (interpret-mode Pallas is
not benchmarked -- it is a correctness vehicle); the jnp path is jitted
and representative of relative scan-loop cost.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import connectivity
from repro.core.lif import LIFParams
from repro.core.network import SNNParams, SNNState, learning_rollout, rollout
from repro.plasticity import PlasticityParams, PlasticityState


def _time(fn, *args, repeats=10):
    jax.block_until_ready(fn(*args))  # compile
    jax.block_until_ready(fn(*args))  # warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats


def run() -> Dict:
    out: Dict = {"bench": "stdp learning-tick vs inference-tick"}
    ticks, b = 32, 16
    pp = PlasticityParams.make("stdp", a_plus=0.1, a_minus=0.05, w_max=255.0)
    ppr = PlasticityParams.make("rstdp", a_plus=0.1, a_minus=0.05, w_max=255.0)
    for n in (74, 256, 1024):
        rng = np.random.default_rng(n)
        c = connectivity.sparse_random(n, 0.5, seed=1).astype(np.float32)
        params = SNNParams(
            w=jnp.asarray(rng.uniform(0, 16, (n, n)), jnp.float32),
            c=jnp.asarray(c),
            w_in=jnp.eye(n, dtype=jnp.float32),
            lif=LIFParams.make(n, v_th=8.0, leak=1.0))
        ext = jnp.asarray(
            (rng.random((ticks, b, n)) < 0.05).astype(np.float32))
        state = SNNState.zeros((b,), n)
        pstate = PlasticityState.zeros((b,), n)

        infer = jax.jit(lambda p, s, e: rollout(p, s, e, ticks)[1])
        learn = jax.jit(lambda p, s, ps, e: learning_rollout(
            p, s, ps, e, ticks, plasticity=pp)[1])
        learn_r = jax.jit(lambda p, s, ps, e: learning_rollout(
            p, s, ps, e, ticks, plasticity=ppr)[1])

        t_inf = _time(infer, params, state, ext)
        t_stdp = _time(learn, params, state, pstate, ext)
        t_rstdp = _time(learn_r, params, state, pstate, ext)

        inf_flops = 2 * b * n * n * ticks
        learn_flops = 3 * inf_flops  # + 2 outer products per tick
        out[f"n{n}_infer_ticks_per_s"] = round(ticks * b / t_inf, 1)
        out[f"n{n}_stdp_ticks_per_s"] = round(ticks * b / t_stdp, 1)
        out[f"n{n}_rstdp_ticks_per_s"] = round(ticks * b / t_rstdp, 1)
        out[f"n{n}_stdp_overhead_x"] = round(t_stdp / t_inf, 2)
        out[f"n{n}_rstdp_overhead_x"] = round(t_rstdp / t_inf, 2)
        out[f"n{n}_flop_model_overhead_x"] = round(learn_flops / inf_flops, 2)
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
