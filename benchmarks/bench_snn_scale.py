"""Scaling the universal interconnect: backend sweep + cost model.

Three readouts, one file (``BENCH_snn_scale.json`` when run as a script):

* **Backend sweep** -- ticks/sec and recompile counts of the TickEngine
  rollout across ``jnp`` (reference), ``pallas`` (fused matmul+LIF),
  ``pallas_fused`` (the whole-tick megakernel, one launch per tick) and
  ``event`` (event-driven sparse dispatch) for n in {256, 1024, 4096}
  with a live 4-slot delay ring. On TPU the megakernel is the dense
  headline (the all-to-all O(n^2) tick is the scaling wall); on CPU the
  Pallas kernels run in interpret mode: wall-times are structure, not
  speed -- what CI gates on is *parity* (every backend bit-exact vs
  jnp) and *recompiles == 0* (advancing the scalar-prefetched delay
  pointer must never retrace).

* **Sparse operating point** -- the event backend's reason to exist:
  n from the ``snn-event`` bundle (4096 full / 1024 fast), density and
  input rate <= 0.05. Dense backends pay ``B*n*n`` regardless of
  activity; event dispatch pays ``B*k*n``, and this section *measures*
  the win (``*_sparse_event_win_vs_*`` keys) with the same bit-parity
  and zero-recompile gates as the dense sweep.

* **Telemetry overhead** -- the observability gate: the jnp rollout at
  n=1024 timed with the carry-resident :class:`TickTelemetry` off vs on;
  the on/off ticks-per-sec ratio is gated (>= 0.9, i.e. <10% overhead)
  and parity stays bitwise (raster unchanged, on-device spike counter ==
  raster sum).

* **Cost model** -- the paper Table I analogue: per-tick FLOPs/bytes of
  the masked synaptic matmul as N grows, the event-driven dispatch win
  at realistic spike rates, and the 64k-neuron per-chip budget.

Parity is gated *bitwise* (``np.array_equal`` on rasters). To make that
robust to reduction order at any n, sweep weights live on a dyadic grid
(u8 integers x a power-of-two scale -- the paper's register domain):
every synaptic sum is then exact in f32, so any summation order -- the
dense dot, the K-tiled Pallas accumulator, the event path's
spikes-ascending gather -- produces the identical bits.

  PYTHONPATH=src python benchmarks/bench_snn_scale.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ref import spike_matmul_ref

BACKENDS = ("jnp", "pallas", "pallas_fused", "event")


def _dyadic_weights(rng, n: int, *, scale_target: Optional[float] = None):
    """u8-grid weights: integers in [0, 255] x a power-of-two scale near
    ``2/sqrt(n)``. Sums of <= n terms stay exact in f32 (the grid spans
    < 2^24 ulps), so every backend's reduction order yields identical
    bits -- the parity gates test dispatch, not summation trees."""
    if scale_target is None:
        scale_target = 2.0 / np.sqrt(n)
    scale = 2.0 ** round(np.log2(scale_target))
    return (rng.integers(0, 256, (n, n)) * (2.0 ** -7) * scale).astype(
        np.float32)


def _sweep_case(n: int, *, batch: int, max_delay: int, seed: int,
                density: float = 0.5, w_scale_div: float = 1.0):
    from repro.core import connectivity
    from repro.core.lif import LIFParams
    from repro.core.network import SNNParams, SNNState

    rng = np.random.default_rng(seed)
    c = connectivity.sparse_random(n, density, seed=seed)
    params = SNNParams(
        w=jnp.asarray(
            _dyadic_weights(rng, n,
                            scale_target=2.0 / np.sqrt(n) / w_scale_div),
            jnp.float32),
        c=jnp.asarray(c, jnp.float32),
        w_in=jnp.eye(n, dtype=jnp.float32),
        lif=LIFParams.make(n, v_th=1.0, leak=0.1, r_ref=1),
    )
    state = SNNState.zeros((batch,), n, max_delay=max_delay)
    return params, state


def _bench_backend(
    backend: str, params, state, ext, n_ticks: int, reps: int,
) -> Tuple[Dict, jax.Array]:
    """Time a jitted rollout; returns (metrics, raster).

    The compile counter is a trace-time side effect (the convention from
    ``launch.serve.SNNServer``): the wrapped body only runs when jit
    traces, so ``traces - 1`` after warmup + timed reps + a tick-offset
    re-run is the recompile count -- pinned to 0.
    """
    from repro.core.network import rollout

    traces = {"n": 0}

    def fn(p, st, e):
        traces["n"] += 1
        return rollout(p, st, e, n_ticks, backend=backend)

    jfn = jax.jit(fn)
    final, raster = jfn(params, state, ext)          # warmup == the 1 compile
    jax.block_until_ready(raster)
    t0 = time.perf_counter()
    for _ in range(reps):
        final2, raster = jfn(params, state, ext)
        jax.block_until_ready(raster)
    wall = time.perf_counter() - t0
    # Advancing the circular delay pointer (tick offset) must hit the cache:
    # the pointer is a runtime scalar (scalar prefetch), never a constant.
    _, raster_off = jfn(params, final, ext)
    jax.block_until_ready(raster_off)
    metrics = {
        "ticks_per_s": round(n_ticks * reps / max(1e-9, wall), 2),
        "wall_s_per_rollout": round(wall / reps, 4),
        "recompiles": traces["n"] - 1,
    }
    return metrics, raster


def _sparse_sweep(fast: bool = True) -> Dict:
    """The event backend's operating point: large n, density <= 0.05,
    input rate <= 0.05 (from the ``snn-event`` bundle).

    Dense backends pay the full ``B*n*n`` masked matmul per tick here;
    event dispatch gathers only spiking fan-outs. The gated win
    (``*_event_win_vs_pallas_fused``, asserted > 1) compares the two
    TPU-shaped backends structure-for-structure at their shared
    operating point. The ``*_event_win_vs_jnp`` ratio is recorded but
    *not* asserted: on CPU the "dense" jnp tick is an Eigen GEMM while
    XLA lowers the event path's gathers to scalar loops, so the FLOP win
    (8x at n=4096) does not survive as CPU wall-clock -- on TPU the
    event kernel's DMA-steered gathers are the whole point. Parity is
    bitwise at every size (dyadic-grid weights)."""
    from repro.configs import get_bundle

    bundle = get_bundle("snn-event")
    cfg = bundle.smoke if fast else bundle.model
    n = cfg.n_neurons
    density, rate = cfg.snn_density, cfg.snn_rate
    n_ticks, batch, max_delay, reps = 8, 16, 4, 2
    # "pallas" adds nothing over "pallas_fused" at this point; skip it.
    backends = ("jnp", "pallas_fused", "event")

    out: Dict = {
        "sparse_n": n,
        "sparse_density": density,
        "sparse_rate": rate,
        "sparse_n_ticks": n_ticks,
    }
    # w_scale_div keeps the recurrent fabric *subcritical* (expected
    # per-tick synaptic drive below the leak), so the network actually
    # runs at the claimed rate instead of amplifying toward saturation --
    # the measured mean_spike_rate key pins it.
    params, state = _sweep_case(n, batch=batch, max_delay=max_delay,
                                seed=n + 1, density=density, w_scale_div=8.0)
    rng = np.random.default_rng(2)
    ext = jnp.asarray(
        (rng.random((n_ticks, batch, n)) < rate).astype(np.float32))
    rasters = {}
    for backend in backends:
        metrics, raster = _bench_backend(
            backend, params, state, ext, n_ticks, reps)
        rasters[backend] = np.asarray(raster)
        for k, v in metrics.items():
            out[f"n{n}_sparse_{backend}_{k}"] = v
    out[f"n{n}_sparse_mean_spike_rate"] = round(
        float(rasters["event"].mean()), 4)
    for backend in backends:
        if backend != "jnp":
            out[f"n{n}_sparse_{backend}_exact"] = bool(
                np.array_equal(rasters[backend], rasters["jnp"]))
    for other in ("jnp", "pallas", "pallas_fused"):
        key = f"n{n}_sparse_{other}_ticks_per_s"
        if key in out:
            out[f"n{n}_sparse_event_win_vs_{other}"] = round(
                out[f"n{n}_sparse_event_ticks_per_s"] / out[key], 3)

    # The same CI contract as the dense sweep, at the sparse point.
    for backend in backends:
        if backend != "jnp":
            assert out[f"n{n}_sparse_{backend}_exact"], (
                f"{backend} diverged from jnp at sparse n={n}")
        assert out[f"n{n}_sparse_{backend}_recompiles"] == 0, (
            f"{backend} retraced at sparse n={n}")
    assert out[f"n{n}_sparse_event_win_vs_pallas_fused"] > 1.0, (
        "event dispatch failed to beat the whole-tick megakernel at the "
        f"sparse point: {out[f'n{n}_sparse_event_win_vs_pallas_fused']}x")
    return out


def _telemetry_overhead(reps: int = 9) -> Dict:
    """The observability layer's CI gate: telemetry-on ticks/s must stay
    within 10% of telemetry-off at the gate point (n=1024, jnp backend
    -- the reference datapath both CI platforms actually *time*;
    interpret-mode Pallas wall-clock is structure, not speed).

    Telemetry costs one extra reduce kernel per tick (the variadic
    reduce in :meth:`TickTelemetry.accumulate`) against the
    weights-dominated n^2 synaptic matmul -- a few percent at the gate
    point. The measurement is built for noisy shared CI runners:
    off/on rollouts are timed in interleaved pairs (runner-speed drift
    hits both sides of a pair equally) and the gated ratio is the
    *median* of the per-pair ratios. The
    ``n1024_telemetry_on_off_ratio`` key is gated in check_regression.py
    as a *policy floor* (baseline 0.9 == the <10% budget; --refresh
    preserves it instead of snapshotting a lucky run)."""
    from repro.core.network import rollout

    n, batch, n_ticks, max_delay = 1024, 4, 8, 4
    params, state = _sweep_case(n, batch=batch, max_delay=max_delay, seed=7)
    rng = np.random.default_rng(3)
    ext = jnp.asarray(
        (rng.random((n_ticks, batch, n)) < 0.1).astype(np.float32))

    off = jax.jit(lambda p, st, e: rollout(p, st, e, n_ticks, backend="jnp"))
    on = jax.jit(lambda p, st, e: rollout(p, st, e, n_ticks, backend="jnp",
                                          telemetry=True))
    step_off = lambda: jax.block_until_ready(off(params, state, ext))
    step_on = lambda: jax.block_until_ready(on(params, state, ext))
    step_off(), step_on()                        # warmup == the compiles
    wall_off = wall_on = float("inf")
    ratios = []
    for _ in range(reps):
        t0 = time.perf_counter()
        step_off()
        w_off = time.perf_counter() - t0
        t0 = time.perf_counter()
        step_on()
        w_on = time.perf_counter() - t0
        wall_off = min(wall_off, w_off)
        wall_on = min(wall_on, w_on)
        ratios.append(w_off / w_on)
    _, r_off = off(params, state, ext)
    _, r_on, telem = on(params, state, ext)
    out = {
        "n1024_telem_off_ticks_per_s": round(n_ticks / wall_off, 2),
        "n1024_telem_on_ticks_per_s": round(n_ticks / wall_on, 2),
        "n1024_telemetry_on_off_ratio": round(
            float(np.median(ratios)), 3),
        "n1024_telemetry_raster_exact": bool(
            np.array_equal(np.asarray(r_off), np.asarray(r_on))),
        # On-device spike counter == the raster's own sum, bit-for-bit.
        "n1024_telemetry_spikes_exact": bool(np.array_equal(
            np.asarray(telem.spikes), np.asarray(r_on).sum(axis=(0, 2)))),
    }
    assert out["n1024_telemetry_raster_exact"], (
        "telemetry perturbed the raster")
    assert out["n1024_telemetry_spikes_exact"], (
        "on-device spike count != raster sum")
    return out


def run(fast: bool = True, ns: Optional[Tuple[int, ...]] = None) -> Dict:
    from repro.configs import get_bundle

    bundle = get_bundle("snn-fused")
    cfg = bundle.smoke if fast else bundle.model
    on_tpu = jax.default_backend() == "tpu"
    if ns is None:
        # CPU interpret mode exists for correctness, not speed: the full
        # sweep (up to the snn-fused FULL fabric) is a TPU run.
        ns = (256, 1024, 4096) if (on_tpu or not fast) else (cfg.n_neurons,)
    n_ticks = cfg.n_ticks
    batch, max_delay, reps = 16, 4, (2 if fast else 5)

    assert cfg.snn_backend in BACKENDS, (
        f"snn-fused config names unknown backend {cfg.snn_backend!r}")
    out: Dict = {
        "bench": "snn scaling: backend sweep + paper Table I cost model",
        "backend_platform": jax.default_backend(),
        "configured_backend": cfg.snn_backend,   # what the arch serves with
        "n_ticks": n_ticks,
        "batch": batch,
        "max_delay": max_delay,
    }
    rng = np.random.default_rng(1)
    for n in ns:
        params, state = _sweep_case(n, batch=batch, max_delay=max_delay, seed=n)
        ext = jnp.asarray(
            (rng.random((n_ticks, batch, n)) < 0.1).astype(np.float32))
        rasters = {}
        for backend in BACKENDS:
            metrics, raster = _bench_backend(
                backend, params, state, ext, n_ticks, reps)
            rasters[backend] = np.asarray(raster)
            for k, v in metrics.items():
                out[f"n{n}_{backend}_{k}"] = v
        for backend in BACKENDS[1:]:
            out[f"n{n}_{backend}_exact"] = bool(
                np.array_equal(rasters[backend], rasters["jnp"]))
        if out.get(f"n{n}_pallas_ticks_per_s"):
            out[f"n{n}_fused_speedup_vs_pallas"] = round(
                out[f"n{n}_pallas_fused_ticks_per_s"]
                / out[f"n{n}_pallas_ticks_per_s"], 3)

    # CI contract (CPU or TPU): every backend bit-exact, zero recompiles.
    for n in ns:
        for backend in BACKENDS[1:]:
            assert out[f"n{n}_{backend}_exact"], (
                f"{backend} diverged from jnp at n={n}")
        for backend in BACKENDS:
            assert out[f"n{n}_{backend}_recompiles"] == 0, (
                f"{backend} retraced at n={n}")

    out.update(_sparse_sweep(fast=fast))
    out.update(_telemetry_overhead(reps=(9 if fast else 15)))

    # -- paper Table I cost model (kept from the seed bench) ---------------
    for n in (74, 256, 1024):
        b, rate = 32, 0.05
        s = (rng.random((b, n)) < rate).astype(np.float32)
        w = rng.normal(size=(n, n)).astype(np.float32)
        c = (rng.random((n, n)) < 0.5).astype(np.float32)
        dense_flops = 2 * b * n * n
        k_active = max(8, int(2 * rate * n))
        event_flops = 2 * b * k_active * n
        got = ops.event_spike_matmul(jnp.asarray(s), jnp.asarray(w),
                                     jnp.asarray(c), k_active=k_active)
        want = spike_matmul_ref(jnp.asarray(s), jnp.asarray(w), jnp.asarray(c))
        out[f"n{n}_dense_flops_per_tick"] = dense_flops
        out[f"n{n}_event_flops_per_tick"] = event_flops
        out[f"n{n}_event_speedup_model"] = dense_flops / event_flops
        # (renamed from n{n}_event_exact, which now names the *sweep*'s
        # event-backend raster parity at the same n)
        out[f"n{n}_event_model_exact"] = bool(np.allclose(got, want, rtol=1e-4,
                                                          atol=1e-4))
        out[f"n{n}_synapse_bytes_u8"] = n * n
        out[f"n{n}_spike_bytes_per_tick"] = b * n  # what the mux fabric moves
    # 64k-neuron production core, per-tick cost model on the (16,16) mesh
    n, b = 65536, 256
    out["n65536_synapse_GB_u8"] = n * n / 2**30
    out["n65536_dense_TFLOPs_per_tick"] = 2 * b * n * n / 1e12
    out["n65536_per_chip_MB_u8_256chips"] = n * n / 256 / 2**20
    return out


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke sizes only (what CPU CI runs)")
    ap.add_argument("--out", default="BENCH_snn_scale.json")
    args = ap.parse_args(argv)
    res = run(fast=args.fast)
    for k, v in res.items():
        print(f"{k}: {v}")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {args.out}")
    return res


if __name__ == "__main__":
    main()
