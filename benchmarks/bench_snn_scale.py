"""Scaling the universal interconnect: backend sweep + cost model.

Two readouts, one file (``BENCH_snn_scale.json`` when run as a script):

* **Backend sweep** -- ticks/sec and recompile counts of the TickEngine
  rollout across ``jnp`` (reference), ``pallas`` (fused matmul+LIF) and
  ``pallas_fused`` (the whole-tick megakernel, one launch per tick) for
  n in {256, 1024, 4096} with a live 4-slot delay ring. On TPU the
  megakernel is the headline (the all-to-all O(n^2) tick is the scaling
  wall; fusing the whole circuit removes the inter-phase HBM
  round-trips). On CPU the kernels run in interpret mode: wall-times are
  structure, not speed -- what CI gates on is *parity* (every backend
  bit-exact vs jnp) and *recompiles == 0* (advancing the scalar-
  prefetched delay pointer must never retrace).

* **Cost model** -- the paper Table I analogue: per-tick FLOPs/bytes of
  the masked synaptic matmul as N grows, the event-driven dispatch win
  at realistic spike rates, and the 64k-neuron per-chip budget.

  PYTHONPATH=src python benchmarks/bench_snn_scale.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ref import spike_matmul_ref

BACKENDS = ("jnp", "pallas", "pallas_fused")


def _sweep_case(n: int, *, batch: int, max_delay: int, seed: int):
    from repro.core import connectivity
    from repro.core.lif import LIFParams
    from repro.core.network import SNNParams, SNNState

    rng = np.random.default_rng(seed)
    c = connectivity.sparse_random(n, 0.5, seed=seed)
    params = SNNParams(
        w=jnp.asarray(rng.uniform(0, 2.0 / np.sqrt(n), (n, n)), jnp.float32),
        c=jnp.asarray(c, jnp.float32),
        w_in=jnp.eye(n, dtype=jnp.float32),
        lif=LIFParams.make(n, v_th=1.0, leak=0.1, r_ref=1),
    )
    state = SNNState.zeros((batch,), n, max_delay=max_delay)
    return params, state


def _bench_backend(
    backend: str, params, state, ext, n_ticks: int, reps: int,
) -> Tuple[Dict, jax.Array]:
    """Time a jitted rollout; returns (metrics, raster).

    The compile counter is a trace-time side effect (the convention from
    ``launch.serve.SNNServer``): the wrapped body only runs when jit
    traces, so ``traces - 1`` after warmup + timed reps + a tick-offset
    re-run is the recompile count -- pinned to 0.
    """
    from repro.core.network import rollout

    traces = {"n": 0}

    def fn(p, st, e):
        traces["n"] += 1
        return rollout(p, st, e, n_ticks, backend=backend)

    jfn = jax.jit(fn)
    final, raster = jfn(params, state, ext)          # warmup == the 1 compile
    jax.block_until_ready(raster)
    t0 = time.perf_counter()
    for _ in range(reps):
        final2, raster = jfn(params, state, ext)
        jax.block_until_ready(raster)
    wall = time.perf_counter() - t0
    # Advancing the circular delay pointer (tick offset) must hit the cache:
    # the pointer is a runtime scalar (scalar prefetch), never a constant.
    _, raster_off = jfn(params, final, ext)
    jax.block_until_ready(raster_off)
    metrics = {
        "ticks_per_s": round(n_ticks * reps / max(1e-9, wall), 2),
        "wall_s_per_rollout": round(wall / reps, 4),
        "recompiles": traces["n"] - 1,
    }
    return metrics, raster


def run(fast: bool = True, ns: Optional[Tuple[int, ...]] = None) -> Dict:
    from repro.configs import get_bundle

    bundle = get_bundle("snn-fused")
    cfg = bundle.smoke if fast else bundle.model
    on_tpu = jax.default_backend() == "tpu"
    if ns is None:
        # CPU interpret mode exists for correctness, not speed: the full
        # sweep (up to the snn-fused FULL fabric) is a TPU run.
        ns = (256, 1024, 4096) if (on_tpu or not fast) else (cfg.n_neurons,)
    n_ticks = cfg.n_ticks
    batch, max_delay, reps = 16, 4, (2 if fast else 5)

    assert cfg.snn_backend in BACKENDS, (
        f"snn-fused config names unknown backend {cfg.snn_backend!r}")
    out: Dict = {
        "bench": "snn scaling: backend sweep + paper Table I cost model",
        "backend_platform": jax.default_backend(),
        "configured_backend": cfg.snn_backend,   # what the arch serves with
        "n_ticks": n_ticks,
        "batch": batch,
        "max_delay": max_delay,
    }
    rng = np.random.default_rng(1)
    for n in ns:
        params, state = _sweep_case(n, batch=batch, max_delay=max_delay, seed=n)
        ext = jnp.asarray(
            (rng.random((n_ticks, batch, n)) < 0.1).astype(np.float32))
        rasters = {}
        for backend in BACKENDS:
            metrics, raster = _bench_backend(
                backend, params, state, ext, n_ticks, reps)
            rasters[backend] = np.asarray(raster)
            for k, v in metrics.items():
                out[f"n{n}_{backend}_{k}"] = v
        for backend in ("pallas", "pallas_fused"):
            out[f"n{n}_{backend}_exact"] = bool(
                np.array_equal(rasters[backend], rasters["jnp"]))
        if out.get(f"n{n}_pallas_ticks_per_s"):
            out[f"n{n}_fused_speedup_vs_pallas"] = round(
                out[f"n{n}_pallas_fused_ticks_per_s"]
                / out[f"n{n}_pallas_ticks_per_s"], 3)

    # CI contract (CPU or TPU): every backend bit-exact, zero recompiles.
    for n in ns:
        for backend in ("pallas", "pallas_fused"):
            assert out[f"n{n}_{backend}_exact"], (
                f"{backend} diverged from jnp at n={n}")
        for backend in BACKENDS:
            assert out[f"n{n}_{backend}_recompiles"] == 0, (
                f"{backend} retraced at n={n}")

    # -- paper Table I cost model (kept from the seed bench) ---------------
    for n in (74, 256, 1024):
        b, rate = 32, 0.05
        s = (rng.random((b, n)) < rate).astype(np.float32)
        w = rng.normal(size=(n, n)).astype(np.float32)
        c = (rng.random((n, n)) < 0.5).astype(np.float32)
        dense_flops = 2 * b * n * n
        k_active = max(8, int(2 * rate * n))
        event_flops = 2 * b * k_active * n
        got = ops.event_spike_matmul(jnp.asarray(s), jnp.asarray(w),
                                     jnp.asarray(c), k_active=k_active)
        want = spike_matmul_ref(jnp.asarray(s), jnp.asarray(w), jnp.asarray(c))
        out[f"n{n}_dense_flops_per_tick"] = dense_flops
        out[f"n{n}_event_flops_per_tick"] = event_flops
        out[f"n{n}_event_speedup_model"] = dense_flops / event_flops
        out[f"n{n}_event_exact"] = bool(np.allclose(got, want, rtol=1e-4,
                                                    atol=1e-4))
        out[f"n{n}_synapse_bytes_u8"] = n * n
        out[f"n{n}_spike_bytes_per_tick"] = b * n  # what the mux fabric moves
    # 64k-neuron production core, per-tick cost model on the (16,16) mesh
    n, b = 65536, 256
    out["n65536_synapse_GB_u8"] = n * n / 2**30
    out["n65536_dense_TFLOPs_per_tick"] = 2 * b * n * n / 1e12
    out["n65536_per_chip_MB_u8_256chips"] = n * n / 256 / 2**20
    return out


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke sizes only (what CPU CI runs)")
    ap.add_argument("--out", default="BENCH_snn_scale.json")
    args = ap.parse_args(argv)
    res = run(fast=args.fast)
    for k, v in res.items():
        print(f"{k}: {v}")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {args.out}")
    return res


if __name__ == "__main__":
    main()
