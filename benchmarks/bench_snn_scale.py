"""Scaling the universal interconnect (paper Table I analogue + DESIGN §4).

Paper Table I reports per-neuron LUT/register cost growing with fan-in.
Our TPU analogue: per-tick FLOPs/bytes of the sharded masked synaptic
matmul as N grows, plus the beyond-paper event-driven dispatch win at
realistic spike rates (the mux fabric "routing zeros" vs skipping them).
Wall-times here are CPU-interpret numbers (structure, not speed); the
FLOP/byte model is the hardware-relevant output.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ref import spike_matmul_ref


def run() -> Dict:
    rng = np.random.default_rng(0)
    out: Dict = {"bench": "snn scaling (paper Table I analogue)"}
    for n in (74, 256, 1024):
        b = 32
        rate = 0.05
        s = (rng.random((b, n)) < rate).astype(np.float32)
        w = rng.normal(size=(n, n)).astype(np.float32)
        c = (rng.random((n, n)) < 0.5).astype(np.float32)

        dense_flops = 2 * b * n * n
        k_active = max(8, int(2 * rate * n))
        event_flops = 2 * b * k_active * n
        got = ops.event_spike_matmul(jnp.asarray(s), jnp.asarray(w),
                                     jnp.asarray(c), k_active=k_active)
        want = spike_matmul_ref(jnp.asarray(s), jnp.asarray(w), jnp.asarray(c))
        exact = bool(np.allclose(got, want, rtol=1e-4, atol=1e-4))

        out[f"n{n}_dense_flops_per_tick"] = dense_flops
        out[f"n{n}_event_flops_per_tick"] = event_flops
        out[f"n{n}_event_speedup_model"] = dense_flops / event_flops
        out[f"n{n}_event_exact"] = exact
        out[f"n{n}_synapse_bytes_u8"] = n * n
        out[f"n{n}_spike_bytes_per_tick"] = b * n  # what the mux fabric moves
    # 64k-neuron production core, per-tick cost model on the (16,16) mesh
    n, b = 65536, 256
    out["n65536_synapse_GB_u8"] = n * n / 2**30
    out["n65536_dense_TFLOPs_per_tick"] = 2 * b * n * n / 1e12
    out["n65536_per_chip_MB_u8_256chips"] = n * n / 256 / 2**20
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
