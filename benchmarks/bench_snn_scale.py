"""Scaling the universal interconnect: backend sweep + cost model.

Three readouts, one file (``BENCH_snn_scale.json`` when run as a script):

* **Backend sweep** -- ticks/sec and recompile counts of the TickEngine
  rollout across ``jnp`` (reference), ``pallas`` (fused matmul+LIF),
  ``pallas_fused`` (the whole-tick megakernel, one launch per tick) and
  ``event`` (event-driven sparse dispatch) for n in {256, 1024, 4096}
  with a live 4-slot delay ring. On TPU the megakernel is the dense
  headline (the all-to-all O(n^2) tick is the scaling wall); on CPU the
  Pallas kernels run in interpret mode: wall-times are structure, not
  speed -- what CI gates on is *parity* (every backend bit-exact vs
  jnp) and *recompiles == 0* (advancing the scalar-prefetched delay
  pointer must never retrace).

* **Sparse operating point** -- the event backend's reason to exist:
  n from the ``snn-event`` bundle (4096 full / 1024 fast), density and
  input rate <= 0.05. Dense backends pay ``B*n*n`` regardless of
  activity; event dispatch pays ``B*k*n``, and this section *measures*
  the win (``*_sparse_event_win_vs_*`` keys) with the same bit-parity
  and zero-recompile gates as the dense sweep.

* **Telemetry overhead** -- the observability gate: the jnp rollout at
  n=1024 timed with the carry-resident :class:`TickTelemetry` off vs on;
  the on/off ticks-per-sec ratio is gated (>= 0.9, i.e. <10% overhead)
  and parity stays bitwise (raster unchanged, on-device spike counter ==
  raster sum).

* **Cost model** -- the paper Table I analogue: per-tick FLOPs/bytes of
  the masked synaptic matmul as N grows, the event-driven dispatch win
  at realistic spike rates, and the 64k-neuron per-chip budget.

Parity is gated *bitwise* (``np.array_equal`` on rasters). To make that
robust to reduction order at any n, sweep weights live on a dyadic grid
(u8 integers x a power-of-two scale -- the paper's register domain):
every synaptic sum is then exact in f32, so any summation order -- the
dense dot, the K-tiled Pallas accumulator, the event path's
spikes-ascending gather -- produces the identical bits.

  PYTHONPATH=src python benchmarks/bench_snn_scale.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ref import spike_matmul_ref

BACKENDS = ("jnp", "pallas", "pallas_fused", "event")


def _dyadic_weights(rng, n: int, *, scale_target: Optional[float] = None):
    """u8-grid weights: integers in [0, 255] x a power-of-two scale near
    ``2/sqrt(n)``. Sums of <= n terms stay exact in f32 (the grid spans
    < 2^24 ulps), so every backend's reduction order yields identical
    bits -- the parity gates test dispatch, not summation trees."""
    if scale_target is None:
        scale_target = 2.0 / np.sqrt(n)
    scale = 2.0 ** round(np.log2(scale_target))
    return (rng.integers(0, 256, (n, n)) * (2.0 ** -7) * scale).astype(
        np.float32)


def _sweep_case(n: int, *, batch: int, max_delay: int, seed: int,
                density: float = 0.5, w_scale_div: float = 1.0):
    from repro.core import connectivity
    from repro.core.lif import LIFParams
    from repro.core.network import SNNParams, SNNState

    rng = np.random.default_rng(seed)
    c = connectivity.sparse_random(n, density, seed=seed)
    params = SNNParams(
        w=jnp.asarray(
            _dyadic_weights(rng, n,
                            scale_target=2.0 / np.sqrt(n) / w_scale_div),
            jnp.float32),
        c=jnp.asarray(c, jnp.float32),
        w_in=jnp.eye(n, dtype=jnp.float32),
        lif=LIFParams.make(n, v_th=1.0, leak=0.1, r_ref=1),
    )
    state = SNNState.zeros((batch,), n, max_delay=max_delay)
    return params, state


def _bench_backend(
    backend: str, params, state, ext, n_ticks: int, reps: int,
    dispatch=None,
) -> Tuple[Dict, jax.Array]:
    """Time a jitted rollout; returns (metrics, raster).

    The compile counter is a trace-time side effect (the convention from
    ``launch.serve.SNNServer``): the wrapped body only runs when jit
    traces, so ``traces - 1`` after warmup + timed reps + a tick-offset
    re-run is the recompile count -- pinned to 0.

    ``dispatch`` is an optional pre-built
    :class:`~repro.core.dispatch_policy.DispatchPlan` (planned OUTSIDE
    the jit, from the concrete topology -- the policy's contract).
    """
    from repro.core.network import rollout

    traces = {"n": 0}

    def fn(p, st, e):
        traces["n"] += 1
        return rollout(p, st, e, n_ticks, backend=backend, dispatch=dispatch)

    jfn = jax.jit(fn)
    final, raster = jfn(params, state, ext)          # warmup == the 1 compile
    jax.block_until_ready(raster)
    t0 = time.perf_counter()
    for _ in range(reps):
        final2, raster = jfn(params, state, ext)
        jax.block_until_ready(raster)
    wall = time.perf_counter() - t0
    # Advancing the circular delay pointer (tick offset) must hit the cache:
    # the pointer is a runtime scalar (scalar prefetch), never a constant.
    _, raster_off = jfn(params, final, ext)
    jax.block_until_ready(raster_off)
    metrics = {
        "ticks_per_s": round(n_ticks * reps / max(1e-9, wall), 2),
        "wall_s_per_rollout": round(wall / reps, 4),
        "recompiles": traces["n"] - 1,
    }
    return metrics, raster


def _sparse_sweep(fast: bool = True) -> Dict:
    """The event backend's operating grid: n x density at the bundle's
    input rate (<= 0.05), event served through ``dispatch_policy.plan``.

    Dense backends pay the full ``B*n*n`` masked matmul per tick here
    (plus a SECOND full GEMM for the diagonal input drive ``ext @ I``);
    the planned event backend gathers only in-edges where the gather
    clears the platform's penalty and otherwise runs the dense product
    with the diagonal drive eliminated -- so ``event`` is the fastest
    backend at *every* sparse grid point, on CPU too (the ROADMAP item 3
    win condition).  Per point the sweep records
    ``n{n}_sparse_d{dd}_event_win_vs_jnp`` and ``.._vs_pallas_fused``;
    check_regression.py gates every ``*_win_vs_*`` key as a POLICY FLOOR
    (committed >= 1.0 for vs-jnp), so a policy regression that hands the
    lead back to a dense backend fails CI.  Parity stays bitwise at
    every point (dyadic-grid weights + the exact diagonal-drive
    rewrite).  The ungridded ``n{n}_sparse_*`` keys of the bundle's own
    (n, density) point are kept for baseline continuity.

    ``pallas_fused`` (whose cost is density-independent) is timed once
    per n at the bundle density and reused across the grid row -- on CPU
    it runs in interpret mode, so this is the slow part of the sweep.
    """
    from repro.configs import get_bundle
    from repro.core import dispatch_policy

    bundle = get_bundle("snn-event")
    cfg = bundle.smoke if fast else bundle.model
    rate = cfg.snn_rate
    ns = (1024, 4096)
    densities = (0.02, 0.05, 0.1)
    n_ticks, batch, max_delay = 8, 16, 4

    out: Dict = {
        "sparse_n": cfg.n_neurons,
        "sparse_density": cfg.snn_density,
        "sparse_rate": rate,
        "sparse_n_ticks": n_ticks,
        "sparse_grid_ns": list(ns),
        "sparse_grid_densities": list(densities),
    }
    for n in ns:
        # Interpret-mode pallas_fused at n=4096 is wall-clock heavy; one
        # timed rep there still yields a stable ratio (the gated floors
        # for it sit at 2.0 against measured wins of 15x+).
        reps = 2 if (n <= 1024 or not fast) else 1
        fused_tps = None
        # Bundle density first: pallas_fused is timed on that row, so the
        # legacy n{n}_sparse_pallas_fused_* aliases stay populated.
        for density in sorted(densities, key=lambda d: d != cfg.snn_density):
            dtag = f"d{int(round(density * 100)):02d}"
            tag = f"n{n}_sparse_{dtag}"
            legacy = (n == cfg.n_neurons and density == cfg.snn_density)
            # w_scale_div keeps the recurrent fabric *subcritical*
            # (expected per-tick synaptic drive below the leak), so the
            # network actually runs near the claimed rate instead of
            # amplifying toward saturation -- mean_spike_rate pins it.
            params, state = _sweep_case(
                n, batch=batch, max_delay=max_delay, seed=n + 1,
                density=density, w_scale_div=8.0)
            rng = np.random.default_rng(2)
            ext = jnp.asarray(
                (rng.random((n_ticks, batch, n)) < rate).astype(np.float32))

            # The plan is built HERE, outside jit, from the concrete
            # topology -- what serving does at tenant admission.  The
            # bundle's snn_dispatch ("auto") delegates to the policy; a
            # literal strategy string would be forwarded verbatim.
            if cfg.snn_dispatch == "auto":
                ev_dispatch = dispatch_policy.plan(
                    np.asarray(params.c), w_in=np.asarray(params.w_in),
                    batch=batch, rate=rate)
                out[f"{tag}_event_strategy"] = ev_dispatch.strategy
                out[f"{tag}_event_ext_diag"] = ev_dispatch.ext_diag
            else:
                ev_dispatch = cfg.snn_dispatch
                out[f"{tag}_event_strategy"] = cfg.snn_dispatch

            point: Dict = {}
            rasters = {}
            for backend in ("jnp", "event"):
                metrics, raster = _bench_backend(
                    backend, params, state, ext, n_ticks, reps,
                    dispatch=ev_dispatch if backend == "event" else None)
                rasters[backend] = np.asarray(raster)
                for k, v in metrics.items():
                    point[f"{backend}_{k}"] = v
            if fused_tps is None:
                # Dense megakernel: density-independent cost, timed once
                # per n (at the bundle's density row when possible).
                metrics, raster = _bench_backend(
                    "pallas_fused", params, state, ext, n_ticks, reps)
                rasters["pallas_fused"] = np.asarray(raster)
                for k, v in metrics.items():
                    point[f"pallas_fused_{k}"] = v
                fused_tps = metrics["ticks_per_s"]
                point["pallas_fused_exact"] = bool(np.array_equal(
                    rasters["pallas_fused"], rasters["jnp"]))
                assert point["pallas_fused_exact"], (
                    f"pallas_fused diverged from jnp at sparse n={n}")
                assert point["pallas_fused_recompiles"] == 0

            point["mean_spike_rate"] = round(
                float(rasters["event"].mean()), 4)
            point["event_exact"] = bool(
                np.array_equal(rasters["event"], rasters["jnp"]))
            point["event_win_vs_jnp"] = round(
                point["event_ticks_per_s"] / point["jnp_ticks_per_s"], 3)
            point["event_win_vs_pallas_fused"] = round(
                point["event_ticks_per_s"] / fused_tps, 3)

            for k, v in point.items():
                out[f"{tag}_{k}"] = v
            if legacy:
                for k, v in point.items():
                    out[f"n{n}_sparse_{k}"] = v

            # The same CI contract as the dense sweep, at every point.
            assert point["event_exact"], (
                f"event diverged from jnp at sparse n={n} d={density}")
            for backend in ("jnp", "event"):
                assert point[f"{backend}_recompiles"] == 0, (
                    f"{backend} retraced at sparse n={n} d={density}")
            assert point["event_win_vs_pallas_fused"] > 1.0, (
                "event dispatch failed to beat the whole-tick megakernel "
                f"at sparse n={n} d={density}: "
                f"{point['event_win_vs_pallas_fused']}x")
    return out


def _bench_sharded_point(n: int, mesh, *, n_ticks: int, batch: int,
                         reps: int, n_in: int = 256) -> Tuple[Dict, np.ndarray]:
    """Time a (possibly mesh-sharded) frozen jnp rollout at one fabric
    size.  ``mesh=None`` runs the plain single-device engine -- the
    weak-scaling baseline and the parity reference.

    The fabric is the implicit all-to-all (``c=None``): at 64k the
    ``(n, n)`` f32 weights are 16 GiB and the mask would be a second 16
    GiB that never needs to exist.  Weights come from
    :func:`~repro.parallel.snn_sharding.make_sharded_dyadic_weights`
    (column-block seeded, so sharded and unsharded runs see the
    identical global matrix -- and the dyadic grid keeps every
    reduction order exact, so parity is gated bitwise here too)."""
    from repro.core.engine import EngineOptions, TickEngine
    from repro.core.lif import LIFParams
    from repro.core.network import SNNParams, SNNState
    from repro.parallel import snn_sharding

    engine = TickEngine(EngineOptions(backend="jnp", mesh=mesh))
    w = snn_sharding.make_sharded_dyadic_weights(n, mesh)
    rng = np.random.default_rng(11)
    w_in = jnp.asarray(
        rng.integers(0, 8, (n_in, n)).astype(np.float32) * 0.25)
    params = SNNParams(w=w, c=None, w_in=w_in,
                       lif=LIFParams.make(n, v_th=1.0, leak=0.1, r_ref=1))
    if mesh is not None:
        rules = snn_sharding.snn_rules(mesh)
        params = snn_sharding.place(
            params, snn_sharding.params_specs(rules, params), mesh)
    state = SNNState.zeros((batch,), n)
    ext = jnp.asarray(
        (np.random.default_rng(13).random((n_ticks, batch, n_in)) < 0.1),
        jnp.float32)

    traces = {"n": 0}

    def fn(p, st, e):
        traces["n"] += 1
        return engine.rollout(p, st, e, n_ticks)

    jfn = jax.jit(fn)
    final, raster = jfn(params, state, ext)          # warmup == the 1 compile
    jax.block_until_ready(raster)
    t0 = time.perf_counter()
    for _ in range(reps):
        _, raster = jfn(params, state, ext)
        jax.block_until_ready(raster)
    wall = time.perf_counter() - t0
    # Resuming from an advanced carry (the chunked-serving hand-off) must
    # hit the cache -- shapes and statics are identical.
    _, raster_off = jfn(params, final, ext)
    jax.block_until_ready(raster_off)
    metrics = {
        "ticks_per_s": round(n_ticks * reps / max(1e-9, wall), 3),
        "wall_s_per_rollout": round(wall / reps, 4),
        "recompiles": traces["n"] - 1,
    }
    return metrics, np.asarray(raster)


def _sharded_section(fast: bool = True, n_dev: int = 8) -> Dict:
    """The configs/snn_64k.py operating point: the fabric partitioned by
    destination columns over a simulated ``n_dev``-device mesh
    (DESIGN.md §15).

    Per n this measures the 8-device sharded rollout (ticks/s, per-device
    synaptic throughput, recompiles == 0) against a single-device run at
    ``n_base ~= n / sqrt(D)`` -- same per-device memory and per-device
    work, so the **weak-scaling efficiency**

        eff = (n^2 * tps_sharded) / (n_base^2 * tps_base)

    is the fraction of aggregate synaptic throughput the partition
    retains after paying the per-tick spike all_gather.  On real meshes
    each device is its own chip; on the CI host every simulated device
    shares one CPU, so eff ~= 1.0 there and the committed 0.6 floor
    catches structural regressions (a weight operand slipping into the
    per-tick exchange tanks it).  ``sharded_n16384_weak_scaling_
    efficiency`` is gated as a policy floor in check_regression.py.

    Fast mode stops at n=16384 (1 GiB of weights -- hosted-runner safe);
    the full run adds the 65536 headline (16 GiB, 2 GiB/device).
    """
    from repro.launch.mesh import make_snn_mesh

    if len(jax.devices()) < n_dev:
        raise RuntimeError(
            f"sharded section needs {n_dev} devices, jax sees "
            f"{len(jax.devices())}; call repro.util.env."
            f"ensure_host_device_count({n_dev}) before jax initializes")
    mesh = make_snn_mesh(n_dev)
    ns = (16384,) if fast else (16384, 65536)
    # batch=4: wide enough that BLAS efficiency is comparable between
    # the sharded (n, n/D) and baseline (n_base, n_base) GEMM shapes --
    # at batch<=2 the matvec-shaped sharded product measures memory
    # subsystem quirks, not the partition.
    n_ticks, batch, reps = 8, 4, 2
    out: Dict = {
        "sharded_devices": n_dev,
        "sharded_ns": list(ns),
        "sharded_n_ticks": n_ticks,
        "sharded_batch": batch,
    }
    for n in ns:
        # Same per-device footprint as the sharded run: n_base^2 ~= n^2/D
        # synapses on one device (rounded to the weight-gen block grid).
        n_base = int(round(n / np.sqrt(n_dev) / 8) * 8)
        tag = f"sharded_n{n}_d{n_dev}"
        m, raster = _bench_sharded_point(
            n, mesh, n_ticks=n_ticks, batch=batch, reps=reps)
        out[f"{tag}_ticks_per_s"] = m["ticks_per_s"]
        out[f"{tag}_wall_s_per_rollout"] = m["wall_s_per_rollout"]
        out[f"{tag}_recompiles"] = m["recompiles"]
        out[f"{tag}_synops_per_device_per_s"] = round(
            m["ticks_per_s"] * batch * n * n / n_dev, 1)
        mb, _ = _bench_sharded_point(
            n_base, None, n_ticks=n_ticks, batch=batch, reps=reps)
        out[f"sharded_n{n}_base{n_base}_ticks_per_s"] = mb["ticks_per_s"]
        out[f"sharded_n{n}_weak_scaling_efficiency"] = round(
            (n * n * m["ticks_per_s"])
            / (n_base * n_base * mb["ticks_per_s"]), 3)
        if n <= 16384:
            # Bitwise parity vs the plain single-device engine at the
            # same n (weights are block-seeded, so both arms see the
            # identical fabric).  Skipped at 65536: the reference run
            # would need its own 16 GiB replica.
            m1, raster1 = _bench_sharded_point(
                n, None, n_ticks=n_ticks, batch=batch, reps=1)
            out[f"sharded_n{n}_exact"] = bool(
                np.array_equal(raster, raster1))
            assert out[f"sharded_n{n}_exact"], (
                f"sharded rollout diverged from single-device at n={n}")
        assert m["recompiles"] == 0, f"sharded rollout retraced at n={n}"
        assert out[f"sharded_n{n}_weak_scaling_efficiency"] > 0, (
            "weak-scaling efficiency must be positive")
    return out


def sharded_table(res: Dict) -> str:
    """Markdown weak-scaling table (what the multi-device CI leg posts
    to the step summary)."""
    d = res["sharded_devices"]
    rows = ["| n | devices | ticks/s | synops/s/device | n_base "
            "| base ticks/s | weak-scaling eff |",
            "|---|---------|---------|-----------------|--------"
            "|--------------|------------------|"]
    for n in res["sharded_ns"]:
        base = [k for k in res
                if k.startswith(f"sharded_n{n}_base") and
                k.endswith("_ticks_per_s")]
        n_base = base[0].split("_base")[1].split("_")[0] if base else "?"
        rows.append(
            f"| {n} | {d} | {res[f'sharded_n{n}_d{d}_ticks_per_s']} "
            f"| {res[f'sharded_n{n}_d{d}_synops_per_device_per_s']:.3g} "
            f"| {n_base} | {res[base[0]] if base else '?'} "
            f"| {res[f'sharded_n{n}_weak_scaling_efficiency']} |")
    return "\n".join(rows)


def _telemetry_overhead(reps: int = 9) -> Dict:
    """The observability layer's CI gate: telemetry-on ticks/s must stay
    within 10% of telemetry-off at the gate point (n=1024, jnp backend
    -- the reference datapath both CI platforms actually *time*;
    interpret-mode Pallas wall-clock is structure, not speed).

    Telemetry costs one extra reduce kernel per tick (the variadic
    reduce in :meth:`TickTelemetry.accumulate`) against the
    weights-dominated n^2 synaptic matmul -- a few percent at the gate
    point. The measurement is built for noisy shared CI runners:
    off/on rollouts are timed in interleaved pairs (runner-speed drift
    hits both sides of a pair equally) and the gated ratio is the
    *median* of the per-pair ratios. The
    ``n1024_telemetry_on_off_ratio`` key is gated in check_regression.py
    as a *policy floor* (baseline 0.9 == the <10% budget; --refresh
    preserves it instead of snapshotting a lucky run)."""
    from repro.core.network import rollout

    n, batch, n_ticks, max_delay = 1024, 4, 8, 4
    params, state = _sweep_case(n, batch=batch, max_delay=max_delay, seed=7)
    rng = np.random.default_rng(3)
    ext = jnp.asarray(
        (rng.random((n_ticks, batch, n)) < 0.1).astype(np.float32))

    off = jax.jit(lambda p, st, e: rollout(p, st, e, n_ticks, backend="jnp"))
    on = jax.jit(lambda p, st, e: rollout(p, st, e, n_ticks, backend="jnp",
                                          telemetry=True))
    step_off = lambda: jax.block_until_ready(off(params, state, ext))
    step_on = lambda: jax.block_until_ready(on(params, state, ext))
    step_off(), step_on()                        # warmup == the compiles
    wall_off = wall_on = float("inf")
    ratios = []
    for _ in range(reps):
        t0 = time.perf_counter()
        step_off()
        w_off = time.perf_counter() - t0
        t0 = time.perf_counter()
        step_on()
        w_on = time.perf_counter() - t0
        wall_off = min(wall_off, w_off)
        wall_on = min(wall_on, w_on)
        ratios.append(w_off / w_on)
    _, r_off = off(params, state, ext)
    _, r_on, telem = on(params, state, ext)
    out = {
        "n1024_telem_off_ticks_per_s": round(n_ticks / wall_off, 2),
        "n1024_telem_on_ticks_per_s": round(n_ticks / wall_on, 2),
        "n1024_telemetry_on_off_ratio": round(
            float(np.median(ratios)), 3),
        "n1024_telemetry_raster_exact": bool(
            np.array_equal(np.asarray(r_off), np.asarray(r_on))),
        # On-device spike counter == the raster's own sum, bit-for-bit.
        "n1024_telemetry_spikes_exact": bool(np.array_equal(
            np.asarray(telem.spikes), np.asarray(r_on).sum(axis=(0, 2)))),
    }
    assert out["n1024_telemetry_raster_exact"], (
        "telemetry perturbed the raster")
    assert out["n1024_telemetry_spikes_exact"], (
        "on-device spike count != raster sum")
    return out


def run(fast: bool = True, ns: Optional[Tuple[int, ...]] = None) -> Dict:
    from repro.configs import get_bundle

    bundle = get_bundle("snn-fused")
    cfg = bundle.smoke if fast else bundle.model
    on_tpu = jax.default_backend() == "tpu"
    if ns is None:
        # CPU interpret mode exists for correctness, not speed: the full
        # sweep (up to the snn-fused FULL fabric) is a TPU run.
        ns = (256, 1024, 4096) if (on_tpu or not fast) else (cfg.n_neurons,)
    n_ticks = cfg.n_ticks
    batch, max_delay, reps = 16, 4, (2 if fast else 5)

    assert cfg.snn_backend in BACKENDS, (
        f"snn-fused config names unknown backend {cfg.snn_backend!r}")
    out: Dict = {
        "bench": "snn scaling: backend sweep + paper Table I cost model",
        "backend_platform": jax.default_backend(),
        "configured_backend": cfg.snn_backend,   # what the arch serves with
        "n_ticks": n_ticks,
        "batch": batch,
        "max_delay": max_delay,
    }
    rng = np.random.default_rng(1)
    for n in ns:
        params, state = _sweep_case(n, batch=batch, max_delay=max_delay, seed=n)
        ext = jnp.asarray(
            (rng.random((n_ticks, batch, n)) < 0.1).astype(np.float32))
        rasters = {}
        for backend in BACKENDS:
            metrics, raster = _bench_backend(
                backend, params, state, ext, n_ticks, reps)
            rasters[backend] = np.asarray(raster)
            for k, v in metrics.items():
                out[f"n{n}_{backend}_{k}"] = v
        for backend in BACKENDS[1:]:
            out[f"n{n}_{backend}_exact"] = bool(
                np.array_equal(rasters[backend], rasters["jnp"]))
        if out.get(f"n{n}_pallas_ticks_per_s"):
            out[f"n{n}_fused_speedup_vs_pallas"] = round(
                out[f"n{n}_pallas_fused_ticks_per_s"]
                / out[f"n{n}_pallas_ticks_per_s"], 3)

    # CI contract (CPU or TPU): every backend bit-exact, zero recompiles.
    for n in ns:
        for backend in BACKENDS[1:]:
            assert out[f"n{n}_{backend}_exact"], (
                f"{backend} diverged from jnp at n={n}")
        for backend in BACKENDS:
            assert out[f"n{n}_{backend}_recompiles"] == 0, (
                f"{backend} retraced at n={n}")

    out.update(_sparse_sweep(fast=fast))
    out.update(_sharded_section(fast=fast))
    out.update(_telemetry_overhead(reps=(9 if fast else 15)))

    # -- paper Table I cost model (kept from the seed bench) ---------------
    for n in (74, 256, 1024):
        b, rate = 32, 0.05
        s = (rng.random((b, n)) < rate).astype(np.float32)
        w = rng.normal(size=(n, n)).astype(np.float32)
        c = (rng.random((n, n)) < 0.5).astype(np.float32)
        dense_flops = 2 * b * n * n
        k_active = max(8, int(2 * rate * n))
        event_flops = 2 * b * k_active * n
        got = ops.event_spike_matmul(jnp.asarray(s), jnp.asarray(w),
                                     jnp.asarray(c), k_active=k_active)
        want = spike_matmul_ref(jnp.asarray(s), jnp.asarray(w), jnp.asarray(c))
        out[f"n{n}_dense_flops_per_tick"] = dense_flops
        out[f"n{n}_event_flops_per_tick"] = event_flops
        out[f"n{n}_event_speedup_model"] = dense_flops / event_flops
        # (renamed from n{n}_event_exact, which now names the *sweep*'s
        # event-backend raster parity at the same n)
        out[f"n{n}_event_model_exact"] = bool(np.allclose(got, want, rtol=1e-4,
                                                          atol=1e-4))
        out[f"n{n}_synapse_bytes_u8"] = n * n
        out[f"n{n}_spike_bytes_per_tick"] = b * n  # what the mux fabric moves
    # 64k-neuron production core, per-tick cost model on the (16,16) mesh
    n, b = 65536, 256
    out["n65536_synapse_GB_u8"] = n * n / 2**30
    out["n65536_dense_TFLOPs_per_tick"] = 2 * b * n * n / 1e12
    out["n65536_per_chip_MB_u8_256chips"] = n * n / 256 / 2**20
    return out


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke sizes only (what CPU CI runs)")
    ap.add_argument("--sharded-only", action="store_true",
                    help="run only the mesh-sharded section (the "
                         "multi-device CI leg)")
    ap.add_argument("--out", default="BENCH_snn_scale.json")
    args = ap.parse_args(argv)
    # Must run before jax initializes a backend: the sharded section
    # needs an 8-device (simulated, on CPU) mesh.
    from repro.util.env import ensure_host_device_count
    ensure_host_device_count(8)
    res = _sharded_section(fast=args.fast) if args.sharded_only else run(
        fast=args.fast)
    for k, v in res.items():
        print(f"{k}: {v}")
    if "sharded_devices" in res:
        print("\n" + sharded_table(res))
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {args.out}")
    return res


if __name__ == "__main__":
    main()
