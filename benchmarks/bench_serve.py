"""Multi-tenant SNN serving throughput: spikes/s, TTFT, recompiles.

Emits ``BENCH_serve.json`` when run as a script:

  tokens_of_spikes_per_s   rate-decoded output spikes per wall second
  mean_ttft_s              submit -> first decoded output, averaged
  recompiles               traces after warmup -- MUST be 0 across tenant
                           swaps (the "no re-synthesis" property, served)

Tenant churn is the point: every wave swaps different register images
(heterogeneous topologies, one plastic tenant learning online) through
the same slots of one compiled program.

A second section measures *continuous admission* (``serve_continuous``)
against wave admission on the workload it targets -- a bimodal serving
mix where most requests are short and a minority run the full tick
budget, so wave admission pads every short request to the longest:

  continuous_goodput_slot_ticks_per_s   useful (in-budget) slot-ticks/s
  continuous_p99_ttft_s                 gated as a latency ceiling
  continuous_goodput_win_vs_wave        policy floor: continuous must
                                        keep >= 1.3x wave goodput here
                                        (measures ~1.5x on a dev box;
                                        the committed floor leaves room
                                        for runner jitter)
  continuous_wave_exact                 per-request counts/preds match
                                        the wave path bit-for-bit
  continuous_recompiles                 0 across every slot refill

  PYTHONPATH=src python benchmarks/bench_serve.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

import jax

jax.config.update("jax_platform_name", "cpu")


def run(fast: bool = True) -> Dict:
    from repro.launch.serve import SNNServer, make_demo_requests, make_demo_tenants

    n_max, slots, max_ticks = (24, 4, 12) if fast else (74, 8, 32)
    n_requests = 16 if fast else 96
    server = SNNServer(n_max=n_max, slots=slots, max_ticks=max_ticks)
    names = make_demo_tenants(server, 8, seed=0)

    # Warmup wave (the one and only compile), then the measured run.
    warm = make_demo_requests(server, names, slots, seed=99)
    server.serve(warm)
    compiles_after_warmup = server.compiles

    reqs = make_demo_requests(server, names, n_requests, seed=1)
    t0 = time.perf_counter()
    stats = server.serve(reqs)
    wall = time.perf_counter() - t0

    recompiles = server.compiles - compiles_after_warmup
    out = {
        "bench": "multi-tenant SNN serving",
        "n_max": n_max,
        "slots": slots,
        "max_ticks": max_ticks,
        "n_tenants": stats["n_tenants"],
        "n_requests": stats["n_requests"],
        "waves": stats["waves"],
        "tokens_of_spikes": stats["spikes_out"],
        "tokens_of_spikes_per_s": round(stats["spikes_out"] / max(1e-9, wall), 1),
        "slot_ticks_per_s": round(
            stats["waves"] * max_ticks * slots / max(1e-9, wall), 1),
        "mean_ttft_s": stats["mean_ttft_s"],
        "wall_s": round(wall, 3),
        "recompiles": recompiles,
        # Wave-telemetry summary (underscore keys are informational, not
        # gated): whole-fabric activity + the per-tenant report, straight
        # off the scan carry -- what the BENCH artifact preserves for a
        # reader who wasn't at the run.
        "_telemetry": {
            "event_overflow_ticks": server.registry.get(
                "snn_event_overflow_ticks_total").value(),
            "weight_delta_l1": round(server.registry.get(
                "snn_weight_delta_l1_total").value(), 3),
            "tenants": server.tenant_report(),
        },
    }
    assert recompiles == 0, f"tenant swaps recompiled {recompiles}x"
    out.update(run_continuous(fast=fast))
    return out


def make_serving_mix(server, names: List[str], n_requests: int, *,
                     seed: int) -> List:
    """A bimodal serving mix: ~75% short interactive requests, ~25%
    running the full tick budget -- the regime continuous admission
    targets (wave admission pads every short request to ``max_ticks``)."""
    from repro.launch.serve import ServeRequest

    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        t = server.tenants[names[i % len(names)]]
        if rng.random() < 0.75:
            ticks = int(rng.integers(2, max(3, server.max_ticks // 8) + 1))
        else:
            ticks = server.max_ticks
        ext = ((rng.random((ticks, t.n_in)) < 0.3)
               * rng.integers(80, 255, (ticks, t.n_in))).astype(np.float32)
        reqs.append(ServeRequest(rid=i, tenant=t.name, ext=ext,
                                 n_ticks=ticks))
    return reqs


def run_continuous(fast: bool = True) -> Dict:
    from repro.launch.serve import SNNServer, make_demo_requests, make_demo_tenants

    # The mix needs enough requests to amortize warm-path assembly, or
    # the win ratio under-reads -- fast mode still runs ~0.5 s.
    n_max, slots, max_ticks, chunk = 74, 8, 96, 16
    n_requests = 64 if fast else 128
    reps = 2 if fast else 3

    def build():
        s = SNNServer(n_max=n_max, slots=slots, max_ticks=max_ticks,
                      chunk_ticks=chunk)
        return s, make_demo_tenants(s, 8, seed=0)

    # Two identically-built, identically-warmed servers: the plastic
    # tenant's weights drift with every request it serves, so the
    # exactness comparison needs both paths to start from the same
    # learned state.
    sw, names = build()
    sw.serve(make_demo_requests(sw, names, slots, seed=99))
    sc, _ = build()
    sc.serve_continuous(make_demo_requests(sc, names, slots, seed=99))
    compiles_after_warmup = sc.compiles

    # Exactness pass: one run of the same mix through each path.
    reqs_w = make_serving_mix(sw, names, n_requests, seed=7)
    reqs_c = make_serving_mix(sc, names, n_requests, seed=7)
    stats_w = sw.serve(reqs_w)
    stats_c = sc.serve_continuous(reqs_c)
    exact = all(
        np.array_equal(a.counts, b.counts) and a.pred == b.pred
        for a, b in zip(reqs_w, reqs_c))

    # Timing passes: min-of-reps walls (weights keep drifting, which
    # changes values but not work; both servers see the same mixes).
    def timed_min(fn) -> float:
        best = None
        for rep in range(reps):
            mix_seed = 100 + rep
            t0 = time.perf_counter()
            fn(mix_seed)
            wall = time.perf_counter() - t0
            best = wall if best is None else min(best, wall)
        return best

    wall_w = timed_min(
        lambda s: sw.serve(make_serving_mix(sw, names, n_requests, seed=s)))
    wall_c = timed_min(
        lambda s: sc.serve_continuous(
            make_serving_mix(sc, names, n_requests, seed=s)))

    useful = stats_w["useful_slot_ticks"]
    recompiles = sc.compiles - compiles_after_warmup
    out = {
        "continuous_n_requests": n_requests,
        "continuous_chunk_ticks": chunk,
        "continuous_useful_slot_ticks": useful,
        "continuous_goodput_slot_ticks_per_s": round(useful / max(1e-9, wall_c), 1),
        "continuous_p99_ttft_s": stats_c["p99_ttft_s"],
        "continuous_goodput_win_vs_wave": round(wall_w / max(1e-9, wall_c), 3),
        "continuous_wave_exact": bool(exact),
        "continuous_recompiles": recompiles,
        "continuous_wall_s": round(wall_c, 3),
        "wave_wall_s_on_mix": round(wall_w, 3),
    }
    assert recompiles == 0, f"slot refills recompiled {recompiles}x"
    assert exact, "continuous path drifted from the wave oracle"
    return out


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    res = run(fast=args.fast)
    for k, v in res.items():
        print(f"{k}: {v}")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {args.out}")
    return res


if __name__ == "__main__":
    main()
