"""Multi-tenant SNN serving throughput: spikes/s, TTFT, recompiles.

Emits ``BENCH_serve.json`` when run as a script:

  tokens_of_spikes_per_s   rate-decoded output spikes per wall second
  mean_ttft_s              submit -> first decoded output, averaged
  recompiles               traces after warmup -- MUST be 0 across tenant
                           swaps (the "no re-synthesis" property, served)

Tenant churn is the point: every wave swaps different register images
(heterogeneous topologies, one plastic tenant learning online) through
the same slots of one compiled program.

  PYTHONPATH=src python benchmarks/bench_serve.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

import jax

jax.config.update("jax_platform_name", "cpu")


def run(fast: bool = True) -> Dict:
    from repro.launch.serve import SNNServer, make_demo_requests, make_demo_tenants

    n_max, slots, max_ticks = (24, 4, 12) if fast else (74, 8, 32)
    n_requests = 16 if fast else 96
    server = SNNServer(n_max=n_max, slots=slots, max_ticks=max_ticks)
    names = make_demo_tenants(server, 8, seed=0)

    # Warmup wave (the one and only compile), then the measured run.
    warm = make_demo_requests(server, names, slots, seed=99)
    server.serve(warm)
    compiles_after_warmup = server.compiles

    reqs = make_demo_requests(server, names, n_requests, seed=1)
    t0 = time.perf_counter()
    stats = server.serve(reqs)
    wall = time.perf_counter() - t0

    recompiles = server.compiles - compiles_after_warmup
    out = {
        "bench": "multi-tenant SNN serving",
        "n_max": n_max,
        "slots": slots,
        "max_ticks": max_ticks,
        "n_tenants": stats["n_tenants"],
        "n_requests": stats["n_requests"],
        "waves": stats["waves"],
        "tokens_of_spikes": stats["spikes_out"],
        "tokens_of_spikes_per_s": round(stats["spikes_out"] / max(1e-9, wall), 1),
        "slot_ticks_per_s": round(
            stats["waves"] * max_ticks * slots / max(1e-9, wall), 1),
        "mean_ttft_s": stats["mean_ttft_s"],
        "wall_s": round(wall, 3),
        "recompiles": recompiles,
        # Wave-telemetry summary (underscore keys are informational, not
        # gated): whole-fabric activity + the per-tenant report, straight
        # off the scan carry -- what the BENCH artifact preserves for a
        # reader who wasn't at the run.
        "_telemetry": {
            "event_overflow_ticks": server.registry.get(
                "snn_event_overflow_ticks_total").value(),
            "weight_delta_l1": round(server.registry.get(
                "snn_weight_delta_l1_total").value(), 3),
            "tenants": server.tenant_report(),
        },
    }
    assert recompiles == 0, f"tenant swaps recompiled {recompiles}x"
    return out


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    res = run(fast=args.fast)
    for k, v in res.items():
        print(f"{k}: {v}")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {args.out}")
    return res


if __name__ == "__main__":
    main()
