"""The paper's latency model (§II.C): 2 cycles/layer, 5 cycles end-to-end,
validated against the tick semantics of our scan rollout."""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core import connectivity
from repro.core.lif import LIFParams
from repro.core.network import SNNParams, SNNState, rollout


def _ticks_to_output(layer_sizes) -> int:
    """Measure, by simulation, how many ticks an input wavefront needs to
    reach the output layer (== network depth in our tick semantics)."""
    n = sum(layer_sizes)
    p = SNNParams(
        w=jnp.ones((n, n)) * 2.0,
        c=jnp.asarray(connectivity.layered(layer_sizes), jnp.float32),
        w_in=jnp.eye(n) * 2.0,
        lif=LIFParams.make(n, v_th=1.0, leak=0.0, r_ref=0))
    ext = jnp.zeros((8, n)).at[0, : layer_sizes[0]].set(1.0)
    st = SNNState.zeros((), n)
    _, raster = rollout(p, st, ext, 8)
    out = np.asarray(raster[:, n - layer_sizes[-1]:])
    ticks = int(np.argmax(out.sum(axis=1) > 0))
    return ticks + 1  # tick index -> count


def run() -> Dict:
    measured_2layer = _ticks_to_output([4, 3])
    measured_3layer = _ticks_to_output([4, 4, 3])
    # paper model: 1 cycle sampling + 2 cycles per layer
    paper_cycles_2layer = 1 + 2 * 2
    clock_mhz = 100.0
    return {
        "bench": "latency model (paper §II.C)",
        "ticks_to_output_2layer": measured_2layer,      # == depth (2)
        "ticks_to_output_3layer": measured_3layer,      # == depth (3)
        "paper_cycles_2layer_e2e": paper_cycles_2layer,  # 5
        "paper_latency_ns_at_100MHz": paper_cycles_2layer / clock_mhz * 1e3,
        "cycles_per_layer": 2,
        "iris==mnist_latency": True,  # both 2-layer -> identical 5 cycles
    }


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
