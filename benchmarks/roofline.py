"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, derives the three terms:

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

Sources: ``hlo_cost`` fields of the dry-run JSON (the trip-count-corrected
parse of ``compiled.as_text()``; XLA's raw ``cost_analysis()`` counts while
bodies once -- both are recorded). Since SPMD modules are per-device
programs, per-device FLOPs/bytes are already "/ chips"; terms divide by
per-chip peaks directly.

Hardware model (TPU v5e target):
  peak 197 TFLOP/s bf16 / chip; 819 GB/s HBM / chip; ~50 GB/s/link ICI.

MODEL_FLOPS = 6*N*D (train, dense) or 6*N_active*D (MoE); decode/prefill
use 2*N_active per token. The MODEL/HLO ratio flags remat & dispatch waste.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s/link (per-chip effective)

DRYRUN_DIR = "artifacts/dryrun"


def model_flops(rec: Dict) -> float:
    """Paper-standard useful FLOPs for the cell (whole program, all chips)."""
    n_active = rec["n_active_params"]
    tokens = rec["global_batch"] * rec["seq_len"]
    if rec["kind"] == "train":
        return 6.0 * n_active * tokens
    if rec["kind"] == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n_active * rec["global_batch"]


def roofline_terms(rec: Dict) -> Dict:
    chips = rec["n_chips"]
    hc = rec["hlo_cost"]
    flops_dev = hc["flops_per_device"]
    bytes_dev = hc["dot_bytes_per_device"]
    coll_dev = hc["total_collective_bytes_per_device"]

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_collective = coll_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_collective}
    dominant = max(terms, key=terms.get)

    mf = model_flops(rec)
    hlo_global = flops_dev * chips
    useful_ratio = mf / hlo_global if hlo_global else float("nan")
    # roofline fraction: useful model FLOPs vs what the dominant term's
    # wall-time could have delivered at peak.
    bound_s = max(terms.values())
    frac = (mf / chips / PEAK_FLOPS) / bound_s if bound_s > 0 else float("nan")
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": useful_ratio,
        "roofline_fraction": frac,
        "collective_bytes_by_kind": hc["collective_bytes_per_device"],
    }


def load_records(dryrun_dir: str = DRYRUN_DIR, mesh: Optional[str] = None) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        recs.append(rec)
    return recs


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:7.2f}s "
    if x >= 1e-3:
        return f"{x*1e3:7.2f}ms"
    return f"{x*1e6:7.2f}us"


def table(recs: List[Dict], *, only_singlepod: bool = True) -> str:
    lines = []
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':8s} {'compute':9s} {'memory':9s} "
           f"{'collect':9s} {'bound':8s} {'MFLOPs/HLO':10s} {'roofline%':9s} {'mem/chip':9s}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for rec in recs:
        if only_singlepod and rec["mesh"] != "16x16":
            continue
        t = roofline_terms(rec)
        mem_gb = (rec["memory_analysis"].get("temp_size_in_bytes", 0)
                  + rec["memory_analysis"].get("argument_size_in_bytes", 0)) / 2**30
        lines.append(
            f"{rec['arch']:26s} {rec['shape']:12s} {rec['mesh']:8s} "
            f"{fmt_s(t['compute_s'])} {fmt_s(t['memory_s'])} {fmt_s(t['collective_s'])} "
            f"{t['dominant']:8s} {t['useful_ratio']:10.3f} "
            f"{100*t['roofline_fraction']:8.1f}% {mem_gb:7.1f}GB")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default=DRYRUN_DIR)
    ap.add_argument("--all-meshes", action="store_true")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    recs = load_records(args.dryrun_dir)
    print(table(recs, only_singlepod=not args.all_meshes))
    if args.json_out:
        out = []
        for rec in recs:
            out.append({
                "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
                "tag": rec.get("tag", ""),
                **roofline_terms(rec),
                "memory_analysis": rec["memory_analysis"],
            })
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
