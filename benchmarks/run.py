"""Benchmark runner: one bench per paper table/figure + the roofline readout.

Usage: PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the training-heavy benches")
    args = ap.parse_args()

    from benchmarks import (
        bench_iris, bench_latency, bench_mnist, bench_serve, bench_snn_scale,
        bench_stdp, bench_uart,
    )

    benches = [
        ("uart", bench_uart.run),
        ("latency", bench_latency.run),
        ("snn_scale", bench_snn_scale.run),
        ("stdp", bench_stdp.run),
        ("serve", lambda: bench_serve.run(fast=args.fast)),
    ]
    if not args.fast:
        benches += [("iris", bench_iris.run), ("mnist", bench_mnist.run)]

    results = {}
    for name, fn in benches:
        t0 = time.time()
        print(f"=== bench:{name} ===", flush=True)
        res = fn()
        res["_wall_s"] = round(time.time() - t0, 2)
        results[name] = res
        for k, v in res.items():
            print(f"  {k}: {v}")

    # roofline summary if dry-run artifacts exist
    try:
        from benchmarks import roofline
        recs = roofline.load_records()
        if recs:
            print("=== bench:roofline (from dry-run artifacts) ===")
            print(roofline.table(recs))
        else:
            print("=== roofline: no dry-run artifacts (run repro.launch.dryrun) ===")
    except Exception as e:  # noqa: BLE001
        print(f"roofline summary unavailable: {e}")

    print("=== benchmark summary (json) ===")
    print(json.dumps(results, indent=2, default=str))


if __name__ == "__main__":
    main()
