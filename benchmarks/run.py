"""Benchmark runner: one bench per paper table/figure + the roofline readout.

Writes the full summary to ``BENCH_all.json`` (plus whatever per-bench
``BENCH_*.json`` files the individual benches emit) and exits nonzero if
any bench raises -- a crashed bench must fail CI, not vanish into a
printout (the old behaviour only printed the summary and swallowed
nothing explicitly, but gave the gate nothing to read either).

Every bench record carries a uniform ``_wall_s`` (runner-measured, not
bench-self-reported) and ``_platform`` (``jax.default_backend()``), so a
BENCH file read months later says what device produced it. ``--profile
DIR`` captures a ``jax.profiler`` trace of the whole run (the CI bench
job uploads it next to the BENCH_*.json artifacts).

Usage: PYTHONPATH=src python -m benchmarks.run [--fast] [--out BENCH_all.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the training-heavy benches")
    ap.add_argument("--out", default="BENCH_all.json")
    ap.add_argument("--profile", metavar="DIR", default=None,
                    help="capture a jax.profiler trace of the benches "
                         "into DIR")
    ap.add_argument("--skip-analysis", action="store_true",
                    help="skip the static-analysis pre-flight")
    args = ap.parse_args()

    # Before anything initializes a jax backend: the snn_scale sharded
    # section (and the analysis sweep's mesh programs) want a simulated
    # multi-device view of the CPU host.
    from repro.util.env import ensure_host_device_count
    ensure_host_device_count(8)

    import jax

    if not args.skip_analysis:
        # Pre-flight: trace-level invariants are seconds to check and a
        # violated one (host callback in the scan, W*C recomputed per
        # tick, retrace-per-call static) invalidates every number the
        # benches below would spend minutes producing.
        from repro.analysis import check as analysis_check

        print("=== static-analysis pre-flight ===", flush=True)
        report = analysis_check.run()
        if not report.ok():
            print(report.table(), file=sys.stderr)
            print(report.summary(), file=sys.stderr)
            print("analysis pre-flight failed: benchmark numbers would be "
                  "meaningless; fix the findings (or --skip-analysis to "
                  "measure anyway)", file=sys.stderr)
            sys.exit(report.exit_code())
        print(report.summary(), flush=True)

    from benchmarks import (
        bench_iris, bench_latency, bench_mnist, bench_serve, bench_snn_scale,
        bench_stdp, bench_uart,
    )
    from repro.obs import profile

    benches = [
        ("uart", bench_uart.run),
        ("latency", bench_latency.run),
        ("snn_scale", lambda: bench_snn_scale.run(fast=args.fast)),
        ("stdp", bench_stdp.run),
        ("serve", lambda: bench_serve.run(fast=args.fast)),
    ]
    if not args.fast:
        benches += [("iris", bench_iris.run), ("mnist", bench_mnist.run)]

    platform = jax.default_backend()
    results = {"_platform": platform}
    failures = []
    with profile(args.profile):
        for name, fn in benches:
            t0 = time.perf_counter()
            print(f"=== bench:{name} ===", flush=True)
            try:
                res = fn()
            except Exception as e:  # noqa: BLE001 -- recorded, fatal at exit
                traceback.print_exc()
                failures.append(name)
                results[name] = {"_error": f"{type(e).__name__}: {e}"}
                continue
            # perf_counter + 6 decimals: cost-model benches (e.g. uart)
            # finish in well under 10 ms, which the old time.time()/
            # round(_, 2) pair recorded as a flat (and wrong) 0.0.
            res["_wall_s"] = round(time.perf_counter() - t0, 6)
            res["_platform"] = platform
            results[name] = res
            for k, v in res.items():
                print(f"  {k}: {v}")
            # Per-bench artifact (what check_regression.py and CI read/
            # upload); same file the bench's own __main__ writes.
            with open(f"BENCH_{name}.json", "w") as f:
                json.dump(res, f, indent=2, default=str)

    # roofline summary if dry-run artifacts exist (best-effort readout of
    # OPTIONAL artifacts -- unlike the benches above, absence is not failure)
    try:
        from benchmarks import roofline
        recs = roofline.load_records()
        if recs:
            print("=== bench:roofline (from dry-run artifacts) ===")
            print(roofline.table(recs))
        else:
            print("=== roofline: no dry-run artifacts (run repro.launch.dryrun) ===")
    except Exception as e:  # noqa: BLE001
        print(f"roofline summary unavailable: {e}")

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, default=str)
    print(f"wrote {args.out}")
    print("=== benchmark summary (json) ===")
    print(json.dumps(results, indent=2, default=str))
    if failures:
        print(f"FAILED benches: {', '.join(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
