"""Core: the paper's contribution -- LIF SNN with universal interconnections."""
from repro.core.lif import LIFParams, LIFState, lif_step, lif_step_euler, lif_step_fixed_leak, lif_step_int
from repro.core.engine import TickCarry, TickEngine
from repro.core.network import SNNParams, SNNState, step, rollout, learning_rollout, forward_layered, synaptic_input, params_from_registers
from repro.core.registers import RegisterBank, TimingModel, WeightLayout, transaction_breakdown
from repro.core.surrogate import spike_surrogate, spike_hard
from repro.core import connectivity, encoding, quant, uart

__all__ = [
    "LIFParams", "LIFState", "lif_step", "lif_step_euler", "lif_step_fixed_leak", "lif_step_int",
    "TickCarry", "TickEngine",
    "SNNParams", "SNNState", "step", "rollout", "learning_rollout", "forward_layered", "synaptic_input", "params_from_registers",
    "RegisterBank", "TimingModel", "WeightLayout", "transaction_breakdown",
    "spike_surrogate", "spike_hard",
    "connectivity", "encoding", "quant", "uart",
]
