"""Parameter/state pytrees shared by the tick engine and its wrappers.

Split out of :mod:`repro.core.network` so that :mod:`repro.core.engine`
(which *implements* the tick) and :mod:`repro.core.network` (which
exposes the user-facing rollout wrappers) can both import them without a
cycle. Everything here is re-exported from ``repro.core.network`` --
existing callers never see the split.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lif import LIFParams, LIFState


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SNNParams:
    """Network parameters (all runtime inputs -- never compiled constants).

    Attributes:
      w: synaptic weights, shape ``(n, n)``; ``w[pre, post]``.
      c: connection list, shape ``(n, n)`` bool/0-1; ``c[pre, post]``.
        ``None`` means the implicit all-to-all (every mux closed): the
        effective matrix is ``w`` itself and no second ``(n, n)`` buffer
        exists -- the 64k-fabric memory escape hatch (jnp/event backends
        only; the Pallas kernels stream ``c`` explicitly).
      w_in: input weights, shape ``(n_in, n)`` mapping external channels
        onto neurons (identity for the paper's networks where inputs drive
        input-layer neurons directly).
      lif: per-neuron :class:`LIFParams`.
    """

    w: jax.Array
    c: Optional[jax.Array]
    w_in: jax.Array
    lif: LIFParams


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SNNState:
    """Rollout state: LIF state + circular delay line.

    ``delay_buf`` has shape ``(..., max_delay, n)``; slot ``(k % max_delay)``
    holds the spikes scheduled to arrive at tick ``k``. ``max_delay == 1``
    (the hardware default) degenerates to plain previous-tick delivery.
    """

    lif: LIFState
    delay_buf: jax.Array
    tick: jax.Array

    @staticmethod
    def zeros(batch_shape, n: int, max_delay: int = 1, dtype=jnp.float32) -> "SNNState":
        return SNNState(
            lif=LIFState.zeros(batch_shape, n, dtype=dtype),
            delay_buf=jnp.zeros(tuple(batch_shape) + (max_delay, n), dtype=dtype),
            tick=jnp.zeros((), dtype=jnp.int32),
        )


def synaptic_input(
    spikes: jax.Array, params: SNNParams, ext: Optional[jax.Array]
) -> jax.Array:
    """``sum_pre s[pre] * W[pre,post] * C[pre,post] (+ ext @ W_in)``.

    The masked matmul *is* the mux fabric: C routes a zero exactly where the
    hardware's multiplexer would (``c=None``: every mux closed, ``wc = w``).
    """
    wc = (params.w if params.c is None
          else params.w * params.c.astype(params.w.dtype))
    syn = spikes @ wc
    if ext is not None:
        syn = syn + ext @ params.w_in
    return syn
