"""The paper's classification workflow, end to end (§III + §IV).

Host side: encode features to spikes, train the 2-layer SNN offline
(surrogate-gradient; the paper's authors likewise prepared weights on the
host), quantize to the u8 hardware grid, and download through the register
bank byte protocol. Device side: bit-faithful integer LIF inference
(``lif_step_int``) -- exactly what the FPGA executes.

Weights are constrained non-negative (softplus) to match the hardware's
0-255 weight registers; argmax readout over output-neuron accumulated
potential is invariant to the common offset, so non-negativity costs no
expressiveness for classification.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import quant
from repro.core.lif import LIFParams, LIFState, lif_step
from repro.core.registers import RegisterBank, WeightLayout
from repro.optim import adamw


@dataclasses.dataclass
class TrainedSNN:
    w: np.ndarray            # float non-negative (n_in, n_out)
    bias: np.ndarray         # float non-negative (n_out,) tonic I_bias (Eq. 1)
    v_th: float
    n_ticks: int
    leak: float
    r_ref: int


def _forward_float(w, bias, x_drive, *, v_th: float, n_ticks: int, leak: float,
                   surrogate: bool):
    """Clamp input drive for n_ticks; return output logits.

    x_drive: (B, n_in) spike/level drive. Output neurons integrate
    ``x_drive @ w + I_bias`` each tick (paper Eq. 1); logits = spike count
    + a membrane term (differentiable through the surrogate). The bias is
    the per-neuron tonic input register -- with non-negative weights it
    supplies the per-class offset a pure excitatory fabric lacks."""
    b, n_in = x_drive.shape
    n_out = w.shape[1]
    p = LIFParams.make(n_out, v_th=v_th, leak=leak, r_ref=0)
    syn = x_drive @ w + bias[None, :]

    def tick(state, _):
        s2 = lif_step(state, syn, p, mode="fixed_leak", surrogate=surrogate,
                      reset="subtract")
        return s2, s2.y

    s0 = LIFState.zeros((b,), n_out)
    s_fin, ys = jax.lax.scan(tick, s0, None, length=n_ticks)
    # Rate-coding identity (reset-by-subtraction):
    #   count * v_th + v_final == n_ticks * drive   (exactly)
    # so this readout is an exact monotone image of the drive.
    return ys.sum(0) + s_fin.v / v_th


def train(
    x: np.ndarray,
    y: np.ndarray,
    cfg: ModelConfig,
    *,
    epochs: int = 1500,
    lr: float = 0.1,
    v_th: float | None = None,
    leak: float = 0.0,
    seed: int = 0,
) -> TrainedSNN:
    """Full-batch training of the paper's 2-layer net.

    Optimizes the per-class *drive* ``x @ w + I_bias`` directly. This is
    exact, not a shortcut: with a threshold shared across output neurons,
    the hardware readout (spike count, membrane remainder) is the same
    strictly-monotone function of each neuron's constant drive, so
    ``argmax(readout_c) == argmax(drive_c)`` -- training the drive trains
    the spiking classifier (validated float-vs-int in tests). Weights and
    biases are softplus-constrained non-negative (u8 registers); the bias
    is the tonic ``I_bias`` of Eq. 1, which restores the per-class offset
    an excitatory-only fabric otherwise lacks.

    After training, ``v_th`` is set just below the winning class's typical
    drive so that (as the paper describes) "only one of the output neurons
    spikes to indicate the classification result".
    """
    n_in, n_out = cfg.layer_sizes
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    raw = {"w": jax.random.normal(k1, (n_in, n_out), jnp.float32) * 0.3,
           "b": jax.random.normal(k2, (n_out,), jnp.float32) * 0.1}
    xd = jnp.asarray(x, jnp.float32)
    yd = jnp.asarray(y, jnp.int32)

    def drives(params, xx):
        w = jax.nn.softplus(params["w"]) * 2.0
        bias = jax.nn.softplus(params["b"]) * 2.0
        return xx @ w + bias[None, :]

    def loss_fn(params):
        lp = jax.nn.log_softmax(drives(params, xd), axis=-1)
        return -jnp.take_along_axis(lp, yd[:, None], axis=-1).mean()

    opt = adamw.init(raw)
    step = jax.jit(lambda p, o: _train_step(p, o, loss_fn, lr))
    for _ in range(epochs):
        raw, opt = step(raw, opt)
    w = np.asarray(jax.nn.softplus(raw["w"]) * 2.0)
    bias = np.asarray(jax.nn.softplus(raw["b"]) * 2.0)

    if v_th is None:
        # Threshold ABOVE the per-tick drive band: every output neuron then
        # operates in the strictly-monotone accumulate-several-ticks-per-
        # spike regime (score = count + membrane remainder is injective in
        # the drive), so no two classes can saturate into a tie. The winner
        # still spikes within the readout window (n_ticks * drive >> v_th).
        d = np.asarray(drives(raw, xd))
        # Any shared v_th is exact under reset-by-subtraction; choose it in
        # the winner-spikes band (paper: "only one output neuron spikes").
        v_th = float(np.median(d.max(axis=1)) * 0.9) + 1e-3
    return TrainedSNN(w=w, bias=bias, v_th=v_th, n_ticks=cfg.n_ticks,
                      leak=leak, r_ref=0)


def _train_step(params, opt, loss_fn, lr):
    grads = jax.grad(loss_fn)(params)
    return adamw.update(grads, opt, params, lr=lr, weight_decay=0.0)


def predict_float(model: TrainedSNN, x: np.ndarray) -> np.ndarray:
    logits = _forward_float(
        jnp.asarray(model.w), jnp.asarray(model.bias), jnp.asarray(x, jnp.float32),
        v_th=model.v_th, n_ticks=model.n_ticks, leak=model.leak, surrogate=False)
    return np.asarray(jnp.argmax(logits, axis=-1))


# ---------------------------------------------------------------------------
# hardware download path


@dataclasses.dataclass
class DeployedSNN:
    """What lives on the device after the UART download."""
    bank: RegisterBank
    w_int: np.ndarray       # i32 (n_in, n_out) reconstructed from registers
    th_int: np.ndarray      # i32 (n_out,)
    b_int: np.ndarray       # i32 (n_out,) tonic I_bias register
    scale: float
    n_ticks: int


def deploy(model: TrainedSNN, *, n_neurons: Optional[int] = None) -> DeployedSNN:
    """Quantize -> pack into a RegisterBank -> serialize over the UART byte
    protocol -> reload on the 'device' -> reconstruct integer network.

    Uses the general per-synapse layout (paper §II.A: per-synapse u8
    weights); the flat neuron array is [inputs..., outputs...] as in
    Fig. 4/6, with the connection list wiring the bipartite layers.
    """
    from repro.core import connectivity

    n_in, n_out = model.w.shape
    n = n_neurons or (n_in + n_out)
    # shared quantization grid across weights, biases, and thresholds; the
    # grid must cover v_th (8-bit threshold registers) or th_int clips
    w_max = float(max(model.w.max(), model.bias.max(), model.v_th, 1e-8))
    qw = quant.quantize_u8(jnp.asarray(model.w), w_max)
    qb = quant.quantize_u8(jnp.asarray(model.bias), w_max)
    th_q = quant.quantize_threshold(
        jnp.full((n_out,), model.v_th), qw.scale)

    bank = RegisterBank(n, weight_layout=WeightLayout.PER_SYNAPSE)
    w_full = np.zeros((n, n), np.uint8)
    w_full[:n_in, n_in : n_in + n_out] = np.asarray(qw.q)
    bank.set_weights(w_full)
    bank.set_connection_list(connectivity.layered([n_in, n_out]))
    th_full = np.zeros((n,), np.uint8)
    th_full[n_in : n_in + n_out] = np.asarray(th_q)
    bank.set_thresholds(th_full)
    b_full = np.zeros((n,), np.uint8)
    b_full[n_in : n_in + n_out] = np.asarray(qb.q)
    bank.set_bias(b_full)

    # wire transfer: serialize -> (UART) -> reload
    from repro.core import uart
    payload = bank.serialize()
    link = uart.HostLink()
    received = link.send(payload)
    bank_dev = RegisterBank(n, weight_layout=WeightLayout.PER_SYNAPSE)
    bank_dev.load_bytes(received)
    bank_dev.set_bias(bank.bias)  # device-local registers (not in the stream)

    c = bank_dev.get_connection_list().astype(np.int32)
    w_dev = bank_dev.weights.astype(np.int32) * c
    w_int = w_dev[:n_in, n_in : n_in + n_out]
    th_int = bank_dev.thresholds[n_in : n_in + n_out].astype(np.int32)
    b_int = bank_dev.bias[n_in : n_in + n_out].astype(np.int32)
    return DeployedSNN(bank=bank_dev, w_int=w_int, th_int=th_int, b_int=b_int,
                       scale=float(qw.scale), n_ticks=model.n_ticks)


def predict_int(dep: DeployedSNN, x_spikes: np.ndarray,
                drive_levels: int = 1) -> np.ndarray:
    """Bit-faithful integer inference (the FPGA datapath).

    x_spikes: (B, n_in) integer drive (binary spikes or quantized levels).
    Returns argmax over accumulated integer membrane + spike counts.
    """
    xd = jnp.asarray(x_spikes, jnp.int32)
    b = xd.shape[0]
    n_out = dep.w_int.shape[1]
    syn = xd @ jnp.asarray(dep.w_int)

    p = LIFParams(
        v_th=jnp.asarray(dep.th_int), leak=jnp.zeros(n_out, jnp.int32),
        r_ref=jnp.zeros(n_out, jnp.int32), gain=jnp.ones(n_out, jnp.int32),
        i_bias=jnp.asarray(dep.b_int), v_reset=jnp.zeros(n_out, jnp.int32))

    state = LIFState(v=jnp.zeros((b, n_out), jnp.int32),
                     r=jnp.zeros((b, n_out), jnp.int32),
                     y=jnp.zeros((b, n_out), jnp.int32))
    counts = jnp.zeros((b, n_out), jnp.int32)
    for _ in range(dep.n_ticks):
        state = lif_step(state, syn, p, mode="int", reset="subtract")
        counts = counts + state.y
    # exact rate-coding readout: count*v_th + v_final == n_ticks*drive
    score = counts * jnp.asarray(dep.th_int) + state.v
    return np.asarray(jnp.argmax(score, axis=-1))


def accuracy(pred: np.ndarray, y: np.ndarray) -> float:
    return float((pred == y).mean())
