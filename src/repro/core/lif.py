"""Discrete-time leaky integrate-and-fire (LIF) neuron dynamics.

Implements the paper's two formulations exactly:

* **Euler model** (paper Eq. 1-4): membrane decays by a factor
  ``(1 - dt/tau_m)`` each tick and integrates ``dt/C_m * (w.s + I_bias)``.

* **Fixed-leak hardware realization** (paper Eq. 5): the leak is a constant
  decrement ``lambda`` applied only while the membrane is non-zero,
  ``v' = v + sum_j w_j s_j - lambda * 1{v != 0}``,
  followed by the same threshold / reset / refractory logic.

Both are pure functions over a :class:`LIFState`, vectorised over arbitrary
leading (batch) dimensions, and differentiable through the surrogate spike
function (:mod:`repro.core.surrogate`).

The integer mode mirrors the FPGA datapath: u8 weights (0-255), i32
accumulation, integer thresholds -- bit-exact with the register-bank
contents (:mod:`repro.core.registers`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.surrogate import spike_surrogate


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LIFParams:
    """Neuron parameters, one entry per neuron (shape ``(n,)`` or scalar).

    Attributes:
      v_th: firing threshold ``V_th``.
      leak: Euler mode: ``dt/tau_m`` (decay fraction per tick).
            Fixed-leak mode: the per-tick decrement ``lambda``.
      r_ref: refractory length ``R_ref`` in ticks.
      gain: Euler mode input gain ``dt/C_m``; unused (1.0) in fixed-leak mode.
      i_bias: tonic bias current ``I_bias``.
      v_reset: reset potential (paper resets to 0).
    """

    v_th: jax.Array
    leak: jax.Array
    r_ref: jax.Array
    gain: jax.Array
    i_bias: jax.Array
    v_reset: jax.Array

    @staticmethod
    def make(
        n: int,
        *,
        v_th: float = 1.0,
        leak: float = 0.0,
        r_ref: int = 0,
        gain: float = 1.0,
        i_bias: float = 0.0,
        v_reset: float = 0.0,
        dtype=jnp.float32,
    ) -> "LIFParams":
        full = lambda v: jnp.full((n,), v, dtype=dtype)
        return LIFParams(
            v_th=full(v_th),
            leak=full(leak),
            r_ref=jnp.full((n,), r_ref, dtype=jnp.int32),
            gain=full(gain),
            i_bias=full(i_bias),
            v_reset=full(v_reset),
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LIFState:
    """Dynamic neuron state with arbitrary leading batch dims.

    Attributes:
      v: membrane potential ``v[k]``, shape ``(..., n)``.
      r: refractory counter ``r[k]`` (ticks remaining), shape ``(..., n)``.
      y: output spikes from the previous tick, shape ``(..., n)``.
    """

    v: jax.Array
    r: jax.Array
    y: jax.Array

    @staticmethod
    def zeros(batch_shape, n: int, dtype=jnp.float32) -> "LIFState":
        shape = tuple(batch_shape) + (n,)
        return LIFState(
            v=jnp.zeros(shape, dtype=dtype),
            r=jnp.zeros(shape, dtype=jnp.int32),
            y=jnp.zeros(shape, dtype=dtype),
        )


def _threshold_reset_refractory(
    v_tilde: jax.Array,
    state: LIFState,
    params: LIFParams,
    *,
    surrogate: bool,
    reset: str = "zero",
) -> LIFState:
    """Paper Eq. 2-4: spike, reset, refractory-counter update (shared).

    ``reset``: "zero" (paper Eq. 3: v -> v_reset) or "subtract"
    (v -> v - V_th on spike; the standard rate-coding-exact hardware
    variant -- one line of HDL -- used by the classifier readout; see
    EXPERIMENTS.md §Iris for the deviation note).
    """
    not_refractory = (state.r == 0)
    if surrogate:
        y_soft = spike_surrogate(v_tilde - params.v_th)
        y = y_soft * not_refractory.astype(v_tilde.dtype)
    else:
        y = ((v_tilde >= params.v_th) & not_refractory).astype(v_tilde.dtype)
    spiked = y > 0
    if reset == "subtract":
        v_after = v_tilde - params.v_th.astype(v_tilde.dtype)
        v_new = jnp.where(spiked, v_after, v_tilde)
        v_new = jnp.where(state.r > 0, params.v_reset.astype(v_tilde.dtype), v_new)
    else:
        # Eq. 3: v resets if the neuron spiked OR it is still refractory.
        hold = spiked | (state.r > 0)
        v_new = jnp.where(hold, params.v_reset.astype(v_tilde.dtype), v_tilde)
    # Eq. 4: reload the counter on spike, else count down to zero.
    r_new = jnp.where(spiked, params.r_ref, jnp.maximum(state.r - 1, 0))
    return LIFState(v=v_new, r=r_new, y=y)


def lif_step_euler(
    state: LIFState,
    syn_input: jax.Array,
    params: LIFParams,
    *,
    surrogate: bool = False,
    reset: str = "zero",
) -> LIFState:
    """One tick of the Euler LIF model (paper Eq. 1-4).

    Args:
      state: current :class:`LIFState`.
      syn_input: summed weighted synaptic drive ``sum_j w_j s_j[k]`` of shape
        ``(..., n)`` (the synaptic matmul happens outside, or fused in the
        Pallas kernel).
      params: :class:`LIFParams`.
      surrogate: use the differentiable surrogate spike (training).
    """
    decay = (1.0 - params.leak).astype(state.v.dtype)
    v_tilde = decay * state.v + params.gain * (syn_input + params.i_bias)
    return _threshold_reset_refractory(v_tilde, state, params,
                                       surrogate=surrogate, reset=reset)


def lif_step_fixed_leak(
    state: LIFState,
    syn_input: jax.Array,
    params: LIFParams,
    *,
    surrogate: bool = False,
    reset: str = "zero",
) -> LIFState:
    """One tick of the fixed-leak hardware model (paper Eq. 5).

    ``v' = v + sum_j w_j s_j - lambda * 1{v != 0}`` -- the leak is a constant
    decrement applied only to active (non-zero) membranes, exactly as the
    FPGA implements it. The decrement never drives ``v`` through zero from
    the leak alone (the hardware clamps at rest); we clamp the *leak
    contribution* the same way.
    """
    active = (state.v != 0).astype(state.v.dtype)
    leak_step = params.leak * active
    # Clamp: leak alone must not overshoot past the resting potential.
    leak_step = jnp.minimum(leak_step, jnp.abs(state.v))
    v_tilde = state.v + syn_input + params.i_bias - jnp.sign(state.v) * leak_step
    return _threshold_reset_refractory(v_tilde, state, params,
                                       surrogate=surrogate, reset=reset)


def lif_step_int(
    state: LIFState,
    syn_input: jax.Array,
    params: LIFParams,
    *,
    reset: str = "zero",
) -> LIFState:
    """Bit-faithful integer datapath (u8 weights, i32 accumulate).

    Mirrors the FPGA: all quantities are integers, the leak is the fixed
    decrement, and there is no surrogate (inference only).
    """
    v = state.v.astype(jnp.int32)
    syn = syn_input.astype(jnp.int32) + params.i_bias.astype(jnp.int32)
    leak = params.leak.astype(jnp.int32)
    active = (v != 0).astype(jnp.int32)
    leak_step = jnp.minimum(leak * active, jnp.abs(v))
    v_tilde = v + syn - jnp.sign(v) * leak_step
    not_refractory = state.r == 0
    th = params.v_th.astype(jnp.int32)
    spiked = (v_tilde >= th) & not_refractory
    y = spiked.astype(jnp.int32)
    if reset == "subtract":
        v_new = jnp.where(spiked, v_tilde - th, v_tilde)
        v_new = jnp.where(state.r > 0, params.v_reset.astype(jnp.int32), v_new)
    else:
        hold = spiked | (state.r > 0)
        v_new = jnp.where(hold, params.v_reset.astype(jnp.int32), v_tilde)
    r_new = jnp.where(spiked, params.r_ref, jnp.maximum(state.r - 1, 0))
    return LIFState(v=v_new, r=r_new, y=y)


def lif_step(
    state: LIFState,
    syn_input: jax.Array,
    params: LIFParams,
    *,
    mode: str = "fixed_leak",
    surrogate: bool = False,
    reset: str = "zero",
) -> LIFState:
    """Dispatch on the paper's two formulations (+ integer datapath)."""
    if mode == "euler":
        return lif_step_euler(state, syn_input, params, surrogate=surrogate, reset=reset)
    if mode == "fixed_leak":
        return lif_step_fixed_leak(state, syn_input, params, surrogate=surrogate, reset=reset)
    if mode == "int":
        return lif_step_int(state, syn_input, params, reset=reset)
    raise ValueError(f"unknown LIF mode: {mode!r}")
