"""Crossover policy for event dispatch: which formulation wins, where.

The event backend has three ways to compute a tick's synaptic input,
and none of them wins everywhere:

* **dense** -- the plain masked product ``s @ (W*C)``.  ``2*B*n*n``
  FLOPs regardless of activity, but those FLOPs run at GEMM throughput,
  the fastest arithmetic any platform offers.
* **fan_in** -- the padded fan-in gather (:class:`~repro.kernels.ops.
  EventFanIn`): every postsynaptic neuron reads exactly its ``cap``
  in-edges.  ``2*B*n*cap`` FLOPs, activity-independent, vmap-safe --
  but gathers run well below GEMM throughput, so the FLOP reduction
  must clear a platform-dependent *gather penalty* before it pays.
* **topk** -- the spike-list gather (top-k spiking rows steer the
  weight DMA).  ``2*B*k*n`` FLOPs; on TPU this is the Pallas kernel
  whose scalar-prefetched spike list means only spiking rows' fan-out
  slices ever leave HBM.  Cost scales with the *spike budget* ``k``,
  which makes it the one formulation a per-tick spike count can
  arbitrate (the adaptive knee below).

This module is the ONE place those trade-offs live.  Before it, the
fallback trigger ``k = min(k_active or n//8, n)`` was derived twice
(``core/engine.py`` and ``ops.event_synaptic_input``) and could drift;
:func:`resolve_k_active` is now the single source both import.  The
knee model is calibrated against the Table-I-style cost model in
``benchmarks/bench_snn_scale.py`` (same FLOP counts; the measured
gather penalties below come from the committed bench runs).

Two decision levels:

* **Trace time** (:func:`plan`): from concrete connectivity (and the
  input-weight structure), pick the strategy, the fan-in lists, the
  spike budget and the knee.  Runs on the host, *outside* jit -- the
  whole point is that topology is runtime data the compiled program
  never branches on.
* **Tick time** (the knee): for the ``topk`` strategy the engine
  measures the tick's spike count in-scan and ``lax.cond``s to the
  dense product above :func:`knee_spikes`, with hysteresis so the
  branch doesn't thrash when activity hovers at the knee.  Both arms
  are bit-exact, so the branch choice is pure policy, never semantics.

A structural observation the policy also exploits: the external drive
``ext @ w_in`` is a *second* dense ``n x n`` GEMM every tick, and on
the paper's datapath ``w_in`` is diagonal (impulse registers are
per-neuron -- ``network.params_from_registers`` builds ``w_in = I``).
:func:`plan` detects diagonal ``w_in`` and the engine then computes the
drive as an elementwise ``ext * diag(w_in)`` -- identical bits (adding
exact zeros is a no-op in f32), one full GEMM gone.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

# Gather penalty: how many dense MACs one gathered+accumulated element
# costs, per platform.  Calibrated from bench_snn_scale.py runs: on CPU
# (XLA:CPU scalarizes row gathers while Eigen runs the GEMM at full
# vector width) a gathered element costs ~20 dense MACs; on TPU the
# event kernel's DMA-steered gathers stream at memory speed, so the
# penalty is small.  These are *policy* constants -- both arms of every
# choice are bit-exact, so a miscalibration costs speed, never bits.
GATHER_PENALTY: Dict[str, float] = {"cpu": 20.0, "gpu": 6.0, "tpu": 2.0}

# Fixed per-tick overhead of the topk path (the top_k sort itself),
# in dense-MAC-equivalents per presynaptic row scanned.
TOPK_SORT_PENALTY = 4.0

# Hysteresis: the dense->event release threshold as a fraction of the
# event->dense knee.  Activity must fall this far below the knee before
# the engine switches back, so a spike count hovering at the knee
# doesn't flip the branch every tick.
DEFAULT_HYSTERESIS = 0.75


def _platform(platform: Optional[str] = None) -> str:
    if platform is not None:
        return platform
    try:
        import jax

        return jax.default_backend()
    except Exception:  # pragma: no cover
        return "cpu"


def gather_penalty(platform: Optional[str] = None) -> float:
    return GATHER_PENALTY.get(_platform(platform), GATHER_PENALTY["cpu"])


def resolve_k_active(n: int, k_active: Optional[int] = None) -> int:
    """THE spike-budget trigger: ``min(k_active or n//8 (floor 8), n)``.

    Single source of truth for the event backend's top-k slot count --
    ``ops.event_synaptic_input``'s internal trigger, the engine's
    telemetry mirror, and the Pallas kernel bridge all call this, so
    the thresholds cannot drift (they once were derived independently
    in two modules).
    """
    if k_active is None:
        k_active = min(n, max(8, n // 8))
    return min(int(k_active), int(n))


def knee_spikes(n: int, *, platform: Optional[str] = None) -> int:
    """The spike count above which the dense product is the cheaper arm.

    The topk arm pays ``~penalty`` dense-MAC-equivalents per gathered
    weight-row element; the dense arm pays ``n`` rows regardless.  They
    cross where ``spikes * penalty == n``: on CPU (penalty ~20) the
    knee sits near ``n/20``; on TPU near ``n/2``.  Floored at 1 so the
    knee is always a usable threshold.
    """
    return max(1, int(n / gather_penalty(platform)))


# -- cost model (dense-MAC-equivalents per tick) ----------------------------


def dense_cost(n: int, batch: int, *, n_ext_gemms: int = 0) -> float:
    """Masked product ``B*n*n`` MACs (+ any full input-drive GEMMs)."""
    return float(batch) * n * n * (1 + n_ext_gemms)


def fanin_cost(n: int, batch: int, cap: int,
               *, platform: Optional[str] = None) -> float:
    """Padded fan-in gather: ``B*n*cap`` gathered elements."""
    return float(batch) * n * cap * gather_penalty(platform)


def topk_cost(n: int, batch: int, k: int,
              *, platform: Optional[str] = None) -> float:
    """Spike-list gather: ``B*k*n`` gathered elements + the top-k scan."""
    return (float(batch) * k * n * gather_penalty(platform)
            + float(batch) * n * TOPK_SORT_PENALTY)


# -- the trace-time plan ----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """What :func:`plan` decided for one fabric.

    ``strategy`` is the synaptic-input formulation ("fan_in" | "topk" |
    "dense" -- "dense" is still the *event backend*: it keeps the
    diagonal-drive elimination and the adaptive machinery, it just
    computes the synaptic product densely because the topology is past
    the gather knee on this platform).  ``neighbors`` holds the
    :class:`~repro.kernels.ops.EventFanIn` lists when the strategy is
    "fan_in" (runtime data -- same-cap topology swaps never retrace).
    ``knee`` is the per-tick adaptive switch threshold for the "topk"
    strategy (None = no in-scan switching).  ``ext_diag`` records that
    ``w_in`` is diagonal, enabling the elementwise drive.
    ``costs`` is the modeled cost of every candidate (for logs/benches).
    """

    strategy: str
    k_active: int
    knee: Optional[int]
    hysteresis: float
    neighbors: Optional[Any]
    ext_diag: bool
    cap: Optional[int]
    costs: Dict[str, float]

    def engine_kwargs(self) -> Dict[str, Any]:
        """Static kwargs for :class:`~repro.core.engine.TickEngine`.

        ``neighbors`` is runtime data -- pass it to the rollout call,
        not the engine constructor.
        """
        return dict(
            backend="event",
            event_dispatch=self.strategy,
            event_k_active=self.k_active,
            event_knee=self.knee,
            event_hysteresis=self.hysteresis,
            event_ext_diag=self.ext_diag,
        )

    def engine_options(self, **overrides):
        """This plan as a validated
        :class:`~repro.core.engine.EngineOptions` (the preferred engine
        construction); ``overrides`` layer non-event statics on top,
        e.g. ``plan.engine_options(mode="euler", telemetry=True)``."""
        from repro.core.engine import EngineOptions

        kw = self.engine_kwargs()
        kw.update(overrides)
        return EngineOptions(**kw)


def is_diagonal(w_in: Optional[np.ndarray]) -> bool:
    """True when the input matrix routes each input only to its own
    neuron (the paper's per-neuron impulse registers)."""
    if w_in is None:
        return False
    a = np.asarray(w_in)
    if a.ndim != 2 or a.shape[0] != a.shape[1]:
        return False
    return bool(np.count_nonzero(a - np.diag(np.diagonal(a))) == 0)


def plan(
    c,
    *,
    w_in=None,
    batch: int = 1,
    rate: Optional[float] = None,
    k_active: Optional[int] = None,
    cap: Optional[int] = None,
    platform: Optional[str] = None,
    vmap_safe: bool = False,
    adaptive: bool = True,
    prefer_density: Optional[float] = None,
) -> DispatchPlan:
    """Pick the event backend's dispatch strategy for one concrete fabric.

    Host-side, outside jit: ``c`` (and ``w_in``) must be concrete
    arrays -- topology statistics cannot be read off a tracer, which is
    the point (the compiled program never branches on topology; the
    *plan* does, once, at admission/build time).

    Args:
      c: concrete ``(n, n)`` connectivity (bool/0-1).
      w_in: concrete input matrix; diagonal ``w_in`` enables the
        elementwise drive (see module docstring).
      batch: batch size the rollout will run at (cost-model input).
      rate: expected spike rate; tightens the topk budget to
        ``2*rate*n`` instead of the safe default ``n//8`` (the adaptive
        knee + overflow fallback keep any underestimate exact).
      k_active: explicit spike budget (overrides ``rate``).
      cap: force the fan-in list width (serving uses one shared cap so
        every tenant's lists stack to a static shape); None = tightest.
      platform: cost-model platform override (default: the running one).
      vmap_safe: exclude the "topk" strategy -- its overflow/knee
        ``lax.cond`` lowers to a both-arms ``select`` under ``vmap``,
        which forfeits the win (the multi-tenant server sets this).
      adaptive: arm the per-tick knee for the "topk" strategy.
      prefer_density: operator override -- at or below this density a
        fabric whose fan-in fits ``cap`` takes "fan_in" regardless of
        the modeled cost (the server's ``event_density`` contract: the
        operator knows the fleet better than the cost model).
    """
    import jax

    if isinstance(c, jax.core.Tracer) or isinstance(w_in, jax.core.Tracer):
        raise TypeError(
            "dispatch_policy.plan needs concrete connectivity (got a "
            "tracer): plan outside jit -- e.g. at tenant admission or "
            "bench setup -- and pass the resulting DispatchPlan in")
    from repro.core import connectivity

    c_np = np.asarray(c) > 0
    n = c_np.shape[0]
    st = connectivity.stats(c_np)
    if cap is not None and st.max_fan_in > cap:
        # Never truncate: a fabric whose fan-in exceeds the forced cap
        # simply can't take the fan_in strategy.
        cap_eff = None
    else:
        cap_eff = int(cap if cap is not None else max(1, st.max_fan_in))

    if rate is not None and k_active is None:
        k_active = max(8, int(2 * rate * n))
    k = resolve_k_active(n, k_active)

    costs: Dict[str, float] = {
        "dense": dense_cost(n, batch),
        "topk": topk_cost(n, batch, k, platform=platform),
    }
    if cap_eff is not None:
        costs["fan_in"] = fanin_cost(n, batch, cap_eff, platform=platform)

    allowed = ["dense"]
    if cap_eff is not None:
        allowed.append("fan_in")
    if not vmap_safe:
        allowed.append("topk")
    strategy = min(allowed, key=lambda s: costs[s])
    if (prefer_density is not None and st.density <= prefer_density
            and cap_eff is not None):
        strategy = "fan_in"

    neighbors = None
    if strategy == "fan_in":
        from repro.kernels.ops import EventFanIn

        neighbors = EventFanIn.from_padded(
            connectivity.padded_fan_in(c_np, cap_eff))

    knee = None
    if strategy == "topk" and adaptive:
        knee = min(knee_spikes(n, platform=platform), k)

    return DispatchPlan(
        strategy=strategy,
        k_active=k,
        knee=knee,
        hysteresis=DEFAULT_HYSTERESIS,
        neighbors=neighbors,
        ext_diag=is_diagonal(w_in),
        cap=cap_eff,
        costs=costs,
    )
