"""u8 weight quantization -- the FPGA's integer datapath.

The hardware stores synaptic weights as integers in [0, 255] (paper §II.A)
and thresholds as 8-bit registers. Research-mode training uses floats; this
module maps trained float weights onto the hardware grid so the register
bank holds *exactly* what the FPGA would, and inference through
``lif_step_int`` is bit-faithful.

Scheme: symmetric-positive affine. Weights here are non-negative
(excitatory-only hardware); signed nets are shifted by bias neurons before
download (``quantize_signed`` handles the split).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

WEIGHT_MAX = 255
THRESH_MAX = 255


class QuantizedWeights(NamedTuple):
    q: jax.Array       # u8 weights
    scale: jax.Array   # float scale: w ~= q * scale


def quantize_u8(w: jax.Array, w_max: float | None = None) -> QuantizedWeights:
    """Quantize non-negative float weights to u8 with a shared scale."""
    w = jnp.maximum(w, 0.0)
    if w_max is None:
        w_max = jnp.maximum(jnp.max(w), 1e-8)
    scale = w_max / WEIGHT_MAX
    q = jnp.clip(jnp.round(w / scale), 0, WEIGHT_MAX).astype(jnp.uint8)
    return QuantizedWeights(q=q, scale=jnp.asarray(scale, jnp.float32))


def dequantize_u8(qw: QuantizedWeights) -> jax.Array:
    return qw.q.astype(jnp.float32) * qw.scale


def quantize_signed(w: jax.Array) -> Tuple[QuantizedWeights, QuantizedWeights]:
    """Split a signed weight matrix into excitatory / inhibitory u8 banks.

    The FPGA fabric is excitatory-only per synapse; inhibition is realized
    by a parallel bank whose contribution is subtracted in the accumulator.
    Returns ``(excitatory, inhibitory)`` quantized with a shared scale so
    the integer difference reproduces the signed sum.
    """
    w_max = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    pos = quantize_u8(jnp.maximum(w, 0.0), w_max)
    neg = quantize_u8(jnp.maximum(-w, 0.0), w_max)
    return pos, neg


def quantize_threshold(v_th: jax.Array, scale: jax.Array) -> jax.Array:
    """Thresholds live on the same integer grid as the weights."""
    return jnp.clip(jnp.round(v_th / scale), 1, THRESH_MAX).astype(jnp.uint8)


def integer_network(w: jax.Array, v_th: jax.Array):
    """Convenience: signed float net -> (w_int i32, v_th i32, scale).

    ``w_int = q_pos - q_neg`` accumulated in i32 -- exactly the two-bank
    hardware sum. Thresholds are quantized on the shared scale.
    """
    pos, neg = quantize_signed(w)
    w_int = pos.q.astype(jnp.int32) - neg.q.astype(jnp.int32)
    th_int = quantize_threshold(v_th, pos.scale).astype(jnp.int32)
    return w_int, th_int, pos.scale
