"""Surrogate-gradient spike function.

The paper programs weights from the host (inference-only hardware). To
*validate* the paper's accuracy claims end-to-end without hand-tuned
weights, we train the SNN offline with surrogate-gradient BPTT and then
quantize + download the weights through the register bank -- the same
workflow the authors used (host-side Python prepares all parameters).

Forward: Heaviside step.  Backward: fast-sigmoid surrogate
(SuperSpike, Zenke & Ganguli 2018): ``d/dx H(x) ~= 1 / (beta*|x| + 1)^2``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

DEFAULT_BETA = 10.0


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def spike_surrogate(x: jax.Array, beta: float = DEFAULT_BETA) -> jax.Array:
    """Heaviside forward / fast-sigmoid backward."""
    return (x >= 0).astype(x.dtype)


def _fwd(x, beta):
    return spike_surrogate(x, beta), x


def _bwd(beta, x, g):
    surr = 1.0 / (beta * jnp.abs(x) + 1.0) ** 2
    return (g * surr.astype(g.dtype),)


spike_surrogate.defvjp(_fwd, _bwd)


def spike_hard(x: jax.Array) -> jax.Array:
    """Non-differentiable Heaviside (inference datapath)."""
    return (x >= 0).astype(x.dtype)
