"""UART 8N1 framing codec + host link simulation (paper §II.B-C).

The ZedBoard link runs 9600-8N1: each byte on the wire is
``[start=0][8 data bits, LSB first][stop=1]``. We implement the exact bit
codec (property-tested for roundtrip), a byte-level host link with the
validation gating the paper describes (``tx_valid``), and the timing
calculator shared with :mod:`repro.core.registers`.

At production scale the UART's *role* (host->device parameter download) is
played by ``jax.device_put`` of register arrays; :func:`scaled_reprogram_time`
gives the equivalent cost model over PCIe/ICI for DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List

import numpy as np

BAUD_DEFAULT = 9600
BITS_PER_FRAME = 10  # start + 8 data + stop


def encode_frame(byte: int) -> List[int]:
    """One 8N1 frame, LSB-first data."""
    if not 0 <= byte <= 0xFF:
        raise ValueError(f"byte out of range: {byte}")
    data = [(byte >> i) & 1 for i in range(8)]
    return [0] + data + [1]


def decode_frame(bits: Iterable[int]) -> int:
    bits = list(bits)
    if len(bits) != BITS_PER_FRAME:
        raise ValueError(f"frame must be {BITS_PER_FRAME} bits, got {len(bits)}")
    if bits[0] != 0:
        raise ValueError("bad start bit")
    if bits[-1] != 1:
        raise ValueError("bad stop bit")
    return sum(b << i for i, b in enumerate(bits[1:9]))


def encode_stream(payload: bytes) -> np.ndarray:
    """Bytes -> wire bit stream (idle-high between frames omitted)."""
    out = np.empty(len(payload) * BITS_PER_FRAME, dtype=np.uint8)
    for i, b in enumerate(payload):
        out[i * BITS_PER_FRAME : (i + 1) * BITS_PER_FRAME] = encode_frame(b)
    return out


def decode_stream(bits: np.ndarray) -> bytes:
    if len(bits) % BITS_PER_FRAME:
        raise ValueError("bit stream length not a multiple of frame size")
    n = len(bits) // BITS_PER_FRAME
    return bytes(
        decode_frame(bits[i * BITS_PER_FRAME : (i + 1) * BITS_PER_FRAME]) for i in range(n)
    )


def wire_time_s(n_bytes: int, baud: int = BAUD_DEFAULT) -> float:
    """Physical transfer time for n bytes at 8N1."""
    return n_bytes * BITS_PER_FRAME / baud


@dataclasses.dataclass
class LinkStats:
    bytes_tx: int = 0
    bytes_rx: int = 0
    frames_bad: int = 0

    @property
    def time_s(self) -> float:
        return wire_time_s(self.bytes_tx + self.bytes_rx)


class HostLink:
    """Loop-back UART link with tx_valid gating and stats.

    ``send`` models host->FPGA (UART_Rx path): bytes are framed, "wired",
    decoded, and handed to the device callback only when the frame is valid
    -- the validation gating of §II.C.
    """

    def __init__(self, baud: int = BAUD_DEFAULT):
        self.baud = baud
        self.stats = LinkStats()

    def send(self, payload: bytes) -> bytes:
        bits = encode_stream(payload)
        self.stats.bytes_tx += len(payload)
        decoded = decode_stream(bits)
        return decoded

    def receive(self, payload: bytes) -> bytes:
        """FPGA->host (UART_Tx path)."""
        bits = encode_stream(payload)
        self.stats.bytes_rx += len(payload)
        return decode_stream(bits)


def scaled_reprogram_time(
    n_bytes: int, *, bandwidth_gbps: float = 16.0, latency_us: float = 10.0
) -> float:
    """Host->device register download cost at production scale.

    The paper's future-work section proposes Ethernet/USB to beat the
    93.54 ms UART reprogram; on a TPU host the same role is a PCIe-class
    transfer. Returns seconds for ``n_bytes`` at ``bandwidth_gbps`` plus a
    fixed dispatch latency.
    """
    return latency_us * 1e-6 + n_bytes * 8 / (bandwidth_gbps * 1e9)
