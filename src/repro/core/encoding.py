"""Spike encoders / decoders (the paper's host-side preprocessing, §IV).

* MNIST 8x8: grayscale -> binarize by threshold -> one spike per active
  pixel (paper §III.B).
* Iris: features normalized and quantized to small spike counts
  (the waveform in Fig. 5 shows quantized feature values 01/01/04/02 used
  as impulse magnitudes); we provide both *rate* coding (feature value ->
  number of spikes over T ticks) and *level* coding (feature value ->
  integer impulse magnitude on one tick).
* Decoders: spike-count argmax ("the neuron with the highest accumulated
  activation", §III.B) and first-spike.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def binarize(x: jax.Array, threshold: float = 0.5) -> jax.Array:
    """Pixels above threshold spike ('1'), the rest stay silent ('0')."""
    return (x > threshold).astype(jnp.float32)


def level_encode(x: jax.Array, levels: int = 4, x_max: float = 1.0) -> jax.Array:
    """Quantize a feature in [0, x_max] to an integer impulse magnitude.

    Reproduces the Fig. 5 impulse registers (values like 01/02/04): the
    feature is scaled to ``[0, levels]`` and rounded. The result drives the
    synaptic input directly on a single tick.
    """
    q = jnp.round(jnp.clip(x / x_max, 0.0, 1.0) * levels)
    return q.astype(jnp.float32)


def rate_encode(
    x: jax.Array, n_ticks: int, x_max: float = 1.0
) -> jax.Array:
    """Deterministic rate code: feature value -> spike count over n_ticks.

    Returns shape ``(n_ticks, *x.shape)`` of {0,1} spikes, evenly spaced
    (deterministic; reproducible without RNG, like the hardware testbench).
    """
    frac = jnp.clip(x / x_max, 0.0, 1.0)
    # Spike at tick t iff floor(frac*(t+1)) > floor(frac*t)  (Bresenham).
    t = jnp.arange(1, n_ticks + 1, dtype=jnp.float32)
    shaped = frac[None, ...] * t.reshape((n_ticks,) + (1,) * x.ndim)
    prev = frac[None, ...] * (t - 1.0).reshape((n_ticks,) + (1,) * x.ndim)
    return (jnp.floor(shaped + 1e-6) > jnp.floor(prev + 1e-6)).astype(jnp.float32)


def latency_encode(x: jax.Array, n_ticks: int, x_max: float = 1.0) -> jax.Array:
    """Stronger inputs spike earlier; zero input never spikes."""
    frac = jnp.clip(x / x_max, 0.0, 1.0)
    fire_at = jnp.where(frac > 0, jnp.round((1.0 - frac) * (n_ticks - 1)), n_ticks)
    t = jnp.arange(n_ticks).reshape((n_ticks,) + (1,) * x.ndim)
    return (t == fire_at[None, ...]).astype(jnp.float32)


def decode_spike_count(spikes: jax.Array, axis: int = 0) -> jax.Array:
    """Class = output neuron with the highest accumulated activation."""
    return jnp.argmax(spikes.sum(axis=axis), axis=-1)


def decode_first_spike(
    spikes: jax.Array, v: jax.Array = None, *, silent: int = -1
) -> jax.Array:
    """Class = first output neuron to spike (ties -> lower index).

    ``spikes`` has shape ``(T, ..., n_out)``.

    All-silent rows (no output neuron ever spikes) used to decode to
    class 0 silently: every ``first`` entry was ``n_ticks`` and argmin
    returned the first index.  Now they fall back to
    :func:`decode_potential` tie-breaking when the final membrane
    potentials ``v`` (shape ``(..., n_out)``) are given, and otherwise
    return the documented ``silent`` sentinel (default -1, never a valid
    class) so callers can't mistake silence for a confident class-0.
    """
    t_axis = 0
    n_ticks = spikes.shape[t_axis]
    ticks = jnp.arange(n_ticks, dtype=jnp.float32).reshape(
        (n_ticks,) + (1,) * (spikes.ndim - 1)
    )
    first = jnp.where(spikes > 0, ticks, jnp.float32(n_ticks))
    first = first.min(axis=t_axis)
    pred = jnp.argmin(first, axis=-1)
    all_silent = first.min(axis=-1) >= n_ticks
    fallback = decode_potential(v) if v is not None else jnp.asarray(
        silent, pred.dtype)
    return jnp.where(all_silent, fallback, pred)


def decode_potential(v: jax.Array) -> jax.Array:
    """Class = output neuron with the highest final membrane potential
    (tie-break decoder when no output neuron reaches threshold)."""
    return jnp.argmax(v, axis=-1)
