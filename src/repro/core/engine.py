"""TickEngine: ONE scan body behind every rollout flavor.

The paper's datapath is a single resident circuit -- delay-line read,
masked synaptic accumulation (the mux fabric), LIF update, delay-line
write -- and everything else (frozen inference, on-device learning,
layered feed-forward sweeps, multi-tenant serving) is just a different
*carry* threaded through that same circuit. Before this module the repo
had three near-duplicate ``lax.scan`` bodies re-deriving the tick;
now :meth:`TickEngine.tick_body` is the only place the tick exists, and
``repro.core.network.rollout`` / ``learning_rollout`` /
``forward_layered`` are thin wrappers over :meth:`TickEngine.scan`.

Two structural invariants the engine owns:

* **One backend dispatch point.** ``backend="jnp"`` (reference) vs
  ``backend="pallas"`` (fused synaptic-matmul+LIF kernel) vs
  ``backend="pallas_fused"`` (the whole-tick megakernel: delay read,
  masked accumulation, LIF update, delay write in ONE ``pallas_call``,
  circular delay pointer scalar-prefetched -- see
  :mod:`repro.kernels.tick_fused`) vs ``backend="event"`` (event-driven
  sparse dispatch: only spiking neurons' fan-outs are gathered, the mux
  fabric's silent-neurons-cost-nothing property -- see
  :func:`repro.kernels.ops.event_lif_step`) is decided in exactly one
  branch inside the tick body -- no caller ever re-implements it, and
  delay rings, refractory state and the plasticity hook compose with
  every backend unchanged.

* **Loop-invariant mask hoisting.** For the frozen-weight path the
  masked matrix ``W*C`` is materialized once per rollout, *outside* the
  scan, and closed over as a scan constant (tests/test_engine.py pins
  this on the optimized HLO: no (n,n) multiply inside the while body).
  The learning path recomputes ``W*C`` per tick because ``W`` lives in
  the carry and changes every tick -- that recompute is the datapath,
  not waste.

Carry spec: :class:`TickCarry` has four slots -- ``state`` (always),
``plast`` + ``w`` (learning only) and ``telem`` (telemetry only;
``None`` leaves vanish from the pytree, so the frozen/untelemetered
carry is exactly the seed's ``SNNState`` carry and rasters stay
bit-identical).

Observability (DESIGN.md §11): ``telemetry=True`` (a *static* flag, like
``backend``) threads a :class:`~repro.obs.telemetry.TickTelemetry`
accumulator through the carry -- per-tick spike counts, membrane
mean/max, refractory occupancy, event-overflow ticks and plasticity
weight-delta norms, all carry-resident reductions with no host syncs
inside the scan. ``telemetry=False`` compiles to HLO byte-identical to
the pre-observability engine (pinned in tests/test_obs.py), and the
``jax.named_scope`` labels on the backend arms are pure metadata under
the same pin.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lif import lif_step
from repro.deprecation import warn_deprecated
from repro.core.network_types import SNNParams, SNNState  # noqa: F401 (re-export surface)

_BACKENDS = ("jnp", "pallas", "pallas_fused", "event")
_MODES = ("fixed_leak", "euler", "int")
_OVERFLOW = ("fallback", "strict", "unchecked")
_DISPATCH = ("auto", "fan_in", "topk", "dense")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TickCarry:
    """What one tick hands the next.

    Attributes:
      state: the network state (LIF + delay line + tick counter).
      plast: plasticity traces/eligibility, or None on the frozen path.
      w: the *mutable* weight matrix, or None on the frozen path (frozen
        weights are scan constants, so they live outside the carry and
        the hoisted ``W*C`` stays valid for the whole rollout).
      telem: :class:`~repro.obs.telemetry.TickTelemetry` accumulators, or
        None when the engine's ``telemetry`` flag is off (the leaf then
        vanishes from the pytree -- zero carry growth, identical HLO).
      policy: adaptive-dispatch hysteresis bit (scalar bool), or None
        when the engine has no per-tick knee armed (``event_knee``).
        True means the previous tick ran the dense arm for speed; the
        knee's release threshold then drops to ``hysteresis * knee`` so
        activity hovering at the knee doesn't flip the branch per tick.
    """

    state: SNNState
    plast: Optional[Any] = None
    w: Optional[jax.Array] = None
    telem: Optional[Any] = None
    policy: Optional[jax.Array] = None


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    """ALL of the engine's static (trace-time) configuration, in one
    frozen, *validated* dataclass.

    This is the one home for what used to be :class:`TickEngine`'s
    sprawl of per-call statics (``backend``, ``telemetry``,
    ``event_k_active``, ``event_overflow``, ``event_dispatch``,
    ``event_knee``, ``event_hysteresis``, ``event_ext_diag``, ...).
    Invalid values and invalid *combinations* (e.g. ``event_knee``
    without ``event_overflow="fallback"``) fail here, at construction,
    with a clear message -- not deep inside the scan.

    Hashable non-pytree, like the LIF ``mode`` string it generalizes:
    jit-safe to close over, cheap to ``dataclasses.replace``. Build one
    and pass it to :class:`TickEngine`,
    :func:`repro.core.network.rollout` /
    :func:`~repro.core.network.learning_rollout`, or
    :class:`repro.launch.serve.SNNServer` -- the per-call static kwargs
    those accept remain as a deprecation shim for one release.

    Attributes:
      mode: LIF formulation ("fixed_leak" | "euler" | "int").
      surrogate: differentiable surrogate spike (training; jnp/event only).
      backend: "jnp" (reference), "pallas" (fused matmul+LIF kernel),
        "pallas_fused" (whole-tick megakernel, one launch per tick) or
        "event" (event-driven sparse dispatch: gather only spiking
        neurons' fan-outs -- the large-sparse-fabric backend).
      plasticity: optional :class:`~repro.plasticity.stdp.PlasticityParams`;
        when set *and* the carry holds weights, the plasticity hook runs
        after the delay-line write each tick.
      plasticity_backend: backend for the plasticity hook; defaults to
        following ``backend``.
      event_k_active: spike-slot budget for the event backend's top-k
        dispatch (None -> ``n // 8``, floored at 8, via
        :func:`repro.core.dispatch_policy.resolve_k_active`); rows
        spiking past it fall back to the dense product per
        ``event_overflow``.
      event_overflow: "fallback" (dense product on overflow ticks,
        exact at any rate), "strict" (checkify error) or "unchecked".
      event_dispatch: the event backend's synaptic-input formulation --
        "auto" (fan-in gather when ``neighbors`` is provided, else the
        top-k spike list; the :mod:`~repro.core.dispatch_policy` plan
        picks smarter), "fan_in" (requires ``neighbors``), "topk"
        (spike-list gather) or "dense" (masked product; still the event
        backend: it keeps the diagonal-drive elimination and telemetry,
        it just computes the synaptic product densely because the
        topology is past the gather knee on this platform).
      event_knee: per-tick adaptive switch for the "topk" strategy:
        ticks whose max batch-row spike count exceeds this run the
        dense product instead of the spike-list gather (both arms
        bit-exact -- the knee is pure speed policy). None disables
        in-scan switching. See :func:`repro.core.dispatch_policy.
        knee_spikes` for the calibrated default.
      event_hysteresis: release fraction for the knee: after a dense
        tick, activity must fall below ``hysteresis * knee`` before the
        engine switches back to the spike-list arm.
      event_ext_diag: the external drive ``ext @ w_in`` is computed as
        the elementwise ``ext * diag(w_in)`` -- set (by the dispatch
        plan) only when ``w_in`` is diagonal, where it is bit-identical
        and saves a full ``n x n`` GEMM per tick.
      telemetry: static flag; when True the carry gains a
        :class:`~repro.obs.telemetry.TickTelemetry` slot and every tick
        folds its reductions in (see the module docstring). When False
        (default) the lowered HLO is byte-identical to the
        pre-observability engine.
      mesh: optional :class:`jax.sharding.Mesh`; when set, ``scan()``
        (and everything funneling through it: rollout, learning_rollout,
        chunk) runs under ``shard_map`` with the fabric partitioned by
        destination columns across ``shard_axis`` -- see
        :mod:`repro.parallel.snn_sharding` and DESIGN.md §15.  Hashable
        (meshes compare by device assignment), so the options stay a
        jit-safe static.
      shard_axis: mesh axis name to shard over (None -> the mesh's first
        axis).  Set *without* ``mesh`` it marks the engine as running
        INSIDE a ``shard_map`` body (the tick body then all-gathers the
        arriving spikes along this axis) -- that is how
        ``snn_sharding.sharded_scan`` builds its inner engine; user code
        sets ``mesh`` and leaves the inner form alone.
    """

    mode: str = "fixed_leak"
    surrogate: bool = False
    backend: str = "jnp"
    plasticity: Optional[Any] = None
    plasticity_backend: Optional[str] = None
    event_k_active: Optional[int] = None
    event_overflow: str = "fallback"
    event_dispatch: str = "auto"
    event_knee: Optional[int] = None
    event_hysteresis: float = 0.75
    event_ext_diag: bool = False
    telemetry: bool = False
    mesh: Optional[Any] = None
    shard_axis: Optional[str] = None

    def __post_init__(self):
        self.validate()

    def validate(self) -> None:
        """Fail fast on invalid values or combinations (construction-time)."""
        if self.backend not in _BACKENDS:
            raise ValueError(
                f"backend must be one of {_BACKENDS}, got {self.backend!r}")
        if self.mode not in _MODES:
            raise ValueError(
                f"mode must be one of {_MODES}, got {self.mode!r}")
        if self.plasticity_backend not in (None,) + _BACKENDS:
            raise ValueError(
                f"plasticity_backend must be None or one of {_BACKENDS}, "
                f"got {self.plasticity_backend!r}")
        if self.event_overflow not in _OVERFLOW:
            raise ValueError(
                f"event_overflow must be one of {_OVERFLOW}, "
                f"got {self.event_overflow!r}")
        if self.event_dispatch not in _DISPATCH:
            raise ValueError(
                f"event_dispatch must be one of {_DISPATCH}, "
                f"got {self.event_dispatch!r}")
        if self.event_k_active is not None and int(self.event_k_active) < 1:
            raise ValueError(
                f"event_k_active must be >= 1 (or None for the n//8 "
                f"default), got {self.event_k_active}")
        if self.event_knee is not None:
            if int(self.event_knee) < 1:
                raise ValueError(
                    f"event_knee must be >= 1 ticks' spikes (or None to "
                    f"disable the adaptive knee), got {self.event_knee}")
            if self.event_overflow != "fallback":
                raise ValueError(
                    "event_knee requires event_overflow='fallback' (the "
                    "knee routes overflow ticks to the dense arm silently, "
                    "which contradicts strict/unchecked semantics)")
        if not (0.0 < float(self.event_hysteresis) <= 1.0):
            raise ValueError(
                "event_hysteresis is a release *fraction* of the knee and "
                f"must lie in (0, 1], got {self.event_hysteresis}")
        if self.mesh is not None:
            from jax.sharding import Mesh

            if not isinstance(self.mesh, Mesh):
                raise ValueError(
                    f"mesh must be a jax.sharding.Mesh, got {type(self.mesh)}")
            names = tuple(self.mesh.axis_names)
            axis = self.shard_axis if self.shard_axis is not None else names[0]
            if axis not in names:
                raise ValueError(
                    f"shard_axis {axis!r} is not a mesh axis (axes: {names})")
        if self.sharded and self.event_ext_diag:
            raise ValueError(
                "event_ext_diag is unavailable on the sharded path: each "
                "shard holds a rectangular (n_in, n/D) slice of w_in whose "
                "jnp.diagonal is NOT the diagonal drive; the full "
                "ext @ w_in product is rectangular-safe, use that")

    @property
    def sharded(self) -> bool:
        """True when this engine partitions (or runs inside a partition
        of) the fabric -- outer ``mesh`` or inner ``shard_axis`` form."""
        return self.mesh is not None or self.shard_axis is not None

    def resolved_shard_axis(self) -> Optional[str]:
        """The mesh axis the fabric shards over (None when unsharded)."""
        if self.shard_axis is not None:
            return self.shard_axis
        if self.mesh is not None:
            return tuple(self.mesh.axis_names)[0]
        return None

    def effective_backend(self) -> str:
        """The backend the tick body actually dispatches to.

        Sharded ``"pallas_fused"`` remaps to ``"pallas"``: the whole-tick
        megakernel couples the delay-ring width to the state width inside
        one ``pallas_call`` and so cannot span the per-tick spike
        all-gather; the unfused pallas arm (fused synaptic-matmul+LIF,
        ring managed outside) composes with the collective unchanged.

        Exactness of the remap: on the frozen path weights live on the
        dyadic u8-grid, every f32 reduction order is exact, and the two
        arms are bitwise identical (pinned in tests/test_snn_sharding).
        Learning pushes weights off the grid, so the remapped arm agrees
        with single-device ``"pallas"`` learning bitwise and with the
        megakernel only to the ulp -- the documented contract for
        sharded ``pallas_fused`` learning.  (A 1-device mesh skips the
        remap entirely and stays bitwise with the megakernel: see
        :func:`repro.parallel.snn_sharding.sharded_scan`.)"""
        if self.sharded and self.backend == "pallas_fused":
            return "pallas"
        return self.backend

    def _event_strategy(self, neighbors: Optional[Any]) -> str:
        """Resolve ``event_dispatch`` against what the call provided."""
        strategy = self.event_dispatch
        if strategy == "auto":
            strategy = "fan_in" if neighbors is not None else "topk"
        if strategy not in ("fan_in", "topk", "dense"):
            raise ValueError(
                f"event_dispatch must be auto|fan_in|topk|dense, got "
                f"{self.event_dispatch!r}")
        if strategy == "fan_in" and neighbors is None:
            raise ValueError(
                "event_dispatch='fan_in' needs fan-in neighbor lists: pass "
                "neighbors=EventFanIn.from_dense(wc, c) (or let "
                "dispatch_policy.plan build them)")
        return strategy


class TickEngine(EngineOptions):
    """The resident tick datapath, configured by :class:`EngineOptions`.

    Preferred construction::

        eng = TickEngine(EngineOptions(backend="event", telemetry=True))

    The old per-call static kwargs (``TickEngine(backend=..., mode=...,
    event_k_active=..., ...)``) remain accepted as a deprecation shim for
    one release; they emit a :class:`DeprecationWarning` and keep the old
    *lazy* validation semantics (invalid combinations fail where they
    always did, inside the scan) so existing callers see no behavior
    change. New code should build an :class:`EngineOptions`, which
    validates eagerly at construction.

    Hashable, frozen, and field-compatible with :class:`EngineOptions`
    (it *is* one), so it stays jit-safe to close over.
    """

    def __init__(self, options: Optional[EngineOptions] = None, **legacy):
        if options is not None:
            if legacy:
                raise TypeError(
                    "pass ONE of EngineOptions or legacy static kwargs, "
                    f"not both (got options= and {sorted(legacy)})")
            if not isinstance(options, EngineOptions):
                raise TypeError(
                    f"options must be an EngineOptions, got {type(options)}")
            EngineOptions.__init__(
                self, **{f.name: getattr(options, f.name)
                         for f in dataclasses.fields(EngineOptions)})
            return
        names = {f.name for f in dataclasses.fields(EngineOptions)}
        unknown = set(legacy) - names
        if unknown:
            raise TypeError(
                f"unknown engine option(s) {sorted(unknown)}; valid names: "
                f"{sorted(names)}")
        if legacy:
            warn_deprecated(
                "TickEngine(**per-call statics) is deprecated; build a "
                "validated EngineOptions and pass TickEngine(options) "
                "(the kwargs shim remains for one release)")
        # Legacy shim: set fields WITHOUT the eager cross-field validation
        # (old callers relied on e.g. the event_knee/event_overflow clash
        # raising inside rollout, not at construction).
        for f in dataclasses.fields(EngineOptions):
            object.__setattr__(self, f.name, legacy.get(f.name, f.default))

    @property
    def options(self) -> EngineOptions:
        """This engine's configuration as a plain :class:`EngineOptions`."""
        return EngineOptions(**{f.name: getattr(self, f.name)
                                for f in dataclasses.fields(EngineOptions)})

    # -- the single tick body ---------------------------------------------

    def masked_weights(self, params: SNNParams, w: Optional[jax.Array] = None) -> jax.Array:
        """``W*C``: the mux fabric's effective matrix.

        ``c=None`` means the implicit all-to-all (every mux closed): the
        effective matrix IS ``w``, and no second ``(n, n)`` buffer is ever
        materialized -- the memory-math escape hatch for the 64k fabric
        (DESIGN.md §15)."""
        w = params.w if w is None else w
        if params.c is None:
            return w
        return w * params.c.astype(w.dtype)

    def tick_body(
        self,
        carry: TickCarry,
        xs: Tuple[Optional[jax.Array], Optional[jax.Array]],
        *,
        params: SNNParams,
        wc: Optional[jax.Array] = None,
        delays: Optional[jax.Array] = None,
        plastic_c: Optional[jax.Array] = None,
        learn_until: Optional[jax.Array] = None,
        neighbors: Optional[Any] = None,
    ) -> Tuple[TickCarry, jax.Array]:
        """One synchronous network tick:

        delay-line read -> synaptic input -> LIF step -> delay-line write
        [-> plasticity hook].

        Args:
          xs: ``(ext, reward)`` -- this tick's external drive (impulse
            registers) and dopamine scalar; either may be None.
          wc: pre-masked ``W*C`` (frozen path; loop-invariant, hoisted by
            the caller). None means derive it from the carry weights.
          delays: optional per-synapse delay matrix, shape ``(n, n)`` int
            in ``[1, max_delay]``.
          plastic_c: learnable-synapse mask for the plasticity hook.
          learn_until: optional scalar tick bound (runtime value): the
            plasticity hook only commits weight/trace updates while
            ``tick < learn_until``. Serving uses this to stop learning at
            a request's tick budget without changing program shape.
          neighbors: optional :class:`repro.kernels.ops.EventFanIn`
            switching the ``"event"`` backend to its padded fan-in gather
            path (no data-dependent control flow -- safe under ``vmap``,
            which is how the multi-tenant server runs sparse tenants).
            Ignored by the dense backends.
        """
        ext, reward = xs
        st = carry.state
        learning = carry.w is not None
        w = carry.w if learning else params.w
        backend = self.effective_backend()
        # Inner-shard form (set by snn_sharding.sharded_scan): this tick
        # body runs inside shard_map on (n, n/D) operands and must gather
        # the arriving spikes before the fan-in product.
        shard_axis = self.shard_axis if self.mesh is None else None
        if params.c is None and backend in ("pallas", "pallas_fused"):
            raise ValueError(
                "c=None (implicit all-to-all) needs the jnp or event "
                "backend: the Pallas kernels stream c as an explicit "
                "operand and mask per tile")

        max_delay = st.delay_buf.shape[-2]

        if backend == "pallas_fused":
            # -- whole-tick megakernel: delay read, masked accumulation, LIF
            #    update and delay write in ONE pallas_call; the circular
            #    pointers ride in as scalar prefetch (no retrace per tick).
            #    ``wc`` (pre-masked, hoisted) serves the frozen path; the
            #    learning path streams w (this tick's matrix) + c and masks
            #    per tile in VMEM.
            from repro.kernels import ops  # local import; CPU tests use jnp

            with jax.named_scope("tick/pallas_fused"):
                p = dataclasses.replace(params, w=w) if learning else params
                lif_state, delay_buf = ops.fused_tick(
                    st, p, ext, wc=wc, delays=delays,
                    mode=self.mode, surrogate=self.surrogate)
            state2 = SNNState(lif=lif_state, delay_buf=delay_buf,
                              tick=st.tick + 1)
            return self._tick_tail(carry, st, state2, w, reward,
                                   params, plastic_c, learn_until)

        if wc is None and (delays is not None or backend != "pallas"):
            # Every remaining path consumes the premasked matrix -- except
            # the unfused "pallas" uniform-delay tick, whose kernel masks
            # per tile in VMEM; forming wc there would be a dead (n, n)
            # multiply traced into every tick.
            wc = w if params.c is None else w * params.c.astype(w.dtype)

        slot = jnp.mod(st.tick, max_delay)
        overflow_inc = None
        policy_inc = None
        policy_out = None

        if delays is None:
            # -- delay-line read: spikes scheduled to arrive this tick.
            arriving = jax.lax.dynamic_index_in_dim(
                st.delay_buf, slot, axis=-2, keepdims=False
            ) if max_delay > 1 else st.lif.y
            if shard_axis is not None:
                # -- cross-shard spike exchange: THE one collective per
                #    tick. Gathering the (B, n/D) local arriving spikes
                #    into the full (B, n) presynaptic vector lets every
                #    shard reduce its output columns over the complete
                #    fan-in locally, in the single-device order -- which
                #    is what keeps the sharded rollout bit-exact (a psum
                #    of partial fan-ins would re-associate the f32 sum).
                #    tiled=True concatenates shard blocks in axis order,
                #    exactly the global column layout.  The gather sits
                #    BEFORE the event knee's lax.cond, so both arms (and
                #    every shard's branch decision) see identical data
                #    and no collective ever hides inside a branch.
                with jax.named_scope("tick/spike_all_gather"):
                    arriving = jax.lax.all_gather(
                        arriving, shard_axis,
                        axis=arriving.ndim - 1, tiled=True)
            # -- synaptic input + LIF step: THE backend dispatch point.
            if backend == "pallas":
                from repro.kernels import ops  # local import; CPU tests use jnp

                with jax.named_scope("tick/pallas"):
                    p = dataclasses.replace(params, w=w) if learning else params
                    lif_state = ops.fused_lif_step(
                        st.lif, arriving, p, ext,
                        mode=self.mode, surrogate=self.surrogate)
            elif backend == "event":
                # -- event-driven dispatch: only spiking neurons' fan-outs
                #    are gathered (the mux fabric routes nothing for silent
                #    neurons). ``wc`` is the hoisted matrix on the frozen
                #    path and this tick's carry-derived matrix when learning.
                #    The formulation ("fan_in" gather | "topk" spike list |
                #    "dense" product) is the trace-time strategy; the "topk"
                #    strategy additionally arbitrates per tick at the knee.
                from repro.core import dispatch_policy
                from repro.kernels import ops  # local import; CPU path is jnp

                strategy = self._event_strategy(neighbors)
                n = arriving.shape[-1]
                k = dispatch_policy.resolve_k_active(n, self.event_k_active)
                telemetry = self.telemetry and carry.telem is not None

                def _dense_step():
                    # The dense arm of the event backend: the masked product
                    # plus the (possibly diagonal-eliminated) drive. With
                    # event_ext_diag=False this is bit-identical to the
                    # "jnp" backend's tick; with it, identical anyway when
                    # w_in is diagonal (adding exact zeros is a f32 no-op).
                    syn = arriving @ wc
                    if ext is not None:
                        syn = syn + (
                            ext * jnp.diagonal(params.w_in)
                            if self.event_ext_diag else ext @ params.w_in)
                    return lif_step(st.lif, syn, params.lif, mode=self.mode,
                                    surrogate=self.surrogate)

                with jax.named_scope(f"tick/event/{strategy}"):
                    if strategy == "dense":
                        lif_state = _dense_step()
                    elif strategy == "fan_in":
                        # Exact by construction (no overflow: every in-edge
                        # is always read), safe under vmap.
                        lif_state = ops.event_lif_step(
                            st.lif, arriving, params, ext, wc,
                            k_active=self.event_k_active, fan_in=neighbors,
                            overflow=self.event_overflow,
                            mode=self.mode, surrogate=self.surrogate,
                            ext_diag=self.event_ext_diag)
                    elif self.event_knee is None:
                        lif_state = ops.event_lif_step(
                            st.lif, arriving, params, ext, wc,
                            k_active=self.event_k_active, fan_in=None,
                            overflow=self.event_overflow,
                            mode=self.mode, surrogate=self.surrogate,
                            ext_diag=self.event_ext_diag)
                        if telemetry:
                            # Mirror ops.event_synaptic_input's fallback
                            # trigger: ANY batch row spiking past k_active
                            # flips the whole tick to the dense product.
                            over = jnp.any(
                                jnp.sum(arriving > 0, axis=-1) > k)
                            overflow_inc = jnp.broadcast_to(
                                over.astype(jnp.int32),
                                carry.telem.overflow.shape)
                    else:
                        # -- adaptive knee: the spike-list gather's cost is
                        #    ~spikes * gather_penalty dense-row-equivalents,
                        #    so past the knee the dense product is simply
                        #    the faster exact arm. Generalizes the overflow
                        #    fallback from safety valve to speed policy:
                        #    overflow (m > k) *must* go dense for bits;
                        #    the knee band (knee < m <= k) goes dense for
                        #    ticks/s. Hysteresis: once dense, stay dense
                        #    until m falls below hysteresis * knee.
                        if self.event_overflow != "fallback":
                            raise ValueError(
                                "event_knee requires event_overflow="
                                "'fallback' (the knee routes overflow "
                                "ticks to the dense arm silently, which "
                                "contradicts strict/unchecked semantics)")
                        m = jnp.max(jnp.sum(arriving > 0, axis=-1))
                        over_k = m > k
                        hi = min(int(self.event_knee), k)
                        lo = int(hi * self.event_hysteresis)
                        prev = (carry.policy if carry.policy is not None
                                else jnp.zeros((), jnp.bool_))
                        dense_mode = (m > hi) | (prev & (m > lo))
                        take_dense = over_k | dense_mode
                        # Inside the event arm m <= min(knee, k): every
                        # spiking row fits the k top-k slots, so the
                        # unchecked gather is exact (the guard IS the
                        # overflow check -- no second cond inside).
                        lif_state = jax.lax.cond(
                            take_dense,
                            _dense_step,
                            lambda: ops.event_lif_step(
                                st.lif, arriving, params, ext, wc,
                                k_active=k, fan_in=None,
                                overflow="unchecked",
                                mode=self.mode, surrogate=self.surrogate,
                                ext_diag=self.event_ext_diag))
                        if carry.policy is not None:
                            policy_out = dense_mode
                        if telemetry:
                            overflow_inc = jnp.broadcast_to(
                                over_k.astype(jnp.int32),
                                carry.telem.overflow.shape)
                            policy_inc = jnp.broadcast_to(
                                (take_dense & ~over_k).astype(jnp.int32),
                                carry.telem.policy_dense.shape)
            else:
                with jax.named_scope("tick/jnp"):
                    syn = arriving @ wc
                    if ext is not None:
                        syn = syn + ext @ params.w_in
                    lif_state = lif_step(st.lif, syn, params.lif,
                                         mode=self.mode,
                                         surrogate=self.surrogate)
        else:
            # -- per-synapse delays: synapse (pre,post) reads slot (tick - delay).
            #    Like "pallas", the "event" backend composes with the matrix-
            #    delay path through this reference einsum (per-delay history
            #    planes defeat a single spike-list gather).
            def gather_delay(d):
                idx = jnp.mod(slot - d, max_delay)
                return jax.lax.dynamic_index_in_dim(
                    st.delay_buf, idx, axis=-2, keepdims=False)

            hist = jnp.stack([gather_delay(d) for d in range(max_delay)], axis=0)
            onehot = jax.nn.one_hot(delays - 1, max_delay, axis=0, dtype=wc.dtype)
            syn = jnp.einsum("d...p,dpq,pq->...q", hist, onehot, wc)
            if ext is not None:
                syn = syn + ext @ params.w_in
            lif_state = lif_step(st.lif, syn, params.lif,
                                 mode=self.mode, surrogate=self.surrogate)

        # -- delay-line write: freshly emitted spikes land at tick+1 (1-cycle min).
        if max_delay > 1:
            write_slot = jnp.mod(st.tick + 1, max_delay)
            delay_buf = jax.lax.dynamic_update_index_in_dim(
                st.delay_buf, lif_state.y, write_slot, axis=-2)
        else:
            delay_buf = st.delay_buf
        state2 = SNNState(lif=lif_state, delay_buf=delay_buf, tick=st.tick + 1)
        # Sharded learning: the presynaptic events are the GATHERED full-
        # width arriving spikes (with max_delay == 1 they are exactly the
        # gathered previous-tick emissions), so the plasticity hook sees
        # the same (.., n) x (.., n/D) operands on every shard and its
        # x_pre trace stays replicated by construction.
        s_pre = arriving if (shard_axis is not None and delays is None) else None
        return self._tick_tail(carry, st, state2, w, reward,
                               params, plastic_c, learn_until,
                               overflow_inc=overflow_inc,
                               policy=policy_out, policy_inc=policy_inc,
                               s_pre=s_pre)

    def _tick_tail(
        self, carry, st, state2, w, reward, params, plastic_c, learn_until,
        overflow_inc=None, policy=None, policy_inc=None, s_pre=None,
    ) -> Tuple[TickCarry, jax.Array]:
        """Shared tick tail: optionally run the plasticity datapath, fold
        telemetry, and rebuild the carry.

        ``s_pre`` is what arrived (previous emissions), ``s_post`` what was
        just emitted -- the NeuroCoreX shared datapath. The hook always runs
        *outside* the tick kernel (including for ``backend="pallas_fused"``):
        learning is its own fused pass over ``(w, elig, traces)``, a disjoint
        working set from the tick's ``(v, r, delay line)``.

        The default presynaptic events are ``st.lif.y`` (the previous
        tick's emissions; exact for ``max_delay == 1``, which learning
        requires); the sharded tick body overrides ``s_pre`` with the
        gathered full-width arriving spikes so plasticity sees the whole
        presynaptic axis against its local postsynaptic columns.
        """
        learning = carry.w is not None
        lif_state = state2.lif
        telemetry = self.telemetry and carry.telem is not None
        # Hysteresis slot: updated only by the adaptive knee; every other
        # path passes the carried bit (usually None) through unchanged so
        # the carry pytree stays scan-invariant.
        policy2 = policy if policy is not None else carry.policy
        dw = None
        if learning and self.plasticity is not None:
            from repro.plasticity import rules as plasticity_rules

            pb = self.plasticity_backend or self.backend
            if pb == "pallas_fused":
                pb = "pallas"  # the plasticity pass has no whole-tick variant
            elif pb == "event":
                pb = "jnp"     # STDP outer products are dense; no event pass
            with jax.named_scope("tick/plasticity"):
                pst2, w2 = plasticity_rules.plasticity_step(
                    carry.plast, st.lif.y if s_pre is None else s_pre,
                    lif_state.y, w,
                    params.c if plastic_c is None else plastic_c,
                    self.plasticity, reward, backend=pb)
            if learn_until is not None:
                gate = st.tick < learn_until
                w2 = jnp.where(gate, w2, w)
                pst2 = jax.tree.map(
                    lambda new, old: jnp.where(gate, new, old),
                    pst2, carry.plast)
            if telemetry:
                dw = w2 - w  # the committed delta (after learn_until gating)
            telem2 = carry.telem.accumulate(
                lif_state, overflow_inc=overflow_inc, policy_inc=policy_inc,
                dw=dw) if telemetry else carry.telem
            return TickCarry(state=state2, plast=pst2, w=w2,
                             telem=telem2, policy=policy2), lif_state.y
        telem2 = carry.telem.accumulate(
            lif_state, overflow_inc=overflow_inc,
            policy_inc=policy_inc) if telemetry else carry.telem
        return TickCarry(state=state2, plast=carry.plast, w=carry.w,
                         telem=telem2, policy=policy2), lif_state.y

    # -- scan driver -------------------------------------------------------

    def _seed_carry(self, carry0: TickCarry, neighbors: Optional[Any]) -> TickCarry:
        """Seed the optional carry slots (telemetry accumulator, knee
        hysteresis bit) the engine's statics call for.  Shared by the
        single-device scan and the sharded wrapper (which seeds on the
        GLOBAL side so its spec trees see the final carry structure)."""
        if self.telemetry and carry0.telem is None:
            from repro.obs.telemetry import TickTelemetry

            carry0 = dataclasses.replace(
                carry0,
                telem=TickTelemetry.zeros(carry0.state.lif.v.shape[:-1]))
        if (self.backend == "event" and self.event_knee is not None
                and carry0.policy is None
                and self._event_strategy(neighbors) == "topk"):
            # Seed the hysteresis bit (start in the spike-list arm).
            carry0 = dataclasses.replace(
                carry0, policy=jnp.zeros((), jnp.bool_))
        return carry0

    def scan(
        self,
        params: SNNParams,
        carry0: TickCarry,
        ext_seq: Optional[jax.Array],
        n_ticks: int,
        *,
        rewards: Optional[jax.Array] = None,
        delays: Optional[jax.Array] = None,
        plastic_c: Optional[jax.Array] = None,
        learn_until: Optional[jax.Array] = None,
        neighbors: Optional[Any] = None,
    ) -> Tuple[TickCarry, jax.Array]:
        """Scan ``n_ticks`` ticks of :meth:`tick_body`; returns
        ``(final_carry, raster)``.

        Frozen carries (``carry0.w is None``) get the hoisted ``W*C``;
        learning carries re-derive it per tick from the carried weights.
        With ``telemetry=True`` a zeroed accumulator is seeded into the
        carry when the caller didn't provide one.

        With ``mesh`` set this whole method runs under ``shard_map``
        instead (:func:`repro.parallel.snn_sharding.sharded_scan`): one
        compiled program, the hoist and the scan INSIDE the partition,
        so the frozen path still materializes its (local) ``W*C`` slab
        exactly once per rollout.
        """
        if self.mesh is not None:
            from repro.parallel import snn_sharding

            return snn_sharding.sharded_scan(
                self, params, carry0, ext_seq, n_ticks, rewards=rewards,
                delays=delays, plastic_c=plastic_c,
                learn_until=learn_until, neighbors=neighbors)
        carry0 = self._seed_carry(carry0, neighbors)
        learning = carry0.w is not None
        wc = None
        if not learning and self.effective_backend() != "pallas":
            # Loop-invariant: materialized ONCE per rollout, a scan constant.
            # For "pallas_fused" this pre-masked matrix is the kernel's single
            # weight operand (no per-tile mask multiply, no c traffic).
            wc = self.masked_weights(params)

        def body(carry, xs):
            return self.tick_body(carry, xs, params=params, wc=wc,
                                  delays=delays, plastic_c=plastic_c,
                                  learn_until=learn_until, neighbors=neighbors)

        if ext_seq is None and rewards is None:
            return jax.lax.scan(
                lambda c, _: body(c, (None, None)), carry0, None, length=n_ticks)
        if ext_seq is None:
            return jax.lax.scan(
                lambda c, r: body(c, (None, r)), carry0, rewards, length=n_ticks)
        if rewards is None:
            return jax.lax.scan(
                lambda c, e: body(c, (e, None)), carry0, ext_seq)
        return jax.lax.scan(body, carry0, (ext_seq, rewards))

    # -- convenience entry points (what the network wrappers call) --------

    def tick(
        self,
        state: SNNState,
        params: SNNParams,
        ext: Optional[jax.Array] = None,
        *,
        delays: Optional[jax.Array] = None,
        neighbors: Optional[Any] = None,
    ) -> SNNState:
        """One frozen-weight tick (the public ``network.step`` semantics)."""
        if self.mesh is not None:
            raise ValueError(
                "tick() is single-device; the sharded engine runs through "
                "scan()/rollout()/chunk() (shard_map wraps the whole scan, "
                "so a 1-tick chunk() is the sharded single tick)")
        carry, _ = self.tick_body(TickCarry(state=state), (ext, None),
                                  params=params, delays=delays,
                                  neighbors=neighbors)
        return carry.state

    def rollout(
        self,
        params: SNNParams,
        state: SNNState,
        ext_seq: Optional[jax.Array],
        n_ticks: int,
        *,
        delays: Optional[jax.Array] = None,
        neighbors: Optional[Any] = None,
    ):
        """Frozen-weight rollout; returns ``(final_state, raster)`` -- or
        ``(final_state, raster, telemetry)`` when the engine's static
        ``telemetry`` flag is set (the extra element is compile-time
        constant arity, so no retraces)."""
        final, raster = self.scan(params, TickCarry(state=state), ext_seq,
                                  n_ticks, delays=delays, neighbors=neighbors)
        if self.telemetry:
            return final.state, raster, final.telem
        return final.state, raster

    def learning_rollout(
        self,
        params: SNNParams,
        state: SNNState,
        plast_state: Any,
        ext_seq: Optional[jax.Array],
        n_ticks: int,
        *,
        rewards: Optional[jax.Array] = None,
        plastic_c: Optional[jax.Array] = None,
        learn_until: Optional[jax.Array] = None,
        neighbors: Optional[Any] = None,
    ):
        """Learning rollout: the carry holds mutable weights; returns
        ``((final_state, final_plast_state, final_w), raster)`` -- plus a
        trailing ``telemetry`` element when the engine's static
        ``telemetry`` flag is set.

        ``learn_until`` (optional runtime scalar) freezes the plasticity
        hook from that tick on -- see :meth:`tick_body`."""
        if self.plasticity is None:
            raise ValueError("learning_rollout needs a TickEngine with plasticity set")
        if state.delay_buf.shape[-2] != 1:
            raise ValueError(
                "learning_rollout requires max_delay == 1 (pair STDP reads the "
                "previous tick's spikes as the presynaptic events)")
        if rewards is None:
            rewards = jnp.zeros((n_ticks,), jnp.float32)
        if plastic_c is None:
            if params.c is None:
                raise ValueError(
                    "learning with c=None (implicit all-to-all) needs an "
                    "explicit plastic_c mask (pass jnp.ones((n, n)) to "
                    "learn every synapse)")
            plastic_c = params.c
        carry0 = TickCarry(state=state, plast=plast_state, w=params.w)
        final, raster = self.scan(params, carry0, ext_seq, n_ticks,
                                  rewards=rewards, plastic_c=plastic_c,
                                  learn_until=learn_until, neighbors=neighbors)
        if self.telemetry:
            return (final.state, final.plast, final.w), raster, final.telem
        return (final.state, final.plast, final.w), raster

    def init_learning_carry(
        self,
        params: SNNParams,
        state: SNNState,
        plast_state: Any,
    ) -> TickCarry:
        """Build the chunk-resumable carry for a fresh learning request.

        Pairs with :meth:`chunk` -- the continuous-serving path builds
        one of these when a slot is (re)filled, then hands it across
        chunk boundaries instead of re-entering :meth:`learning_rollout`
        from scratch every wave."""
        return TickCarry(state=state, plast=plast_state, w=params.w)

    def chunk(
        self,
        params: SNNParams,
        carry: TickCarry,
        ext_seq: Optional[jax.Array],
        n_ticks: int,
        *,
        rewards: Optional[jax.Array] = None,
        plastic_c: Optional[jax.Array] = None,
        learn_until: Optional[jax.Array] = None,
        neighbors: Optional[Any] = None,
    ) -> Tuple[TickCarry, jax.Array]:
        """Run ``n_ticks`` more ticks from an *existing* carry; returns
        ``(next_carry, raster)``.

        This is the continuous-admission hand-off: a serving loop that
        admits per slot (not per wave) runs the fabric in small chunks
        and threads the full :class:`TickCarry` -- state, plasticity
        traces, mutable weights, telemetry, hysteresis bit -- across
        chunk boundaries, so ``K`` chunks of ``T`` ticks are bit-exact
        with one ``K*T``-tick rollout (pinned in
        tests/test_engine_options.py). ``n_ticks`` stays static per
        chunk size, so one compiled chunk program serves every request
        length; the carry is the only thing that moves.

        ``rewards`` defaults to zeros on learning carries (``carry.w``
        present) -- mid-stream R-STDP feedback passes real rewards."""
        if rewards is None and carry.w is not None:
            rewards = jnp.zeros((n_ticks,), jnp.float32)
        if plastic_c is None and carry.w is not None:
            if params.c is None:
                raise ValueError(
                    "learning chunk with c=None needs an explicit "
                    "plastic_c mask (see learning_rollout)")
            plastic_c = params.c
        return self.scan(params, carry, ext_seq, n_ticks,
                         rewards=rewards, plastic_c=plastic_c,
                         learn_until=learn_until, neighbors=neighbors)
