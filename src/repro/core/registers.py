"""Byte-exact register bank + UART transaction cost model (paper §II.C, §III.B).

The FPGA holds all SNN parameters in a UART-fed register bank; runtime
reconfiguration = rewriting these registers (never re-synthesis). We
reproduce the register layout byte-for-byte and the paper's transaction
arithmetic exactly:

  74-neuron system:
    CL registers   74 rows x ceil(74/8)=10 bytes  -> 740 transactions
    Thresholds     74 x 1 byte                    ->  74
    Weights        74 x 1 byte                    ->  74
    Impulses       ceil(74/8)=10 bytes            ->  10
    total                                             898 transactions
  1-neuron system: 1 + 1 + 1 + 1 = 4 transactions.

Timing: the paper charges 104.17 us per transaction (one 9600-baud bit
time), i.e. 898 txns -> 93.54 ms, and 4 txns -> 416.68 us. A byte on a
9600-8N1 wire actually occupies 10 bit times (1.0417 ms); we reproduce the
paper's figure as ``PAPER`` and also report the bit-accurate ``WIRE_8N1``
model (10x the paper's). EXPERIMENTS.md discusses the discrepancy.

Note the paper's count implies *one weight byte per neuron* (74, not
74x74): the hardware applies a per-neuron weight to the summed input. The
bank supports both that layout and the general per-synapse matrix layout
used by the scaled framework.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict

import numpy as np

from repro.core import connectivity

BAUD = 9600
BIT_TIME_S = 1.0 / BAUD                 # 104.17 us -- the paper's "transaction"
BYTE_TIME_8N1_S = 10.0 / BAUD           # start + 8 data + stop


class TimingModel(str, enum.Enum):
    PAPER = "paper"        # 1 bit-time per transaction (paper's arithmetic)
    WIRE_8N1 = "wire_8n1"  # 10 bit-times per byte (physical 8N1 framing)


class WeightLayout(str, enum.Enum):
    PER_NEURON = "per_neuron"    # paper's register count: N weight bytes
    PER_SYNAPSE = "per_synapse"  # general N x N u8 matrix


@dataclasses.dataclass
class TransactionBreakdown:
    connection_list: int
    thresholds: int
    weights: int
    impulses: int

    @property
    def total(self) -> int:
        return self.connection_list + self.thresholds + self.weights + self.impulses

    def time_s(self, model: TimingModel = TimingModel.PAPER) -> float:
        per = BIT_TIME_S if model == TimingModel.PAPER else BYTE_TIME_8N1_S
        return self.total * per


def transaction_breakdown(
    n_neurons: int, layout: WeightLayout = WeightLayout.PER_NEURON
) -> TransactionBreakdown:
    """The paper's §III.B arithmetic, generalized to any N."""
    row_bytes = math.ceil(n_neurons / 8)
    cl = n_neurons * row_bytes
    th = n_neurons
    w = n_neurons if layout == WeightLayout.PER_NEURON else n_neurons * n_neurons
    imp = row_bytes
    return TransactionBreakdown(cl, th, w, imp)


class RegisterBank:
    """Host-visible parameter store; the single source of truth the SNN
    module reads, mirroring ``reg_input_clf`` / ``reg_threshclf`` /
    ``weight_reg`` / ``impulse_reg`` of the waveform (Fig. 5/7).

    All fields are u8 numpy arrays (byte-exact). ``serialize()`` produces
    the UART byte stream; ``load_bytes()`` applies one (the device side).
    Rewriting registers never changes shapes -> jitted programs that take
    these arrays as inputs are never re-traced: the "no re-synthesis"
    property.
    """

    def __init__(
        self,
        n_neurons: int,
        *,
        weight_layout: WeightLayout = WeightLayout.PER_NEURON,
    ):
        self.n = int(n_neurons)
        self.weight_layout = weight_layout
        row_bytes = math.ceil(self.n / 8)
        self.connection_list = np.zeros((self.n, row_bytes), dtype=np.uint8)
        self.thresholds = np.zeros((self.n,), dtype=np.uint8)
        if weight_layout == WeightLayout.PER_NEURON:
            self.weights = np.zeros((self.n,), dtype=np.uint8)
        else:
            self.weights = np.zeros((self.n, self.n), dtype=np.uint8)
        self.impulses = np.zeros((row_bytes,), dtype=np.uint8)
        self.refractory = np.zeros((self.n,), dtype=np.uint8)
        self.leak = np.zeros((self.n,), dtype=np.uint8)
        # tonic-input register (paper Eq. 1 I_bias); device-local like
        # refractory/leak, not part of the §III.B transaction stream
        self.bias = np.zeros((self.n,), dtype=np.uint8)

    # -- host-side setters ------------------------------------------------
    def set_connection_list(self, c: np.ndarray) -> None:
        connectivity.validate(c)
        if c.shape != (self.n, self.n):
            raise ValueError(f"expected ({self.n},{self.n}), got {c.shape}")
        self.connection_list = connectivity.pack_bits(c)

    def get_connection_list(self) -> np.ndarray:
        return connectivity.unpack_bits(self.connection_list, self.n)

    def set_thresholds(self, th: np.ndarray) -> None:
        self.thresholds = np.asarray(th, dtype=np.uint8).reshape(self.n)

    def set_weights(self, w: np.ndarray) -> None:
        w = np.asarray(w, dtype=np.uint8)
        expect = (self.n,) if self.weight_layout == WeightLayout.PER_NEURON else (self.n, self.n)
        if w.shape != expect:
            raise ValueError(f"expected {expect}, got {w.shape}")
        self.weights = w

    def set_impulses(self, spikes: np.ndarray) -> None:
        """Bit-pack the input spike vector (the impulse register)."""
        s = np.asarray(spikes).astype(np.bool_).reshape(1, self.n)
        self.impulses = np.packbits(s, axis=1)[0]

    def get_impulses(self) -> np.ndarray:
        return np.unpackbits(self.impulses.reshape(1, -1), axis=1)[0, : self.n]

    def set_refractory(self, r) -> None:
        self.refractory = np.asarray(np.broadcast_to(r, (self.n,)), dtype=np.uint8).copy()

    def set_leak(self, lam) -> None:
        self.leak = np.asarray(np.broadcast_to(lam, (self.n,)), dtype=np.uint8).copy()

    def set_bias(self, b) -> None:
        self.bias = np.asarray(np.broadcast_to(b, (self.n,)), dtype=np.uint8).copy()

    # -- wire format -------------------------------------------------------
    def serialize(self) -> bytes:
        """CL rows, thresholds, weights, impulses -- the §III.B order."""
        parts = [
            self.connection_list.tobytes(),
            self.thresholds.tobytes(),
            self.weights.tobytes(),
            self.impulses.tobytes(),
        ]
        return b"".join(parts)

    def load_bytes(self, payload: bytes) -> None:
        expect = self.breakdown().total
        if len(payload) != expect:
            raise ValueError(f"expected {expect} bytes, got {len(payload)}")
        a = np.frombuffer(payload, dtype=np.uint8)
        o = 0
        cl_n = self.connection_list.size
        self.connection_list = a[o : o + cl_n].reshape(self.connection_list.shape).copy(); o += cl_n
        self.thresholds = a[o : o + self.n].copy(); o += self.n
        w_n = self.weights.size
        self.weights = a[o : o + w_n].reshape(self.weights.shape).copy(); o += w_n
        self.impulses = a[o:].copy()

    def breakdown(self) -> TransactionBreakdown:
        return transaction_breakdown(self.n, self.weight_layout)

    def reprogram_time_s(self, model: TimingModel = TimingModel.PAPER) -> float:
        return self.breakdown().time_s(model)

    def as_dict(self) -> Dict[str, np.ndarray]:
        return {
            "connection_list": self.get_connection_list(),
            "thresholds": self.thresholds,
            "weights": self.weights,
            "impulses": self.get_impulses(),
            "refractory": self.refractory,
            "leak": self.leak,
        }
