"""Connection-list topologies -- the paper's "universal interconnections".

On the FPGA, ``connection_list[n][m] = 1`` closes a multiplexer that routes
the output spike of neuron *n* to an input of neuron *m*; a 0 routes a
constant zero. Here the connection list is a boolean matrix ``C`` (a runtime
*input*, never a compiled constant), and spike routing is the masked matmul
``s @ (W * C)``. Any topology -- feed-forward, recurrent, sparse, dense --
is therefore data, and switching topologies never re-traces or re-compiles
the program (the paper's "no re-synthesis" property).

Convention: ``C[n, m]`` routes *presynaptic* neuron ``n`` -> *postsynaptic*
neuron ``m``, matching the paper's ``connection list[n][m]``.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np


def all_to_all(n: int, *, self_connections: bool = False) -> np.ndarray:
    """Fully connected N x N topology (the hardware's maximal fabric)."""
    c = np.ones((n, n), dtype=np.bool_)
    if not self_connections:
        np.fill_diagonal(c, False)
    return c


def layered(layer_sizes: Sequence[int]) -> np.ndarray:
    """Feed-forward topology over a flat neuron array.

    ``layered([4, 3])`` reproduces the paper's Iris network: neurons 0-3 are
    the input layer, neurons 4-6 the output layer, with full bipartite
    connectivity between consecutive layers and nothing else. This is the
    exact construction of Fig. 4 / Fig. 6.
    """
    n = int(sum(layer_sizes))
    c = np.zeros((n, n), dtype=np.bool_)
    offset = 0
    for a, b in zip(layer_sizes[:-1], layer_sizes[1:]):
        c[offset : offset + a, offset + a : offset + a + b] = True
        offset += a
    return c


def sparse_random(
    n: int, density: float, *, seed: int = 0, self_connections: bool = False
) -> np.ndarray:
    """Random sparse topology at the given density (for scaling studies)."""
    rng = np.random.default_rng(seed)
    c = rng.random((n, n)) < density
    if not self_connections:
        np.fill_diagonal(c, False)
    return c


def ring(n: int, k: int = 1) -> np.ndarray:
    """Each neuron feeds its next ``k`` neighbours (synfire chain)."""
    c = np.zeros((n, n), dtype=np.bool_)
    for i in range(n):
        for j in range(1, k + 1):
            c[i, (i + j) % n] = True
    return c


def validate(c: np.ndarray) -> None:
    if c.ndim != 2 or c.shape[0] != c.shape[1]:
        raise ValueError(f"connection list must be square, got {c.shape}")
    if c.dtype != np.bool_:
        raise ValueError(f"connection list must be boolean, got {c.dtype}")


def pack_bits(c: np.ndarray) -> np.ndarray:
    """Bit-pack each row to bytes -- the register-bank wire format.

    Row ``n`` of the 74-neuron system packs to ``ceil(74/8) = 10`` bytes,
    reproducing the paper's "each CL requires 10 transactions".
    """
    validate(c)
    return np.packbits(c, axis=1)


def unpack_bits(packed: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack_bits` (drops pad bits)."""
    return np.unpackbits(packed, axis=1)[:, :n].astype(np.bool_)


def masked_weights(w: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """The effective synapse matrix the mux fabric realizes: ``W * C``."""
    return w * c.astype(w.dtype)


def fan_in(c: np.ndarray) -> np.ndarray:
    """Per-neuron in-degree (drives per-neuron LUT cost, paper Table I)."""
    return np.asarray(c).sum(axis=0)


def fan_out(c: np.ndarray) -> np.ndarray:
    return np.asarray(c).sum(axis=1)
