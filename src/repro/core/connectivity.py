"""Connection-list topologies -- the paper's "universal interconnections".

On the FPGA, ``connection_list[n][m] = 1`` closes a multiplexer that routes
the output spike of neuron *n* to an input of neuron *m*; a 0 routes a
constant zero. Here the connection list is a boolean matrix ``C`` (a runtime
*input*, never a compiled constant), and spike routing is the masked matmul
``s @ (W * C)``. Any topology -- feed-forward, recurrent, sparse, dense --
is therefore data, and switching topologies never re-traces or re-compiles
the program (the paper's "no re-synthesis" property).

Convention: ``C[n, m]`` routes *presynaptic* neuron ``n`` -> *postsynaptic*
neuron ``m``, matching the paper's ``connection list[n][m]``.
Dense ``C`` is the *semantic* format (and the register-bank wire format
bit-packs it row-wise); the event-driven backend additionally wants a
*compressed* view that only names the closed muxes.  Two builders below
provide it: :func:`to_csr` (exact CSR triple, round-trips with
:func:`csr_to_dense`) and :func:`padded_neighbors` /
:func:`padded_fan_in` (fixed-width padded neighbor lists -- the
TPU-friendly layout: every row padded to a common fan-out/fan-in cap so
gathers stay static-shaped, with padding stats so callers can see what
the cap costs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


def all_to_all(n: int, *, self_connections: bool = False) -> np.ndarray:
    """Fully connected N x N topology (the hardware's maximal fabric)."""
    c = np.ones((n, n), dtype=np.bool_)
    if not self_connections:
        np.fill_diagonal(c, False)
    return c


def layered(layer_sizes: Sequence[int]) -> np.ndarray:
    """Feed-forward topology over a flat neuron array.

    ``layered([4, 3])`` reproduces the paper's Iris network: neurons 0-3 are
    the input layer, neurons 4-6 the output layer, with full bipartite
    connectivity between consecutive layers and nothing else. This is the
    exact construction of Fig. 4 / Fig. 6.
    """
    n = int(sum(layer_sizes))
    c = np.zeros((n, n), dtype=np.bool_)
    offset = 0
    for a, b in zip(layer_sizes[:-1], layer_sizes[1:]):
        c[offset : offset + a, offset + a : offset + a + b] = True
        offset += a
    return c


def sparse_random(
    n: int, density: float, *, seed: int = 0, self_connections: bool = False
) -> np.ndarray:
    """Random sparse topology at the given density (for scaling studies)."""
    rng = np.random.default_rng(seed)
    c = rng.random((n, n)) < density
    if not self_connections:
        np.fill_diagonal(c, False)
    return c


def ring(n: int, k: int = 1) -> np.ndarray:
    """Each neuron feeds its next ``k`` neighbours (synfire chain)."""
    c = np.zeros((n, n), dtype=np.bool_)
    for i in range(n):
        for j in range(1, k + 1):
            c[i, (i + j) % n] = True
    return c


def validate(c: np.ndarray) -> None:
    if c.ndim != 2 or c.shape[0] != c.shape[1]:
        raise ValueError(f"connection list must be square, got {c.shape}")
    if c.dtype != np.bool_:
        raise ValueError(f"connection list must be boolean, got {c.dtype}")


def pack_bits(c: np.ndarray) -> np.ndarray:
    """Bit-pack each row to bytes -- the register-bank wire format.

    Row ``n`` of the 74-neuron system packs to ``ceil(74/8) = 10`` bytes,
    reproducing the paper's "each CL requires 10 transactions".
    """
    validate(c)
    return np.packbits(c, axis=1)


def unpack_bits(packed: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack_bits` (drops pad bits)."""
    return np.unpackbits(packed, axis=1)[:, :n].astype(np.bool_)


def masked_weights(w: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """The effective synapse matrix the mux fabric realizes: ``W * C``."""
    return w * c.astype(w.dtype)


def fan_in(c: np.ndarray) -> np.ndarray:
    """Per-neuron in-degree (drives per-neuron LUT cost, paper Table I)."""
    return np.asarray(c).sum(axis=0)


def fan_out(c: np.ndarray) -> np.ndarray:
    return np.asarray(c).sum(axis=1)


# ---------------------------------------------------------------------------
# Compressed connectivity: CSR + padded neighbor lists (the event backend's
# data layout -- only the *closed* muxes are named; silent rows cost nothing)
# ---------------------------------------------------------------------------


def to_csr(c: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Dense boolean ``C`` -> CSR ``(indptr, indices)`` over presynaptic rows.

    ``indices[indptr[p]:indptr[p+1]]`` are the postsynaptic targets of
    neuron ``p``, ascending.  Exact: :func:`csr_to_dense` round-trips.
    """
    validate(c)
    n = c.shape[0]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(c.sum(axis=1), out=indptr[1:])
    indices = np.nonzero(c)[1].astype(np.int32)
    return indptr, indices


def csr_to_dense(indptr: np.ndarray, indices: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`to_csr`."""
    c = np.zeros((n, n), dtype=np.bool_)
    for p in range(n):
        c[p, indices[indptr[p] : indptr[p + 1]]] = True
    return c


@dataclasses.dataclass(frozen=True)
class PaddedNeighbors:
    """Fixed-width neighbor lists: row ``i`` of ``idx`` holds the (ascending)
    neighbors of neuron ``i``, padded to ``cap`` entries; ``mask`` is 1.0 on
    real entries and 0.0 on padding (padded ``idx`` entries are 0 and must be
    gated by the mask before use).

    ``axis`` records the direction: ``"out"`` (row i = fan-out targets of
    presynaptic i, from :func:`padded_neighbors`) or ``"in"`` (row i =
    fan-in sources of postsynaptic i, from :func:`padded_fan_in`).

    The cap/padding trade-off the stats expose: a tight cap minimizes the
    gather width (and the event backend's FLOPs/bytes), but the cap must
    hold the *maximum* degree -- one hub row sets the width for everyone,
    and ``padding_fraction`` says how much of the padded layout is air.
    """

    idx: np.ndarray          # (n, cap) int32
    mask: np.ndarray         # (n, cap) float32, 1.0 = real edge
    cap: int
    axis: str                # "out" | "in"
    n_edges: int
    max_degree: int

    @property
    def mean_degree(self) -> float:
        return self.n_edges / max(1, self.idx.shape[0])

    @property
    def padding_fraction(self) -> float:
        """Fraction of the (n, cap) layout that is padding."""
        slots = self.idx.shape[0] * self.cap
        return 1.0 - self.n_edges / max(1, slots)


def _padded_lists(c: np.ndarray, cap: Optional[int], axis: str) -> PaddedNeighbors:
    validate(c)
    rows = c if axis == "out" else c.T
    degrees = rows.sum(axis=1).astype(np.int64)
    max_deg = int(degrees.max()) if rows.size else 0
    if cap is None:
        cap = max(1, max_deg)
    if max_deg > cap:
        raise ValueError(
            f"fan-{axis} cap {cap} below max degree {max_deg}: a capped "
            "neighbor list would silently drop synapses (raise the cap or "
            "prune the topology)")
    n = rows.shape[0]
    idx = np.zeros((n, cap), dtype=np.int32)
    mask = np.zeros((n, cap), dtype=np.float32)
    for i in range(n):
        nz = np.nonzero(rows[i])[0]
        idx[i, : nz.size] = nz
        mask[i, : nz.size] = 1.0
    return PaddedNeighbors(idx=idx, mask=mask, cap=int(cap), axis=axis,
                           n_edges=int(degrees.sum()), max_degree=max_deg)


def padded_neighbors(c: np.ndarray, cap: Optional[int] = None) -> PaddedNeighbors:
    """Padded fan-OUT lists: row ``p`` = postsynaptic targets of ``p``.

    ``cap=None`` picks the tightest cap (the max fan-out).  Raises if an
    explicit cap is below the max degree -- the builders never truncate.
    """
    return _padded_lists(c, cap, "out")


def padded_fan_in(c: np.ndarray, cap: Optional[int] = None) -> PaddedNeighbors:
    """Padded fan-IN lists: row ``m`` = presynaptic sources of ``m``.

    This is the gather-friendly dual of :func:`padded_neighbors`: the
    event backend's vmap-safe path reads, for every postsynaptic neuron,
    exactly its ``cap`` (mostly real) in-edges -- no scatter, no
    data-dependent control flow, FLOPs ``B*n*cap`` instead of ``B*n*n``.
    """
    return _padded_lists(c, cap, "in")


def shard_fan_in(
    c: np.ndarray, n_shards: int, cap: Optional[int] = None
) -> Tuple[PaddedNeighbors, ...]:
    """Slice the padded fan-in lists by DESTINATION shard (DESIGN.md §15).

    Shard ``i`` gets the rows of :func:`padded_fan_in` for its own
    postsynaptic neurons ``[i*n/D, (i+1)*n/D)``:

    * ``idx`` entries stay **global** presynaptic ids -- under the
      fabric's column sharding each shard's ``wc`` slab keeps the full
      presynaptic row axis, so no index translation ever happens;
    * the cap is the **global** max fan-in for every shard -- uniform
      shapes, so one compiled event-backend program serves all shards
      (a per-shard tight cap would mean per-shard program shapes).

    Per-shard ``n_edges``/``max_degree`` are recomputed on the slice, so
    the returned stats expose the load balance the topology actually
    gives each device (see :func:`shard_stats` for the full view).
    """
    full = padded_fan_in(c, cap)
    n = full.idx.shape[0]
    if n_shards < 1 or n % n_shards:
        raise ValueError(
            f"n={n} destinations do not split evenly over {n_shards} shards")
    n_local = n // n_shards
    out = []
    for i in range(n_shards):
        idx = full.idx[i * n_local:(i + 1) * n_local]
        mask = full.mask[i * n_local:(i + 1) * n_local]
        degrees = mask.sum(axis=1).astype(np.int64)
        out.append(PaddedNeighbors(
            idx=idx, mask=mask, cap=full.cap, axis="in",
            n_edges=int(degrees.sum()),
            max_degree=int(degrees.max()) if degrees.size else 0))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class ShardStats:
    """Per-shard load view of a destination-sharded topology.

    ``n_edges_in`` is the shard's synaptic work per tick (its fan-in dot
    reduces exactly these edges); ``n_edges_out`` is how many of the
    fabric's synapses *originate* from the shard's own neurons (how much
    of the gathered spike vector the rest of the fabric consumes from
    it).  A balanced topology keeps ``n_edges_in`` near ``edges / D``.
    """

    shard: int
    n_post: int
    n_edges_in: int
    max_fan_in: int
    mean_fan_in: float
    n_edges_out: int
    max_fan_out: int
    mean_fan_out: float


def shard_stats(c: np.ndarray, n_shards: int) -> Tuple[ShardStats, ...]:
    """Host-side per-shard statistics (serve/bench print these at load).

    Computed from the dense list directly -- no padded layout needed --
    so it works at any n the host can hold the boolean matrix for.
    """
    cb = np.asarray(c) > 0
    validate(cb)
    n = cb.shape[0]
    if n_shards < 1 or n % n_shards:
        raise ValueError(
            f"n={n} destinations do not split evenly over {n_shards} shards")
    n_local = n // n_shards
    out = []
    for i in range(n_shards):
        lo, hi = i * n_local, (i + 1) * n_local
        fi = cb[:, lo:hi].sum(axis=0)          # fan-in of local posts
        fo = cb[lo:hi, :].sum(axis=1)          # fan-out of local pres
        out.append(ShardStats(
            shard=i, n_post=n_local,
            n_edges_in=int(fi.sum()),
            max_fan_in=int(fi.max()) if fi.size else 0,
            mean_fan_in=float(fi.mean()) if fi.size else 0.0,
            n_edges_out=int(fo.sum()),
            max_fan_out=int(fo.max()) if fo.size else 0,
            mean_fan_out=float(fo.mean()) if fo.size else 0.0))
    return tuple(out)


def shard_imbalance(stats: Sequence[ShardStats]) -> float:
    """Max/mean ratio of per-shard synaptic work (1.0 = perfectly even;
    the weak-scaling efficiency ceiling is roughly its reciprocal)."""
    edges = [s.n_edges_in for s in stats]
    mean = sum(edges) / max(1, len(edges))
    return max(edges) / mean if mean else 1.0


@dataclasses.dataclass(frozen=True)
class ConnectivityStats:
    """Topology statistics the dispatch policy decides from.

    ``padding_fraction_in``/``_out`` are the air fractions of the
    *tightest* padded layouts (cap == max degree): how much of the
    fan-in gather / fan-out scatter would multiply zeros.  A hub-heavy
    topology has a large max/mean gap and a padding fraction near 1 --
    exactly where the fixed-cap gather stops paying and the policy
    should pick the dense product or the spike-list path instead.
    """

    n: int
    n_edges: int
    density: float
    max_fan_in: int
    mean_fan_in: float
    max_fan_out: int
    mean_fan_out: float
    padding_fraction_in: float
    padding_fraction_out: float


def stats(c: np.ndarray) -> ConnectivityStats:
    """Host-side summary of a concrete connection list (the dispatch
    policy's trace-time input -- see :mod:`repro.core.dispatch_policy`)."""
    validate(np.asarray(c) > 0 if np.asarray(c).dtype != np.bool_ else c)
    cb = np.asarray(c) > 0
    n = cb.shape[0]
    fi = cb.sum(axis=0)
    fo = cb.sum(axis=1)
    edges = int(cb.sum())
    max_fi = int(fi.max()) if n else 0
    max_fo = int(fo.max()) if n else 0
    frac = lambda mx: 1.0 - edges / max(1, n * max(1, mx))
    return ConnectivityStats(
        n=n, n_edges=edges, density=edges / max(1, n * n),
        max_fan_in=max_fi, mean_fan_in=float(fi.mean()) if n else 0.0,
        max_fan_out=max_fo, mean_fan_out=float(fo.mean()) if n else 0.0,
        padding_fraction_in=frac(max_fi), padding_fraction_out=frac(max_fo))
