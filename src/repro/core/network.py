"""The SNN processor core: all-to-all network with scan rollout.

One instance == the paper's ``u_snn_proc`` block: a flat array of N
homogeneous LIF neurons, a synaptic weight matrix ``W`` gated by the
connection list ``C``, per-neuron thresholds / leak / refractory registers,
and optional per-synapse-group delays (paper: 1-255 cycles, default 1).

Semantics: one call to :func:`step` is one synchronous network tick (one
clock of the FPGA datapath after the 2-cycle neuron pipeline is abstracted
to a tick). Spikes emitted at tick k arrive at tick k+delay. A rollout over
T ticks is a ``lax.scan``.

Distribution: ``batch`` shards over ``("pod","data")`` (i.e. ``"data"`` on a
single pod) and the neuron axis over ``"model"``; the synapse matrix shards
2-D ``P("model", None)`` on its presynaptic axis so each model shard owns
the fan-out rows of its neurons. Each tick all-gathers the (tiny, u8)
spike vector along "model" and computes a local (N x N/16) masked matmul --
the TPU restatement of the paper's mux fabric (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lif import LIFParams, LIFState, lif_step


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SNNParams:
    """Network parameters (all runtime inputs -- never compiled constants).

    Attributes:
      w: synaptic weights, shape ``(n, n)``; ``w[pre, post]``.
      c: connection list, shape ``(n, n)`` bool/0-1; ``c[pre, post]``.
      w_in: input weights, shape ``(n_in, n)`` mapping external channels
        onto neurons (identity for the paper's networks where inputs drive
        input-layer neurons directly).
      lif: per-neuron :class:`LIFParams`.
    """

    w: jax.Array
    c: jax.Array
    w_in: jax.Array
    lif: LIFParams


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SNNState:
    """Rollout state: LIF state + circular delay line.

    ``delay_buf`` has shape ``(..., max_delay, n)``; slot ``(k % max_delay)``
    holds the spikes scheduled to arrive at tick ``k``. ``max_delay == 1``
    (the hardware default) degenerates to plain previous-tick delivery.
    """

    lif: LIFState
    delay_buf: jax.Array
    tick: jax.Array

    @staticmethod
    def zeros(batch_shape, n: int, max_delay: int = 1, dtype=jnp.float32) -> "SNNState":
        return SNNState(
            lif=LIFState.zeros(batch_shape, n, dtype=dtype),
            delay_buf=jnp.zeros(tuple(batch_shape) + (max_delay, n), dtype=dtype),
            tick=jnp.zeros((), dtype=jnp.int32),
        )


def synaptic_input(
    spikes: jax.Array, params: SNNParams, ext: Optional[jax.Array]
) -> jax.Array:
    """``sum_pre s[pre] * W[pre,post] * C[pre,post] (+ ext @ W_in)``.

    The masked matmul *is* the mux fabric: C routes a zero exactly where the
    hardware's multiplexer would.
    """
    wc = params.w * params.c.astype(params.w.dtype)
    syn = spikes @ wc
    if ext is not None:
        syn = syn + ext @ params.w_in
    return syn


def step(
    state: SNNState,
    params: SNNParams,
    ext: Optional[jax.Array] = None,
    *,
    mode: str = "fixed_leak",
    surrogate: bool = False,
    delays: Optional[jax.Array] = None,
    backend: str = "jnp",
) -> SNNState:
    """One synchronous network tick.

    Args:
      ext: external drive for this tick, shape ``(..., n_in)`` -- the
        impulse register contents.
      delays: optional per-synapse delay in ticks, shape ``(n, n)`` int,
        values in [1, max_delay]. With delays, presynaptic spikes are
        written into the delay line and each synapse reads the slot its
        delay points at.
      backend: "jnp" (reference) or "pallas" (fused TPU kernel via
        :mod:`repro.kernels.ops`).
    """
    max_delay = state.delay_buf.shape[-2]
    slot = jnp.mod(state.tick, max_delay)

    if delays is None:
        # Default 1-cycle delay: read the spikes scheduled for *this* tick.
        arriving = jax.lax.dynamic_index_in_dim(
            state.delay_buf, slot, axis=-2, keepdims=False
        ) if max_delay > 1 else state.lif.y
        if backend == "pallas":
            from repro.kernels import ops  # local import; CPU tests use jnp

            lif_state = ops.fused_lif_step(
                state.lif, arriving, params, ext, mode=mode, surrogate=surrogate
            )
        else:
            syn = synaptic_input(arriving, params, ext)
            lif_state = lif_step(state.lif, syn, params.lif, mode=mode, surrogate=surrogate)
    else:
        # Per-synapse delays: synapse (pre,post) reads slot (tick - delay).
        # Gather per-delay spike history: hist[d] = spikes emitted d+1 ticks ago.
        def gather_delay(d):
            idx = jnp.mod(slot - d, max_delay)
            return jax.lax.dynamic_index_in_dim(state.delay_buf, idx, axis=-2, keepdims=False)

        hist = jnp.stack([gather_delay(d) for d in range(max_delay)], axis=0)
        # (max_delay, ..., n_pre) x one-hot(delays-1) -> effective spikes per synapse.
        onehot = jax.nn.one_hot(delays - 1, max_delay, axis=0, dtype=params.w.dtype)
        wc = params.w * params.c.astype(params.w.dtype)
        # syn[..., post] = sum_pre sum_d hist[d, ..., pre] * onehot[d, pre, post] * wc[pre, post]
        syn = jnp.einsum("d...p,dpq,pq->...q", hist, onehot, wc)
        if ext is not None:
            syn = syn + ext @ params.w_in
        lif_state = lif_step(state.lif, syn, params.lif, mode=mode, surrogate=surrogate)

    # Write freshly emitted spikes into the slot for tick+1 (1-cycle min).
    if max_delay > 1:
        write_slot = jnp.mod(state.tick + 1, max_delay)
        delay_buf = jax.lax.dynamic_update_index_in_dim(
            state.delay_buf, lif_state.y, write_slot, axis=-2
        )
    else:
        delay_buf = state.delay_buf
    return SNNState(lif=lif_state, delay_buf=delay_buf, tick=state.tick + 1)


def rollout(
    params: SNNParams,
    state: SNNState,
    ext_seq: Optional[jax.Array],
    n_ticks: int,
    *,
    mode: str = "fixed_leak",
    surrogate: bool = False,
    delays: Optional[jax.Array] = None,
    backend: str = "jnp",
) -> Tuple[SNNState, jax.Array]:
    """Scan ``n_ticks`` network ticks; returns final state + spike raster.

    ``ext_seq`` is ``(n_ticks, ..., n_in)`` or None (autonomous dynamics).
    The raster has shape ``(n_ticks, ..., n)``.
    """

    def body(st, ext):
        st2 = step(
            st, params, ext, mode=mode, surrogate=surrogate, delays=delays, backend=backend
        )
        return st2, st2.lif.y

    if ext_seq is None:
        return jax.lax.scan(body, state, None, length=n_ticks)
    return jax.lax.scan(body, state, ext_seq)


def learning_rollout(
    params: SNNParams,
    state: SNNState,
    plast_state,  # repro.plasticity.stdp.PlasticityState
    ext_seq: Optional[jax.Array],
    n_ticks: int,
    *,
    plasticity,  # repro.plasticity.stdp.PlasticityParams
    rewards: Optional[jax.Array] = None,
    plastic_c: Optional[jax.Array] = None,
    mode: str = "fixed_leak",
    backend: str = "jnp",
    plasticity_backend: Optional[str] = None,
) -> Tuple[Tuple[SNNState, "object", jax.Array], jax.Array]:
    """Scan ``n_ticks`` *learning* ticks: the carry holds mutable weights.

    Each tick runs the inference datapath (:func:`step`) with the current
    weight matrix, then the plasticity datapath
    (:func:`repro.plasticity.rules.plasticity_step`) on the spikes that
    tick produced: ``s_pre`` is what arrived at the neurons (the previous
    tick's emissions, ``max_delay == 1``), ``s_post`` what they emitted.
    Weights stay masked by ``params.c`` and clipped to the u8 register
    domain throughout, so the final matrix serializes straight back
    through :class:`repro.core.registers.RegisterBank`.

    Args:
      plast_state: initial :class:`~repro.plasticity.stdp.PlasticityState`
        with batch dims matching ``state``.
      ext_seq: ``(n_ticks, ..., n_in)`` external drive or None.
      rewards: ``(n_ticks,)`` scalar dopamine sequence (R-STDP); None
        means zero reward every tick (eligibility accumulates, weights
        hold -- apply the episode outcome afterwards with
        :func:`repro.plasticity.stdp.apply_reward`).
      plastic_c: learnable-synapse mask; defaults to ``params.c`` (every
        routed synapse learns).  Pass a sub-mask to freeze part of the
        fabric -- e.g. a fixed inhibitory winner-take-all block stays
        bit-identical while the feed-forward block learns.
      backend / plasticity_backend: "jnp" or "pallas"; the plasticity
        backend defaults to following ``backend``.

    Returns:
      ``((final_state, final_plast_state, final_w), raster)``.
    """
    from repro.plasticity import rules as plasticity_rules

    if state.delay_buf.shape[-2] != 1:
        raise ValueError(
            "learning_rollout requires max_delay == 1 (pair STDP reads the "
            "previous tick's spikes as the presynaptic events)")
    if plasticity_backend is None:
        plasticity_backend = backend
    if rewards is None:
        rewards = jnp.zeros((n_ticks,), jnp.float32)
    if plastic_c is None:
        plastic_c = params.c

    def body(carry, xs):
        st, pst, w = carry
        ext, reward = xs
        p = dataclasses.replace(params, w=w)
        s_pre = st.lif.y
        st2 = step(st, p, ext, mode=mode, backend=backend)
        pst2, w2 = plasticity_rules.plasticity_step(
            pst, s_pre, st2.lif.y, w, plastic_c, plasticity, reward,
            backend=plasticity_backend)
        return (st2, pst2, w2), st2.lif.y

    carry0 = (state, plast_state, params.w)
    if ext_seq is None:
        return jax.lax.scan(
            lambda c, r: body(c, (None, r)), carry0, rewards, length=n_ticks)
    return jax.lax.scan(body, carry0, (ext_seq, rewards))


def forward_layered(
    params: SNNParams,
    spikes_in: jax.Array,
    layer_sizes,
    n_ticks: Optional[int] = None,
    *,
    mode: str = "fixed_leak",
    surrogate: bool = False,
    backend: str = "jnp",
) -> Tuple[jax.Array, SNNState]:
    """The paper's inference pattern: clamp input-layer drive, tick until
    the wavefront crosses all layers, read output-layer spikes.

    Latency accounting (paper §II.C): 1 tick of input sampling + 1 tick per
    layer crossing => ``depth`` ticks here; the hardware charges 2 clock
    cycles per layer within a tick (5 clocks end-to-end for 2 layers),
    reproduced in benchmarks/bench_latency.py.

    Args:
      spikes_in: ``(..., n_in)`` external drive, clamped for all ticks
        (level coding) -- or ``(T, ..., n_in)`` for a spike train.
    Returns:
      (output spike raster ``(T, ..., n_out)``, final state).
    """
    n = params.w.shape[0]
    depth = len(layer_sizes)
    if n_ticks is None:
        n_ticks = depth + 1
    batch_shape = spikes_in.shape[:-1] if spikes_in.ndim >= 1 else ()
    if spikes_in.ndim >= 2 and spikes_in.shape[0] == n_ticks and n_ticks > 1:
        ext_seq = spikes_in
        batch_shape = spikes_in.shape[1:-1]
    else:
        ext_seq = jnp.broadcast_to(
            spikes_in[None], (n_ticks,) + spikes_in.shape
        )
        batch_shape = spikes_in.shape[:-1]
    state = SNNState.zeros(batch_shape, n, dtype=params.w.dtype)
    final, raster = rollout(
        params, state, ext_seq, n_ticks, mode=mode, surrogate=surrogate, backend=backend
    )
    n_out = layer_sizes[-1]
    return raster[..., n - n_out :], final


def params_from_registers(bank, *, dtype=jnp.float32) -> SNNParams:
    """Build runtime params straight from a :class:`RegisterBank`.

    The per-neuron weight layout (paper's 898-txn encoding) broadcasts the
    postsynaptic neuron's weight byte across its fan-in; per-synapse layout
    uses the full matrix.
    """
    import numpy as np

    n = bank.n
    c = bank.get_connection_list().astype(np.float32)
    if bank.weights.ndim == 1:
        w = np.broadcast_to(bank.weights.astype(np.float32)[None, :], (n, n)).copy()
    else:
        w = bank.weights.astype(np.float32)
    lif = LIFParams(
        v_th=jnp.asarray(bank.thresholds, dtype),
        leak=jnp.asarray(bank.leak, dtype),
        r_ref=jnp.asarray(bank.refractory, jnp.int32),
        gain=jnp.ones((n,), dtype),
        i_bias=jnp.zeros((n,), dtype),
        v_reset=jnp.zeros((n,), dtype),
    )
    return SNNParams(
        w=jnp.asarray(w, dtype),
        c=jnp.asarray(c, dtype),
        w_in=jnp.eye(n, dtype=dtype),
        lif=lif,
    )
