"""The SNN processor core: all-to-all network with scan rollout.

One instance == the paper's ``u_snn_proc`` block: a flat array of N
homogeneous LIF neurons, a synaptic weight matrix ``W`` gated by the
connection list ``C``, per-neuron thresholds / leak / refractory registers,
and optional per-synapse-group delays (paper: 1-255 cycles, default 1).

Semantics: one call to :func:`step` is one synchronous network tick (one
clock of the FPGA datapath after the 2-cycle neuron pipeline is abstracted
to a tick). Spikes emitted at tick k arrive at tick k+delay. A rollout over
T ticks is a ``lax.scan``.

As of the TickEngine refactor the tick itself lives in exactly one place
-- :meth:`repro.core.engine.TickEngine.tick_body` -- and every function
here is a thin wrapper that builds an engine and threads the right carry
through it. Rasters are bit-identical to the pre-engine implementations
(pinned in tests/test_engine.py against inlined oracles).

Distribution (implemented -- set ``EngineOptions.mesh``; DESIGN.md §15):
the *postsynaptic* neuron axis shards over ``"model"``, so the synapse
matrix shards ``P(None, "model")`` -- each shard owns the full fan-IN
columns of its own neurons, plus their delay rings and LIF state. Each
tick all-gathers the (tiny) spike vector along ``"model"`` and computes
the complete local ``(N x N/D)`` masked matmul -- the TPU restatement of
the paper's mux fabric, bit-exact with the single-device engine because
every output column still reduces over its whole fan-in on one device
(see :mod:`repro.parallel.snn_sharding`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.engine import (  # noqa: F401 (public API)
    EngineOptions, TickCarry, TickEngine,
)
from repro.core.lif import LIFParams
from repro.deprecation import warn_deprecated
from repro.core.network_types import (  # noqa: F401 (back-compat re-exports)
    SNNParams, SNNState, synaptic_input,
)


def _resolve_dispatch(dispatch, params, state, neighbors):
    """Turn the ``dispatch`` argument into engine kwargs (+ fan-in lists).

    ``dispatch`` is ``None`` (keep the explicit ``backend``), a
    :class:`~repro.core.dispatch_policy.DispatchPlan` (use it), the
    string ``"auto"`` (plan here, from the *concrete* ``params.c`` /
    ``params.w_in`` -- inside jit these are tracers and the policy
    raises with a pointer to plan outside), or a literal strategy
    string (``"fan_in"`` | ``"topk"`` | ``"dense"``) forwarded to the
    engine's ``event_dispatch`` static.
    """
    from repro.core import dispatch_policy

    if isinstance(dispatch, dispatch_policy.DispatchPlan):
        plan = dispatch
    elif dispatch == "auto":
        batch = 1
        for d in state.lif.v.shape[:-1]:
            batch *= int(d)
        plan = dispatch_policy.plan(params.c, w_in=params.w_in, batch=batch)
    else:
        return dict(backend="event", event_dispatch=str(dispatch)), neighbors
    if neighbors is None:
        neighbors = plan.neighbors
    return plan.engine_kwargs(), neighbors


def _build_engine(options, kw, dispatch, params, state, neighbors):
    """One engine-construction point for every wrapper below.

    ``options`` (an :class:`EngineOptions`) wins over the legacy per-call
    kwargs in ``kw``; a ``dispatch`` policy overlays its event statics on
    either. Always returns a *validated* engine (the wrappers never take
    the deprecated ``TickEngine(**kw)`` path)."""
    if dispatch is not None:
        dkw, neighbors = _resolve_dispatch(dispatch, params, state, neighbors)
    else:
        dkw = {}
    if options is not None:
        if not isinstance(options, EngineOptions):
            raise TypeError(
                f"options must be an EngineOptions, got {type(options)}")
        if dkw:
            opts = _replace_options(options, **dkw)
        else:
            opts = options
    else:
        kw = dict(kw)
        kw.update(dkw)
        opts = EngineOptions(**kw)
    return TickEngine(opts), neighbors


def _replace_options(options: EngineOptions, **changes) -> EngineOptions:
    """``dataclasses.replace`` that always lands on a plain (validated)
    EngineOptions -- safe even when handed a TickEngine subclass, whose
    ``replace`` would route through the deprecated kwargs shim."""
    merged = {f.name: getattr(options, f.name)
              for f in dataclasses.fields(EngineOptions)}
    merged.update(changes)
    return EngineOptions(**merged)


def step(
    state: SNNState,
    params: SNNParams,
    ext: Optional[jax.Array] = None,
    *,
    mode: str = "fixed_leak",
    surrogate: bool = False,
    delays: Optional[jax.Array] = None,
    backend: str = "jnp",
    neighbors=None,
    dispatch=None,
    options: Optional[EngineOptions] = None,
) -> SNNState:
    """One synchronous network tick.

    Args:
      ext: external drive for this tick, shape ``(..., n_in)`` -- the
        impulse register contents.
      delays: optional per-synapse delay in ticks, shape ``(n, n)`` int,
        values in [1, max_delay]. With delays, presynaptic spikes are
        written into the delay line and each synapse reads the slot its
        delay points at.
      backend: "jnp" (reference), "pallas" (fused matmul+LIF kernel),
        "pallas_fused" (whole-tick megakernel -- one launch per tick,
        delay pointer scalar-prefetched; :mod:`repro.kernels.tick_fused`)
        or "event" (event-driven sparse dispatch -- only spiking neurons'
        fan-outs are gathered; :func:`repro.kernels.ops.event_lif_step`).
      neighbors: optional :class:`repro.kernels.ops.EventFanIn` switching
        the "event" backend to its vmap-safe padded fan-in gather path.
      dispatch: event-dispatch policy -- ``None`` (use ``backend`` as
        given), ``"auto"`` (plan from the concrete topology via
        :func:`repro.core.dispatch_policy.plan`; implies the event
        backend), a :class:`~repro.core.dispatch_policy.DispatchPlan`,
        or a literal strategy string ("fan_in"|"topk"|"dense").
      options: a prebuilt :class:`~repro.core.engine.EngineOptions`; when
        given it supersedes the per-call static kwargs (``mode`` /
        ``surrogate`` / ``backend``) entirely.
    """
    eng, neighbors = _build_engine(
        options, dict(mode=mode, surrogate=surrogate, backend=backend),
        dispatch, params, state, neighbors)
    return eng.tick(state, params, ext, delays=delays, neighbors=neighbors)


def rollout(
    params: SNNParams,
    state: SNNState,
    ext_seq: Optional[jax.Array],
    n_ticks: int,
    *,
    mode: str = "fixed_leak",
    surrogate: bool = False,
    delays: Optional[jax.Array] = None,
    backend: str = "jnp",
    neighbors=None,
    telemetry: bool = False,
    dispatch=None,
    options: Optional[EngineOptions] = None,
):
    """Scan ``n_ticks`` network ticks; returns final state + spike raster.

    ``ext_seq`` is ``(n_ticks, ..., n_in)`` or None (autonomous dynamics).
    The raster has shape ``(n_ticks, ..., n)``. The masked matrix ``W*C``
    is hoisted out of the scan (loop-invariant for frozen weights).
    ``backend``/``neighbors``/``dispatch``: see :func:`step`.
    ``telemetry=True`` (static) appends a
    :class:`repro.obs.telemetry.TickTelemetry` to the return tuple:
    ``(final_state, raster, telemetry)``; off by default and bit-free
    when off (tests/test_obs.py pins the HLO identity).
    ``options``: a prebuilt :class:`~repro.core.engine.EngineOptions`
    superseding the per-call static kwargs.
    """
    eng, neighbors = _build_engine(
        options,
        dict(mode=mode, surrogate=surrogate, backend=backend,
             telemetry=telemetry),
        dispatch, params, state, neighbors)
    return eng.rollout(params, state, ext_seq, n_ticks, delays=delays,
                       neighbors=neighbors)


def learning_rollout(
    params: SNNParams,
    state: SNNState,
    plast_state,  # repro.plasticity.stdp.PlasticityState
    ext_seq: Optional[jax.Array],
    n_ticks: int,
    *,
    plasticity=None,  # repro.plasticity.stdp.PlasticityParams (or in options)
    rewards: Optional[jax.Array] = None,
    plastic_c: Optional[jax.Array] = None,
    mode: str = "fixed_leak",
    backend: str = "jnp",
    plasticity_backend: Optional[str] = None,
    neighbors=None,
    telemetry: bool = False,
    dispatch=None,
    options: Optional[EngineOptions] = None,
):
    """Scan ``n_ticks`` *learning* ticks: the carry holds mutable weights.

    Each tick runs the inference datapath with the current weight matrix,
    then the plasticity datapath
    (:func:`repro.plasticity.rules.plasticity_step`) on the spikes that
    tick produced: ``s_pre`` is what arrived at the neurons (the previous
    tick's emissions, ``max_delay == 1``), ``s_post`` what they emitted.
    Weights stay masked by ``params.c`` and clipped to the u8 register
    domain throughout, so the final matrix serializes straight back
    through :class:`repro.core.registers.RegisterBank`.

    Args:
      plast_state: initial :class:`~repro.plasticity.stdp.PlasticityState`
        with batch dims matching ``state``.
      ext_seq: ``(n_ticks, ..., n_in)`` external drive or None.
      rewards: ``(n_ticks,)`` scalar dopamine sequence (R-STDP); None
        means zero reward every tick (eligibility accumulates, weights
        hold -- apply the episode outcome afterwards with
        :func:`repro.plasticity.stdp.apply_reward`).
      plastic_c: learnable-synapse mask; defaults to ``params.c`` (every
        routed synapse learns).  Pass a sub-mask to freeze part of the
        fabric -- e.g. a fixed inhibitory winner-take-all block stays
        bit-identical while the feed-forward block learns.
      backend / plasticity_backend: "jnp", "pallas", "pallas_fused" or
        "event"; the plasticity backend defaults to following ``backend``
        ("pallas_fused" maps to the "pallas" plasticity pass, "event" to
        "jnp" -- the learning hook always runs outside the tick kernel).
      neighbors: optional :class:`repro.kernels.ops.EventFanIn` for the
        "event" backend's vmap-safe fan-in gather path.
      telemetry: static flag; True appends a
        :class:`repro.obs.telemetry.TickTelemetry` to the return tuple.
      dispatch: event-dispatch policy (see :func:`step`).

    Returns:
      ``((final_state, final_plast_state, final_w), raster)``, plus a
      trailing ``telemetry`` element when ``telemetry=True``.

    ``options``: a prebuilt :class:`~repro.core.engine.EngineOptions`
    superseding the per-call static kwargs (it must then carry the
    ``plasticity`` params itself, or the explicit ``plasticity`` arg is
    overlaid onto it).
    """
    if options is not None and options.plasticity is None and plasticity is not None:
        options = _replace_options(options, plasticity=plasticity,
                                   plasticity_backend=plasticity_backend)
    eng, neighbors = _build_engine(
        options,
        dict(mode=mode, backend=backend, plasticity=plasticity,
             plasticity_backend=plasticity_backend, telemetry=telemetry),
        dispatch, params, state, neighbors)
    return eng.learning_rollout(params, state, plast_state, ext_seq, n_ticks,
                                rewards=rewards, plastic_c=plastic_c,
                                neighbors=neighbors)


def forward_layered(
    params: SNNParams,
    spikes_in: jax.Array,
    layer_sizes,
    n_ticks: Optional[int] = None,
    *,
    mode: str = "fixed_leak",
    surrogate: bool = False,
    backend: str = "jnp",
    time_major: Optional[bool] = None,
) -> Tuple[jax.Array, SNNState]:
    """The paper's inference pattern: clamp input-layer drive, tick until
    the wavefront crosses all layers, read output-layer spikes.

    Latency accounting (paper §II.C): 1 tick of input sampling + 1 tick per
    layer crossing => ``depth`` ticks here; the hardware charges 2 clock
    cycles per layer within a tick (5 clocks end-to-end for 2 layers),
    reproduced in benchmarks/bench_latency.py.

    Args:
      spikes_in: ``(..., n_in)`` external drive, clamped for all ticks
        (level coding) -- or ``(n_ticks, ..., n_in)`` for a spike train.
      time_major: True -- ``spikes_in`` is a spike train with a leading
        time axis of length ``n_ticks``; False -- ``spikes_in`` is a
        single drive vector/batch, clamped (broadcast) over all ticks.
        None (deprecated) falls back to the old shape heuristic, which
        silently misreads a batch dim that happens to equal ``n_ticks``
        -- pass ``time_major`` explicitly.
    Returns:
      (output spike raster ``(n_ticks, ..., n_out)``, final state).
    """
    n = params.w.shape[0]
    depth = len(layer_sizes)
    if n_ticks is None:
        n_ticks = depth + 1
    if time_major is None:
        # Deprecated heuristic: a leading axis equal to n_ticks "must" be
        # time. Ambiguous whenever a batch dim equals n_ticks.
        time_major = bool(
            spikes_in.ndim >= 2 and spikes_in.shape[0] == n_ticks and n_ticks > 1)
        if time_major:
            warn_deprecated(
                "forward_layered is inferring time_major=True from "
                f"spikes_in.shape[0] == n_ticks == {n_ticks}; this heuristic "
                "misfires when a batch dim equals n_ticks. Pass "
                "time_major=True (spike train) or time_major=False "
                "(clamped drive) explicitly.")
    if time_major:
        if spikes_in.ndim < 2 or spikes_in.shape[0] != n_ticks:
            raise ValueError(
                "time_major spikes_in needs a leading time axis of length "
                f"n_ticks={n_ticks}; got shape {spikes_in.shape}")
        ext_seq = spikes_in
        batch_shape = spikes_in.shape[1:-1]
    else:
        ext_seq = jnp.broadcast_to(
            spikes_in[None], (n_ticks,) + spikes_in.shape
        )
        batch_shape = spikes_in.shape[:-1]
    state = SNNState.zeros(batch_shape, n, dtype=params.w.dtype)
    eng = TickEngine(EngineOptions(mode=mode, surrogate=surrogate,
                                   backend=backend))
    final, raster = eng.rollout(params, state, ext_seq, n_ticks)
    n_out = layer_sizes[-1]
    return raster[..., n - n_out :], final


def params_from_registers(bank, *, dtype=jnp.float32) -> SNNParams:
    """Build runtime params straight from a :class:`RegisterBank`.

    The per-neuron weight layout (paper's 898-txn encoding) broadcasts the
    postsynaptic neuron's weight byte across its fan-in; per-synapse layout
    uses the full matrix.
    """
    import numpy as np

    n = bank.n
    c = bank.get_connection_list().astype(np.float32)
    if bank.weights.ndim == 1:
        w = np.broadcast_to(bank.weights.astype(np.float32)[None, :], (n, n)).copy()
    else:
        w = bank.weights.astype(np.float32)
    lif = LIFParams(
        v_th=jnp.asarray(bank.thresholds, dtype),
        leak=jnp.asarray(bank.leak, dtype),
        r_ref=jnp.asarray(bank.refractory, jnp.int32),
        gain=jnp.ones((n,), dtype),
        i_bias=jnp.zeros((n,), dtype),
        v_reset=jnp.zeros((n,), dtype),
    )
    return SNNParams(
        w=jnp.asarray(w, dtype),
        c=jnp.asarray(c, dtype),
        w_in=jnp.eye(n, dtype=dtype),
        lif=lif,
    )
