"""Recompile-hazard rules: static arguments must be hashable and stable.

The serving layer's zero-recompile guarantee rests on every jit static
being (a) hashable, (b) built from immutable parts, and (c) equal (and
equal-hashing) across independently-constructed instances describing the
same configuration.  A dict/list/ndarray smuggled into a static
dataclass, or a ``__hash__`` that varies per instance, silently turns
every call into a retrace -- the bench gate catches that *after the
fact* by counting cache misses; these rules catch it by inspection.
"""

from __future__ import annotations

import dataclasses
import enum
import inspect
from typing import Any, Callable, List, Sequence

from repro.analysis.findings import ERROR, Finding

__all__ = [
    "is_deeply_immutable", "check_hashable_static", "check_hash_stability",
    "check_static_argnames", "check_dispatch_plan",
]

_ATOMS = (str, int, float, bool, bytes, type(None))


def _mesh_types() -> tuple:
    """jax's own static-intended mesh types (version-tolerant)."""
    try:
        from jax.sharding import Mesh
    except ImportError:     # pragma: no cover - ancient jax
        return ()
    try:
        from jax.sharding import AbstractMesh
        return (Mesh, AbstractMesh)
    except ImportError:
        return (Mesh,)


def is_deeply_immutable(value: Any) -> bool:
    """True when ``value`` is built purely from immutable parts (the only
    things safe to use as jit statics)."""
    if isinstance(value, _ATOMS) or isinstance(value, enum.Enum):
        return True
    if isinstance(value, _mesh_types()):
        # jax.sharding.Mesh is jax's own jit-static currency: hashable,
        # ==/hash keyed on (device assignment, axis names), and nothing
        # user-reachable mutates one after construction.  EngineOptions
        # carries one for the sharded engine (DESIGN.md §15).
        return True
    if isinstance(value, (tuple, frozenset)):
        return all(is_deeply_immutable(v) for v in value)
    if dataclasses.is_dataclass(value) and not isinstance(value, type):
        params = getattr(value, "__dataclass_params__", None)
        if params is None or not params.frozen:
            return False
        return all(is_deeply_immutable(getattr(value, f.name))
                   for f in dataclasses.fields(value))
    return False


def check_hashable_static(value: Any, program: str, *,
                          name: str = "") -> List[Finding]:
    """``value`` is about to be used as a jit static: it must hash, and
    every reachable field must be immutable."""
    label = name or type(value).__name__
    out: List[Finding] = []
    try:
        hash(value)
    except TypeError as e:
        out.append(Finding(
            rule="static.unhashable", severity=ERROR, program=program,
            location=label,
            message=f"static `{label}` is unhashable: {e}"))
        return out
    if dataclasses.is_dataclass(value) and not isinstance(value, type):
        for f in dataclasses.fields(value):
            v = getattr(value, f.name)
            if not is_deeply_immutable(v):
                out.append(Finding(
                    rule="static.mutable_field", severity=ERROR,
                    program=program, location=f"{label}.{f.name}",
                    message=f"static field `{f.name}` holds mutable "
                            f"{type(v).__name__}: hash may drift or "
                            f"collide across calls"))
    elif not is_deeply_immutable(value):
        out.append(Finding(
            rule="static.mutable_field", severity=ERROR, program=program,
            location=label,
            message=f"static `{label}` ({type(value).__name__}) is not "
                    f"deeply immutable"))
    return out


def check_hash_stability(make: Callable[[], Any], program: str, *,
                         name: str = "") -> List[Finding]:
    """Two fresh instances of the same configuration must be ``==`` and
    hash-equal -- otherwise every independently-built request retraces.
    """
    a, b = make(), make()
    label = name or type(a).__name__
    out: List[Finding] = []
    try:
        if a != b:
            out.append(Finding(
                rule="static.unstable_eq", severity=ERROR, program=program,
                location=label,
                message=f"two fresh `{label}` instances compare unequal: "
                        f"per-call retrace"))
        elif hash(a) != hash(b):
            out.append(Finding(
                rule="static.unstable_hash", severity=ERROR,
                program=program, location=label,
                message=f"equal `{label}` instances hash differently "
                        f"(identity-based __hash__?): per-call retrace"))
    except TypeError as e:
        out.append(Finding(
            rule="static.unhashable", severity=ERROR, program=program,
            location=label, message=f"`{label}` is unhashable: {e}"))
    return out


def check_static_argnames(fn: Callable, static_argnames: Sequence[str],
                          program: str, *, name: str = "") -> List[Finding]:
    """Every declared static must exist in the (unwrapped) function
    signature as a keyword-bindable parameter -- a typo'd static name is
    silently ignored by jax until a shape under it changes, then every
    call retraces."""
    label = name or getattr(fn, "__name__", str(fn))
    out: List[Finding] = []
    try:
        sig = inspect.signature(inspect.unwrap(fn))
    except (TypeError, ValueError):
        out.append(Finding(
            rule="static.no_signature", severity=ERROR, program=program,
            location=label,
            message=f"cannot inspect signature of `{label}` to validate "
                    f"static_argnames"))
        return out
    kinds_ok = (inspect.Parameter.POSITIONAL_OR_KEYWORD,
                inspect.Parameter.KEYWORD_ONLY)
    has_var_kw = any(p.kind == inspect.Parameter.VAR_KEYWORD
                     for p in sig.parameters.values())
    for s in static_argnames:
        p = sig.parameters.get(s)
        if p is None:
            if not has_var_kw:
                out.append(Finding(
                    rule="static.unknown_argname", severity=ERROR,
                    program=program, location=f"{label}({s})",
                    message=f"static_argnames entry `{s}` is not a "
                            f"parameter of `{label}`"))
        elif p.kind not in kinds_ok:
            out.append(Finding(
                rule="static.positional_only", severity=ERROR,
                program=program, location=f"{label}({s})",
                message=f"static `{s}` is {p.kind.description} in "
                        f"`{label}`: jax matches statics by keyword"))
    return out


def check_dispatch_plan(plan: Any, program: str) -> List[Finding]:
    """A :class:`~repro.core.dispatch_policy.DispatchPlan` carries arrays
    (neighbor lists) next to statics -- the plan object itself must NEVER
    be a jit static; only ``plan.engine_kwargs()`` may cross that
    boundary, and every value it exposes must be a stable static."""
    out: List[Finding] = []
    try:
        hash(plan)
        out.append(Finding(
            rule="static.plan_hashable", severity=ERROR, program=program,
            location=type(plan).__name__,
            message="DispatchPlan hashes -- someone could pass the whole "
                    "plan (arrays included) as a jit static, keying the "
                    "cache on array identity"))
    except TypeError:
        pass   # unhashable is the contract: arrays never become statics
    kwargs = plan.engine_kwargs()
    for k, v in kwargs.items():
        out.extend(check_hashable_static(
            v, program, name=f"engine_kwargs[{k}]"))
    return out
