"""Jaxpr-level structural rules: purity, dtype discipline, hoist contracts.

These rules walk *closed jaxprs* (``jax.make_jaxpr``) rather than lowered
HLO text: the jaxpr is a stable, typed IR where "is this primitive a
callback", "what dtype is this aval", and "is this eqn inside a scan
body" are direct queries instead of regexes over a pretty-printer whose
output shifts between jax releases.

Version-compat note: ``ClosedJaxpr``/``Jaxpr``/``JaxprEqn`` moved from
``jax.core`` to ``jax.extend.core`` across the supported jax range
(0.4.35 → latest), so nothing here isinstance-checks jaxpr types --
sub-jaxprs hiding in ``eqn.params`` are recognized *structurally* (an
object with ``.eqns``, or wrapping one via ``.jaxpr``), which survives
the module moves.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Iterable, Iterator, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.findings import ERROR, WARNING, Finding

__all__ = [
    "EqnSite", "iter_eqns", "closed_jaxpr_of",
    "check_hot_loop_purity", "check_dtype_discipline", "check_hoist",
    "CALLBACK_PRIMS", "TRANSFER_PRIMS", "DEFAULT_UPCAST_ALLOWLIST",
]

# Primitive names that call back into the host Python process.  Any of
# these inside a jitted tick program means a device->host sync (and on
# TPU, a buffer round-trip) per firing -- the exact thing the paper's
# "runtime reconfiguration without resynthesis" pitch forbids in our
# software analogue.  `debug_print` lowers through `debug_callback`; both
# names are listed because the primitive name differs across jax versions.
CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "debug_print",
    "outside_call", "host_callback_call",
})

# Primitives that move buffers between devices or to/from the host.
TRANSFER_PRIMS = frozenset({
    "device_put", "infeed", "outfeed",
    "transfer_to_host", "transfer_from_host",
})

# Loop-body primitives: an eqn inside one of these runs once per tick
# (or per chunk iteration), not once per program.
LOOP_PRIMS = frozenset({"scan", "while"})

# name_stack patterns under which a uint8 -> float convert is sanctioned
# (register decode / quantization boundaries -- the places u8 weights are
# *supposed* to widen, once, outside the hot loop).
DEFAULT_UPCAST_ALLOWLIST: Tuple[str, ...] = (
    r"decode_u8", r"quant", r"registers", r"encode",
)

_64BIT = (jnp.float64, jnp.complex128, jnp.int64, jnp.uint64)


@dataclasses.dataclass(frozen=True)
class EqnSite:
    """One eqn plus its structural context in the walk."""

    eqn: Any
    in_loop: bool
    path: str

    @property
    def name(self) -> str:
        return self.eqn.primitive.name

    @property
    def scope(self) -> str:
        """The ``jax.named_scope`` stack the eqn was traced under
        (empty string when source info is unavailable)."""
        try:
            return str(self.eqn.source_info.name_stack)
        except Exception:
            return ""


def _as_jaxpr(obj: Any) -> Any:
    """Duck-typed unwrap: a Jaxpr has ``.eqns``; a ClosedJaxpr wraps one
    via ``.jaxpr``.  Returns None for anything else."""
    if hasattr(obj, "eqns"):
        return obj
    inner = getattr(obj, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    return None


def _sub_jaxprs(params: dict) -> Iterator[Any]:
    """Yield every jaxpr-like value reachable from an eqn's params
    (scan/pjit ``jaxpr``, cond ``branches`` tuples, while
    ``cond_jaxpr``/``body_jaxpr``, custom_* ``call_jaxpr`` ...)."""
    for val in params.values():
        j = _as_jaxpr(val)
        if j is not None:
            yield j
            continue
        if isinstance(val, (tuple, list)):
            for item in val:
                j = _as_jaxpr(item)
                if j is not None:
                    yield j


def iter_eqns(jaxpr: Any, *, in_loop: bool = False, path: str = "",
              recurse_pallas: bool = True) -> Iterator[EqnSite]:
    """Depth-first walk over every eqn in ``jaxpr`` and its sub-jaxprs.

    ``in_loop`` is True for eqns inside a ``scan``/``while`` body (at any
    nesting depth).  ``recurse_pallas=False`` treats ``pallas_call`` as
    opaque -- kernel-internal arithmetic is then the Pallas lint's
    problem (:mod:`repro.analysis.pallas_rules`), not this walk's.
    """
    j = _as_jaxpr(jaxpr)
    if j is None:
        raise TypeError(f"not a jaxpr-like object: {type(jaxpr)!r}")
    for i, eqn in enumerate(j.eqns):
        name = eqn.primitive.name
        here = f"{path}.{name}[{i}]" if path else f"{name}[{i}]"
        yield EqnSite(eqn, in_loop, here)
        if name == "pallas_call" and not recurse_pallas:
            continue
        child_in_loop = in_loop or name in LOOP_PRIMS
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub, in_loop=child_in_loop, path=here,
                                 recurse_pallas=recurse_pallas)


def closed_jaxpr_of(fn: Callable, *args: Any, **kwargs: Any) -> Any:
    """``jax.make_jaxpr`` with kwargs threaded through."""
    return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)


# ---------------------------------------------------------------------------
# Rule (a): hot-loop purity
# ---------------------------------------------------------------------------

def check_hot_loop_purity(cj: Any, program: str, *,
                          allow: Sequence[str] = ()) -> List[Finding]:
    """No callback primitives in the program, no transfer primitives, and
    in particular no ``io_callback`` inside any scan/while body.

    ``allow`` lists primitive names exempted for this program (none of
    the shipped programs need one; fixtures use it to scope teeth tests).
    """
    out: List[Finding] = []
    for site in iter_eqns(cj):
        name = site.name
        if name in allow:
            continue
        if name in CALLBACK_PRIMS:
            if site.in_loop:
                out.append(Finding(
                    rule="purity.callback_in_loop", severity=ERROR,
                    program=program, location=site.path,
                    message=f"host callback `{name}` inside a scan/while "
                            f"body: one device->host sync per tick"))
            elif name == "io_callback":
                out.append(Finding(
                    rule="purity.io_callback", severity=WARNING,
                    program=program, location=site.path,
                    message="io_callback outside the loop: ordered host "
                            "effect serializes dispatch"))
            else:
                out.append(Finding(
                    rule="purity.callback", severity=ERROR,
                    program=program, location=site.path,
                    message=f"host callback `{name}` in a jitted program"))
        elif name in TRANSFER_PRIMS:
            out.append(Finding(
                rule="purity.transfer", severity=ERROR, program=program,
                location=site.path,
                message=f"transfer primitive `{name}` in a jitted program "
                        f"{'(inside loop body)' if site.in_loop else ''}"
                        .strip()))
    return out


# ---------------------------------------------------------------------------
# Rule (b): dtype discipline
# ---------------------------------------------------------------------------

def _avals_of(eqn: Any) -> Iterable[Any]:
    for v in eqn.outvars:
        aval = getattr(v, "aval", None)
        if aval is not None:
            yield aval


def check_dtype_discipline(
        cj: Any, program: str, *,
        upcast_allowlist: Sequence[str] = DEFAULT_UPCAST_ALLOWLIST,
) -> List[Finding]:
    """No 64-bit avals anywhere (weak-typed or not), and every
    ``uint8 -> float`` widen sits under a sanctioned name scope.

    u8 is the paper's wire format (RegisterBank / UART); the SNN compute
    path is f32.  A u8 widen *inside* a jitted program is only legal at
    the register-decode / quantization boundary -- anywhere else it means
    register bytes leaked into the hot path and are being re-decoded per
    call (or worse, per tick).
    """
    out: List[Finding] = []
    pats = [re.compile(p) for p in upcast_allowlist]
    for aval in getattr(cj, "in_avals", ()):
        dt = getattr(aval, "dtype", None)
        if dt is not None and dt in _64BIT:
            out.append(Finding(
                rule="dtype.x64_input", severity=ERROR, program=program,
                location="in_avals",
                message=f"64-bit program input ({dt})"))
    for site in iter_eqns(cj):
        for aval in _avals_of(site.eqn):
            dt = getattr(aval, "dtype", None)
            if dt is not None and dt in _64BIT:
                weak = " (weak-type promotion)" if getattr(
                    aval, "weak_type", False) else ""
                out.append(Finding(
                    rule="dtype.x64", severity=ERROR, program=program,
                    location=site.path,
                    message=f"64-bit intermediate `{site.name}` -> "
                            f"{dt}{weak}"))
        if site.name == "convert_element_type":
            src = getattr(getattr(site.eqn.invars[0], "aval", None),
                          "dtype", None)
            dst = site.eqn.params.get("new_dtype")
            if (src == jnp.uint8 and dst is not None
                    and jnp.issubdtype(dst, jnp.floating)):
                scope = site.scope
                if not any(p.search(scope) for p in pats):
                    out.append(Finding(
                        rule="dtype.u8_upcast", severity=ERROR,
                        program=program, location=site.path,
                        message=f"uint8 -> {jnp.dtype(dst).name} widen "
                                f"outside sanctioned scopes (scope="
                                f"{scope or '<none>'})"))
    return out


# ---------------------------------------------------------------------------
# Rule (c): hoist contract
# ---------------------------------------------------------------------------

# What a program promises about the premasked W*C product:
HOIST_HOISTED = "hoisted"    # frozen weights: mul outside every loop body
HOIST_IN_LOOP = "in_loop"    # learning: weights change per tick, mul in body
HOIST_KERNEL = "kernel"      # mul lives inside a Pallas kernel; only assert
                             # no stray dense mul leaked outside the kernel
HOIST_SKIP = "skip"          # rule not applicable (no W*C in this program)


def _square_muls(cj: Any, n: int) -> Tuple[int, int]:
    """Count elementwise ``mul`` eqns whose operands are both (n, n):
    returns (inside-loop, outside-loop).  ``pallas_call`` bodies are
    opaque here -- a mul inside a kernel block is per-launch by
    construction and is judged by the kernel lint instead."""
    in_loop = hoisted = 0
    for site in iter_eqns(cj, recurse_pallas=False):
        if site.name != "mul":
            continue
        shapes = [getattr(getattr(v, "aval", None), "shape", None)
                  for v in site.eqn.invars]
        if all(s == (n, n) for s in shapes):
            if site.in_loop:
                in_loop += 1
            else:
                hoisted += 1
    return in_loop, hoisted


def check_hoist(cj: Any, program: str, *, n: int,
                expect: str = HOIST_HOISTED) -> List[Finding]:
    """The W*C premask contract, as a jaxpr-level structural assertion.

    The (n, n) elementwise product of weights and connectivity is the
    single largest intermediate in a tick.  Frozen-weight programs must
    materialize it ONCE per rollout (outside every scan body); learning
    programs must recompute it per tick (weights are loop-variant, a
    hoisted stale product would be a silent correctness bug) -- the rule
    has teeth in both directions.
    """
    if expect == HOIST_SKIP:
        return []
    in_loop, hoisted = _square_muls(cj, n)
    out: List[Finding] = []
    if expect == HOIST_HOISTED:
        if in_loop:
            out.append(Finding(
                rule="hoist.wc_in_loop", severity=ERROR, program=program,
                location=f"{in_loop} eqn(s)",
                message=f"frozen-weight program materializes ({n},{n}) "
                        f"W*C inside a loop body {in_loop}x"))
        if not hoisted:
            out.append(Finding(
                rule="hoist.wc_missing", severity=ERROR, program=program,
                message=f"no hoisted ({n},{n}) W*C multiply found -- "
                        f"premask was optimized away or never formed"))
    elif expect == HOIST_IN_LOOP:
        if not in_loop:
            out.append(Finding(
                rule="hoist.wc_not_in_loop", severity=ERROR,
                program=program,
                message=f"learning program has no in-loop ({n},{n}) W*C "
                        f"multiply: a hoisted stale premask would miss "
                        f"per-tick weight updates"))
    elif expect == HOIST_KERNEL:
        if in_loop:
            out.append(Finding(
                rule="hoist.wc_in_loop", severity=ERROR, program=program,
                location=f"{in_loop} eqn(s)",
                message=f"({n},{n}) W*C multiply leaked outside the "
                        f"kernel into a loop body"))
    else:
        raise ValueError(f"unknown hoist expectation {expect!r}")
    return out
