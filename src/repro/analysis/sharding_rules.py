"""Sharding rules: no fabric-sized collective inside the hot loop.

The sharded engine's contract (DESIGN.md §15) is ONE collective per
tick, and it moves *spikes* -- ``B*n`` floats, about ``n``-fold smaller
than any weight operand.  The failure mode this rule guards is the easy
regression: a spec change (or an XLA repartition) that makes the tick
loop ``all_gather`` the weight matrix itself, turning the
communication-light column partition into a per-tick replication of 16
GiB at the 64k operating point.

The check is structural, on the jaxpr: any collective equation whose
OUTPUT is at least ``n x n`` elements, sitting inside a ``scan``/
``while`` body, is an error.  The legitimate spike gather passes by
construction (its output is ``(..., n)``); hoisted weight movement
outside the loop (e.g. the one-time premask placement) also passes --
it runs once per rollout, not once per tick.
"""

from __future__ import annotations

from typing import Any, List

from repro.analysis.findings import ERROR, Finding
from repro.analysis.jaxpr_rules import iter_eqns

__all__ = ["COLLECTIVE_PRIMS", "check_no_w_gather_in_loop"]

# Jaxpr primitive names that move data across mesh shards.  (`psum` is
# the all-reduce primitive's jaxpr name; `all_gather_invariant` is the
# shard_map-era variant of all_gather.)
COLLECTIVE_PRIMS = frozenset({
    "all_gather", "all_gather_invariant", "all_to_all", "psum",
    "psum_invariant", "reduce_scatter", "ppermute",
})


def _out_numel(eqn: Any) -> int:
    best = 0
    for v in eqn.outvars:
        shape = getattr(getattr(v, "aval", None), "shape", None)
        if shape is None:
            continue
        numel = 1
        for d in shape:
            try:
                numel *= int(d)
            except TypeError:   # symbolic dim; treat as 1
                pass
        best = max(best, numel)
    return best


def check_no_w_gather_in_loop(cj: Any, program: str, *,
                              n: int) -> List[Finding]:
    """ERROR on any collective inside a scan/while body whose output is
    ``>= n*n`` elements -- the weight operand (or something its size)
    being replicated per tick."""
    out: List[Finding] = []
    threshold = n * n
    for site in iter_eqns(cj, recurse_pallas=False):
        if site.name not in COLLECTIVE_PRIMS or not site.in_loop:
            continue
        numel = _out_numel(site.eqn)
        if numel >= threshold:
            out.append(Finding(
                rule="sharding.w_gather_in_loop", severity=ERROR,
                program=program, location=site.path,
                message=f"collective `{site.name}` inside a loop body "
                        f"moves {numel} elements (>= n*n = {threshold}): "
                        f"the weight operand is being replicated per "
                        f"tick; only the (B, n) spike exchange belongs "
                        f"in the tick loop"))
    return out
