"""The analysis gate: sweep every shipped program through every rule.

Usage::

    python -m repro.analysis.check --all            # the full shipped matrix
    python -m repro.analysis.check --list           # what --all covers
    python -m repro.analysis.check --program tick/event/frozen/notelem
    python -m repro.analysis.check --all --include-info

Exit status is nonzero iff any ``error``-severity finding fired, so the
CI job is just ``python -m repro.analysis.check --all``.  Findings also
mirror through the shared JSON-lines event log (``REPRO_EVENT_LOG=path``,
see :mod:`repro.obs.log`) for machine consumption.

Nothing here executes a tick: programs are traced (``jax.make_jaxpr``)
and lowered (``.lower().as_text()``), kernels are linted from their
launch descriptors, statics are hashed.  A full ``--all`` sweep runs in
seconds on CPU.
"""

from __future__ import annotations

import argparse
import sys
import traceback
from typing import List, Optional, Sequence

from repro.analysis import (hlo_rules, jaxpr_rules, pallas_rules, programs,
                            sharding_rules, static_rules)
from repro.analysis.findings import ERROR, Finding, Report
from repro.analysis.programs import Program


def check_program(prog: Program, report: Report) -> None:
    """Run every applicable rule family on one program."""
    report.mark_checked(prog.name)
    if prog.fn is not None:
        cj = jaxpr_rules.closed_jaxpr_of(prog.fn, *prog.args)
        report.extend(jaxpr_rules.check_hot_loop_purity(cj, prog.name))
        report.extend(jaxpr_rules.check_dtype_discipline(
            cj, prog.name, upcast_allowlist=prog.upcast_allowlist))
        report.extend(jaxpr_rules.check_hoist(
            cj, prog.name, n=prog.n, expect=prog.hoist))
        report.extend(sharding_rules.check_no_w_gather_in_loop(
            cj, prog.name, n=prog.n))
        if prog.check_hlo:
            text = hlo_rules.lowered_text(prog.fn, *prog.args)
            report.extend(hlo_rules.check_no_f64_text(text, prog.name))
            report.extend(hlo_rules.check_no_host_calls_text(text, prog.name))
    if prog.options_factory is not None:
        report.extend(static_rules.check_hashable_static(
            prog.options_factory(), prog.name, name="EngineOptions"))
        report.extend(static_rules.check_hash_stability(
            prog.options_factory, prog.name, name="EngineOptions"))
    for launch in prog.launches:
        report.extend(pallas_rules.check_launch(launch, prog.name))


def check_static_surface(report: Report) -> None:
    """The program-independent recompile-hazard surface: every kernel
    entry point's declared static_argnames, and the admission-time
    dispatch plan (which must stay UNhashable -- it carries arrays)."""
    name = "static/jit-surface"
    report.mark_checked(name)
    for fn, statics in programs.jit_static_registry():
        label = getattr(fn, "__name__", repr(fn))
        report.extend(static_rules.check_static_argnames(
            fn, statics, name, name=label))
    plan_prog = "static/dispatch-plan"
    report.mark_checked(plan_prog)
    report.extend(static_rules.check_dispatch_plan(
        programs.demo_dispatch_plan(), plan_prog))


def run(names: Optional[Sequence[str]] = None, *,
        include_static: bool = True) -> Report:
    """Build + check the named programs (default: the full registry).
    A program that fails to even build/trace is itself an error finding
    (``analysis.build``) -- a rule that can't run must not pass silently.
    """
    report = Report()
    for name in (names or programs.program_names()):
        try:
            prog = programs.build_program(name)
            check_program(prog, report)
        except Exception as e:  # noqa: BLE001 - reported as a finding
            report.mark_checked(name)
            report.add(Finding(
                rule="analysis.build", severity=ERROR, program=name,
                message=f"program failed to build/trace: "
                        f"{type(e).__name__}: {e}"))
            traceback.print_exc(file=sys.stderr)
    if include_static:
        check_static_surface(report)
    return report


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="Static analysis gate over every shipped compiled "
                    "program (jaxpr/HLO invariants + Pallas kernel lint).")
    ap.add_argument("--all", action="store_true",
                    help="sweep the full program registry (default when "
                         "no --program is given)")
    ap.add_argument("--program", action="append", default=[],
                    metavar="NAME", help="check one program (repeatable; "
                    "see --list)")
    ap.add_argument("--list", action="store_true",
                    help="print the registry and exit")
    ap.add_argument("--include-info", action="store_true",
                    help="show info-severity findings in the table")
    args = ap.parse_args(argv)

    if args.list:
        for name in programs.program_names():
            print(name)
        print("static/jit-surface")
        print("static/dispatch-plan")
        return 0

    names: Optional[List[str]] = args.program or None
    if names:
        known = set(programs.program_names())
        bad = [n for n in names if n not in known]
        if bad:
            ap.error(f"unknown program(s) {bad}; see --list")
    report = run(names, include_static=not names)
    print(report.table(include_info=args.include_info))
    report.emit_json()
    print(report.summary())
    return report.exit_code()


if __name__ == "__main__":
    sys.exit(main())
