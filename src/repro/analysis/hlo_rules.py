"""Lowered-HLO (StableHLO text) rules.

The jaxpr rules (:mod:`repro.analysis.jaxpr_rules`) are the primary
gate -- typed IR, no regexes.  Two classes of hazard only become visible
*after* lowering, so they get text-level checks here:

* 64-bit types introduced by the lowering itself (``f64[``-style
  leakage), and
* host-callback ``custom_call`` targets that jax lowers callbacks into.

This module also hosts the region-aware W*C multiply counter that
``tests/test_engine.py`` pioneered (``while_spans`` /
``wc_multiplies``) -- the test now imports it from here, so the analyzer
and the test suite cannot drift apart.
"""

from __future__ import annotations

import re
from typing import Any, Callable, List, Tuple

import jax

from repro.analysis.findings import ERROR, Finding

__all__ = [
    "lowered_text", "normalize_module_text", "while_spans", "wc_multiplies",
    "check_no_f64_text", "check_no_host_calls_text",
]

# custom_call targets that are fine in a pure device program.  Everything
# else containing "callback"/"infeed"/"outfeed"/host-transfer markers is a
# violation; unknown targets are reported too (fail closed -- a new jax
# version introducing a new host-call target should trip the gate, not
# slide through).
_HOST_CALL_MARKERS = ("callback", "infeed", "outfeed", "send", "recv",
                     "host")


def lowered_text(fn: Callable, *args: Any, **kwargs: Any) -> str:
    """StableHLO text of ``jit(fn)(*args)``, module name normalized so
    two lowerings of the same program compare equal."""
    txt = jax.jit(lambda *a: fn(*a, **kwargs)).lower(*args).as_text()
    return normalize_module_text(txt)


def normalize_module_text(text: str) -> str:
    """Strip the one non-deterministic token (the module's auto-generated
    name) so text-level comparisons are stable across processes."""
    return re.sub(r"module @\S+", "module @m", text)


# ---------------------------------------------------------------------------
# Region-aware W*C counting (moved from tests/test_engine.py)
# ---------------------------------------------------------------------------

def _match_region(text: str, k: int) -> int:
    """Return the end index of the brace region opening at ``text[k]``."""
    depth = 0
    for m in range(k, len(text)):
        if text[m] == "{":
            depth += 1
        elif text[m] == "}":
            depth -= 1
            if depth == 0:
                return m
    return -1


def while_spans(text: str) -> List[Tuple[int, int]]:
    """(start, end) char spans of every ``stablehlo.while`` op's regions --
    the ``cond`` region and the chained ``do`` region."""
    spans = []
    i = 0
    while True:
        j = text.find("stablehlo.while", i)
        if j < 0:
            break
        k = text.find("{", j)
        m = _match_region(text, k) if k >= 0 else -1
        if m < 0:
            break
        spans.append((k, m))
        i = m
        if re.match(r"\s*do\s*\{", text[m + 1:]):
            k2 = text.find("{", m + 1)
            m2 = _match_region(text, k2)
            if m2 > 0:
                spans.append((k2, m2))
                i = m2
        i += 1
    return spans


def wc_multiplies(text: str, n: int) -> Tuple[int, int]:
    """Count (n, n) elementwise multiplies: (executed-per-tick, hoisted).

    JAX outlines scan bodies into private ``func.func``s called from the
    ``while`` op's ``do`` region, so "inside the loop" means: textually
    within a while region, OR within any function other than ``@main``
    (the only callers of outlined private functions in these programs are
    loop bodies).  Everything in ``@main`` outside a while region runs
    once per rollout.
    """
    wc_shape = f"tensor<{n}x{n}xf32>"
    spans = while_spans(text)
    funcs = [(m.start(), m.group(1))
             for m in re.finditer(r"func\.func\s+\w+\s+@([\w.\-$]+)", text)]
    in_loop = out_of_loop = 0
    for m in re.finditer(
            r"stablehlo\.multiply.*" + re.escape(wc_shape), text):
        o = m.start()
        enclosing = "main"
        for start, name in funcs:
            if start < o:
                enclosing = name
            else:
                break
        if enclosing != "main" or any(a <= o <= b for a, b in spans):
            in_loop += 1
        else:
            out_of_loop += 1
    return in_loop, out_of_loop


# ---------------------------------------------------------------------------
# Text-level rules
# ---------------------------------------------------------------------------

def check_no_f64_text(text: str, program: str) -> List[Finding]:
    """No 64-bit element types survive lowering (catches f64 the lowering
    itself introduces, which a jaxpr walk cannot see)."""
    out: List[Finding] = []
    for token in ("f64[", "c128[", "tensor<f64", "xf64>", "xc128>"):
        if token in text:
            out.append(Finding(
                rule="dtype.x64_lowered", severity=ERROR, program=program,
                location=token,
                message=f"64-bit element type `{token}` in lowered HLO"))
            break
    return out


def check_no_host_calls_text(text: str, program: str) -> List[Finding]:
    """No host-callback ``custom_call`` targets in the lowered program."""
    out: List[Finding] = []
    for m in re.finditer(r"custom_call\s*@?\"?([\w.\-$]+)", text):
        target = m.group(1).lower()
        if any(marker in target for marker in _HOST_CALL_MARKERS):
            out.append(Finding(
                rule="purity.host_custom_call", severity=ERROR,
                program=program, location=m.group(1),
                message=f"host-call custom_call target `{m.group(1)}` "
                        f"in lowered program"))
    return out
