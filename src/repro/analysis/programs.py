"""The program registry: every compiled program we ship, as an
analyzable spec.

A :class:`Program` bundles what the rules need: a traceable ``fn`` +
example args (for the jaxpr/HLO rules), the W*C hoist expectation, the
:class:`~repro.core.engine.EngineOptions` factory (for the
recompile-hazard rules), and the Pallas launch descriptors the program's
kernels would use at a representative operating point (for the kernel
lint).  :func:`iter_programs` yields the full shipped matrix:

* tick programs -- 4 backends x frozen/learning x telemetry on/off
  (16 programs), the event knee variant riding on the frozen event
  programs so the adaptive ``lax.cond`` arms are linted as shipped;
* serve programs -- the wave program (dense + event), the continuous
  chunked step, and the slot-refill register-download program;
* kernel launches -- each Pallas kernel's descriptor at a
  representative padded shape (what :mod:`repro.kernels.ops` would
  launch on TPU; CPU runs interpret mode, but the descriptor is
  identical).

Everything is built lazily and small (n <= 24, a handful of ticks):
the analyzer traces and lowers, it never executes a tick.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Iterator, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.analysis import jaxpr_rules
from repro.kernels.launch_spec import KernelLaunch

# Small but non-degenerate: n is the fabric width the hoist rule greps
# for, chosen to collide with nothing else (ticks, delay depth, batch).
_N = 24
_TICKS = 5


@dataclasses.dataclass
class Program:
    """One analyzable program (see module docstring)."""

    name: str
    fn: Optional[Callable] = None
    args: Tuple[Any, ...] = ()
    n: int = _N
    hoist: str = jaxpr_rules.HOIST_SKIP
    upcast_allowlist: Sequence[str] = jaxpr_rules.DEFAULT_UPCAST_ALLOWLIST
    check_hlo: bool = True
    options_factory: Optional[Callable[[], Any]] = None
    launches: Tuple[KernelLaunch, ...] = ()


# ---------------------------------------------------------------------------
# Tick programs
# ---------------------------------------------------------------------------

def _snn_params(n: int):
    from repro.core import connectivity
    from repro.core.lif import LIFParams
    from repro.core.network import SNNParams

    rng = np.random.default_rng(0)
    c = connectivity.sparse_random(n, 0.3, seed=0)
    return SNNParams(
        w=jnp.asarray(rng.uniform(0, 2.0, (n, n)), jnp.float32),
        c=jnp.asarray(c, jnp.float32),
        w_in=jnp.eye(n, dtype=jnp.float32),
        lif=LIFParams.make(n, v_th=1.0, leak=0.25, r_ref=1))


def _ext_seq(n: int, ticks: int):
    rng = np.random.default_rng(1)
    return jnp.asarray((rng.random((ticks, n)) < 0.3), jnp.float32)


def _tick_options(backend: str, learning: bool, telemetry: bool):
    from repro.core.engine import EngineOptions
    from repro.plasticity import PlasticityParams

    kw: dict = dict(backend=backend, telemetry=telemetry)
    if learning:
        kw["plasticity"] = PlasticityParams.make(
            "stdp", a_plus=0.05, a_minus=0.05)
    elif backend == "event":
        # The frozen event programs ship with the adaptive knee on, so
        # the per-tick lax.cond (both arms) is part of the linted program.
        kw["event_knee"] = 4
    return EngineOptions(**kw)


def _tick_hoist(backend: str, learning: bool) -> str:
    if backend == "pallas":
        # w and c stream into the kernel separately; the mask multiply
        # happens per tile in VMEM (judged by the kernel lint), so the
        # jaxpr-level contract is only "no dense W*C leaked into the loop".
        return jaxpr_rules.HOIST_KERNEL
    if learning:
        return (jaxpr_rules.HOIST_IN_LOOP
                if backend in ("jnp", "event")
                else jaxpr_rules.HOIST_KERNEL)
    return jaxpr_rules.HOIST_HOISTED


def _tick_program(backend: str, learning: bool, telemetry: bool) -> Program:
    from repro.core.engine import TickEngine
    from repro.core.network import SNNState

    opts = _tick_options(backend, learning, telemetry)
    engine = TickEngine(opts)
    params = _snn_params(_N)
    state = SNNState.zeros((), _N)
    ext = _ext_seq(_N, _TICKS)
    if learning:
        from repro.plasticity import PlasticityState

        pst = PlasticityState.zeros((), _N)
        fn = functools.partial(engine.learning_rollout, n_ticks=_TICKS)
        args = (params, state, pst, ext)
    else:
        fn = functools.partial(engine.rollout, n_ticks=_TICKS)
        args = (params, state, ext)
    tag = "learning" if learning else "frozen"
    tel = "telem" if telemetry else "notelem"
    return Program(
        name=f"tick/{backend}/{tag}/{tel}",
        fn=fn, args=args, n=_N,
        hoist=_tick_hoist(backend, learning),
        options_factory=functools.partial(
            _tick_options, backend, learning, telemetry),
    )


def _sharded_options(learning: bool, telemetry: bool):
    """EngineOptions with a 1-device ``("model",)`` mesh: the analysis
    sweep runs wherever CI lands (usually one visible device), and the
    sharded program structure -- shard_map around the scan, spec trees,
    the spike collective plumbing -- is identical at any axis size; only
    the gather width changes.  Meshes compare by device assignment, so
    the factory stays hash-stable across calls (rule d)."""
    from repro.core.engine import EngineOptions
    from repro.launch.mesh import make_snn_mesh
    from repro.plasticity import PlasticityParams

    kw: dict = dict(backend="jnp", telemetry=telemetry,
                    mesh=make_snn_mesh(1))
    if learning:
        kw["plasticity"] = PlasticityParams.make(
            "stdp", a_plus=0.05, a_minus=0.05)
    return EngineOptions(**kw)


def _tick_sharded_program(learning: bool, telemetry: bool) -> Program:
    from repro.core.engine import TickEngine
    from repro.core.network import SNNState

    engine = TickEngine(_sharded_options(learning, telemetry))
    params = _snn_params(_N)
    state = SNNState.zeros((), _N)
    ext = _ext_seq(_N, _TICKS)
    if learning:
        from repro.plasticity import PlasticityState

        pst = PlasticityState.zeros((), _N)
        fn = functools.partial(engine.learning_rollout, n_ticks=_TICKS)
        args = (params, state, pst, ext)
    else:
        fn = functools.partial(engine.rollout, n_ticks=_TICKS)
        args = (params, state, ext)
    tag = "learning" if learning else "frozen"
    tel = "telem" if telemetry else "notelem"
    # shard_map is not a loop primitive: the frozen premask hoists to
    # just inside the partition, which the hoist walk still sees as
    # outside every scan body -- HOIST_HOISTED holds sharded too.
    return Program(
        name=f"tick/sharded/{tag}/{tel}",
        fn=fn, args=args, n=_N,
        hoist=(jaxpr_rules.HOIST_IN_LOOP if learning
               else jaxpr_rules.HOIST_HOISTED),
        options_factory=functools.partial(
            _sharded_options, learning, telemetry),
    )


# ---------------------------------------------------------------------------
# Serve programs (wave / chunk / refill)
# ---------------------------------------------------------------------------

def _demo_server(event: bool):
    """A tiny 2-slot server with one resident demo tenant (dense or
    sparse-enough-to-ride-the-event-program)."""
    from repro.core import connectivity
    from repro.core.lif import LIFParams
    from repro.core.network import SNNParams
    from repro.launch.serve import SNNServer

    n_max, n = 16, 12
    server = SNNServer(n_max=n_max, slots=2, max_ticks=4, backend="jnp",
                       event_density=0.2 if event else None, chunk_ticks=2)
    rng = np.random.default_rng(2)
    c = (connectivity.sparse_random(n, 0.08, seed=3) if event
         else connectivity.all_to_all(n))
    params = SNNParams(
        w=jnp.asarray(rng.uniform(0, 2.0, (n, n)), jnp.float32),
        c=jnp.asarray(c, jnp.float32),
        w_in=jnp.eye(n, dtype=jnp.float32),
        lif=LIFParams.make(n, v_th=1.0, leak=0.25, r_ref=1))
    t = server.add_tenant_params("demo", params, n_in=n, n_out=n,
                                 plastic=False)
    if event and t.backend != "event":
        raise RuntimeError(
            "demo tenant did not route to the event program; the serve "
            "registry is mis-built")
    return server, t


def _serve_wave_program(event: bool) -> Program:
    from repro.launch.serve import ServeRequest

    server, t = _demo_server(event)
    backend = t.backend
    reqs = [ServeRequest(rid=i, tenant="demo",
                         ext=np.zeros((4, t.n_in), np.float32), n_ticks=4)
            for i in range(server.slots)]
    args = server._assemble(reqs)
    # _run_for registers the backend engine and returns the jitted wave
    # program -- the same object serving runs (make_jaxpr recurses into
    # the pjit eqn, so the analysis sees the whole body).
    fn = server._run_for(backend)
    # The wave vmaps the rollout over slots, so every W*C product carries
    # a leading slot axis -- the rank-2 hoist grep does not apply (the
    # tick programs above pin the hoist contract for each backend).
    return Program(name=f"serve/wave/{backend}", fn=fn, args=args,
                   n=server.n_max, hoist=jaxpr_rules.HOIST_SKIP)


def _serve_chunk_program() -> Program:
    import jax

    server, t = _demo_server(False)
    S, N, chunk = server.slots, server.n_max, 2
    fresh = server._fresh_slot_carry(t)
    bcast = lambda x: jax.tree.map(
        lambda a: jnp.broadcast_to(a, (S,) + a.shape), x)
    args = (bcast(t.params), bcast(fresh),
            jnp.zeros((S, chunk, N), jnp.float32),
            jnp.broadcast_to(t.plastic_c, (S,) + t.plastic_c.shape),
            jnp.zeros((S, chunk), jnp.float32),
            jnp.zeros((S,), jnp.int32),
            jnp.zeros((S,), jnp.int32),
            jnp.zeros((S, N), jnp.float32),
            None, None)
    fn = functools.partial(server._chunk_fn, "jnp", chunk)
    return Program(name="serve/chunk/jnp", fn=fn, args=args,
                   n=N, hoist=jaxpr_rules.HOIST_SKIP)


def _serve_refill_program() -> Program:
    import jax

    server, t = _demo_server(False)
    S, N = server.slots, server.n_max
    fresh = server._fresh_slot_carry(t)
    bcast = lambda x: jax.tree.map(
        lambda a: jnp.broadcast_to(a, (S,) + a.shape), x)
    zero_row = jnp.zeros((N,), jnp.float32)
    stacked = (bcast(t.params), bcast(fresh),
               jnp.broadcast_to(t.plastic_c, (S,) + t.plastic_c.shape),
               jnp.zeros((S, N), jnp.float32), None, None)
    image = (t.params, fresh, t.plastic_c, zero_row, None, None)
    fill = server._fill_run_for("jnp")
    return Program(name="serve/refill/jnp", fn=fill,
                   args=(stacked, image, jnp.asarray(0, jnp.int32)),
                   n=N, hoist=jaxpr_rules.HOIST_SKIP)


# ---------------------------------------------------------------------------
# Kernel launches (representative padded operating point)
# ---------------------------------------------------------------------------

def kernel_launches() -> Tuple[Tuple[str, KernelLaunch], ...]:
    """``(registry name, launch)`` for each Pallas kernel at a
    representative shape (MXU-aligned, the sizes
    :mod:`repro.kernels.ops` would pick for a mid-size fabric).  The
    registry name disambiguates variants of the same kernel (the frozen
    and learning tick launches share ``KernelLaunch.name``)."""
    from repro.kernels.event_dispatch import event_db_launch, event_launch
    from repro.kernels.lif_step import lif_launch
    from repro.kernels.stdp_update import stdp_launch
    from repro.kernels.tick_fused import tick_launch

    f32, i32 = jnp.float32, jnp.int32
    lif_dt = {"s": f32, "w": f32, "c": f32, "v": f32, "r": i32,
              "drive": f32, "param": f32}
    tick_dt = {"dly_read": f32, "w": f32, "c": f32, "delays": i32,
               "v": f32, "r": i32, "drive": f32, "dly_full": f32,
               "param": f32}
    ev_dt = {"w": f32, "v": f32, "r": i32, "drive": f32, "param": f32}
    stdp_dt = {"s_pre": f32, "x_pre": f32, "s_post": f32, "x_post": f32,
               "w": f32, "c": f32, "elig": f32, "reward": f32}
    return (
        ("lif_step", lif_launch(B=128, K=512, N=256, dtypes=lif_dt)),
        # Frozen pre-masked uniform-delay tick (no c operand), delay
        # depth 4: the scalar-prefetched read slot steers the DMA.
        ("tick_fused/frozen",
         tick_launch(B=128, K=512, N=256, n_read=4, dtypes=tick_dt,
                     has_c=False, has_delays=False, has_drive=True,
                     write_delay=True, n_full=4)),
        # Learning per-synapse-delay tick: w and c stream separately.
        ("tick_fused/learning",
         tick_launch(B=128, K=512, N=256, n_read=4, dtypes=tick_dt,
                     has_c=True, has_delays=True, has_drive=True,
                     write_delay=True, n_full=4)),
        ("event_dispatch", event_launch(B=8, K=1024, N=256, k_active=128,
                                        dtypes=ev_dt, has_drive=True)),
        ("event_dispatch_db",
         event_db_launch(B=8, K=1024, N=256, k_active=128, dtypes=ev_dt,
                         has_drive=True)),
        ("stdp_update", stdp_launch(B=128, K=128, N=128, dtypes=stdp_dt)),
    )


# ---------------------------------------------------------------------------
# Static-argnames registry (rule d)
# ---------------------------------------------------------------------------

def jit_static_registry():
    """(jitted fn, declared static_argnames) for every kernel entry point
    -- the analyzer validates each name against the unwrapped signature.
    """
    from repro.kernels import event_dispatch, lif_step, stdp_update, tick_fused

    dims = ("block_b", "block_n", "block_k")
    return (
        (tick_fused.fused_tick, ("mode",) + dims + ("interpret",)),
        (lif_step.fused_lif_step, ("mode",) + dims + ("interpret",)),
        (event_dispatch.event_lif_dispatch,
         ("mode", "block_n", "interpret")),
        (event_dispatch.event_lif_dispatch_db,
         ("mode", "block_n", "interpret")),
        (stdp_update.fused_stdp_step,
         ("rule", "a_plus", "a_minus", "decay_pre", "decay_post",
          "decay_elig", "lr_reward", "w_min", "w_max") + dims
         + ("interpret",)),
    )


def demo_dispatch_plan():
    """A representative admission-time dispatch plan (sparse topology at
    the serve cap) for the DispatchPlan static rules."""
    from repro.core import connectivity, dispatch_policy

    c = np.asarray(connectivity.sparse_random(_N, 0.08, seed=5)) > 0
    return dispatch_policy.plan(
        c, w_in=np.eye(_N, dtype=np.float32), cap=8, vmap_safe=True,
        prefer_density=0.2)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

BACKENDS = ("jnp", "pallas", "pallas_fused", "event")


def program_names() -> Tuple[str, ...]:
    names = [f"tick/{b}/{t}/{tel}"
             for b in BACKENDS
             for t in ("frozen", "learning")
             for tel in ("notelem", "telem")]
    names += ["tick/sharded/frozen/notelem", "tick/sharded/learning/telem"]
    names += ["serve/wave/jnp", "serve/wave/event", "serve/chunk/jnp",
              "serve/refill/jnp"]
    names += [f"kernel/{reg}" for reg, _ in kernel_launches()]
    return tuple(names)


def build_program(name: str) -> Program:
    """Build one program by name (lazy -- nothing traces until a rule
    asks for the jaxpr)."""
    parts = name.split("/")
    if parts[0] == "tick":
        _, backend, tag, tel = parts
        if backend == "sharded":
            return _tick_sharded_program(tag == "learning", tel == "telem")
        return _tick_program(backend, tag == "learning", tel == "telem")
    if name == "serve/wave/jnp":
        return _serve_wave_program(False)
    if name == "serve/wave/event":
        return _serve_wave_program(True)
    if name == "serve/chunk/jnp":
        return _serve_chunk_program()
    if name == "serve/refill/jnp":
        return _serve_refill_program()
    if parts[0] == "kernel":
        reg_name = "/".join(parts[1:])
        for reg, launch in kernel_launches():
            if reg == reg_name:
                return Program(name=name, launches=(launch,))
        raise KeyError(f"unknown kernel launch {reg_name!r}")
    raise KeyError(f"unknown program {name!r}")


def iter_programs(names: Optional[Sequence[str]] = None) -> Iterator[Program]:
    for name in (names or program_names()):
        yield build_program(name)
