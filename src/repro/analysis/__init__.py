"""repro.analysis -- static analysis gate for every compiled tick program.

Walks closed jaxprs and lowered HLO of the shipped programs (4 backends
x frozen/learning x telemetry on/off, plus the serve wave/continuous/
refill programs) and lints every Pallas kernel's launch descriptor --
all without executing a tick.  See DESIGN.md §14 for the rule catalogue
and ``python -m repro.analysis.check --help`` for the CLI.
"""

from repro.analysis.findings import ERROR, INFO, WARNING, Finding, Report

__all__ = ["Finding", "Report", "ERROR", "WARNING", "INFO"]
