"""Finding/Report types for the static analyzer.

A *finding* is one rule firing (or passing) on one program.  A *report*
is a collection of findings over a sweep: it renders a human table,
mirrors every finding as a JSON line through :mod:`repro.obs.log` (same
sink the serving layer uses, so CI artifacts interleave), and reduces to
an exit code (nonzero iff any ``error``-severity finding).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional

from repro.obs.log import log_event

__all__ = ["Finding", "Report", "ERROR", "WARNING", "INFO"]

ERROR = "error"
WARNING = "warning"
INFO = "info"

_SEVERITIES = (ERROR, WARNING, INFO)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule outcome on one program.

    ``rule`` is a stable dotted id (``purity.callback_in_scan``);
    ``program`` names the analyzed program (``tick/event/frozen/telem``);
    ``location`` is a best-effort pointer into the artifact (an eqn path
    like ``scan[0].cond[1]``, a BlockSpec operand name, a dataclass
    field).
    """

    rule: str
    severity: str
    program: str
    message: str
    location: str = ""

    def __post_init__(self) -> None:
        if self.severity not in _SEVERITIES:
            raise ValueError(
                f"severity must be one of {_SEVERITIES}, got "
                f"{self.severity!r}")

    def row(self) -> List[str]:
        return [self.severity.upper(), self.program, self.rule,
                self.location, self.message]


class Report:
    """Accumulates findings across a sweep; renders + scores them."""

    def __init__(self) -> None:
        self.findings: List[Finding] = []
        self.programs_checked: List[str] = []

    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def extend(self, findings: Iterable[Finding]) -> None:
        for f in findings:
            self.add(f)

    def mark_checked(self, program: str) -> None:
        if program not in self.programs_checked:
            self.programs_checked.append(program)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == WARNING]

    def ok(self) -> bool:
        return not self.errors

    def exit_code(self) -> int:
        return 1 if self.errors else 0

    # -- rendering ---------------------------------------------------------

    def table(self, *, include_info: bool = False) -> str:
        """Aligned human-readable findings table (markdown-compatible:
        the CI job appends it verbatim to the step summary)."""
        shown = [f for f in self.findings
                 if include_info or f.severity != INFO]
        header = ["severity", "program", "rule", "location", "message"]
        rows = [f.row() for f in shown]
        widths = [max(len(header[i]), *(len(r[i]) for r in rows))
                  if rows else len(header[i]) for i in range(len(header))]
        fmt = lambda r: "| " + " | ".join(
            c.ljust(w) for c, w in zip(r, widths)) + " |"
        lines = [fmt(header),
                 "|" + "|".join("-" * (w + 2) for w in widths) + "|"]
        lines += [fmt(r) for r in rows]
        if not rows:
            lines.append(fmt(["-"] * len(header)))
        lines.append("")
        lines.append(
            f"{len(self.programs_checked)} program(s) checked, "
            f"{len(self.errors)} error(s), {len(self.warnings)} warning(s).")
        return "\n".join(lines)

    def emit_json(self) -> None:
        """Mirror every finding through the shared JSON-lines event log
        (set ``REPRO_EVENT_LOG=path`` to capture; see obs/log.py)."""
        for f in self.findings:
            log_event("analysis_finding", rule=f.rule, severity=f.severity,
                      program=f.program, location=f.location,
                      message=f.message)
        log_event("analysis_report", programs=len(self.programs_checked),
                  errors=len(self.errors), warnings=len(self.warnings))

    def summary(self) -> str:
        verdict = "PASS" if self.ok() else "FAIL"
        return (f"analysis: {verdict} -- {len(self.programs_checked)} "
                f"program(s), {len(self.errors)} error(s), "
                f"{len(self.warnings)} warning(s)")


def finding_or_none(condition: bool, finding: Finding) -> Optional[Finding]:
    """Tiny helper: ``finding`` if ``condition`` else None (filter-friendly)."""
    return finding if condition else None
