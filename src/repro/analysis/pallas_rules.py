"""Pallas kernel lint, driven by the kernels' own launch descriptors.

Every kernel in :mod:`repro.kernels` builds its ``pallas_call`` from a
:class:`repro.kernels.launch_spec.KernelLaunch`; this module lints that
same descriptor, so the checks can never drift from what actually
launches.  Crucially the BlockSpec index maps in a descriptor are plain
Python lambdas -- the lint *evaluates them directly* at every concrete
grid point (substituting worst-case example values for the
scalar-prefetch operands, e.g. the sentinel row id), instead of parsing
``pallas_call`` jaxpr params whose internal layout changes between jax
releases.

Rules:

* ``pallas.oob``      -- an index map selects a block outside its operand
  (an out-of-bounds DMA on real hardware: silent garbage or a fault).
* ``pallas.vmem``     -- estimated VMEM footprint (all tiled blocks
  double-buffered by the pipeline, plus scratch) exceeds the per-platform
  budget.
* ``pallas.alias``    -- an ``input_output_aliases`` entry pairs operands
  of different shape/dtype (or out-of-range indices).
* ``pallas.dma.*``    -- the manual-DMA protocol (``dma_schedule`` twin)
  violates semaphore pairing: start on a busy semaphore, use before
  wait, wait without start, a copy never waited, or a live spike never
  consumed.
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence

from repro.analysis.findings import ERROR, WARNING, Finding
from repro.kernels.launch_spec import KernelLaunch, Operand

__all__ = [
    "TPU_VMEM_BUDGET", "check_index_maps", "check_vmem", "check_aliasing",
    "check_dma_schedule", "check_launch",
]

# ~16 MiB of VMEM per TPU core; the budget the pipeline's working set
# must fit in (see DESIGN.md §14 for the estimator model).
TPU_VMEM_BUDGET = 16 * 1024 * 1024


def _grid_points(grid: Sequence[int]):
    """All concrete grid index tuples (row-major)."""
    points = [()]
    for extent in grid:
        points = [p + (i,) for p in points for i in range(extent)]
    return points


def check_index_maps(launch: KernelLaunch, program: str) -> List[Finding]:
    """Evaluate every BlockSpec index map at every grid point (with the
    worst-case prefetch example) and reject blocks that fall outside
    their operand -- the static form of an out-of-bounds DMA."""
    out: List[Finding] = []
    points = _grid_points(launch.grid)
    for op in launch.tiled_operands():
        bad = _oob_for_operand(op, points, launch.prefetch_example)
        if bad is not None:
            point, idx = bad
            out.append(Finding(
                rule="pallas.oob", severity=ERROR, program=program,
                location=f"{launch.name}:{op.name}",
                message=f"index map selects block {idx} at grid point "
                        f"{point}: exceeds operand shape {op.shape} with "
                        f"block {op.block_shape}"))
    return out


def _oob_for_operand(op: Operand, points, prefetch) -> Optional[Any]:
    assert op.index_map is not None and op.block_shape is not None
    for point in points:
        idx = op.index_map(*point, *prefetch)
        if not isinstance(idx, tuple):
            idx = (idx,)
        idx = tuple(int(i) for i in idx)
        if len(idx) != len(op.block_shape):
            return point, idx
        for i, b, extent in zip(idx, op.block_shape, op.shape):
            if i < 0 or (i + 1) * b > extent:
                return point, idx
    return None


def check_vmem(launch: KernelLaunch, program: str, *,
               budget: int = TPU_VMEM_BUDGET) -> List[Finding]:
    """Estimated peak VMEM (2x every tiled block + scratch) vs budget."""
    est = launch.vmem_bytes()
    out: List[Finding] = []
    if est > budget:
        out.append(Finding(
            rule="pallas.vmem", severity=ERROR, program=program,
            location=launch.name,
            message=f"estimated VMEM {est / 2 ** 20:.2f} MiB exceeds the "
                    f"{budget / 2 ** 20:.0f} MiB budget: shrink blocks"))
    elif est > budget * 0.75:
        out.append(Finding(
            rule="pallas.vmem", severity=WARNING, program=program,
            location=launch.name,
            message=f"estimated VMEM {est / 2 ** 20:.2f} MiB is within "
                    f"25% of the {budget / 2 ** 20:.0f} MiB budget"))
    return out


def check_aliasing(launch: KernelLaunch, program: str) -> List[Finding]:
    """``input_output_aliases`` pairs must exist and agree on shape+dtype
    (an aliased buffer is reused in place: a mismatch corrupts memory)."""
    out: List[Finding] = []
    for in_idx, out_idx in launch.input_output_aliases.items():
        loc = f"{launch.name}:alias {in_idx}->{out_idx}"
        if not (0 <= in_idx < len(launch.inputs)
                and 0 <= out_idx < len(launch.outputs)):
            out.append(Finding(
                rule="pallas.alias", severity=ERROR, program=program,
                location=loc, message="alias index out of range"))
            continue
        a, b = launch.inputs[in_idx], launch.outputs[out_idx]
        if a.shape != b.shape or str(a.dtype) != str(b.dtype):
            out.append(Finding(
                rule="pallas.alias", severity=ERROR, program=program,
                location=loc,
                message=f"aliased operands disagree: {a.name} "
                        f"{a.shape}/{a.dtype} vs {b.name} "
                        f"{b.shape}/{b.dtype}"))
    return out


def simulate_dma_schedule(ops, n_slots: int = 2):
    """Run one DMA op list through the semaphore state machine; returns a
    list of (rule, message) violations.

    Model: each buffer slot has one DMA semaphore.  ``start`` puts a copy
    in flight on the slot (illegal while one is already in flight --
    the second completion would double-signal the semaphore and corrupt
    the pairing); ``wait`` consumes the in-flight copy (illegal with
    nothing in flight: deadlock); ``use`` reads the buffer and must see
    exactly the spike the last completed copy delivered.
    """
    in_flight = [None] * n_slots   # spike id being copied into slot
    ready = [None] * n_slots       # spike id whose data sits in slot
    used = set()
    bad = []
    for op_kind, slot, k in ops:
        if not (0 <= slot < n_slots):
            bad.append(("pallas.dma.bad_slot",
                        f"op {op_kind} addresses slot {slot}"))
            continue
        if op_kind == "start":
            if in_flight[slot] is not None:
                bad.append((
                    "pallas.dma.start_busy",
                    f"start(spike {k}) on slot {slot} while spike "
                    f"{in_flight[slot]}'s copy is still in flight"))
            in_flight[slot] = k
        elif op_kind == "wait":
            if in_flight[slot] is None:
                bad.append(("pallas.dma.wait_without_start",
                            f"wait on slot {slot} with no copy in flight"))
            else:
                ready[slot] = in_flight[slot]
                in_flight[slot] = None
        elif op_kind == "use":
            if ready[slot] != k:
                have = ("in-flight (use before wait)"
                        if in_flight[slot] == k else
                        f"holds {ready[slot]}")
                bad.append(("pallas.dma.use_before_wait",
                            f"use(spike {k}) on slot {slot} but buffer "
                            f"{have}"))
            used.add(k)
        else:
            bad.append(("pallas.dma.bad_op", f"unknown op {op_kind!r}"))
    for slot, k in enumerate(in_flight):
        if k is not None:
            bad.append(("pallas.dma.dangling",
                        f"copy of spike {k} into slot {slot} never "
                        f"waited on"))
    return bad, used


def check_dma_schedule(launch: KernelLaunch, program: str, *,
                       max_live: int = 8) -> List[Finding]:
    """Simulate the kernel's manual-DMA protocol for every live-spike
    count up to ``max_live`` (plus 0: the quiet-row case must issue no
    DMA at all)."""
    if launch.dma_schedule is None:
        return []
    out: List[Finding] = []
    for nb in range(max_live + 1):
        ops = launch.dma_schedule(nb)
        bad, used = simulate_dma_schedule(ops)
        for rule, msg in bad:
            out.append(Finding(
                rule=rule, severity=ERROR, program=program,
                location=f"{launch.name}:nb={nb}", message=msg))
        missing = set(range(nb)) - used
        if missing:
            out.append(Finding(
                rule="pallas.dma.missing_spike", severity=ERROR,
                program=program, location=f"{launch.name}:nb={nb}",
                message=f"live spikes {sorted(missing)} never accumulated "
                        f"-- silent spike drop"))
        if nb == 0 and ops:
            out.append(Finding(
                rule="pallas.dma.quiet_row", severity=ERROR,
                program=program, location=f"{launch.name}:nb=0",
                message="quiet row issues DMA ops: the zero-cost-silence "
                        "contract is broken"))
    return out


def check_launch(launch: KernelLaunch, program: str, *,
                 vmem_budget: int = TPU_VMEM_BUDGET) -> List[Finding]:
    """All kernel-lint rules on one launch descriptor."""
    out = check_index_maps(launch, program)
    out += check_vmem(launch, program, budget=vmem_budget)
    out += check_aliasing(launch, program)
    out += check_dma_schedule(launch, program)
    return out
