"""GQA attention with head-aligned tensor parallelism.

Sharding design (EXPERIMENTS.md §Perf iteration 1):

* **Q side**: projection columns are padded to ``head_pad`` (= TP width, 16)
  whole heads -- ``Hqp = ceil(Hq/16)*16`` -- so the flat->heads reshape is
  always shard-aligned (each model shard owns ``Hqp/16`` complete heads).
  Dead pad heads are hard-masked after attention (exact semantics; their
  FLOPs show up honestly in the roofline's useful-ratio). Without this,
  GSPMD hits "involuntary full rematerialization" on the misaligned
  reshape and replicates multi-GB activations per layer (measured: 2.1 TB
  of all-reduce per device on llama4 prefill_32k, 16x attention FLOP
  waste on smollm -- see EXPERIMENTS.md before/after).

* **KV side**: every assigned arch has kv_heads < 16, so KV is never
  TP-sharded. K/V projections are small and computed *replicated* on each
  model shard (zero communication); each q head gathers its kv head
  locally via a constant index map (GQA grouping).

* **KV cache**: stored flat (B, S, Hkv*Dh) and sharded along **kv_seq**
  (flash-decoding style): decode computes shard-local partial attention
  over its sequence slice; the softmax reduction and PV combine are
  tiny cross-shard collectives (B x Hq x Dh scale, not cache scale).

Memory discipline: for q_len > ``Q_CHUNK`` a ``lax.scan`` over query
chunks bounds the transient score matrix at (chunk x S) per head.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import Spec, apply_rope, rms_norm
from repro.parallel.sharding import constrain

Q_CHUNK = 512
NEG_INF = -1e30


def padded_q_heads(cfg: ModelConfig) -> int:
    pad = max(1, cfg.head_pad)
    return -(-cfg.n_heads // pad) * pad


def head_maps(cfg: ModelConfig) -> Tuple[np.ndarray, np.ndarray]:
    """(head_to_kv index map, live-head mask) over padded q heads."""
    hqp = padded_q_heads(cfg)
    g = max(1, cfg.n_heads // cfg.n_kv_heads)
    to_kv = np.asarray(
        [min(h // g, cfg.n_kv_heads - 1) for h in range(hqp)], np.int32)
    mask = np.asarray([1.0 if h < cfg.n_heads else 0.0 for h in range(hqp)],
                      np.float32)
    return to_kv, mask


def attn_specs(cfg: ModelConfig, *, cross: bool = False) -> Dict[str, Spec]:
    d, hkv, dh = cfg.d_model, cfg.n_kv_heads, cfg.d_head
    hqp = padded_q_heads(cfg)
    s = {
        "ln": Spec((d,), ("norm",), "ones"),
        "wq": Spec((d, hqp * dh), ("qkv_in", "q_heads")),
        "wk": Spec((d, hkv, dh), ("qkv_in", None, None)),
        "wv": Spec((d, hkv, dh), ("qkv_in", None, None)),
        "wo": Spec((hqp * dh, d), ("q_heads", "qkv_in")),
    }
    if cfg.qk_norm:
        s["q_norm"] = Spec((dh,), ("norm",), "ones")
        s["k_norm"] = Spec((dh,), ("norm",), "ones")
    return s


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, H_kv * Dh)  -- flat, kv_seq-sharded
    v: jax.Array


def _project_q(x, p, cfg: ModelConfig, positions, *, shard_heads: bool):
    b, sq = x.shape[0], x.shape[1]
    hqp, dh = padded_q_heads(cfg), cfg.d_head
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    if shard_heads:
        q = constrain(q, "batch", None, "act_heads")
    q = q.reshape(b, sq, hqp, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
    if cfg.pos_embed == "rope" and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
    return q


def _project_kv(x, p, cfg: ModelConfig, kv_positions):
    """Replicated (per model shard) K/V projection; (B, T, Hkv, Dh)."""
    k = jnp.einsum("btd,dhn->bthn", x, p["wk"])
    v = jnp.einsum("btd,dhn->bthn", x, p["wv"])
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"])
    if cfg.pos_embed == "rope" and kv_positions is not None:
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    return k, v


def _expand_kv(k: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Gather each (padded) q head's kv head: (B,T,Hkv,Dh) -> (B,T,Hqp,Dh).

    A local take along the (replicated) head axis -- no communication.
    """
    to_kv, _ = head_maps(cfg)
    return jnp.take(k, jnp.asarray(to_kv), axis=2)


def _mask_heads(out: jax.Array, cfg: ModelConfig) -> jax.Array:
    _, mask = head_maps(cfg)
    if mask.min() >= 1.0:
        return out
    return out * jnp.asarray(mask, out.dtype)[None, None, :, None]


def _sdpa(q, ke, ve, *, causal: bool, q_offset) -> jax.Array:
    """q, ke, ve: (B, *, Hqp, Dh) -- kv already expanded to q heads."""
    b, sq, hqp, dh = q.shape
    t = ke.shape[1]
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bshd,bthd->bhst", q, ke).astype(jnp.float32) * scale
    if causal:
        qpos = q_offset + jnp.arange(sq)
        kpos = jnp.arange(t)
        mask = kpos[None, :] <= qpos[:, None]            # (sq, t)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(ve.dtype)
    return jnp.einsum("bhst,bthd->bshd", w, ve)


def _sdpa_chunked(q, ke, ve, *, causal: bool) -> jax.Array:
    """lax.scan over query chunks; transient score memory = chunk x T."""
    b, sq, hqp, dh = q.shape
    n_chunks = sq // Q_CHUNK
    assert sq % Q_CHUNK == 0, f"seq {sq} not divisible by q-chunk {Q_CHUNK}"
    qc = q.reshape(b, n_chunks, Q_CHUNK, hqp, dh).transpose(1, 0, 2, 3, 4)

    def body(_, args):
        i, q_i = args
        o = _sdpa(q_i, ke, ve, causal=causal, q_offset=i * Q_CHUNK)
        return None, o

    _, out = jax.lax.scan(body, None, (jnp.arange(n_chunks), qc))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, hqp, dh)


def self_attention(
    x: jax.Array,
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: Optional[KVCache] = None,
    cache_pos=None,
) -> Tuple[jax.Array, Optional[KVCache]]:
    """Pre-norm residual self-attention sublayer.

    Train/prefill: ``cache is None`` -> causal attention over x itself
    (returns fresh flat K/V as a cache when ``cache_pos == 'prefill'``).
    Decode: ``cache`` given, x is (B, q_len, D) at position ``cache_pos``.
    """
    b = x.shape[0]
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    hqp = padded_q_heads(cfg)
    h = rms_norm(x, p["ln"])
    h = constrain(h, "batch", "seq", "embed")

    if cache is None or cache_pos == "prefill":
        q = _project_q(h, p, cfg, positions, shard_heads=True)
        k, v = _project_kv(h, p, cfg, positions)
        ke, ve = _expand_kv(k, cfg), _expand_kv(v, cfg)
        sq = q.shape[1]
        if sq > Q_CHUNK:
            out = _sdpa_chunked(q, ke, ve, causal=True)
        else:
            out = _sdpa(q, ke, ve, causal=True, q_offset=0)
        new_cache = None
        if cache_pos == "prefill":
            k_flat = constrain(k.reshape(b, sq, hkv * dh), "batch", "kv_seq", None)
            v_flat = constrain(v.reshape(b, sq, hkv * dh), "batch", "kv_seq", None)
            new_cache = KVCache(k=k_flat, v=v_flat)
    else:
        # Decode: q is tiny -> replicated over model; cache is kv_seq-sharded.
        q = _project_q(h, p, cfg, positions, shard_heads=False)
        k_new, v_new = _project_kv(h, p, cfg, positions)
        q_len = q.shape[1]
        k_flat = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.reshape(b, q_len, hkv * dh).astype(cache.k.dtype),
            cache_pos, axis=1)
        v_flat = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.reshape(b, q_len, hkv * dh).astype(cache.v.dtype),
            cache_pos, axis=1)
        k_flat = constrain(k_flat, "batch", "kv_seq", None)
        v_flat = constrain(v_flat, "batch", "kv_seq", None)
        t = k_flat.shape[1]
        ke = _expand_kv(k_flat.reshape(b, t, hkv, dh), cfg)
        ve = _expand_kv(v_flat.reshape(b, t, hkv, dh), cfg)
        kpos = jnp.arange(t)
        valid = jnp.broadcast_to(kpos[None, :] <= cache_pos + q_len - 1, (b, t))
        out = _decode_sdpa(q, ke, ve, valid)
        new_cache = KVCache(k=k_flat, v=v_flat)

    out = _mask_heads(out, cfg)
    out = out.reshape(b, -1, hqp * dh)
    if cache is None or cache_pos == "prefill":
        out = constrain(out, "batch", None, "act_heads")
    y = jnp.einsum("bse,ed->bsd", out, p["wo"])
    y = constrain(y, "batch", "seq", "embed")
    return x + y, new_cache


def _decode_sdpa(q, ke, ve, valid) -> jax.Array:
    """q: (B, q_len, Hqp, Dh) vs kv_seq-sharded expanded cache."""
    b, sq, hqp, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bshd,bthd->bhst", q, ke).astype(jnp.float32) * scale
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(ve.dtype)
    return jnp.einsum("bhst,bthd->bshd", w, ve)


def cross_attention(
    x: jax.Array,
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    kv_cache: KVCache,
) -> jax.Array:
    """Cross-attention over precomputed (cached) flat vision K/V."""
    b, sq = x.shape[0], x.shape[1]
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    hqp = padded_q_heads(cfg)
    h = rms_norm(x, p["ln"])
    q = _project_q(h, p, cfg, None, shard_heads=True)
    t = kv_cache.k.shape[1]
    ke = _expand_kv(kv_cache.k.reshape(b, t, hkv, dh), cfg)
    ve = _expand_kv(kv_cache.v.reshape(b, t, hkv, dh), cfg)
    out = _sdpa(q, ke, ve, causal=False, q_offset=0)
    out = _mask_heads(out, cfg)
    out = out.reshape(b, sq, hqp * dh)
    y = jnp.einsum("bse,ed->bsd", out, p["wo"])
    return x + y


def project_vision_kv(vision_proj: jax.Array, p: Dict[str, jax.Array],
                      cfg: ModelConfig) -> KVCache:
    """Project (already d_model-projected) vision tokens to flat K/V."""
    b, t = vision_proj.shape[0], vision_proj.shape[1]
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    k, v = _project_kv(vision_proj, p, cfg, None)
    return KVCache(k=k.reshape(b, t, hkv * dh), v=v.reshape(b, t, hkv * dh))
