"""Top-level model: embed -> stages -> norm -> head, plus step builders.

Public surface used by the launcher, dry-run, tests and benchmarks:

  specs(cfg)                      parameter Spec tree
  init(cfg, key)                  materialized params
  loss_fn(params, cfg, batch)     train NLL (+ MoE aux)
  prefill_fn / decode_fn          serving steps with KV/SSM caches
  make_cache_specs(cfg, shape)    cache Spec tree for AOT lowering
  input_specs(cfg, shape)         ShapeDtypeStruct batch stand-ins
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf
from repro.models.common import Spec, cross_entropy, init_params, param_count, rms_norm, sinusoidal_pos_embed, zeros_params
from repro.parallel.sharding import constrain


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# specs

def specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    s: Dict[str, Any] = {}
    if cfg.family == "audio":
        s["embed"] = Spec((cfg.n_codebooks, v, d), ("codebooks", "vocab", "embed_param"))
        s["lm_head"] = Spec((d, cfg.n_codebooks, v), ("embed_param", "codebooks", "vocab"))
    else:
        s["embed"] = Spec((v, d), ("vocab", "embed_param"))
        if not cfg.tie_embeddings:
            s["lm_head"] = Spec((d, v), ("embed_param", "vocab"))
    if cfg.family == "vlm":
        s["vision_proj"] = Spec((cfg.d_vision, d), ("vision_embed", "embed_param"))
    s["stages"] = tf.stack_stage_specs(cfg)
    s["final_ln"] = Spec((d,), ("norm",), "ones")
    return s


def init(cfg: ModelConfig, key: jax.Array):
    return init_params(specs(cfg), key, dtype_of(cfg))


def n_params(cfg: ModelConfig) -> int:
    return param_count(specs(cfg))


# ---------------------------------------------------------------------------
# forward

def _embed(params, cfg: ModelConfig, tokens: jax.Array, positions: jax.Array) -> jax.Array:
    if cfg.family == "audio":
        # tokens: (B, S, K); sum the K codebook embeddings (MusicGen).
        x = jnp.take(params["embed"][0], tokens[..., 0], axis=0)
        for kb in range(1, cfg.n_codebooks):
            x = x + jnp.take(params["embed"][kb], tokens[..., kb], axis=0)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.pos_embed == "sinusoidal":
        x = x + sinusoidal_pos_embed(positions, cfg.d_model).astype(x.dtype)
    return constrain(x, "batch", "seq", "embed")


def _head(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_ln"])
    if cfg.family == "audio":
        logits = jnp.einsum("bsd,dkv->bskv", x, params["lm_head"])
    elif cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return constrain(logits, *(("batch", "seq", None, "act_vocab")
                               if cfg.family == "audio"
                               else ("batch", "seq", "act_vocab")))


def forward(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    mode: str,
    positions: Optional[jax.Array] = None,
    cache_pos=None,
    caches=None,
    vision_embeds: Optional[jax.Array] = None,
    remat: str = "block",
):
    """Returns (logits, new_caches, aux)."""
    b = tokens.shape[0]
    s = tokens.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _embed(params, cfg, tokens, positions)
    vision_proj = None
    if cfg.family == "vlm" and vision_embeds is not None:
        vision_proj = jnp.einsum("bnd,de->bne", vision_embeds, params["vision_proj"])
        vision_proj = constrain(vision_proj, "batch", "vision_seq", "embed")
    x, new_caches, aux = tf.apply_stages(
        x, params["stages"], cfg,
        mode=mode, positions=positions, cache_pos=cache_pos,
        caches=caches, vision_proj=vision_proj, remat=remat,
    )
    logits = _head(params, cfg, x)
    return logits, new_caches, aux


# ---------------------------------------------------------------------------
# step functions

def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            remat: str = "block") -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, _, aux = forward(
        params, cfg, batch["inputs"], mode="train",
        vision_embeds=batch.get("vision_embeds"), remat=remat)
    nll = cross_entropy(logits, batch["targets"])
    loss = nll + cfg.router_aux_weight * aux
    return loss, {"nll": nll, "router_aux": aux}


def prefill_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array], caches):
    """Process a full prompt, fill caches; returns (last-token logits, caches)."""
    logits, new_caches, _ = forward(
        params, cfg, batch["inputs"], mode="prefill",
        caches=caches, vision_embeds=batch.get("vision_embeds"), remat="none")
    return logits[:, -1], new_caches


def decode_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array], caches):
    """One decode step: new token at position ``pos`` against full caches."""
    pos = batch["pos"]  # scalar int32
    b = batch["token"].shape[0]
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    logits, new_caches, _ = forward(
        params, cfg, batch["token"], mode="decode",
        positions=positions, cache_pos=pos, caches=caches, remat="none")
    return logits[:, -1], new_caches


# ---------------------------------------------------------------------------
# cache + input specs

def make_cache_specs(cfg: ModelConfig, batch: int, s_max: int):
    return tf.cache_specs(cfg, batch, s_max)


def init_cache(cfg: ModelConfig, batch: int, s_max: int):
    return zeros_params(make_cache_specs(cfg, batch, s_max), dtype_of(cfg))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Spec]:
    """Input Spec tree for one (arch, shape) cell (dry-run stand-ins)."""
    b, s = shape.global_batch, shape.seq_len
    tok_axes = ("batch", "seq")
    if shape.kind == "train":
        if cfg.family == "audio":
            io = {
                "inputs": Spec((b, s, cfg.n_codebooks), tok_axes + (None,), dtype="int32"),
                "targets": Spec((b, s, cfg.n_codebooks), tok_axes + (None,), dtype="int32"),
            }
        else:
            io = {
                "inputs": Spec((b, s), tok_axes, dtype="int32"),
                "targets": Spec((b, s), tok_axes, dtype="int32"),
            }
        if cfg.family == "vlm":
            io["vision_embeds"] = Spec(
                (b, cfg.n_vision_tokens, cfg.d_vision),
                ("batch", "vision_seq", "vision_embed"), dtype=cfg.dtype)
        return io
    if shape.kind == "prefill":
        if cfg.family == "audio":
            io = {"inputs": Spec((b, s, cfg.n_codebooks), tok_axes + (None,), dtype="int32")}
        else:
            io = {"inputs": Spec((b, s), tok_axes, dtype="int32")}
        if cfg.family == "vlm":
            io["vision_embeds"] = Spec(
                (b, cfg.n_vision_tokens, cfg.d_vision),
                ("batch", "vision_seq", "vision_embed"), dtype=cfg.dtype)
        return io
    if shape.kind == "decode":
        tok_shape = (b, 1, cfg.n_codebooks) if cfg.family == "audio" else (b, 1)
        tok_ax = ("batch", "seq", None) if cfg.family == "audio" else ("batch", "seq")
        return {
            "token": Spec(tok_shape, tok_ax, dtype="int32"),
            "pos": Spec((), (), dtype="int32"),
        }
    raise ValueError(shape.kind)
