"""Shared model machinery: param specs, init, norms, rotary embeddings.

Parameters are plain pytrees (nested dicts of arrays). Each leaf is
described once by a :class:`Spec` carrying shape, *logical axes* (for
sharding) and init style; ``init_params`` and ``logical_axes`` both derive
from the same spec tree, so sharding annotations can never drift from the
parameter structure.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp



@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones | small
    scale: float = 1.0         # fan-in override multiplier
    dtype: Optional[str] = None  # override model dtype (e.g. f32 SSM states)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape/axes rank mismatch: {self.shape} vs {self.axes}")


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def init_params(specs, key: jax.Array, dtype=jnp.bfloat16):
    """Materialize a spec tree. Weight init: truncated-normal style
    1/sqrt(fan_in) (fan_in = product of all but the last dim)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        leaf_dtype = spec.dtype or dtype
        if spec.init == "zeros":
            a = jnp.zeros(spec.shape, leaf_dtype)
        elif spec.init == "ones":
            a = jnp.ones(spec.shape, leaf_dtype)
        else:
            fan_in = max(1, math.prod(spec.shape[:-1]) if len(spec.shape) > 1 else spec.shape[0])
            std = spec.scale / math.sqrt(fan_in)
            if spec.init == "small":
                std = spec.scale * 0.02
            a = (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(leaf_dtype)
        out.append(a)
    return jax.tree.unflatten(treedef, out)


def zeros_params(specs, dtype=jnp.bfloat16):
    """All-zeros materialization (cache init)."""
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype or dtype)),
        specs, is_leaf=is_spec)


def shape_structs(specs, dtype=jnp.bfloat16, rules=None):
    """ShapeDtypeStructs (+ shardings if rules given) for AOT lowering."""
    def mk(s: Spec):
        sharding = rules.sharding(s.axes) if rules is not None else None
        return jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or dtype), sharding=sharding)
    return jax.tree.map(mk, specs, is_leaf=is_spec)


def stack_specs(specs, n: int, axis_name: Optional[str] = "groups"):
    """Prepend a stacking dim (for scan-over-groups) to every leaf spec."""
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale, s.dtype),
        specs, is_leaf=is_spec)


def logical_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def shapes_of(specs):
    return jax.tree.map(lambda s: s.shape, specs, is_leaf=is_spec)


def param_count(specs) -> int:
    return sum(math.prod(s.shape) for s in jax.tree.leaves(specs, is_leaf=is_spec))


# ---------------------------------------------------------------------------
# numerics


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, d_head); positions: (..., seq) int32."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                      # (d_head/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, d/2)
    cos = jnp.cos(angles)[..., None, :]                    # (..., seq, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_embed(positions: jax.Array, d_model: int) -> jax.Array:
    """MusicGen-style absolute sinusoidal embedding; positions (..., seq)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL; logits in any float dtype (softmax in f32)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)
