"""FFN sublayers: SwiGLU dense + top-k MoE with capacity-based dispatch.

The MoE dispatch *is* the paper's connection-list idea at LM scale: the
router writes a (token -> expert) gating mask at runtime, and dispatch is
a masked einsum against that mask -- compute flows only where the
"connection list" routes it, and reconfiguring the routing (new router
weights / new mask) never recompiles the program. DESIGN.md §5.

Dispatch follows GShard/MaxText: tokens are split into groups of
``group_tokens``; each expert accepts ``capacity = top_k * group_tokens *
capacity_factor / n_experts`` tokens per group (overflow dropped). The
one-hot dispatch tensor is (G, T_g, E, C) -- group size bounds its memory.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Spec, rms_norm, silu
from repro.parallel.sharding import constrain

MOE_GROUP_TOKENS = 512


def dense_ffn_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, Spec]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    s = {
        "ln": Spec((d,), ("norm",), "ones"),
        "w_up": Spec((d, f), ("mlp_in", "mlp")),
        "w_down": Spec((f, d), ("mlp", "mlp_in")),
    }
    if cfg.ffn_act == "swiglu":
        s["w_gate"] = Spec((d, f), ("mlp_in", "mlp"))
    return s


def dense_ffn(x: jax.Array, p: Dict[str, jax.Array]) -> jax.Array:
    h = rms_norm(x, p["ln"])
    h = constrain(h, "batch", "seq", "embed")
    u = jnp.einsum("bsd,df->bsf", h, p["w_up"])
    if "w_gate" in p:  # SwiGLU
        g = jnp.einsum("bsd,df->bsf", h, p["w_gate"])
        a = silu(g) * u
    else:              # non-gated GELU (starcoder2)
        a = jax.nn.gelu(u, approximate=True)
    a = constrain(a, "batch", None, "act_mlp")
    y = jnp.einsum("bsf,fd->bsd", a, p["w_down"])
    return x + constrain(y, "batch", "seq", "embed")


def moe_ffn_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    s = {
        "ln": Spec((d,), ("norm",), "ones"),
        "router": Spec((d, e), (None, "experts"), "small"),
        "w_gate": Spec((e, d, f), ("experts", "expert_in", "expert_mlp")),
        "w_up": Spec((e, d, f), ("experts", "expert_in", "expert_mlp")),
        "w_down": Spec((e, f, d), ("experts", "expert_mlp", "expert_in")),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        s["shared"] = {
            "w_gate": Spec((d, fs), ("mlp_in", "mlp")),
            "w_up": Spec((d, fs), ("mlp_in", "mlp")),
            "w_down": Spec((fs, d), ("mlp", "mlp_in")),
        }
    return s


DECODE_CAPACITY_FACTOR = 4.0  # serving headroom: dropless in practice


def _capacity(cfg: ModelConfig, group_tokens: int, cap_factor: float) -> int:
    c = int(math.ceil(cfg.top_k * group_tokens * cap_factor / cfg.n_experts))
    return max(c, cfg.top_k)


def moe_ffn(
    x: jax.Array, p: Dict[str, jax.Array], cfg: ModelConfig,
    cap_factor: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux load-balance loss)."""
    b, s, d = x.shape
    h = rms_norm(x, p["ln"])

    t_total = b * s
    g_tok = min(MOE_GROUP_TOKENS, t_total)
    assert t_total % g_tok == 0, f"tokens {t_total} not divisible by group {g_tok}"
    n_groups = t_total // g_tok
    e = cfg.n_experts
    cap = _capacity(cfg, g_tok, cap_factor or cfg.capacity_factor)

    ht = h.reshape(n_groups, g_tok, d)
    ht = constrain(ht, "batch", None, "embed")
    logits = jnp.einsum("gtd,de->gte", ht, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)          # (G, T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Reduce the K claims to per-(token, expert) masks first (a token picks
    # each expert at most once) so no (T, K, E, C) tensor ever exists.
    onehot_k = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)      # (G, T, K, E)
    expert_mask = onehot_k.sum(axis=2)                               # (G, T, E) in {0,1}
    gate_e = (onehot_k * gate_vals[..., None]).sum(axis=2)           # (G, T, E)
    # Slot of token t in expert e's capacity buffer (token-index order).
    pos = jnp.cumsum(expert_mask, axis=1) - expert_mask              # (G, T, E)
    # one_hot of pos >= cap is all-zeros -> overflow tokens drop out.
    slot = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=x.dtype)  # (G, T, E, C)
    dispatch = slot * expert_mask.astype(x.dtype)[..., None]         # (G, T, E, C)
    combine = dispatch * gate_e.astype(x.dtype)[..., None]

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, ht)                  # (G, E, C, D)
    xe = constrain(xe, "batch", "experts", None, "embed")
    gg = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
    uu = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", silu(gg) * uu, p["w_down"])
    ye = constrain(ye, "batch", "experts", None, "embed")
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)
    y = y.reshape(b, s, d)

    if "shared" in p:
        sh = p["shared"]
        g2 = jnp.einsum("bsd,df->bsf", h, sh["w_gate"])
        u2 = jnp.einsum("bsd,df->bsf", h, sh["w_up"])
        y = y + jnp.einsum("bsf,fd->bsd", silu(g2) * u2, sh["w_down"])

    # Load-balance aux (Switch): E * sum_e f_e * p_e.
    frac = expert_mask.mean(axis=(0, 1))                             # fraction routed
    prob = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(frac * prob)
    return x + constrain(y, "batch", "seq", "embed"), aux
