"""Mamba-1 block (Jamba's SSM layer): selective scan with chunked rollout.

The selective state update ``h' = exp(dt*A) h + dt*B*x`` is the same
shape of computation as the paper's LIF membrane update (input-conditioned
decay + drive; DESIGN.md §5) and shares the discrete-time scan substrate.

Memory strategy: the scan over time nests (outer chunks x inner steps) with
the inner chunk body checkpointed, so a layer's forward keeps only
chunk-boundary states; under block-level remat even those are recomputed in
backward. Decode carries (conv_state, h) explicitly.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Spec, rms_norm, silu
from repro.parallel.sharding import constrain

SSM_CHUNK = 256


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def mamba_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    d, di, n, k = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv
    r = dt_rank(cfg)
    return {
        "ln": Spec((d,), ("norm",), "ones"),
        "in_proj_x": Spec((d, di), ("mlp_in", "d_inner")),
        "in_proj_z": Spec((d, di), ("mlp_in", "d_inner")),
        "conv_w": Spec((k, di), ("d_conv", "d_inner")),
        "conv_b": Spec((di,), ("d_inner",), "zeros"),
        "x_proj_dt": Spec((di, r), ("d_inner", None)),
        "x_proj_b": Spec((di, n), ("d_inner", "d_state")),
        "x_proj_c": Spec((di, n), ("d_inner", "d_state")),
        "dt_proj": Spec((r, di), (None, "d_inner")),
        "dt_bias": Spec((di,), ("d_inner",), "zeros"),
        "a_log": Spec((di, n), ("d_inner", "d_state"), "ones"),
        "d_skip": Spec((di,), ("d_inner",), "ones"),
        "out_proj": Spec((di, d), ("d_inner", "mlp_in")),
    }


class MambaState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, d_inner) trailing inputs
    h: jax.Array     # (B, d_inner, d_state) f32


def init_mamba_state(batch: int, cfg: ModelConfig, dtype) -> MambaState:
    return MambaState(
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        h=jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
    )


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prepend: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv1d. x: (B, S, di); w: (k, di)."""
    k = w.shape[0]
    pad = prepend if prepend is not None else jnp.zeros(
        (x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+k-1, di)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def _selective_scan(
    h0: jax.Array, dt: jax.Array, bmat: jax.Array, cmat: jax.Array,
    xc: jax.Array, a: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Fused selective scan; never materializes (B, S, di, n).

    Per step: ``h = exp(dt*A) h + (dt*x) B_t``; ``y = <h, C_t>``.
    Args (time-major f32): dt, xc: (S, B, di); bmat, cmat: (S, B, n);
    a: (di, n); h0: (B, di, n). Returns (ys (S, B, di) f32, h_T).
    Nested chunked scan: the checkpointed inner chunk keeps only
    chunk-boundary carries live in the forward.
    """
    s = dt.shape[0]
    chunk = min(SSM_CHUNK, s)
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    n_chunks = s // chunk
    rs = lambda t: t.reshape((n_chunks, chunk) + t.shape[1:])

    def step(h, args):
        dt_t, b_t, c_t, x_t = args
        decay = jnp.exp(dt_t[..., None] * a)
        h = decay * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("ben,bn->be", h, c_t)
        return h, y

    @jax.checkpoint
    def chunk_body(h, args):
        return jax.lax.scan(step, h, args)

    hT, ys = jax.lax.scan(chunk_body, h0, (rs(dt), rs(bmat), rs(cmat), rs(xc)))
    return ys.reshape((s,) + ys.shape[2:]), hT


def mamba_block(
    x: jax.Array,
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    state: Optional[MambaState] = None,
    return_state: bool = False,
) -> Tuple[jax.Array, Optional[MambaState]]:
    """Pre-norm residual Mamba sublayer.

    Train/prefill: state None (zeros) unless resuming; full-sequence scan.
    Decode: x is (B, 1, D) and ``state`` carries (conv, h).
    """
    bsz, s, d = x.shape
    h_in = rms_norm(x, p["ln"])
    h_in = constrain(h_in, "batch", "seq", "embed")
    xi = jnp.einsum("bsd,de->bse", h_in, p["in_proj_x"])
    z = jnp.einsum("bsd,de->bse", h_in, p["in_proj_z"])
    xi = constrain(xi, "batch", None, "d_inner")

    prepend = state.conv if state is not None else None
    xc = silu(_causal_conv(xi, p["conv_w"], p["conv_b"], prepend))

    dt = jnp.einsum("bse,er->bsr", xc, p["x_proj_dt"])
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt, p["dt_proj"]) + p["dt_bias"])
    bmat = jnp.einsum("bse,en->bsn", xc, p["x_proj_b"]).astype(jnp.float32)
    cmat = jnp.einsum("bse,en->bsn", xc, p["x_proj_c"]).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                     # (di, n)

    dtf = dt.astype(jnp.float32)
    xcf = xc.astype(jnp.float32)

    h0 = state.h if state is not None else jnp.zeros(
        (bsz, cfg.d_inner, cfg.d_state), jnp.float32)

    if s == 1:
        decay0 = jnp.exp(dtf[:, 0, :, None] * a)
        hT = decay0 * h0 + (dtf[:, 0] * xcf[:, 0])[..., None] * bmat[:, 0, None, :]
        y = jnp.einsum("ben,bn->be", hT, cmat[:, 0])[:, None]        # (B,1,di)
    else:
        ys, hT = _selective_scan(
            h0, dtf.transpose(1, 0, 2), bmat.transpose(1, 0, 2),
            cmat.transpose(1, 0, 2), xcf.transpose(1, 0, 2), a)
        y = ys.transpose(1, 0, 2)                                    # (B,S,di)
    y = y.astype(x.dtype) + p["d_skip"] * xc
    y = y * silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    out = constrain(out, "batch", "seq", "embed")

    new_state = None
    if return_state:
        conv_tail_src = jnp.concatenate(
            [state.conv, xi], axis=1) if state is not None else xi
        pad = cfg.d_conv - 1
        if conv_tail_src.shape[1] < pad:
            conv_tail_src = jnp.concatenate(
                [jnp.zeros((bsz, pad - conv_tail_src.shape[1], cfg.d_inner), xi.dtype),
                 conv_tail_src], axis=1)
        new_state = MambaState(conv=conv_tail_src[:, -pad:], h=hT)
    return x + out, new_state
