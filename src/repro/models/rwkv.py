"""RWKV6 ("Finch") block: data-dependent-decay linear attention.

The WKV recurrence ``S_t = diag(w_t) S_{t-1} + k_t^T v_t`` is an
input-conditioned leaky integrator -- the closest LM-scale analogue of the
paper's LIF membrane dynamics (the learned, data-dependent decay ``w_t``
plays the role of the leak lambda; DESIGN.md §5). It shares the nested
chunked-scan substrate with :mod:`repro.models.ssm`.

Follows arXiv:2404.05892: token-shift with LoRA data-dependent mixing for
(r, k, v, w, g), LoRA decay, per-head bonus ``u``, group-norm over heads.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Spec, rms_norm, silu
from repro.parallel.sharding import constrain

WKV_CHUNK = 256
N_MIX = 5  # r, k, v, w, g


def rwkv_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def rwkv_att_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    d = cfg.d_model
    h, dk = rwkv_heads(cfg), cfg.rwkv_head_dim
    mix, dec = cfg.rwkv_lora_mix, cfg.rwkv_lora_decay
    return {
        "ln": Spec((d,), ("norm",), "ones"),
        "mu_x": Spec((d,), ("norm",), "small"),
        "mu_base": Spec((N_MIX, d), (None, "norm"), "small"),
        "w1": Spec((d, N_MIX * mix), ("mlp_in", "rwkv_lora"), "small"),
        "w2": Spec((N_MIX, mix, d), (None, "rwkv_lora", "norm"), "small"),
        "w0_decay": Spec((d,), ("norm",), "zeros"),
        "wd1": Spec((d, dec), ("mlp_in", "rwkv_lora"), "small"),
        "wd2": Spec((dec, d), ("rwkv_lora", "norm"), "small"),
        "u": Spec((h, dk), ("rwkv_heads", "rwkv_key"), "small"),
        "wr": Spec((d, d), ("mlp_in", "d_inner")),
        "wk": Spec((d, d), ("mlp_in", "d_inner")),
        "wv": Spec((d, d), ("mlp_in", "d_inner")),
        "wg": Spec((d, d), ("mlp_in", "d_inner")),
        "gn_gamma": Spec((d,), ("norm",), "ones"),
        "gn_beta": Spec((d,), ("norm",), "zeros"),
        "wo": Spec((d, d), ("d_inner", "mlp_in")),
    }


def rwkv_ffn_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ln": Spec((d,), ("norm",), "ones"),
        "mu_k": Spec((d,), ("norm",), "small"),
        "mu_r": Spec((d,), ("norm",), "small"),
        "wk": Spec((d, f), ("mlp_in", "mlp")),
        "wv": Spec((f, d), ("mlp", "mlp_in")),
        "wr": Spec((d, d), ("mlp_in", "mlp_in")),
    }


class RWKVState(NamedTuple):
    att_x: jax.Array  # (B, D) last token fed to time-mix
    ffn_x: jax.Array  # (B, D) last token fed to channel-mix
    wkv: jax.Array    # (B, H, dk, dv) f32 state


def init_rwkv_state(batch: int, cfg: ModelConfig, dtype) -> RWKVState:
    h, dk = rwkv_heads(cfg), cfg.rwkv_head_dim
    return RWKVState(
        att_x=jnp.zeros((batch, cfg.d_model), dtype),
        ffn_x=jnp.zeros((batch, cfg.d_model), dtype),
        wkv=jnp.zeros((batch, h, dk, dk), jnp.float32),
    )


def _shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """Token shift: x_{t-1} (zeros / carried state at t=0). x: (B,S,D)."""
    first = prev[:, None, :] if prev is not None else jnp.zeros_like(x[:, :1])
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _wkv_scan(s0, r, k, v, w, u) -> Tuple[jax.Array, jax.Array]:
    """Time-major WKV recurrence; returns (ys (S,B,H,dv), s_T).

    r,k,v,w: (S, B, H, dk) f32 (w already exp(-exp(.)) in (0,1)).
    """
    s_len = r.shape[0]
    chunk = min(WKV_CHUNK, s_len)
    assert s_len % chunk == 0
    n_chunks = s_len // chunk
    rs = lambda t: t.reshape((n_chunks, chunk) + t.shape[1:])

    def step(s, args):
        r_t, k_t, v_t, w_t = args
        kv = k_t[..., None] * v_t[..., None, :]            # (B,H,dk,dv)
        y = jnp.einsum("bhi,bhij->bhj", r_t, s + u * kv)   # u: (1,H,dk,1)
        s = w_t[..., None] * s + kv
        return s, y

    @jax.checkpoint
    def chunk_body(s, args):
        return jax.lax.scan(step, s, args)

    sT, ys = jax.lax.scan(chunk_body, s0, (rs(r), rs(k), rs(v), rs(w)))
    return ys.reshape((s_len,) + ys.shape[2:]), sT


def _group_norm(y: jax.Array, gamma: jax.Array, beta: jax.Array, n_heads: int,
                eps: float = 1e-5) -> jax.Array:
    """Per-head normalization over the head dim. y: (B, S, D)."""
    b, s, d = y.shape
    yh = y.reshape(b, s, n_heads, d // n_heads).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    out = yh.reshape(b, s, d) * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return out


def rwkv_time_mix(
    x: jax.Array,
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    state: Optional[RWKVState] = None,
    return_state: bool = False,
) -> Tuple[jax.Array, Optional[jax.Array], Optional[jax.Array]]:
    """Returns (x + out, new_att_x, new_wkv)."""
    b, s, d = x.shape
    h_n, dk = rwkv_heads(cfg), cfg.rwkv_head_dim
    xn = rms_norm(x, p["ln"])
    xn = constrain(xn, "batch", "seq", "embed")

    xx = _shift(xn, state.att_x if state is not None else None)
    dx = xx - xn
    # Data-dependent mixing (ddlerp): 5 interpolation targets via LoRA.
    lora = jnp.tanh(jnp.einsum("bsd,dm->bsm", xn + dx * p["mu_x"], p["w1"]))
    lora = lora.reshape(b, s, N_MIX, -1)
    deltas = jnp.einsum("bsfm,fmd->bsfd", lora, p["w2"])
    m = xn[:, :, None, :] + dx[:, :, None, :] * (p["mu_base"] + deltas)
    m_r, m_k, m_v, m_w, m_g = [m[:, :, i, :] for i in range(N_MIX)]

    r = jnp.einsum("bsd,de->bse", m_r, p["wr"])
    k = jnp.einsum("bsd,de->bse", m_k, p["wk"])
    v = jnp.einsum("bsd,de->bse", m_v, p["wv"])
    g = silu(jnp.einsum("bsd,de->bse", m_g, p["wg"]))
    # Data-dependent decay (the learned leak): w in (0,1).
    w_raw = p["w0_decay"] + jnp.einsum(
        "bsm,md->bsd", jnp.tanh(jnp.einsum("bsd,dm->bsm", m_w, p["wd1"])), p["wd2"])
    w = jnp.exp(-jnp.exp(w_raw.astype(jnp.float32)))

    hd = lambda t: t.reshape(b, s, h_n, dk)
    rf, kf, vf, wf = (hd(r).astype(jnp.float32), hd(k).astype(jnp.float32),
                      hd(v).astype(jnp.float32), hd(w))
    u = p["u"].astype(jnp.float32)                         # (H, dk)

    s0 = state.wkv if state is not None else jnp.zeros((b, h_n, dk, dk), jnp.float32)
    if s == 1:
        kv = kf[:, 0, :, :, None] * vf[:, 0, :, None, :]
        y = jnp.einsum("bhi,bhij->bhj", rf[:, 0], s0 + u[None, :, :, None] * kv)
        sT = wf[:, 0, ..., None] * s0 + kv
        ys = y[:, None]                                    # (B,1,H,dv)
    else:
        tm = lambda t: t.transpose(1, 0, 2, 3)
        ys_t, sT = _wkv_scan(s0, tm(rf), tm(kf), tm(vf), tm(wf), u[None, :, :, None])
        ys = ys_t.transpose(1, 0, 2, 3)

    y = ys.reshape(b, s, d)
    y = _group_norm(y, p["gn_gamma"], p["gn_beta"], h_n)
    y = (y * g.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, p["wo"])
    out = constrain(out, "batch", "seq", "embed")

    new_att_x = xn[:, -1] if return_state else None
    new_wkv = sT if return_state else None
    return x + out, new_att_x, new_wkv


def rwkv_channel_mix(
    x: jax.Array,
    p: Dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    state_x: Optional[jax.Array] = None,
    return_state: bool = False,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    xn = rms_norm(x, p["ln"])
    xx = _shift(xn, state_x)
    dx = xx - xn
    k_in = xn + dx * p["mu_k"]
    r_in = xn + dx * p["mu_r"]
    k = jnp.einsum("bsd,df->bsf", k_in, p["wk"])
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", r_in, p["wr"])) * kv
    new_x = xn[:, -1] if return_state else None
    return x + out, new_x
