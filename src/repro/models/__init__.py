from repro.models import attention, common, ffn, model, rwkv, ssm, transformer

__all__ = ["attention", "common", "ffn", "model", "rwkv", "ssm", "transformer"]
