"""Generic decoder stack: every assigned architecture is a *stage plan*.

A model is a list of stages; each stage scans over ``n_groups`` identical
groups; a group applies a fixed pattern of layers (mixer + FFN kind). This
single machine expresses:

  dense/audio     1 stage, group = [attn + dense]
  llama4 (MoE)    1 stage, group = [attn + moe(+shared)]
  moonshot        2 stages: [attn + dense] x1, then [attn + moe] x47
  jamba           1 stage of 9 groups x 8 layers (attn at idx 4, mamba
                  elsewhere; MoE at odd indices)
  vlm             1 stage of 20 groups x 5 layers (cross-attn at idx 0)
  rwkv6           1 stage, group = [time-mix + channel-mix]

Scanning over groups keeps the HLO O(group) instead of O(L) -- fast AOT
compiles on the 512-device dry-run mesh -- while the roofline parser
multiplies while-body costs by trip counts (launch/hlo_cost.py).

KV caches / SSM states thread through the scan as per-group xs/ys.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.common import Spec, stack_specs
from repro.parallel.sharding import constrain


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    mixer: str   # attn | cross | mamba | rwkv
    ffn: str     # dense | moe | rwkv | none


@dataclasses.dataclass(frozen=True)
class StagePlan:
    n_groups: int
    layers: Tuple[LayerPlan, ...]


def stage_plans(cfg: ModelConfig) -> List[StagePlan]:
    fam = cfg.family
    if fam in ("dense", "audio"):
        return [StagePlan(cfg.n_layers, (LayerPlan("attn", "dense"),))]
    if fam == "moe":
        stages = []
        if cfg.first_dense_layers:
            stages.append(StagePlan(cfg.first_dense_layers, (LayerPlan("attn", "dense"),)))
        rest = cfg.n_layers - cfg.first_dense_layers
        kind = "moe"
        stages.append(StagePlan(rest, (LayerPlan("attn", kind),)))
        return stages
    if fam == "hybrid":
        g = cfg.group_size
        assert cfg.n_layers % g == 0
        layers = tuple(
            LayerPlan(
                "attn" if i == cfg.attn_index else "mamba",
                "moe" if cfg.is_moe_layer(i) else "dense",
            )
            for i in range(g)
        )
        return [StagePlan(cfg.n_layers // g, layers)]
    if fam == "vlm":
        g = cfg.group_size
        assert cfg.n_layers % g == 0
        layers = tuple(
            LayerPlan("cross" if i == cfg.cross_index else "attn", "dense")
            for i in range(g)
        )
        return [StagePlan(cfg.n_layers // g, layers)]
    if fam == "rwkv":
        return [StagePlan(cfg.n_layers, (LayerPlan("rwkv", "rwkv"),))]
    raise ValueError(f"unknown family {fam!r}")


# ---------------------------------------------------------------------------
# specs


def _layer_specs(cfg: ModelConfig, plan: LayerPlan) -> Dict[str, Any]:
    s: Dict[str, Any] = {}
    if plan.mixer == "attn":
        s["mixer"] = attn.attn_specs(cfg)
    elif plan.mixer == "cross":
        s["mixer"] = attn.attn_specs(cfg, cross=True)
    elif plan.mixer == "mamba":
        s["mixer"] = ssm_mod.mamba_specs(cfg)
    elif plan.mixer == "rwkv":
        s["mixer"] = rwkv_mod.rwkv_att_specs(cfg)
    else:
        raise ValueError(plan.mixer)
    if plan.ffn == "dense":
        s["ffn"] = ffn_mod.dense_ffn_specs(cfg, cfg.d_ff_dense or None)
    elif plan.ffn == "moe":
        s["ffn"] = ffn_mod.moe_ffn_specs(cfg)
    elif plan.ffn == "rwkv":
        s["ffn"] = rwkv_mod.rwkv_ffn_specs(cfg)
    elif plan.ffn != "none":
        raise ValueError(plan.ffn)
    return s


def stack_stage_specs(cfg: ModelConfig) -> List[Dict[str, Any]]:
    out = []
    for stage in stage_plans(cfg):
        layer_specs = {
            f"layer{i}": _layer_specs(cfg, lp) for i, lp in enumerate(stage.layers)
        }
        out.append(stack_specs(layer_specs, stage.n_groups, "groups"))
    return out


# ---------------------------------------------------------------------------
# caches

def _layer_cache_specs(
    cfg: ModelConfig, plan: LayerPlan, batch: int, s_max: int
) -> Optional[Dict[str, Any]]:
    dh, hkv = cfg.d_head, cfg.n_kv_heads
    if plan.mixer in ("attn",):
        # Flat KV, sharded along kv_seq (flash-decoding style) -- never on
        # the head dim (every assigned arch has kv_heads < TP width).
        kv = {
            "k": Spec((batch, s_max, hkv * dh), ("batch", "kv_seq", None), "zeros"),
            "v": Spec((batch, s_max, hkv * dh), ("batch", "kv_seq", None), "zeros"),
        }
        return {"kv": kv}
    if plan.mixer == "cross":
        nv = cfg.n_vision_tokens
        kv = {
            "k": Spec((batch, nv, hkv * dh), ("batch", "vision_seq", None), "zeros"),
            "v": Spec((batch, nv, hkv * dh), ("batch", "vision_seq", None), "zeros"),
        }
        return {"kv": kv}
    if plan.mixer == "mamba":
        return {
            "conv": Spec((batch, cfg.d_conv - 1, cfg.d_inner), ("batch", None, "d_inner"), "zeros"),
            "h": Spec((batch, cfg.d_inner, cfg.d_state), ("batch", "d_inner", "d_state"), "zeros",
                      dtype="float32"),
        }
    if plan.mixer == "rwkv":
        h_n, dk = rwkv_mod.rwkv_heads(cfg), cfg.rwkv_head_dim
        return {
            "att_x": Spec((batch, cfg.d_model), ("batch", "embed"), "zeros"),
            "ffn_x": Spec((batch, cfg.d_model), ("batch", "embed"), "zeros"),
            "wkv": Spec((batch, h_n, dk, dk), ("batch", "rwkv_heads", "rwkv_key", None),
                        "zeros", dtype="float32"),
        }
    return None


def cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> List[Dict[str, Any]]:
    """Spec tree for the decode cache, one entry per stage (stacked)."""
    out = []
    for stage in stage_plans(cfg):
        layer_caches = {}
        for i, lp in enumerate(stage.layers):
            c = _layer_cache_specs(cfg, lp, batch, s_max)
            if c is not None:
                layer_caches[f"layer{i}"] = c
        out.append(stack_specs(layer_caches, stage.n_groups, "groups"))
    return out


# ---------------------------------------------------------------------------
# apply


def _apply_layer(
    x: jax.Array,
    p: Dict[str, Any],
    cfg: ModelConfig,
    plan: LayerPlan,
    *,
    mode: str,
    positions: jax.Array,
    cache_pos,
    cache: Optional[Dict[str, Any]],
    vision_proj: Optional[jax.Array],
) -> Tuple[jax.Array, Optional[Dict[str, Any]], jax.Array]:
    """Returns (x, new_cache_leaf_dict, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[Dict[str, Any]] = None

    if plan.mixer == "attn":
        if mode == "train":
            x, _ = attn.self_attention(x, p["mixer"], cfg, positions=positions)
        elif mode == "prefill":
            x, kv = attn.self_attention(
                x, p["mixer"], cfg, positions=positions, cache_pos="prefill")
            # Write fresh K/V into the fixed-size cache buffer.
            k_buf = jax.lax.dynamic_update_slice_in_dim(
                cache["kv"]["k"], kv.k.astype(cache["kv"]["k"].dtype), 0, axis=1)
            v_buf = jax.lax.dynamic_update_slice_in_dim(
                cache["kv"]["v"], kv.v.astype(cache["kv"]["v"].dtype), 0, axis=1)
            new_cache = {"kv": {"k": k_buf, "v": v_buf}}
        else:  # decode
            kvc = attn.KVCache(k=cache["kv"]["k"], v=cache["kv"]["v"])
            x, kv = attn.self_attention(
                x, p["mixer"], cfg, positions=positions, cache=kvc, cache_pos=cache_pos)
            new_cache = {"kv": {"k": kv.k, "v": kv.v}}
    elif plan.mixer == "cross":
        if mode == "train":
            kv = attn.project_vision_kv(vision_proj, p["mixer"], cfg)
            x = attn.cross_attention(x, p["mixer"], cfg, kv_cache=kv)
        elif mode == "prefill":
            kv = attn.project_vision_kv(vision_proj, p["mixer"], cfg)
            x = attn.cross_attention(x, p["mixer"], cfg, kv_cache=kv)
            new_cache = {"kv": {"k": kv.k.astype(cache["kv"]["k"].dtype),
                                "v": kv.v.astype(cache["kv"]["v"].dtype)}}
        else:
            kv = attn.KVCache(k=cache["kv"]["k"], v=cache["kv"]["v"])
            x = attn.cross_attention(x, p["mixer"], cfg, kv_cache=kv)
            new_cache = {"kv": {"k": kv.k, "v": kv.v}}
    elif plan.mixer == "mamba":
        if mode == "train":
            x, _ = ssm_mod.mamba_block(x, p["mixer"], cfg)
        else:
            st = None
            if mode == "decode":
                st = ssm_mod.MambaState(conv=cache["conv"], h=cache["h"])
            x, new_st = ssm_mod.mamba_block(
                x, p["mixer"], cfg, state=st, return_state=True)
            new_cache = {"conv": new_st.conv, "h": new_st.h}
    elif plan.mixer == "rwkv":
        st = None
        if mode == "decode":
            st = rwkv_mod.RWKVState(
                att_x=cache["att_x"], ffn_x=cache["ffn_x"], wkv=cache["wkv"])
        want_state = mode != "train"
        x, new_att_x, new_wkv = rwkv_mod.rwkv_time_mix(
            x, p["mixer"], cfg, state=st, return_state=want_state)
        x, new_ffn_x = rwkv_mod.rwkv_channel_mix(
            x, p["ffn"], cfg,
            state_x=st.ffn_x if st is not None else None, return_state=want_state)
        if want_state:
            new_cache = {"att_x": new_att_x, "ffn_x": new_ffn_x, "wkv": new_wkv}
        return x, new_cache, aux

    # FFN (rwkv handled above)
    if plan.ffn == "dense":
        x = ffn_mod.dense_ffn(x, p["ffn"])
    elif plan.ffn == "moe":
        # Decode steps get serving capacity headroom (dropless in practice);
        # train/prefill use the GShard capacity factor.
        cap = ffn_mod.DECODE_CAPACITY_FACTOR if mode == "decode" else None
        x, aux = ffn_mod.moe_ffn(x, p["ffn"], cfg, cap_factor=cap)
    return x, new_cache, aux


def apply_stages(
    x: jax.Array,
    stage_params: List[Dict[str, Any]],
    cfg: ModelConfig,
    *,
    mode: str,
    positions: jax.Array,
    cache_pos=None,
    caches: Optional[List[Dict[str, Any]]] = None,
    vision_proj: Optional[jax.Array] = None,
    remat: str = "block",
) -> Tuple[jax.Array, Optional[List[Dict[str, Any]]], jax.Array]:
    """Run all stages; returns (x, new_caches, total_aux)."""
    plans = stage_plans(cfg)
    new_caches: List[Any] = []
    total_aux = jnp.zeros((), jnp.float32)

    for stage, params, cache in zip(
        plans, stage_params, caches if caches is not None else [None] * len(plans)
    ):
        def group_body(carry, xs, _stage=stage):
            h, aux_acc = carry
            p_group, cache_group = xs
            cache_out = {}
            for i, lp in enumerate(_stage.layers):
                name = f"layer{i}"
                c_in = cache_group.get(name) if cache_group is not None else None
                h, c_new, aux = _apply_layer(
                    h, p_group[name], cfg, lp,
                    mode=mode, positions=positions, cache_pos=cache_pos,
                    cache=c_in, vision_proj=vision_proj,
                )
                if c_new is not None:
                    cache_out[name] = c_new
            h = constrain(h, "batch", "seq", "embed")
            return (h, aux_acc + aux), cache_out

        body = group_body
        if mode == "train" and remat != "none":
            policy = None
            if remat == "dots":
                policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            body = jax.checkpoint(group_body, policy=policy, prevent_cse=False)

        xs = (params, cache)
        if cache is None:
            xs = (params, None)
            # scan needs a pytree of arrays; use params-only xs then.
            (x, total_aux), cache_ys = jax.lax.scan(
                lambda c, p_g: body(c, (p_g, None)), (x, total_aux), params)
        else:
            (x, total_aux), cache_ys = jax.lax.scan(body, (x, total_aux), xs)
        new_caches.append(cache_ys)

    return x, (new_caches if caches is not None else None), total_aux
