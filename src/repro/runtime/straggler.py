"""Straggler detection: per-step timing statistics with z-score flagging.

On a pod, per-host step times are gathered by the controller; a host whose
EWMA step time exceeds mean + ``z_threshold`` * std of the fleet is flagged
and (at the job level) drained/replaced. Here the monitor tracks one
process but the math and interface are fleet-shaped: ``observe(host, dt)``.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class HostStats:
    ewma: Optional[float] = None
    n: int = 0

    def update(self, dt: float, alpha: float = 0.2) -> None:
        self.ewma = dt if self.ewma is None else alpha * dt + (1 - alpha) * self.ewma
        self.n += 1


class StragglerMonitor:
    def __init__(self, z_threshold: float = 3.0, min_steps: int = 5):
        self.z_threshold = z_threshold
        self.min_steps = min_steps
        self.hosts: Dict[str, HostStats] = defaultdict(HostStats)

    def observe(self, host: str, step_time_s: float) -> None:
        self.hosts[host].update(step_time_s)

    def fleet_stats(self) -> Tuple[float, float]:
        vals = [h.ewma for h in self.hosts.values() if h.ewma is not None]
        if not vals:
            return 0.0, 0.0
        mean = sum(vals) / len(vals)
        var = sum((v - mean) ** 2 for v in vals) / max(1, len(vals) - 1)
        return mean, math.sqrt(var)

    def stragglers(self) -> List[str]:
        mean, std = self.fleet_stats()
        if std == 0.0:
            return []
        out = []
        for host, st in self.hosts.items():
            if st.n >= self.min_steps and st.ewma is not None:
                if (st.ewma - mean) / std > self.z_threshold:
                    out.append(host)
        return sorted(out)

    def exclusion_plan(self) -> Dict[str, str]:
        """host -> action; feeds runtime/elastic.py re-mesh planning."""
        return {h: "drain_and_replace" for h in self.stragglers()}
