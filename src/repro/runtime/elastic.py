"""Elastic re-meshing: rebuild the mesh from surviving hosts and reshard.

Protocol on host loss (paired with checkpoint/ for state):
  1. the controller computes the largest valid mesh from surviving chips
     (``plan_remesh``) -- the model axis is preserved (TP degree is a
     property of the model's sharding), the data axis shrinks;
  2. global batch is preserved by raising per-device batch or
     gradient-accumulation steps (``rebalance``);
  3. parameters/optimizer state are restored from the checkpoint with the
     *new* mesh's shardings (checkpoint.restore(..., shardings=new)) --
     resharding happens in device_put, no custom gather logic.

The dry-run validates step 3 end-to-end with virtual devices
(tests/test_elastic.py): a checkpoint written on a (4, 4) mesh restores
onto (2, 4) with identical values.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    microbatch_multiplier: int   # grad-accum factor to preserve global batch


def plan_remesh(
    *,
    old_shape: Tuple[int, ...],
    axis_names: Tuple[str, ...],
    n_lost_chips: int,
    model_axis: str = "model",
) -> RemeshPlan:
    """Shrink the data(-most) axis to the largest power-of-two fit.

    The model axis never shrinks (parameter sharding would change); lost
    capacity comes out of data parallelism, compensated by gradient
    accumulation so the global batch (and thus optimization trajectory)
    is unchanged.
    """
    sizes = dict(zip(axis_names, old_shape))
    total = 1
    for s in old_shape:
        total *= s
    survivors = total - n_lost_chips
    model = sizes[model_axis]
    if survivors < model:
        raise ValueError(f"cannot keep model axis {model} with {survivors} chips")
    # data capacity = largest power-of-two divisor fit of survivors // model
    data_cap = survivors // model
    new_data = 1
    while new_data * 2 <= data_cap:
        new_data *= 2
    # shrink the first non-model axis (pod-major first if present)
    data_axes = [a for a in axis_names if a != model_axis]
    old_data = 1
    for a in data_axes:
        old_data *= sizes[a]
    # collapse all data axes into one logical data axis of new_data
    new_shape = []
    remaining = new_data
    for a in axis_names:
        if a == model_axis:
            new_shape.append(model)
        else:
            take = min(sizes[a], remaining)
            # keep axis if it still divides, else fold to 1
            while take > 1 and remaining % take:
                take -= 1
            new_shape.append(take)
            remaining //= take
    mult = max(1, old_data // max(1, new_data))
    return RemeshPlan(
        old_shape=tuple(old_shape),
        new_shape=tuple(new_shape),
        axis_names=tuple(axis_names),
        microbatch_multiplier=mult,
    )


def build_mesh(plan: RemeshPlan) -> jax.sharding.Mesh:
    return jax.make_mesh(plan.new_shape, plan.axis_names)
