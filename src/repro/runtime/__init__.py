from repro.runtime import elastic, fault_tolerance, straggler

__all__ = ["elastic", "fault_tolerance", "straggler"]
