"""Fault tolerance: bounded-retry step loop with checkpoint restart.

The controller pattern for 1000+-node runs: the training loop body is
wrapped so that any step failure (preempted host, XLA abort, data node
loss) triggers (1) state restore from the last complete checkpoint,
(2) pipeline rewind to the checkpointed step (exact, since the pipeline is
counter-based), (3) bounded retry with backoff. Heartbeats let an external
watchdog distinguish "slow" from "dead".
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional, Tuple

log = logging.getLogger(__name__)


@dataclasses.dataclass
class Heartbeat:
    """Liveness marker, updated once per step; a watchdog (or test) reads
    ``age()`` to detect a hung worker."""
    last_beat: float = dataclasses.field(default_factory=time.monotonic)

    def beat(self) -> None:
        self.last_beat = time.monotonic()

    def age(self) -> float:
        return time.monotonic() - self.last_beat


@dataclasses.dataclass
class RetryPolicy:
    max_failures: int = 3
    backoff_s: float = 0.0       # 0 in tests; seconds on a real cluster
    failures_seen: int = 0


class StepFailure(RuntimeError):
    pass


def run_resilient_loop(
    *,
    n_steps: int,
    start_step: int,
    step_fn: Callable[[int, Any], Any],       # (step, state) -> state
    state: Any,
    save_fn: Callable[[int, Any], None],      # checkpoint write
    restore_fn: Callable[[], Tuple[int, Any]],  # -> (step, state)
    checkpoint_every: int,
    policy: Optional[RetryPolicy] = None,
    heartbeat: Optional[Heartbeat] = None,
    on_step: Optional[Callable[[int, Any], None]] = None,
) -> Tuple[int, Any]:
    """Run ``step_fn`` for steps [start_step, n_steps) with restart-on-failure.

    Returns (final_step, final_state). Raises once ``policy.max_failures``
    is exhausted (the job-level scheduler takes over from there).
    """
    policy = policy or RetryPolicy()
    heartbeat = heartbeat or Heartbeat()
    step = start_step
    while step < n_steps:
        try:
            state = step_fn(step, state)
            heartbeat.beat()
            if on_step is not None:
                on_step(step, state)
            step += 1
            if step % checkpoint_every == 0 or step == n_steps:
                save_fn(step, state)
        except Exception as e:  # noqa: BLE001 -- any step failure is retryable
            policy.failures_seen += 1
            log.warning("step %d failed (%s); failure %d/%d",
                        step, e, policy.failures_seen, policy.max_failures)
            if policy.failures_seen > policy.max_failures:
                raise StepFailure(
                    f"exceeded {policy.max_failures} failures at step {step}") from e
            if policy.backoff_s:
                time.sleep(policy.backoff_s * policy.failures_seen)
            step, state = restore_fn()
            log.warning("restored to step %d; resuming", step)
    return step, state
