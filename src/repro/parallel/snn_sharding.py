"""Mesh partitioning of the SNN tick fabric (DESIGN.md §15).

The fabric shards by **destination** (fan-in / column sharding): mesh
shard ``i`` owns postsynaptic columns ``[i*n/D, (i+1)*n/D)`` of the
synapse matrix ``W`` (and ``C``), the matching slices of ``w_in``, the
per-neuron LIF parameters/state, and -- crucially -- the delay rings of
its own neurons.  Each tick, every shard

1. reads the spikes arriving at its local neurons from its local ring,
2. ``all_gather``\\ s them along the mesh axis into the full presynaptic
   spike vector (the ONE collective per tick; ``B*n`` floats, ~n x
   smaller than any weight movement),
3. computes the *complete* fan-in dot ``s_full @ (W*C)[:, local]`` for
   its columns, and
4. steps LIF + writes its local ring.

Because every output column is still reduced over the full presynaptic
axis **on one device, in the same order** as the single-device engine,
the sharded rollout is bit-exact -- unlike row (source) sharding, whose
per-tick ``psum`` would re-associate the f32 fan-in sum.  The scheme is
also exactly what the repo's backends already are: the jnp/event arms
consume a pre-masked ``(n, n_local)`` slab, the event top-k/fan-in
gathers index *rows* of that slab with global presynaptic ids (rows stay
whole under column sharding), and the Pallas fused-LIF kernel is
rectangular in ``(K, N)`` already.

Implementation: :func:`sharded_scan` wraps the UNCHANGED
:meth:`repro.core.engine.TickEngine.scan` in ``shard_map`` -- one
compiled program, the whole tick loop inside, so chunked serving crosses
no host boundary and recompiles exactly as often as the single-device
engine (never, after warmup).  Specs come from the same
:class:`repro.parallel.sharding.AxisRules` machinery the transformer
stack uses, with the SNN logical axes mapped so that only
``neurons_post`` shards.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.engine import TickCarry, TickEngine
from repro.core.network_types import SNNParams, SNNState
from repro.parallel.sharding import AxisRules, BASE_RULES


def shard_map_fn(f, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` across jax versions (module move + kwarg rename)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm  # jax < 0.6
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def snn_rules(mesh: Optional[Mesh] = None, axis: str = "model") -> AxisRules:
    """The SNN logical->mesh table: destination columns shard, everything
    presynaptic/batch/time replicates.  Built on BASE_RULES so per-run
    overrides compose the same way they do for the transformer cells."""
    mapping = dict(BASE_RULES)
    mapping.update({
        "batch": None,          # one fabric, batch rides along replicated
        "time": None,
        "delay": None,
        "inputs": None,
        "neurons_pre": None,    # full presynaptic axis on every shard
        "neurons_post": axis,   # the ONE sharded dimension
    })
    return AxisRules(mapping, mesh=mesh)


def _vec(rules: AxisRules, a: jax.Array) -> P:
    """(..., n) -> shard the trailing neuron axis, replicate the rest."""
    return rules.spec((None,) * (a.ndim - 1) + ("neurons_post",))


def _mat(rules: AxisRules) -> P:
    return rules.spec(("neurons_pre", "neurons_post"))


def _rep(tree) -> Any:
    return jax.tree.map(lambda _: P(), tree)


def params_specs(rules: AxisRules, params: SNNParams) -> SNNParams:
    """PartitionSpec tree for :class:`SNNParams` (c=None passes through)."""
    return SNNParams(
        w=_mat(rules),
        c=None if params.c is None else _mat(rules),
        w_in=rules.spec(("inputs", "neurons_post")),
        lif=jax.tree.map(lambda a: _vec(rules, a), params.lif),
    )


def state_specs(rules: AxisRules, state: SNNState) -> SNNState:
    return SNNState(
        lif=jax.tree.map(lambda a: _vec(rules, a), state.lif),
        delay_buf=_vec(rules, state.delay_buf),
        tick=P(),
    )


def carry_specs(rules: AxisRules, carry: TickCarry) -> TickCarry:
    """Spec tree for a (seeded) :class:`TickCarry`.

    ``plast.x_pre`` replicates: presynaptic traces are a function of the
    *gathered* full-width spike vector, so every shard computes the
    identical trace array -- no collective needed for plasticity beyond
    the tick's own spike exchange.  Telemetry replicates (local partials
    are combined once per scan by :func:`combine_telemetry`)."""
    plast = None
    if carry.plast is not None:
        plast = dataclasses.replace(
            jax.tree.map(lambda _: P(), carry.plast),
            x_pre=P(),
            x_post=_vec(rules, carry.plast.x_post),
            elig=_mat(rules),
        )
    return TickCarry(
        state=state_specs(rules, carry.state),
        plast=plast,
        w=None if carry.w is None else _mat(rules),
        telem=None if carry.telem is None else _rep(carry.telem),
        policy=None if carry.policy is None else P(),
    )


def neighbors_specs(rules: AxisRules, neighbors: Any) -> Any:
    """Fan-in lists slice by destination ROW (idx entries stay global
    presynaptic ids -- rows of the local ``wc`` slab are the full
    presynaptic axis, so no index translation)."""
    spec = rules.spec(("neurons_post", None))
    return jax.tree.map(lambda _: spec, neighbors)


def combine_telemetry(telem_in, telem_out, axis: str):
    """Fold per-shard telemetry partials into fabric-wide totals (one
    collective bundle per SCAN, not per tick).

    Only the DELTA this scan accumulated is combined: the incoming
    accumulator ``telem_in`` is replicated (it is either the zero seed or
    the already-combined output of the previous chunk), so summing
    ``telem_out`` wholesale would re-``psum`` prior chunks' totals D-fold
    every chunk.  Sums (spikes, dw norms) ``psum`` their delta; the
    mean-based accumulators additionally divide by the axis size because
    each shard normalized by its local ``n/D``; ``v_max`` is a plain
    ``pmax`` (max is idempotent over the replicated prior).
    ``ticks``/``overflow``/``policy_dense`` are computed from replicated
    inputs (tick counter, gathered spikes) and are already identical on
    every shard."""
    d = jax.lax.psum(1, axis)
    dsum = lambda i, o: i + jax.lax.psum(o - i, axis)
    dmean = lambda i, o: i + jax.lax.psum(o - i, axis) / d
    return dataclasses.replace(
        telem_out,
        spikes=dsum(telem_in.spikes, telem_out.spikes),
        v_sum=dmean(telem_in.v_sum, telem_out.v_sum),
        v_max=jax.lax.pmax(telem_out.v_max, axis),
        ref_sum=dmean(telem_in.ref_sum, telem_out.ref_sum),
        dw_l1=dsum(telem_in.dw_l1, telem_out.dw_l1),
        dw_sq=dsum(telem_in.dw_sq, telem_out.dw_sq),
    )


def named_shardings(mesh: Mesh, specs):
    """Spec tree (from the builders above) -> NamedSharding tree.

    ``P`` is a tuple subclass, i.e. itself a pytree -- the ``is_leaf``
    stops the map from descending into it."""
    from jax.sharding import NamedSharding

    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def place(tree, specs, mesh: Mesh):
    """Commit a global pytree onto the mesh per its spec tree.

    Placement OUTSIDE compiled programs (plain ``jax.device_put`` -- the
    analysis gate's purity rule forbids transfers inside the hot loop);
    once the carry is committed, every subsequent ``chunk()`` finds its
    operands already resident and moves nothing."""
    return jax.device_put(tree, named_shardings(mesh, specs))


def make_sharded_dyadic_weights(
    n: int,
    mesh: Optional[Mesh] = None,
    axis: str = "model",
    *,
    seed: int = 0,
    n_blocks: int = 8,
    levels: int = 8,
) -> jax.Array:
    """Dyadic-grid weights materialized shard-local (the 64k-safe path).

    Weights are ``uint8 levels x 2^round(log2(2/sqrt(n)))`` -- the grid on
    which every f32 reduction order is exact (the repo's bitwise-parity
    substrate).  Generation is seeded per COLUMN BLOCK (``n_blocks``
    fixed blocks, independent of the mesh), so the same ``(n, seed)``
    yields the identical global matrix on any mesh size -- D=1 vs D=8
    parity checks compare the same fabric.  With ``mesh`` given, each
    device shard is assembled directly from its covering blocks via
    ``jax.make_array_from_callback``: the full ``(n, n)`` f32 matrix (16
    GiB at 64k) never exists as one host allocation.
    """
    import math

    import numpy as np

    if n % n_blocks:
        raise ValueError(f"n={n} must divide into {n_blocks} gen blocks")
    scale = 2.0 ** round(math.log2(2.0 / math.sqrt(n)))
    bw = n // n_blocks

    def block(b: int) -> np.ndarray:
        rng = np.random.default_rng((seed, b))
        u8 = rng.integers(0, levels, size=(n, bw), dtype=np.uint8)
        return u8.astype(np.float32) * np.float32(scale)

    if mesh is None:
        return jnp.concatenate([block(b) for b in range(n_blocks)], axis=1)
    from jax.sharding import NamedSharding

    sharding = NamedSharding(mesh, P(None, axis))

    def cb(index) -> np.ndarray:
        lo = index[1].start or 0
        hi = index[1].stop if index[1].stop is not None else n
        parts = []
        for b in range(n_blocks):
            blo, bhi = b * bw, (b + 1) * bw
            if bhi <= lo or blo >= hi:
                continue
            parts.append(block(b)[:, max(lo, blo) - blo:min(hi, bhi) - blo])
        return np.concatenate(parts, axis=1)

    return jax.make_array_from_callback((n, n), sharding, cb)


def sharded_scan(
    engine: TickEngine,
    params: SNNParams,
    carry0: TickCarry,
    ext_seq: Optional[jax.Array],
    n_ticks: int,
    *,
    rewards: Optional[jax.Array] = None,
    delays: Optional[jax.Array] = None,
    plastic_c: Optional[jax.Array] = None,
    learn_until: Optional[jax.Array] = None,
    neighbors: Optional[Any] = None,
) -> Tuple[TickCarry, jax.Array]:
    """Run :meth:`TickEngine.scan` under ``shard_map`` on ``engine.mesh``.

    The inner engine is the same options with ``mesh=None`` and the
    resolved ``shard_axis`` set -- its tick body all-gathers the arriving
    spikes and otherwise runs unchanged on ``(n, n/D)`` operands, so all
    four backends, plasticity, telemetry and the chunk contract compose
    exactly as on one device (and D=1 is bitwise the single-device
    program)."""
    mesh = engine.mesh
    if mesh is None:
        raise ValueError("sharded_scan needs EngineOptions.mesh set")
    axis = engine.resolved_shard_axis()
    n_dev = mesh.shape[axis]
    n = carry0.state.lif.v.shape[-1]
    if n % n_dev:
        raise ValueError(
            f"n={n} neurons do not split evenly over mesh axis "
            f"{axis!r} of size {n_dev} (pad the fabric or resize the mesh)")
    if delays is not None:
        raise ValueError(
            "per-synapse delay matrices don't compose with the sharded arm "
            "(the delay-plane einsum needs full-width spike history); use "
            "uniform rings (max_delay) or run single-device")
    learning = carry0.w is not None
    if learning and carry0.state.delay_buf.shape[-2] != 1:
        raise ValueError(
            "sharded learning requires max_delay == 1 (pair STDP reads the "
            "previous tick's spikes as the presynaptic events)")

    # Seed telemetry/policy slots on the GLOBAL side so the spec trees
    # below see the final carry structure; the inner scan's own seeding
    # then no-ops.
    carry0 = engine._seed_carry(carry0, neighbors)
    # A 1-device mesh partitions nothing: run the PLAIN engine inside
    # the (trivial) shard_map -- no gather, no pallas_fused remap -- so
    # "sharded at D=1" is the single-device program bit-for-bit, for
    # every backend including the learning megakernel.
    inner = TickEngine(dataclasses.replace(
        engine.options, mesh=None,
        shard_axis=axis if n_dev > 1 else None))

    rules = snn_rules(mesh, axis)
    args: Dict[str, Any] = {
        "params": params, "carry": carry0, "ext": ext_seq,
        "rewards": rewards, "plastic_c": plastic_c,
        "learn_until": learn_until, "neighbors": neighbors,
    }
    in_specs = {
        "params": params_specs(rules, params),
        "carry": carry_specs(rules, carry0),
        "ext": _rep(ext_seq),
        "rewards": _rep(rewards),
        "plastic_c": None if plastic_c is None else _mat(rules),
        "learn_until": _rep(learn_until),
        "neighbors": (None if neighbors is None
                      else neighbors_specs(rules, neighbors)),
    }
    # Raster is (T, *batch, n): shard only the trailing neuron axis.
    raster_spec = P(*([None] * carry0.state.lif.y.ndim), axis)
    out_specs = (carry_specs(rules, carry0), raster_spec)

    def body(a):
        carry, raster = inner.scan(
            a["params"], a["carry"], a["ext"], n_ticks,
            rewards=a["rewards"], plastic_c=a["plastic_c"],
            learn_until=a["learn_until"], neighbors=a["neighbors"])
        # D=1 partitions nothing -- leave the accumulator untouched so
        # the 1-device-mesh program stays bitwise the plain engine.
        if carry.telem is not None and n_dev > 1:
            carry = dataclasses.replace(
                carry,
                telem=combine_telemetry(a["carry"].telem, carry.telem, axis))
        return carry, raster

    return shard_map_fn(body, mesh, (in_specs,), out_specs)(args)
