"""Logical-axis sharding rules (MaxText-style).

Every parameter and activation in the model code is annotated with
*logical* axis names ("embed", "mlp", "q_heads", ...). A rules table maps
logical names to mesh axes; changing the table re-lowers the same model
under a different distribution -- the primary hillclimb lever in
EXPERIMENTS.md §Perf, and the reason sharding choices never leak into model
code.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


# Baseline logical->mesh mapping for a (data, model) mesh; the dry-run
# swaps "batch" to ("pod","data") on the multi-pod mesh and per-arch/
# per-shape overrides are applied on top (see configs + launch/dryrun).
BASE_RULES: Dict[str, MeshAxes] = {
    # activations
    "batch": "data",
    "seq": None,
    "kv_seq": None,
    "embed": None,
    "act_mlp": "model",
    "act_heads": "model",
    "act_vocab": "model",
    # params -- dense
    "embed_param": None,      # fsdp: "data"
    "vocab": "model",
    "mlp": "model",
    "q_heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qkv_in": None,           # fsdp: "data"
    "mlp_in": None,           # fsdp: "data"
    "norm": None,
    # params -- moe
    "experts": "model",
    "expert_in": None,        # fsdp: "data"
    "expert_mlp": None,
    # params -- ssm / rwkv
    "d_inner": "model",
    "d_state": None,
    "d_conv": None,
    "rwkv_heads": "model",
    "rwkv_key": None,
    "rwkv_value": None,
    "rwkv_lora": None,
    # vlm / audio
    "vision_seq": None,
    "vision_embed": None,
    "codebooks": None,
    # stacking
    "layers": None,
    "groups": None,
    # snn -- destination (fan-in/column) sharding: postsynaptic columns
    # shard, the presynaptic axis replicates, so every output column is
    # reduced over its full fan-in on one device (bit-exact; see
    # repro.parallel.snn_sharding and DESIGN.md §15).
    "neurons_pre": None,
    "neurons_post": "model",
    "inputs": None,
    "time": None,
    "delay": None,
}


@dataclasses.dataclass(frozen=True)
class AxisRules:
    mapping: Mapping[str, MeshAxes]
    mesh: Optional[Mesh] = None

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        entries = []
        used = set()
        for a in axes:
            if a is None:
                entries.append(None)
                continue
            if a not in self.mapping:
                raise KeyError(f"unknown logical axis {a!r}")
            e = self.mapping[a]
            # A mesh axis may appear at most once per spec; when rule
            # overrides collide (e.g. Megatron-SP seq="model" meeting an
            # interior heads="model" constraint), earlier dims win.
            flat = (e,) if isinstance(e, str) else tuple(e or ())
            if any(f in used for f in flat):
                entries.append(None)
                continue
            used.update(flat)
            entries.append(e)
        return P(*entries)

    def sharding(self, axes: Sequence[Optional[str]]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(axes))

    def with_overrides(self, overrides: Mapping[str, MeshAxes]) -> "AxisRules":
        m = dict(self.mapping)
        m.update(overrides)
        return AxisRules(mapping=m, mesh=self.mesh)

    def with_mesh(self, mesh: Optional[Mesh]) -> "AxisRules":
        return AxisRules(mapping=self.mapping, mesh=mesh)


_ctx = threading.local()


def current_rules() -> Optional[AxisRules]:
    return getattr(_ctx, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[AxisRules]):
    prev = current_rules()
    _ctx.rules = rules
    try:
        yield rules
    finally:
        _ctx.rules = prev


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a sharding constraint if rules are active; else identity.

    CPU unit tests run with no rules -> zero overhead, no mesh needed.
    """
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"rank mismatch: {len(axes)} axes for shape {x.shape}")
    return jax.lax.with_sharding_constraint(x, rules.sharding(axes))


def fsdp_overrides() -> Dict[str, MeshAxes]:
    """ZeRO-3-style parameter sharding for >=15B archs: the non-"model"
    major axis of every large matrix also shards over "data"; GSPMD
    inserts the per-block all-gathers."""
    return {
        "embed_param": "data",
        "qkv_in": "data",
        "mlp_in": "data",
        "expert_in": "data",
    }


def multipod_overrides() -> Dict[str, MeshAxes]:
    """Batch additionally shards over the pod axis (pure-DP across pods)."""
    return {"batch": ("pod", "data")}


def seq_shard_overrides(data_axes: MeshAxes = "data") -> Dict[str, MeshAxes]:
    """long_500k (global_batch=1): shard sequence instead of batch."""
    return {"batch": None, "seq": data_axes, "kv_seq": data_axes}
