"""Rule dispatch: one entry point for a learning tick, any backend.

``plasticity_step`` is what the network scan calls.  It owns the single
state<->array bridge (flatten batch dims, default the reward, expand the
hyper-parameters, rebuild :class:`PlasticityState`) and routes the
array-level work to either the pure-jnp oracle
(:func:`repro.kernels.ref.fused_stdp_step_ref`) or the fused Pallas
kernel (:func:`repro.kernels.ops.fused_stdp_step`), which computes the
trace decay and the batched outer-product weight update in one VMEM pass
(interpret mode on CPU is correctness-identical to the TPU lowering;
tests/test_plasticity.py pins the equivalence).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.plasticity.stdp import PlasticityParams, PlasticityState


def _hyper_kwargs(params: PlasticityParams) -> dict:
    """The array-level hyper-parameter expansion both backends share."""
    return dict(
        rule=params.rule, a_plus=params.a_plus, a_minus=params.a_minus,
        decay_pre=params.decay_pre, decay_post=params.decay_post,
        decay_elig=params.decay_elig, lr_reward=params.lr_reward,
        w_min=params.w_min, w_max=params.w_max)


def plasticity_step(
    state: PlasticityState,
    s_pre: jax.Array,
    s_post: jax.Array,
    w: jax.Array,
    c: jax.Array,
    params: PlasticityParams,
    reward: Optional[jax.Array] = None,
    *,
    backend: str = "jnp",
    interpret: Optional[bool] = None,
) -> Tuple[PlasticityState, jax.Array]:
    """One learning tick: update traces, eligibility, and weights.

    Args mirror :func:`repro.plasticity.stdp.stdp_step_ref`; ``backend``
    selects ``"jnp"`` (reference) or ``"pallas"`` (fused kernel, with
    ``interpret`` plumbed through for CPU execution).
    """
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown plasticity backend {backend!r}")
    batch_shape = s_pre.shape[:-1]
    flat = lambda a: a.reshape((-1, a.shape[-1]))
    r = jnp.zeros((), jnp.float32) if reward is None else jnp.asarray(
        reward, jnp.float32)
    args = (flat(s_pre), flat(state.x_pre), flat(s_post), flat(state.x_post),
            w, c, state.elig, r)
    if backend == "jnp":
        from repro.kernels.ref import fused_stdp_step_ref

        out = fused_stdp_step_ref(*args, **_hyper_kwargs(params))
    else:
        from repro.kernels import ops  # local import; CPU tests use jnp

        out = ops.fused_stdp_step(
            *args, interpret=interpret, **_hyper_kwargs(params))
    w_new, elig, x_pre, x_post = out
    return (
        PlasticityState(
            x_pre=x_pre.reshape(batch_shape + s_pre.shape[-1:]),
            x_post=x_post.reshape(batch_shape + s_post.shape[-1:]),
            elig=elig),
        w_new,
    )
