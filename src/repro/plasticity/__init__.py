"""On-device plasticity: trace-based STDP / R-STDP inside the tick loop.

The paper's processor is inference-only -- weights are trained off-chip
and streamed in over the UART.  This subsystem closes the loop the way
NeuroCoreX (arXiv:2506.14138) does for the same architecture family:
pair-based STDP with pre/post eligibility traces co-located with the
neuron datapath, plus a reward-modulated variant (R-STDP) for on-device
supervised readouts.  Weights live on the register bank's u8 grid
([0, 255]) the whole time, so a *learned* network serializes back through
:class:`repro.core.registers.RegisterBank` / UART byte-exactly -- the
paper's "no re-synthesis" reconfiguration story run in reverse
(device -> host weight readback).

Layering:

* :mod:`repro.plasticity.traces`  -- exponential spike-trace arithmetic.
* :mod:`repro.plasticity.stdp`    -- ``PlasticityParams`` / ``PlasticityState``
  and the pure-jnp pair-STDP weight update (the reference semantics).
* :mod:`repro.plasticity.rules`   -- rule dispatch (stdp | rstdp) and the
  backend switch (jnp reference vs the fused Pallas kernel in
  :mod:`repro.kernels.stdp_update`).
* ``repro.core.network.learning_rollout`` -- the scan whose carry includes
  the mutable weight matrix.

DESIGN.md §7 documents the datapath restatement.
"""
from repro.plasticity.stdp import (  # noqa: F401
    PlasticityParams,
    PlasticityState,
    apply_reward,
    quantize_weights,
    weights_to_bank,
    weights_from_bank,
)
from repro.plasticity.rules import plasticity_step  # noqa: F401
