"""Exponential spike traces -- the plasticity subsystem's state variables.

A trace ``x`` low-pass filters a spike train: every tick it decays by a
constant factor and increments by the tick's spikes,

    x[k+1] = decay * x[k] + s[k+1],        decay = exp(-1 / tau).

On the FPGA this is one shift-and-add per neuron per tick (NeuroCoreX
realizes the same filter with a power-of-two decay); here it is one fused
multiply-add in VREGs, either in the jnp reference or inside the Pallas
STDP kernel so the trace never makes an extra HBM round-trip.

Traces are carried per *neuron*, not per synapse: pair-based STDP needs
only the presynaptic trace ``x_pre`` (potentiation) and postsynaptic
trace ``x_post`` (depression), each shape ``(..., n)``.  The per-synapse
eligibility matrix used by R-STDP lives in
:class:`repro.plasticity.stdp.PlasticityState` instead.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decay_from_tau(tau: float) -> float:
    """Per-tick decay factor ``exp(-1/tau)`` for a time constant in ticks."""
    if tau <= 0:
        raise ValueError(f"tau must be positive, got {tau}")
    return math.exp(-1.0 / tau)


def trace_step(x: jax.Array, spikes: jax.Array, decay: float) -> jax.Array:
    """One tick of the exponential trace filter (decay *then* accumulate).

    The returned trace already includes this tick's spikes -- the
    convention every STDP term in :mod:`repro.plasticity.stdp` is written
    against (a pre and post spike in the *same* tick see each other).
    """
    return decay * x + spikes.astype(x.dtype)


def trace_steady_state(rate: float, decay: float) -> float:
    """Fixed point of the filter under a constant spike rate (diagnostics:
    bounds the trace magnitude entering the weight update)."""
    return rate / (1.0 - decay)
