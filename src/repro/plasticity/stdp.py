"""Pair-based STDP / R-STDP core API: params, state, reference update.

Semantics (one network tick, matching ``repro.core.network.step``):

    x_pre'  = decay_pre  * x_pre  + s_pre          (trace incl. this tick)
    x_post' = decay_post * x_post + s_post
    dw[i,j] = a_plus  * sum_b x_pre'[b,i] * s_post[b,j]      (LTP)
            - a_minus * sum_b s_pre[b,i]  * x_post'[b,j]     (LTD)

``s_pre`` are the spikes *arriving* at this tick (the presynaptic events
the mux fabric routed in), ``s_post`` the spikes emitted by the updated
neurons.  A pre spike that precedes a post spike is captured by ``x_pre``
at post time (causal potentiation); a post spike that precedes a pre
spike is captured by ``x_post`` at pre-arrival time (acausal depression).
Coincident pre/post spikes hit both terms and contribute
``a_plus - a_minus`` net -- document-once convention, shared bit-for-bit
by the jnp reference here, the oracle in :mod:`repro.kernels.ref`, and
the fused Pallas kernel in :mod:`repro.kernels.stdp_update`.

Batch dims are *summed* into the shared weight matrix (the hardware has
one synapse array; a batch is a sum of per-sample updates -- scale
``a_plus/a_minus`` by ``1/B`` for a mean).

Weight updates are masked by the connection list ``C`` (a mux that routes
a zero cannot learn) and clipped to the register bank's u8 domain
``[w_min, w_max] ⊆ [0, 255]``, so the learned matrix rounds onto the wire
format losslessly (:func:`weights_to_bank` / :func:`weights_from_bank`).

Rules:

* ``"stdp"``  -- apply ``dw`` immediately (unsupervised Hebbian learning).
* ``"rstdp"`` -- accumulate ``dw`` into a per-synapse eligibility trace
  ``elig' = decay_elig * elig + dw`` and apply
  ``w' = w + lr_reward * reward * elig'`` -- a scalar dopamine signal
  gates, scales, and signs the update (three-factor rule).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.plasticity import traces

RULES = ("stdp", "rstdp")


@dataclasses.dataclass(frozen=True)
class PlasticityParams:
    """Learning hyper-parameters.

    A plain (non-pytree) dataclass: these are compile-time constants like
    the LIF ``mode`` string, baked into the jitted tick -- the hardware
    analogue is a synthesis-time learning-engine configuration, while the
    *weights* stay runtime registers.

    Attributes:
      rule: ``"stdp"`` or ``"rstdp"``.
      a_plus: LTP amplitude per (pre-trace, post-spike) pairing.
      a_minus: LTD amplitude per (pre-spike, post-trace) pairing.
      decay_pre: per-tick presynaptic trace decay ``exp(-1/tau_pre)``.
      decay_post: per-tick postsynaptic trace decay.
      decay_elig: per-tick eligibility decay (R-STDP only).
      lr_reward: reward learning rate (R-STDP only).
      w_min, w_max: hard weight bounds, the register bank's u8 domain.
    """

    rule: str = "stdp"
    a_plus: float = 1.0
    a_minus: float = 1.0
    decay_pre: float = 0.7165313106
    decay_post: float = 0.7165313106
    decay_elig: float = 0.9048374180
    lr_reward: float = 1.0
    w_min: float = 0.0
    w_max: float = 255.0

    def __post_init__(self):
        if self.rule not in RULES:
            raise ValueError(f"unknown plasticity rule {self.rule!r}; have {RULES}")
        if not (0.0 <= self.w_min < self.w_max <= 255.0):
            raise ValueError(
                f"[w_min, w_max]=[{self.w_min}, {self.w_max}] must lie in the "
                "u8 register domain [0, 255]")

    @staticmethod
    def make(
        rule: str = "stdp",
        *,
        tau_pre: float = 3.0,
        tau_post: float = 3.0,
        tau_elig: float = 10.0,
        a_plus: float = 1.0,
        a_minus: float = 1.0,
        lr_reward: float = 1.0,
        w_min: float = 0.0,
        w_max: float = 255.0,
    ) -> "PlasticityParams":
        """Construct from time constants in ticks (the usual papers' units)."""
        return PlasticityParams(
            rule=rule,
            a_plus=a_plus,
            a_minus=a_minus,
            decay_pre=traces.decay_from_tau(tau_pre),
            decay_post=traces.decay_from_tau(tau_post),
            decay_elig=traces.decay_from_tau(tau_elig),
            lr_reward=lr_reward,
            w_min=w_min,
            w_max=w_max,
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PlasticityState:
    """Learning state carried through the tick scan.

    Attributes:
      x_pre: presynaptic traces, shape ``(..., n_pre)`` (batch dims match
        the network state).
      x_post: postsynaptic traces, shape ``(..., n_post)``.
      elig: per-synapse eligibility, shape ``(n_pre, n_post)`` -- shared
        across the batch like the weights it gates (zeros and unused for
        ``rule="stdp"``).
    """

    x_pre: jax.Array
    x_post: jax.Array
    elig: jax.Array

    @staticmethod
    def zeros(
        batch_shape,
        n_pre: int,
        n_post: Optional[int] = None,
        dtype=jnp.float32,
    ) -> "PlasticityState":
        n_post = n_pre if n_post is None else n_post
        shape = tuple(batch_shape)
        return PlasticityState(
            x_pre=jnp.zeros(shape + (n_pre,), dtype=dtype),
            x_post=jnp.zeros(shape + (n_post,), dtype=dtype),
            elig=jnp.zeros((n_pre, n_post), dtype=dtype),
        )


def stdp_step_ref(
    state: PlasticityState,
    s_pre: jax.Array,
    s_post: jax.Array,
    w: jax.Array,
    c: jax.Array,
    params: PlasticityParams,
    reward: Optional[jax.Array] = None,
) -> Tuple[PlasticityState, jax.Array]:
    """One learning tick, pure-jnp reference semantics.

    Args:
      s_pre: spikes arriving this tick, ``(..., n_pre)``.
      s_post: spikes emitted this tick, ``(..., n_post)``.
      w: weights ``(n_pre, n_post)``; plastic entries live on the u8 grid.
      c: plastic mask ``(n_pre, n_post)`` in {0, 1} -- usually the
        connection list; pass a sub-mask to freeze part of the fabric
        (e.g. a fixed inhibitory winner-take-all block).  Synapses with
        ``c == 0`` are returned bit-identical (not even clipped).
      reward: scalar dopamine signal (R-STDP; ignored for ``"stdp"``).

    Returns:
      ``(new_state, new_weights)``.
    """
    # One bridge, one source of truth: the dispatcher in rules.py routes
    # to the array-level oracle (kernels/ref.py) for the jnp backend.
    from repro.plasticity.rules import plasticity_step

    return plasticity_step(
        state, s_pre, s_post, w, c, params, reward, backend="jnp")


def apply_reward(
    w: jax.Array,
    elig: jax.Array,
    reward,
    params: PlasticityParams,
    c: Optional[jax.Array] = None,
) -> jax.Array:
    """Episode-level R-STDP: apply a terminal reward to banked eligibility.

    The common deployment runs the rollout with ``reward=0`` (eligibility
    accumulates, weights stay put) and applies the scalar outcome once the
    episode's prediction is known -- exactly
    ``w' = clip(w + lr * r * elig)``; equivalent to passing a rewards
    sequence that is zero except at the final tick.
    """
    wf = w.astype(jnp.float32)
    upd = params.lr_reward * jnp.asarray(reward, jnp.float32) * elig.astype(
        jnp.float32)
    w_new = jnp.clip(wf + upd, params.w_min, params.w_max)
    if c is not None:
        w_new = jnp.where(c.astype(jnp.float32) > 0, w_new, wf)
    return w_new.astype(w.dtype)


# ---------------------------------------------------------------------------
# register-bank readback: the reconfiguration story in reverse


def quantize_weights(w: jax.Array) -> np.ndarray:
    """Round learned weights (already clipped to [0, 255]) onto the u8 grid."""
    wq = np.rint(np.asarray(w, np.float64))
    if wq.min() < 0 or wq.max() > 255:
        raise ValueError(
            f"weights [{wq.min()}, {wq.max()}] outside the u8 register domain "
            "-- was the rollout run with w_min/w_max inside [0, 255]?")
    return wq.astype(np.uint8)


def weights_to_bank(bank, w: jax.Array) -> np.ndarray:
    """Write a learned ``(n, n)`` weight matrix into a PER_SYNAPSE bank.

    Returns the u8 matrix actually stored (the round-tripped truth the
    caller should keep using, so host and device stay bit-identical).
    """
    from repro.core.registers import WeightLayout

    if bank.weight_layout != WeightLayout.PER_SYNAPSE:
        raise ValueError("learned weights need WeightLayout.PER_SYNAPSE")
    wq = quantize_weights(w)
    bank.set_weights(wq)
    return wq


def weights_from_bank(bank, dtype=jnp.float32) -> jax.Array:
    """Read the device's u8 weights back to the learning (float) domain."""
    return jnp.asarray(bank.weights, dtype)
