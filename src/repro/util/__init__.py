"""Process-level utilities (environment setup before jax initializes)."""
