"""Computation-environment setup that must happen BEFORE jax initializes.

The multi-device SNN path (DESIGN.md §15) runs on plain CPU hosts by
simulating a device mesh: XLA splits the host into ``N`` logical devices
when ``--xla_force_host_platform_device_count=N`` is in ``XLA_FLAGS`` at
backend-initialization time.  That flag is process-global and read once,
so every entry point that wants a mesh -- tests (tests/conftest.py),
benchmarks (benchmarks/run.py, bench_snn_scale.py), the serve CLI and CI
-- funnels through :func:`ensure_host_device_count` instead of each
hand-rolling the ``os.environ`` dance (launch/dryrun.py predates this
module and keeps its subprocess-env variant).

Importing :mod:`jax` does NOT initialize the backend -- the first device
lookup or op does -- so calling these from a ``main()`` after imports is
fine; calling them after the first jax op is a silent no-op on the flag,
which is why :func:`ensure_host_device_count` returns the *actual*
device count for the caller to check.
"""
from __future__ import annotations

import os

_FLAG = "--xla_force_host_platform_device_count"


def ensure_host_device_count(n: int) -> int:
    """Ask XLA for ``n`` simulated host devices; return the actual count.

    Appends ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``
    (leaving any other flags intact) unless some value for it is already
    set -- an explicit operator/dry-run choice wins.  Then imports jax
    (initializing the backend if this is the first touch) and returns
    ``len(jax.devices())``, which callers must treat as the truth: if the
    backend initialized before this call, the flag had no effect and the
    return value says so.
    """
    if int(n) < 1:
        raise ValueError(f"device count must be >= 1, got {n}")
    flags = os.environ.get("XLA_FLAGS", "")
    if _FLAG not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {_FLAG}={int(n)}".strip()
    import jax

    return len(jax.devices())


def set_platform(platform: str = "cpu") -> None:
    """Pin the jax platform ("cpu" | "gpu" | "tpu"); effective only before
    the first jax op of the process (same contract as the XLA flag)."""
    import jax

    jax.config.update("jax_platform_name", platform)


def enable_x64(use_x64: bool = True) -> None:
    """Toggle 64-bit array defaults (the repo's programs are f32-strict --
    see repro.analysis -- so this exists for host-side verification
    scripts, not for anything that lowers)."""
    import jax

    jax.config.update("jax_enable_x64", bool(use_x64))
