"""Sharded, resumable input pipeline.

State = one integer step counter (the generator is counter-based), saved
with every checkpoint; after restart the pipeline resumes bit-exactly.
``make_batch`` materializes a global batch and (optionally) places it with
the mesh sharding -- on the real cluster each host materializes only its
addressable shard (same code path; jax.make_array_from_callback).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data import synthetic


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int

    def as_dict(self):
        return {"seed": self.seed, "step": self.step}

    @staticmethod
    def from_dict(d):
        return PipelineState(seed=int(d["seed"]), step=int(d["step"]))


def make_batch(
    cfg: ModelConfig,
    shape: ShapeConfig,
    state: PipelineState,
    *,
    shardings: Optional[Dict[str, jax.sharding.Sharding]] = None,
):
    """Next global batch for (cfg, shape); advances no state (pure)."""
    out = synthetic.token_batch(
        state.seed, state.step,
        global_batch=shape.global_batch, seq_len=shape.seq_len,
        vocab_size=cfg.vocab_size,
        n_codebooks=cfg.n_codebooks if cfg.family == "audio" else 0,
    )
    if cfg.family == "vlm":
        out["vision_embeds"] = synthetic.vision_batch(
            state.seed, state.step,
            global_batch=shape.global_batch,
            n_tokens=cfg.n_vision_tokens, d_vision=cfg.d_vision)
    if shardings:
        out = {
            k: jax.device_put(v, shardings[k]) if k in shardings else v
            for k, v in out.items()
        }
    return out


def advance(state: PipelineState) -> PipelineState:
    return PipelineState(seed=state.seed, step=state.step + 1)
