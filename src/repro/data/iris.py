"""Iris-like dataset synthesized from Fisher's published class statistics.

The container is offline, so we generate 50 samples/class from per-class
Gaussian statistics (means/stds of the real Iris data, public record).
This preserves the classification structure the paper's 4->3 network
exploits (setosa linearly separable; versicolor/virginica close). The
paper's claim validated here is *functional correctness of the pipeline*
(host encode -> register download -> FPGA-semantics inference -> decode),
not a statistical benchmark -- see EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

CLASS_NAMES = ("setosa", "versicolor", "virginica")

# (mean, std) per feature: sepal length, sepal width, petal length, petal width
_STATS = {
    0: (np.array([5.006, 3.428, 1.462, 0.246]), np.array([0.352, 0.379, 0.174, 0.105])),
    1: (np.array([5.936, 2.770, 4.260, 1.326]), np.array([0.516, 0.314, 0.470, 0.198])),
    2: (np.array([6.588, 2.974, 5.552, 2.026]), np.array([0.636, 0.322, 0.552, 0.275])),
}

FEATURE_MAX = np.array([8.0, 4.5, 7.0, 2.6])


def load(seed: int = 0, per_class: int = 50) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x (150, 4) float32 in feature units, y (150,) int32)."""
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    for c, (mu, sd) in _STATS.items():
        x = rng.normal(mu, sd, size=(per_class, 4))
        xs.append(np.clip(x, 0.1, FEATURE_MAX))
        ys.append(np.full(per_class, c))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    perm = rng.permutation(len(y))
    return x[perm], y[perm]


def normalize(x: np.ndarray) -> np.ndarray:
    """Scale features to [0, 1] by fixed per-feature maxima (host preprocessing)."""
    return (x / FEATURE_MAX).astype(np.float32)


def train_test_split(x, y, *, test_frac: float = 0.3, seed: int = 1):
    rng = np.random.default_rng(seed)
    n = len(y)
    perm = rng.permutation(n)
    n_test = int(n * test_frac)
    te, tr = perm[:n_test], perm[n_test:]
    return (x[tr], y[tr]), (x[te], y[te])
