"""Procedural MNIST-8x8: template digits + jitter + pixel noise (offline).

The paper resizes MNIST to 8x8, grayscales, binarizes by threshold, and
maps the 64 pixels onto input neurons 0..63 (§III.B). We synthesize the
8x8 digit images from hand-drawn templates with random shifts and noise,
then run the exact host pipeline: binarize -> spike impulses.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

_T = [
    # each template is 8 rows of 8 chars; '#' = ink
    [
        "..####..",
        ".#....#.",
        ".#....#.",
        ".#....#.",
        ".#....#.",
        ".#....#.",
        ".#....#.",
        "..####..",
    ],
    [
        "...##...",
        "..###...",
        "...##...",
        "...##...",
        "...##...",
        "...##...",
        "...##...",
        "..####..",
    ],
    [
        "..####..",
        ".#....#.",
        "......#.",
        ".....#..",
        "....#...",
        "...#....",
        "..#.....",
        ".######.",
    ],
    [
        ".#####..",
        "......#.",
        "......#.",
        "..####..",
        "......#.",
        "......#.",
        "......#.",
        ".#####..",
    ],
    [
        "....##..",
        "...#.#..",
        "..#..#..",
        ".#...#..",
        ".######.",
        ".....#..",
        ".....#..",
        ".....#..",
    ],
    [
        ".######.",
        ".#......",
        ".#......",
        ".#####..",
        "......#.",
        "......#.",
        "......#.",
        ".#####..",
    ],
    [
        "...###..",
        "..#.....",
        ".#......",
        ".#.###..",
        ".##...#.",
        ".#....#.",
        ".#....#.",
        "..####..",
    ],
    [
        ".######.",
        "......#.",
        ".....#..",
        "....#...",
        "...#....",
        "...#....",
        "...#....",
        "...#....",
    ],
    [
        "..####..",
        ".#....#.",
        ".#....#.",
        "..####..",
        ".#....#.",
        ".#....#.",
        ".#....#.",
        "..####..",
    ],
    [
        "..####..",
        ".#....#.",
        ".#....#.",
        "..#####.",
        "......#.",
        ".....#..",
        "....#...",
        "...#....",
    ],
]

TEMPLATES = np.stack(
    [np.array([[c == "#" for c in row] for row in t], dtype=np.float32) for t in _T]
)


def load(n_per_class: int = 50, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x (N, 8, 8) float32 grayscale in [0,1], y (N,) int32)."""
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    for digit in range(10):
        base = TEMPLATES[digit]
        for _ in range(n_per_class):
            img = base.copy()
            # sub-pixel intensity variation + stroke jitter
            img = img * rng.uniform(0.7, 1.0)
            dx, dy = rng.integers(-1, 2, size=2)
            img = np.roll(np.roll(img, dx, axis=1), dy, axis=0)
            img = img + rng.normal(0.0, 0.08, size=(8, 8))
            xs.append(np.clip(img, 0.0, 1.0))
            ys.append(digit)
    x = np.stack(xs).astype(np.float32)
    y = np.asarray(ys, dtype=np.int32)
    perm = rng.permutation(len(y))
    return x[perm], y[perm]


def binarize(x: np.ndarray, threshold: float = 0.35) -> np.ndarray:
    """Paper's host preprocessing: pixels above threshold spike ('1')."""
    return (x > threshold).astype(np.float32)


def to_spikes(x: np.ndarray, threshold: float = 0.35) -> np.ndarray:
    """(N, 8, 8) -> (N, 64) binary spike vectors for input neurons 0..63."""
    return binarize(x, threshold).reshape(x.shape[0], 64)
