"""Deterministic, resumable synthetic token stream.

Batches are a pure function of (seed, step) -- a counter-based generator,
so the pipeline state that must be checkpointed is exactly one integer and
restart-after-failure is trivially exact (runtime/fault_tolerance.py).
Token distribution is Zipf-like over the vocab with a per-sequence offset
pattern so the LM loss is learnable (structure exists) without external
data.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def token_batch(
    seed: int,
    step: int,
    *,
    global_batch: int,
    seq_len: int,
    vocab_size: int,
    n_codebooks: int = 0,
    zipf_a: float = 1.3,
) -> Dict[str, np.ndarray]:
    """Returns {"inputs", "targets"} of shape (B, S[, K]) int32.

    targets are inputs shifted by one within a (B, S+1) sample, so the
    next-token objective has real sequential structure (learnable bigrams:
    each token deterministically biases its successor).
    """
    rng = _rng(seed, step)
    shape = (global_batch, seq_len + 1)
    if n_codebooks:
        shape = shape + (n_codebooks,)
    raw = rng.zipf(zipf_a, size=shape).astype(np.int64)
    toks = (raw - 1) % vocab_size
    # Inject bigram structure: even positions seed their successor.
    succ = (toks * 31 + 7) % vocab_size
    mask = (np.arange(seq_len + 1) % 2 == 1)
    if n_codebooks:
        mask = mask[None, :, None]
    else:
        mask = mask[None, :]
    toks = np.where(mask, np.roll(succ, 1, axis=1), toks)
    toks = toks.astype(np.int32)
    return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}


def vision_batch(seed: int, step: int, *, global_batch: int, n_tokens: int,
                 d_vision: int, dtype=np.float32) -> np.ndarray:
    rng = _rng(seed, step + 1_000_003)
    return rng.standard_normal((global_batch, n_tokens, d_vision)).astype(dtype)
