from repro.data import synthetic, iris, mnist, pipeline

__all__ = ["synthetic", "iris", "mnist", "pipeline"]
