"""Fused LIF tick kernel: masked synaptic matmul + neuron state update.

This is the paper's per-neuron datapath (charge accumulation -> leak ->
threshold -> reset -> refractory) restated for the TPU memory hierarchy:

* The FPGA instantiates N parallel neuron state machines, each muxing N
  single-bit inputs. The TPU equivalent streams (bB x bK) spike tiles and
  (bK x bN) weight/connection tiles HBM->VMEM, feeds the MXU with the
  masked product, and applies the LIF nonlinearity in VREGs before the
  (bB x bN) state tiles leave VMEM -- one HBM round-trip per tick instead
  of three (matmul out, mask product, state update).
* The connection-list mask is fused into the matmul operand (``w * c``
  per tile in VMEM) so the gated synapse matrix is never materialized in
  HBM -- the mux-"routes-a-zero" semantics at zero bandwidth cost.

Grid: ``(B/bB, N/bN, K/bK)`` with K the presynaptic (contraction) axis;
K-steps accumulate into a VMEM f32 scratch; the LIF epilogue fires on the
last K step. Blocks default to MXU-aligned (128, 128, 512).

All shapes must be pre-padded to block multiples by the caller
(:mod:`repro.kernels.ops` handles padding + unpadding).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams
from repro.kernels.launch_spec import KernelLaunch, Operand, Scratch

DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 512


def lif_launch(*, B: int, K: int, N: int, dtypes: dict,
               block_b: int = DEFAULT_BLOCK_B,
               block_n: int = DEFAULT_BLOCK_N,
               block_k: int = DEFAULT_BLOCK_K) -> KernelLaunch:
    """Launch descriptor for :func:`fused_lif_step` (see
    :mod:`repro.kernels.launch_spec`).  ``dtypes`` maps ``s, w, c, v, r,
    drive, param`` to dtypes (``drive`` always present: the entry point
    substitutes a zeros placeholder when the caller passes None)."""
    bn = ((block_b, block_n), lambda i, j, k: (i, j))
    param = ((1, block_n), lambda i, j, k: (0, j))
    kn = ((block_k, block_n), lambda i, j, k: (k, j))
    inputs = [
        Operand("s", (B, K), dtypes["s"], (block_b, block_k),
                lambda i, j, k: (i, k)),
        Operand("w", (K, N), dtypes["w"], *kn),
        Operand("c", (K, N), dtypes["c"], *kn),
        Operand("v", (B, N), dtypes["v"], *bn),
        Operand("r", (B, N), dtypes["r"], *bn),
        Operand("drive", (B, N), dtypes["drive"], *bn),
    ]
    inputs += [Operand(pname, (1, N), dtypes.get(pname, dtypes["param"]),
                       *param)
               for pname in ("v_th", "leak", "r_ref", "gain", "i_bias",
                             "v_reset")]
    outputs = (Operand("v_out", (B, N), dtypes["v"], *bn),
               Operand("r_out", (B, N), dtypes["r"], *bn),
               Operand("y_out", (B, N), dtypes["s"], *bn))
    return KernelLaunch(
        name="lif_step",
        grid=(B // block_b, N // block_n, K // block_k),
        inputs=tuple(inputs),
        outputs=outputs,
        scratch=(Scratch("vmem", (block_b, block_n), jnp.float32),),
    )


def _lif_epilogue(acc, v, r, drive, v_th, leak, r_ref, gain, i_bias, v_reset, mode):
    """Shared epilogue math (f32 in VREGs)."""
    syn = acc if drive is None else acc + drive
    if mode == "euler":
        v_tilde = (1.0 - leak) * v + gain * (syn + i_bias)
    else:  # fixed_leak
        active = (v != 0).astype(jnp.float32)
        leak_step = jnp.minimum(leak * active, jnp.abs(v))
        v_tilde = v + syn + i_bias - jnp.sign(v) * leak_step
    not_ref = r == 0
    spiked = (v_tilde >= v_th) & not_ref
    hold = spiked | (r > 0)
    v_new = jnp.where(hold, v_reset, v_tilde)
    r_new = jnp.where(spiked, r_ref, jnp.maximum(r - 1, 0))
    return v_new, r_new, spiked


def _fused_kernel(
    # inputs
    s_ref, w_ref, c_ref, v_ref, r_ref_in, drive_ref,
    vth_ref, leak_ref, rref_ref, gain_ref, ibias_ref, vreset_ref,
    # outputs
    v_out_ref, r_out_ref, y_out_ref,
    # scratch
    acc_ref,
    *, mode: str, has_drive: bool,
):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Masked MXU tile: the mux fabric. w*c fused in VMEM, never in HBM.
    wc = (w_ref[...] * c_ref[...].astype(w_ref.dtype)).astype(jnp.float32)
    acc_ref[...] += jnp.dot(
        s_ref[...].astype(jnp.float32), wc, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        v = v_ref[...].astype(jnp.float32)
        r = r_ref_in[...]
        drive = drive_ref[...].astype(jnp.float32) if has_drive else None
        v_new, r_new, spiked = _lif_epilogue(
            acc_ref[...], v, r, drive,
            vth_ref[...].astype(jnp.float32),
            leak_ref[...].astype(jnp.float32),
            rref_ref[...],
            gain_ref[...].astype(jnp.float32),
            ibias_ref[...].astype(jnp.float32),
            vreset_ref[...].astype(jnp.float32),
            mode,
        )
        v_out_ref[...] = v_new.astype(v_out_ref.dtype)
        r_out_ref[...] = r_new.astype(r_out_ref.dtype)
        y_out_ref[...] = spiked.astype(y_out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "block_b", "block_n", "block_k", "interpret"),
)
def fused_lif_step(
    s: jax.Array,
    w: jax.Array,
    c: jax.Array,
    v: jax.Array,
    r: jax.Array,
    drive: Optional[jax.Array],
    v_th: jax.Array,
    leak: jax.Array,
    r_ref: jax.Array,
    gain: jax.Array,
    i_bias: jax.Array,
    v_reset: jax.Array,
    *,
    mode: str = "fixed_leak",
    block_b: int = DEFAULT_BLOCK_B,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One fused network tick. Shapes (pre-padded to block multiples):

    ``s``: (B, K) previous-tick spikes; ``w, c``: (K, N); ``v, drive``: (B, N);
    ``r``: (B, N) i32; per-neuron params: (N,) (reshaped to (1, N) blocks).
    Returns ``(v', r', y')`` each (B, N).
    """
    B, K = s.shape
    N = w.shape[1]
    if B % block_b or N % block_n or K % block_k:
        raise ValueError(
            f"shapes must be block-aligned: B={B}%{block_b}, N={N}%{block_n}, K={K}%{block_k}"
        )
    has_drive = drive is not None
    if drive is None:
        drive = jnp.zeros((B, N), v.dtype)  # placeholder operand (unread)

    row = lambda a: a.reshape(1, N)
    launch = lif_launch(
        B=B, K=K, N=N,
        dtypes={"s": s.dtype, "w": w.dtype, "c": c.dtype, "v": v.dtype,
                "r": r.dtype, "drive": drive.dtype, "param": v_th.dtype},
        block_b=block_b, block_n=block_n, block_k=block_k)

    kernel = functools.partial(_fused_kernel, mode=mode, has_drive=has_drive)
    v_new, r_new, y = pl.pallas_call(
        kernel,
        grid_spec=launch.grid_spec(),
        out_shape=launch.out_shapes(),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*launch.gather(
        {"s": s, "w": w, "c": c, "v": v, "r": r, "drive": drive,
         "v_th": row(v_th), "leak": row(leak), "r_ref": row(r_ref),
         "gain": row(gain), "i_bias": row(i_bias),
         "v_reset": row(v_reset)}))
    return v_new, r_new, y
