"""Standalone masked spike matmul kernel: ``out = s @ (w * c)``.

The building block of the fused tick kernel, exposed separately because the
scaled framework also uses it for (a) input projection through large
``w_in`` matrices and (b) the event-driven sparse-dispatch comparison
(benchmarks). Same tiling story as :mod:`repro.kernels.lif_step`: the
connection mask is applied tile-by-tile in VMEM so the gated matrix never
exists in HBM, halving weight-side HBM traffic vs a separate mask kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 512


def _kernel(s_ref, w_ref, c_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    wc = (w_ref[...] * c_ref[...].astype(w_ref.dtype)).astype(jnp.float32)
    acc_ref[...] += jnp.dot(
        s_ref[...].astype(jnp.float32), wc, preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_n", "block_k", "interpret", "out_dtype")
)
def spike_matmul(
    s: jax.Array,
    w: jax.Array,
    c: jax.Array,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """``(B,K) @ ((K,N) * (K,N)) -> (B,N)``, f32 MXU accumulation."""
    B, K = s.shape
    K2, N = w.shape
    if K != K2 or w.shape != c.shape:
        raise ValueError(f"shape mismatch: s{s.shape} w{w.shape} c{c.shape}")
    if B % block_b or N % block_n or K % block_k:
        raise ValueError(
            f"shapes must be block-aligned: B={B}%{block_b}, N={N}%{block_n}, K={K}%{block_k}"
        )
    grid = (B // block_b, N // block_n, K // block_k)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_b, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(s, w, c)
