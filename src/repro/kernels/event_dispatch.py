"""Event-driven dispatch kernel: gather spiking fan-outs, accumulate, LIF.

The paper's mux fabric routes *only* closed connections, and a silent
neuron costs nothing -- its muxes simply never fire.  The dense kernels
(:mod:`lif_step`, :mod:`tick_fused`) pay the full ``B*K*N`` masked
matmul per tick regardless of activity; at the sparse operating point
the ROADMAP cares about (large n, density <= 0.05, rate <= 0.05) almost
all of that work multiplies zeros.  This kernel is the TPU restatement
of event dispatch: per batch row, only the (at most ``k_active``)
*spiking* presynaptic neurons' fan-out slices are ever gathered out of
HBM, and they are scatter-accumulated into the synaptic-input tile in
VMEM before the shared LIF epilogue runs in VREGs.

Structure (grid ``(B, N/bN, k_active)``, the k axis walking the spike
list):

* **Spike indices ride in as scalar prefetch.**  The caller
  (:func:`repro.kernels.ops.event_lif_step`) extracts the spiking row
  ids with a tie-stable ``top_k`` -- ascending presynaptic order, so the
  accumulation visits contributions in the same order as the dense
  product and stays bit-compatible with the jnp reference.  The ids are
  *runtime data* in SMEM: the weight operand's index map reads
  ``idx_ref[b, k]`` and the pipeline DMAs exactly the one ``(1, bN)``
  fan-out slice that spike needs.  Empty spike slots point at a
  sentinel all-zero row appended to the weight matrix, so padding
  contributes nothing without any branch in the kernel body.
* **Scatter-accumulate in VMEM.**  ``acc += w[idx[b, k]]`` -- the
  gathered fan-out slice lands in the f32 accumulator tile; across the
  k grid steps this is the scatter of every active synapse into its
  postsynaptic neuron's input, at ``B*k_active*N`` adds instead of the
  dense ``B*K*N`` MACs.  Spikes are binary (the emitted raster), so no
  value multiply is needed.
* **Shared LIF epilogue.**  The last k step runs
  :func:`repro.kernels.lif_step._lif_epilogue` -- the identical
  threshold/leak/reset/refractory math every other backend uses.

Overflow (a batch row spiking more than ``k_active`` times) is handled
by the caller, not here: the bridge detects it and falls back to the
dense fused kernel (or raises under checkify), so truncation can never
silently drop spikes.  All shapes must be pre-padded to block multiples
on the N axis by the caller.

Two variants share the epilogue:

* :func:`event_lif_dispatch` -- the grid kernel above: the k axis is a
  grid dimension and the *pipeline* DMAs each spike's fan-out slice
  (sentinel slots still cost a (zero) DMA + add each).
* :func:`event_lif_dispatch_db` -- the double-buffered compact-list
  kernel: grid ``(B, N/bN)`` only; a ``fori_loop`` walks just the
  ``counts[b]`` *live* spike slots, issuing the weight-row DMA for
  spike k+1 into the alternate VMEM buffer while accumulating spike k
  (copy start -> accumulate previous -> wait).  Sentinel slots are
  never touched -- a quiet batch row costs zero DMAs -- and the weight
  matrix stays in HBM (``memory_space=ANY``), only the gathered
  ``(1, bN)`` slices ever landing in VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import numpy as np

from repro.kernels.compat import CompilerParams
from repro.kernels.launch_spec import KernelLaunch, Operand, Scratch
from repro.kernels.lif_step import _lif_epilogue

DEFAULT_BLOCK_N = 128


def _epilogue_operands(B: int, N: int, block_n: int, dtypes: dict,
                       has_drive: bool, index_arity: int):
    """The state/param/output operands every event variant shares.

    ``index_arity`` is the number of grid axes the index maps take before
    the scalar-prefetch operand(s) (grid kernel: 3, db kernel: 2)."""
    bn = (1, block_n)
    if index_arity == 3:
        map_b = lambda b, j, k, s: (b, j)
        map_p = lambda b, j, k, s: (0, j)
    else:
        map_b = lambda b, j, i, c: (b, j)
        map_p = lambda b, j, i, c: (0, j)
    state = [Operand("v", (B, N), dtypes["v"], bn, map_b),
             Operand("r", (B, N), dtypes["r"], bn, map_b)]
    if has_drive:
        state.append(Operand("drive", (B, N), dtypes["drive"], bn, map_b))
    params = [Operand(pname, (1, N), dtypes.get(pname, dtypes["param"]),
                      bn, map_p)
              for pname in ("v_th", "leak", "r_ref", "gain", "i_bias",
                            "v_reset")]
    outputs = [Operand("v_out", (B, N), dtypes["v"], bn, map_b),
               Operand("r_out", (B, N), dtypes["r"], bn, map_b),
               Operand("y_out", (B, N), dtypes["v"], bn, map_b)]
    return state, params, outputs


def event_launch(*, B: int, K: int, N: int, k_active: int, dtypes: dict,
                 has_drive: bool,
                 block_n: int = DEFAULT_BLOCK_N) -> KernelLaunch:
    """Launch descriptor for the grid variant (:func:`event_lif_dispatch`).

    ``K`` is the presynaptic row count *without* the sentinel; the weight
    operand is (K+1, N) and the lint's prefetch example is an all-sentinel
    spike list -- the worst-case row index the steered DMA can take.
    """
    # The scalar-prefetched spike list steers the DMA: only spiking rows'
    # fan-out slices ever leave HBM.
    w_op = Operand("w", (K + 1, N), dtypes["w"], (1, block_n),
                   lambda b, j, k, s: (s[b, k], j))
    state, params, outputs = _epilogue_operands(
        B, N, block_n, dtypes, has_drive, index_arity=3)
    idx_ex = np.full((B, k_active), K, np.int32)
    return KernelLaunch(
        name="event_dispatch",
        grid=(B, N // block_n, k_active),
        inputs=tuple([w_op] + state + params),
        outputs=tuple(outputs),
        scratch=(Scratch("vmem", (1, block_n), jnp.float32),),
        num_scalar_prefetch=1,
        prefetch_example=(idx_ex,),
    )


def db_dma_schedule(nb: int):
    """The double-buffered DMA protocol of ``_event_db_kernel``, as a
    concrete op list for ``nb`` live spikes.

    This is the kernel's manual-DMA twin: the kernel's control flow is
    traced (``pl.when`` + ``fori_loop``), so the analyzer cannot walk it
    -- instead this function restates the exact same protocol in plain
    Python (warmup start, prefetch-next start, wait, accumulate), and the
    semaphore-pairing lint simulates it for every ``nb``.  If the kernel
    protocol changes, change THIS function in the same commit -- the
    parity comment in ``_event_db_kernel.body`` points back here.

    Ops: ``("start", slot, k)`` begins spike ``k``'s copy into buffer
    ``slot`` (signals semaphore ``slot``); ``("wait", slot, k)`` blocks
    on semaphore ``slot``; ``("use", slot, k)`` reads buffer ``slot``
    expecting spike ``k``'s data.
    """
    ops = []
    if nb > 0:
        ops.append(("start", 0, 0))          # warmup: spike 0 -> buffer 0
    for k in range(nb):
        slot = k % 2
        if k + 1 < nb:
            # Start spike k+1's DMA into the other buffer BEFORE waiting
            # on spike k: the gather overlaps the accumulate.
            ops.append(("start", 1 - slot, k + 1))
        ops.append(("wait", slot, k))
        ops.append(("use", slot, k))
    return ops


def event_db_launch(*, B: int, K: int, N: int, k_active: int, dtypes: dict,
                    has_drive: bool,
                    block_n: int = DEFAULT_BLOCK_N) -> KernelLaunch:
    """Launch descriptor for the double-buffered compact-list variant
    (:func:`event_lif_dispatch_db`).  The weight matrix stays in HBM
    (``memory_space=ANY``); its gathers are manual DMAs described by
    :func:`db_dma_schedule`."""
    w_op = Operand("w", (K + 1, N), dtypes["w"], memory_space="any")
    state, params, outputs = _epilogue_operands(
        B, N, block_n, dtypes, has_drive, index_arity=2)
    idx_ex = np.full((B, k_active), K, np.int32)
    counts_ex = np.full((B,), k_active, np.int32)
    return KernelLaunch(
        name="event_dispatch_db",
        grid=(B, N // block_n),
        inputs=tuple([w_op] + state + params),
        outputs=tuple(outputs),
        scratch=(Scratch("vmem", (1, block_n), jnp.float32),
                 Scratch("vmem", (2, 1, block_n), dtypes["w"]),
                 Scratch("sem_dma", (2,))),
        num_scalar_prefetch=2,
        prefetch_example=(idx_ex, counts_ex),
        dma_schedule=db_dma_schedule,
    )


def _event_kernel(
    idx_ref,            # (B, k) i32 in SMEM: spiking row ids (sentinel-padded)
    *refs,
    mode: str,
    has_drive: bool,
):
    """One grid step: accumulate one spike's fan-out slice; LIF on the last."""
    it = iter(refs)
    w_ref = next(it)
    v_ref = next(it)
    r_in_ref = next(it)
    drive_ref = next(it) if has_drive else None
    vth_ref, leak_ref, rref_ref, gain_ref, ibias_ref, vreset_ref = (
        next(it), next(it), next(it), next(it), next(it), next(it))
    v_out_ref, r_out_ref, y_out_ref = next(it), next(it), next(it)
    acc_ref = next(it)

    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The index map already steered the DMA to row idx_ref[b, k]: this IS
    # the event dispatch -- one spiking neuron's fan-out lands on its
    # postsynaptic tile. Sentinel slots gathered an all-zero row.
    acc_ref[...] += w_ref[...].astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        v = v_ref[...].astype(jnp.float32)
        r = r_in_ref[...]
        drive = drive_ref[...].astype(jnp.float32) if has_drive else None
        v_new, r_new, spiked = _lif_epilogue(
            acc_ref[...], v, r, drive,
            vth_ref[...].astype(jnp.float32),
            leak_ref[...].astype(jnp.float32),
            rref_ref[...],
            gain_ref[...].astype(jnp.float32),
            ibias_ref[...].astype(jnp.float32),
            vreset_ref[...].astype(jnp.float32),
            mode,
        )
        v_out_ref[...] = v_new.astype(v_out_ref.dtype)
        r_out_ref[...] = r_new.astype(r_out_ref.dtype)
        y_out_ref[...] = spiked.astype(y_out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("mode", "block_n", "interpret"),
)
def event_lif_dispatch(
    idx: jax.Array,
    w: jax.Array,
    v: jax.Array,
    r: jax.Array,
    drive: Optional[jax.Array],
    v_th: jax.Array,
    leak: jax.Array,
    r_ref: jax.Array,
    gain: jax.Array,
    i_bias: jax.Array,
    v_reset: jax.Array,
    *,
    mode: str = "fixed_leak",
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Event tick as a single ``pallas_call``.

    Shapes (N pre-padded to ``block_n`` multiples):

    * ``idx``: (B, k_active) i32 -- spiking presynaptic row ids, ascending,
      padded with the sentinel ``K`` (scalar prefetch).
    * ``w``: (K + 1, N) effective weights ``W*C`` with an all-zero sentinel
      row appended at index ``K``.
    * ``v``/``drive``: (B, N) f32; ``r``: (B, N) i32; params: (N,).

    Returns ``(v', r', y')`` each (B, N).
    """
    B, k_active = idx.shape
    N = w.shape[1]
    if N % block_n:
        raise ValueError(f"N={N} must be a multiple of block_n={block_n}")
    if mode not in ("fixed_leak", "euler"):
        raise ValueError(f"event dispatch supports fixed_leak|euler, got {mode!r}")
    has_drive = drive is not None

    launch = event_launch(
        B=B, K=w.shape[0] - 1, N=N, k_active=k_active,
        dtypes={"w": w.dtype, "v": v.dtype, "r": r.dtype,
                "drive": drive.dtype if has_drive else None,
                "param": v_th.dtype},
        has_drive=has_drive, block_n=block_n)
    row = lambda a: a.reshape(1, N)
    arrays = {"w": w, "v": v, "r": r, "drive": drive,
              "v_th": row(v_th), "leak": row(leak), "r_ref": row(r_ref),
              "gain": row(gain), "i_bias": row(i_bias),
              "v_reset": row(v_reset)}

    kernel = functools.partial(_event_kernel, mode=mode, has_drive=has_drive)
    return pl.pallas_call(
        kernel,
        grid_spec=launch.grid_spec(),
        out_shape=launch.out_shapes(),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(idx.astype(jnp.int32), *launch.gather(arrays))


def _event_db_kernel(
    idx_ref,            # (B, k) i32 in SMEM: spiking row ids (sentinel-padded)
    counts_ref,         # (B,) i32 in SMEM: live (non-sentinel) slots per row
    *refs,
    mode: str,
    has_drive: bool,
    block_n: int,
):
    """One (b, j) tile: double-buffered walk of the compact spike list."""
    it = iter(refs)
    w_hbm_ref = next(it)    # full (K+1, N) weights, memory_space=ANY (HBM)
    v_ref = next(it)
    r_in_ref = next(it)
    drive_ref = next(it) if has_drive else None
    vth_ref, leak_ref, rref_ref, gain_ref, ibias_ref, vreset_ref = (
        next(it), next(it), next(it), next(it), next(it), next(it))
    v_out_ref, r_out_ref, y_out_ref = next(it), next(it), next(it)
    acc_ref = next(it)      # (1, block_n) f32 VMEM
    w_buf_ref = next(it)    # (2, 1, block_n) VMEM: the double buffer
    sem_ref = next(it)      # (2,) DMA semaphores, one per buffer slot

    b = pl.program_id(0)
    col = pl.program_id(1) * block_n
    nb = counts_ref[b]

    def copy_k(slot, k):
        # The gather: spike k's fan-out slice for this column tile,
        # HBM -> VMEM buffer `slot`.
        return pltpu.make_async_copy(
            w_hbm_ref.at[pl.ds(idx_ref[b, k], 1), pl.ds(col, block_n)],
            w_buf_ref.at[slot],
            sem_ref.at[slot],
        )

    acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(nb > 0)
    def _warmup():
        copy_k(0, 0).start()

    # DMA protocol twin: db_dma_schedule() restates this exact
    # start/wait/use order in plain Python for the semaphore-pairing
    # lint -- change both together.
    def body(k, carry):
        slot = jax.lax.rem(k, 2)

        @pl.when(k + 1 < nb)
        def _prefetch():
            # Start spike k+1's DMA into the other buffer BEFORE waiting
            # on spike k: the gather overlaps the accumulate.
            copy_k(1 - slot, k + 1).start()

        copy_k(slot, k).wait()
        acc_ref[...] += w_buf_ref[slot].astype(jnp.float32)
        return carry

    # Only the live slots: the loop bound IS the compact-list length, so
    # sentinel padding costs no DMA, no add -- a quiet row costs nothing.
    jax.lax.fori_loop(0, nb, body, 0)

    v = v_ref[...].astype(jnp.float32)
    r = r_in_ref[...]
    drive = drive_ref[...].astype(jnp.float32) if has_drive else None
    v_new, r_new, spiked = _lif_epilogue(
        acc_ref[...], v, r, drive,
        vth_ref[...].astype(jnp.float32),
        leak_ref[...].astype(jnp.float32),
        rref_ref[...],
        gain_ref[...].astype(jnp.float32),
        ibias_ref[...].astype(jnp.float32),
        vreset_ref[...].astype(jnp.float32),
        mode,
    )
    v_out_ref[...] = v_new.astype(v_out_ref.dtype)
    r_out_ref[...] = r_new.astype(r_out_ref.dtype)
    y_out_ref[...] = spiked.astype(y_out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("mode", "block_n", "interpret"),
)
def event_lif_dispatch_db(
    idx: jax.Array,
    w: jax.Array,
    v: jax.Array,
    r: jax.Array,
    drive: Optional[jax.Array],
    v_th: jax.Array,
    leak: jax.Array,
    r_ref: jax.Array,
    gain: jax.Array,
    i_bias: jax.Array,
    v_reset: jax.Array,
    *,
    counts: jax.Array,
    mode: str = "fixed_leak",
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Double-buffered compact-spike-list event tick (one ``pallas_call``).

    Same contract as :func:`event_lif_dispatch` plus ``counts``: (B,) i32,
    the number of live (non-sentinel) slots at the *front* of each row of
    ``idx`` (the tie-stable top-k packs real spikes first, so the compact
    list is just the prefix).  The kernel walks only that prefix with a
    two-slot VMEM buffer: spike k+1's weight-row DMA is in flight while
    spike k accumulates.  Sentinel slots cost nothing at all (the grid
    kernel pays a zero-row DMA + add for each).

    Returns ``(v', r', y')`` each (B, N).
    """
    B, k_active = idx.shape
    N = w.shape[1]
    if N % block_n:
        raise ValueError(f"N={N} must be a multiple of block_n={block_n}")
    if mode not in ("fixed_leak", "euler"):
        raise ValueError(f"event dispatch supports fixed_leak|euler, got {mode!r}")
    if counts.shape != (B,):
        raise ValueError(f"counts must be shape ({B},), got {counts.shape}")
    has_drive = drive is not None

    launch = event_db_launch(
        B=B, K=w.shape[0] - 1, N=N, k_active=k_active,
        dtypes={"w": w.dtype, "v": v.dtype, "r": r.dtype,
                "drive": drive.dtype if has_drive else None,
                "param": v_th.dtype},
        has_drive=has_drive, block_n=block_n)
    row = lambda a: a.reshape(1, N)
    arrays = {"w": w, "v": v, "r": r, "drive": drive,
              "v_th": row(v_th), "leak": row(leak), "r_ref": row(r_ref),
              "gain": row(gain), "i_bias": row(i_bias),
              "v_reset": row(v_reset)}

    kernel = functools.partial(_event_db_kernel, mode=mode,
                               has_drive=has_drive, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid_spec=launch.grid_spec(),
        out_shape=launch.out_shapes(),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(idx.astype(jnp.int32), counts.astype(jnp.int32),
      *launch.gather(arrays))
