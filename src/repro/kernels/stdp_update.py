"""Fused STDP tick kernel: trace decay + outer-product weight update.

The learning-tick datapath restated for the TPU memory hierarchy
(companion to :mod:`repro.kernels.lif_step`, which owns the inference
half of the tick):

* NeuroCoreX co-locates a trace register and a multiply-accumulate with
  every synapse cell, so learning costs zero extra memory traffic.  The
  TPU restatement: the batched pair-STDP update is two MXU matmuls
  contracted over the batch axis,

      dw = a_plus * x_pre'^T @ s_post  -  a_minus * s_pre^T @ x_post',

  computed tile-by-tile in VMEM while the weight tile is already resident
  for the update -- weights, eligibility, and traces make exactly one HBM
  round-trip per learning tick instead of four (trace decay out,
  LTP matmul out, LTD matmul out, clip/update out).
* The trace decays (``x' = decay * x + s``, one FMA in VREGs) are fused
  at the head of the same pass; the updated traces are both an output and
  the operand of the LTP/LTD products, so they never exist in HBM in
  their pre-decay form.
* The connection-list mask ``C`` gates ``dw`` in VMEM (a mux that routes
  a zero cannot learn), and the epilogue clips to the register bank's u8
  domain ``[w_min, w_max]`` so the weights stay serializable at every
  tick.

Grid: ``(K/bk, N/bn, B/bB)`` with the batch axis B innermost (the
contraction axis of both outer products); per-(i,j) partial products
accumulate into a VMEM f32 scratch and the weight/eligibility epilogue
fires on the last B step.  Trace outputs are recomputed and rewritten on
every visit of their block (their buffers are re-fetched undefined when
the grid axis their index map ignores advances).

All shapes must be pre-padded to block multiples by the caller
(:mod:`repro.kernels.ops` handles padding + unpadding; zero-padding is
exact: padded batch rows contribute 0 to both products, padded synapses
have C == 0).

Hyper-parameters enter as compile-time constants (like the LIF ``mode``)
-- they are synthesis-time learning-engine configuration; only the
*reward* is a runtime scalar (SMEM), because R-STDP's dopamine signal
changes every tick.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams
from repro.kernels.launch_spec import KernelLaunch, Operand, Scratch

DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_K = 128
DEFAULT_BLOCK_N = 128


def stdp_launch(*, B: int, K: int, N: int, dtypes: dict,
                block_b: int = DEFAULT_BLOCK_B,
                block_k: int = DEFAULT_BLOCK_K,
                block_n: int = DEFAULT_BLOCK_N) -> KernelLaunch:
    """Launch descriptor for :func:`fused_stdp_step` (see
    :mod:`repro.kernels.launch_spec`): grid ``(K/bk, N/bn, B/bB)``, batch
    innermost as the contraction axis of both outer products.  ``dtypes``
    maps ``s_pre, x_pre, s_post, x_post, w, c, elig, reward`` to dtypes.
    """
    bk = ((block_b, block_k), lambda i, j, b: (b, i))
    bn = ((block_b, block_n), lambda i, j, b: (b, j))
    kn = ((block_k, block_n), lambda i, j, b: (i, j))
    inputs = (
        Operand("s_pre", (B, K), dtypes["s_pre"], *bk),
        Operand("x_pre", (B, K), dtypes["x_pre"], *bk),
        Operand("s_post", (B, N), dtypes["s_post"], *bn),
        Operand("x_post", (B, N), dtypes["x_post"], *bn),
        Operand("w", (K, N), dtypes["w"], *kn),
        Operand("c", (K, N), dtypes["c"], *kn),
        Operand("elig", (K, N), dtypes["elig"], *kn),
        # R-STDP's dopamine scalar is runtime data: SMEM, not a constant.
        Operand("reward", (1, 1), dtypes["reward"], (1, 1),
                lambda i, j, b: (0, 0), memory_space="smem"),
    )
    outputs = (
        Operand("w_out", (K, N), dtypes["w"], *kn),
        Operand("elig_out", (K, N), dtypes["elig"], *kn),
        Operand("x_pre_out", (B, K), dtypes["x_pre"], *bk),
        Operand("x_post_out", (B, N), dtypes["x_post"], *bn),
    )
    return KernelLaunch(
        name="stdp_update",
        grid=(K // block_k, N // block_n, B // block_b),
        inputs=inputs,
        outputs=outputs,
        scratch=(Scratch("vmem", (block_k, block_n), jnp.float32),),
    )


def _stdp_kernel(
    # inputs
    spre_ref, xpre_ref, spost_ref, xpost_ref, w_ref, c_ref, elig_ref,
    reward_ref,
    # outputs
    w_out_ref, elig_out_ref, xpre_out_ref, xpost_out_ref,
    # scratch
    acc_ref,
    *,
    rule: str,
    a_plus: float,
    a_minus: float,
    decay_pre: float,
    decay_post: float,
    decay_elig: float,
    lr_reward: float,
    w_min: float,
    w_max: float,
):
    b = pl.program_id(2)
    nb = pl.num_programs(2)
    f32 = jnp.float32

    @pl.when(b == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Fused trace decay (one FMA; the traces never round-trip pre-decay).
    x_pre_new = decay_pre * xpre_ref[...].astype(f32) + spre_ref[...].astype(f32)
    x_post_new = (
        decay_post * xpost_ref[...].astype(f32) + spost_ref[...].astype(f32))

    # Batched pair STDP == two MXU products contracted over the batch axis.
    contract_b = (((0,), (0,)), ((), ()))
    ltp = jax.lax.dot_general(
        x_pre_new, spost_ref[...].astype(f32), contract_b,
        preferred_element_type=f32)
    ltd = jax.lax.dot_general(
        spre_ref[...].astype(f32), x_post_new, contract_b,
        preferred_element_type=f32)
    acc_ref[...] += a_plus * ltp - a_minus * ltd

    # Trace outputs are revisited across the grid axis their index map
    # ignores (j for x_pre, i for x_post), and a revisited output buffer is
    # re-fetched undefined -- so write on *every* visit (the value is
    # identical each time; the FMA is already in registers).
    xpre_out_ref[...] = x_pre_new.astype(xpre_out_ref.dtype)
    xpost_out_ref[...] = x_post_new.astype(xpost_out_ref.dtype)

    @pl.when(b == nb - 1)
    def _epilogue():
        cf = c_ref[...].astype(f32)
        dw = acc_ref[...] * cf                      # the mux gates learning
        w = w_ref[...].astype(f32)
        if rule == "rstdp":
            elig_new = decay_elig * elig_ref[...].astype(f32) + dw
            upd = lr_reward * reward_ref[0, 0].astype(f32) * elig_new
        else:
            elig_new = elig_ref[...].astype(f32)
            upd = dw
        # Non-plastic synapses (c == 0) pass through bit-identical (not
        # even clipped): a frozen inhibitory block may share the matrix.
        w_new = jnp.where(cf > 0, jnp.clip(w + upd, w_min, w_max), w)
        w_out_ref[...] = w_new.astype(w_out_ref.dtype)
        elig_out_ref[...] = elig_new.astype(elig_out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "rule", "a_plus", "a_minus", "decay_pre", "decay_post", "decay_elig",
        "lr_reward", "w_min", "w_max", "block_b", "block_k", "block_n",
        "interpret",
    ),
)
def fused_stdp_step(
    s_pre: jax.Array,
    x_pre: jax.Array,
    s_post: jax.Array,
    x_post: jax.Array,
    w: jax.Array,
    c: jax.Array,
    elig: jax.Array,
    reward: jax.Array,
    *,
    rule: str,
    a_plus: float,
    a_minus: float,
    decay_pre: float,
    decay_post: float,
    decay_elig: float,
    lr_reward: float,
    w_min: float,
    w_max: float,
    block_b: int = DEFAULT_BLOCK_B,
    block_k: int = DEFAULT_BLOCK_K,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One fused learning tick. Shapes (pre-padded to block multiples):

    ``s_pre, x_pre``: (B, K); ``s_post, x_post``: (B, N);
    ``w, c, elig``: (K, N); ``reward``: (1, 1) runtime scalar.
    Returns ``(w', elig', x_pre', x_post')`` -- semantics of
    :func:`repro.kernels.ref.fused_stdp_step_ref`.
    """
    B, K = s_pre.shape
    N = s_post.shape[1]
    if B % block_b or K % block_k or N % block_n:
        raise ValueError(
            f"shapes must be block-aligned: B={B}%{block_b}, "
            f"K={K}%{block_k}, N={N}%{block_n}")
    launch = stdp_launch(
        B=B, K=K, N=N,
        dtypes={"s_pre": s_pre.dtype, "x_pre": x_pre.dtype,
                "s_post": s_post.dtype, "x_post": x_post.dtype,
                "w": w.dtype, "c": c.dtype, "elig": elig.dtype,
                "reward": reward.dtype},
        block_b=block_b, block_k=block_k, block_n=block_n)

    kernel = functools.partial(
        _stdp_kernel,
        rule=rule, a_plus=a_plus, a_minus=a_minus,
        decay_pre=decay_pre, decay_post=decay_post, decay_elig=decay_elig,
        lr_reward=lr_reward, w_min=w_min, w_max=w_max,
    )
    w_new, elig_new, x_pre_new, x_post_new = pl.pallas_call(
        kernel,
        grid_spec=launch.grid_spec(),
        out_shape=launch.out_shapes(),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*launch.gather(
        {"s_pre": s_pre, "x_pre": x_pre, "s_post": s_post,
         "x_post": x_post, "w": w, "c": c, "elig": elig,
         "reward": reward.reshape(1, 1)}))
    return w_new, elig_new, x_pre_new, x_post_new
