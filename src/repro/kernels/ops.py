"""Jit'd public wrappers for the Pallas kernels.

Handles: block padding/unpadding, backend selection (real TPU Pallas vs
interpret mode on CPU -- correctness-identical), dtype plumbing, and the
bridge to :mod:`repro.core` state dataclasses.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lif import LIFState
from repro.kernels import lif_step as _lif_kernel
from repro.kernels import spike_matmul as _sm_kernel
from repro.kernels import stdp_update as _stdp_kernel
from repro.kernels import tick_fused as _tick_kernel
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _pick_block(n: int, target: int, align: int) -> int:
    """Largest block <= target that keeps padded overhead small."""
    if n >= target:
        return target
    # round n up to alignment
    return max(align, -(-n // align) * align)


def spike_matmul(
    s: jax.Array,
    w: jax.Array,
    c: jax.Array,
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Padded, backend-selected ``s @ (w*c)``; returns (B, N) f32."""
    if interpret is None:
        interpret = not _on_tpu()
    B, K = s.shape
    N = w.shape[1]
    bb = _pick_block(B, _sm_kernel.DEFAULT_BLOCK_B, 8)
    bn = _pick_block(N, _sm_kernel.DEFAULT_BLOCK_N, 128)
    bk = _pick_block(K, _sm_kernel.DEFAULT_BLOCK_K, 128)
    s_p = _pad_to(_pad_to(s, 0, bb), 1, bk)
    w_p = _pad_to(_pad_to(w, 0, bk), 1, bn)
    c_p = _pad_to(_pad_to(c, 0, bk), 1, bn)
    out = _sm_kernel.spike_matmul(
        s_p, w_p, c_p, block_b=bb, block_n=bn, block_k=bk, interpret=interpret
    )
    return out[:B, :N]


def fused_lif_step_arrays(
    s: jax.Array,
    w: jax.Array,
    c: jax.Array,
    v: jax.Array,
    r: jax.Array,
    drive: Optional[jax.Array],
    v_th: jax.Array,
    leak: jax.Array,
    r_ref: jax.Array,
    gain: jax.Array,
    i_bias: jax.Array,
    v_reset: jax.Array,
    *,
    mode: str = "fixed_leak",
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Array-level fused tick with padding; see kernel docstring."""
    if interpret is None:
        interpret = not _on_tpu()
    B, K = s.shape
    N = w.shape[1]
    bb = _pick_block(B, _lif_kernel.DEFAULT_BLOCK_B, 8)
    bn = _pick_block(N, _lif_kernel.DEFAULT_BLOCK_N, 128)
    bk = _pick_block(K, _lif_kernel.DEFAULT_BLOCK_K, 128)

    s_p = _pad_to(_pad_to(s, 0, bb), 1, bk)
    w_p = _pad_to(_pad_to(w, 0, bk), 1, bn)
    c_p = _pad_to(_pad_to(c, 0, bk), 1, bn)
    v_p = _pad_to(_pad_to(v, 0, bb), 1, bn)
    # Padded neurons must never spike: give them refractory lock + huge th.
    r_p = _pad_to(_pad_to(r, 0, bb), 1, bn, value=1)
    drive_p = None if drive is None else _pad_to(_pad_to(drive, 0, bb), 1, bn)
    big = jnp.finfo(jnp.float32).max / 2
    vth_p = _pad_to(v_th, 0, bn, value=big)
    leak_p = _pad_to(leak, 0, bn)
    rref_p = _pad_to(r_ref, 0, bn)
    gain_p = _pad_to(gain, 0, bn)
    ibias_p = _pad_to(i_bias, 0, bn)
    vreset_p = _pad_to(v_reset, 0, bn)

    v_new, r_new, y = _lif_kernel.fused_lif_step(
        s_p, w_p, c_p, v_p, r_p, drive_p,
        vth_p, leak_p, rref_p, gain_p, ibias_p, vreset_p,
        mode=mode, block_b=bb, block_n=bn, block_k=bk, interpret=interpret,
    )
    return v_new[:B, :N], r_new[:B, :N], y[:B, :N]


def fused_lif_step(
    lif_state: LIFState,
    spikes: jax.Array,
    params,  # SNNParams (avoids circular import in annotations)
    ext: Optional[jax.Array],
    *,
    mode: str = "fixed_leak",
    surrogate: bool = False,
    interpret: Optional[bool] = None,
) -> LIFState:
    """State-level bridge used by ``repro.core.network.step(backend="pallas")``.

    The fused kernel is the inference datapath; surrogate-gradient training
    uses the jnp path (the kernel has no custom VJP -- by design, matching
    the inference-only FPGA).
    """
    if surrogate:
        raise ValueError("pallas backend is inference-only; use backend='jnp' to train")
    batch_shape = lif_state.v.shape[:-1]
    n = lif_state.v.shape[-1]
    flat = lambda a: a.reshape((-1, a.shape[-1]))
    drive = None
    if ext is not None:
        drive = flat(ext) @ params.w_in
    v, r, y = fused_lif_step_arrays(
        flat(spikes), params.w, params.c, flat(lif_state.v), flat(lif_state.r), drive,
        params.lif.v_th, params.lif.leak, params.lif.r_ref,
        params.lif.gain, params.lif.i_bias, params.lif.v_reset,
        mode=mode, interpret=interpret,
    )
    unflat = lambda a: a.reshape(batch_shape + (n,))
    return LIFState(v=unflat(v), r=unflat(r), y=unflat(y))


def fused_tick(
    state,  # SNNState (avoids circular import in annotations)
    params,  # SNNParams
    ext: Optional[jax.Array],
    *,
    wc: Optional[jax.Array] = None,
    delays: Optional[jax.Array] = None,
    mode: str = "fixed_leak",
    surrogate: bool = False,
    interpret: Optional[bool] = None,
) -> Tuple[LIFState, jax.Array]:
    """Whole-tick bridge used by ``TickEngine`` (``backend="pallas_fused"``).

    One kernel launch executes the complete tick circuit -- delay-line
    slot read, masked synaptic accumulation, LIF update, delay-line slot
    write -- replacing the 4-op chain of the split backends (see
    :mod:`repro.kernels.tick_fused`). The circular read/write pointers
    ``tick % D`` / ``(tick+1) % D`` ride in as scalar-prefetch operands,
    so advancing the tick never retraces.

    Args:
      wc: pre-masked ``W*C`` (frozen path, hoisted by the caller as a
        scan constant); None streams ``w`` and ``c`` separately and masks
        per tile in VMEM (learning path -- ``params.w`` is this tick's
        mutable matrix).
      delays: optional per-synapse delay matrix ``(n, n)`` i32 in
        ``[1, max_delay]``.

    Returns:
      ``(lif_state', delay_buf')`` -- the delay buffer is returned
      unchanged when ``max_delay == 1`` (the tick never writes it, same
      as the reference path).
    """
    if surrogate:
        raise ValueError(
            "pallas_fused backend is inference-only; use backend='jnp' to train")
    if interpret is None:
        interpret = not _on_tpu()
    st = state
    batch_shape = st.lif.v.shape[:-1]
    n = st.lif.v.shape[-1]
    max_delay = st.delay_buf.shape[-2]
    flat = lambda a: a.reshape((-1, a.shape[-1]))
    v = flat(st.lif.v)
    r = flat(st.lif.r)
    B = v.shape[0]
    drive = None
    if ext is not None:
        drive = flat(ext) @ params.w_in

    slots = jnp.stack(
        [jnp.mod(st.tick, max_delay), jnp.mod(st.tick + 1, max_delay)]
    ).astype(jnp.int32)

    if delays is None and max_delay == 1:
        # Degenerate ring: arriving == previous-tick emissions, no write.
        read = flat(st.lif.y)[:, None, :]
    else:
        read = st.delay_buf.reshape((-1, max_delay, n))
    write = max_delay > 1

    w_op = params.w if wc is None else wc
    c_op = params.c if wc is None else None

    bb = _pick_block(B, _tick_kernel.DEFAULT_BLOCK_B, 8)
    bn = _pick_block(n, _tick_kernel.DEFAULT_BLOCK_N, 128)
    bk = _pick_block(n, _tick_kernel.DEFAULT_BLOCK_K, 128)

    pad_b_last = lambda a, m: _pad_to(_pad_to(a, 0, bb), a.ndim - 1, m)
    read_p = pad_b_last(read, bk)
    w_p = _pad_to(_pad_to(w_op, 0, bk), 1, bn)
    c_p = None if c_op is None else _pad_to(_pad_to(c_op, 0, bk), 1, bn)
    delays_p = None
    if delays is not None:
        delays_p = _pad_to(
            _pad_to(delays.astype(jnp.int32), 0, bk, value=1), 1, bn, value=1)
    v_p = pad_b_last(v, bn)
    # Padded neurons must never spike: give them refractory lock + huge th.
    r_p = _pad_to(_pad_to(r, 0, bb), 1, bn, value=1)
    drive_p = None if drive is None else pad_b_last(drive, bn)
    dly_full_p = pad_b_last(read, bn) if write else None
    big = jnp.finfo(jnp.float32).max / 2
    vth_p = _pad_to(params.lif.v_th, 0, bn, value=big)
    leak_p = _pad_to(params.lif.leak, 0, bn)
    rref_p = _pad_to(params.lif.r_ref, 0, bn)
    gain_p = _pad_to(params.lif.gain, 0, bn)
    ibias_p = _pad_to(params.lif.i_bias, 0, bn)
    vreset_p = _pad_to(params.lif.v_reset, 0, bn)

    v_new, r_new, y, dly_new = _tick_kernel.fused_tick(
        slots, read_p, w_p, c_p, delays_p, v_p, r_p, drive_p, dly_full_p,
        vth_p, leak_p, rref_p, gain_p, ibias_p, vreset_p,
        mode=mode, block_b=bb, block_n=bn, block_k=bk, interpret=interpret,
    )
    unflat = lambda a: a[:B, :n].reshape(batch_shape + (n,))
    lif = LIFState(v=unflat(v_new), r=unflat(r_new), y=unflat(y))
    if not write:
        return lif, st.delay_buf
    delay_buf = dly_new[:B, :, :n].reshape(batch_shape + (max_delay, n))
    return lif, delay_buf


def fused_stdp_step(
    s_pre: jax.Array,
    x_pre: jax.Array,
    s_post: jax.Array,
    x_post: jax.Array,
    w: jax.Array,
    c: jax.Array,
    elig: jax.Array,
    reward: jax.Array,
    *,
    rule: str,
    a_plus: float,
    a_minus: float,
    decay_pre: float,
    decay_post: float,
    decay_elig: float,
    lr_reward: float,
    w_min: float,
    w_max: float,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Padded, backend-selected fused learning tick; see kernel docstring.

    The state<->array bridge (batch flattening, PlasticityState rebuild)
    lives in ``repro.plasticity.rules.plasticity_step`` -- this is the
    array-level entry point it and the tests share.  Zero-padding is
    exact here: padded batch rows contribute zero to both outer products,
    padded synapses carry C == 0 (so dw == 0 there), and every padded
    region is sliced away before returning.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, K = s_pre.shape
    N = s_post.shape[1]
    bb = _pick_block(B, _stdp_kernel.DEFAULT_BLOCK_B, 8)
    bk = _pick_block(K, _stdp_kernel.DEFAULT_BLOCK_K, 128)
    bn = _pick_block(N, _stdp_kernel.DEFAULT_BLOCK_N, 128)

    pad_bk = lambda a: _pad_to(_pad_to(a, 0, bb), 1, bk)
    pad_bn = lambda a: _pad_to(_pad_to(a, 0, bb), 1, bn)
    pad_kn = lambda a: _pad_to(_pad_to(a, 0, bk), 1, bn)

    w_new, elig_new, x_pre_new, x_post_new = _stdp_kernel.fused_stdp_step(
        pad_bk(s_pre), pad_bk(x_pre), pad_bn(s_post), pad_bn(x_post),
        pad_kn(w), pad_kn(c), pad_kn(elig),
        jnp.asarray(reward, jnp.float32),
        rule=rule, a_plus=a_plus, a_minus=a_minus,
        decay_pre=decay_pre, decay_post=decay_post, decay_elig=decay_elig,
        lr_reward=lr_reward, w_min=w_min, w_max=w_max,
        block_b=bb, block_k=bk, block_n=bn, interpret=interpret,
    )
    return (
        w_new[:K, :N], elig_new[:K, :N],
        x_pre_new[:B, :K], x_post_new[:B, :N],
    )


def fused_lif_step_slots(
    lif_state: LIFState,
    spikes: jax.Array,
    params,  # SNNParams with a leading slot axis on every leaf
    ext: Optional[jax.Array],
    *,
    mode: str = "fixed_leak",
    surrogate: bool = False,
    interpret: Optional[bool] = None,
) -> LIFState:
    """Slot-batched fused tick: S resident networks, one program.

    Every leaf of ``lif_state`` / ``params`` (and ``spikes`` / ``ext``)
    carries a leading *slot* axis of length S -- S independent register
    images time-sharing one compiled datapath, the serving restatement of
    the paper's one-fabric-many-networks claim.  Implemented as ``vmap``
    over :func:`fused_lif_step`, which the Pallas batching rule lowers to
    an extra grid dimension (interpret mode on CPU is identical).

    ``launch.serve.SNNServer`` reaches the same lowering by vmapping the
    whole engine rollout over the slot axis (so one vmap covers the
    plasticity hook too); this array-level entry point is for callers
    that drive single ticks of many resident networks directly --
    equivalence against the per-slot loop is pinned in
    tests/test_serve_snn.py.
    """
    f = functools.partial(fused_lif_step, mode=mode, surrogate=surrogate,
                          interpret=interpret)
    if ext is None:
        return jax.vmap(lambda st, sp, p: f(st, sp, p, None))(
            lif_state, spikes, params)
    return jax.vmap(f)(lif_state, spikes, params, ext)


def event_spike_matmul(
    s: jax.Array, w: jax.Array, c: jax.Array, *, k_active: int
) -> jax.Array:
    """Beyond-paper event-driven dispatch (pure JAX, MXU-friendly).

    Instead of the dense (B,K)x(K,N) product, gather the fan-out rows of at
    most ``k_active`` spiking presynaptic neurons per batch row and reduce:
    FLOPs drop from ``B*K*N`` to ``B*k_active*N`` -- the TPU analogue of the
    paper's mux fabric *not even routing* silent neurons. Exact whenever the
    per-row spike count <= k_active (guaranteed by construction at low rates;
    validated against the dense oracle in tests).
    """
    B, K = s.shape
    wc = w * c.astype(w.dtype)
    # Top-k by spike value (1.0 beats 0.0); ties broken by index -- fine,
    # since any selected silent neuron contributes s=0 anyway.
    vals, idx = jax.lax.top_k(s, k_active)                    # (B, k)
    rows = jnp.take(wc, idx.reshape(-1), axis=0)              # (B*k, N)
    rows = rows.reshape(B, k_active, -1)
    return jnp.einsum("bk,bkn->bn", vals.astype(jnp.float32), rows.astype(jnp.float32))


# Re-export oracles for test convenience.
ref = _ref
