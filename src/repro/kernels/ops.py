"""Jit'd public wrappers for the Pallas kernels.

Handles: block padding/unpadding, backend selection (real TPU Pallas vs
interpret mode on CPU -- correctness-identical), dtype plumbing, and the
bridge to :mod:`repro.core` state dataclasses.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lif import LIFState
from repro.kernels import lif_step as _lif_kernel
from repro.kernels import spike_matmul as _sm_kernel
from repro.kernels import stdp_update as _stdp_kernel
from repro.kernels import tick_fused as _tick_kernel
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _pick_block(n: int, target: int, align: int) -> int:
    """Largest block <= target that keeps padded overhead small."""
    if n >= target:
        return target
    # round n up to alignment
    return max(align, -(-n // align) * align)


def spike_matmul(
    s: jax.Array,
    w: jax.Array,
    c: jax.Array,
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Padded, backend-selected ``s @ (w*c)``; returns (B, N) f32."""
    if interpret is None:
        interpret = not _on_tpu()
    B, K = s.shape
    N = w.shape[1]
    bb = _pick_block(B, _sm_kernel.DEFAULT_BLOCK_B, 8)
    bn = _pick_block(N, _sm_kernel.DEFAULT_BLOCK_N, 128)
    bk = _pick_block(K, _sm_kernel.DEFAULT_BLOCK_K, 128)
    s_p = _pad_to(_pad_to(s, 0, bb), 1, bk)
    w_p = _pad_to(_pad_to(w, 0, bk), 1, bn)
    c_p = _pad_to(_pad_to(c, 0, bk), 1, bn)
    out = _sm_kernel.spike_matmul(
        s_p, w_p, c_p, block_b=bb, block_n=bn, block_k=bk, interpret=interpret
    )
    return out[:B, :N]


def fused_lif_step_arrays(
    s: jax.Array,
    w: jax.Array,
    c: jax.Array,
    v: jax.Array,
    r: jax.Array,
    drive: Optional[jax.Array],
    v_th: jax.Array,
    leak: jax.Array,
    r_ref: jax.Array,
    gain: jax.Array,
    i_bias: jax.Array,
    v_reset: jax.Array,
    *,
    mode: str = "fixed_leak",
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Array-level fused tick with padding; see kernel docstring."""
    if interpret is None:
        interpret = not _on_tpu()
    B, K = s.shape
    N = w.shape[1]
    bb = _pick_block(B, _lif_kernel.DEFAULT_BLOCK_B, 8)
    bn = _pick_block(N, _lif_kernel.DEFAULT_BLOCK_N, 128)
    bk = _pick_block(K, _lif_kernel.DEFAULT_BLOCK_K, 128)

    s_p = _pad_to(_pad_to(s, 0, bb), 1, bk)
    w_p = _pad_to(_pad_to(w, 0, bk), 1, bn)
    c_p = _pad_to(_pad_to(c, 0, bk), 1, bn)
    v_p = _pad_to(_pad_to(v, 0, bb), 1, bn)
    # Padded neurons must never spike: give them refractory lock + huge th.
    r_p = _pad_to(_pad_to(r, 0, bb), 1, bn, value=1)
    drive_p = None if drive is None else _pad_to(_pad_to(drive, 0, bb), 1, bn)
    big = jnp.finfo(jnp.float32).max / 2
    vth_p = _pad_to(v_th, 0, bn, value=big)
    leak_p = _pad_to(leak, 0, bn)
    rref_p = _pad_to(r_ref, 0, bn)
    gain_p = _pad_to(gain, 0, bn)
    ibias_p = _pad_to(i_bias, 0, bn)
    vreset_p = _pad_to(v_reset, 0, bn)

    v_new, r_new, y = _lif_kernel.fused_lif_step(
        s_p, w_p, c_p, v_p, r_p, drive_p,
        vth_p, leak_p, rref_p, gain_p, ibias_p, vreset_p,
        mode=mode, block_b=bb, block_n=bn, block_k=bk, interpret=interpret,
    )
    return v_new[:B, :N], r_new[:B, :N], y[:B, :N]


def fused_lif_step(
    lif_state: LIFState,
    spikes: jax.Array,
    params,  # SNNParams (avoids circular import in annotations)
    ext: Optional[jax.Array],
    *,
    mode: str = "fixed_leak",
    surrogate: bool = False,
    interpret: Optional[bool] = None,
) -> LIFState:
    """State-level bridge used by ``repro.core.network.step(backend="pallas")``.

    The fused kernel is the inference datapath; surrogate-gradient training
    uses the jnp path (the kernel has no custom VJP -- by design, matching
    the inference-only FPGA).
    """
    if surrogate:
        raise ValueError("pallas backend is inference-only; use backend='jnp' to train")
    batch_shape = lif_state.v.shape[:-1]
    n = lif_state.v.shape[-1]
    flat = lambda a: a.reshape((-1, a.shape[-1]))
    drive = None
    if ext is not None:
        drive = flat(ext) @ params.w_in
    v, r, y = fused_lif_step_arrays(
        flat(spikes), params.w, params.c, flat(lif_state.v), flat(lif_state.r), drive,
        params.lif.v_th, params.lif.leak, params.lif.r_ref,
        params.lif.gain, params.lif.i_bias, params.lif.v_reset,
        mode=mode, interpret=interpret,
    )
    unflat = lambda a: a.reshape(batch_shape + (n,))
    return LIFState(v=unflat(v), r=unflat(r), y=unflat(y))


def fused_tick(
    state,  # SNNState (avoids circular import in annotations)
    params,  # SNNParams
    ext: Optional[jax.Array],
    *,
    wc: Optional[jax.Array] = None,
    delays: Optional[jax.Array] = None,
    mode: str = "fixed_leak",
    surrogate: bool = False,
    interpret: Optional[bool] = None,
) -> Tuple[LIFState, jax.Array]:
    """Whole-tick bridge used by ``TickEngine`` (``backend="pallas_fused"``).

    One kernel launch executes the complete tick circuit -- delay-line
    slot read, masked synaptic accumulation, LIF update, delay-line slot
    write -- replacing the 4-op chain of the split backends (see
    :mod:`repro.kernels.tick_fused`). The circular read/write pointers
    ``tick % D`` / ``(tick+1) % D`` ride in as scalar-prefetch operands,
    so advancing the tick never retraces.

    Args:
      wc: pre-masked ``W*C`` (frozen path, hoisted by the caller as a
        scan constant); None streams ``w`` and ``c`` separately and masks
        per tile in VMEM (learning path -- ``params.w`` is this tick's
        mutable matrix).
      delays: optional per-synapse delay matrix ``(n, n)`` i32 in
        ``[1, max_delay]``.

    Returns:
      ``(lif_state', delay_buf')`` -- the delay buffer is returned
      unchanged when ``max_delay == 1`` (the tick never writes it, same
      as the reference path).
    """
    if surrogate:
        raise ValueError(
            "pallas_fused backend is inference-only; use backend='jnp' to train")
    if interpret is None:
        interpret = not _on_tpu()
    st = state
    batch_shape = st.lif.v.shape[:-1]
    n = st.lif.v.shape[-1]
    max_delay = st.delay_buf.shape[-2]
    flat = lambda a: a.reshape((-1, a.shape[-1]))
    v = flat(st.lif.v)
    r = flat(st.lif.r)
    B = v.shape[0]
    drive = None
    if ext is not None:
        drive = flat(ext) @ params.w_in

    slots = jnp.stack(
        [jnp.mod(st.tick, max_delay), jnp.mod(st.tick + 1, max_delay)]
    ).astype(jnp.int32)

    if delays is None and max_delay == 1:
        # Degenerate ring: arriving == previous-tick emissions, no write.
        read = flat(st.lif.y)[:, None, :]
    else:
        read = st.delay_buf.reshape((-1, max_delay, n))
    write = max_delay > 1

    w_op = params.w if wc is None else wc
    c_op = params.c if wc is None else None

    bb = _pick_block(B, _tick_kernel.DEFAULT_BLOCK_B, 8)
    bn = _pick_block(n, _tick_kernel.DEFAULT_BLOCK_N, 128)
    bk = _pick_block(n, _tick_kernel.DEFAULT_BLOCK_K, 128)

    pad_b_last = lambda a, m: _pad_to(_pad_to(a, 0, bb), a.ndim - 1, m)
    read_p = pad_b_last(read, bk)
    w_p = _pad_to(_pad_to(w_op, 0, bk), 1, bn)
    c_p = None if c_op is None else _pad_to(_pad_to(c_op, 0, bk), 1, bn)
    delays_p = None
    if delays is not None:
        delays_p = _pad_to(
            _pad_to(delays.astype(jnp.int32), 0, bk, value=1), 1, bn, value=1)
    v_p = pad_b_last(v, bn)
    # Padded neurons must never spike: give them refractory lock + huge th.
    r_p = _pad_to(_pad_to(r, 0, bb), 1, bn, value=1)
    drive_p = None if drive is None else pad_b_last(drive, bn)
    dly_full_p = pad_b_last(read, bn) if write else None
    big = jnp.finfo(jnp.float32).max / 2
    vth_p = _pad_to(params.lif.v_th, 0, bn, value=big)
    leak_p = _pad_to(params.lif.leak, 0, bn)
    rref_p = _pad_to(params.lif.r_ref, 0, bn)
    gain_p = _pad_to(params.lif.gain, 0, bn)
    ibias_p = _pad_to(params.lif.i_bias, 0, bn)
    vreset_p = _pad_to(params.lif.v_reset, 0, bn)

    v_new, r_new, y, dly_new = _tick_kernel.fused_tick(
        slots, read_p, w_p, c_p, delays_p, v_p, r_p, drive_p, dly_full_p,
        vth_p, leak_p, rref_p, gain_p, ibias_p, vreset_p,
        mode=mode, block_b=bb, block_n=bn, block_k=bk, interpret=interpret,
    )
    unflat = lambda a: a[:B, :n].reshape(batch_shape + (n,))
    lif = LIFState(v=unflat(v_new), r=unflat(r_new), y=unflat(y))
    if not write:
        return lif, st.delay_buf
    delay_buf = dly_new[:B, :, :n].reshape(batch_shape + (max_delay, n))
    return lif, delay_buf


def fused_stdp_step(
    s_pre: jax.Array,
    x_pre: jax.Array,
    s_post: jax.Array,
    x_post: jax.Array,
    w: jax.Array,
    c: jax.Array,
    elig: jax.Array,
    reward: jax.Array,
    *,
    rule: str,
    a_plus: float,
    a_minus: float,
    decay_pre: float,
    decay_post: float,
    decay_elig: float,
    lr_reward: float,
    w_min: float,
    w_max: float,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Padded, backend-selected fused learning tick; see kernel docstring.

    The state<->array bridge (batch flattening, PlasticityState rebuild)
    lives in ``repro.plasticity.rules.plasticity_step`` -- this is the
    array-level entry point it and the tests share.  Zero-padding is
    exact here: padded batch rows contribute zero to both outer products,
    padded synapses carry C == 0 (so dw == 0 there), and every padded
    region is sliced away before returning.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, K = s_pre.shape
    N = s_post.shape[1]
    bb = _pick_block(B, _stdp_kernel.DEFAULT_BLOCK_B, 8)
    bk = _pick_block(K, _stdp_kernel.DEFAULT_BLOCK_K, 128)
    bn = _pick_block(N, _stdp_kernel.DEFAULT_BLOCK_N, 128)

    pad_bk = lambda a: _pad_to(_pad_to(a, 0, bb), 1, bk)
    pad_bn = lambda a: _pad_to(_pad_to(a, 0, bb), 1, bn)
    pad_kn = lambda a: _pad_to(_pad_to(a, 0, bk), 1, bn)

    w_new, elig_new, x_pre_new, x_post_new = _stdp_kernel.fused_stdp_step(
        pad_bk(s_pre), pad_bk(x_pre), pad_bn(s_post), pad_bn(x_post),
        pad_kn(w), pad_kn(c), pad_kn(elig),
        jnp.asarray(reward, jnp.float32),
        rule=rule, a_plus=a_plus, a_minus=a_minus,
        decay_pre=decay_pre, decay_post=decay_post, decay_elig=decay_elig,
        lr_reward=lr_reward, w_min=w_min, w_max=w_max,
        block_b=bb, block_k=bk, block_n=bn, interpret=interpret,
    )
    return (
        w_new[:K, :N], elig_new[:K, :N],
        x_pre_new[:B, :K], x_post_new[:B, :N],
    )


def fused_lif_step_slots(
    lif_state: LIFState,
    spikes: jax.Array,
    params,  # SNNParams with a leading slot axis on every leaf
    ext: Optional[jax.Array],
    *,
    mode: str = "fixed_leak",
    surrogate: bool = False,
    interpret: Optional[bool] = None,
) -> LIFState:
    """Slot-batched fused tick: S resident networks, one program.

    Every leaf of ``lif_state`` / ``params`` (and ``spikes`` / ``ext``)
    carries a leading *slot* axis of length S -- S independent register
    images time-sharing one compiled datapath, the serving restatement of
    the paper's one-fabric-many-networks claim.  Implemented as ``vmap``
    over :func:`fused_lif_step`, which the Pallas batching rule lowers to
    an extra grid dimension (interpret mode on CPU is identical).

    ``launch.serve.SNNServer`` reaches the same lowering by vmapping the
    whole engine rollout over the slot axis (so one vmap covers the
    plasticity hook too); this array-level entry point is for callers
    that drive single ticks of many resident networks directly --
    equivalence against the per-slot loop is pinned in
    tests/test_serve_snn.py.
    """
    f = functools.partial(fused_lif_step, mode=mode, surrogate=surrogate,
                          interpret=interpret)
    if ext is None:
        return jax.vmap(lambda st, sp, p: f(st, sp, p, None))(
            lif_state, spikes, params)
    return jax.vmap(f)(lif_state, spikes, params, ext)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EventFanIn:
    """Device-side padded fan-in lists (the event backend's gather layout).

    ``idx[m, j]`` is the j-th presynaptic source of postsynaptic neuron
    ``m`` (ascending, 0-padded); ``mask`` gates padding to 0.  Built once
    per topology from :func:`repro.core.connectivity.padded_fan_in` via
    :meth:`from_padded` -- like the connection list itself it is runtime
    *data*, so swapping topologies of equal cap never retraces.
    """

    idx: jax.Array           # (n, cap) int32
    mask: jax.Array          # (n, cap) float32

    @classmethod
    def from_padded(cls, nbrs) -> "EventFanIn":
        if nbrs.axis != "in":
            raise ValueError(
                f"EventFanIn needs fan-in lists (axis='in'), got {nbrs.axis!r}")
        return cls(idx=jnp.asarray(nbrs.idx, jnp.int32),
                   mask=jnp.asarray(nbrs.mask, jnp.float32))

    @classmethod
    def from_dense(cls, c, cap: Optional[int] = None) -> "EventFanIn":
        from repro.core import connectivity
        import numpy as np

        return cls.from_padded(
            connectivity.padded_fan_in(np.asarray(c) > 0, cap))


def default_k_active(n: int) -> int:
    """Default spike-slot budget for the top-k event path: n/8, floored at 8
    (matches the bench cost model's ``2*rate*n`` at rate ~0.06).

    Thin alias over :func:`repro.core.dispatch_policy.resolve_k_active`
    (with ``k_active=None``) -- the single source of the trigger that the
    engine's telemetry mirror and the kernel bridge also use.
    """
    from repro.core.dispatch_policy import resolve_k_active

    return resolve_k_active(n, None)


def event_synaptic_input(
    s: jax.Array,
    wc: jax.Array,
    *,
    k_active: Optional[int] = None,
    fan_in: Optional[EventFanIn] = None,
    overflow: str = "fallback",
) -> jax.Array:
    """Event-driven synaptic input: the pure-jnp reference the ``"event"``
    backend and the Pallas dispatch kernel both answer to.

    Two dispatch strategies, both exploiting what the paper's mux fabric
    exploits (an open mux routes nothing; a silent neuron costs nothing):

    * **top-k spike gather** (default): select the (at most ``k_active``)
      spiking presynaptic rows per batch element, gather their fan-out
      slices of ``wc`` and reduce -- ``B*k_active*N`` FLOPs instead of
      ``B*K*N``.  ``jax.lax.top_k`` is tie-stable, so the gathered rows
      come out in ascending presynaptic order and the reduction sums the
      same nonzero terms in the same order as the dense product.
    * **fan-in gather** (``fan_in`` given): for every postsynaptic neuron
      read exactly its padded in-edge list -- ``B*N*cap`` FLOPs, no
      data-dependent control flow at all (safe under ``vmap``, which is
      how the multi-tenant server runs it).

    Args:
      s: ``(..., K)`` presynaptic spikes in {0, 1}.
      wc: ``(K, N)`` pre-masked effective matrix ``W*C``.
      k_active: spike-slot budget for the top-k path (None -> ``K//8``,
        floored at 8).  Ignored when ``fan_in`` is given.
      fan_in: optional :class:`EventFanIn` switching to the gather path.
      overflow: what the top-k path does when some batch row spikes more
        than ``k_active`` times (where truncation would silently drop real
        spikes -- the bug this argument exists to kill):

        * ``"fallback"`` (default): detect ``s.sum(-1) > k_active`` and
          compute the dense product instead -- exact at any rate, and the
          scalar ``lax.cond`` only pays for the dense branch on ticks
          that overflow (outside ``vmap``).
        * ``"strict"``: fail under :mod:`jax.experimental.checkify`
          instead of falling back (run the caller through
          ``checkify.checkify`` to surface the error).
        * ``"unchecked"``: no detection -- caller guarantees the rate.
    """
    K = s.shape[-1]
    if fan_in is not None:
        # Gather path: s[..., idx] is (..., N, cap); the per-edge weights
        # wc[idx[m, j], m] come straight off the dense matrix, so the same
        # call serves frozen (hoisted wc) and learning (per-tick wc) paths.
        n = wc.shape[1]
        w_edges = wc[fan_in.idx, jnp.arange(n)[:, None]] * fan_in.mask
        gathered = s[..., fan_in.idx]                       # (..., n, cap)
        return jnp.einsum("...nc,nc->...n", gathered.astype(jnp.float32),
                          w_edges.astype(jnp.float32))

    from repro.core.dispatch_policy import resolve_k_active

    k_active = resolve_k_active(K, k_active)

    def dense(sv):
        return sv.astype(jnp.float32) @ wc.astype(jnp.float32)

    def event(sv):
        # Top-k by spike value (1.0 beats 0.0); ties broken by lower index,
        # so spiking rows arrive in ascending presynaptic order.
        vals, idx = jax.lax.top_k(sv, k_active)             # (..., k)
        rows = jnp.take(wc, idx, axis=0)                    # (..., k, N)
        return jnp.einsum("...k,...kn->...n", vals.astype(jnp.float32),
                          rows.astype(jnp.float32))

    if overflow == "unchecked":
        return event(s)
    n_spiking = jnp.sum(s > 0, axis=-1)
    over = jnp.any(n_spiking > k_active)
    if overflow == "strict":
        from jax.experimental import checkify

        checkify.check(
            jnp.logical_not(over),
            "event dispatch overflow: {m} spiking rows > k_active={k}",
            m=jnp.max(n_spiking), k=jnp.asarray(k_active))
        return event(s)
    if overflow != "fallback":
        raise ValueError(f"unknown overflow mode {overflow!r}")
    return jax.lax.cond(over, dense, event, s)


def event_spike_matmul(
    s: jax.Array, w: jax.Array, c: jax.Array, *, k_active: int,
    overflow: str = "fallback",
) -> jax.Array:
    """Beyond-paper event-driven dispatch (pure JAX, MXU-friendly).

    Instead of the dense (B,K)x(K,N) product, gather the fan-out rows of at
    most ``k_active`` spiking presynaptic neurons per batch row and reduce:
    FLOPs drop from ``B*K*N`` to ``B*k_active*N`` -- the TPU analogue of the
    paper's mux fabric *not even routing* silent neurons.

    Exact at *any* rate: rows with more than ``k_active`` spikes used to be
    silently truncated by the top-k (dropping real spikes and returning a
    wrong synaptic input); the overflow is now detected and falls back to
    the dense product (or raises -- ``overflow="strict"`` under checkify).
    See :func:`event_synaptic_input` for the modes.
    """
    wc = w * c.astype(w.dtype)
    return event_synaptic_input(s, wc, k_active=k_active, overflow=overflow)


def event_lif_step(
    lif_state: LIFState,
    spikes: jax.Array,
    params,  # SNNParams (avoids circular import in annotations)
    ext: Optional[jax.Array],
    wc: jax.Array,
    *,
    k_active: Optional[int] = None,
    fan_in: Optional[EventFanIn] = None,
    overflow: str = "fallback",
    mode: str = "fixed_leak",
    surrogate: bool = False,
    ext_diag: bool = False,
    use_kernel: Optional[bool] = None,
    kernel: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> LIFState:
    """State-level bridge for ``TickEngine(backend="event")``.

    On TPU the top-k path lowers to a Pallas event-dispatch kernel
    (:mod:`repro.kernels.event_dispatch`): spike indices ride in as scalar
    prefetch and only the spiking rows' fan-out slices ever leave HBM.
    ``kernel`` picks the variant -- ``"db"`` (default on TPU) is the
    double-buffered compact-spike-list kernel that prefetches row k+1's
    fan-out slice while accumulating row k and skips sentinel slots
    entirely; ``"grid"`` is the BlockSpec-steered grid kernel.  On CPU
    (and for the fan-in gather / surrogate paths) the pure-jnp reference
    above *is* the implementation -- XLA already executes the gathers
    natively, so interpret-mode emulation would only add overhead.

    ``ext_diag=True`` computes the external drive as the elementwise
    ``ext * diag(w_in)`` instead of the full ``ext @ w_in`` GEMM --
    bit-identical when ``w_in`` is diagonal (the caller's contract;
    :func:`repro.core.dispatch_policy.is_diagonal` checks it).
    """
    if use_kernel is None:
        use_kernel = _on_tpu() and fan_in is None and not surrogate

    def _drive_of(e):
        if e is None:
            return None
        if ext_diag:
            return e * jnp.diagonal(params.w_in)
        return e @ params.w_in

    if use_kernel:
        from repro.kernels import event_dispatch as _ev_kernel

        if surrogate:
            raise ValueError(
                "event kernel path is inference-only; use the jnp path to train")
        if kernel is None:
            kernel = "db"
        if kernel not in ("db", "grid"):
            raise ValueError(f"kernel must be 'db' or 'grid', got {kernel!r}")
        from repro.core.dispatch_policy import resolve_k_active

        batch_shape = lif_state.v.shape[:-1]
        n = lif_state.v.shape[-1]
        flat = lambda a: a.reshape((-1, a.shape[-1]))
        s = flat(spikes)
        B, K = s.shape
        k = resolve_k_active(K, k_active)
        drive = _drive_of(None if ext is None else flat(ext))
        vals, idx = jax.lax.top_k(s, k)
        # Padded slots point at the sentinel zero row appended below.
        idx = jnp.where(vals > 0, idx, K).astype(jnp.int32)
        # Per-row live-slot count: top_k packs the 1.0s first, so the
        # first counts[b] slots are the real spiking rows (ascending) and
        # the double-buffered kernel never touches the sentinel tail.
        counts = jnp.sum(vals > 0, axis=-1).astype(jnp.int32)
        bn = _pick_block(n, _ev_kernel.DEFAULT_BLOCK_N, 128)
        pad_n = lambda a, v=0: _pad_to(a, a.ndim - 1, bn, value=v)
        wc_p = pad_n(jnp.concatenate(
            [wc, jnp.zeros((1, wc.shape[1]), wc.dtype)], axis=0))
        v_p = pad_n(flat(lif_state.v))
        r_p = pad_n(flat(lif_state.r), 1)   # padded neurons: refractory lock
        drive_p = None if drive is None else pad_n(drive)
        big = jnp.finfo(jnp.float32).max / 2
        lp = params.lif

        def event(_):
            dispatch = (_ev_kernel.event_lif_dispatch_db if kernel == "db"
                        else _ev_kernel.event_lif_dispatch)
            kw = dict(counts=counts) if kernel == "db" else {}
            v_new, r_new, y = dispatch(
                idx, wc_p, v_p, r_p, drive_p,
                _pad_to(lp.v_th, 0, bn, value=big), _pad_to(lp.leak, 0, bn),
                _pad_to(lp.r_ref, 0, bn), _pad_to(lp.gain, 0, bn),
                _pad_to(lp.i_bias, 0, bn), _pad_to(lp.v_reset, 0, bn),
                mode=mode, block_n=bn,
                interpret=not _on_tpu() if interpret is None else interpret,
                **kw,
            )
            return v_new[:, :n], r_new[:, :n], y[:, :n]

        n_spiking = jnp.sum(s > 0, axis=-1)
        if overflow == "fallback":
            # The kernel's k slots truncate past k_active; overflow ticks
            # take the dense fused kernel instead (exact at any rate).
            def dense(_):
                return fused_lif_step_arrays(
                    s, wc, jnp.ones_like(wc), flat(lif_state.v),
                    flat(lif_state.r), drive, lp.v_th, lp.leak, lp.r_ref,
                    lp.gain, lp.i_bias, lp.v_reset,
                    mode=mode, interpret=interpret)

            v_new, r_new, y = jax.lax.cond(
                jnp.any(n_spiking > k), dense, event, 0)
        else:
            if overflow == "strict":
                from jax.experimental import checkify

                checkify.check(
                    jnp.logical_not(jnp.any(n_spiking > k)),
                    "event dispatch overflow: {m} spiking rows > k_active={k}",
                    m=jnp.max(n_spiking), k=jnp.asarray(k))
            v_new, r_new, y = event(0)
        unflat = lambda a: a.reshape(batch_shape + (n,))
        return LIFState(v=unflat(v_new), r=unflat(r_new), y=unflat(y))

    from repro.core.lif import lif_step

    syn = event_synaptic_input(spikes, wc, k_active=k_active, fan_in=fan_in,
                               overflow=overflow)
    if ext is not None:
        syn = syn + _drive_of(ext)
    return lif_step(lif_state, syn, params.lif, mode=mode, surrogate=surrogate)


# Re-export oracles for test convenience.
ref = _ref
