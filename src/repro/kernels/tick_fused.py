"""Whole-tick fused kernel: delay read -> masked matmul -> LIF -> delay write.

The paper's datapath is ONE resident circuit that completes the entire
tick -- delay-line slot read, all-to-all masked synaptic accumulation,
LIF update, delay-line slot write -- before the next tick starts; that
single-circuit property is why the FPGA hits its latency numbers.
:mod:`repro.kernels.lif_step` fused the *middle* of that tick (matmul +
LIF) but still left the delay-line read and write as separate XLA ops,
i.e. two extra HBM round-trips per tick on the raster and the delay
buffer. This kernel closes the loop: one ``pallas_call`` per tick is the
whole circuit.

Structure (grid ``(B/bB, N/bN, K/bK)``, K the presynaptic contraction
axis, K-steps accumulating into a VMEM f32 scratch):

* **Delay-line read at zero cost.** The circular read pointer
  ``slot = tick % D`` is a *runtime scalar*, so the slot cannot be baked
  into a BlockSpec constant without retracing every tick. It rides in as
  a scalar-prefetch operand (``pltpu.PrefetchScalarGridSpec``): the
  index map of the delay-buffer operand reads ``slots_ref[0]`` and the
  pipeline DMAs exactly the one ``(bB, 1, bK)`` slot tile the tick
  needs -- the read costs the same HBM traffic as a plain spike-vector
  load, and changing ``tick`` never recompiles.
* **Masked accumulation.** Same as :mod:`lif_step`: ``w*c`` fused per
  tile in VMEM (the mux that routes a zero, at zero bandwidth), double-
  buffered by the Pallas pipeline across K steps. The frozen path passes
  a pre-masked ``W*C`` scan constant instead (no ``c`` operand at all --
  half the weight-side traffic); the learning path streams ``w`` and
  ``c`` separately because ``w`` changes every tick.
* **Per-synapse delays.** With a delay matrix, synapse ``(pre, post)``
  with delay ``d`` reads history slot ``(slot - (d-1)) % D``. The kernel
  loads the full ``(bB, D, bK)`` history tile, builds the d-major
  flattened ``(bB, D*bK) @ (D*bK, bN)`` product with per-delay masked
  weight planes -- the same contraction, in the same d-major order, as
  the reference einsum in ``TickEngine.tick_body``.
* **LIF epilogue + delay-line write.** On the last K step the shared
  :func:`repro.kernels.lif_step._lif_epilogue` runs in VREGs and the
  fresh spikes are stored into write slot ``slots_ref[1] = (tick+1) % D``
  of the output delay buffer (the other ``D-1`` slots stream through
  unchanged from the input tile).

All shapes must be pre-padded to block multiples by the caller
(:func:`repro.kernels.ops.fused_tick` handles padding, slot scalars,
and the state-dataclass bridge).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import numpy as np

from repro.kernels.compat import CompilerParams
from repro.kernels.launch_spec import KernelLaunch, Operand, Scratch
from repro.kernels.lif_step import _lif_epilogue

DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 512


def _tick_kernel(
    slots_ref,          # (2,) i32 in SMEM: [read_slot, write_slot]
    *refs,
    mode: str,
    n_delay: int,
    has_c: bool,
    has_delays: bool,
    has_drive: bool,
    write_delay: bool,
):
    """One grid step of the whole-tick circuit.

    ``refs`` carries, in order: the variable-presence inputs
    (``dly_read, w, [c], [delays], v, r, [drive], [dly_full]``), the six
    per-neuron parameter rows, the outputs (``v', r', y', [dly']``), and
    the f32 accumulator scratch.
    """
    it = iter(refs)
    dly_read_ref = next(it)
    w_ref = next(it)
    c_ref = next(it) if has_c else None
    delays_ref = next(it) if has_delays else None
    v_ref = next(it)
    r_in_ref = next(it)
    drive_ref = next(it) if has_drive else None
    dly_full_ref = next(it) if write_delay else None
    vth_ref, leak_ref, rref_ref, gain_ref, ibias_ref, vreset_ref = (
        next(it), next(it), next(it), next(it), next(it), next(it))
    v_out_ref, r_out_ref, y_out_ref = next(it), next(it), next(it)
    dly_out_ref = next(it) if write_delay else None
    acc_ref = next(it)

    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Masked MXU tile: the mux fabric. On the frozen path w IS W*C already.
    wc = w_ref[...].astype(jnp.float32)
    if has_c:
        wc = wc * c_ref[...].astype(jnp.float32)

    if not has_delays:
        # Uniform delay: the BlockSpec index map already steered the DMA at
        # the scalar-prefetched read slot; the tile is (bB, 1, bK).
        s = dly_read_ref[:, 0, :].astype(jnp.float32)
        acc_ref[...] += jnp.dot(s, wc, preferred_element_type=jnp.float32)
    else:
        # Per-synapse delays: synapse with delay d reads history slot
        # (slot - (d-1)) % D. Build the d-major flattened contraction so the
        # summation order matches the reference einsum exactly.
        slot = slots_ref[0]
        hist = [
            dly_read_ref[:, pl.ds(jax.lax.rem(slot - d + n_delay, n_delay), 1), :][:, 0, :]
            for d in range(n_delay)
        ]
        hist_flat = jnp.concatenate(hist, axis=1).astype(jnp.float32)  # (bB, D*bK)
        d_ids = delays_ref[...]
        w_planes = [wc * (d_ids == d + 1).astype(jnp.float32) for d in range(n_delay)]
        w_flat = jnp.concatenate(w_planes, axis=0)                     # (D*bK, bN)
        acc_ref[...] += jnp.dot(hist_flat, w_flat,
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        v = v_ref[...].astype(jnp.float32)
        r = r_in_ref[...]
        drive = drive_ref[...].astype(jnp.float32) if has_drive else None
        v_new, r_new, spiked = _lif_epilogue(
            acc_ref[...], v, r, drive,
            vth_ref[...].astype(jnp.float32),
            leak_ref[...].astype(jnp.float32),
            rref_ref[...],
            gain_ref[...].astype(jnp.float32),
            ibias_ref[...].astype(jnp.float32),
            vreset_ref[...].astype(jnp.float32),
            mode,
        )
        y = spiked.astype(y_out_ref.dtype)
        v_out_ref[...] = v_new.astype(v_out_ref.dtype)
        r_out_ref[...] = r_new.astype(r_out_ref.dtype)
        y_out_ref[...] = y
        if write_delay:
            # Delay-line write: fresh spikes land at slot (tick+1) % D; the
            # other D-1 slots stream through from the input tile unchanged.
            buf = dly_full_ref[...]
            dly_out_ref[...] = buf
            dly_out_ref[:, pl.ds(slots_ref[1], 1), :] = (
                y[:, None, :].astype(dly_out_ref.dtype))


def tick_launch(
    *,
    B: int,
    K: int,
    N: int,
    n_read: int,
    dtypes: dict,
    has_c: bool,
    has_delays: bool,
    has_drive: bool,
    write_delay: bool,
    n_full: int = 0,
    block_b: int = DEFAULT_BLOCK_B,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
) -> KernelLaunch:
    """The whole-tick kernel's launch descriptor.

    This is the single source of truth for the grid, the BlockSpecs, the
    operand order (which must match ``_tick_kernel``'s ``refs``
    iteration), and the VMEM scratch -- :func:`fused_tick` materializes a
    ``pallas_call`` from it and :mod:`repro.analysis.pallas_rules` lints
    it.  ``dtypes`` maps operand names (``dly_read, w, c, delays, v, r,
    drive, dly_full, param``) to dtypes; ``n_full`` is the full delay
    depth D when ``write_delay``.
    """
    grid = (B // block_b, N // block_n, K // block_k)
    bn = (block_b, block_n)
    kn = (block_k, block_n)
    map_bn = lambda i, j, k, s: (i, j)
    map_kn = lambda i, j, k, s: (k, j)
    map_param = lambda i, j, k, s: (0, j)

    if has_delays:
        # Full history tile: every slot participates in the contraction.
        read = Operand("dly_read", (B, n_read, K), dtypes["dly_read"],
                       (block_b, n_read, block_k),
                       lambda i, j, k, s: (i, 0, k))
    else:
        # The scalar-prefetched circular pointer steers the DMA: only the
        # slot arriving this tick ever leaves HBM.
        read = Operand("dly_read", (B, n_read, K), dtypes["dly_read"],
                       (block_b, 1, block_k),
                       lambda i, j, k, s: (i, s[0], k))

    inputs = [read,
              Operand("w", (K, N), dtypes["w"], kn, map_kn)]
    if has_c:
        inputs.append(Operand("c", (K, N), dtypes["c"], kn, map_kn))
    if has_delays:
        inputs.append(Operand("delays", (K, N), dtypes["delays"],
                              kn, map_kn))
    inputs += [Operand("v", (B, N), dtypes["v"], bn, map_bn),
               Operand("r", (B, N), dtypes["r"], bn, map_bn)]
    if has_drive:
        inputs.append(Operand("drive", (B, N), dtypes["drive"],
                              bn, map_bn))
    if write_delay:
        dly_bn = ((block_b, n_full, block_n),
                  lambda i, j, k, s: (i, 0, j))
        inputs.append(Operand("dly_full", (B, n_full, N),
                              dtypes["dly_full"], *dly_bn))
    param = (1, block_n)
    for pname in ("v_th", "leak", "r_ref", "gain", "i_bias", "v_reset"):
        inputs.append(Operand(pname, (1, N),
                              dtypes.get(pname, dtypes["param"]),
                              param, map_param))

    outputs = [Operand("v_out", (B, N), dtypes["v"], bn, map_bn),
               Operand("r_out", (B, N), dtypes["r"], bn, map_bn),
               Operand("y_out", (B, N), dtypes["dly_read"], bn, map_bn)]
    if write_delay:
        outputs.append(Operand("dly_out", (B, n_full, N),
                               dtypes["dly_full"], *dly_bn))

    # Worst-case prefetch example for the lint: read slot at the deepest
    # history index, write slot at the deepest buffer index.
    slots_ex = np.array(
        [n_read - 1, (n_full - 1) if write_delay else 0], np.int32)
    return KernelLaunch(
        name="tick_fused",
        grid=grid,
        inputs=tuple(inputs),
        outputs=tuple(outputs),
        scratch=(Scratch("vmem", (block_b, block_n), jnp.float32),),
        num_scalar_prefetch=1,
        prefetch_example=(slots_ex,),
    )


@functools.partial(
    jax.jit,
    static_argnames=("mode", "block_b", "block_n", "block_k", "interpret"),
)
def fused_tick(
    slots: jax.Array,
    dly_read: jax.Array,
    w: jax.Array,
    c: Optional[jax.Array],
    delays: Optional[jax.Array],
    v: jax.Array,
    r: jax.Array,
    drive: Optional[jax.Array],
    dly_full: Optional[jax.Array],
    v_th: jax.Array,
    leak: jax.Array,
    r_ref: jax.Array,
    gain: jax.Array,
    i_bias: jax.Array,
    v_reset: jax.Array,
    *,
    mode: str = "fixed_leak",
    block_b: int = DEFAULT_BLOCK_B,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, Optional[jax.Array]]:
    """One whole network tick as a single ``pallas_call``.

    Shapes (pre-padded to block multiples):

    * ``slots``: (2,) i32 -- ``[tick % D, (tick+1) % D]`` (scalar prefetch).
    * ``dly_read``: (B, Dr, K) spike history. Uniform-delay reads take the
      one prefetched slot; per-synapse delays take all ``Dr`` slots.
    * ``w``: (K, N) weights -- pre-masked ``W*C`` when ``c`` is None.
    * ``c``: (K, N) connection mask or None (frozen pre-masked path).
    * ``delays``: (K, N) i32 in ``[1, Dr]`` or None (uniform 1-tick delay).
    * ``v``/``drive``: (B, N) f32; ``r``: (B, N) i32.
    * ``dly_full``: (B, D, N) delay buffer to write through, or None when
      the tick does not write the delay line (``max_delay == 1``).
    * per-neuron params: (N,), reshaped to (1, N) rows.

    Returns ``(v', r', y', dly')`` with ``dly'`` None iff ``dly_full`` is.
    """
    B, n_read, K = dly_read.shape
    N = w.shape[1]
    if B % block_b or N % block_n or K % block_k:
        raise ValueError(
            f"shapes must be block-aligned: B={B}%{block_b}, "
            f"N={N}%{block_n}, K={K}%{block_k}")
    if mode not in ("fixed_leak", "euler"):
        raise ValueError(f"fused tick supports fixed_leak|euler, got {mode!r}")
    has_c = c is not None
    has_delays = delays is not None
    has_drive = drive is not None
    write_delay = dly_full is not None
    n_delay = n_read

    row = lambda a: a.reshape(1, N)
    launch = tick_launch(
        B=B, K=K, N=N, n_read=n_read,
        dtypes={"dly_read": dly_read.dtype, "w": w.dtype,
                "c": c.dtype if has_c else None,
                "delays": delays.dtype if has_delays else None,
                "v": v.dtype, "r": r.dtype,
                "drive": drive.dtype if has_drive else None,
                "dly_full": dly_full.dtype if write_delay else None,
                "param": v_th.dtype},
        has_c=has_c, has_delays=has_delays, has_drive=has_drive,
        write_delay=write_delay,
        n_full=dly_full.shape[1] if write_delay else 0,
        block_b=block_b, block_n=block_n, block_k=block_k)
    arrays = {"dly_read": dly_read, "w": w, "c": c, "delays": delays,
              "v": v, "r": r, "drive": drive, "dly_full": dly_full,
              "v_th": row(v_th), "leak": row(leak), "r_ref": row(r_ref),
              "gain": row(gain), "i_bias": row(i_bias),
              "v_reset": row(v_reset)}

    kernel = functools.partial(
        _tick_kernel, mode=mode, n_delay=n_delay, has_c=has_c,
        has_delays=has_delays, has_drive=has_drive, write_delay=write_delay)
    out = pl.pallas_call(
        kernel,
        grid_spec=launch.grid_spec(),
        out_shape=launch.out_shapes(),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(slots.astype(jnp.int32), *launch.gather(arrays))
    if write_delay:
        v_new, r_new, y, dly_new = out
        return v_new, r_new, y, dly_new
    v_new, r_new, y = out
    return v_new, r_new, y, None
