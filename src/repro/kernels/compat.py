"""Version shims for the Pallas TPU API surface.

jaxlib renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
(and back-compat aliases differ across the versions this repo meets in
CI vs the baked container).  Every kernel imports the name from here so
the sweep in tests/test_kernels.py runs on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
