"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every kernel in this package must match its oracle here to
``assert_allclose`` tolerances across the shape/dtype sweep in
``tests/test_kernels.py``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


def spike_matmul_ref(
    s: jax.Array, w: jax.Array, c: jax.Array
) -> jax.Array:
    """Masked synaptic matmul: ``s @ (w * c)``, f32 accumulation.

    ``s``: (B, N_pre) spikes in {0,1} (any float dtype).
    ``w``: (N_pre, N_post) synaptic weights.
    ``c``: (N_pre, N_post) connection list in {0,1}.
    """
    wc = (w * c.astype(w.dtype)).astype(jnp.float32)
    return jnp.dot(s.astype(jnp.float32), wc)


class LIFStepOut(NamedTuple):
    v: jax.Array
    r: jax.Array
    y: jax.Array


def fused_lif_step_ref(
    s: jax.Array,
    w: jax.Array,
    c: jax.Array,
    v: jax.Array,
    r: jax.Array,
    drive: Optional[jax.Array],
    v_th: jax.Array,
    leak: jax.Array,
    r_ref: jax.Array,
    gain: jax.Array,
    i_bias: jax.Array,
    v_reset: jax.Array,
    *,
    mode: str = "fixed_leak",
) -> LIFStepOut:
    """Fused tick: synaptic matmul + LIF threshold/reset/refractory.

    Shapes: ``s, v, drive``: (B, N); ``r``: (B, N) i32; per-neuron params (N,).
    ``drive`` is the precomputed external input ``ext @ w_in`` (or None).
    Matches ``repro.core.lif.lif_step(..., surrogate=False)`` composed with
    ``repro.core.network.synaptic_input``.
    """
    syn = spike_matmul_ref(s, w, c)
    if drive is not None:
        syn = syn + drive.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if mode == "euler":
        v_tilde = (1.0 - leak) * vf + gain * (syn + i_bias)
    elif mode == "fixed_leak":
        active = (vf != 0).astype(jnp.float32)
        leak_step = jnp.minimum(leak * active, jnp.abs(vf))
        v_tilde = vf + syn + i_bias - jnp.sign(vf) * leak_step
    else:
        raise ValueError(mode)
    not_ref = r == 0
    spiked = (v_tilde >= v_th) & not_ref
    y = spiked.astype(v.dtype)
    hold = spiked | (r > 0)
    v_new = jnp.where(hold, v_reset, v_tilde).astype(v.dtype)
    r_new = jnp.where(spiked, r_ref, jnp.maximum(r - 1, 0)).astype(r.dtype)
    return LIFStepOut(v=v_new, r=r_new, y=y)


def event_spike_matmul_ref(
    s: jax.Array, w: jax.Array, c: jax.Array, k_active: int
) -> jax.Array:
    """Event-driven oracle: identical result to :func:`spike_matmul_ref`
    provided at most ``k_active`` presynaptic neurons spike per batch row
    (the beyond-paper sparse-dispatch path gathers only active fan-outs)."""
    return spike_matmul_ref(s, w, c)


class STDPStepOut(NamedTuple):
    w: jax.Array       # (K, N) updated weights, clipped to [w_min, w_max]
    elig: jax.Array    # (K, N) eligibility (decayed+accumulated iff rstdp)
    x_pre: jax.Array   # (B, K) updated presynaptic traces
    x_post: jax.Array  # (B, N) updated postsynaptic traces


def fused_stdp_step_ref(
    s_pre: jax.Array,
    x_pre: jax.Array,
    s_post: jax.Array,
    x_post: jax.Array,
    w: jax.Array,
    c: jax.Array,
    elig: jax.Array,
    reward: jax.Array,
    *,
    rule: str,
    a_plus: float,
    a_minus: float,
    decay_pre: float,
    decay_post: float,
    decay_elig: float,
    lr_reward: float,
    w_min: float,
    w_max: float,
) -> STDPStepOut:
    """Fused learning tick: trace decay + pair-STDP outer-product update.

    The array-level oracle for :mod:`repro.kernels.stdp_update`, and the
    exact semantics of :func:`repro.plasticity.stdp.stdp_step_ref` once the
    dataclass plumbing is stripped.  Shapes: ``s_pre, x_pre``: (B, K);
    ``s_post, x_post``: (B, N); ``w, c, elig``: (K, N); ``reward``: scalar.

    LTP pairs the *updated* pre trace (incl. this tick's pre spikes) with
    this tick's post spikes; LTD pairs this tick's pre spikes with the
    *updated* post trace.  Batch contributions sum.
    """
    f32 = jnp.float32
    x_pre_new = decay_pre * x_pre.astype(f32) + s_pre.astype(f32)
    x_post_new = decay_post * x_post.astype(f32) + s_post.astype(f32)
    ltp = jnp.dot(x_pre_new.T, s_post.astype(f32))
    ltd = jnp.dot(s_pre.astype(f32).T, x_post_new)
    cf = c.astype(f32)
    dw = (a_plus * ltp - a_minus * ltd) * cf
    wf = w.astype(f32)
    if rule == "rstdp":
        elig_new = decay_elig * elig.astype(f32) + dw
        w_new = wf + lr_reward * jnp.asarray(reward, f32) * elig_new
    else:
        elig_new = elig.astype(f32)
        w_new = wf + dw
    # Non-plastic synapses (c == 0) come back bit-identical, not clipped:
    # a frozen (e.g. negative inhibitory) block may share the matrix.
    w_new = jnp.where(cf > 0, jnp.clip(w_new, w_min, w_max), wf)
    return STDPStepOut(
        w=w_new.astype(w.dtype),
        elig=elig_new.astype(elig.dtype),
        x_pre=x_pre_new.astype(x_pre.dtype),
        x_post=x_post_new.astype(x_post.dtype),
    )
