"""Kernel launch descriptors: ONE structure drives both the
``pallas_call`` and the static lint.

Every Pallas kernel in this package builds its grid / BlockSpecs /
scratch through a :class:`KernelLaunch` returned by a module-level
``*_launch(...)`` builder.  The kernel entry point materializes real
``pl.BlockSpec`` objects from it; :mod:`repro.analysis.pallas_rules`
reads the *same* descriptor to evaluate index maps at concrete grid
points (out-of-bounds DMA detection), estimate the VMEM footprint, and
check aliasing declarations -- so the lint can never drift from what the
kernel actually launches, and never needs to parse ``pallas_call`` eqn
params (whose layout churns between jax releases).

Index maps here are the plain Python lambdas handed to ``pl.BlockSpec``:
the analyzer calls them directly with integer grid indices (plus example
scalar-prefetch values), no tracing involved.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Operand", "Scratch", "KernelLaunch"]

# Memory-space tags (strings, not pltpu enums, so the analyzer can reason
# about them without importing TPU-only symbols).
VMEM = "vmem"
SMEM = "smem"
ANY = "any"    # stays in HBM; the kernel DMAs slices manually


@dataclasses.dataclass(frozen=True)
class Operand:
    """One kernel input/output: full shape + the BlockSpec that tiles it.

    ``block_shape``/``index_map`` are None for ``memory_space="any"``
    operands (no automatic pipelining -- the kernel issues its own DMAs,
    described by :attr:`KernelLaunch.dma_schedule`).
    """

    name: str
    shape: Tuple[int, ...]
    dtype: Any
    block_shape: Optional[Tuple[int, ...]] = None
    index_map: Optional[Callable[..., Tuple[int, ...]]] = None
    memory_space: str = VMEM

    def block_spec(self):
        """The real ``pl.BlockSpec`` this descriptor stands for."""
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu

        if self.memory_space == ANY:
            return pl.BlockSpec(memory_space=pltpu.ANY)
        if self.memory_space == SMEM:
            return pl.BlockSpec(self.block_shape, self.index_map,
                                memory_space=pltpu.SMEM)
        return pl.BlockSpec(self.block_shape, self.index_map)

    @property
    def block_bytes(self) -> int:
        if self.block_shape is None:
            return 0   # HBM-resident; manual DMAs are scratch-accounted
        return (math.prod(self.block_shape)
                * np.dtype(self.dtype).itemsize)


@dataclasses.dataclass(frozen=True)
class Scratch:
    """One scratch allocation: ``kind`` is ``vmem`` | ``sem_dma`` |
    ``sem``; semaphores carry shape only."""

    kind: str
    shape: Tuple[int, ...] = ()
    dtype: Any = np.float32

    def shape_obj(self):
        from jax.experimental.pallas import tpu as pltpu

        if self.kind == "vmem":
            return pltpu.VMEM(self.shape, self.dtype)
        if self.kind == "sem_dma":
            return pltpu.SemaphoreType.DMA(self.shape)
        if self.kind == "sem":
            return pltpu.SemaphoreType.REGULAR
        raise ValueError(f"unknown scratch kind {self.kind!r}")

    @property
    def bytes(self) -> int:
        if self.kind != "vmem":
            return 0
        return math.prod(self.shape) * np.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class KernelLaunch:
    """Everything the ``pallas_call`` and the lint both need to know.

    ``prefetch_example`` holds concrete example values for the
    scalar-prefetch operands (worst-case indices included, e.g. the
    sentinel row): the analyzer substitutes them for ``s`` when it
    evaluates index maps.  ``dma_schedule`` is the manual-DMA protocol
    twin for kernels that stream from ``ANY``-space operands (see
    :func:`repro.kernels.event_dispatch.db_dma_schedule`).
    """

    name: str
    grid: Tuple[int, ...]
    inputs: Tuple[Operand, ...]
    outputs: Tuple[Operand, ...]
    scratch: Tuple[Scratch, ...] = ()
    num_scalar_prefetch: int = 0
    prefetch_example: Tuple[np.ndarray, ...] = ()
    input_output_aliases: Dict[int, int] = dataclasses.field(
        default_factory=dict)
    dma_schedule: Optional[Callable[..., List[Tuple]]] = None

    # -- pallas_call construction -----------------------------------------

    def grid_spec(self):
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=self.num_scalar_prefetch,
            grid=self.grid,
            in_specs=[op.block_spec() for op in self.inputs],
            out_specs=[op.block_spec() for op in self.outputs],
            scratch_shapes=[s.shape_obj() for s in self.scratch],
        )

    def out_shapes(self):
        import jax

        return [jax.ShapeDtypeStruct(op.shape, op.dtype)
                for op in self.outputs]

    def gather(self, arrays: Dict[str, Any]) -> List[Any]:
        """Order a name->array dict into positional pallas_call operands
        (the descriptor's input order is THE order)."""
        return [arrays[op.name] for op in self.inputs]

    # -- lint-facing views -------------------------------------------------

    def tiled_operands(self) -> Sequence[Operand]:
        return [op for op in tuple(self.inputs) + tuple(self.outputs)
                if op.block_shape is not None]

    def vmem_bytes(self) -> int:
        """Estimated peak VMEM: every tiled block double-buffered by the
        Pallas pipeline (x2), plus explicit scratch."""
        tiles = sum(op.block_bytes for op in self.tiled_operands())
        return 2 * tiles + sum(s.bytes for s in self.scratch)
