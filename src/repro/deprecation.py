"""Repro-originated deprecation warnings.

Every deprecation shim in this codebase warns with
:class:`ReproDeprecationWarning` (a :class:`DeprecationWarning` subclass,
so ``pytest.warns(DeprecationWarning)`` in the dedicated shim tests keeps
passing).  The subclass exists so the pytest ``filterwarnings`` config can
turn *our* deprecations into errors without also erroring on third-party
``DeprecationWarning`` noise from jax/numpy internals:

    filterwarnings = ["error::repro.deprecation.ReproDeprecationWarning"]

A shim site calls :func:`warn_deprecated` (stacklevel is relative to the
shim, so the warning is attributed to the *caller* of the deprecated API).
"""

from __future__ import annotations

import warnings

__all__ = ["ReproDeprecationWarning", "warn_deprecated"]


class ReproDeprecationWarning(DeprecationWarning):
    """A deprecation originating from repro's own shims (not a dependency)."""


def warn_deprecated(message: str, *, stacklevel: int = 2) -> None:
    """Emit a :class:`ReproDeprecationWarning` attributed to the caller's
    caller (default ``stacklevel=2`` == the code invoking the shim)."""
    warnings.warn(message, ReproDeprecationWarning, stacklevel=stacklevel + 1)
