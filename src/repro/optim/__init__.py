from repro.optim import adamw, adafactor, schedule, clip, compression

__all__ = ["adamw", "adafactor", "schedule", "clip", "compression"]
