"""Adafactor (Shazeer & Stern 2018): factored second moment.

For >=100B parameters the O(N) second moment dominates HBM; Adafactor
stores row/col factors instead -- O(n+m) per (n, m) matrix. Offered as the
``optimizer="adafactor"`` choice for the largest archs.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any   # row factors (or full v for <2D leaves)
    vc: Any   # col factors (zeros-placeholder for <2D leaves)


def _factored(p) -> bool:
    return p.ndim >= 2


def init(params) -> AdafactorState:
    def vr_init(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vc_init(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((), jnp.float32)

    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        vr=jax.tree.map(vr_init, params),
        vc=jax.tree.map(vc_init, params),
    )


def update(
    grads,
    state: AdafactorState,
    params,
    *,
    lr,
    decay: float = 0.99,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Tuple[Any, AdafactorState]:
    step = state.step + 1

    def upd(p, g, vr, vc):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if _factored(p):
            new_vr = decay * vr + (1 - decay) * g2.mean(axis=-1)
            new_vc = decay * vc + (1 - decay) * g2.mean(axis=-2)
            denom_r = new_vr / jnp.maximum(new_vr.mean(axis=-1, keepdims=True), eps)
            u = gf / (jnp.sqrt(denom_r)[..., None] * jnp.sqrt(new_vc)[..., None, :] + eps)
        else:
            new_vr = decay * vr + (1 - decay) * g2
            new_vc = vc
            u = gf / (jnp.sqrt(new_vr) + eps)
        # update clipping by RMS
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        newp = (p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32)))
        return newp.astype(p.dtype), new_vr, new_vc

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_vr = tdef.flatten_up_to(state.vr)
    flat_vc = tdef.flatten_up_to(state.vc)
    out = [upd(*t) for t in zip(flat_p, flat_g, flat_vr, flat_vc)]
    return (
        tdef.unflatten([o[0] for o in out]),
        AdafactorState(
            step=step,
            vr=tdef.unflatten([o[1] for o in out]),
            vc=tdef.unflatten([o[2] for o in out]),
        ),
    )
