"""int8 error-feedback gradient compression (pod-axis all-reduce trick).

At 512+ chips the cross-pod (DCI) gradient all-reduce is the slowest
collective. Compressing gradients to int8 with per-leaf scales cuts those
bytes 4x (vs f32 master grads; 2x vs bf16); the quantization error is
carried in a residual buffer and re-added next step (error feedback,
Seide et al. 2014 / 1-bit Adam lineage), preserving convergence to first
order. Applied *around* the optimizer: grads -> compress -> (all-reduce
happens in the sharded update) -> decompress.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: Any  # same tree as grads, f32 error carry


def init(params) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress(grads, state: CompressionState):
    """Returns ((q_int8, scales), new_state). q = round(g+r / scale)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * scale
        return q, scale, new_r

    flat, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(state.residual)
    qs, scales, rs = zip(*[one(g, r) for g, r in zip(flat, flat_r)])
    return (
        (tdef.unflatten(list(qs)), tdef.unflatten(list(scales))),
        CompressionState(residual=tdef.unflatten(list(rs))),
    )


def decompress(q_and_scales) -> Any:
    q, scales = q_and_scales
    return jax.tree.map(
        lambda qq, s: qq.astype(jnp.float32) * s, q, scales)
