"""AdamW from scratch, with dtype-configurable sharded state.

State leaves inherit the parameter's sharding (same tree structure), so
ZeRO-style partitioning falls out of the parameter sharding rules for
free. ``state_dtype=bfloat16`` halves optimizer HBM for >=100B archs
(jamba-398b: 12.4 GB -> 6.2 GB per chip; DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params, state_dtype=jnp.float32) -> AdamWState:
    z = lambda p: jnp.zeros(p.shape, jnp.dtype(state_dtype))
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(z, params),
        v=jax.tree.map(z, params),
    )


def update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, AdamWState]:
    """Returns (new_params, new_state). ``lr`` may be a traced scalar."""
    step = state.step + 1
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** sf
    bc2 = 1.0 - b2 ** sf

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
