"""Production mesh construction + per-cell sharding-rule assembly.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state -- the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization and only then builds meshes.
"""
from __future__ import annotations


import jax

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.parallel.sharding import AxisRules, BASE_RULES, fsdp_overrides, multipod_overrides


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    # older jaxlib: no explicit axis types (Auto is the implicit default)
    return jax.make_mesh(shape, axes)


def make_snn_mesh(n_devices: int | None = None, axis: str = "model") -> jax.sharding.Mesh:
    """1-D mesh the SNN fabric shards over (DESIGN.md §15).

    ``n_devices=None`` takes every visible device.  On a plain CPU host,
    call :func:`repro.util.env.ensure_host_device_count` BEFORE any jax
    op to simulate a mesh (this is a function, not a module constant,
    for exactly that reason -- importing this module must not initialize
    the backend).
    """
    if n_devices is None:
        n_devices = len(jax.devices())
    if n_devices < 1 or n_devices > len(jax.devices()):
        raise ValueError(
            f"n_devices={n_devices} out of range: {len(jax.devices())} "
            "devices visible (set XLA_FLAGS="
            "--xla_force_host_platform_device_count before jax init, "
            "e.g. via repro.util.env.ensure_host_device_count)")
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh((n_devices,), (axis,),
                             axis_types=(axis_type.Auto,))
    return jax.make_mesh((n_devices,), (axis,))


def make_rules(
    mesh: jax.sharding.Mesh,
    cfg: ModelConfig,
    shape: ShapeConfig,
    pcfg: ParallelConfig,
    *,
    multi_pod: bool = False,
) -> AxisRules:
    """BASE_RULES + multipod + fsdp + shape-driven + per-cell overrides."""
    rules = AxisRules(BASE_RULES, mesh=mesh)
    over = {}
    if multi_pod:
        over.update(multipod_overrides())
    if pcfg.fsdp:
        over.update(fsdp_overrides())
    if pcfg.seq_shard_activations and shape.kind == "train":
        over.update({"seq": "model"})
    if shape.kind in ("prefill", "decode"):
        # KV caches shard along their sequence axis over "model"
        # (flash-decoding): decode computes shard-local partial attention,
        # combining with tiny collectives instead of gathering the cache.
        over["kv_seq"] = "model"
    if shape.global_batch == 1:
        # long_500k: nothing to shard on batch; shard the KV sequence over
        # every axis we have. The one-token query stays replicated.
        data_axes = ("pod", "data") if multi_pod else ("data",)
        over["batch"] = None
        over["seq"] = None
        over["kv_seq"] = tuple(data_axes) + ("model",)
    over.update(dict(pcfg.rule_overrides))
    return rules.with_overrides(over)
