import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first initialization, and the dry-run needs 512 placeholder CPU
# devices to build the production meshes. (Only the dry-run does this --
# smoke tests and benchmarks see the real single device.)

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (lower+compile succeeds, no sharding
    mismatch / unsupported collective),
  * the memory plan fits (compiled.memory_analysis() per-device bytes),
  * and it extracts the roofline terms (cost_analysis + the trip-count-
    aware HLO parser in launch/hlo_cost.py).

Artifacts: one JSON per cell under --out (default artifacts/dryrun/),
consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all            # every assigned cell, both meshes
"""
import argparse
import json
import math
import subprocess
import sys
import time
import traceback
from typing import Dict, Optional

DEFAULT_OUT = "artifacts/dryrun"


def cell_name(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}__{shape}__{'multipod' if multi_pod else 'singlepod'}"


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             save_hlo: bool = False,
             rule_overrides_json: Optional[str] = None,
             tag: str = "") -> Dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_bundle
    from repro.configs.base import SHAPES
    from repro.launch import hlo_cost, steps
    from repro.launch.mesh import make_production_mesh, make_rules
    from repro.models import model as M
    from repro.parallel.sharding import use_rules

    bundle = get_bundle(arch)
    cfg = bundle.model
    shape = SHAPES[shape_name]
    pcfg = bundle.parallel_for(shape_name)
    if rule_overrides_json:
        pcfg = pcfg.replace(rule_overrides={**dict(pcfg.rule_overrides),
                                            **json.loads(rule_overrides_json)})

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, cfg, shape, pcfg, multi_pod=multi_pod)
    rep = rules.sharding(())

    result: Dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "n_chips": int(math.prod(mesh.devices.shape)),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "n_params": M.n_params(cfg),
        "n_active_params": n_active_params(cfg),
        "parallel": {
            "fsdp": pcfg.fsdp, "microbatches": pcfg.microbatches,
            "remat": pcfg.remat, "optimizer": pcfg.optimizer,
            "opt_state_dtype": pcfg.opt_state_dtype,
            "seq_shard_activations": pcfg.seq_shard_activations,
            "rule_overrides": dict(pcfg.rule_overrides),
        },
        "tag": tag,
    }

    with use_rules(rules), mesh:
        if shape.kind == "train":
            step_fn = steps.make_train_step(cfg, pcfg)
            in_sh = (steps.state_shardings(cfg, rules, pcfg),
                     steps.batch_shardings(cfg, shape, rules))
            out_sh = (steps.state_shardings(cfg, rules, pcfg), rep)
            args = (steps.state_structs(cfg, pcfg, None),
                    steps.batch_structs(cfg, shape, None))
        else:  # prefill / decode share the (params, batch, caches) signature
            if shape.kind == "prefill":
                step_fn = steps.make_prefill_step(cfg)
            else:
                step_fn = steps.make_decode_step(cfg)
            if cfg.family == "audio":
                logits_sh = rules.sharding(("batch", None, "act_vocab"))
            else:
                logits_sh = rules.sharding(("batch", "act_vocab"))
            in_sh = (steps.param_shardings(cfg, rules),
                     steps.batch_shardings(cfg, shape, rules),
                     steps.cache_shardings(cfg, shape, rules))
            out_sh = (logits_sh, steps.cache_shardings(cfg, shape, rules))
            args = (steps.params_structs(cfg),
                    steps.batch_structs(cfg, shape, None),
                    steps.cache_structs(cfg, shape, None))

        t_lower0 = time.time()
        lowered = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
        t_lower = time.time() - t_lower0
        t_c0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t_c0

        ma = compiled.memory_analysis()
        mem = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if hasattr(ma, k)
        }
        print("memory_analysis:", mem)
        ca = hlo_cost.cost_dict(compiled.cost_analysis())
        print("cost_analysis: flops=%s bytes=%s" % (
            ca.get("flops"), ca.get("bytes accessed")))

        hlo = compiled.as_text()
        summary = hlo_cost.analyze(hlo)
        if save_hlo:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(
                    out_dir, cell_name(arch, shape_name, multi_pod) + ".hlo"), "w") as f:
                f.write(hlo)

    result.update({
        "timings": {"mesh_s": t_lower0 - t0, "lower_s": t_lower, "compile_s": t_compile},
        "memory_analysis": mem,
        "cost_analysis_raw": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
        "hlo_cost": {
            "flops_per_device": summary.flops,
            "dot_bytes_per_device": summary.dot_bytes,
            "collective_bytes_per_device": dict(summary.collective_bytes),
            "total_collective_bytes_per_device": summary.total_collective_bytes,
        },
        "status": "ok",
    })
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, cell_name(arch, shape_name, multi_pod) +
                        (f".{tag}" if tag else "") + ".json")
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[dryrun] OK {cell_name(arch, shape_name, multi_pod)} "
          f"compile={t_compile:.1f}s -> {path}")
    return result


def run_snn_cell(arch: str, multi_pod: bool, out_dir: str,
                 batch: int = 256, n_ticks: int = 8) -> Dict:
    """Dry-run the paper's technique at production scale: one synchronous
    tick-rollout of the all-to-all SNN core, sharded across the mesh.

    Synapse matrix W (and connection list C) shard 2-D over
    (model=presynaptic, data=postsynaptic); spike state shards over batch.
    Proves the universal-interconnect maps onto the pod (DESIGN.md §4).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_bundle
    from repro.core.lif import LIFParams, LIFState
    from repro.core.network import SNNParams, SNNState, rollout
    from repro.launch import hlo_cost
    from repro.launch.mesh import make_production_mesh

    cfg = get_bundle(arch).model
    n = cfg.n_neurons
    mesh = make_production_mesh(multi_pod=multi_pod)
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    s = lambda *spec: NamedSharding(mesh, P(*spec))

    def tick_rollout(params, state, ext):
        final, raster = rollout(params, state, ext, n_ticks, mode=cfg.snn_mode,
                                backend=cfg.snn_backend)
        return final.lif.v, raster.sum(axis=(0, 1))

    f32 = jnp.float32
    params = SNNParams(
        w=jax.ShapeDtypeStruct((n, n), f32, sharding=s("model", batch_axes)),
        c=jax.ShapeDtypeStruct((n, n), f32, sharding=s("model", batch_axes)),
        w_in=jax.ShapeDtypeStruct((n, n), f32, sharding=s("model", batch_axes)),
        lif=LIFParams(
            v_th=jax.ShapeDtypeStruct((n,), f32, sharding=s(None)),
            leak=jax.ShapeDtypeStruct((n,), f32, sharding=s(None)),
            r_ref=jax.ShapeDtypeStruct((n,), jnp.int32, sharding=s(None)),
            gain=jax.ShapeDtypeStruct((n,), f32, sharding=s(None)),
            i_bias=jax.ShapeDtypeStruct((n,), f32, sharding=s(None)),
            v_reset=jax.ShapeDtypeStruct((n,), f32, sharding=s(None)),
        ))
    bsh = s(batch_axes, None)
    state = SNNState(
        lif=LIFState(
            v=jax.ShapeDtypeStruct((batch, n), f32, sharding=bsh),
            r=jax.ShapeDtypeStruct((batch, n), jnp.int32, sharding=bsh),
            y=jax.ShapeDtypeStruct((batch, n), f32, sharding=bsh)),
        delay_buf=jax.ShapeDtypeStruct((batch, 1, n), f32,
                                       sharding=s(batch_axes, None, None)),
        tick=jax.ShapeDtypeStruct((), jnp.int32, sharding=s()),
    )
    ext = jax.ShapeDtypeStruct((n_ticks, batch, n), f32,
                               sharding=s(None, batch_axes, None))
    t0 = time.time()
    with mesh:
        lowered = jax.jit(tick_rollout).lower(params, state, ext)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        mem = {k: int(getattr(ma, k)) for k in
               ("argument_size_in_bytes", "temp_size_in_bytes") if hasattr(ma, k)}
        summary = hlo_cost.analyze(compiled.as_text())
    result = {
        "arch": arch, "shape": f"tick_rollout_b{batch}_t{n_ticks}",
        "mesh": "2x16x16" if multi_pod else "16x16", "kind": "snn_tick",
        "n_chips": int(math.prod(mesh.devices.shape)),
        "seq_len": n_ticks, "global_batch": batch,
        "n_params": n * n, "n_active_params": n * n,
        "parallel": {}, "tag": "",
        "timings": {"compile_s": time.time() - t0},
        "memory_analysis": mem,
        "cost_analysis_raw": {},
        "hlo_cost": {
            "flops_per_device": summary.flops,
            "dot_bytes_per_device": summary.dot_bytes,
            "collective_bytes_per_device": dict(summary.collective_bytes),
            "total_collective_bytes_per_device": summary.total_collective_bytes,
        },
        "status": "ok",
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, cell_name(arch, result["shape"], multi_pod) + ".json")
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[dryrun] OK snn cell {arch} ({result['mesh']}) "
          f"mem={mem} flops/dev={summary.flops/1e12:.2f}TF -> {path}")
    return result


def n_active_params(cfg) -> int:
    """Parameters touched per token: MoE experts count at top_k/E (+shared)."""
    from repro.models import model as M
    from repro.models.common import is_spec
    import jax

    total = 0.0
    for leaf in jax.tree.leaves(M.specs(cfg), is_leaf=is_spec):
        n = math.prod(leaf.shape)
        if "experts" in leaf.axes and cfg.n_experts:
            n = n * cfg.top_k / cfg.n_experts
        total += n
    return int(total)


def all_cells():
    from repro.configs import ASSIGNED_ARCHS, get_bundle
    from repro.configs.base import applicable_shapes

    cells = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_bundle(arch).model
        for shape_name in applicable_shapes(cfg):
            for multi_pod in (False, True):
                cells.append((arch, shape_name, multi_pod))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--rule-overrides", default=None,
                    help="JSON dict of logical-axis overrides (hillclimb)")
    ap.add_argument("--tag", default="", help="artifact suffix (hillclimb iters)")
    args = ap.parse_args()

    if args.all:
        failures = []
        for arch, shape_name, multi_pod in all_cells():
            name = cell_name(arch, shape_name, multi_pod)
            path = os.path.join(args.out, name + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[dryrun] skip existing {name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name, "--out", args.out]
            if multi_pod:
                cmd.append("--multi-pod")
            if args.save_hlo:
                cmd.append("--save-hlo")
            print(f"[dryrun] === {name} ===", flush=True)
            rc = subprocess.run(cmd).returncode
            if rc != 0:
                failures.append(name)
                print(f"[dryrun] FAIL {name} (rc={rc})", flush=True)
        if failures:
            print("[dryrun] FAILURES:", failures)
            sys.exit(1)
        print("[dryrun] all cells passed")
        return

    try:
        if args.arch and args.arch.endswith("snn") or args.arch == "snn-64k":
            run_snn_cell(args.arch, args.multi_pod, args.out)
        else:
            run_cell(args.arch, args.shape, args.multi_pod, args.out,
                     save_hlo=args.save_hlo,
                     rule_overrides_json=args.rule_overrides, tag=args.tag)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
