"""End-to-end training driver: data -> sharded step -> checkpoint/restart.

Production shape: counter-based resumable pipeline, jitted sharded
train_step, async checkpoints, straggler monitor, bounded-retry restart
loop (runtime/fault_tolerance). On CPU this runs the reduced configs
(--smoke); on a pod the same driver takes the full config and the
production mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 100 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import logging
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs import get_bundle
from repro.configs.base import ShapeConfig
from repro.data import pipeline
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_rules
from repro.parallel.sharding import AxisRules, use_rules
from repro.runtime import fault_tolerance as ft
from repro.runtime.straggler import StragglerMonitor

log = logging.getLogger("repro.train")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", choices=["none", "single", "multi"], default="none",
                    help="'none' = current devices unsharded (CPU demo)")
    args = ap.parse_args(argv)

    bundle = get_bundle(args.arch)
    cfg = bundle.smoke if args.smoke else bundle.model
    shape = ShapeConfig("cli", "train", args.seq_len, args.global_batch)
    pcfg = bundle.parallel_for("train_4k").replace(microbatches=1)

    rules: Optional[AxisRules] = None
    mesh_ctx = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        mesh_ctx = make_production_mesh(multi_pod=args.mesh == "multi")
        rules = make_rules(mesh_ctx, cfg, shape, pcfg,
                           multi_pod=args.mesh == "multi")

    key = jax.random.PRNGKey(0)
    state = steps_mod.init_train_state(cfg, pcfg, key)
    train_step = steps_mod.make_train_step(
        cfg, pcfg, peak_lr=args.peak_lr, warmup_steps=min(20, args.steps // 5 + 1),
        total_steps=args.steps)
    jitted = jax.jit(train_step)

    pipe = pipeline.PipelineState(seed=17, step=0)
    monitor = StragglerMonitor()
    checkpointer = (ckpt.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None)
    start_step = 0

    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state, meta = ckpt.restore(args.ckpt_dir, state)
        start_step = meta["step"]
        pipe = pipeline.PipelineState.from_dict(meta["extra"]["pipeline"])
        log.warning("resumed from step %d", start_step)

    losses = []

    def one_step(step: int, carry):
        state, pipe = carry
        t0 = time.time()
        batch = pipeline.make_batch(cfg, shape, pipe)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = jitted(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        monitor.observe("host0", time.time() - t0)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({time.time()-t0:.2f}s)", flush=True)
        return state, pipeline.advance(pipe)

    def save_fn(step, carry):
        if checkpointer is not None:
            state, pipe = carry
            checkpointer.save_async(step, state,
                                    extra_meta={"pipeline": pipe.as_dict()})

    def restore_fn():
        restored, meta = ckpt.restore(args.ckpt_dir, state)
        p = pipeline.PipelineState.from_dict(meta["extra"]["pipeline"])
        return meta["step"], (restored, p)

    ctx = use_rules(rules)
    with ctx:
        if mesh_ctx is not None:
            with mesh_ctx:
                final_step, (state, pipe) = ft.run_resilient_loop(
                    n_steps=args.steps, start_step=start_step,
                    step_fn=one_step, state=(state, pipe),
                    save_fn=save_fn, restore_fn=restore_fn,
                    checkpoint_every=args.ckpt_every)
        else:
            final_step, (state, pipe) = ft.run_resilient_loop(
                n_steps=args.steps, start_step=start_step,
                step_fn=one_step, state=(state, pipe),
                save_fn=save_fn, restore_fn=restore_fn,
                checkpoint_every=args.ckpt_every)
    if checkpointer is not None:
        checkpointer.wait()

    print(f"done: {final_step} steps; loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"stragglers: {monitor.stragglers()}")
    return losses


if __name__ == "__main__":
    logging.basicConfig(level=logging.INFO)
    main()
