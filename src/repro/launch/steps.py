"""Step functions (train / prefill / decode) + their sharding trees.

This is the seam between the model library and the distributed runtime:
everything the dry-run lowers, the trainer executes, and the roofline
analyzes comes from here, so the compiled artifact and the production step
are the same program.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.models import model as M
from repro.models.common import logical_axes, shape_structs
from repro.optim import adafactor, adamw, clip, schedule
from repro.parallel.sharding import AxisRules


class TrainState(NamedTuple):
    params: Any
    opt: Any  # AdamWState | AdafactorState


# ---------------------------------------------------------------------------
# sharding trees


def param_shardings(cfg: ModelConfig, rules: AxisRules):
    axes = logical_axes(M.specs(cfg))
    return jax.tree.map(lambda a: rules.sharding(a), axes,
                        is_leaf=lambda x: isinstance(x, tuple))


def _axes_tree(cfg: ModelConfig):
    return logical_axes(M.specs(cfg))


def opt_shardings(cfg: ModelConfig, rules: AxisRules, optimizer: str):
    p_axes = _axes_tree(cfg)
    is_axes = lambda x: isinstance(x, tuple)
    rep = rules.sharding(())
    if optimizer == "adamw":
        mom = jax.tree.map(lambda a: rules.sharding(a), p_axes, is_leaf=is_axes)
        return adamw.AdamWState(step=rep, m=mom, v=mom)
    if optimizer == "adafactor":
        vr = jax.tree.map(lambda a: rules.sharding(a[:-1]), p_axes, is_leaf=is_axes)
        vc = jax.tree.map(
            lambda a: rules.sharding(a[:-2] + a[-1:]) if len(a) >= 2 else rep,
            p_axes, is_leaf=is_axes)
        return adafactor.AdafactorState(step=rep, vr=vr, vc=vc)
    raise ValueError(optimizer)


def state_shardings(cfg: ModelConfig, rules: AxisRules, pcfg: ParallelConfig):
    return TrainState(
        params=param_shardings(cfg, rules),
        opt=opt_shardings(cfg, rules, pcfg.optimizer),
    )


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, rules: AxisRules):
    specs = M.batch_specs(cfg, shape)
    from repro.models.common import Spec, is_spec
    return jax.tree.map(lambda s: rules.sharding(s.axes), specs, is_leaf=is_spec)


def cache_shardings(cfg: ModelConfig, shape: ShapeConfig, rules: AxisRules):
    specs = M.make_cache_specs(cfg, shape.global_batch, shape.seq_len)
    from repro.models.common import is_spec
    return jax.tree.map(lambda s: rules.sharding(s.axes), specs, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# struct builders (dry-run stand-ins; no allocation)


def state_structs(cfg: ModelConfig, pcfg: ParallelConfig, rules: Optional[AxisRules]):
    p = shape_structs(M.specs(cfg), M.dtype_of(cfg), rules)
    if pcfg.optimizer == "adamw":
        sd = jnp.dtype(pcfg.opt_state_dtype)
        mom_axes = _axes_tree(cfg)
        def mom_struct(spec_axes, leaf):
            sharding = rules.sharding(spec_axes) if rules else None
            return jax.ShapeDtypeStruct(leaf.shape, sd, sharding=sharding)
        m = jax.tree.map(mom_struct, mom_axes, p,
                         is_leaf=lambda x: isinstance(x, tuple))
        step = jax.ShapeDtypeStruct((), jnp.int32,
                                    sharding=rules.sharding(()) if rules else None)
        opt = adamw.AdamWState(step=step, m=m, v=m)
    else:
        axes = _axes_tree(cfg)
        is_axes = lambda x: isinstance(x, tuple)
        def vr_struct(a, leaf):
            sharding = rules.sharding(a[:-1]) if rules else None
            return jax.ShapeDtypeStruct(leaf.shape[:-1], jnp.float32, sharding=sharding)
        def vc_struct(a, leaf):
            if len(leaf.shape) >= 2:
                sharding = rules.sharding(a[:-2] + a[-1:]) if rules else None
                return jax.ShapeDtypeStruct(
                    leaf.shape[:-2] + leaf.shape[-1:], jnp.float32, sharding=sharding)
            sharding = rules.sharding(()) if rules else None
            return jax.ShapeDtypeStruct((), jnp.float32, sharding=sharding)
        vr = jax.tree.map(vr_struct, axes, p, is_leaf=is_axes)
        vc = jax.tree.map(vc_struct, axes, p, is_leaf=is_axes)
        step = jax.ShapeDtypeStruct((), jnp.int32,
                                    sharding=rules.sharding(()) if rules else None)
        opt = adafactor.AdafactorState(step=step, vr=vr, vc=vc)
    return TrainState(params=p, opt=opt)


def params_structs(cfg: ModelConfig, rules: Optional[AxisRules] = None):
    return shape_structs(M.specs(cfg), M.dtype_of(cfg), rules)


def batch_structs(cfg: ModelConfig, shape: ShapeConfig, rules: Optional[AxisRules]):
    return shape_structs(M.batch_specs(cfg, shape), M.dtype_of(cfg), rules)


def cache_structs(cfg: ModelConfig, shape: ShapeConfig, rules: Optional[AxisRules]):
    return shape_structs(
        M.make_cache_specs(cfg, shape.global_batch, shape.seq_len),
        M.dtype_of(cfg), rules)


# ---------------------------------------------------------------------------
# step functions


def make_train_step(
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    *,
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10_000,
    max_grad_norm: float = 1.0,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""
    micro = max(1, pcfg.microbatches)
    grad_accum_dtype = jnp.dtype(pcfg.grad_accum_dtype)

    def loss_of(params, mb):
        return M.loss_fn(params, cfg, mb, remat=pcfg.remat)

    def train_step(state: TrainState, batch):
        if micro == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
                state.params, batch)
        else:
            def split_mb(x):
                y = x.reshape((micro, x.shape[0] // micro) + x.shape[1:])
                return y

            mbs = jax.tree.map(split_mb, batch)

            def accum(carry, mb):
                gsum, lsum, aux_sum = carry
                (loss, metrics), g = jax.value_and_grad(loss_of, has_aux=True)(
                    state.params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(grad_accum_dtype), gsum, g)
                return (gsum, lsum + loss, aux_sum + metrics["router_aux"]), None

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, grad_accum_dtype), state.params)
            (gsum, lsum, aux_sum), _ = jax.lax.scan(
                accum, (gzero, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                mbs)
            grads = jax.tree.map(lambda g: g / micro, gsum)
            loss = lsum / micro
            metrics = {"nll": loss, "router_aux": aux_sum / micro}

        grads, gnorm = clip.clip_by_global_norm(grads, max_grad_norm)
        lr = schedule.warmup_cosine(
            state.opt.step, peak_lr=peak_lr,
            warmup_steps=warmup_steps, total_steps=total_steps)
        if pcfg.optimizer == "adamw":
            new_params, new_opt = adamw.update(grads, state.opt, state.params, lr=lr)
        else:
            new_params, new_opt = adafactor.update(grads, state.opt, state.params, lr=lr)
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm, "lr": lr})
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch, caches):
        return M.prefill_fn(params, cfg, batch, caches)
    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, batch, caches):
        return M.decode_fn(params, cfg, batch, caches)
    return decode_step


def init_train_state(cfg: ModelConfig, pcfg: ParallelConfig, key) -> TrainState:
    params = M.init(cfg, key)
    if pcfg.optimizer == "adamw":
        opt = adamw.init(params, jnp.dtype(pcfg.opt_state_dtype))
    else:
        opt = adafactor.init(params)
    return TrainState(params=params, opt=opt)
