"""Trip-count-aware HLO cost extraction for the roofline analysis.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**
(verified in tests/test_hlo_cost.py), so any scanned model -- scan over
layers, scan over sequence chunks -- under-reports FLOPs and collective
bytes by the trip count. This module parses ``compiled.as_text()`` into a
computation call graph, extracts per-computation costs from a per-op
symbol table, recovers while-loop trip counts (from the
``known_trip_count`` backend config, falling back to the condition
computation's loop bound constant), and propagates totals bottom-up.

Outputs per program:
  flops              dot/convolution FLOPs x trip counts
  collective_bytes   operand bytes per collective kind x trip counts
  dot_bytes          dot operand+output bytes x trip counts (an HBM-traffic
                     model assuming elementwise ops fuse into the dots)

This is the profiling substrate the §Perf loop reads -- "your profile is
lowered.as_text() + cost_analysis()".
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_CONST_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*?"n"\s*:\s*"(\d+)"')
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$")

Shape = Tuple[str, Tuple[int, ...]]


def cost_dict(cost_analysis) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across jaxlib versions.

    Older jaxlib returns a flat dict; newer returns a one-element list of
    dicts (one per program).  Returns {} for None/empty so callers can
    ``.get()`` unconditionally.
    """
    if cost_analysis is None:
        return {}
    if isinstance(cost_analysis, dict):
        return cost_analysis
    if isinstance(cost_analysis, (list, tuple)):
        return cost_analysis[0] if cost_analysis and isinstance(
            cost_analysis[0], dict) else {}
    return {}


def _nbytes(sh: Shape) -> int:
    dt, dims = sh
    return _DTYPE_BYTES.get(dt, 4) * (math.prod(dims) if dims else 1)


def _parse_shapes(type_str: str) -> List[Shape]:
    """All dtype[dims] occurrences in a type spec (tuple-aware)."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = m.group(2)
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((m.group(1), shape))
    return out


def _split_type_and_rest(rhs: str) -> Tuple[str, str]:
    """Split 'f32[8,8]{1,0} dot(...)' or '(s32[], f32[..]) while(...)'."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rhs[: i + 1], rhs[i + 1 :].strip()
        return rhs, ""
    m = re.match(r"(\w+\[[\d,]*\](?:\{[^}]*\})?)\s*(.*)", rhs)
    if m:
        return m.group(1), m.group(2)
    return "", rhs


def _first_paren_args(rest: str) -> str:
    lp = rest.find("(")
    if lp < 0:
        return ""
    depth = 0
    for i in range(lp, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                return rest[lp + 1 : i]
    return rest[lp + 1 :]


@dataclasses.dataclass
class Computation:
    name: str
    flops: float = 0.0
    dot_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    children: List[str] = dataclasses.field(default_factory=list)
    whiles: List[Tuple[str, str, Optional[int]]] = dataclasses.field(
        default_factory=list)  # (body, cond, known_trips)
    max_const: int = 0


def _split_computations(hlo: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    entry: Optional[str] = None
    current: Optional[str] = None
    buf: List[str] = []
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if current is None:
            m = _HEADER_RE.match(stripped.strip())
            if m:
                current = m.group(2)
                if m.group(1):
                    entry = current
                buf = []
                comps[current] = buf
            continue
        if stripped.strip() == "}" or stripped.startswith("}"):
            current = None
            continue
        buf.append(stripped.strip())
    return comps, entry


def parse(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps_lines, entry = _split_computations(hlo)
    comps: Dict[str, Computation] = {}
    for name, lines in comps_lines.items():
        c = Computation(name=name)
        symtab: Dict[str, List[Shape]] = {}
        for line in lines:
            cm = _CONST_RE.search(line)
            if cm:
                c.max_const = max(c.max_const, int(cm.group(1)))
            m = _OP_RE.match(line)
            if not m:
                continue
            op_name, rhs = m.group(1), m.group(2)
            type_str, rest = _split_type_and_rest(rhs)
            out_shapes = _parse_shapes(type_str)
            symtab[op_name] = out_shapes
            opm = re.match(r"([\w\-]+)", rest)
            opcode = opm.group(1) if opm else ""
            args = _first_paren_args(rest)
            operand_names = re.findall(r"%([\w.\-]+)", args)

            if opcode == "dot":
                lhs_shapes = symtab.get(operand_names[0], []) if operand_names else []
                lhs = lhs_shapes[0] if lhs_shapes else ("f32", ())
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
                cdim = 1
                if cdims and cdims.group(1):
                    for d in cdims.group(1).split(","):
                        di = int(d)
                        if di < len(lhs[1]):
                            cdim *= lhs[1][di]
                out = out_shapes[0] if out_shapes else ("f32", ())
                c.flops += 2.0 * math.prod(out[1] or (1,)) * cdim
                byte_sum = _nbytes(out)
                for on in operand_names[:2]:
                    for sh in symtab.get(on, []):
                        byte_sum += _nbytes(sh)
                c.dot_bytes += byte_sum
            elif opcode == "convolution":
                out = out_shapes[0] if out_shapes else ("f32", ())
                k_shapes = symtab.get(operand_names[1], []) if len(operand_names) > 1 else []
                k_elems = math.prod(k_shapes[0][1]) if k_shapes and k_shapes[0][1] else 1
                out_elems = math.prod(out[1] or (1,))
                cout = out[1][-1] if out[1] else 1
                c.flops += 2.0 * out_elems * max(1, k_elems // max(1, cout))
                c.dot_bytes += _nbytes(out) + sum(
                    _nbytes(sh) for on in operand_names[:2] for sh in symtab.get(on, []))
            elif any(opcode.startswith(k) for k in COLLECTIVE_KINDS):
                kind = next(k for k in COLLECTIVE_KINDS if opcode.startswith(k))
                by = 0.0
                for on in operand_names:
                    for sh in symtab.get(on, []):
                        by += _nbytes(sh)
                if by == 0.0:  # operands defined in another computation scope
                    by = sum(_nbytes(sh) for sh in out_shapes)
                c.collective_bytes[kind] += by
            elif opcode == "while":
                body = re.search(r"body=%?([\w.\-]+)", rest)
                cond = re.search(r"condition=%?([\w.\-]+)", rest)
                trips = None
                tm = _TRIP_RE.search(rest)
                if tm:
                    trips = int(tm.group(1))
                if body and cond:
                    c.whiles.append((body.group(1), cond.group(1), trips))
            elif opcode == "conditional":
                for key in ("true_computation", "false_computation"):
                    mm = re.search(rf"{key}=%?([\w.\-]+)", rest)
                    if mm:
                        c.children.append(mm.group(1))
                bm = re.search(r"branch_computations=\{([^}]*)\}", rest)
                if bm:
                    for b in bm.group(1).split(","):
                        b = b.strip().lstrip("%")
                        if b:
                            c.children.append(b)
            else:
                for mm in re.finditer(r"(?:calls=|to_apply=)%?([\w.\-]+)", rest):
                    c.children.append(mm.group(1))
        comps[name] = c
    return comps, entry


@dataclasses.dataclass
class CostSummary:
    flops: float
    dot_bytes: float
    collective_bytes: Dict[str, float]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def scaled(self, k: float) -> "CostSummary":
        return CostSummary(
            flops=self.flops * k,
            dot_bytes=self.dot_bytes * k,
            collective_bytes={kk: v * k for kk, v in self.collective_bytes.items()},
        )


def _entry_name(comps: Dict[str, Computation], entry: Optional[str]) -> str:
    if entry and entry in comps:
        return entry
    referenced = set()
    for c in comps.values():
        referenced.update(c.children)
        for b, cn, _ in c.whiles:
            referenced.add(b)
            referenced.add(cn)
    for name in comps:
        if name not in referenced:
            return name
    return next(iter(comps))


def analyze(hlo: str) -> CostSummary:
    """Whole-program cost with while-body trip-count multipliers."""
    comps, entry = parse(hlo)
    entry = _entry_name(comps, entry)
    memo: Dict[str, CostSummary] = {}

    def total(name: str) -> CostSummary:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return CostSummary(0.0, 0.0, {})
        memo[name] = CostSummary(0.0, 0.0, {})  # cycle guard (HLO is a DAG)
        flops = c.flops
        dot_bytes = c.dot_bytes
        coll: Dict[str, float] = defaultdict(float, c.collective_bytes)
        for child in c.children:
            sub = total(child)
            flops += sub.flops
            dot_bytes += sub.dot_bytes
            for k, v in sub.collective_bytes.items():
                coll[k] += v
        for body, cond, trips in c.whiles:
            if trips is None:
                trips = max(1, comps.get(cond, Computation(cond)).max_const)
            sub = total(body)
            subc = total(cond)
            flops += trips * (sub.flops + subc.flops)
            dot_bytes += trips * (sub.dot_bytes + subc.dot_bytes)
            for k, v in sub.collective_bytes.items():
                coll[k] += trips * v
            for k, v in subc.collective_bytes.items():
                coll[k] += trips * v
        out = CostSummary(flops=flops, dot_bytes=dot_bytes, collective_bytes=dict(coll))
        memo[name] = out
        return out

    import sys
    sys.setrecursionlimit(max(10000, sys.getrecursionlimit()))
    return total(entry)
