"""Batched serving driver (the paper's kind: an inference platform).

Two server flavors share one shape of loop:

* :class:`WaveServer` -- the LM model zoo: requests are grouped into
  waves of ``slots``; each wave left-pads prompts to a common length,
  prefills the whole wave in one batched program, then decodes all slots
  in lock-step (one jitted decode program).

* :class:`SNNServer` -- the SNN processor itself, multi-tenant: S
  independent *networks* (each its own ``W/C/thresholds/leak`` register
  image, loaded via :func:`repro.core.network.params_from_registers`)
  ride one compiled tick program, vmapped over a slot axis. The slot
  axis is the TPU restatement of time-sharing the mux fabric
  (DESIGN.md §8): swapping a tenant in = rewriting a slot's registers,
  never recompiling.

Both mirror how the FPGA serves: one resident "fabric" (compiled
program), per-request state swapped in registers -- and like the FPGA,
switching requests never recompiles anything.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 6 --max-new 12
  PYTHONPATH=src python -m repro.launch.serve --arch snn --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_bundle
from repro.core.engine import EngineOptions
from repro.deprecation import warn_deprecated
from repro.models import model as M
from repro.obs import MetricsRegistry, log_event, profile, span


@dataclasses.dataclass
class ServeRequest:
    """ONE request type for both servers (the unified serve surface).

    The LM :class:`WaveServer` reads ``prompt``/``max_new``; the
    :class:`SNNServer` reads ``ext``/``n_ticks``/``rewards``. ``t_submit``
    is the *enqueue* time: callers that queue requests (the async
    front-end) stamp it at admission so TTFT includes queue wait; the
    servers only stamp it (lazily, when still ``0.0``) for requests
    handed to them directly.

    Result fields (``out``/``counts``/``pred``/timestamps) are filled in
    place as the request completes -- :meth:`ServeResult.of` snapshots
    them into the immutable result record the stats dicts carry.
    """

    rid: int
    # -- LM fields
    prompt: Optional[np.ndarray] = None   # (S,) or (S, K) int32
    max_new: int = 0
    # -- SNN fields
    tenant: str = ""
    ext: Optional[np.ndarray] = None      # (T_req, n_in) input spike train
    n_ticks: int = 0                      # tick budget for this request
    rewards: Optional[np.ndarray] = None  # (T_req,) dopamine (R-STDP)
    # -- result fields (filled by the servers)
    out: List = dataclasses.field(default_factory=list)
    counts: Optional[np.ndarray] = None   # (n_out,) rate-decoded counts
    pred: Optional[int] = None            # argmax over output neurons
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """Immutable completion record, one per served request.

    ``ttft_s`` is measured from *enqueue* (``t_submit``), not from
    wave/chunk start -- under continuous admission a queued request's
    wait is real latency its caller observed.
    """

    rid: int
    tenant: str = ""
    out: tuple = ()                       # LM: generated token ids
    counts: Optional[np.ndarray] = None   # SNN: rate-decoded counts
    pred: Optional[int] = None
    rejected: bool = False
    reason: str = ""                      # admission-rejection reason
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def ttft_s(self) -> float:
        if self.t_first is None:
            return 0.0
        return max(0.0, self.t_first - self.t_submit)

    @classmethod
    def of(cls, r: "ServeRequest") -> "ServeResult":
        return cls(rid=r.rid, tenant=r.tenant, out=tuple(r.out),
                   counts=r.counts, pred=r.pred, t_submit=r.t_submit,
                   t_first=r.t_first, t_done=r.t_done)

    @classmethod
    def rejection(cls, r: "ServeRequest", reason: str) -> "ServeResult":
        now = time.time()
        return cls(rid=r.rid, tenant=r.tenant, rejected=True, reason=reason,
                   t_submit=r.t_submit or now, t_first=None, t_done=now)


class Request(ServeRequest):
    """Deprecated LM request shim -- use :class:`ServeRequest`."""

    def __init__(self, rid, prompt=None, max_new=0, out=None,
                 t_submit=0.0, t_first=None, t_done=None):
        warn_deprecated(
            "launch.serve.Request is deprecated; use ServeRequest "
            "(same fields, shared with SNNServer)")
        super().__init__(rid=rid, prompt=prompt, max_new=max_new,
                         t_submit=t_submit, t_first=t_first, t_done=t_done)
        if out is not None:
            self.out = out


class SNNRequest(ServeRequest):
    """Deprecated SNN request shim -- use :class:`ServeRequest`."""

    def __init__(self, rid, tenant="", ext=None, n_ticks=0, rewards=None,
                 counts=None, pred=None, t_submit=0.0, t_first=None,
                 t_done=None):
        warn_deprecated(
            "launch.serve.SNNRequest is deprecated; use ServeRequest "
            "(same fields, shared with the LM WaveServer)")
        super().__init__(rid=rid, tenant=tenant, ext=ext, n_ticks=n_ticks,
                         rewards=rewards, counts=counts, pred=pred,
                         t_submit=t_submit, t_first=t_first, t_done=t_done)


class WaveServer:
    """One compiled prefill + one compiled decode program, reused forever."""

    def __init__(self, cfg, params, *, slots: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self._decode = jax.jit(lambda p, b, c: M.decode_fn(p, cfg, b, c))
        self._prefill = jax.jit(lambda p, b, c: M.prefill_fn(p, cfg, b, c))

    def _pad_prompts(self, reqs: List[ServeRequest]) -> np.ndarray:
        plen = max(len(r.prompt) for r in reqs)
        shape = (self.slots, plen) + (
            (self.cfg.n_codebooks,) if self.cfg.family == "audio" else ())
        toks = np.zeros(shape, np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad with 0
        return toks

    def run_wave(self, reqs: List[ServeRequest]) -> int:
        """Prefill + decode one wave to completion; returns decode steps."""
        cfg = self.cfg
        toks = self._pad_prompts(reqs)
        plen = toks.shape[1]
        caches = M.init_cache(cfg, self.slots, self.max_len)
        last, caches = self._prefill(self.params, {"inputs": jnp.asarray(toks)},
                                     caches)
        last_np = np.asarray(last, np.float32)        # (slots, V) or (slots,K,V)
        now = time.time()
        cur = last_np.argmax(-1).astype(np.int32)     # (slots,) or (slots, K)
        for r_i, r in enumerate(reqs):
            r.t_first = now
            r.out.append(int(np.atleast_1d(cur[r_i]).flat[0]))

        steps = 0
        pos = plen
        active = {i for i, r in enumerate(reqs) if len(r.out) < r.max_new}
        for r_i, r in enumerate(reqs):
            if r_i not in active:
                r.t_done = now
        max_new = max(r.max_new for r in reqs)
        while active and pos < self.max_len - 1 and steps < max_new:
            tok_in = cur[:, None] if cfg.family != "audio" else cur[:, None, :]
            batch = {"token": jnp.asarray(tok_in),
                     "pos": jnp.asarray(pos, jnp.int32)}
            logits, caches = self._decode(self.params, batch, caches)
            cur = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            steps += 1
            pos += 1
            now = time.time()
            for r_i in list(active):
                r = reqs[r_i]
                r.out.append(int(np.atleast_1d(cur[r_i]).flat[0]))
                if len(r.out) >= r.max_new:
                    r.t_done = now
                    active.discard(r_i)
        now = time.time()
        for r in reqs:
            if r.t_done is None:
                r.t_done = now
        return steps


def serve(cfg, params, requests: List[ServeRequest], *, slots: int = 4,
          max_len: int = 64) -> Dict:
    if not requests:
        # Empty queue: a well-formed zero report, never np.mean([]).
        return {"n_requests": 0, "requests_served": 0, "decode_steps": 0,
                "new_tokens": 0, "wall_s": 0.0, "tokens_per_s": 0.0,
                "mean_ttft_s": 0.0, "p99_ttft_s": 0.0, "outputs": {},
                "results": []}
    server = WaveServer(cfg, params, slots=slots, max_len=max_len)
    now = time.time()
    for r in requests:
        # TTFT counts from *enqueue*: keep a caller-stamped submit time
        # (the async front-end stamps at admission), stamp only if unset.
        if not r.t_submit:
            r.t_submit = now
    done: List[ServeRequest] = []
    steps = 0
    queue = list(requests)
    while queue:
        wave = queue[:slots]
        queue = queue[slots:]
        # pad the wave with a dummy clone so the batch shape is static
        while len(wave) < slots:
            wave.append(ServeRequest(rid=-1, prompt=wave[0].prompt, max_new=1))
        steps += server.run_wave(wave)
        done.extend(r for r in wave if r.rid >= 0)

    total_new = sum(len(r.out) for r in done)
    t0 = min(r.t_submit for r in done)
    t1 = max(r.t_done for r in done)
    ttfts = [r.t_first - r.t_submit for r in done]
    return {
        "n_requests": len(done),
        "requests_served": len(done),
        "decode_steps": steps,
        "new_tokens": total_new,
        "wall_s": round(t1 - t0, 3),
        "tokens_per_s": round(total_new / max(1e-9, t1 - t0), 2),
        "mean_ttft_s": round(float(np.mean(ttfts)), 3) if done else 0.0,
        "p99_ttft_s": round(float(np.percentile(ttfts, 99)), 4) if done else 0.0,
        "outputs": {r.rid: r.out[:8] for r in done},
        "results": [ServeResult.of(r) for r in done],
    }


# ---------------------------------------------------------------------------
# Multi-tenant SNN serving: many resident networks, one compiled tick program
# ---------------------------------------------------------------------------

_PAD_VTH = 1e30  # padded neurons can never reach threshold


@dataclasses.dataclass
class Tenant:
    """One resident network: a register image padded onto the fabric.

    ``params`` leaves are fabric-shaped ``(n_max, ...)``; neurons past
    ``n`` carry an unreachable threshold (silent forever) and a zeroed
    connection/plastic mask (can never learn). ``plastic_c`` gates the
    learning hook per synapse: all-zero for frozen tenants, so their
    weights come back *bit-identical* from every wave.

    ``backend`` is the tick program this tenant rides: the server's
    default, or ``"event"`` when the tenant's topology is sparse enough
    to clear the server's ``event_density`` threshold (then ``fan_idx``
    / ``fan_mask`` hold its padded fan-in lists, fabric-shaped
    ``(n_max, event_cap)`` so every event-wave slot stacks to one static
    shape).
    """

    name: str
    n: int
    n_in: int
    n_out: int
    plastic: bool
    params: "object"            # repro.core.network.SNNParams, padded
    plastic_c: jax.Array        # (n_max, n_max)
    density: float = 1.0
    backend: str = "jnp"
    fan_idx: Optional[jax.Array] = None   # (n_max, event_cap) i32
    fan_mask: Optional[jax.Array] = None  # (n_max, event_cap) f32
    plan: Optional["object"] = None       # dispatch_policy.DispatchPlan


def pad_tenant_params(params, n_max: int):
    """Zero-pad an ``(n, n)`` register image onto the ``n_max`` fabric."""
    from repro.core.lif import LIFParams
    from repro.core.network import SNNParams

    n = params.w.shape[0]
    if n > n_max:
        raise ValueError(f"tenant has {n} neurons; fabric holds {n_max}")
    p2 = lambda a: jnp.pad(
        a, ((0, n_max - a.shape[0]), (0, n_max - a.shape[1])))
    p1 = lambda a, v=0: jnp.pad(a, (0, n_max - n), constant_values=v)
    lif = LIFParams(
        v_th=p1(params.lif.v_th, _PAD_VTH),
        leak=p1(params.lif.leak),
        r_ref=p1(params.lif.r_ref),
        gain=p1(params.lif.gain, 1.0),
        i_bias=p1(params.lif.i_bias),
        v_reset=p1(params.lif.v_reset),
    )
    # w_in may be rectangular (n_in, n): pad each axis to the fabric size.
    return SNNParams(w=p2(params.w), c=p2(params.c), w_in=p2(params.w_in), lif=lif)


class SNNServer:
    """Slot-batched multi-tenant SNN serving on one compiled tick program.

    S slots x one :class:`~repro.core.engine.TickEngine`, vmapped over the
    slot axis: every wave runs S independent networks -- heterogeneous
    ``C`` topologies, thresholds, leaks, even a mix of frozen and plastic
    tenants -- through ONE jitted program of static shape
    ``(slots, max_ticks, n_max)``. Admission is wave-batched like the LM
    :class:`WaveServer`; per-request tick budgets are runtime masks, so
    neither budgets nor tenant swaps ever retrace (``self.compiles``
    counts traces and must stay at 1 after warmup).

    Every wave runs the *learning* tick body (the engine's plasticity
    hook); frozen tenants pass an all-zero ``plastic_c``, which the STDP
    rule turns into an exact no-op -- one datapath for inference and
    learning, as NeuroCoreX does in silicon.
    """

    def __init__(self, *, n_max: int, slots: int = 8, max_ticks: int = 32,
                 mode: str = "fixed_leak", backend: str = "jnp",
                 plasticity=None, event_density: Optional[float] = None,
                 event_cap: Optional[int] = None, telemetry: bool = True,
                 registry: Optional[MetricsRegistry] = None,
                 options: Optional[EngineOptions] = None,
                 chunk_ticks: Optional[int] = None):
        """Args (beyond the obvious):

        backend: the default tick backend every tenant rides.
        event_density: when set, tenants whose topology density is at or
          below it (and whose max in-degree fits ``event_cap``) are served
          through a second resident program with ``backend="event"`` --
          the sparse tenants pick event dispatch per slot, dense tenants
          keep the default program.  None disables the event program.
        event_cap: fan-in cap (static shape) of the event program's padded
          neighbor lists; defaults to ``n_max // 4``.  One cap for the
          whole server keeps the event wave's shapes static, so tenant
          swaps never retrace (a tenant whose in-degree exceeds the cap
          simply stays on the dense program -- never truncated).
        telemetry: thread :class:`~repro.obs.telemetry.TickTelemetry`
          through every wave's scan carry (static flag -- the resident
          programs are traced with it once, never retraced). Feeds
          :meth:`tenant_report` and the spike/overflow/weight-delta
          metrics; False serves the exact telemetry-free programs.
        registry: a :class:`~repro.obs.metrics.MetricsRegistry` to report
          into; defaults to a fresh private one (``server.registry``).
        options: a validated :class:`~repro.core.engine.EngineOptions`
          superseding the per-call engine statics (``mode`` / ``backend``
          / ``plasticity`` / ``telemetry``) -- the preferred spelling;
          the individual kwargs remain as a compatibility shim.
        chunk_ticks: tick-chunk size for :meth:`serve_continuous`
          (default ``max(1, min(8, max_ticks // 4))``): smaller chunks
          retire/refill slots sooner (lower TTFT, higher goodput under
          mixed budgets) at more per-chunk host dispatch overhead.
        """
        from repro.core.engine import TickEngine
        from repro.plasticity import PlasticityParams

        if options is not None:
            mode = options.mode
            backend = options.backend
            telemetry = options.telemetry
            if options.plasticity is not None:
                plasticity = options.plasticity
        self.n_max = int(n_max)
        self.slots = int(slots)
        self.max_ticks = int(max_ticks)
        self.backend = backend
        self.event_density = event_density
        self.event_cap = int(event_cap or max(1, n_max // 4))
        self.telemetry = bool(telemetry)
        self.chunk_ticks = int(
            max(1, min(8, self.max_ticks // 4))
            if chunk_ticks is None else chunk_ticks)
        if not (1 <= self.chunk_ticks <= self.max_ticks):
            raise ValueError(
                f"chunk_ticks must lie in [1, max_ticks={self.max_ticks}], "
                f"got {self.chunk_ticks}")
        if plasticity is None:
            plasticity = PlasticityParams.make(
                "stdp", a_plus=0.5, a_minus=0.25, w_min=0.0, w_max=255.0)
        self._mk_engine = lambda b: TickEngine(EngineOptions(
            mode=mode, backend=b, plasticity=plasticity,
            telemetry=self.telemetry))
        self.engine = self._mk_engine(backend)
        self._engines = {backend: self.engine}
        self.tenants: Dict[str, Tenant] = {}
        self._compiles: Dict[str, int] = {}   # per-program, TRACE time only
        self._runs: Dict[str, object] = {}
        self._chunk_runs: Dict[tuple, object] = {}
        self._fresh_zeros = None
        self._tenant_obs: Dict[str, Dict] = {}  # accumulated telemetry
        self.registry = registry if registry is not None else MetricsRegistry()
        r = self.registry
        self._c_requests = r.counter(
            "snn_requests_total", "requests served to completion")
        self._c_rejected = r.counter(
            "snn_requests_rejected_total", "requests refused at admission")
        self._c_rej_reason = r.counter(
            "snn_admission_rejections_total",
            "admission rejections, by reason", ("reason",))
        self._c_waves = r.counter(
            "snn_waves_total", "waves run, by resident program", ("backend",))
        self._c_chunks = r.counter(
            "snn_chunks_total",
            "continuous-admission chunks run, by resident program",
            ("backend",))
        self._c_spikes = r.counter(
            "snn_spikes_out_total", "rate-decoded output spikes")
        self._c_slot_ticks = r.counter(
            "snn_slot_ticks_total", "slot-ticks executed (slots x ticks)")
        self._c_useful_ticks = r.counter(
            "snn_useful_slot_ticks_total",
            "slot-ticks inside a live request's budget (goodput numerator)")
        self._c_overflow = r.counter(
            "snn_event_overflow_ticks_total",
            "event-backend ticks that overflowed k_active to dense fallback")
        self._c_policy = r.counter(
            "snn_event_policy_dense_ticks_total",
            "event-backend ticks the adaptive knee routed dense for speed")
        self._c_dw = r.counter(
            "snn_weight_delta_l1_total", "summed |dw| applied by plasticity")
        self._g_queue = r.gauge("snn_queue_depth", "requests awaiting a wave")
        self._g_busy = r.gauge(
            "snn_slots_busy", "slots holding a live request right now")
        self._g_goodput = r.gauge(
            "snn_slot_ticks_per_s", "raw slot-tick rate of the last serve call")
        self._g_useful_goodput = r.gauge(
            "snn_goodput_slot_ticks_per_s",
            "useful (in-budget) slot-ticks per second of the last serve call")
        self._h_ttft = r.histogram(
            "snn_ttft_seconds", "enqueue-to-first-output latency")
        self._h_wave = r.histogram(
            "snn_wave_seconds", "wave wall time, by resident program",
            ("backend",))
        self._h_chunk = r.histogram(
            "snn_chunk_seconds", "chunk wall time, by resident program",
            ("backend",))

    @property
    def compiles(self) -> int:
        """Total trace count across the server's resident programs (one
        per backend in use; tenant/slot churn must never add to it)."""
        return sum(self._compiles.values())

    def _run_for(self, backend: str):
        if backend not in self._runs:
            self._engines.setdefault(backend, self._mk_engine(backend))
            self._runs[backend] = jax.jit(
                functools.partial(self._wave_fn, backend))
        return self._runs[backend]

    def _chunk_run_for(self, backend: str, chunk: int):
        """The jitted chunked step -- one resident program per
        (backend, chunk size), traced once; slot refills only rewrite
        its array arguments."""
        key = (backend, int(chunk))
        if key not in self._chunk_runs:
            self._engines.setdefault(backend, self._mk_engine(backend))
            self._chunk_runs[key] = jax.jit(
                functools.partial(self._chunk_fn, backend, int(chunk)))
        return self._chunk_runs[key]

    # -- tenant registry ---------------------------------------------------

    def add_tenant(self, name: str, bank, *, n_in: int, n_out: int,
                   plastic: bool = False) -> Tenant:
        """Register a tenant from its :class:`RegisterBank` image.

        The bank is the wire format (the paper's UART-fed registers);
        loading it is a parameter download -- shapes never change, so the
        resident program is never re-traced.
        """
        from repro.core.network import params_from_registers

        params = params_from_registers(bank)
        return self.add_tenant_params(name, params, n_in=n_in, n_out=n_out,
                                      plastic=plastic)

    def add_tenant_params(self, name: str, params, *, n_in: int, n_out: int,
                          plastic: bool = False) -> Tenant:
        n = params.w.shape[0]
        if not (0 < n_in <= n and 0 < n_out <= n):
            raise ValueError(
                f"tenant {name!r}: n_in={n_in}, n_out={n_out} must lie in "
                f"[1, {n}] (the tenant's live neuron count)")
        padded = pad_tenant_params(params, self.n_max)
        plastic_c = padded.c if plastic else jnp.zeros_like(padded.c)
        density = float(np.asarray(params.c).sum()) / max(1, n * n)
        backend, fan_idx, fan_mask, plan = self.backend, None, None, None
        if self.event_density is not None and density <= self.event_density:
            from repro.core import dispatch_policy

            # Admission-time dispatch plan (host side, concrete topology):
            # vmap_safe because the wave vmaps the rollout over slots (the
            # topk path's lax.cond would lower to a both-arms select);
            # prefer_density is the operator contract -- at or below the
            # server's threshold a fabric whose fan-in fits the shared cap
            # rides the event program regardless of the modeled cost.
            plan = dispatch_policy.plan(
                np.asarray(padded.c) > 0, w_in=np.asarray(padded.w_in),
                cap=self.event_cap, vmap_safe=True,
                prefer_density=self.event_density)
            if plan.strategy == "fan_in":
                # Sparse tenant: ride the event program. Fan-in lists are
                # built at the shared cap so every event slot stacks to
                # one static shape (no retrace on tenant swap).
                backend = "event"
                fan_idx = plan.neighbors.idx
                fan_mask = plan.neighbors.mask
        t = Tenant(name=name, n=n, n_in=n_in, n_out=n_out, plastic=plastic,
                   params=padded, plastic_c=plastic_c, density=density,
                   backend=backend, fan_idx=fan_idx, fan_mask=fan_mask,
                   plan=plan)
        self.tenants[name] = t
        return t

    # -- the one compiled program -----------------------------------------

    def _wave_fn(self, backend, params, ext_seq, plastic_c, rewards, budget,
                 fan_idx=None, fan_mask=None):
        """(slot-batched params, (S,T,N) ext, (S,N,N) mask, (S,T) rewards,
        (S,) budgets[, (S,N,cap) fan-in lists]) -> ((S,N) masked spike
        counts, (S,N,N) new weights).

        The per-slot budget gates BOTH the rate decode (ticks >= budget
        don't count) and the plasticity hook (``learn_until``): a request
        never learns past its own tick budget, so the persisted weights
        don't depend on the server's ``max_ticks`` ceiling.

        Event waves vmap the engine's fan-in gather path -- pure gathers,
        no data-dependent control flow, so the slot axis lowers exactly
        like the dense program's.

        With ``telemetry`` on, a per-slot
        :class:`~repro.obs.telemetry.TickTelemetry` rides the scan carry
        and is appended to the return tuple; it covers the full
        ``max_ticks`` rollout (ticks past a request's budget included --
        they run, they just don't count or learn)."""
        from repro.core.network import SNNState
        from repro.plasticity import PlasticityState

        self._compiles[backend] = self._compiles.get(backend, 0) + 1
        T, N = self.max_ticks, self.n_max
        engine = self._engines[backend]

        def per_slot(p, ext, pc, rew, until, fi, fm):
            from repro.kernels.ops import EventFanIn

            st = SNNState.zeros((), N)
            pst = PlasticityState.zeros((), N)
            nbrs = None if fi is None else EventFanIn(idx=fi, mask=fm)
            out = engine.learning_rollout(
                p, st, pst, ext, T, rewards=rew, plastic_c=pc,
                learn_until=until, neighbors=nbrs)
            if self.telemetry:
                (_, _, w2), raster, telem = out
                return raster, w2, telem           # (T, N), (N, N), scalars
            (_, _, w2), raster = out
            return raster, w2                      # (T, N), (N, N)

        out = jax.vmap(per_slot)(params, ext_seq, plastic_c, rewards,
                                 budget, fan_idx, fan_mask)
        raster, w2 = out[:2]
        # Per-request tick budgets: runtime masks, not shapes.
        tmask = (jnp.arange(T)[None, :] < budget[:, None]).astype(raster.dtype)
        counts = (raster * tmask[:, :, None]).sum(axis=1)   # (S, N) rate code
        return (counts, w2, out[2]) if self.telemetry else (counts, w2)

    def _chunk_fn(self, backend, chunk, params, carry, ext, plastic_c,
                  rewards, offset, budget, counts_acc,
                  fan_idx=None, fan_mask=None):
        """The continuous-admission step: run every resident slot for
        ``chunk`` ticks from its carried state.

        ``(slot-batched params, slot-batched TickCarry, (S,chunk,N) ext,
        (S,N,N) mask, (S,chunk) rewards, (S,) tick offsets, (S,)
        budgets, (S,N) running counts[, fan-in lists]) -> (next carry,
        (S,N) updated running counts)``.

        Counts accumulate *on device* -- the host only reads a slot's
        row back when its request retires, so consecutive chunks
        dispatch without a host round-trip between them.

        Everything per-request is *runtime data* -- offsets, budgets,
        the carry, even which tenant owns a slot (its registers are just
        array values) -- so one trace serves every refill; only the
        chunk size and backend are static. ``learn_until=budget`` rides
        the carry's own tick counter, which persists across chunks, so
        plasticity stops at exactly the same absolute tick as the wave
        path and the learned weights come back bit-identical. The count
        mask compares the absolute tick index (``offset + arange``)
        against the budget, so partial counts summed across chunks equal
        the wave path's one-shot masked sum exactly (small integers in
        f32 -- order-free)."""
        from repro.kernels.ops import EventFanIn

        key = f"chunk/{backend}"
        self._compiles[key] = self._compiles.get(key, 0) + 1
        engine = self._engines[backend]

        def per_slot(p, c, e, pc, rew, until, fi, fm):
            nbrs = None if fi is None else EventFanIn(idx=fi, mask=fm)
            c2, raster = engine.chunk(
                p, c, e, chunk, rewards=rew, plastic_c=pc,
                learn_until=until, neighbors=nbrs)
            return c2, raster

        carry2, raster = jax.vmap(per_slot)(
            params, carry, ext, plastic_c, rewards, budget,
            fan_idx, fan_mask)
        t_abs = offset[:, None] + jnp.arange(chunk)[None, :]     # (S, chunk)
        tmask = (t_abs < budget[:, None]).astype(raster.dtype)
        counts = (raster * tmask[:, :, None]).sum(axis=1)        # (S, N)
        return carry2, counts_acc + counts

    # -- wave assembly (host side) ----------------------------------------

    def _assemble(self, reqs: List[ServeRequest]):
        S, T, N = self.slots, self.max_ticks, self.n_max
        stack = lambda leaves: jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)
        params = stack([self.tenants[r.tenant].params for r in reqs])
        plastic_c = jnp.stack(
            [self.tenants[r.tenant].plastic_c for r in reqs])
        ext = np.zeros((S, T, N), np.float32)
        rew = np.zeros((S, T), np.float32)
        budget = np.zeros((S,), np.int32)
        for i, r in enumerate(reqs):
            t = min(r.ext.shape[0], T)
            ext[i, :t, : r.ext.shape[1]] = r.ext[:t]
            if r.rewards is not None:
                rew[i, : min(len(r.rewards), T)] = r.rewards[:T]
            budget[i] = 0 if r.rid < 0 else min(r.n_ticks, T)
        args = (params, jnp.asarray(ext), plastic_c, jnp.asarray(rew),
                jnp.asarray(budget))
        backends = {self.tenants[r.tenant].backend for r in reqs}
        if backends != {"event"}:
            return args + (None, None)
        fan_idx = jnp.stack([self.tenants[r.tenant].fan_idx for r in reqs])
        fan_mask = jnp.stack([self.tenants[r.tenant].fan_mask for r in reqs])
        return args + (fan_idx, fan_mask)

    def run_wave(self, reqs: List[ServeRequest]) -> None:
        """One wave: S tenant register images in, S rate-decoded outputs
        (and, for plastic tenants, learned weights written back).

        A wave is backend-homogeneous (admission groups by tenant
        backend), so each wave runs one of the server's resident
        programs -- no per-slot branching inside the compiled tick."""
        backends = {self.tenants[r.tenant].backend for r in reqs}
        if len(backends) != 1:
            raise ValueError(f"wave mixes backends {sorted(backends)}")
        backend = backends.pop()
        run = self._run_for(backend)
        with span(f"snn/wave/{backend}", histogram=self._h_wave,
                  backend=backend):
            out = jax.block_until_ready(run(*self._assemble(reqs)))
        self._c_waves.inc(backend=backend)
        self._c_slot_ticks.inc(self.slots * self.max_ticks)
        if self.telemetry:
            counts, w2, telem = out
            tel = jax.tree.map(np.asarray, telem)
            self._c_overflow.inc(float(tel.overflow.sum()))
            self._c_policy.inc(float(tel.policy_dense.sum()))
            self._c_dw.inc(float(tel.dw_l1.sum()))
        else:
            counts, w2 = out
            tel = None
        now = time.time()
        counts = np.asarray(counts)
        for i, r in enumerate(reqs):
            if r.rid < 0:
                continue
            t = self.tenants[r.tenant]
            out = counts[i, t.n - t.n_out : t.n]
            r.counts = out
            r.pred = int(out.argmax())
            r.t_first = r.t_done = now
            if tel is not None:
                self._observe_slot(t, tel, i)
            if t.plastic:
                # Register write-back: the tenant's next wave starts from
                # the weights this wave learned (still fabric-shaped).
                t.params = dataclasses.replace(t.params, w=w2[i])

    def _observe_slot(self, t: Tenant, tel, i: int) -> None:
        """Fold slot ``i`` of a wave's telemetry into the tenant ledger."""
        o = self._tenant_obs.setdefault(t.name, {
            "requests": 0, "ticks": 0, "spikes": 0.0, "v_max": 0.0,
            "ref_sum": 0.0, "overflow_ticks": 0, "policy_dense_ticks": 0,
            "dw_l1": 0.0})
        o["requests"] += 1
        o["ticks"] += int(tel.ticks[i])
        o["spikes"] += float(tel.spikes[i])
        o["v_max"] = max(o["v_max"], float(tel.v_max[i]))
        o["ref_sum"] += float(tel.ref_sum[i])
        o["overflow_ticks"] += int(tel.overflow[i])
        o["policy_dense_ticks"] += int(tel.policy_dense[i])
        o["dw_l1"] += float(tel.dw_l1[i])

    def tenant_report(self) -> Dict[str, Dict]:
        """Per-tenant activity from accumulated wave telemetry.

        ``spike_rate`` is spikes per live-neuron-tick (padded fabric
        neurons carry an unreachable threshold, so every spike belongs
        to one of the tenant's ``n`` live neurons); the refractory
        occupancy is rescaled from the fabric axis to live neurons the
        same way. Empty when the server was built with
        ``telemetry=False`` or has served nothing yet.
        """
        rep: Dict[str, Dict] = {}
        for name in sorted(self._tenant_obs):
            o, t = self._tenant_obs[name], self.tenants[name]
            ticks = o["ticks"]
            rescale = self.n_max / max(1, t.n)
            rep[name] = {
                "requests": o["requests"],
                "ticks": ticks,
                "spikes": o["spikes"],
                "spike_rate": round(o["spikes"] / max(1, ticks * t.n), 4),
                "v_max": round(o["v_max"], 4),
                "refractory_occupancy": round(
                    o["ref_sum"] / max(1, ticks) * rescale, 4),
                "overflow_ticks": o["overflow_ticks"],
                "policy_dense_ticks": o["policy_dense_ticks"],
                "dw_l1": round(o["dw_l1"], 3),
                "plastic": t.plastic,
                "backend": t.backend,
                "dispatch": t.plan.strategy if t.plan is not None else None,
            }
        return rep

    def _stats(self, *, mode: str, done: List[ServeRequest],
               n_rejected: int, waves: int = 0, chunks: int = 0,
               ticks: int = 0, slot_ticks: int = 0,
               wall_s: float = 0.0) -> Dict:
        """ONE stats schema for the wave path, the continuous path and
        the empty report -- identical key sets, no drift (pinned in
        tests/test_serve_continuous.py).

        ``slot_ticks_per_s`` is the raw rate (every tick the fabric ran,
        padding and post-budget ticks included); the goodput rate counts
        only ticks inside a live request's budget -- the quantity
        continuous admission exists to improve.
        """
        wall = max(1e-9, wall_s)
        ttfts = [r.t_first - r.t_submit for r in done]
        useful = sum(min(int(r.n_ticks), self.max_ticks) for r in done)
        total_spikes = float(sum(r.counts.sum() for r in done)) if done else 0.0
        return {
            "mode": mode,
            "n_requests": len(done),
            "requests_served": len(done),
            "requests_rejected": n_rejected,
            "n_tenants": len({r.tenant for r in done}),
            "waves": waves,
            "chunks": chunks,
            "ticks": ticks,
            "useful_slot_ticks": useful,
            "spikes_out": total_spikes,
            "wall_s": round(wall_s, 3),
            "spikes_per_s": round(total_spikes / wall, 1) if done else 0.0,
            "slot_ticks_per_s": round(slot_ticks / wall, 1) if done else 0.0,
            "goodput_slot_ticks_per_s":
                round(useful / wall, 1) if done else 0.0,
            "mean_ttft_s":
                round(float(np.mean(ttfts)), 4) if done else 0.0,
            "p99_ttft_s":
                round(float(np.percentile(ttfts, 99)), 4) if done else 0.0,
            "compiles": self.compiles,
            # One trace per resident program (per backend, plus per
            # (backend, chunk) for the continuous step) is warmup;
            # anything past that is a retrace regression.
            "recompiles_after_warmup": sum(
                max(0, c - 1) for c in self._compiles.values()),
            "backends": {
                b: sum(1 for r in done
                       if self.tenants[r.tenant].backend == b)
                for b in sorted({self.tenants[r.tenant].backend
                                 for r in done})},
            "preds": {r.rid: r.pred for r in done},
            "results": [ServeResult.of(r) for r in done],
        }

    def _empty_stats(self, rejected: int, mode: str = "wave") -> Dict:
        """A well-formed zero report: nothing ran, nothing was served."""
        return self._stats(mode=mode, done=[], n_rejected=rejected)

    def _reject_unknown(self, requests: List[ServeRequest]):
        """Split off requests naming an unregistered tenant (counted,
        logged, never a KeyError mid-wave)."""
        rejected = [r for r in requests if r.tenant not in self.tenants]
        if rejected:
            self._c_rejected.inc(len(rejected))
            self._c_rej_reason.inc(len(rejected), reason="unknown_tenant")
            log_event("snn_requests_rejected", n=len(rejected),
                      tenants=sorted({r.tenant for r in rejected}))
        return [r for r in requests if r.tenant in self.tenants], rejected

    def serve(self, requests: List[ServeRequest]) -> Dict:
        """Wave admission over a request queue + the LM server's stats.

        Admission first rejects requests naming an unregistered tenant
        (counted, logged, never a KeyError mid-wave), then groups the
        queue by tenant backend (waves are backend-homogeneous: a sparse
        tenant rides the event program, a dense one the default program
        -- each program compiled once, ever), then keeps at most ONE
        request per *plastic* tenant in any wave: two slots learning
        from the same pre-wave registers would race on the write-back
        (last slot wins, first request's learning silently lost).
        Deferred duplicates ride the next wave, which starts from the
        weights this wave learned.

        The returned per-call stats dict is a *view* over this call;
        ``server.registry`` accumulates the same quantities cumulatively
        across calls (Prometheus text via ``registry.to_prometheus()``).
        An empty or fully-rejected queue returns the zero report with
        ``requests_served: 0`` -- never a ``np.mean([])`` warning.
        """
        requests, rejected = self._reject_unknown(requests)
        if not requests:
            return self._empty_stats(len(rejected))
        now = time.time()
        for r in requests:
            if not r.t_submit:   # TTFT from enqueue: keep caller's stamp
                r.t_submit = now
        done: List[ServeRequest] = []
        waves = 0
        backends_in_use = sorted(
            {self.tenants[r.tenant].backend for r in requests})
        for backend in backends_in_use:
            queue = [r for r in requests
                     if self.tenants[r.tenant].backend == backend]
            while queue:
                self._g_queue.set(len(queue))
                wave, deferred, plastic_in_wave = [], [], set()
                for r in queue:
                    t = self.tenants[r.tenant]
                    admit = len(wave) < self.slots and not (
                        t.plastic and r.tenant in plastic_in_wave)
                    if admit:
                        wave.append(r)
                        if t.plastic:
                            plastic_in_wave.add(r.tenant)
                    else:
                        deferred.append(r)
                queue = deferred
                while len(wave) < self.slots:  # static batch: pad w/ dummy
                    wave.append(ServeRequest(
                        rid=-1, tenant=wave[0].tenant,
                        ext=np.zeros((1, 1), np.float32), n_ticks=0))
                self.run_wave(wave)
                done.extend(r for r in wave if r.rid >= 0)
                waves += 1
        self._g_queue.set(0)
        t0 = min(r.t_submit for r in done)
        t1 = max(r.t_done for r in done)
        stats = self._stats(
            mode="wave", done=done, n_rejected=len(rejected), waves=waves,
            ticks=waves * self.max_ticks,
            slot_ticks=waves * self.max_ticks * self.slots,
            wall_s=t1 - t0)
        self._c_requests.inc(len(done))
        self._c_spikes.inc(stats["spikes_out"])
        self._c_useful_ticks.inc(stats["useful_slot_ticks"])
        self._g_goodput.set(stats["slot_ticks_per_s"])
        self._g_useful_goodput.set(stats["goodput_slot_ticks_per_s"])
        for r in done:
            self._h_ttft.observe(r.t_first - r.t_submit)
        return stats

    # -- continuous admission (per-slot refill, not per-wave) --------------

    def _fresh_slot_carry(self, tenant: Tenant):
        """A fresh single-slot :class:`~repro.core.engine.TickCarry` for
        a just-admitted request: zeroed state/traces/telemetry, the
        tenant's current (possibly learned) weights.

        The zero leaves are tenant-independent (every tenant rides the
        same padded fabric), so they are built once and shared -- a
        refill must not pay a dozen eager zero-array dispatches."""
        from repro.core.engine import TickCarry
        from repro.core.network import SNNState
        from repro.plasticity import PlasticityState

        if self._fresh_zeros is None:
            telem = None
            if self.telemetry:
                from repro.obs.telemetry import TickTelemetry

                telem = TickTelemetry.zeros(())
            self._fresh_zeros = (SNNState.zeros((), self.n_max),
                                 PlasticityState.zeros((), self.n_max),
                                 telem)
        state, plast, telem = self._fresh_zeros
        return TickCarry(state=state, plast=plast,
                         w=tenant.params.w, telem=telem)

    def _fill_run_for(self, backend: str):
        """The jitted slot-refill program for ``backend``: writes one
        tenant image into slot ``i`` of the stacked program inputs in a
        single compiled call (one trace per backend; an eager
        ``.at[i].set`` per leaf costs ~1 ms each, which would dominate
        the chunk loop)."""
        key = ("fill", backend)
        if key not in self._chunk_runs:
            def _fill(stacked, image, i):
                k = f"fill/{backend}"
                self._compiles[k] = self._compiles.get(k, 0) + 1
                return jax.tree.map(lambda a, b: a.at[i].set(b),
                                    stacked, image)

            self._chunk_runs[key] = jax.jit(_fill)
        return self._chunk_runs[key]

    @staticmethod
    def _next_admittable(pending: deque, busy_plastic: set,
                         tenants: Dict[str, Tenant]):
        """Pop the first FIFO request whose tenant isn't a currently
        resident *plastic* tenant (two slots learning from the same
        pre-admission registers would race the write-back -- the wave
        path's one-plastic-request-per-wave rule, continuized)."""
        for idx, r in enumerate(pending):
            t = tenants[r.tenant]
            if t.plastic and r.tenant in busy_plastic:
                continue
            del pending[idx]
            return r
        return None

    def _route(self, r: ServeRequest, pending_map: Dict[str, deque],
               rejected: List[ServeRequest]) -> None:
        """Admit one (feeder-supplied) request into the right backend
        queue, stamping its enqueue time if the caller didn't."""
        if not r.t_submit:
            r.t_submit = time.time()
        if r.tenant not in self.tenants:
            self._c_rejected.inc()
            self._c_rej_reason.inc(reason="unknown_tenant")
            log_event("snn_requests_rejected", n=1, tenants=[r.tenant])
            rejected.append(r)
            return
        b = self.tenants[r.tenant].backend
        pending_map.setdefault(b, deque()).append(r)

    def serve_continuous(
        self,
        requests: Optional[List[ServeRequest]] = None,
        *,
        chunk_ticks: Optional[int] = None,
        feeder: Optional[Callable[[], Optional[ServeRequest]]] = None,
        on_complete: Optional[Callable[[ServeRequest], None]] = None,
    ) -> Dict:
        """Per-slot continuous admission: the tentpole replacement for
        wave admission.

        Instead of draining a whole wave before anything new admits, the
        fabric runs in chunks of ``chunk_ticks`` ticks; after each chunk,
        slots whose request exhausted its tick budget *retire* (decode,
        write back learned weights, complete) and are *refilled* from
        the queue -- without recompiling: the chunked step is one jitted
        program per (backend, chunk size), and a refill only rewrites
        its array arguments (registers, carry slices, budgets). Short
        requests no longer pay for long ones; a request's latency is its
        own budget plus at most ``chunk_ticks - 1`` overshoot ticks.

        Args:
          requests: the initial queue (any mix of tenants/backends).
          chunk_ticks: override the server's default chunk size.
          feeder: optional non-blocking callable polled once per chunk
            for late-arriving requests (``None`` = none right now); this
            is how the async front-end streams admissions into a running
            loop. The call returns when every queue is drained and the
            feeder (if any) has nothing more to give.
          on_complete: optional callback invoked (from this thread) with
            each request as it completes -- the async front-end resolves
            per-request futures here, long before the batch returns.

        Returns the same stats schema as :meth:`serve`, with
        ``mode="continuous"`` and chunk/goodput accounting filled in.
        Per-tenant outputs are bit-exact vs the wave path (oracle test:
        tests/test_serve_continuous.py).
        """
        chunk = int(self.chunk_ticks if chunk_ticks is None else chunk_ticks)
        if not (1 <= chunk <= self.max_ticks):
            raise ValueError(
                f"chunk_ticks must lie in [1, max_ticks={self.max_ticks}], "
                f"got {chunk}")
        t_start = time.time()
        requests, rejected = self._reject_unknown(list(requests or []))
        for r in requests:
            if not r.t_submit:
                r.t_submit = t_start
        pending_map: Dict[str, deque] = {}
        for r in requests:
            pending_map.setdefault(
                self.tenants[r.tenant].backend, deque()).append(r)
        done: List[ServeRequest] = []
        chunks = 0
        fed_dry = feeder is None
        while True:
            live = [b for b, q in pending_map.items() if q]
            if not live:
                if fed_dry:
                    break
                # One more feeder poll before giving up: a request may
                # have arrived between the last chunk and now.
                n_before = len(rejected)
                got = False
                while feeder is not None:
                    r = feeder()
                    if r is None:
                        break
                    self._route(r, pending_map, rejected)
                    got = True
                if not got and len(rejected) == n_before:
                    break
                continue
            # FIFO across backends: run the program whose queue holds
            # the oldest waiting request.
            backend = min(live, key=lambda b: pending_map[b][0].t_submit)
            chunks += self._continuous_group(
                backend, pending_map, rejected, chunk, feeder, on_complete,
                done)
        self._g_queue.set(0)
        self._g_busy.set(0)
        if not done:
            return self._empty_stats(len(rejected), mode="continuous")
        t0 = min(r.t_submit for r in done)
        t1 = max(r.t_done for r in done)
        stats = self._stats(
            mode="continuous", done=done, n_rejected=len(rejected),
            chunks=chunks, ticks=chunks * chunk,
            slot_ticks=chunks * chunk * self.slots, wall_s=t1 - t0)
        self._c_spikes.inc(stats["spikes_out"])
        self._g_goodput.set(stats["slot_ticks_per_s"])
        self._g_useful_goodput.set(stats["goodput_slot_ticks_per_s"])
        return stats

    def _continuous_group(self, backend: str, pending_map: Dict[str, deque],
                          rejected: List[ServeRequest], chunk: int,
                          feeder, on_complete,
                          done: List[ServeRequest]) -> int:
        """Run one backend's resident chunked program until its queue
        drains; returns the number of chunks run.

        Slot state (which request, tick offset, accumulated counts)
        lives host-side; the compiled step sees only arrays. Refill
        writes one slot's registers/carry via ``.at[i].set`` -- values,
        not shapes, so the program never retraces (pinned:
        ``recompiles_after_warmup == 0`` across refills)."""
        S, N = self.slots, self.n_max
        pending = pending_map.setdefault(backend, deque())
        run = self._chunk_run_for(backend, chunk)
        fill_run = self._fill_run_for(backend)
        slot_req: List[Optional[ServeRequest]] = [None] * S
        slot_tenant: List[Optional[Tenant]] = [None] * S
        busy_plastic: set = set()
        params_s = carry_s = plastic_c_s = counts_acc = None
        fan_idx_s = fan_mask_s = None
        zero_row = jnp.zeros((N,), jnp.float32)   # refill counts reset
        offset = np.zeros((S,), np.int64)   # absolute ticks already run
        budget = np.zeros((S,), np.int32)
        chunks = 0

        def fill(i: int, r: ServeRequest) -> None:
            nonlocal params_s, carry_s, plastic_c_s, counts_acc
            nonlocal fan_idx_s, fan_mask_s
            t = self.tenants[r.tenant]
            slot_req[i], slot_tenant[i] = r, t
            offset[i] = 0
            budget[i] = min(int(r.n_ticks), self.max_ticks)
            if t.plastic:
                busy_plastic.add(t.name)
            fresh = self._fresh_slot_carry(t)
            if params_s is None:
                # First fill seeds EVERY slot with this tenant's image;
                # idle slots ride along at budget 0 (masked to nothing),
                # exactly like the wave path's dummy padding.
                bcast = lambda x: jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (S,) + a.shape), x)
                params_s = bcast(t.params)
                carry_s = bcast(fresh)
                counts_acc = jnp.zeros((S, N), jnp.float32)
                plastic_c_s = jnp.broadcast_to(
                    t.plastic_c, (S,) + t.plastic_c.shape)
                if backend == "event":
                    fan_idx_s = jnp.broadcast_to(
                        t.fan_idx, (S,) + t.fan_idx.shape)
                    fan_mask_s = jnp.broadcast_to(
                        t.fan_mask, (S,) + t.fan_mask.shape)
                return
            ev = backend == "event"
            image = (t.params, fresh, t.plastic_c, zero_row,
                     t.fan_idx if ev else None, t.fan_mask if ev else None)
            stacked = (params_s, carry_s, plastic_c_s, counts_acc,
                       fan_idx_s, fan_mask_s)
            (params_s, carry_s, plastic_c_s, counts_acc,
             fan_idx_s, fan_mask_s) = fill_run(stacked, image, i)

        def retire(i: int, now: float, row: Optional[np.ndarray] = None,
                   tel=None) -> None:
            r, t = slot_req[i], slot_tenant[i]
            if row is None:   # the retire-time sync point
                row = np.asarray(counts_acc[i])
            out = row[t.n - t.n_out: t.n]
            r.counts = out
            r.pred = int(out.argmax())
            r.t_first = r.t_done = now
            if self.telemetry and carry_s is not None and offset[i] > 0:
                if tel is None:
                    tel = jax.tree.map(np.asarray, carry_s.telem)
                self._observe_slot(t, tel, i)
                self._c_overflow.inc(float(tel.overflow[i]))
                self._c_policy.inc(float(tel.policy_dense[i]))
                self._c_dw.inc(float(tel.dw_l1[i]))
            if t.plastic:
                # Register write-back, same as the wave path: the
                # tenant's next request starts from what this one learned.
                t.params = dataclasses.replace(t.params, w=carry_s.w[i])
                busy_plastic.discard(t.name)
            slot_req[i] = slot_tenant[i] = None
            done.append(r)
            self._c_requests.inc()
            self._c_useful_ticks.inc(int(budget[i]))
            self._h_ttft.observe(r.t_done - r.t_submit)
            if on_complete is not None:
                on_complete(r)

        while True:
            # Stream in late arrivals (the async front-end's feeder).
            while feeder is not None:
                r = feeder()
                if r is None:
                    break
                self._route(r, pending_map, rejected)
            # Refill free slots FIFO; zero-budget requests complete
            # without running a tick (counts all-zero, nothing learned).
            for i in range(S):
                if slot_req[i] is None and pending:
                    r = self._next_admittable(pending, busy_plastic,
                                              self.tenants)
                    if r is not None:
                        fill(i, r)
                if slot_req[i] is not None and budget[i] <= offset[i]:
                    retire(i, time.time())
            busy = [i for i in range(S) if slot_req[i] is not None]
            self._g_queue.set(sum(len(q) for q in pending_map.values()))
            self._g_busy.set(len(busy))
            if not busy:
                if pending:
                    continue   # freed a plastic tenant; re-admit
                break
            ext = np.zeros((S, chunk, N), np.float32)
            rew = np.zeros((S, chunk), np.float32)
            for i in busy:
                r = slot_req[i]
                o = int(offset[i])
                if r.ext is not None and o < r.ext.shape[0]:
                    seg = np.asarray(r.ext[o:o + chunk], np.float32)
                    ext[i, :seg.shape[0], :seg.shape[1]] = seg
                if r.rewards is not None and o < len(r.rewards):
                    seg = np.asarray(r.rewards[o:o + chunk], np.float32)
                    rew[i, :seg.shape[0]] = seg
            args = (params_s, carry_s, jnp.asarray(ext), plastic_c_s,
                    jnp.asarray(rew), jnp.asarray(offset, jnp.int32),
                    jnp.asarray(budget), counts_acc)
            if backend == "event":
                args += (fan_idx_s, fan_mask_s)
            # Dispatch-side timing: counts stay on device, so this span
            # does NOT wait for the chunk to execute -- consecutive
            # chunks pipeline, and the device queue only drains at a
            # retire (the counts row read).
            with span(f"snn/chunk/{backend}", histogram=self._h_chunk,
                      backend=backend):
                carry_s, counts_acc = run(*args)
            chunks += 1
            self._c_chunks.inc(backend=backend)
            self._c_slot_ticks.inc(S * chunk)
            for i in busy:
                offset[i] += chunk
            due = [i for i in busy if offset[i] >= budget[i]]
            if due:
                # One (S, N) read-back (and one telemetry pull) serves
                # every retire this round.
                rows = np.asarray(counts_acc)
                tel = (jax.tree.map(np.asarray, carry_s.telem)
                       if self.telemetry else None)
                now = time.time()
                for i in due:
                    retire(i, now, rows[i], tel)
        return chunks


def make_demo_tenants(server: SNNServer, n_tenants: int = 8, *,
                      seed: int = 0) -> List[str]:
    """Register ``n_tenants`` heterogeneous networks on the fabric.

    Mixed topologies (layered / ring / sparse-random / all-to-all),
    per-tenant thresholds and leaks, and one plastic (STDP) tenant --
    all loaded through the byte-exact :class:`RegisterBank` wire format.
    """
    from repro.core import connectivity
    from repro.core.registers import RegisterBank, WeightLayout

    rng = np.random.default_rng(seed)
    names: List[str] = []
    n_max = server.n_max
    for i in range(n_tenants):
        kind = ("layered", "ring", "sparse", "dense")[i % 4]
        n = int(rng.integers(max(6, n_max // 3), n_max + 1))
        if kind == "layered":
            n_in = max(2, n // 3)
            n_out = max(2, n // 4)
            hidden = n - n_in - n_out
            sizes = [n_in, hidden, n_out] if hidden > 0 else [n_in, n_out]
            c = connectivity.layered(sizes)
        elif kind == "ring":
            c = connectivity.ring(n, k=1 + i % 2)
            n_in, n_out = n, n
        elif kind == "sparse":
            # Sparse enough to clear the default event_density threshold:
            # these tenants ride the event program when it's enabled.
            c = connectivity.sparse_random(n, 0.1, seed=seed + i)
            n_in, n_out = n, n
        else:
            c = connectivity.all_to_all(n)
            n_in, n_out = n, n
        bank = RegisterBank(n, weight_layout=WeightLayout.PER_SYNAPSE)
        bank.set_connection_list(c)
        bank.set_weights(
            (rng.integers(40, 200, (n, n)) * c).astype(np.uint8))
        bank.set_thresholds(rng.integers(60, 160, (n,)).astype(np.uint8))
        bank.set_leak(int(rng.integers(0, 8)))
        bank.set_refractory(int(rng.integers(0, 3)))
        name = f"{kind}-{i}"
        server.add_tenant(name, bank, n_in=n_in, n_out=n_out,
                          plastic=(i == n_tenants - 1))
        names.append(name)
    return names


def make_demo_requests(server: SNNServer, names: List[str], n_requests: int,
                       *, seed: int = 0) -> List[ServeRequest]:
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        t = server.tenants[names[i % len(names)]]
        ticks = int(rng.integers(4, server.max_ticks + 1))
        # Impulse-register drive: spikes carry u8 magnitudes (paper Fig. 5),
        # sized so a spike can actually cross the tenants' u8 thresholds.
        ext = ((rng.random((ticks, t.n_in)) < 0.3)
               * rng.integers(80, 255, (ticks, t.n_in))).astype(np.float32)
        reqs.append(ServeRequest(rid=i, tenant=t.name, ext=ext, n_ticks=ticks))
    return reqs


def serve_snn_main(cfg, args) -> Dict:
    # Dense default program + event program for sparse tenants: tenants at
    # or below 20% density pick event dispatch per slot (DESIGN.md §10).
    backend = "jnp" if cfg.snn_backend == "event" else cfg.snn_backend
    server = SNNServer(n_max=cfg.n_neurons, slots=args.slots,
                       max_ticks=cfg.n_ticks, mode=cfg.snn_mode,
                       backend=backend, event_density=0.2,
                       chunk_ticks=max(
                           1, min(cfg.snn_chunk_ticks, cfg.n_ticks)))
    names = make_demo_tenants(server, max(8, args.slots))
    print(f"serving SNN fabric n_max={server.n_max}: {len(names)} resident "
          f"tenants, {args.slots} slots, {args.requests} requests")
    reqs = make_demo_requests(server, names, max(args.requests, len(names)))
    with profile(getattr(args, "profile", None)):
        if getattr(args, "continuous", False):
            stats = server.serve_continuous(reqs)
        else:
            stats = server.serve(reqs)
    for k, v in stats.items():
        if k == "results":
            continue
        print(f"{k}: {v}")
    report = server.tenant_report()
    if report:
        print("\nper-tenant activity (wave telemetry):")
        for name, row in report.items():
            print(f"  {name}: " + ", ".join(
                f"{k}={v}" for k, v in row.items()))
    print("\nmetrics exposition:")
    print(server.registry.to_prometheus())
    out = getattr(args, "metrics_out", None)
    if out:
        import json

        with open(out, "w") as fh:
            json.dump(server.registry.to_dict(), fh, indent=1, sort_keys=True)
        print(f"wrote metrics JSON to {out}")
    assert stats["recompiles_after_warmup"] == 0, "tenant swap recompiled!"
    return stats


def serve_sharded_main(cfg, args) -> Dict:
    """Serve a mesh-sharded fabric: ONE tenant occupying every device.

    The slotted :class:`SNNServer` time-shares one small fabric between
    many tenants; this is the other end of the scale axis (DESIGN.md
    §15): a single network too large for one device, its ``(n, n)``
    weight matrix partitioned by destination columns over the
    ``("model",)`` mesh from ``cfg.snn_mesh``.  The serving loop is the
    continuous-admission chunk contract reused verbatim -- jitted
    ``engine.chunk`` calls threading the (mesh-resident) carry, zero
    recompiles after warmup -- just with D devices under each chunk.

    At >=16384 neurons the topology is the implicit all-to-all
    (``c=None``): ``W*C`` is ``W`` itself and the second 16 GiB buffer
    never exists (the 64k memory escape hatch).
    """
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.core import connectivity
    from repro.core.engine import TickCarry, TickEngine
    from repro.core.lif import LIFParams
    from repro.core.network_types import SNNParams, SNNState
    from repro.launch.mesh import make_snn_mesh
    from repro.parallel import snn_sharding
    from repro.util.env import ensure_host_device_count

    n, n_dev = cfg.n_neurons, cfg.snn_mesh
    have = ensure_host_device_count(n_dev)
    if have < n_dev:
        raise SystemExit(
            f"config {cfg.name!r} wants a {n_dev}-device mesh but jax sees "
            f"{have} device(s) and its backend is already initialized; "
            f"re-run with XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_dev} (or let repro.util.env.ensure_host_device_count run "
            f"before anything touches jax)")
    mesh = make_snn_mesh(n_dev)

    backend = cfg.snn_backend
    use_implicit = n > 4096          # c=None: no (n, n) mask at scale
    if use_implicit and backend in ("pallas", "pallas_fused"):
        print(f"backend {backend!r} needs an explicit c; the implicit "
              f"all-to-all fabric at n={n} serves on 'jnp'")
        backend = "jnp"
    engine = TickEngine(EngineOptions(
        mode=cfg.snn_mode, backend=backend, telemetry=True, mesh=mesh))

    # -- build the fabric, shard-local where it is large ------------------
    w = snn_sharding.make_sharded_dyadic_weights(n, mesh)
    if use_implicit:
        c = None
    else:
        c_np = connectivity.sparse_random(n, cfg.snn_density, seed=0)
        sstats = connectivity.shard_stats(c_np, n_dev)
        print(f"topology: density={cfg.snn_density}, edge imbalance "
              f"across {n_dev} shards = "
              f"{connectivity.shard_imbalance(sstats):.3f}")
        c = jax.device_put(
            jnp.asarray(c_np, jnp.float32),
            NamedSharding(mesh, PartitionSpec(None, "model")))
    n_in = min(n, 256)
    rng = np.random.default_rng(7)
    w_in = jnp.asarray(
        rng.integers(0, 8, (n_in, n)).astype(np.float32) * 0.25)
    params = SNNParams(w=w, c=c, w_in=w_in,
                       lif=LIFParams.make(n, v_th=1.0, leak=0.25, r_ref=1))
    rules = snn_sharding.snn_rules(mesh)
    params = snn_sharding.place(
        params, snn_sharding.params_specs(rules, params), mesh)
    # Seed the telemetry slot up front: a carry whose pytree STRUCTURE
    # changes between warmup and steady state would retrace once.
    from repro.obs.telemetry import TickTelemetry

    carry = TickCarry(state=SNNState.zeros((), n),
                      telem=TickTelemetry.zeros(()))

    chunk_ticks = max(1, cfg.snn_chunk_ticks)
    n_chunks = max(2, args.requests)
    traces = 0

    @jax.jit
    def chunk_fn(params, carry, ext):
        nonlocal traces
        traces += 1
        return engine.chunk(params, carry, ext, chunk_ticks)

    def _ext():
        spikes = rng.random((chunk_ticks, n_in)) < cfg.snn_rate
        return jnp.asarray(spikes, jnp.float32)

    print(f"serving sharded SNN fabric n={n} on a {n_dev}-device mesh "
          f"({backend} backend, {chunk_ticks}-tick chunks, "
          f"{n_chunks} chunks)")
    carry, raster = chunk_fn(params, carry, _ext())      # warmup / compile
    jax.block_until_ready(raster)
    warm_traces = traces
    t0 = time.perf_counter()
    for _ in range(n_chunks):
        carry, raster = chunk_fn(params, carry, _ext())
    jax.block_until_ready(raster)
    dt = time.perf_counter() - t0

    ticks = n_chunks * chunk_ticks
    tel = carry.telem.summary(n)
    stats = {
        "mode": "sharded",
        "n_neurons": n,
        "n_devices": n_dev,
        "ticks": ticks,
        "ticks_per_s": ticks / dt,
        "synops_per_s": ticks / dt * float(n) * float(n),
        "recompiles_after_warmup": traces - warm_traces,
    }
    for k, v in stats.items():
        print(f"{k}: {v}")
    print("telemetry: " + ", ".join(f"{k}={v:.4g}" for k, v in tel.items()))
    out = getattr(args, "metrics_out", None)
    if out:
        import json

        with open(out, "w") as fh:
            json.dump({**stats, "telemetry": tel}, fh, indent=1,
                      sort_keys=True)
        print(f"wrote metrics JSON to {out}")
    assert stats["recompiles_after_warmup"] == 0, "chunk loop recompiled!"
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--continuous", action="store_true",
                    help="use per-slot continuous admission instead of "
                         "synchronous waves (SNN server only)")
    ap.add_argument("--profile", metavar="DIR", default=None,
                    help="capture a jax.profiler trace of the serve run "
                         "into DIR (view with TensorBoard/Perfetto)")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="dump the metrics registry as JSON to PATH "
                         "(SNN server only)")
    args = ap.parse_args(argv)

    bundle = get_bundle(args.arch)
    cfg = bundle.smoke if args.smoke else bundle.model
    if cfg.family == "snn":
        if cfg.snn_mesh:
            return serve_sharded_main(cfg, args)
        return serve_snn_main(cfg, args)
    print(f"serving {cfg.name}: {M.n_params(cfg):,} params, "
          f"{args.slots} slots, {args.requests} requests")
    params = M.init(cfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        if cfg.family == "audio":
            prompt = rng.integers(0, cfg.vocab_size, (plen, cfg.n_codebooks))
        else:
            prompt = rng.integers(0, cfg.vocab_size, (plen,))
        reqs.append(ServeRequest(rid=i, prompt=prompt.astype(np.int32),
                                 max_new=args.max_new))
    with profile(args.profile):
        stats = serve(cfg, params, reqs, slots=args.slots,
                      max_len=args.max_len)
    for k, v in stats.items():
        if k == "results":
            continue
        print(f"{k}: {v}")
    return stats


if __name__ == "__main__":
    main()
