"""Batched serving driver (the paper's kind: an inference platform).

Two server flavors share one shape of loop:

* :class:`WaveServer` -- the LM model zoo: requests are grouped into
  waves of ``slots``; each wave left-pads prompts to a common length,
  prefills the whole wave in one batched program, then decodes all slots
  in lock-step (one jitted decode program).

* :class:`SNNServer` -- the SNN processor itself, multi-tenant: S
  independent *networks* (each its own ``W/C/thresholds/leak`` register
  image, loaded via :func:`repro.core.network.params_from_registers`)
  ride one compiled tick program, vmapped over a slot axis. The slot
  axis is the TPU restatement of time-sharing the mux fabric
  (DESIGN.md §8): swapping a tenant in = rewriting a slot's registers,
  never recompiling.

Both mirror how the FPGA serves: one resident "fabric" (compiled
program), per-request state swapped in registers -- and like the FPGA,
switching requests never recompiles anything.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 6 --max-new 12
  PYTHONPATH=src python -m repro.launch.serve --arch snn --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_bundle
from repro.models import model as M
from repro.obs import MetricsRegistry, log_event, profile, span


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) or (S, K) int32
    max_new: int
    out: List = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None


class WaveServer:
    """One compiled prefill + one compiled decode program, reused forever."""

    def __init__(self, cfg, params, *, slots: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self._decode = jax.jit(lambda p, b, c: M.decode_fn(p, cfg, b, c))
        self._prefill = jax.jit(lambda p, b, c: M.prefill_fn(p, cfg, b, c))

    def _pad_prompts(self, reqs: List[Request]) -> np.ndarray:
        plen = max(len(r.prompt) for r in reqs)
        shape = (self.slots, plen) + (
            (self.cfg.n_codebooks,) if self.cfg.family == "audio" else ())
        toks = np.zeros(shape, np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad with 0
        return toks

    def run_wave(self, reqs: List[Request]) -> int:
        """Prefill + decode one wave to completion; returns decode steps."""
        cfg = self.cfg
        toks = self._pad_prompts(reqs)
        plen = toks.shape[1]
        caches = M.init_cache(cfg, self.slots, self.max_len)
        last, caches = self._prefill(self.params, {"inputs": jnp.asarray(toks)},
                                     caches)
        last_np = np.asarray(last, np.float32)        # (slots, V) or (slots,K,V)
        now = time.time()
        cur = last_np.argmax(-1).astype(np.int32)     # (slots,) or (slots, K)
        for r_i, r in enumerate(reqs):
            r.t_first = now
            r.out.append(int(np.atleast_1d(cur[r_i]).flat[0]))

        steps = 0
        pos = plen
        active = {i for i, r in enumerate(reqs) if len(r.out) < r.max_new}
        for r_i, r in enumerate(reqs):
            if r_i not in active:
                r.t_done = now
        max_new = max(r.max_new for r in reqs)
        while active and pos < self.max_len - 1 and steps < max_new:
            tok_in = cur[:, None] if cfg.family != "audio" else cur[:, None, :]
            batch = {"token": jnp.asarray(tok_in),
                     "pos": jnp.asarray(pos, jnp.int32)}
            logits, caches = self._decode(self.params, batch, caches)
            cur = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            steps += 1
            pos += 1
            now = time.time()
            for r_i in list(active):
                r = reqs[r_i]
                r.out.append(int(np.atleast_1d(cur[r_i]).flat[0]))
                if len(r.out) >= r.max_new:
                    r.t_done = now
                    active.discard(r_i)
        now = time.time()
        for r in reqs:
            if r.t_done is None:
                r.t_done = now
        return steps


def serve(cfg, params, requests: List[Request], *, slots: int = 4,
          max_len: int = 64) -> Dict:
    if not requests:
        # Empty queue: a well-formed zero report, never np.mean([]).
        return {"n_requests": 0, "requests_served": 0, "decode_steps": 0,
                "new_tokens": 0, "wall_s": 0.0, "tokens_per_s": 0.0,
                "mean_ttft_s": 0.0, "outputs": {}}
    server = WaveServer(cfg, params, slots=slots, max_len=max_len)
    for r in requests:
        r.t_submit = time.time()
    done: List[Request] = []
    steps = 0
    queue = list(requests)
    while queue:
        wave = queue[:slots]
        queue = queue[slots:]
        # pad the wave with a dummy clone so the batch shape is static
        while len(wave) < slots:
            wave.append(Request(rid=-1, prompt=wave[0].prompt, max_new=1))
        steps += server.run_wave(wave)
        done.extend(r for r in wave if r.rid >= 0)

    total_new = sum(len(r.out) for r in done)
    t0 = min(r.t_submit for r in done)
    t1 = max(r.t_done for r in done)
    return {
        "n_requests": len(done),
        "requests_served": len(done),
        "decode_steps": steps,
        "new_tokens": total_new,
        "wall_s": round(t1 - t0, 3),
        "tokens_per_s": round(total_new / max(1e-9, t1 - t0), 2),
        "mean_ttft_s": round(float(np.mean(
            [r.t_first - r.t_submit for r in done])), 3) if done else 0.0,
        "outputs": {r.rid: r.out[:8] for r in done},
    }


# ---------------------------------------------------------------------------
# Multi-tenant SNN serving: many resident networks, one compiled tick program
# ---------------------------------------------------------------------------

_PAD_VTH = 1e30  # padded neurons can never reach threshold


@dataclasses.dataclass
class Tenant:
    """One resident network: a register image padded onto the fabric.

    ``params`` leaves are fabric-shaped ``(n_max, ...)``; neurons past
    ``n`` carry an unreachable threshold (silent forever) and a zeroed
    connection/plastic mask (can never learn). ``plastic_c`` gates the
    learning hook per synapse: all-zero for frozen tenants, so their
    weights come back *bit-identical* from every wave.

    ``backend`` is the tick program this tenant rides: the server's
    default, or ``"event"`` when the tenant's topology is sparse enough
    to clear the server's ``event_density`` threshold (then ``fan_idx``
    / ``fan_mask`` hold its padded fan-in lists, fabric-shaped
    ``(n_max, event_cap)`` so every event-wave slot stacks to one static
    shape).
    """

    name: str
    n: int
    n_in: int
    n_out: int
    plastic: bool
    params: "object"            # repro.core.network.SNNParams, padded
    plastic_c: jax.Array        # (n_max, n_max)
    density: float = 1.0
    backend: str = "jnp"
    fan_idx: Optional[jax.Array] = None   # (n_max, event_cap) i32
    fan_mask: Optional[jax.Array] = None  # (n_max, event_cap) f32
    plan: Optional["object"] = None       # dispatch_policy.DispatchPlan


@dataclasses.dataclass
class SNNRequest:
    rid: int
    tenant: str
    ext: np.ndarray                       # (T_req, n_in) input spike train
    n_ticks: int                          # tick budget for this request
    rewards: Optional[np.ndarray] = None  # (T_req,) dopamine (R-STDP servers)
    counts: Optional[np.ndarray] = None   # (n_out,) rate-decoded spike counts
    pred: Optional[int] = None            # argmax over output neurons
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None


def pad_tenant_params(params, n_max: int):
    """Zero-pad an ``(n, n)`` register image onto the ``n_max`` fabric."""
    from repro.core.lif import LIFParams
    from repro.core.network import SNNParams

    n = params.w.shape[0]
    if n > n_max:
        raise ValueError(f"tenant has {n} neurons; fabric holds {n_max}")
    p2 = lambda a: jnp.pad(
        a, ((0, n_max - a.shape[0]), (0, n_max - a.shape[1])))
    p1 = lambda a, v=0: jnp.pad(a, (0, n_max - n), constant_values=v)
    lif = LIFParams(
        v_th=p1(params.lif.v_th, _PAD_VTH),
        leak=p1(params.lif.leak),
        r_ref=p1(params.lif.r_ref),
        gain=p1(params.lif.gain, 1.0),
        i_bias=p1(params.lif.i_bias),
        v_reset=p1(params.lif.v_reset),
    )
    # w_in may be rectangular (n_in, n): pad each axis to the fabric size.
    return SNNParams(w=p2(params.w), c=p2(params.c), w_in=p2(params.w_in), lif=lif)


class SNNServer:
    """Slot-batched multi-tenant SNN serving on one compiled tick program.

    S slots x one :class:`~repro.core.engine.TickEngine`, vmapped over the
    slot axis: every wave runs S independent networks -- heterogeneous
    ``C`` topologies, thresholds, leaks, even a mix of frozen and plastic
    tenants -- through ONE jitted program of static shape
    ``(slots, max_ticks, n_max)``. Admission is wave-batched like the LM
    :class:`WaveServer`; per-request tick budgets are runtime masks, so
    neither budgets nor tenant swaps ever retrace (``self.compiles``
    counts traces and must stay at 1 after warmup).

    Every wave runs the *learning* tick body (the engine's plasticity
    hook); frozen tenants pass an all-zero ``plastic_c``, which the STDP
    rule turns into an exact no-op -- one datapath for inference and
    learning, as NeuroCoreX does in silicon.
    """

    def __init__(self, *, n_max: int, slots: int = 8, max_ticks: int = 32,
                 mode: str = "fixed_leak", backend: str = "jnp",
                 plasticity=None, event_density: Optional[float] = None,
                 event_cap: Optional[int] = None, telemetry: bool = True,
                 registry: Optional[MetricsRegistry] = None):
        """Args (beyond the obvious):

        backend: the default tick backend every tenant rides.
        event_density: when set, tenants whose topology density is at or
          below it (and whose max in-degree fits ``event_cap``) are served
          through a second resident program with ``backend="event"`` --
          the sparse tenants pick event dispatch per slot, dense tenants
          keep the default program.  None disables the event program.
        event_cap: fan-in cap (static shape) of the event program's padded
          neighbor lists; defaults to ``n_max // 4``.  One cap for the
          whole server keeps the event wave's shapes static, so tenant
          swaps never retrace (a tenant whose in-degree exceeds the cap
          simply stays on the dense program -- never truncated).
        telemetry: thread :class:`~repro.obs.telemetry.TickTelemetry`
          through every wave's scan carry (static flag -- the resident
          programs are traced with it once, never retraced). Feeds
          :meth:`tenant_report` and the spike/overflow/weight-delta
          metrics; False serves the exact telemetry-free programs.
        registry: a :class:`~repro.obs.metrics.MetricsRegistry` to report
          into; defaults to a fresh private one (``server.registry``).
        """
        from repro.core.engine import TickEngine
        from repro.plasticity import PlasticityParams

        self.n_max = int(n_max)
        self.slots = int(slots)
        self.max_ticks = int(max_ticks)
        self.backend = backend
        self.event_density = event_density
        self.event_cap = int(event_cap or max(1, n_max // 4))
        self.telemetry = bool(telemetry)
        if plasticity is None:
            plasticity = PlasticityParams.make(
                "stdp", a_plus=0.5, a_minus=0.25, w_min=0.0, w_max=255.0)
        self._mk_engine = lambda b: TickEngine(mode=mode, backend=b,
                                               plasticity=plasticity,
                                               telemetry=self.telemetry)
        self.engine = self._mk_engine(backend)
        self._engines = {backend: self.engine}
        self.tenants: Dict[str, Tenant] = {}
        self._compiles: Dict[str, int] = {}   # per-program, TRACE time only
        self._runs: Dict[str, object] = {}
        self._tenant_obs: Dict[str, Dict] = {}  # accumulated telemetry
        self.registry = registry if registry is not None else MetricsRegistry()
        r = self.registry
        self._c_requests = r.counter(
            "snn_requests_total", "requests served to completion")
        self._c_rejected = r.counter(
            "snn_requests_rejected_total", "requests refused at admission")
        self._c_waves = r.counter(
            "snn_waves_total", "waves run, by resident program", ("backend",))
        self._c_spikes = r.counter(
            "snn_spikes_out_total", "rate-decoded output spikes")
        self._c_slot_ticks = r.counter(
            "snn_slot_ticks_total", "slot-ticks executed (slots x ticks)")
        self._c_overflow = r.counter(
            "snn_event_overflow_ticks_total",
            "event-backend ticks that overflowed k_active to dense fallback")
        self._c_policy = r.counter(
            "snn_event_policy_dense_ticks_total",
            "event-backend ticks the adaptive knee routed dense for speed")
        self._c_dw = r.counter(
            "snn_weight_delta_l1_total", "summed |dw| applied by plasticity")
        self._g_queue = r.gauge("snn_queue_depth", "requests awaiting a wave")
        self._g_goodput = r.gauge(
            "snn_slot_ticks_per_s", "goodput of the last serve() call")
        self._h_ttft = r.histogram(
            "snn_ttft_seconds", "submit-to-first-output latency")
        self._h_wave = r.histogram(
            "snn_wave_seconds", "wave wall time, by resident program",
            ("backend",))

    @property
    def compiles(self) -> int:
        """Total trace count across the server's resident programs (one
        per backend in use; tenant/slot churn must never add to it)."""
        return sum(self._compiles.values())

    def _run_for(self, backend: str):
        if backend not in self._runs:
            self._engines.setdefault(backend, self._mk_engine(backend))
            self._runs[backend] = jax.jit(
                functools.partial(self._wave_fn, backend))
        return self._runs[backend]

    # -- tenant registry ---------------------------------------------------

    def add_tenant(self, name: str, bank, *, n_in: int, n_out: int,
                   plastic: bool = False) -> Tenant:
        """Register a tenant from its :class:`RegisterBank` image.

        The bank is the wire format (the paper's UART-fed registers);
        loading it is a parameter download -- shapes never change, so the
        resident program is never re-traced.
        """
        from repro.core.network import params_from_registers

        params = params_from_registers(bank)
        return self.add_tenant_params(name, params, n_in=n_in, n_out=n_out,
                                      plastic=plastic)

    def add_tenant_params(self, name: str, params, *, n_in: int, n_out: int,
                          plastic: bool = False) -> Tenant:
        n = params.w.shape[0]
        if not (0 < n_in <= n and 0 < n_out <= n):
            raise ValueError(
                f"tenant {name!r}: n_in={n_in}, n_out={n_out} must lie in "
                f"[1, {n}] (the tenant's live neuron count)")
        padded = pad_tenant_params(params, self.n_max)
        plastic_c = padded.c if plastic else jnp.zeros_like(padded.c)
        density = float(np.asarray(params.c).sum()) / max(1, n * n)
        backend, fan_idx, fan_mask, plan = self.backend, None, None, None
        if self.event_density is not None and density <= self.event_density:
            from repro.core import dispatch_policy

            # Admission-time dispatch plan (host side, concrete topology):
            # vmap_safe because the wave vmaps the rollout over slots (the
            # topk path's lax.cond would lower to a both-arms select);
            # prefer_density is the operator contract -- at or below the
            # server's threshold a fabric whose fan-in fits the shared cap
            # rides the event program regardless of the modeled cost.
            plan = dispatch_policy.plan(
                np.asarray(padded.c) > 0, w_in=np.asarray(padded.w_in),
                cap=self.event_cap, vmap_safe=True,
                prefer_density=self.event_density)
            if plan.strategy == "fan_in":
                # Sparse tenant: ride the event program. Fan-in lists are
                # built at the shared cap so every event slot stacks to
                # one static shape (no retrace on tenant swap).
                backend = "event"
                fan_idx = plan.neighbors.idx
                fan_mask = plan.neighbors.mask
        t = Tenant(name=name, n=n, n_in=n_in, n_out=n_out, plastic=plastic,
                   params=padded, plastic_c=plastic_c, density=density,
                   backend=backend, fan_idx=fan_idx, fan_mask=fan_mask,
                   plan=plan)
        self.tenants[name] = t
        return t

    # -- the one compiled program -----------------------------------------

    def _wave_fn(self, backend, params, ext_seq, plastic_c, rewards, budget,
                 fan_idx=None, fan_mask=None):
        """(slot-batched params, (S,T,N) ext, (S,N,N) mask, (S,T) rewards,
        (S,) budgets[, (S,N,cap) fan-in lists]) -> ((S,N) masked spike
        counts, (S,N,N) new weights).

        The per-slot budget gates BOTH the rate decode (ticks >= budget
        don't count) and the plasticity hook (``learn_until``): a request
        never learns past its own tick budget, so the persisted weights
        don't depend on the server's ``max_ticks`` ceiling.

        Event waves vmap the engine's fan-in gather path -- pure gathers,
        no data-dependent control flow, so the slot axis lowers exactly
        like the dense program's.

        With ``telemetry`` on, a per-slot
        :class:`~repro.obs.telemetry.TickTelemetry` rides the scan carry
        and is appended to the return tuple; it covers the full
        ``max_ticks`` rollout (ticks past a request's budget included --
        they run, they just don't count or learn)."""
        from repro.core.network import SNNState
        from repro.plasticity import PlasticityState

        self._compiles[backend] = self._compiles.get(backend, 0) + 1
        T, N = self.max_ticks, self.n_max
        engine = self._engines[backend]

        def per_slot(p, ext, pc, rew, until, fi, fm):
            from repro.kernels.ops import EventFanIn

            st = SNNState.zeros((), N)
            pst = PlasticityState.zeros((), N)
            nbrs = None if fi is None else EventFanIn(idx=fi, mask=fm)
            out = engine.learning_rollout(
                p, st, pst, ext, T, rewards=rew, plastic_c=pc,
                learn_until=until, neighbors=nbrs)
            if self.telemetry:
                (_, _, w2), raster, telem = out
                return raster, w2, telem           # (T, N), (N, N), scalars
            (_, _, w2), raster = out
            return raster, w2                      # (T, N), (N, N)

        out = jax.vmap(per_slot)(params, ext_seq, plastic_c, rewards,
                                 budget, fan_idx, fan_mask)
        raster, w2 = out[:2]
        # Per-request tick budgets: runtime masks, not shapes.
        tmask = (jnp.arange(T)[None, :] < budget[:, None]).astype(raster.dtype)
        counts = (raster * tmask[:, :, None]).sum(axis=1)   # (S, N) rate code
        return (counts, w2, out[2]) if self.telemetry else (counts, w2)

    # -- wave assembly (host side) ----------------------------------------

    def _assemble(self, reqs: List[SNNRequest]):
        S, T, N = self.slots, self.max_ticks, self.n_max
        stack = lambda leaves: jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)
        params = stack([self.tenants[r.tenant].params for r in reqs])
        plastic_c = jnp.stack(
            [self.tenants[r.tenant].plastic_c for r in reqs])
        ext = np.zeros((S, T, N), np.float32)
        rew = np.zeros((S, T), np.float32)
        budget = np.zeros((S,), np.int32)
        for i, r in enumerate(reqs):
            t = min(r.ext.shape[0], T)
            ext[i, :t, : r.ext.shape[1]] = r.ext[:t]
            if r.rewards is not None:
                rew[i, : min(len(r.rewards), T)] = r.rewards[:T]
            budget[i] = 0 if r.rid < 0 else min(r.n_ticks, T)
        args = (params, jnp.asarray(ext), plastic_c, jnp.asarray(rew),
                jnp.asarray(budget))
        backends = {self.tenants[r.tenant].backend for r in reqs}
        if backends != {"event"}:
            return args + (None, None)
        fan_idx = jnp.stack([self.tenants[r.tenant].fan_idx for r in reqs])
        fan_mask = jnp.stack([self.tenants[r.tenant].fan_mask for r in reqs])
        return args + (fan_idx, fan_mask)

    def run_wave(self, reqs: List[SNNRequest]) -> None:
        """One wave: S tenant register images in, S rate-decoded outputs
        (and, for plastic tenants, learned weights written back).

        A wave is backend-homogeneous (admission groups by tenant
        backend), so each wave runs one of the server's resident
        programs -- no per-slot branching inside the compiled tick."""
        backends = {self.tenants[r.tenant].backend for r in reqs}
        if len(backends) != 1:
            raise ValueError(f"wave mixes backends {sorted(backends)}")
        backend = backends.pop()
        run = self._run_for(backend)
        with span(f"snn/wave/{backend}", histogram=self._h_wave,
                  backend=backend):
            out = jax.block_until_ready(run(*self._assemble(reqs)))
        self._c_waves.inc(backend=backend)
        self._c_slot_ticks.inc(self.slots * self.max_ticks)
        if self.telemetry:
            counts, w2, telem = out
            tel = jax.tree.map(np.asarray, telem)
            self._c_overflow.inc(float(tel.overflow.sum()))
            self._c_policy.inc(float(tel.policy_dense.sum()))
            self._c_dw.inc(float(tel.dw_l1.sum()))
        else:
            counts, w2 = out
            tel = None
        now = time.time()
        counts = np.asarray(counts)
        for i, r in enumerate(reqs):
            if r.rid < 0:
                continue
            t = self.tenants[r.tenant]
            out = counts[i, t.n - t.n_out : t.n]
            r.counts = out
            r.pred = int(out.argmax())
            r.t_first = r.t_done = now
            if tel is not None:
                self._observe_slot(t, tel, i)
            if t.plastic:
                # Register write-back: the tenant's next wave starts from
                # the weights this wave learned (still fabric-shaped).
                t.params = dataclasses.replace(t.params, w=w2[i])

    def _observe_slot(self, t: Tenant, tel, i: int) -> None:
        """Fold slot ``i`` of a wave's telemetry into the tenant ledger."""
        o = self._tenant_obs.setdefault(t.name, {
            "requests": 0, "ticks": 0, "spikes": 0.0, "v_max": 0.0,
            "ref_sum": 0.0, "overflow_ticks": 0, "policy_dense_ticks": 0,
            "dw_l1": 0.0})
        o["requests"] += 1
        o["ticks"] += int(tel.ticks[i])
        o["spikes"] += float(tel.spikes[i])
        o["v_max"] = max(o["v_max"], float(tel.v_max[i]))
        o["ref_sum"] += float(tel.ref_sum[i])
        o["overflow_ticks"] += int(tel.overflow[i])
        o["policy_dense_ticks"] += int(tel.policy_dense[i])
        o["dw_l1"] += float(tel.dw_l1[i])

    def tenant_report(self) -> Dict[str, Dict]:
        """Per-tenant activity from accumulated wave telemetry.

        ``spike_rate`` is spikes per live-neuron-tick (padded fabric
        neurons carry an unreachable threshold, so every spike belongs
        to one of the tenant's ``n`` live neurons); the refractory
        occupancy is rescaled from the fabric axis to live neurons the
        same way. Empty when the server was built with
        ``telemetry=False`` or has served nothing yet.
        """
        rep: Dict[str, Dict] = {}
        for name in sorted(self._tenant_obs):
            o, t = self._tenant_obs[name], self.tenants[name]
            ticks = o["ticks"]
            rescale = self.n_max / max(1, t.n)
            rep[name] = {
                "requests": o["requests"],
                "ticks": ticks,
                "spikes": o["spikes"],
                "spike_rate": round(o["spikes"] / max(1, ticks * t.n), 4),
                "v_max": round(o["v_max"], 4),
                "refractory_occupancy": round(
                    o["ref_sum"] / max(1, ticks) * rescale, 4),
                "overflow_ticks": o["overflow_ticks"],
                "policy_dense_ticks": o["policy_dense_ticks"],
                "dw_l1": round(o["dw_l1"], 3),
                "plastic": t.plastic,
                "backend": t.backend,
                "dispatch": t.plan.strategy if t.plan is not None else None,
            }
        return rep

    def _empty_stats(self, rejected: int) -> Dict:
        """A well-formed zero report: no waves ran, nothing was served."""
        return {"n_requests": 0, "requests_served": 0,
                "requests_rejected": rejected,
                "n_tenants": 0, "waves": 0, "ticks": 0,
                "spikes_out": 0.0, "wall_s": 0.0, "spikes_per_s": 0.0,
                "slot_ticks_per_s": 0.0, "mean_ttft_s": 0.0,
                "compiles": self.compiles,
                "recompiles_after_warmup": sum(
                    max(0, c - 1) for c in self._compiles.values()),
                "backends": {}, "preds": {}}

    def serve(self, requests: List[SNNRequest]) -> Dict:
        """Wave admission over a request queue + the LM server's stats.

        Admission first rejects requests naming an unregistered tenant
        (counted, logged, never a KeyError mid-wave), then groups the
        queue by tenant backend (waves are backend-homogeneous: a sparse
        tenant rides the event program, a dense one the default program
        -- each program compiled once, ever), then keeps at most ONE
        request per *plastic* tenant in any wave: two slots learning
        from the same pre-wave registers would race on the write-back
        (last slot wins, first request's learning silently lost).
        Deferred duplicates ride the next wave, which starts from the
        weights this wave learned.

        The returned per-call stats dict is a *view* over this call;
        ``server.registry`` accumulates the same quantities cumulatively
        across calls (Prometheus text via ``registry.to_prometheus()``).
        An empty or fully-rejected queue returns the zero report with
        ``requests_served: 0`` -- never a ``np.mean([])`` warning.
        """
        rejected = [r for r in requests if r.tenant not in self.tenants]
        if rejected:
            self._c_rejected.inc(len(rejected))
            log_event("snn_requests_rejected", n=len(rejected),
                      tenants=sorted({r.tenant for r in rejected}))
        requests = [r for r in requests if r.tenant in self.tenants]
        if not requests:
            return self._empty_stats(len(rejected))
        for r in requests:
            r.t_submit = time.time()
        done: List[SNNRequest] = []
        waves = 0
        backends_in_use = sorted(
            {self.tenants[r.tenant].backend for r in requests})
        for backend in backends_in_use:
            queue = [r for r in requests
                     if self.tenants[r.tenant].backend == backend]
            while queue:
                self._g_queue.set(len(queue))
                wave, deferred, plastic_in_wave = [], [], set()
                for r in queue:
                    t = self.tenants[r.tenant]
                    admit = len(wave) < self.slots and not (
                        t.plastic and r.tenant in plastic_in_wave)
                    if admit:
                        wave.append(r)
                        if t.plastic:
                            plastic_in_wave.add(r.tenant)
                    else:
                        deferred.append(r)
                queue = deferred
                while len(wave) < self.slots:  # static batch: pad w/ dummy
                    wave.append(SNNRequest(
                        rid=-1, tenant=wave[0].tenant,
                        ext=np.zeros((1, 1), np.float32), n_ticks=0))
                self.run_wave(wave)
                done.extend(r for r in wave if r.rid >= 0)
                waves += 1
        self._g_queue.set(0)
        total_spikes = float(sum(r.counts.sum() for r in done))
        t0 = min(r.t_submit for r in done)
        t1 = max(r.t_done for r in done)
        goodput = round(
            waves * self.max_ticks * self.slots / max(1e-9, t1 - t0), 1)
        self._c_requests.inc(len(done))
        self._c_spikes.inc(total_spikes)
        self._g_goodput.set(goodput)
        for r in done:
            self._h_ttft.observe(r.t_first - r.t_submit)
        return {
            "n_requests": len(done),
            "requests_served": len(done),
            "requests_rejected": len(rejected),
            "n_tenants": len({r.tenant for r in done}),
            "waves": waves,
            "ticks": waves * self.max_ticks,
            "spikes_out": total_spikes,
            "wall_s": round(t1 - t0, 3),
            "spikes_per_s": round(total_spikes / max(1e-9, t1 - t0), 1),
            "slot_ticks_per_s": goodput,
            "mean_ttft_s": round(float(np.mean(
                [r.t_first - r.t_submit for r in done])), 4) if done else 0.0,
            "compiles": self.compiles,
            # One trace per resident program (per backend) is warmup;
            # anything past that is a retrace regression.
            "recompiles_after_warmup": sum(
                max(0, c - 1) for c in self._compiles.values()),
            "backends": {b: sum(1 for r in done
                                if self.tenants[r.tenant].backend == b)
                         for b in backends_in_use},
            "preds": {r.rid: r.pred for r in done},
        }


def make_demo_tenants(server: SNNServer, n_tenants: int = 8, *,
                      seed: int = 0) -> List[str]:
    """Register ``n_tenants`` heterogeneous networks on the fabric.

    Mixed topologies (layered / ring / sparse-random / all-to-all),
    per-tenant thresholds and leaks, and one plastic (STDP) tenant --
    all loaded through the byte-exact :class:`RegisterBank` wire format.
    """
    from repro.core import connectivity
    from repro.core.registers import RegisterBank, WeightLayout

    rng = np.random.default_rng(seed)
    names: List[str] = []
    n_max = server.n_max
    for i in range(n_tenants):
        kind = ("layered", "ring", "sparse", "dense")[i % 4]
        n = int(rng.integers(max(6, n_max // 3), n_max + 1))
        if kind == "layered":
            n_in = max(2, n // 3)
            n_out = max(2, n // 4)
            hidden = n - n_in - n_out
            sizes = [n_in, hidden, n_out] if hidden > 0 else [n_in, n_out]
            c = connectivity.layered(sizes)
        elif kind == "ring":
            c = connectivity.ring(n, k=1 + i % 2)
            n_in, n_out = n, n
        elif kind == "sparse":
            # Sparse enough to clear the default event_density threshold:
            # these tenants ride the event program when it's enabled.
            c = connectivity.sparse_random(n, 0.1, seed=seed + i)
            n_in, n_out = n, n
        else:
            c = connectivity.all_to_all(n)
            n_in, n_out = n, n
        bank = RegisterBank(n, weight_layout=WeightLayout.PER_SYNAPSE)
        bank.set_connection_list(c)
        bank.set_weights(
            (rng.integers(40, 200, (n, n)) * c).astype(np.uint8))
        bank.set_thresholds(rng.integers(60, 160, (n,)).astype(np.uint8))
        bank.set_leak(int(rng.integers(0, 8)))
        bank.set_refractory(int(rng.integers(0, 3)))
        name = f"{kind}-{i}"
        server.add_tenant(name, bank, n_in=n_in, n_out=n_out,
                          plastic=(i == n_tenants - 1))
        names.append(name)
    return names


def make_demo_requests(server: SNNServer, names: List[str], n_requests: int,
                       *, seed: int = 0) -> List[SNNRequest]:
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        t = server.tenants[names[i % len(names)]]
        ticks = int(rng.integers(4, server.max_ticks + 1))
        # Impulse-register drive: spikes carry u8 magnitudes (paper Fig. 5),
        # sized so a spike can actually cross the tenants' u8 thresholds.
        ext = ((rng.random((ticks, t.n_in)) < 0.3)
               * rng.integers(80, 255, (ticks, t.n_in))).astype(np.float32)
        reqs.append(SNNRequest(rid=i, tenant=t.name, ext=ext, n_ticks=ticks))
    return reqs


def serve_snn_main(cfg, args) -> Dict:
    # Dense default program + event program for sparse tenants: tenants at
    # or below 20% density pick event dispatch per slot (DESIGN.md §10).
    backend = "jnp" if cfg.snn_backend == "event" else cfg.snn_backend
    server = SNNServer(n_max=cfg.n_neurons, slots=args.slots,
                       max_ticks=cfg.n_ticks, mode=cfg.snn_mode,
                       backend=backend, event_density=0.2)
    names = make_demo_tenants(server, max(8, args.slots))
    print(f"serving SNN fabric n_max={server.n_max}: {len(names)} resident "
          f"tenants, {args.slots} slots, {args.requests} requests")
    reqs = make_demo_requests(server, names, max(args.requests, len(names)))
    with profile(getattr(args, "profile", None)):
        stats = server.serve(reqs)
    for k, v in stats.items():
        print(f"{k}: {v}")
    report = server.tenant_report()
    if report:
        print("\nper-tenant activity (wave telemetry):")
        for name, row in report.items():
            print(f"  {name}: " + ", ".join(
                f"{k}={v}" for k, v in row.items()))
    print("\nmetrics exposition:")
    print(server.registry.to_prometheus())
    out = getattr(args, "metrics_out", None)
    if out:
        import json

        with open(out, "w") as fh:
            json.dump(server.registry.to_dict(), fh, indent=1, sort_keys=True)
        print(f"wrote metrics JSON to {out}")
    assert stats["recompiles_after_warmup"] == 0, "tenant swap recompiled!"
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--profile", metavar="DIR", default=None,
                    help="capture a jax.profiler trace of the serve run "
                         "into DIR (view with TensorBoard/Perfetto)")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="dump the metrics registry as JSON to PATH "
                         "(SNN server only)")
    args = ap.parse_args(argv)

    bundle = get_bundle(args.arch)
    cfg = bundle.smoke if args.smoke else bundle.model
    if cfg.family == "snn":
        return serve_snn_main(cfg, args)
    print(f"serving {cfg.name}: {M.n_params(cfg):,} params, "
          f"{args.slots} slots, {args.requests} requests")
    params = M.init(cfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        if cfg.family == "audio":
            prompt = rng.integers(0, cfg.vocab_size, (plen, cfg.n_codebooks))
        else:
            prompt = rng.integers(0, cfg.vocab_size, (plen,))
        reqs.append(Request(rid=i, prompt=prompt.astype(np.int32),
                            max_new=args.max_new))
    with profile(args.profile):
        stats = serve(cfg, params, reqs, slots=args.slots,
                      max_len=args.max_len)
    for k, v in stats.items():
        print(f"{k}: {v}")
    return stats


if __name__ == "__main__":
    main()
