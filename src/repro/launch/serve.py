"""Batched serving driver (the paper's kind: an inference platform).

Wave-batched serving: requests are grouped into waves of ``slots``;
each wave left-pads prompts to a common length, prefills the whole wave
in one batched program, then decodes all slots in lock-step (one jitted
decode program). Mirrors how the FPGA serves: one resident "fabric"
(compiled program), per-request state swapped in registers -- and like
the FPGA, switching requests never recompiles anything.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 6 --max-new 12
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_bundle
from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) or (S, K) int32
    max_new: int
    out: List = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None


class WaveServer:
    """One compiled prefill + one compiled decode program, reused forever."""

    def __init__(self, cfg, params, *, slots: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self._decode = jax.jit(lambda p, b, c: M.decode_fn(p, cfg, b, c))
        self._prefill = jax.jit(lambda p, b, c: M.prefill_fn(p, cfg, b, c))

    def _pad_prompts(self, reqs: List[Request]) -> np.ndarray:
        plen = max(len(r.prompt) for r in reqs)
        shape = (self.slots, plen) + (
            (self.cfg.n_codebooks,) if self.cfg.family == "audio" else ())
        toks = np.zeros(shape, np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad with 0
        return toks

    def run_wave(self, reqs: List[Request]) -> int:
        """Prefill + decode one wave to completion; returns decode steps."""
        cfg = self.cfg
        toks = self._pad_prompts(reqs)
        plen = toks.shape[1]
        caches = M.init_cache(cfg, self.slots, self.max_len)
        last, caches = self._prefill(self.params, {"inputs": jnp.asarray(toks)},
                                     caches)
        last_np = np.asarray(last, np.float32)        # (slots, V) or (slots,K,V)
        now = time.time()
        cur = last_np.argmax(-1).astype(np.int32)     # (slots,) or (slots, K)
        for r_i, r in enumerate(reqs):
            r.t_first = now
            r.out.append(int(np.atleast_1d(cur[r_i]).flat[0]))

        steps = 0
        pos = plen
        active = {i for i, r in enumerate(reqs) if len(r.out) < r.max_new}
        for r_i, r in enumerate(reqs):
            if r_i not in active:
                r.t_done = now
        max_new = max(r.max_new for r in reqs)
        while active and pos < self.max_len - 1 and steps < max_new:
            tok_in = cur[:, None] if cfg.family != "audio" else cur[:, None, :]
            batch = {"token": jnp.asarray(tok_in),
                     "pos": jnp.asarray(pos, jnp.int32)}
            logits, caches = self._decode(self.params, batch, caches)
            cur = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            steps += 1
            pos += 1
            now = time.time()
            for r_i in list(active):
                r = reqs[r_i]
                r.out.append(int(np.atleast_1d(cur[r_i]).flat[0]))
                if len(r.out) >= r.max_new:
                    r.t_done = now
                    active.discard(r_i)
        now = time.time()
        for r in reqs:
            if r.t_done is None:
                r.t_done = now
        return steps


def serve(cfg, params, requests: List[Request], *, slots: int = 4,
          max_len: int = 64) -> Dict:
    server = WaveServer(cfg, params, slots=slots, max_len=max_len)
    for r in requests:
        r.t_submit = time.time()
    done: List[Request] = []
    steps = 0
    queue = list(requests)
    while queue:
        wave = queue[:slots]
        queue = queue[slots:]
        # pad the wave with a dummy clone so the batch shape is static
        while len(wave) < slots:
            wave.append(Request(rid=-1, prompt=wave[0].prompt, max_new=1))
        steps += server.run_wave(wave)
        done.extend(r for r in wave if r.rid >= 0)

    total_new = sum(len(r.out) for r in done)
    t0 = min(r.t_submit for r in done)
    t1 = max(r.t_done for r in done)
    return {
        "n_requests": len(done),
        "decode_steps": steps,
        "new_tokens": total_new,
        "wall_s": round(t1 - t0, 3),
        "tokens_per_s": round(total_new / max(1e-9, t1 - t0), 2),
        "mean_ttft_s": round(float(np.mean(
            [r.t_first - r.t_submit for r in done])), 3),
        "outputs": {r.rid: r.out[:8] for r in done},
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args(argv)

    bundle = get_bundle(args.arch)
    cfg = bundle.smoke if args.smoke else bundle.model
    print(f"serving {cfg.name}: {M.n_params(cfg):,} params, "
          f"{args.slots} slots, {args.requests} requests")
    params = M.init(cfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        if cfg.family == "audio":
            prompt = rng.integers(0, cfg.vocab_size, (plen, cfg.n_codebooks))
        else:
            prompt = rng.integers(0, cfg.vocab_size, (plen,))
        reqs.append(Request(rid=i, prompt=prompt.astype(np.int32),
                            max_new=args.max_new))
    stats = serve(cfg, params, reqs, slots=args.slots, max_len=args.max_len)
    for k, v in stats.items():
        print(f"{k}: {v}")
    return stats


if __name__ == "__main__":
    main()
