"""Async front-end for the continuous-admission SNN server.

:class:`AsyncSNNServer` puts an asyncio face on
:meth:`repro.launch.serve.SNNServer.serve_continuous`: callers
``await submit(request)`` and get a :class:`ServeResult` back as soon
as *their* request retires from its slot, not when a whole batch
drains. Internally one worker thread runs the chunked scheduler; the
event loop never blocks on device work.

The seam between the two worlds is deliberately narrow:

* ``submit`` stamps the enqueue time (so TTFT measures queueing *and*
  compute), applies admission control, and parks an
  ``asyncio.Future``.
* The worker thread feeds the scheduler through the non-blocking
  ``feeder`` hook (polled once per chunk, so late arrivals admit into
  free slots mid-flight) and resolves futures from the
  ``on_complete`` hook via ``loop.call_soon_threadsafe``.

Admission control rejects *before* anything touches the device, each
with a reason counted in ``snn_admission_rejections_total``:

* ``queue_full`` -- queue depth is at ``max_queue``.
* ``tenant_cap`` -- that tenant already has ``tenant_cap`` requests
  in flight (queued or resident in a slot).
* ``unknown_tenant`` -- no such resident tenant.
* ``shutdown`` -- the server was closed.

A rejected ``submit`` still returns a :class:`ServeResult` (with
``rejected=True`` and the reason) rather than raising: rejection is a
normal serving outcome, and the caller decides whether to retry.

Smoke run::

    PYTHONPATH=src python -m repro.launch.serve_async --smoke
"""
from __future__ import annotations

import argparse
import asyncio
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.launch.serve import (
    ServeRequest,
    ServeResult,
    SNNServer,
    make_demo_requests,
    make_demo_tenants,
)
from repro.obs import log_event


class AsyncSNNServer:
    """Asyncio wrapper around one :class:`SNNServer`.

    The wrapped server's compiled chunk programs are reused across
    scheduler runs (they are cached per ``(backend, chunk)`` on the
    server), so the zero-recompile invariant holds across bursts too:
    after the first burst warms a backend, later bursts admit, refill
    and retire without a single retrace.

    Args:
      server: the (already tenant-populated) SNN server to drive.
      max_queue: reject with ``queue_full`` once this many requests
        wait in the queue (slot-resident requests don't count).
      tenant_cap: per-tenant in-flight ceiling (queued + resident);
        keeps one chatty tenant from starving the rest.
      chunk_ticks: chunk size override passed to the scheduler.
    """

    def __init__(self, server: SNNServer, *, max_queue: int = 64,
                 tenant_cap: int = 8,
                 chunk_ticks: Optional[int] = None):
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if tenant_cap < 1:
            raise ValueError(f"tenant_cap must be >= 1, got {tenant_cap}")
        self.server = server
        self.max_queue = int(max_queue)
        self.tenant_cap = int(tenant_cap)
        self.chunk_ticks = chunk_ticks
        self._lock = threading.Lock()
        self._queue: Deque[ServeRequest] = deque()
        self._inflight: Dict[str, int] = {}
        self._futures: Dict[int, Tuple[asyncio.AbstractEventLoop,
                                       asyncio.Future]] = {}
        self._wake = threading.Event()
        self._closed = False
        r = server.registry
        self._g_depth = r.gauge(
            "snn_async_queue_depth", "requests waiting for a slot")
        self._c_submitted = r.counter(
            "snn_async_submitted_total", "requests accepted by submit()")
        self._worker = threading.Thread(
            target=self._run, name="snn-serve-worker", daemon=True)
        self._worker.start()

    # -- admission ---------------------------------------------------------

    def _reject(self, r: ServeRequest, reason: str) -> ServeResult:
        self.server._c_rejected.inc()
        self.server._c_rej_reason.inc(reason=reason)
        log_event("snn_requests_rejected", n=1, tenants=[r.tenant],
                  reason=reason)
        return ServeResult.rejection(r, reason)

    async def submit(self, r: ServeRequest) -> ServeResult:
        """Admit one request; resolves when it retires (or rejects now).

        TTFT for this request is measured from *this* call -- the
        enqueue stamp below rides ``r.t_submit`` through the scheduler
        into the ``snn_ttft_seconds`` histogram.
        """
        loop = asyncio.get_running_loop()
        if not r.t_submit:
            r.t_submit = time.time()
        with self._lock:
            if self._closed:
                return self._reject(r, "shutdown")
            if r.tenant not in self.server.tenants:
                return self._reject(r, "unknown_tenant")
            if len(self._queue) >= self.max_queue:
                return self._reject(r, "queue_full")
            if self._inflight.get(r.tenant, 0) >= self.tenant_cap:
                return self._reject(r, "tenant_cap")
            fut: asyncio.Future = loop.create_future()
            self._futures[id(r)] = (loop, fut)
            self._inflight[r.tenant] = self._inflight.get(r.tenant, 0) + 1
            self._queue.append(r)
            self._g_depth.set(len(self._queue))
            self._c_submitted.inc()
        self._wake.set()
        return await fut

    # -- worker-thread side ------------------------------------------------

    def _feed(self) -> Optional[ServeRequest]:
        """Non-blocking feeder polled by the scheduler once per chunk."""
        with self._lock:
            if not self._queue:
                return None
            r = self._queue.popleft()
            self._g_depth.set(len(self._queue))
            return r

    def _complete(self, r: ServeRequest) -> None:
        """``on_complete`` hook: runs in the worker thread per retire."""
        with self._lock:
            entry = self._futures.pop(id(r), None)
            n = self._inflight.get(r.tenant, 0) - 1
            if n > 0:
                self._inflight[r.tenant] = n
            else:
                self._inflight.pop(r.tenant, None)
        if entry is None:
            return
        loop, fut = entry
        result = ServeResult.of(r)
        loop.call_soon_threadsafe(
            lambda: fut.done() or fut.set_result(result))

    def _run(self) -> None:
        while True:
            self._wake.wait()
            with self._lock:
                self._wake.clear()
                empty, closed = not self._queue, self._closed
            if empty:
                if closed:
                    return
                continue
            # One scheduler burst: drains the queue (and anything that
            # arrives through the feeder while slots are busy).
            self.server.serve_continuous(
                feeder=self._feed, on_complete=self._complete,
                chunk_ticks=self.chunk_ticks)

    # -- lifecycle ---------------------------------------------------------

    def close(self, timeout: float = 60.0) -> None:
        """Stop accepting work, drain what's queued, join the worker.

        Safe to call from sync or async code: the worker never blocks
        on the event loop (futures resolve via
        ``call_soon_threadsafe``), so joining it from a coroutine
        cannot deadlock -- the callbacks just land after ``close``
        returns.
        """
        with self._lock:
            self._closed = True
        self._wake.set()
        self._worker.join(timeout=timeout)
        if self._worker.is_alive():
            raise TimeoutError("serve worker did not drain in time")

    async def aclose(self, timeout: float = 60.0) -> None:
        """``close`` for async callers; joins the worker off-loop."""
        with self._lock:
            self._closed = True
        self._wake.set()
        await asyncio.get_running_loop().run_in_executor(
            None, self._worker.join, timeout)
        if self._worker.is_alive():
            raise TimeoutError("serve worker did not drain in time")


# -- smoke ----------------------------------------------------------------


async def _smoke(n_requests: int, slots: int) -> List[ServeResult]:
    server = SNNServer(n_max=32, slots=slots, max_ticks=16,
                       event_density=0.2)
    names = make_demo_tenants(server, max(6, slots), seed=0)
    reqs = make_demo_requests(server, names, n_requests, seed=1)
    front = AsyncSNNServer(server, max_queue=max(8, n_requests))
    try:
        results = await asyncio.gather(*(front.submit(r) for r in reqs))
    finally:
        await front.aclose()
    ok = [r for r in results if not r.rejected]
    ttfts = sorted(r.ttft_s for r in ok)
    print(f"served {len(ok)}/{len(results)} requests "
          f"({len(results) - len(ok)} rejected)")
    if ttfts:
        print(f"ttft: min {ttfts[0] * 1e3:.1f} ms, "
              f"max {ttfts[-1] * 1e3:.1f} ms")
    print(f"recompiles_after_warmup gauge intact: "
          f"{dict(server._compiles)}")
    print(server.registry.to_prometheus())
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args(argv)
    if not args.smoke:
        ap.error("only --smoke runs are wired for the CLI")
    return asyncio.run(_smoke(args.requests, args.slots))


if __name__ == "__main__":
    main()
