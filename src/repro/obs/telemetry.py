"""On-device tick telemetry: carry-resident reductions inside the scan.

The paper pitches the processor as a research platform with runtime
visibility into the live fabric (spike activity, membrane state over the
UART link). :class:`TickTelemetry` is that visibility for the TPU
restatement: a small pytree of per-rollout accumulators that rides the
:class:`~repro.core.engine.TickCarry` when the engine's static
``telemetry=True`` flag is set.

Design constraints (all pinned in tests/test_obs.py):

* **Zero cost when off.** Telemetry is gated by a *static* engine flag
  and an optional carry slot (``None`` leaves vanish from the pytree),
  so ``telemetry=False`` programs lower to HLO byte-identical to the
  pre-observability engine.

* **Reductions only, no host syncs.** Every update is a per-tick
  reduction over the neuron axis into batch-shaped accumulators; the
  scan never materializes a per-tick series and never leaves the device.

* **vmap-transparent.** Accumulators keep the state's batch shape, so
  the multi-tenant server's slot vmap yields per-slot (= per-tenant)
  telemetry with no extra code.

The numbers come off-device exactly once, at :meth:`TickTelemetry.summary`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TickTelemetry:
    """Per-rollout accumulators; every leaf is batch-shaped ``(...,)``.

    Attributes:
      ticks: ticks accumulated so far (i32).
      spikes: total spikes emitted (``sum_t sum_n y``) -- equals
        ``raster.sum()`` of the same rollout, pinned in tests.
      v_sum: sum over ticks of the mean membrane potential (divide by
        ``ticks`` for the time-averaged mean).
      v_max: running max membrane potential observed after any tick.
      ref_sum: sum over ticks of the refractory-occupancy fraction
        (``mean_n 1{r > 0}``); divide by ``ticks`` for mean occupancy.
      overflow: event-backend overflow ticks -- ticks whose spike count
        exceeded ``k_active`` and took the dense fallback (always 0 for
        dense backends and the event fan-in gather path).  These are
        *correctness* fallbacks: without them spikes would be dropped.
      policy_dense: event-backend *policy* ticks -- ticks the adaptive
        knee routed to the dense arm purely for speed (spike count above
        the knee but within ``k_active``; the event arm would have been
        exact too).  Disjoint from ``overflow`` by construction.
      dw_l1: accumulated ``sum |dw|`` from the plasticity hook (0 when
        frozen) -- the L1 norm of the whole weight-update stream.
      dw_sq: accumulated ``sum dw^2``; ``sqrt`` of it is the L2 norm of
        the update stream.
    """

    ticks: jax.Array
    spikes: jax.Array
    v_sum: jax.Array
    v_max: jax.Array
    ref_sum: jax.Array
    overflow: jax.Array
    policy_dense: jax.Array
    dw_l1: jax.Array
    dw_sq: jax.Array

    @staticmethod
    def zeros(batch_shape=()) -> "TickTelemetry":
        shape = tuple(batch_shape)
        f = lambda: jnp.zeros(shape, jnp.float32)
        i = lambda: jnp.zeros(shape, jnp.int32)
        return TickTelemetry(
            ticks=jnp.zeros(shape, jnp.int32), spikes=f(), v_sum=f(),
            v_max=f(), ref_sum=f(), overflow=i(), policy_dense=i(),
            dw_l1=f(), dw_sq=f())

    def accumulate(
        self,
        lif_state,
        *,
        overflow_inc: Optional[jax.Array] = None,
        policy_inc: Optional[jax.Array] = None,
        dw: Optional[jax.Array] = None,
    ) -> "TickTelemetry":
        """Fold one tick's outputs in (pure reductions over the neuron axis).

        Args:
          lif_state: the post-tick :class:`~repro.core.lif.LIFState`.
          overflow_inc: optional batch-shaped i32 increment (event backend:
            1 on ticks that overflowed ``k_active`` into the dense fallback).
          policy_inc: optional batch-shaped i32 increment (event backend:
            1 on ticks the adaptive knee routed to the dense arm for speed
            -- counted separately from ``overflow_inc``).
          dw: optional weight delta ``w_new - w_old`` from the plasticity
            hook (any shape; reduced to scalars and broadcast).
        """
        y, v, r = lif_state.y, lif_state.v, lif_state.r
        n = y.shape[-1]
        # One variadic reduce for all four neuron-axis statistics: a
        # single kernel per tick instead of four (the scan body's per-op
        # dispatch is the telemetry overhead the bench gate watches, not
        # the arithmetic).
        zero = jnp.zeros((), jnp.float32)
        ninf = jnp.asarray(-jnp.inf, jnp.float32)
        s_y, s_v, m_v, s_r = jax.lax.reduce(
            (y.astype(jnp.float32), v.astype(jnp.float32),
             v.astype(jnp.float32), (r > 0).astype(jnp.float32)),
            (zero, zero, ninf, zero),
            lambda a, b: (a[0] + b[0], a[1] + b[1],
                          jnp.maximum(a[2], b[2]), a[3] + b[3]),
            (y.ndim - 1,))
        dw_l1, dw_sq = self.dw_l1, self.dw_sq
        if dw is not None:
            dw_l1 = dw_l1 + jnp.abs(dw).sum()
            dw_sq = dw_sq + jnp.square(dw).sum()
        overflow = self.overflow
        if overflow_inc is not None:
            overflow = overflow + overflow_inc
        policy_dense = self.policy_dense
        if policy_inc is not None:
            policy_dense = policy_dense + policy_inc
        return TickTelemetry(
            ticks=self.ticks + 1,
            spikes=self.spikes + s_y,
            v_sum=self.v_sum + s_v / n,
            v_max=jnp.maximum(self.v_max, m_v),
            ref_sum=self.ref_sum + s_r / n,
            overflow=overflow,
            policy_dense=policy_dense,
            dw_l1=dw_l1,
            dw_sq=dw_sq)

    # -- host-side readout -------------------------------------------------

    def summary(self, n: int) -> Dict[str, float]:
        """Reduce to host floats (the one device->host hop).

        Args:
          n: live neuron count, for the spike-rate normalization
            (``spikes / (ticks * n)`` -- mean spikes per neuron per tick).
        """
        import numpy as np

        leaf = lambda a: np.asarray(a)
        ticks = float(leaf(self.ticks).max()) if leaf(self.ticks).size else 0.0
        spikes = float(leaf(self.spikes).sum())
        batch = max(1, int(leaf(self.spikes).size))
        denom = max(1.0, ticks * n * batch)
        return {
            "ticks": ticks,
            "spikes": spikes,
            "spike_rate": spikes / denom,
            "v_mean": float(leaf(self.v_sum).mean()) / max(1.0, ticks),
            "v_max": float(leaf(self.v_max).max()),
            "refractory_occupancy":
                float(leaf(self.ref_sum).mean()) / max(1.0, ticks),
            "overflow_ticks": float(leaf(self.overflow).sum()),
            "policy_dense_ticks": float(leaf(self.policy_dense).sum()),
            "dw_l1": float(leaf(self.dw_l1).sum()),
            "dw_l2": float(np.sqrt(leaf(self.dw_sq).sum())),
        }
