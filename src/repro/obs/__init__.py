"""Observability: on-device tick telemetry + host-side metrics/log/tracing.

Two tiers (DESIGN.md §11):

* **Tier A -- on-device**: :class:`~repro.obs.telemetry.TickTelemetry`, a
  carry-resident accumulator the :class:`~repro.core.engine.TickEngine`
  threads through the tick scan when its static ``telemetry=True`` flag
  is set. Pure reductions inside the compiled program -- no host syncs,
  vmap-safe (the multi-tenant server gets per-slot series for free), and
  bit-free when off: ``telemetry=False`` programs compile to HLO
  identical to the pre-observability engine (pinned in tests/test_obs.py).

* **Tier B -- host-side**: a dependency-free metrics registry
  (:mod:`repro.obs.metrics`: counters / gauges / histograms with
  Prometheus text exposition and JSON dump), structured event logging
  (:mod:`repro.obs.log`), and tracing helpers
  (:mod:`repro.obs.tracing`: ``jax.profiler`` spans + ``--profile``
  capture for the serve and bench CLIs).
"""
from repro.obs.log import EventLog, get_event_log, log_event  # noqa: F401
from repro.obs.metrics import (  # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry, get_registry,
)
from repro.obs.telemetry import TickTelemetry  # noqa: F401
from repro.obs.tracing import profile, span, trace_scope  # noqa: F401
