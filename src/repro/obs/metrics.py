"""Dependency-free metrics registry: counters, gauges, histograms.

The host-side half of the observability layer (DESIGN.md §11). No
prometheus_client, no opentelemetry -- the container bakes neither, and
the exposition format is a page of text protocol:

  https://prometheus.io/docs/instrumenting/exposition_formats/

Three instrument kinds, all label-aware:

* :class:`Counter` -- monotonically increasing (requests, spikes, waves).
* :class:`Gauge` -- last-write-wins (queue depth, resident tenants).
* :class:`Histogram` -- fixed buckets, cumulative counts + sum/count
  (TTFT, wave wall time).

One :class:`MetricsRegistry` owns the instruments and renders both a
Prometheus text exposition (:meth:`MetricsRegistry.to_prometheus`) and a
JSON-able dict (:meth:`MetricsRegistry.to_dict`). A process-wide default
registry is available via :func:`get_registry`, but servers create their
own so tests stay isolated.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

# Prometheus-conventional latency buckets, in seconds.
DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                   1.0, 2.5, 5.0, 10.0)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labelnames: Sequence[str], labels: Dict[str, str]) -> LabelKey:
    if set(labels) != set(labelnames):
        raise ValueError(
            f"expected labels {sorted(labelnames)}, got {sorted(labels)}")
    return tuple((k, str(labels[k])) for k in labelnames)


def _render_labels(key: LabelKey, extra: str = "") -> str:
    parts = [f'{k}="{v}"' for k, v in key]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class _Instrument:
    kind = "untyped"

    def __init__(self, name: str, help: str = "", labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()

    def expose(self) -> List[str]:
        raise NotImplementedError

    def to_dict(self) -> Dict:
        raise NotImplementedError


class Counter(_Instrument):
    kind = "counter"

    def __init__(self, name, help="", labelnames=()):
        super().__init__(name, help, labelnames)
        self._values: Dict[LabelKey, float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self._values.get(_label_key(self.labelnames, labels), 0.0)

    def expose(self) -> List[str]:
        lines = []
        for key, v in sorted(self._values.items()):
            lines.append(f"{self.name}{_render_labels(key)} {v:g}")
        return lines or [f"{self.name} 0"]

    def to_dict(self) -> Dict:
        return {"type": self.kind, "help": self.help,
                "values": {_render_labels(k) or "": v
                           for k, v in sorted(self._values.items())}}


class Gauge(_Instrument):
    kind = "gauge"

    def __init__(self, name, help="", labelnames=()):
        super().__init__(name, help, labelnames)
        self._values: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels) -> None:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._values[key] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self._values.get(_label_key(self.labelnames, labels), 0.0)

    def expose(self) -> List[str]:
        lines = []
        for key, v in sorted(self._values.items()):
            lines.append(f"{self.name}{_render_labels(key)} {v:g}")
        return lines or [f"{self.name} 0"]

    def to_dict(self) -> Dict:
        return {"type": self.kind, "help": self.help,
                "values": {_render_labels(k) or "": v
                           for k, v in sorted(self._values.items())}}


class Histogram(_Instrument):
    kind = "histogram"

    def __init__(self, name, help="", labelnames=(),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")
        # per label-set: per-bucket (non-cumulative) counts + sum + count
        self._counts: Dict[LabelKey, List[int]] = {}
        self._sum: Dict[LabelKey, float] = {}
        self._n: Dict[LabelKey, int] = {}

    def observe(self, value: float, **labels) -> None:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            counts = self._counts.setdefault(
                key, [0] * (len(self.buckets) + 1))
            for i, bound in enumerate(self.buckets):
                if value <= bound:
                    counts[i] += 1
                    break
            else:
                counts[-1] += 1  # the +Inf bucket
            self._sum[key] = self._sum.get(key, 0.0) + float(value)
            self._n[key] = self._n.get(key, 0) + 1

    def count(self, **labels) -> int:
        return self._n.get(_label_key(self.labelnames, labels), 0)

    def sum(self, **labels) -> float:
        return self._sum.get(_label_key(self.labelnames, labels), 0.0)

    def percentile(self, q: float, **labels) -> float:
        """Approximate ``q``-quantile (``q`` in [0, 1]) from the bucket
        bounds -- the Prometheus ``histogram_quantile`` estimate, server
        side. Returns the upper bound of the bucket holding the
        quantile observation (the last finite bound for the +Inf
        bucket -- a deliberate under-read, same as Prometheus), and 0.0
        with no observations."""
        if not (0.0 <= q <= 1.0):
            raise ValueError(f"q must lie in [0, 1], got {q}")
        key = _label_key(self.labelnames, labels)
        n = self._n.get(key, 0)
        if n == 0:
            return 0.0
        rank = q * n
        cum = 0
        for bound, c in zip(self.buckets, self._counts[key]):
            cum += c
            if cum >= rank:
                return float(bound)
        return float(self.buckets[-1])

    def expose(self) -> List[str]:
        lines = []
        for key in sorted(self._counts):
            cum = 0
            for bound, c in zip(self.buckets, self._counts[key]):
                cum += c
                le = 'le="%g"' % bound
                lines.append(
                    f"{self.name}_bucket{_render_labels(key, le)} {cum}")
            cum += self._counts[key][-1]
            inf = 'le="+Inf"'
            lines.append(
                f"{self.name}_bucket{_render_labels(key, inf)} {cum}")
            lines.append(
                f"{self.name}_sum{_render_labels(key)} {self._sum[key]:g}")
            lines.append(f"{self.name}_count{_render_labels(key)} {cum}")
        return lines or [f"{self.name}_count 0"]

    def to_dict(self) -> Dict:
        out = {"type": self.kind, "help": self.help,
               "buckets": list(self.buckets), "values": {}}
        for key in sorted(self._counts):
            out["values"][_render_labels(key) or ""] = {
                "counts": list(self._counts[key]),
                "sum": self._sum[key],
                "count": self._n[key]}
        return out


class MetricsRegistry:
    """Owns instruments; idempotent by name (re-registration returns the
    existing instrument, mismatched kind raises)."""

    def __init__(self):
        self._instruments: Dict[str, _Instrument] = {}
        self._lock = threading.Lock()

    def _get_or_make(self, cls, name, help, labelnames, **kw) -> _Instrument:
        with self._lock:
            inst = self._instruments.get(name)
            if inst is not None:
                if not isinstance(inst, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as {inst.kind}")
                return inst
            inst = cls(name, help=help, labelnames=labelnames, **kw)
            self._instruments[name] = inst
            return inst

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_make(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_make(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_make(Histogram, name, help, labelnames,
                                 buckets=buckets)

    def get(self, name: str) -> Optional[_Instrument]:
        return self._instruments.get(name)

    # -- expositions -------------------------------------------------------

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format version 0.0.4)."""
        lines: List[str] = []
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            if inst.help:
                lines.append(f"# HELP {name} {inst.help}")
            lines.append(f"# TYPE {name} {inst.kind}")
            lines.extend(inst.expose())
        return "\n".join(lines) + "\n"

    def to_dict(self) -> Dict:
        """JSON-able snapshot of every instrument."""
        return {name: inst.to_dict()
                for name, inst in sorted(self._instruments.items())}


_DEFAULT = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry (CLIs; servers make their own)."""
    return _DEFAULT
