"""Tracing hooks: named scopes in traced code, profiler spans on the host.

Two different tools for two different views of the same program:

* :func:`trace_scope` -- ``jax.named_scope``: labels ops while *tracing*,
  so HLO dumps and profiler op breakdowns read ``tick/event`` instead of
  ``while/body/dot_general.42``. Free at runtime (pure metadata; the
  telemetry-off HLO-identity pin in tests/test_obs.py proves named
  scopes do not perturb the lowered program).

* :func:`span` -- ``jax.profiler.TraceAnnotation``: marks *host wall
  time* regions (wave admission, encode/decode) so a captured profiler
  trace shows where serving time actually went. Optionally observes the
  elapsed seconds into a :class:`~repro.obs.metrics.Histogram`.

* :func:`profile` -- capture a ``jax.profiler`` trace into a directory
  (the ``--profile <dir>`` flag on the serve and bench CLIs); viewable
  with TensorBoard or Perfetto. A no-op when the directory is None, and
  capture failures degrade to a logged warning, never a crash.
"""
from __future__ import annotations

import contextlib
import time
from typing import Iterator, Optional

import jax

from repro.obs.log import log_event


def trace_scope(name: str):
    """Label ops in traced code (``with trace_scope("tick/event"): ...``)."""
    return jax.named_scope(name)


@contextlib.contextmanager
def span(name: str, histogram=None, **labels) -> Iterator[None]:
    """Host wall-time span: profiler annotation + optional histogram sink.

    Args:
      histogram: optional :class:`repro.obs.metrics.Histogram`; the span's
        elapsed seconds are observed into it with ``labels``.
    """
    t0 = time.perf_counter()
    with jax.profiler.TraceAnnotation(name):
        try:
            yield
        finally:
            if histogram is not None:
                histogram.observe(time.perf_counter() - t0, **labels)


@contextlib.contextmanager
def profile(outdir: Optional[str]) -> Iterator[None]:
    """Capture a ``jax.profiler`` trace into ``outdir`` (None -> no-op).

    Wraps ``jax.profiler.trace``; start/stop failures (sandboxed CI,
    missing profiler backend) are logged and swallowed so a profiling
    flag can never take down a serving run.
    """
    if not outdir:
        yield
        return
    try:
        ctx = jax.profiler.trace(outdir)
        ctx.__enter__()
    except Exception as e:  # noqa: BLE001 -- observability must not crash serving
        log_event("profile_failed", outdir=outdir, error=repr(e))
        yield
        return
    try:
        yield
    finally:
        try:
            ctx.__exit__(None, None, None)
            log_event("profile_captured", outdir=outdir)
        except Exception as e:  # noqa: BLE001
            log_event("profile_failed", outdir=outdir, error=repr(e))
