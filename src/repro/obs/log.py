"""Structured event logging: JSON-lines records, buffered + streamable.

The serving and benchmark paths emit *events* (wave admitted, tenant
registered, profile captured) rather than printf strings, so a consumer
-- the regression gate, a notebook, `jq` -- can filter on fields instead
of parsing prose.

Every record is one JSON object: ``{"ts": ..., "event": ..., **fields}``.
Records are kept in an in-memory ring (for tests and the `events()`
accessor) and, when a stream or path is configured, mirrored as JSON
lines to it.
"""
from __future__ import annotations

import io
import json
import threading
import time
from typing import Dict, List, Optional


class EventLog:
    def __init__(self, stream: Optional[io.TextIOBase] = None,
                 max_records: int = 4096):
        self._stream = stream
        self._records: List[Dict] = []
        self._max = int(max_records)
        self._lock = threading.Lock()

    def configure(self, *, stream=None, path: Optional[str] = None) -> None:
        """Attach a mirror stream (or a file path opened in append mode)."""
        if stream is not None and path is not None:
            raise ValueError("pass stream or path, not both")
        if path is not None:
            stream = open(path, "a")
        self._stream = stream

    def emit(self, event: str, **fields) -> Dict:
        rec = {"ts": round(time.time(), 6), "event": event, **fields}
        line = json.dumps(rec, default=str, sort_keys=True)
        with self._lock:
            self._records.append(rec)
            if len(self._records) > self._max:
                del self._records[: len(self._records) - self._max]
            if self._stream is not None:
                self._stream.write(line + "\n")
                self._stream.flush()
        return rec

    def events(self, event: Optional[str] = None) -> List[Dict]:
        with self._lock:
            recs = list(self._records)
        if event is None:
            return recs
        return [r for r in recs if r["event"] == event]

    def clear(self) -> None:
        with self._lock:
            self._records.clear()


_DEFAULT = EventLog()


def get_event_log() -> EventLog:
    return _DEFAULT


def log_event(event: str, **fields) -> Dict:
    """Emit onto the process-wide default log."""
    return _DEFAULT.emit(event, **fields)
