"""Sharded checkpointing: atomic, rotated, resumable.

Layout: <dir>/step_<N>/ contains one ``.npy`` per pytree leaf (path-keyed)
plus ``META.json`` (step, tree structure, pipeline state, config name).
Writes go to ``step_<N>.tmp`` and are renamed only after fsync -- a crash
mid-write never corrupts the latest checkpoint (restart reads the newest
*complete* step dir). On a multi-host cluster each host writes only its
addressable shards; here (single process) we write full arrays -- the
layout and protocol are identical.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

META = "META.json"


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((key, leaf))
    return out


def save(
    directory: str,
    step: int,
    tree: Any,
    *,
    extra_meta: Optional[Dict] = None,
    keep: int = 3,
) -> str:
    """Atomic checkpoint write; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten_with_paths(tree)
    manifest = {}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest[key] = {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    meta = {"step": step, "manifest": manifest}
    if extra_meta:
        meta["extra"] = extra_meta
    with open(os.path.join(tmp, META), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _rotate(directory, keep)
    return final


def _rotate(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def all_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, META)):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, tree_like: Any, step: Optional[int] = None,
            *, shardings: Any = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``tree_like``; returns (tree, meta).

    ``shardings``: optional matching tree of Shardings -- this is the
    *elastic reshard* path: a checkpoint written on one mesh is loaded
    onto a different mesh by placing each leaf with the new sharding.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, META)) as f:
        meta = json.load(f)
    leaves = _flatten_with_paths(tree_like)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _flatten_with_paths(shardings)]
    out = []
    for i, (key, like) in enumerate(leaves):
        entry = meta["manifest"].get(key)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(path, entry["file"]))
        if shard_leaves is not None and shard_leaves[i] is not None:
            arr = jax.device_put(arr, shard_leaves[i])
        out.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, out), meta


class AsyncCheckpointer:
    """Off-step-path checkpoint writes (one background thread, depth-1 queue)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any, extra_meta=None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.directory, step, host_tree,
                     extra_meta=extra_meta, keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
