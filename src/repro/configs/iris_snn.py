"""The paper's Iris network: 4 input + 3 output LIF neurons (Fig. 4).

Threshold 1, refractory 2 ticks, layered connectivity via connection list.
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="iris-snn",
    family="snn",
    n_neurons=7,
    layer_sizes=(4, 3),
    n_ticks=8,
    snn_mode="fixed_leak",
    dtype="float32",
    source="paper §III.A",
)


@register("iris-snn")
def bundle() -> ArchBundle:
    return ArchBundle(model=FULL, smoke=FULL, parallel={"*": ParallelConfig()})
