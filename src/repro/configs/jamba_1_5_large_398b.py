"""Jamba-1.5-Large 398B: Mamba+attention 1:7 hybrid with MoE.

[arXiv:2403.19887; hf] -- assigned spec: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2. Jamba block structure: groups of 8
layers with attention at in-group index 4, Mamba elsewhere; MoE FFN every
2nd layer (odd in-group indices).
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    group_size=8,
    attn_index=4,
    d_state=16,
    d_conv=4,
    expand=2,
    rope_theta=10000.0,
    source="arXiv:2403.19887",
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    n_experts=4,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    group_size=8,
    attn_index=4,
    d_state=4,
    d_conv=4,
    expand=2,
    head_pad=1,
    dtype="float32",
)


@register("jamba-1.5-large-398b")
def bundle() -> ArchBundle:
    return ArchBundle(
        model=FULL,
        smoke=SMOKE,
        parallel={
            "*": ParallelConfig(fsdp=True, optimizer="adamw", opt_state_dtype="bfloat16"),
            "train_4k": ParallelConfig(
                fsdp=True, microbatches=16, remat="block",
                optimizer="adamw", opt_state_dtype="bfloat16",
                grad_accum_dtype="bfloat16"),
        },
    )
