"""SmolLM-360M: llama-arch small. [hf:HuggingFaceTB/SmolLM-360M; hf]

Assigned spec: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_head=64,
    d_ff=2560,
    vocab_size=49152,
    rope_theta=10000.0,
    source="hf:HuggingFaceTB/SmolLM-360M",
)

SMOKE = ModelConfig(
    name="smollm-360m-smoke",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=3,
    n_kv_heads=1,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    head_pad=1,
    dtype="float32",
)


@register("smollm-360m")
def bundle() -> ArchBundle:
    return ArchBundle(model=FULL, smoke=SMOKE, parallel={"*": ParallelConfig(), "train_4k": ParallelConfig(remat="block", seq_shard_activations=True)})
