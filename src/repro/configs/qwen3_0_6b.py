"""Qwen3-0.6B: dense GQA with qk-norm. [hf:Qwen/Qwen3-0.6B; hf]

Assigned spec: 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen3-0.6B",
)

SMOKE = ModelConfig(
    name="qwen3-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    qk_norm=True,
    tie_embeddings=True,
    head_pad=1,
    dtype="float32",
)


@register("qwen3-0.6b")
def bundle() -> ArchBundle:
    return ArchBundle(model=FULL, smoke=SMOKE, parallel={"*": ParallelConfig(), "train_4k": ParallelConfig(remat="block", seq_shard_activations=True)})
