"""Production-scale SNN core: 65,536 neurons, all-to-all fabric.

The paper's architecture scaled to the point where the synapse matrix
(64k x 64k = 4.3G synapses) must shard across the mesh -- the
"universal interconnect" as a distributed system (DESIGN.md §4). Used by
the SNN scaling benchmark and the optional SNN dry-run cell.
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="snn-64k",
    family="snn",
    n_neurons=65536,
    layer_sizes=(),        # free-form all-to-all, not layered
    n_ticks=8,
    snn_mode="fixed_leak",
    dtype="float32",
    source="DESIGN.md §4 scale-up of paper §II.D",
)

SMOKE = ModelConfig(
    name="snn-64k-smoke",
    family="snn",
    n_neurons=256,
    layer_sizes=(),
    n_ticks=8,
    snn_mode="fixed_leak",
    head_pad=1,
    dtype="float32",
)


@register("snn-64k")
def bundle() -> ArchBundle:
    return ArchBundle(model=FULL, smoke=SMOKE, parallel={"*": ParallelConfig()})
