"""Production-scale SNN core: 65,536 neurons, all-to-all fabric.

The paper's architecture scaled to the point where the synapse matrix
(64k x 64k = 4.3G synapses, 16 GiB in f32) must shard across the mesh --
the "universal interconnect" as a distributed system (DESIGN.md §15).
``snn_mesh=8`` partitions the fabric by destination columns over an
8-device ``("model",)`` mesh (2 GiB of weights per device); the implicit
all-to-all (``c=None``) means no second mask matrix ever exists.  Used
by the SNN scaling benchmark's sharded section and runnable from the
serve CLI (``python -m repro.launch.serve --arch snn-64k --smoke``).
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="snn-64k",
    family="snn",
    n_neurons=65536,
    layer_sizes=(),        # free-form all-to-all, not layered
    n_ticks=8,
    snn_mode="fixed_leak",
    snn_mesh=8,            # shard the fabric over 8 devices (DESIGN.md §15)
    dtype="float32",
    source="DESIGN.md §4 scale-up of paper §II.D",
)

SMOKE = ModelConfig(
    name="snn-64k-smoke",
    family="snn",
    n_neurons=256,
    layer_sizes=(),
    n_ticks=8,
    snn_mode="fixed_leak",
    snn_mesh=2,            # exercise the sharded path at smoke scale
    head_pad=1,
    dtype="float32",
)


@register("snn-64k")
def bundle() -> ArchBundle:
    return ArchBundle(model=FULL, smoke=SMOKE, parallel={"*": ParallelConfig()})
