"""The paper's MNIST network: 64 input + 10 output LIF neurons (Fig. 6).

8x8 binarized images, refractory 4 ticks, 74 neurons total -- the system
whose register bank costs 898 UART transactions (§III.B).
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="mnist-snn",
    family="snn",
    n_neurons=74,
    layer_sizes=(64, 10),
    n_ticks=4,
    snn_mode="fixed_leak",
    dtype="float32",
    source="paper §III.B",
)


@register("mnist-snn")
def bundle() -> ArchBundle:
    return ArchBundle(model=FULL, smoke=FULL, parallel={"*": ParallelConfig()})
