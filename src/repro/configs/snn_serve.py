"""The multi-tenant SNN serving fabric: one resident datapath, S slots.

``n_neurons`` here is the *fabric* size ``n_max`` -- every tenant network
is zero-padded onto it (padded neurons carry an unreachable threshold, so
they never spike and never learn). ``n_ticks`` is the per-wave tick
budget ceiling; requests may ask for less and are masked at decode.
"""
import dataclasses

from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="snn-serve",
    family="snn",
    n_neurons=74,            # the paper's fabric, serving many tenants
    n_ticks=32,
    snn_mode="fixed_leak",
    dtype="float32",
    source="paper §II + multi-tenant serving (NeuroCoreX / low-end-FPGA time-sharing)",
)

SMOKE = dataclasses.replace(FULL, name="snn-serve-smoke", n_neurons=24, n_ticks=12)


@register("snn")
def bundle() -> ArchBundle:
    return ArchBundle(model=FULL, smoke=SMOKE, parallel={"*": ParallelConfig()})
