"""Llama-3.2-Vision 90B backbone: cross-attention image layers.

[hf:meta-llama/Llama-3.2-90B-Vision; unverified] -- assigned spec:
100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. Structure: 20
groups of 5 layers, cross-attention at in-group index 0 (20 cross layers
interleaved 1:4 with 80 self-attention layers). The vision frontend is a
STUB per the assignment: ``input_specs()`` provides precomputed ViT patch
embeddings (n=1601 tokens of d=1280, ViT-H scale); the backbone owns only
the multimodal projector.
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab_size=128256,
    group_size=5,
    cross_index=0,
    n_vision_tokens=1601,
    d_vision=1280,
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-3.2-90B-Vision (unverified)",
)

SMOKE = ModelConfig(
    name="llama-vision-smoke",
    family="vlm",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    group_size=5,
    cross_index=0,
    n_vision_tokens=16,
    d_vision=32,
    head_pad=1,
    dtype="float32",
)


@register("llama-3.2-vision-90b")
def bundle() -> ArchBundle:
    return ArchBundle(
        model=FULL,
        smoke=SMOKE,
        parallel={
            "*": ParallelConfig(fsdp=True),
            "train_4k": ParallelConfig(fsdp=True, microbatches=16, remat="block",
                                       grad_accum_dtype="bfloat16"),
        },
    )
