"""Large sparse fabric served through event-driven dispatch.

The paper's mux fabric routes *only* closed connections, and a silent
neuron costs nothing -- the properties event-driven FPGA emulators
(NeuroCoreX, arXiv:2506.14138; the low-end-FPGA framework,
arXiv:2507.07284) build their whole datapath around.  The dense
backends pay the full ``B*K*N`` masked matmul per tick regardless of
activity; past ~4k neurons at realistic densities (<= 0.05) and rates
(<= 0.05) that is >100x wasted work.  ``backend="event"``
(:mod:`repro.kernels.event_dispatch` + the pure-jnp reference in
:func:`repro.kernels.ops.event_synaptic_input`) gathers only spiking
neurons' fan-out slices instead.  This bundle is the benchmark/serving
shape for that operating point: `benchmarks/bench_snn_scale.py` runs
its sparse sweep from these sizes and CI gates the resulting
`BENCH_snn_scale.json` throughput/parity/recompile metrics.
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="snn-event",
    family="snn",
    n_neurons=4096,          # the sparse operating point the dense backends
    layer_sizes=(),          # can't reach economically (ISSUE 4 / ROADMAP)
    n_ticks=32,
    snn_mode="fixed_leak",
    snn_backend="event",
    snn_dispatch="auto",     # dispatch_policy.plan picks the formulation
    snn_density=0.05,
    snn_rate=0.05,
    dtype="float32",
    source="DESIGN.md §10/§12 event dispatch of paper §II mux fabric",
)

SMOKE = ModelConfig(
    name="snn-event-smoke",
    family="snn",
    n_neurons=1024,
    layer_sizes=(),
    n_ticks=16,
    snn_mode="fixed_leak",
    snn_backend="event",
    snn_dispatch="auto",
    snn_density=0.05,
    snn_rate=0.05,
    head_pad=1,
    dtype="float32",
)


@register("snn-event")
def bundle() -> ArchBundle:
    return ArchBundle(model=FULL, smoke=SMOKE, parallel={"*": ParallelConfig()})
