"""SmolLM-135M: llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]

Assigned spec: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_head=64,
    d_ff=1536,
    vocab_size=49152,
    rope_theta=10000.0,
    source="hf:HuggingFaceTB/SmolLM-135M",
)

SMOKE = ModelConfig(
    name="smollm-135m-smoke",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=3,
    n_kv_heads=1,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    head_pad=4,
    dtype="float32",
)


@register("smollm-135m")
def bundle() -> ArchBundle:
    return ArchBundle(model=FULL, smoke=SMOKE, parallel={"*": ParallelConfig(), "train_4k": ParallelConfig(remat="block", seq_shard_activations=True)})
