"""Large-fabric SNN served through the whole-tick megakernel.

The scaling wall for the paper's architecture is the all-to-all O(n^2)
tick (NeuroCoreX, arXiv:2506.14138; low-end-FPGA framework,
arXiv:2507.07284). Past ~1k neurons the split tick -- delay read, masked
matmul, LIF, delay write as separate XLA/Pallas ops -- pays an HBM
round-trip between every phase; ``backend="pallas_fused"``
(`kernels/tick_fused.py`) runs the whole circuit in one kernel launch
per tick. This bundle is the benchmark/serving shape for that backend:
`benchmarks/bench_snn_scale.py` sweeps its sizes across all four
backends and CI gates on the resulting `BENCH_snn_scale.json`.
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="snn-fused",
    family="snn",
    n_neurons=4096,          # the bench's largest sweep point
    layer_sizes=(),          # free-form all-to-all, not layered
    n_ticks=32,
    snn_mode="fixed_leak",
    snn_backend="pallas_fused",
    dtype="float32",
    source="DESIGN.md §9 whole-tick fusion of paper §II",
)

SMOKE = ModelConfig(
    name="snn-fused-smoke",
    family="snn",
    n_neurons=256,
    layer_sizes=(),
    n_ticks=16,
    snn_mode="fixed_leak",
    snn_backend="pallas_fused",
    head_pad=1,
    dtype="float32",
)


@register("snn-fused")
def bundle() -> ArchBundle:
    return ArchBundle(model=FULL, smoke=SMOKE, parallel={"*": ParallelConfig()})
