"""StarCoder2-15B: dense GQA + RoPE. [arXiv:2402.19173; hf]

Assigned spec: 40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_head=128,
    d_ff=24576,
    vocab_size=49152,
    ffn_act="gelu",
    rope_theta=100000.0,
    source="arXiv:2402.19173",
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_head=8,
    d_ff=256,
    vocab_size=256,
    ffn_act="gelu",
    head_pad=1,
    dtype="float32",
)


@register("starcoder2-15b")
def bundle() -> ArchBundle:
    return ArchBundle(
        model=FULL,
        smoke=SMOKE,
        parallel={
            "*": ParallelConfig(fsdp=True),
            "train_4k": ParallelConfig(fsdp=True, microbatches=8, remat="block"),
        },
    )
