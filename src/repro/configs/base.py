"""Config dataclasses: model, shape, parallelism, run."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | vlm | audio | rwkv | snn
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1               # layer i is MoE iff i % moe_every == moe_offset
    moe_offset: int = 0
    first_dense_layers: int = 0      # leading dense layers (moonshot: 1)
    ffn_act: str = "swiglu"          # swiglu | gelu (non-gated, starcoder2)
    n_shared_experts: int = 0
    d_ff_dense: int = 0              # dense-FFN width when mixed with MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    tie_embeddings: bool = False
    # attention features
    qk_norm: bool = False
    rope_theta: float = 10000.0
    pos_embed: str = "rope"          # rope | sinusoidal
    head_pad: int = 16               # pad q-heads to this multiple (TP width);
                                     # dead heads are hard-masked (exact)
    # hybrid (jamba)
    group_size: int = 0              # layers per scanned group (jamba 8, vlm 5)
    attn_index: int = -1             # index within group that is attention
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    # vlm
    cross_index: int = -1            # index within group that is cross-attn
    n_vision_tokens: int = 0
    d_vision: int = 0
    # audio
    n_codebooks: int = 1
    # rwkv
    rwkv_head_dim: int = 64
    rwkv_lora_decay: int = 64
    rwkv_lora_mix: int = 32
    # snn
    n_neurons: int = 0
    layer_sizes: Tuple[int, ...] = ()
    n_ticks: int = 4
    snn_mode: str = "fixed_leak"
    snn_backend: str = "jnp"         # jnp | pallas | pallas_fused | event (TickEngine)
    snn_dispatch: str = "auto"       # event-backend strategy: auto | fan_in | topk | dense
    snn_density: float = 0.5         # topology density for free-form fabrics
    snn_rate: float = 0.1            # target input spike rate (event operating point)
    snn_chunk_ticks: int = 8         # continuous-admission chunk size (ticks
                                     # per scheduler round; smaller = lower
                                     # TTFT, larger = fewer host/device syncs)
    snn_mesh: int = 0                # devices to shard the fabric over
                                     # (destination columns, DESIGN.md §15);
                                     # 0 = single-device engine
    # numerics
    dtype: str = "bfloat16"
    # provenance
    source: str = ""

    @property
    def d_q(self) -> int:
        return self.n_heads * self.d_head

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0 or i < self.first_dense_layers:
            return False
        return i % self.moe_every == self.moe_offset

    @property
    def full_attention(self) -> bool:
        """True when *every* token-mixing layer is quadratic attention
        (drives the long_500k skip rule)."""
        return self.family in ("dense", "moe", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """Per-(arch, shape) distribution knobs -- the hillclimb surface."""
    fsdp: bool = False
    seq_shard_activations: bool = False   # Megatron-SP between blocks
    microbatches: int = 1                 # gradient-accumulation steps
    remat: str = "block"                  # none | block | dots
    optimizer: str = "adamw"              # adamw | adafactor
    opt_state_dtype: str = "float32"
    grad_accum_dtype: str = "float32"     # bf16 halves accum memory (>=100B)
    rule_overrides: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def replace(self, **kw) -> "ParallelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ArchBundle:
    """Everything the launcher needs for one --arch id."""
    model: ModelConfig
    smoke: ModelConfig                       # reduced same-family config
    parallel: Mapping[str, ParallelConfig]   # shape name -> knobs ("*" default)

    def parallel_for(self, shape_name: str) -> ParallelConfig:
        if shape_name in self.parallel:
            return self.parallel[shape_name]
        return self.parallel.get("*", ParallelConfig())


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """Assignment rules: long_500k only for sub-quadratic archs; SNN archs
    use their own tick-driven shapes (not the LM set)."""
    if cfg.family == "snn":
        return ()
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if not cfg.full_attention:
        names.append("long_500k")
    return tuple(names)
