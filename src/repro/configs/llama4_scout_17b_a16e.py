"""Llama-4 Scout 17B-16E: MoE top-1, 16 routed experts + 1 shared.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] -- assigned spec:
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1.
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=202048,
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (unverified)",
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    n_experts=4,
    top_k=1,
    n_shared_experts=1,
    head_pad=1,
    dtype="float32",
)


@register("llama4-scout-17b-a16e")
def bundle() -> ArchBundle:
    return ArchBundle(
        model=FULL,
        smoke=SMOKE,
        parallel={
            "*": ParallelConfig(fsdp=True),
            "train_4k": ParallelConfig(fsdp=True, microbatches=8, remat="block",
                                       grad_accum_dtype="bfloat16"),
        },
    )
