"""Architecture registry: every --arch id maps to an ArchBundle."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (
    ArchBundle, ModelConfig, ParallelConfig, ShapeConfig, SHAPES, applicable_shapes,
)

_REGISTRY = {}


def register(name):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_bundle(name: str) -> ArchBundle:
    if name not in _REGISTRY:
        # import side-effect registration
        from repro.configs import (  # noqa: F401
            llama4_scout_17b_a16e, moonshot_v1_16b_a3b, qwen3_0_6b,
            starcoder2_15b, smollm_135m, smollm_360m, jamba_1_5_large_398b,
            llama_3_2_vision_90b, rwkv6_1_6b, musicgen_large,
            iris_snn, mnist_snn, mnist_stdp, snn_64k, snn_event, snn_fused,
            snn_serve,
        )
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    get_bundle.__wrapped__ = None  # force imports
    try:
        get_bundle("__none__")
    except KeyError:
        pass
    return sorted(_REGISTRY)


ASSIGNED_ARCHS = [
    "llama4-scout-17b-a16e",
    "moonshot-v1-16b-a3b",
    "qwen3-0.6b",
    "starcoder2-15b",
    "smollm-135m",
    "smollm-360m",
    "jamba-1.5-large-398b",
    "llama-3.2-vision-90b",
    "rwkv6-1.6b",
    "musicgen-large",
]

SNN_ARCHS = ["iris-snn", "mnist-snn", "mnist-stdp", "snn-64k", "snn-event",
             "snn-fused", "snn"]
