"""Moonlight-16B-A3B (Moonshot): DeepSeek-style MoE, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf] -- assigned spec:
48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
HF config adds: first layer dense (intermediate 11264), 2 shared experts.
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab_size=163840,
    n_experts=64,
    top_k=6,
    first_dense_layers=1,
    d_ff_dense=11264,
    n_shared_experts=2,
    rope_theta=50000.0,
    source="hf:moonshotai/Moonlight-16B-A3B",
)

SMOKE = ModelConfig(
    name="moonshot-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=96,
    vocab_size=256,
    n_experts=8,
    top_k=2,
    first_dense_layers=1,
    d_ff_dense=128,
    n_shared_experts=2,
    head_pad=1,
    dtype="float32",
)


@register("moonshot-v1-16b-a3b")
def bundle() -> ArchBundle:
    return ArchBundle(
        model=FULL,
        smoke=SMOKE,
        parallel={
            "*": ParallelConfig(fsdp=True),
            "train_4k": ParallelConfig(fsdp=True, microbatches=4, remat="block"),
        },
    )
