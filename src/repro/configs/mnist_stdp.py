"""On-device learning system: MNIST-8x8 with STDP features + R-STDP readout.

Beyond-paper workload (NeuroCoreX direction, arXiv:2506.14138): the same
64-input fabric as the paper's MNIST system, but the weights are *learned
on the device* instead of streamed in over the UART.

Stage 1 -- unsupervised features: 64 input neurons drive ``n_hidden``
feature neurons through a bipartite connection list; pair STDP moves each
feature neuron's fan-in toward the digit patterns it fires for.  Two
competition mechanisms make the features diverge: a *fixed* lateral
winner-take-all block (negative hidden->hidden weights -- the parallel
inhibitory bank of ``quant.quantize_signed``, frozen via the plastic
mask), and a host-side homeostasis loop that nudges per-neuron threshold
*registers* up on every win (the paper's runtime-reconfiguration story
doing double duty as the slow competition -- no re-synthesis).

Stage 2 -- supervised readout: feature spike trains drive 10 output
neurons; R-STDP banks an eligibility trace during the presentation and a
terminal +/- reward (was the argmax right?) converts it into a weight
update.

All weights live on the u8 register grid throughout, so the learned
network serializes back through the RegisterBank byte protocol unchanged
(examples/online_learning.py asserts the round trip).
"""
from __future__ import annotations

import dataclasses

from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig
from repro.plasticity.stdp import PlasticityParams

N_INPUT = 64
N_HIDDEN = 64     # ~6 feature neurons per class: enough WTA capacity for
                  # the dataset's +/-1-pixel shift variants of each digit
N_CLASSES = 10

FULL = ModelConfig(
    name="mnist-stdp",
    family="snn",
    n_neurons=N_INPUT + N_HIDDEN,
    layer_sizes=(N_INPUT, N_HIDDEN),
    n_ticks=8,
    snn_mode="fixed_leak",
    dtype="float32",
    source="beyond paper: NeuroCoreX-style on-device learning (2506.14138)",
)


@dataclasses.dataclass(frozen=True)
class STDPRunConfig:
    """Everything the online-learning example needs beyond ModelConfig."""

    # stage 1: unsupervised STDP feature layer
    feature: PlasticityParams = PlasticityParams.make(
        "stdp",
        tau_pre=2.0, tau_post=2.0,
        a_plus=0.5, a_minus=0.1,
        w_min=0.0, w_max=12.0,      # low band of the u8 grid: keeps the
    )                               # summed drive inside u8 thresholds
    w_init_lo: float = 2.0
    w_init_hi: float = 10.0
    w_init_density: float = 0.25     # sparse random receptive fields: the
                                    # across-neuron drive variance that lets
                                    # the WTA desynchronize threshold
                                    # crossings (tick-level tie-break)
    v_th_base: float = 96.0         # feature threshold register at init
    theta_init_jitter: float = 40.0  # random initial theta: breaks residual
                                     # first-spike ties
    leak: float = 48.0              # fixed-leak lambda: sub-threshold drives
                                    # (pattern overlaps) never accumulate
    lateral_inhibition: float = 127.0   # fixed hidden->hidden WTA weight,
                                        # realized by the parallel inhibitory
                                        # bank of quant.quantize_signed (u8
                                        # magnitude, subtracted on-chip)
    theta_plus: float = 8.0         # homeostatic threshold bump per spike
    theta_drift: float = 1.0        # per-presentation downward drift: silent
                                    # neurons get easier to fire until they
                                    # claim a pattern (no dead units);
                                    # equilibrium win rate ~= drift/theta_plus
    theta_min: float = -56.0        # v_th_base + theta >= 40 (still a valid
                                    # u8 threshold register)
    theta_max: float = 159.0        # v_th_base + theta stays u8 (<= 255)
    w_total: float = 192.0          # per-neuron fan-in budget (synaptic
                                    # scaling): winning one pattern costs
                                    # weight elsewhere -> receptive fields
                                    # specialize instead of saturating
    ticks_per_sample: int = 8

    # stage 2: R-STDP readout
    readout: PlasticityParams = PlasticityParams.make(
        "rstdp",
        tau_pre=2.0, tau_post=2.0, tau_elig=6.0,
        a_plus=1.0, a_minus=0.25,
        lr_reward=0.7,
        w_min=0.0, w_max=48.0,
    )
    readout_w_init: float = 12.0
    readout_v_th: float = 20.0
    reward_correct: float = 1.0
    reward_wrong: float = -1.0


RUN = STDPRunConfig()


@register("mnist-stdp")
def bundle() -> ArchBundle:
    return ArchBundle(model=FULL, smoke=FULL, parallel={"*": ParallelConfig()})
