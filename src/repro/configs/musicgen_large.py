"""MusicGen-Large: decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Assigned spec: 48L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=2048.
Four EnCodec codebooks (delay pattern applied host-side); the audio
frontend (EnCodec) is a STUB per the assignment -- ``input_specs()``
provides precomputed frame tokens (B, S, 4). Sinusoidal positions as in
the paper.
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_head=64,
    d_ff=8192,
    vocab_size=2048,
    n_codebooks=4,
    pos_embed="sinusoidal",
    source="arXiv:2306.05284",
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=64,
    n_codebooks=4,
    pos_embed="sinusoidal",
    head_pad=1,
    dtype="float32",
)


@register("musicgen-large")
def bundle() -> ArchBundle:
    return ArchBundle(
        model=FULL,
        smoke=SMOKE,
        parallel={"*": ParallelConfig(), "train_4k": ParallelConfig(remat="block", seq_shard_activations=True)},
    )
