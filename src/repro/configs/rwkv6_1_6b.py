"""RWKV6 "Finch" 1.6B: attention-free, data-dependent decay.

[arXiv:2404.05892; unverified] -- assigned spec:
24L d_model=2048 (attn-free) d_ff=7168 vocab=65536.
"""
from repro.configs import register
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL = ModelConfig(
    name="rwkv6-1.6b",
    family="rwkv",
    n_layers=24,
    d_model=2048,
    d_ff=7168,
    vocab_size=65536,
    rwkv_head_dim=64,
    rwkv_lora_decay=64,
    rwkv_lora_mix=32,
    source="arXiv:2404.05892 (unverified)",
)

SMOKE = ModelConfig(
    name="rwkv6-smoke",
    family="rwkv",
    n_layers=2,
    d_model=64,
    d_ff=224,
    vocab_size=256,
    rwkv_head_dim=16,
    rwkv_lora_decay=8,
    rwkv_lora_mix=4,
    head_pad=1,
    dtype="float32",
)


@register("rwkv6-1.6b")
def bundle() -> ArchBundle:
    return ArchBundle(
        model=FULL,
        smoke=SMOKE,
        parallel={"*": ParallelConfig(), "train_4k": ParallelConfig(remat="block", seq_shard_activations=True)},
    )
