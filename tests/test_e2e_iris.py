"""End-to-end Iris (paper §III.A): encode -> train -> quantize -> UART
download -> integer LIF inference. Validates the paper's functional-
correctness claim through the full register-bank path."""
import jax
import numpy as np
import pytest

from repro.configs import get_bundle
from repro.core import classifier, encoding
from repro.data import iris

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def trained():
    cfg = get_bundle("iris-snn").model
    x, y = iris.load(seed=0)
    xn = iris.normalize(x)
    # paper's level coding (Fig. 5): quantized integer impulse magnitudes
    levels = np.asarray(encoding.level_encode(xn, levels=4))
    (xtr, ytr), (xte, yte) = iris.train_test_split(levels, y, test_frac=0.3)
    model = classifier.train(xtr, ytr, cfg)
    return cfg, model, (xtr, ytr), (xte, yte)


def test_float_train_accuracy(trained):
    _, model, (xtr, ytr), _ = trained
    acc = classifier.accuracy(classifier.predict_float(model, xtr), ytr)
    assert acc >= 0.90, f"float train acc {acc}"


def test_int_inference_through_register_bank(trained):
    cfg, model, _, (xte, yte) = trained
    dep = classifier.deploy(model, n_neurons=cfg.n_neurons)
    assert dep.bank.n == 7                     # 4 input + 3 output (Fig. 4)
    assert dep.bank.breakdown().total == len(dep.bank.serialize())
    pred = classifier.predict_int(dep, xte)
    acc = classifier.accuracy(pred, yte)
    assert acc >= 0.85, f"integer datapath acc {acc}"


def test_int_matches_float_mostly(trained):
    """u8 quantization must not change more than a few decisions."""
    cfg, model, _, (xte, yte) = trained
    dep = classifier.deploy(model, n_neurons=cfg.n_neurons)
    pf = classifier.predict_float(model, xte)
    pi = classifier.predict_int(dep, xte)
    agreement = (pf == pi).mean()
    assert agreement >= 0.9, f"float/int agreement {agreement}"


def test_reprogram_cost_matches_paper_model(trained):
    """The Iris system's register download cost under the paper's timing."""
    cfg, model, _, _ = trained
    dep = classifier.deploy(model, n_neurons=cfg.n_neurons)
    bd = dep.bank.breakdown()
    # 7 neurons, per-synapse layout: 7*1 CL + 7 th + 49 w + 1 imp = 64 bytes
    assert bd.connection_list == 7
    assert bd.weights == 49
    assert bd.total == 64
