"""Optimizers, schedules, clipping, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adafactor, adamw, clip, compression, schedule

jax.config.update("jax_platform_name", "cpu")


class TestAdamW:
    def test_matches_reference_formula(self):
        p = {"w": jnp.asarray([1.0, -2.0])}
        g = {"w": jnp.asarray([0.5, 0.5])}
        st = adamw.init(p)
        lr, b1, b2, eps, wd = 0.1, 0.9, 0.95, 1e-8, 0.0
        newp, st2 = adamw.update(g, st, p, lr=lr, b1=b1, b2=b2, eps=eps,
                                 weight_decay=wd)
        m = (1 - b1) * 0.5
        v = (1 - b2) * 0.25
        mhat = m / (1 - b1)
        vhat = v / (1 - b2)
        want = np.asarray([1.0, -2.0]) - lr * mhat / (np.sqrt(vhat) + eps)
        np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-6)
        assert int(st2.step) == 1

    def test_weight_decay_direction(self):
        p = {"w": jnp.asarray([10.0])}
        g = {"w": jnp.asarray([0.0])}
        st = adamw.init(p)
        newp, _ = adamw.update(g, st, p, lr=0.1, weight_decay=0.1)
        assert float(newp["w"][0]) < 10.0

    def test_bf16_state(self):
        p = {"w": jnp.ones((4, 4), jnp.bfloat16)}
        st = adamw.init(p, jnp.bfloat16)
        assert st.m["w"].dtype == jnp.bfloat16
        g = {"w": jnp.full((4, 4), 0.1, jnp.bfloat16)}
        newp, st2 = adamw.update(g, st, p, lr=0.01)
        assert newp["w"].dtype == jnp.bfloat16
        assert jnp.isfinite(newp["w"].astype(jnp.float32)).all()

    def test_converges_on_quadratic(self):
        p = {"w": jnp.asarray([5.0, -3.0])}
        st = adamw.init(p)
        for _ in range(300):
            g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
            p, st = adamw.update(g, st, p, lr=0.05, weight_decay=0.0)
        assert float(jnp.abs(p["w"]).max()) < 0.1


class TestAdafactor:
    def test_factored_state_shapes(self):
        p = {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))}
        st = adafactor.init(p)
        assert st.vr["w"].shape == (8,)
        assert st.vc["w"].shape == (4,)
        assert st.vr["b"].shape == (4,)

    def test_converges_on_quadratic(self):
        p = {"w": jnp.full((4, 4), 3.0)}
        st = adafactor.init(p)
        for _ in range(200):
            g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
            p, st = adafactor.update(g, st, p, lr=0.05)
        assert float(jnp.abs(p["w"]).max()) < 0.3


class TestClipSchedule:
    def test_clip_reduces_norm(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip.clip_by_global_norm(g, 1.0)
        assert float(norm) > 1.0
        assert float(clip.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_clip_noop_below_threshold(self):
        g = {"a": jnp.asarray([0.1])}
        clipped, _ = clip.clip_by_global_norm(g, 1.0)
        np.testing.assert_allclose(np.asarray(clipped["a"]), [0.1], rtol=1e-6)

    def test_warmup_cosine(self):
        lr0 = schedule.warmup_cosine(jnp.asarray(0), peak_lr=1.0,
                                     warmup_steps=10, total_steps=100)
        lr_peak = schedule.warmup_cosine(jnp.asarray(10), peak_lr=1.0,
                                         warmup_steps=10, total_steps=100)
        lr_end = schedule.warmup_cosine(jnp.asarray(100), peak_lr=1.0,
                                        warmup_steps=10, total_steps=100)
        assert float(lr0) == 0.0
        assert float(lr_peak) == pytest.approx(1.0)
        assert float(lr_end) == pytest.approx(0.1, rel=1e-3)


class TestCompression:
    def test_roundtrip_within_scale(self):
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)).astype(np.float32))}
        st = compression.init(g)
        (q, scales), st2 = compression.compress(g, st)
        assert q["w"].dtype == jnp.int8
        back = compression.decompress((q, scales))
        err = np.abs(np.asarray(back["w"]) - np.asarray(g["w"]))
        assert err.max() <= float(scales["w"]) * 0.5 + 1e-7

    def test_error_feedback_corrects_bias(self):
        """Over repeated steps of the SAME gradient, the accumulated applied
        update converges to the true sum (error feedback carries residuals)."""
        g = {"w": jnp.asarray([0.301, -0.299, 0.003])}
        st = compression.init(g)
        applied = np.zeros(3)
        n = 50
        for _ in range(n):
            (q, scales), st = compression.compress(g, st)
            applied += np.asarray(compression.decompress((q, scales))["w"])
        np.testing.assert_allclose(applied, n * np.asarray(g["w"]), rtol=0.02, atol=1e-3)
