"""Decoder edge cases (no hypothesis dependency -- always runs).

Regression home for the all-silent ``decode_first_spike`` bug: a raster
in which no output neuron ever spikes used to decode to class 0 (argmin
of an all-``n_ticks`` first-spike array), indistinguishable from a
confident class-0 prediction.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding

jax.config.update("jax_platform_name", "cpu")


class TestFirstSpikeSilence:
    def test_all_silent_is_sentinel_not_class0(self):
        silent = jnp.zeros((5, 4))
        assert int(encoding.decode_first_spike(silent)) == -1

    def test_batched_only_silent_rows_get_sentinel(self):
        sp = np.zeros((5, 2, 4), np.float32)
        sp[1, 0, 3] = 1.0                  # batch row 0 spikes, row 1 silent
        out = np.asarray(encoding.decode_first_spike(jnp.asarray(sp)))
        assert out[0] == 3 and out[1] == -1

    def test_potential_tiebreak_fallback(self):
        """With final membrane potentials, silent rows fall back to
        decode_potential-style tie-breaking instead of the sentinel."""
        sp = np.zeros((5, 2, 4), np.float32)
        sp[0, 0, 1] = 1.0
        v = np.asarray([[0.0, 0.1, 0.2, 0.05],   # spiking row: v ignored
                        [0.3, 0.1, 0.9, 0.2]])   # silent row: argmax v == 2
        out = np.asarray(encoding.decode_first_spike(
            jnp.asarray(sp), jnp.asarray(v)))
        assert out[0] == 1 and out[1] == 2
        np.testing.assert_array_equal(
            np.asarray(encoding.decode_potential(jnp.asarray(v))), [2, 2])

    def test_custom_sentinel(self):
        silent = jnp.zeros((3, 1, 2))
        assert int(encoding.decode_first_spike(silent, silent=7)[0]) == 7

    def test_spiking_rasters_unchanged(self):
        """The fix must not move any decode that used to be legitimate."""
        t, n = 6, 3
        spikes = np.zeros((t, n), np.float32)
        spikes[1, 2] = 1
        spikes[2:5, 0] = 1
        assert int(encoding.decode_first_spike(jnp.asarray(spikes))) == 2
