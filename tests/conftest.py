"""Tier-1 test environment: simulate an 8-device host mesh.

Runs at collection time, before any test module imports jax, so the
``--xla_force_host_platform_device_count`` flag lands before the backend
initializes.  Multi-device tests (test_snn_sharding.py, the collective
cost tests in test_hlo_cost.py) then run on any CPU box; a test that
still needs to skip must name a real hardware requirement in its reason.
"""
from repro.util.env import ensure_host_device_count

ensure_host_device_count(8)
