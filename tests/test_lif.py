"""LIF dynamics unit + property tests (paper Eq. 1-5)."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="tier-1 property tests need the 'test' extra")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.lif import LIFParams, LIFState, lif_step

jax.config.update("jax_platform_name", "cpu")


def mk(n=4, **kw):
    return LIFParams.make(n, **kw)


class TestFixedLeak:
    def test_integrates_to_threshold(self):
        p = mk(1, v_th=2.0, leak=0.0)
        s = LIFState.zeros((), 1)
        s = lif_step(s, jnp.array([1.0]), p)
        assert s.v[0] == 1.0 and s.y[0] == 0.0
        s = lif_step(s, jnp.array([1.0]), p)
        assert s.y[0] == 1.0 and s.v[0] == 0.0  # spike + reset (Eq. 3)

    def test_leak_only_when_active(self):
        """Eq. 5: the lambda decrement applies iff v != 0."""
        p = mk(2, v_th=10.0, leak=0.5)
        s = LIFState(v=jnp.array([1.0, 0.0]), r=jnp.zeros(2, jnp.int32), y=jnp.zeros(2))
        s = lif_step(s, jnp.zeros(2), p)
        np.testing.assert_allclose(s.v, [0.5, 0.0])

    def test_leak_never_crosses_zero(self):
        p = mk(1, v_th=10.0, leak=5.0)
        s = LIFState(v=jnp.array([1.0]), r=jnp.zeros(1, jnp.int32), y=jnp.zeros(1))
        s = lif_step(s, jnp.zeros(1), p)
        assert s.v[0] == 0.0

    def test_refractory_blocks_spikes(self):
        """Eq. 2/4: after a spike, no output for R_ref ticks."""
        p = mk(1, v_th=1.0, r_ref=2)
        s = LIFState.zeros((), 1)
        drive = jnp.array([5.0])
        s = lif_step(s, drive, p)
        assert s.y[0] == 1.0 and s.r[0] == 2
        s = lif_step(s, drive, p)
        assert s.y[0] == 0.0 and s.r[0] == 1  # held in reset (Eq. 3)
        assert s.v[0] == 0.0
        s = lif_step(s, drive, p)
        assert s.y[0] == 0.0 and s.r[0] == 0
        s = lif_step(s, drive, p)
        assert s.y[0] == 1.0  # fires again once the counter cleared


class TestEuler:
    def test_decay_factor(self):
        """Eq. 1: v' = (1 - dt/tau) v + gain * input."""
        p = mk(1, v_th=100.0, leak=0.25, gain=0.5)
        s = LIFState(v=jnp.array([4.0]), r=jnp.zeros(1, jnp.int32), y=jnp.zeros(1))
        s = lif_step(s, jnp.array([2.0]), p, mode="euler")
        np.testing.assert_allclose(s.v, [0.75 * 4.0 + 0.5 * 2.0])

    def test_bias_drives_tonic_firing(self):
        p = mk(1, v_th=1.0, leak=0.0, i_bias=0.5)
        s = LIFState.zeros((), 1)
        spikes = []
        for _ in range(6):
            s = lif_step(s, jnp.zeros(1), p, mode="euler")
            spikes.append(float(s.y[0]))
        assert sum(spikes) >= 2  # tonic input alone causes periodic spikes


class TestIntegerDatapath:
    def test_matches_float_fixed_leak(self):
        rng = np.random.default_rng(0)
        n = 16
        p_int = LIFParams(
            v_th=jnp.asarray(rng.integers(1, 20, n), jnp.int32),
            leak=jnp.asarray(rng.integers(0, 3, n), jnp.int32),
            r_ref=jnp.asarray(rng.integers(0, 3, n), jnp.int32),
            gain=jnp.ones(n, jnp.int32), i_bias=jnp.zeros(n, jnp.int32),
            v_reset=jnp.zeros(n, jnp.int32))
        p_f = jax.tree.map(lambda a: a.astype(jnp.float32), p_int)
        p_f = LIFParams(v_th=p_f.v_th, leak=p_f.leak, r_ref=p_int.r_ref,
                        gain=p_f.gain, i_bias=p_f.i_bias, v_reset=p_f.v_reset)
        si = LIFState(v=jnp.zeros(n, jnp.int32), r=jnp.zeros(n, jnp.int32),
                      y=jnp.zeros(n, jnp.int32))
        sf = LIFState.zeros((), n)
        for k in range(20):
            drive = rng.integers(0, 6, n)
            si = lif_step(si, jnp.asarray(drive, jnp.int32), p_int, mode="int")
            sf = lif_step(sf, jnp.asarray(drive, jnp.float32), p_f, mode="fixed_leak")
            np.testing.assert_array_equal(np.asarray(si.y), np.asarray(sf.y), err_msg=f"tick {k}")
            np.testing.assert_allclose(np.asarray(si.v), np.asarray(sf.v), err_msg=f"tick {k}")


@settings(deadline=None, max_examples=50)
@given(
    v0=st.floats(-5, 5), drive=st.floats(0, 10),
    v_th=st.floats(0.5, 5), leak=st.floats(0, 2), r0=st.integers(0, 3),
)
def test_invariants(v0, drive, v_th, leak, r0):
    """Property: spikes are binary; refractory counter never negative;
    v resets to v_reset on spike; a refractory neuron never spikes."""
    p = LIFParams.make(1, v_th=v_th, leak=leak, r_ref=2)
    s = LIFState(v=jnp.array([v0]), r=jnp.array([r0], jnp.int32), y=jnp.zeros(1))
    for mode in ("fixed_leak", "euler"):
        s2 = lif_step(s, jnp.array([drive]), p, mode=mode)
        y = float(s2.y[0])
        assert y in (0.0, 1.0)
        assert int(s2.r[0]) >= 0
        if r0 > 0:
            assert y == 0.0
        if y == 1.0:
            assert float(s2.v[0]) == 0.0
