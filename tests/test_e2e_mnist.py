"""End-to-end MNIST-8x8 (paper §III.B): binarize -> spikes -> train ->
register download (the 74-neuron system) -> integer inference."""
import jax
import pytest

from repro.configs import get_bundle
from repro.core import classifier
from repro.data import mnist

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def trained():
    cfg = get_bundle("mnist-snn").model
    x, y = mnist.load(n_per_class=40, seed=0)
    s = mnist.to_spikes(x)                    # (N, 64) binary, paper's encoding
    n = len(y)
    n_test = n // 5
    xtr, ytr = s[n_test:], y[n_test:]
    xte, yte = s[:n_test], y[:n_test]
    model = classifier.train(xtr, ytr, cfg)
    return cfg, model, (xtr, ytr), (xte, yte)


def test_train_accuracy(trained):
    _, model, (xtr, ytr), _ = trained
    acc = classifier.accuracy(classifier.predict_float(model, xtr), ytr)
    assert acc >= 0.9, f"train acc {acc}"


def test_all_digit_classes_recognized(trained):
    """Paper: 'The system correctly tested all digit classes (0-9)'."""
    cfg, model, _, (xte, yte) = trained
    dep = classifier.deploy(model, n_neurons=cfg.n_neurons)
    pred = classifier.predict_int(dep, xte)
    acc = classifier.accuracy(pred, yte)
    assert acc >= 0.8, f"int test acc {acc}"
    per_class_hit = [(pred[yte == d] == d).mean() for d in range(10)]
    assert min(per_class_hit) >= 0.5, f"per-class {per_class_hit}"


def test_register_bank_is_the_papers_74_neuron_system(trained):
    cfg, model, _, _ = trained
    dep = classifier.deploy(model, n_neurons=cfg.n_neurons)
    assert dep.bank.n == 74
    # per-neuron layout reproduces the paper's 898; per-synapse is the
    # general model actually deployed here:
    from repro.core.registers import transaction_breakdown, WeightLayout
    assert transaction_breakdown(74).total == 898
    bd = dep.bank.breakdown()
    assert bd.connection_list == 74 * 10
    assert bd.impulses == 10
