"""Mesh-partitioned tick engine: sharded == single-device, bit for bit.

The contract under test (DESIGN.md §15): setting ``EngineOptions.mesh``
partitions the fabric by destination columns and changes NOTHING else.

* **Frozen parity** -- every backend (jnp / pallas / pallas_fused /
  event), at mesh sizes 1 and 8, produces the bit-identical raster and
  final state tree of the unsharded engine; checked at n=128 and (dense
  jnp + event) n=4096, with uniform delay rings and batch dims riding
  along.

* **Learning parity** -- sharded STDP at D=8 is bitwise the unsharded
  run for jnp/event/pallas.  ``pallas_fused`` is REMAPPED to the
  row-kernel "pallas" arm when sharded (the megakernel's fused update
  order differs at the ulp level), so its D>1 contract is: bitwise vs
  unsharded *pallas*, allclose vs the unsharded megakernel.  A 1-device
  mesh skips the remap, so D=1 is bitwise for all four.

* **Chunked serving** -- K sharded chunks == one K*T-tick sharded
  rollout bitwise, from ONE compiled program (zero recompiles after the
  first trace), with the delta-combined telemetry accumulator matching
  the unsharded totals instead of inflating D-fold per chunk.

* **Fail-fast validation** -- the documented unsupported combinations
  raise instead of silently partitioning wrong.

Weights come from :func:`snn_sharding.make_sharded_dyadic_weights`: u8
levels x a power-of-two scale, the grid on which every f32 summation
order is exact -- that is what licenses ``assert_array_equal`` (not
allclose) across a partition that reorders nothing per-column but could.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity
from repro.core.engine import EngineOptions, TickCarry, TickEngine
from repro.core.lif import LIFParams
from repro.core.network_types import SNNParams, SNNState
from repro.kernels.ops import EventFanIn
from repro.launch.mesh import make_snn_mesh
from repro.obs.telemetry import TickTelemetry
from repro.parallel import snn_sharding
from repro.plasticity import PlasticityParams, PlasticityState

jax.config.update("jax_platform_name", "cpu")

BACKENDS = ("jnp", "pallas", "pallas_fused", "event")

# tests/conftest.py simulates 8 host devices on any CPU box; this only
# skips on a real-accelerator host with fewer than 8 physical devices
# (where the CPU simulation flag does not apply).
needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs an 8-way mesh: 8 physical accelerators (CPU hosts get "
           "8 simulated devices from tests/conftest.py)")


def _params(n, *, density=0.25, seed=0, v_th=1.0, leak=0.25, r_ref=1,
            max_delay=1):
    del max_delay  # state-side; kept in the signature for call-site clarity
    w = snn_sharding.make_sharded_dyadic_weights(n, seed=seed)
    c = jnp.asarray(connectivity.sparse_random(n, density, seed=seed + 1),
                    jnp.float32)
    return SNNParams(
        w=w, c=c,
        w_in=jnp.eye(n, dtype=jnp.float32) * 2.0,
        lif=LIFParams.make(n, v_th=v_th, leak=leak, r_ref=r_ref))


def _ext(n, ticks, batch_shape=(), p=0.3, seed=1):
    rng = np.random.default_rng(seed)
    shape = (ticks,) + tuple(batch_shape) + (n,)
    return jnp.asarray(rng.random(shape) < p, jnp.float32)


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Frozen-weight parity
# ---------------------------------------------------------------------------

@needs8
class TestFrozenParity:
    @pytest.mark.parametrize("n_dev", (1, 8))
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_bitwise_all_backends(self, backend, n_dev):
        n, ticks = 128, 10
        params = _params(n)
        ext = _ext(n, ticks)
        st0 = SNNState.zeros((), n)
        st_ref, ras_ref = TickEngine(EngineOptions(backend=backend)).rollout(
            params, st0, ext, ticks)
        st_sh, ras_sh = TickEngine(EngineOptions(
            backend=backend, mesh=make_snn_mesh(n_dev))).rollout(
            params, st0, ext, ticks)
        np.testing.assert_array_equal(np.asarray(ras_sh), np.asarray(ras_ref))
        _assert_tree_equal(st_sh, st_ref)

    @pytest.mark.parametrize("telemetry", (False, True))
    @pytest.mark.parametrize("backend", ("jnp", "event"))
    def test_bitwise_n4096(self, backend, telemetry):
        """Big enough that a reduction reorder would surface (the bench's
        parity point), small enough for tier-1.  The pallas arms run
        interpret-mode on CPU (minutes per tick at this n); their parity
        is pinned at n=128 above and at n=16384 on the bench's mesh."""
        n, ticks = 4096, 4
        params = _params(n, density=0.05)
        ext = _ext(n, ticks, p=0.1)
        st0 = SNNState.zeros((), n)
        ref = TickEngine(EngineOptions(
            backend=backend, telemetry=telemetry)).rollout(
            params, st0, ext, ticks)
        sh = TickEngine(EngineOptions(
            backend=backend, telemetry=telemetry,
            mesh=make_snn_mesh(8))).rollout(params, st0, ext, ticks)
        np.testing.assert_array_equal(np.asarray(sh[1]), np.asarray(ref[1]))
        if telemetry:
            np.testing.assert_array_equal(np.asarray(sh[2].spikes),
                                          np.asarray(ref[2].spikes))
            np.testing.assert_array_equal(np.asarray(sh[2].v_max),
                                          np.asarray(ref[2].v_max))

    @pytest.mark.parametrize("backend", ("jnp", "event"))
    def test_learning_bitwise_n4096(self, backend):
        n, ticks = 4096, 3
        params = _params(n, density=0.05, v_th=0.8)
        ext = _ext(n, ticks, p=0.2)
        opts = dict(backend=backend, plasticity=PlasticityParams.make(
            "stdp", a_plus=0.05, a_minus=0.05))
        (_, _, w_r), ras_r = TickEngine(EngineOptions(
            **opts)).learning_rollout(
            params, SNNState.zeros((), n),
            PlasticityState.zeros((), n), ext, ticks)
        (_, _, w_s), ras_s = TickEngine(EngineOptions(
            **opts, mesh=make_snn_mesh(8))).learning_rollout(
            params, SNNState.zeros((), n),
            PlasticityState.zeros((), n), ext, ticks)
        np.testing.assert_array_equal(np.asarray(ras_s), np.asarray(ras_r))
        np.testing.assert_array_equal(np.asarray(w_s), np.asarray(w_r))

    def test_batched_rollout(self):
        n, ticks, B = 128, 8, 3
        params = _params(n)
        ext = _ext(n, ticks, (B,))
        st0 = SNNState.zeros((B,), n)
        st_ref, ras_ref = TickEngine(EngineOptions()).rollout(
            params, st0, ext, ticks)
        st_sh, ras_sh = TickEngine(EngineOptions(
            mesh=make_snn_mesh(8))).rollout(params, st0, ext, ticks)
        assert ras_sh.shape == (ticks, B, n)
        np.testing.assert_array_equal(np.asarray(ras_sh), np.asarray(ras_ref))
        _assert_tree_equal(st_sh, st_ref)

    def test_uniform_delay_ring(self):
        """max_delay=4: each shard's ring holds only its own columns; the
        arriving plane still gathers to full width before the dot."""
        n, ticks = 128, 12
        params = _params(n)
        ext = _ext(n, ticks)
        st0 = SNNState.zeros((), n, max_delay=4)
        st_ref, ras_ref = TickEngine(EngineOptions()).rollout(
            params, st0, ext, ticks)
        st_sh, ras_sh = TickEngine(EngineOptions(
            mesh=make_snn_mesh(8))).rollout(params, st0, ext, ticks)
        np.testing.assert_array_equal(np.asarray(ras_sh), np.asarray(ras_ref))
        _assert_tree_equal(st_sh, st_ref)

    def test_event_fan_in_neighbors(self):
        """Fan-in lists shard by destination row, ids stay global."""
        n, ticks = 128, 10
        params = _params(n, density=0.1)
        nbrs = EventFanIn.from_dense(np.asarray(params.c))
        ext = _ext(n, ticks)
        st0 = SNNState.zeros((), n)
        _, ras_ref = TickEngine(EngineOptions(
            backend="event", event_dispatch="fan_in")).rollout(
            params, st0, ext, ticks, neighbors=nbrs)
        _, ras_sh = TickEngine(EngineOptions(
            backend="event", event_dispatch="fan_in",
            mesh=make_snn_mesh(8))).rollout(
            params, st0, ext, ticks, neighbors=nbrs)
        np.testing.assert_array_equal(np.asarray(ras_sh), np.asarray(ras_ref))

    def test_implicit_all_to_all(self):
        """c=None (every mux closed) on the sharded jnp arm: the local
        slab IS the local w columns, no second (n, n) buffer."""
        n, ticks = 128, 8
        p = _params(n)
        params = dataclasses.replace(p, c=None)
        ext = _ext(n, ticks)
        st0 = SNNState.zeros((), n)
        _, ras_ref = TickEngine(EngineOptions()).rollout(
            params, st0, ext, ticks)
        _, ras_sh = TickEngine(EngineOptions(
            mesh=make_snn_mesh(8))).rollout(params, st0, ext, ticks)
        np.testing.assert_array_equal(np.asarray(ras_sh), np.asarray(ras_ref))


# ---------------------------------------------------------------------------
# Telemetry parity (the delta combine)
# ---------------------------------------------------------------------------

@needs8
class TestTelemetryParity:
    def test_totals_match_unsharded(self):
        n, ticks, B = 128, 16, 2
        params = _params(n)
        ext = _ext(n, ticks, (B,))
        st0 = SNNState.zeros((B,), n)
        _, ras_ref, tel_ref = TickEngine(EngineOptions(
            telemetry=True)).rollout(params, st0, ext, ticks)
        _, ras_sh, tel_sh = TickEngine(EngineOptions(
            telemetry=True, mesh=make_snn_mesh(8))).rollout(
            params, st0, ext, ticks)
        np.testing.assert_array_equal(np.asarray(ras_sh), np.asarray(ras_ref))
        # Counting sums (0/1 events, well under 2**24) and max are exact
        # across any partition; the mean-based accumulators reduce in a
        # different order (per-shard sum then psum), so allclose.
        np.testing.assert_array_equal(np.asarray(tel_sh.spikes),
                                      np.asarray(tel_ref.spikes))
        np.testing.assert_array_equal(np.asarray(tel_sh.v_max),
                                      np.asarray(tel_ref.v_max))
        np.testing.assert_allclose(np.asarray(tel_sh.v_sum),
                                   np.asarray(tel_ref.v_sum), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(tel_sh.ref_sum),
                                   np.asarray(tel_ref.ref_sum), rtol=1e-5)

    def test_d1_mesh_is_bitwise_identity(self):
        """A 1-device mesh must skip the combine entirely: f32
        ``(out - in) + in`` is not an identity, bitwise."""
        n, ticks = 128, 12
        params = _params(n)
        ext = _ext(n, ticks)
        st0 = SNNState.zeros((), n)
        _, _, tel_ref = TickEngine(EngineOptions(
            telemetry=True)).rollout(params, st0, ext, ticks)
        _, _, tel_sh = TickEngine(EngineOptions(
            telemetry=True, mesh=make_snn_mesh(1))).rollout(
            params, st0, ext, ticks)
        _assert_tree_equal(tel_sh, tel_ref)


# ---------------------------------------------------------------------------
# Learning parity
# ---------------------------------------------------------------------------

_PP = PlasticityParams.make("stdp", a_plus=0.05, a_minus=0.05)


@needs8
class TestLearningParity:
    def _run(self, backend, mesh, n, ticks):
        params = _params(n, v_th=0.8)
        ext = _ext(n, ticks, p=0.4)
        opts = EngineOptions(backend=backend, plasticity=_PP, mesh=mesh)
        return TickEngine(opts).learning_rollout(
            params, SNNState.zeros((), n),
            PlasticityState.zeros((), n), ext, ticks)

    @pytest.mark.parametrize("backend", ("jnp", "event", "pallas"))
    def test_d8_bitwise(self, backend):
        n, ticks = 64, 10
        (st_r, _, w_r), ras_r = self._run(backend, None, n, ticks)
        (st_s, _, w_s), ras_s = self._run(backend, make_snn_mesh(8), n, ticks)
        np.testing.assert_array_equal(np.asarray(ras_s), np.asarray(ras_r))
        np.testing.assert_array_equal(np.asarray(w_s), np.asarray(w_r))
        _assert_tree_equal(st_s, st_r)
        # learning actually happened (this is not a frozen no-op pin)
        assert float(jnp.abs(w_r - _params(n, v_th=0.8).w).sum()) > 0

    def test_d8_pallas_fused_remap_contract(self):
        """Sharded megakernel learning runs the row-kernel arm: bitwise
        vs unsharded "pallas", allclose vs the unsharded megakernel."""
        n, ticks = 64, 10
        (_, _, w_row), ras_row = self._run("pallas", None, n, ticks)
        (_, _, w_fus), ras_fus = self._run("pallas_fused", None, n, ticks)
        (_, _, w_s), ras_s = self._run(
            "pallas_fused", make_snn_mesh(8), n, ticks)
        np.testing.assert_array_equal(np.asarray(ras_s), np.asarray(ras_row))
        np.testing.assert_array_equal(np.asarray(w_s), np.asarray(w_row))
        np.testing.assert_array_equal(np.asarray(ras_s), np.asarray(ras_fus))
        np.testing.assert_allclose(np.asarray(w_s), np.asarray(w_fus),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_d1_bitwise_identity(self, backend):
        """1-device mesh skips the remap: every backend, megakernel
        included, is the single-device program bit for bit."""
        n, ticks = 64, 8
        (st_r, _, w_r), ras_r = self._run(backend, None, n, ticks)
        (st_s, _, w_s), ras_s = self._run(backend, make_snn_mesh(1), n, ticks)
        np.testing.assert_array_equal(np.asarray(ras_s), np.asarray(ras_r))
        np.testing.assert_array_equal(np.asarray(w_s), np.asarray(w_r))
        _assert_tree_equal(st_s, st_r)


# ---------------------------------------------------------------------------
# Chunked serving: carry hand-off + one compiled program
# ---------------------------------------------------------------------------

@needs8
class TestShardedChunks:
    def test_chunks_match_rollout_zero_recompiles(self):
        n, T, K = 128, 6, 4
        params = _params(n)
        ext = _ext(n, K * T)
        mesh = make_snn_mesh(8)
        eng = TickEngine(EngineOptions(telemetry=True, mesh=mesh))
        _, ras_ref, tel_ref = eng.rollout(
            params, SNNState.zeros((), n), ext, K * T)
        _, _, tel_1dev = TickEngine(EngineOptions(telemetry=True)).rollout(
            params, SNNState.zeros((), n), ext, K * T)

        traces = 0

        @jax.jit
        def chunk_fn(params, carry, ext):
            nonlocal traces
            traces += 1
            return eng.chunk(params, carry, ext, T)

        # Seed the telemetry slot up front: the carry's pytree STRUCTURE
        # must be identical on every chunk or the second call retraces.
        carry = TickCarry(state=SNNState.zeros((), n),
                          telem=TickTelemetry.zeros(()))
        rasters = []
        for k in range(K):
            carry, ras = chunk_fn(params, carry, ext[k * T:(k + 1) * T])
            rasters.append(np.asarray(ras))
        assert traces == 1, "sharded chunk retraced after the first call"
        np.testing.assert_array_equal(
            np.concatenate(rasters, axis=0), np.asarray(ras_ref))
        # Delta combine across K boundaries: totals equal the one-shot
        # sharded scan AND the unsharded engine (no D-fold inflation).
        for tel in (tel_ref, tel_1dev):
            np.testing.assert_array_equal(np.asarray(carry.telem.spikes),
                                          np.asarray(tel.spikes))
            np.testing.assert_array_equal(np.asarray(carry.telem.v_max),
                                          np.asarray(tel.v_max))
            np.testing.assert_allclose(np.asarray(carry.telem.v_sum),
                                       np.asarray(tel.v_sum), rtol=1e-5)


# ---------------------------------------------------------------------------
# Fail-fast validation
# ---------------------------------------------------------------------------

@needs8
class TestValidation:
    def test_n_not_divisible(self):
        n = 100                                   # 100 % 8 != 0
        p = SNNParams(
            w=jnp.zeros((n, n)), c=jnp.zeros((n, n)),
            w_in=jnp.eye(n), lif=LIFParams.make(n))
        eng = TickEngine(EngineOptions(mesh=make_snn_mesh(8)))
        with pytest.raises(ValueError, match="split evenly"):
            eng.rollout(p, SNNState.zeros((), n), _ext(n, 2), 2)

    def test_tick_refuses_mesh(self):
        n = 16
        eng = TickEngine(EngineOptions(mesh=make_snn_mesh(8)))
        with pytest.raises(ValueError, match="single-device"):
            eng.tick(SNNState.zeros((), n), _params(n))

    def test_delay_matrix_refused(self):
        n = 16
        p = _params(n)
        delays = jnp.ones((n, n), jnp.int32)
        eng = TickEngine(EngineOptions(mesh=make_snn_mesh(8)))
        with pytest.raises(ValueError, match="delay"):
            eng.rollout(p, SNNState.zeros((), n, max_delay=2),
                        _ext(n, 2), 2, delays=delays)

    def test_event_ext_diag_refused_at_construction(self):
        with pytest.raises(ValueError, match="event_ext_diag"):
            EngineOptions(backend="event", event_ext_diag=True,
                          mesh=make_snn_mesh(8))

    def test_sharded_learning_needs_delay1(self):
        n = 16
        p = _params(n)
        eng = TickEngine(EngineOptions(plasticity=_PP, mesh=make_snn_mesh(8)))
        with pytest.raises(ValueError, match="max_delay == 1"):
            eng.learning_rollout(
                p, SNNState.zeros((), n, max_delay=4),
                PlasticityState.zeros((), n), _ext(n, 2), 2)

    def test_implicit_c_refuses_pallas(self):
        n = 16
        p = dataclasses.replace(_params(n), c=None)
        eng = TickEngine(EngineOptions(backend="pallas",
                                       mesh=make_snn_mesh(8)))
        with pytest.raises(ValueError):
            eng.rollout(p, SNNState.zeros((), n), _ext(n, 2), 2)

    def test_learning_implicit_c_needs_plastic_mask(self):
        n = 16
        p = dataclasses.replace(_params(n), c=None)
        eng = TickEngine(EngineOptions(plasticity=_PP, mesh=make_snn_mesh(8)))
        with pytest.raises(ValueError, match="plastic_c"):
            eng.learning_rollout(p, SNNState.zeros((), n),
                                 PlasticityState.zeros((), n), _ext(n, 2), 2)


# ---------------------------------------------------------------------------
# Host-side builders: weights and fan-in shards
# ---------------------------------------------------------------------------

class TestBuilders:
    def test_sharded_weights_mesh_independent(self):
        """Same (n, seed) -> the identical global matrix at any mesh size
        (column-block seeding): the substrate of every parity test."""
        n = 256
        w_global = np.asarray(snn_sharding.make_sharded_dyadic_weights(n))
        w_mesh = snn_sharding.make_sharded_dyadic_weights(
            n, make_snn_mesh(min(8, len(jax.devices()))))
        np.testing.assert_array_equal(np.asarray(w_mesh), w_global)

    def test_sharded_weights_on_dyadic_grid(self):
        n, levels = 128, 8
        w = np.asarray(snn_sharding.make_sharded_dyadic_weights(
            n, levels=levels))
        scale = 2.0 ** round(math.log2(2.0 / math.sqrt(n)))
        lv = w / np.float32(scale)
        np.testing.assert_array_equal(lv, np.round(lv))
        assert lv.min() >= 0 and lv.max() <= levels - 1
        assert math.log2(scale) == round(math.log2(scale))

    def test_shard_fan_in_slices_global_lists(self):
        c = connectivity.sparse_random(64, 0.2, seed=3)
        full = connectivity.padded_fan_in(c)
        shards = connectivity.shard_fan_in(c, 4)
        assert len(shards) == 4
        assert all(s.cap == full.cap for s in shards)       # uniform shapes
        assert all(s.axis == "in" for s in shards)
        np.testing.assert_array_equal(
            np.concatenate([s.idx for s in shards]), full.idx)
        np.testing.assert_array_equal(
            np.concatenate([s.mask for s in shards]), full.mask)
        assert sum(s.n_edges for s in shards) == full.n_edges

    def test_shard_fan_in_rejects_ragged(self):
        c = connectivity.sparse_random(64, 0.2, seed=3)
        with pytest.raises(ValueError, match="split evenly"):
            connectivity.shard_fan_in(c, 5)

    def test_shard_stats_and_imbalance(self):
        c = connectivity.sparse_random(64, 0.3, seed=4)
        stats = connectivity.shard_stats(c, 4)
        assert sum(s.n_edges_in for s in stats) == int(c.sum())
        assert sum(s.n_edges_out for s in stats) == int(c.sum())
        assert all(s.n_post == 16 for s in stats)
        assert connectivity.shard_imbalance(stats) >= 1.0
