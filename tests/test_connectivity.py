"""Connection-list topologies: degenerate densities + compressed builders.

The compressed representations (CSR, padded neighbor lists) are the
event backend's data layout; they must round-trip the dense matrix
exactly -- the builders never truncate, they *refuse* (a capped list
that silently dropped a synapse would be the event-backend analogue of
the ``top_k`` overflow bug).
"""
import numpy as np
import pytest

from repro.core import connectivity


class TestSparseRandomEdges:
    @pytest.mark.parametrize("self_connections", [False, True])
    def test_density_zero_is_empty(self, self_connections):
        c = connectivity.sparse_random(32, 0.0,
                                       self_connections=self_connections)
        assert c.dtype == np.bool_ and c.shape == (32, 32)
        assert c.sum() == 0

    def test_density_one_is_all_to_all(self):
        c = connectivity.sparse_random(17, 1.0, self_connections=True)
        assert bool(c.all())
        np.testing.assert_array_equal(
            c, connectivity.all_to_all(17, self_connections=True))

    def test_density_one_no_self_connections(self):
        c = connectivity.sparse_random(17, 1.0)
        assert not c.diagonal().any()
        assert c.sum() == 17 * 16

    def test_validates_through_builders(self):
        for density in (0.0, 1.0):
            c = connectivity.sparse_random(9, density)
            connectivity.validate(c)
            indptr, indices = connectivity.to_csr(c)
            np.testing.assert_array_equal(
                connectivity.csr_to_dense(indptr, indices, 9), c)
            nbrs = connectivity.padded_neighbors(c)
            assert nbrs.n_edges == int(c.sum())


class TestCSR:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("density", [0.05, 0.3, 0.9])
    def test_roundtrip_dense_csr_dense(self, seed, density):
        c = connectivity.sparse_random(41, density, seed=seed)
        indptr, indices = connectivity.to_csr(c)
        assert indptr[0] == 0 and indptr[-1] == c.sum() == indices.size
        np.testing.assert_array_equal(
            connectivity.csr_to_dense(indptr, indices, 41), c)

    def test_row_slices_are_sorted_targets(self):
        c = connectivity.layered([3, 4])
        indptr, indices = connectivity.to_csr(c)
        for p in range(c.shape[0]):
            row = indices[indptr[p] : indptr[p + 1]]
            np.testing.assert_array_equal(row, np.sort(row))
            np.testing.assert_array_equal(row, np.nonzero(c[p])[0])


class TestPaddedNeighbors:
    def test_fan_out_lists_match_dense(self):
        c = connectivity.sparse_random(23, 0.2, seed=3)
        nbrs = connectivity.padded_neighbors(c)
        assert nbrs.axis == "out"
        assert nbrs.cap == int(connectivity.fan_out(c).max())
        for i in range(23):
            live = nbrs.mask[i] > 0
            np.testing.assert_array_equal(nbrs.idx[i][live], np.nonzero(c[i])[0])
            assert not nbrs.idx[i][~live].any()  # padding is zeros

    def test_fan_in_is_transpose_of_fan_out(self):
        c = connectivity.sparse_random(19, 0.25, seed=4)
        fo = connectivity.padded_neighbors(c.T)
        fi = connectivity.padded_fan_in(c)
        np.testing.assert_array_equal(fo.idx, fi.idx)
        np.testing.assert_array_equal(fo.mask, fi.mask)
        assert fi.axis == "in"

    def test_cap_below_max_degree_refuses(self):
        c = np.zeros((6, 6), np.bool_)
        c[0, 1:] = True                        # hub: fan-out 5
        with pytest.raises(ValueError, match="cap 3 below max degree 5"):
            connectivity.padded_neighbors(c, cap=3)

    def test_explicit_cap_pads_and_reports_stats(self):
        c = np.zeros((4, 4), np.bool_)
        c[0, 1] = c[0, 2] = c[1, 3] = True
        nbrs = connectivity.padded_neighbors(c, cap=4)
        assert nbrs.cap == 4 and nbrs.idx.shape == (4, 4)
        assert nbrs.n_edges == 3 and nbrs.max_degree == 2
        assert nbrs.mean_degree == pytest.approx(3 / 4)
        assert nbrs.padding_fraction == pytest.approx(1 - 3 / 16)

    def test_empty_topology_gets_minimal_cap(self):
        nbrs = connectivity.padded_neighbors(np.zeros((5, 5), np.bool_))
        assert nbrs.cap == 1 and nbrs.n_edges == 0
        assert nbrs.padding_fraction == 1.0

    def test_event_fan_in_rejects_fan_out_lists(self):
        from repro.kernels.ops import EventFanIn

        c = connectivity.sparse_random(8, 0.3, seed=5)
        with pytest.raises(ValueError, match="fan-in"):
            EventFanIn.from_padded(connectivity.padded_neighbors(c))
