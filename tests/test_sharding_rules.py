"""Logical-axis sharding rules: specs, overrides, dedup, constrain no-op."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (
    AxisRules, BASE_RULES, constrain, fsdp_overrides, multipod_overrides,
    use_rules,
)

jax.config.update("jax_platform_name", "cpu")


def test_spec_basic():
    r = AxisRules(BASE_RULES)
    assert r.spec(("batch", "seq", "embed")) == P("data", None, None)
    assert r.spec(("vocab", "embed_param")) == P("model", None)


def test_unknown_axis_raises():
    r = AxisRules(BASE_RULES)
    with pytest.raises(KeyError):
        r.spec(("nonsense",))


def test_overrides():
    r = AxisRules(BASE_RULES).with_overrides(multipod_overrides())
    assert r.spec(("batch",)) == P(("pod", "data"))
    r2 = AxisRules(BASE_RULES).with_overrides(fsdp_overrides())
    assert r2.spec(("qkv_in", "q_heads")) == P("data", "model")


def test_duplicate_mesh_axis_dedup():
    """Colliding rules (Megatron-SP seq=model meeting heads=model) must not
    produce an invalid spec -- earlier dims win."""
    r = AxisRules(BASE_RULES).with_overrides({"seq": "model"})
    spec = r.spec(("batch", "seq", "act_heads"))
    assert spec == P("data", "model", None)


def test_constrain_noop_without_rules():
    x = jnp.ones((4, 4))
    assert constrain(x, "batch", "embed") is x


def test_constrain_rank_mismatch():
    r = AxisRules(BASE_RULES, mesh=None)
    with use_rules(r):
        # mesh None -> no-op regardless
        x = jnp.ones((2, 2))
        assert constrain(x, "batch", "embed") is x


def test_make_rules_shapes():
    """Rule assembly per shape kind (no devices needed: mesh=None path)."""
    from repro.configs import get_bundle
    from repro.configs.base import SHAPES

    # exercise the pure-logic parts via AxisRules directly
    r = AxisRules(BASE_RULES).with_overrides({"kv_seq": "model"})
    assert r.spec(("batch", "kv_seq", None)) == P("data", "model", None)
    bundle = get_bundle("jamba-1.5-large-398b")
    assert bundle.parallel_for("train_4k").fsdp
    assert bundle.parallel_for("decode_32k").fsdp  # falls back to "*"


def test_head_maps():
    from repro.configs import get_bundle
    from repro.models.attention import head_maps, padded_q_heads
    import dataclasses

    cfg = dataclasses.replace(get_bundle("smollm-135m").model)  # 9 heads, pad 16
    assert padded_q_heads(cfg) == 16
    to_kv, mask = head_maps(cfg)
    assert mask.sum() == 9              # 9 live, 7 dead
    assert to_kv.max() < cfg.n_kv_heads
    # real heads group 3 q per kv
    assert list(to_kv[:9]) == [0, 0, 0, 1, 1, 1, 2, 2, 2]
