"""Pallas kernel validation: shape/dtype sweeps + hypothesis vs ref.py oracle.

Kernels run in interpret mode on CPU (same kernel body the TPU executes).
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="tier-1 property tests need the 'test' extra")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.kernels import ops
from repro.kernels.ref import fused_lif_step_ref, spike_matmul_ref

jax.config.update("jax_platform_name", "cpu")


def _random_case(rng, b, k, n, dtype, spike_rate=0.2):
    s = (rng.random((b, k)) < spike_rate).astype(dtype)
    w = rng.normal(size=(k, n)).astype(dtype)
    c = (rng.random((k, n)) < 0.5).astype(dtype)
    return jnp.asarray(s), jnp.asarray(w), jnp.asarray(c)


SHAPES = [
    (1, 8, 8),        # minimal
    (4, 74, 74),      # the paper's MNIST system size
    (17, 300, 139),   # ragged, forces padding on every axis
    (32, 512, 128),   # exactly block-aligned
    (8, 1024, 256),   # multi-step K accumulation
]
DTYPES = [np.float32, jnp.bfloat16]


@pytest.mark.parametrize("b,k,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_spike_matmul_sweep(b, k, n, dtype):
    rng = np.random.default_rng(b * 1000 + k + n)
    s, w, c = _random_case(rng, b, k, n, np.float32)
    s, w, c = s.astype(dtype), w.astype(dtype), c.astype(dtype)
    got = ops.spike_matmul(s, w, c)
    want = spike_matmul_ref(s, w, c)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("mode", ["fixed_leak", "euler"])
@pytest.mark.parametrize("b,n", [(4, 74), (16, 139), (8, 256)])
def test_fused_lif_step_sweep(mode, b, n):
    rng = np.random.default_rng(n + b)
    s, w, c = _random_case(rng, b, n, n, np.float32)
    v = jnp.asarray(rng.normal(size=(b, n)).astype(np.float32))
    r = jnp.asarray(rng.integers(0, 3, size=(b, n)).astype(np.int32))
    drive = jnp.asarray(rng.normal(size=(b, n)).astype(np.float32))
    pn = lambda x: jnp.asarray(x.astype(np.float32))
    kw = dict(
        v_th=pn(rng.uniform(0.5, 2.0, n)), leak=pn(rng.uniform(0, 0.5, n)),
        r_ref=jnp.asarray(rng.integers(0, 4, n).astype(np.int32)),
        gain=pn(np.ones(n)), i_bias=pn(rng.normal(size=n) * 0.1),
        v_reset=pn(np.zeros(n)),
    )
    got_v, got_r, got_y = ops.fused_lif_step_arrays(
        s, w, c, v, r, drive, kw["v_th"], kw["leak"], kw["r_ref"],
        kw["gain"], kw["i_bias"], kw["v_reset"], mode=mode)
    want = fused_lif_step_ref(s, w, c, v, r, drive, kw["v_th"], kw["leak"],
                              kw["r_ref"], kw["gain"], kw["i_bias"],
                              kw["v_reset"], mode=mode)
    np.testing.assert_allclose(got_v, want.v, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_r), np.asarray(want.r))
    np.testing.assert_array_equal(np.asarray(got_y), np.asarray(want.y))


@settings(deadline=None, max_examples=25)
@given(
    b=st.integers(1, 24), k=st.integers(1, 200), n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_spike_matmul_property(b, k, n, seed):
    """Any shape (padding path included) matches the oracle."""
    rng = np.random.default_rng(seed)
    s, w, c = _random_case(rng, b, k, n, np.float32)
    got = ops.spike_matmul(s, w, c)
    want = spike_matmul_ref(s, w, c)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(deadline=None, max_examples=15)
@given(
    b=st.integers(1, 8), n=st.integers(8, 128),
    k_active=st.integers(1, 8), seed=st.integers(0, 2**31 - 1),
)
def test_event_matmul_exact_when_sparse(b, n, k_active, seed):
    """Event-driven dispatch is exact whenever <= k_active spikes/row."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(n, n)).astype(np.float32)
    c = (rng.random((n, n)) < 0.5).astype(np.float32)
    s = np.zeros((b, n), np.float32)
    for i in range(b):
        nz = rng.integers(0, k_active + 1)
        s[i, rng.choice(n, nz, replace=False)] = 1.0
    got = ops.event_spike_matmul(jnp.asarray(s), jnp.asarray(w), jnp.asarray(c),
                                 k_active=k_active)
    want = spike_matmul_ref(jnp.asarray(s), jnp.asarray(w), jnp.asarray(c))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=15)
@given(
    b=st.integers(1, 8), n=st.integers(16, 128), seed=st.integers(0, 2**31 - 1),
)
def test_event_matmul_exact_past_k_active(b, n, seed):
    """Regression: rows spiking MORE than k_active used to be silently
    truncated by the top_k (a wrong synaptic input); the overflow now
    falls back to the dense product and stays exact at any rate."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(n, n)).astype(np.float32)
    c = (rng.random((n, n)) < 0.5).astype(np.float32)
    k_active = 4
    s = (rng.random((b, n)) < 0.9).astype(np.float32)
    s[0, : k_active + 2] = 1.0                       # guarantee overflow
    got = ops.event_spike_matmul(jnp.asarray(s), jnp.asarray(w), jnp.asarray(c),
                                 k_active=k_active)
    want = spike_matmul_ref(jnp.asarray(s), jnp.asarray(w), jnp.asarray(c))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_network_pallas_backend_matches_jnp():
    from repro.core import connectivity
    from repro.core.lif import LIFParams
    from repro.core.network import SNNParams, SNNState, rollout

    rng = np.random.default_rng(1)
    n, b, t = 74, 4, 8
    p = SNNParams(
        w=jnp.asarray(rng.uniform(0, 1, (n, n)), jnp.float32),
        c=jnp.asarray(connectivity.sparse_random(n, 0.3, seed=2), jnp.float32),
        w_in=jnp.eye(n) * 2.0,
        lif=LIFParams.make(n, v_th=1.0, leak=0.1, r_ref=1))
    ext = jnp.asarray((rng.random((t, b, n)) < 0.3), jnp.float32)
    st0 = SNNState.zeros((b,), n)
    _, r1 = rollout(p, st0, ext, t, backend="jnp")
    _, r2 = rollout(p, st0, ext, t, backend="pallas")
    assert float(r1.sum()) > 0, "test must exercise spiking"
    np.testing.assert_allclose(r1, r2, rtol=1e-5, atol=1e-5)
