"""Async serving front-end: admission control + future-per-request.

Exercises the seam between the asyncio world and the worker thread
running the continuous scheduler: normal completion resolves futures
with bit-real results, and every admission edge (queue overflow,
per-tenant cap, shutdown, unknown tenant) rejects *before* touching
the device, counted by reason in ``snn_admission_rejections_total``.
"""
import asyncio

import jax
import numpy as np
import pytest

from repro.launch.serve import (
    ServeRequest, ServeResult, SNNServer, make_demo_tenants,
)
from repro.launch.serve_async import AsyncSNNServer

jax.config.update("jax_platform_name", "cpu")


def _server(**kw):
    kw.setdefault("n_max", 24)
    kw.setdefault("slots", 4)
    kw.setdefault("max_ticks", 12)
    kw.setdefault("event_density", 0.2)
    s = SNNServer(**kw)
    names = make_demo_tenants(s, 6, seed=0)
    return s, names


def _req(server, names, rid, *, n_ticks=4, tenant=None, seed=0):
    tenant = tenant or names[rid % len(names)]
    t = server.tenants[tenant]
    rng = np.random.default_rng(seed + rid)
    ext = ((rng.random((max(1, n_ticks), t.n_in)) < 0.3) * 200.0
           ).astype(np.float32)
    return ServeRequest(rid=rid, tenant=tenant, ext=ext, n_ticks=n_ticks)


class TestCompletion:
    def test_requests_complete_with_results(self):
        server, names = _server()

        async def go():
            front = AsyncSNNServer(server, max_queue=16)
            try:
                reqs = [_req(server, names, i) for i in range(6)]
                return await asyncio.gather(*(front.submit(r) for r in reqs))
            finally:
                await front.aclose()

        results = asyncio.run(go())
        assert len(results) == 6
        for res in results:
            assert isinstance(res, ServeResult)
            assert not res.rejected
            assert res.counts is not None
            assert res.ttft_s >= 0.0

    def test_results_match_direct_continuous_serve(self):
        server, names = _server()
        twin = SNNServer(n_max=24, slots=4, max_ticks=12, event_density=0.2)
        make_demo_tenants(twin, 6, seed=0)
        direct = [_req(twin, names, i) for i in range(4)]
        twin.serve_continuous(direct)

        async def go():
            front = AsyncSNNServer(server, max_queue=16)
            try:
                reqs = [_req(server, names, i) for i in range(4)]
                return await asyncio.gather(*(front.submit(r) for r in reqs))
            finally:
                await front.aclose()

        results = asyncio.run(go())
        by_rid = {r.rid: r for r in results}
        for d in direct:
            np.testing.assert_array_equal(by_rid[d.rid].counts, d.counts)
            assert by_rid[d.rid].pred == d.pred

    def test_zero_recompiles_across_bursts(self):
        server, names = _server()

        async def burst(front, base):
            reqs = [_req(server, names, base + i) for i in range(4)]
            return await asyncio.gather(*(front.submit(r) for r in reqs))

        async def go():
            front = AsyncSNNServer(server, max_queue=16)
            try:
                await burst(front, 0)
                warm = server.compiles
                await burst(front, 100)
                assert server.compiles == warm
            finally:
                await front.aclose()

        asyncio.run(go())


class TestAdmissionControl:
    def _rejections(self, server, reason):
        return server.registry.get(
            "snn_admission_rejections_total").value(reason=reason)

    def test_queue_overflow_rejected_and_counted(self):
        server, names = _server()

        async def go():
            front = AsyncSNNServer(server, max_queue=2)
            # Stall the worker by never letting it start: enqueue from
            # inside the loop faster than slots drain is racy, so test
            # the admission check directly against a full queue.
            front._closed = False
            with front._lock:
                front._queue.extend(
                    _req(server, names, 90 + i) for i in range(2))
            res = await front.submit(_req(server, names, 99))
            with front._lock:
                front._queue.clear()
            await front.aclose()
            return res

        res = asyncio.run(go())
        assert res.rejected and res.reason == "queue_full"
        assert self._rejections(server, "queue_full") == 1

    def test_tenant_cap_rejected_and_counted(self):
        server, names = _server()

        async def go():
            front = AsyncSNNServer(server, max_queue=16, tenant_cap=1)
            with front._lock:
                front._inflight[names[0]] = 1   # one already in flight
            res = await front.submit(
                _req(server, names, 0, tenant=names[0]))
            with front._lock:
                front._inflight.clear()
            await front.aclose()
            return res

        res = asyncio.run(go())
        assert res.rejected and res.reason == "tenant_cap"
        assert self._rejections(server, "tenant_cap") == 1

    def test_unknown_tenant_rejected(self):
        server, names = _server()

        async def go():
            front = AsyncSNNServer(server)
            try:
                r = ServeRequest(rid=0, tenant="ghost",
                                 ext=np.zeros((2, 4), np.float32), n_ticks=2)
                return await front.submit(r)
            finally:
                await front.aclose()

        res = asyncio.run(go())
        assert res.rejected and res.reason == "unknown_tenant"
        assert self._rejections(server, "unknown_tenant") == 1

    def test_request_after_shutdown_rejected(self):
        server, names = _server()

        async def go():
            front = AsyncSNNServer(server)
            await front.aclose()
            return await front.submit(_req(server, names, 0))

        res = asyncio.run(go())
        assert res.rejected and res.reason == "shutdown"
        assert self._rejections(server, "shutdown") == 1

    def test_constructor_validation(self):
        server, _ = _server()
        with pytest.raises(ValueError, match="max_queue"):
            AsyncSNNServer(server, max_queue=0)
        with pytest.raises(ValueError, match="tenant_cap"):
            AsyncSNNServer(server, tenant_cap=0)


class TestQueueDepthGauge:
    def test_depth_returns_to_zero(self):
        server, names = _server()

        async def go():
            front = AsyncSNNServer(server, max_queue=16)
            try:
                reqs = [_req(server, names, i) for i in range(5)]
                await asyncio.gather(*(front.submit(r) for r in reqs))
            finally:
                await front.aclose()

        asyncio.run(go())
        assert server.registry.get("snn_async_queue_depth").value() == 0
        assert server.registry.get("snn_async_submitted_total").value() == 5
