"""Plasticity subsystem: fused kernel vs oracle, masking, u8 round-trip.

The deterministic sweep tests always run; the hypothesis property tests
ride along when the 'test' extra is installed (they skip, not fail, when
it is not -- unlike the tier-1 modules this file must stay collectable
everywhere, since it is the only coverage of the new subsystem).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity
from repro.core.lif import LIFParams
from repro.core.network import SNNParams, SNNState, learning_rollout, rollout
from repro.core.registers import RegisterBank, WeightLayout
from repro.kernels import ops
from repro.kernels.ref import fused_stdp_step_ref
from repro.plasticity import (
    PlasticityParams, PlasticityState, apply_reward, plasticity_step,
    quantize_weights, weights_from_bank, weights_to_bank,
)
from repro.plasticity.traces import decay_from_tau, trace_step

jax.config.update("jax_platform_name", "cpu")

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

SHAPES = [
    (1, 8, 8),        # minimal
    (4, 74, 74),      # the paper's MNIST system size
    (3, 130, 70),     # ragged, forces padding on every axis
    (8, 128, 128),    # exactly block-aligned
]
HYPERS = dict(a_plus=0.8, a_minus=0.3, decay_pre=0.7, decay_post=0.6,
              decay_elig=0.9, lr_reward=0.4, w_min=0.0, w_max=255.0)


def _case(rng, b, k, n, spike_rate=0.3):
    return dict(
        s_pre=jnp.asarray((rng.random((b, k)) < spike_rate), jnp.float32),
        x_pre=jnp.asarray(rng.random((b, k)), jnp.float32),
        s_post=jnp.asarray((rng.random((b, n)) < spike_rate), jnp.float32),
        x_post=jnp.asarray(rng.random((b, n)), jnp.float32),
        w=jnp.asarray(rng.uniform(0, 255, (k, n)), jnp.float32),
        c=jnp.asarray((rng.random((k, n)) < 0.5), jnp.float32),
        elig=jnp.asarray(rng.normal(size=(k, n)), jnp.float32),
    )


class TestFusedKernelVsOracle:
    @pytest.mark.parametrize("b,k,n", SHAPES)
    @pytest.mark.parametrize("rule", ["stdp", "rstdp"])
    def test_interpret_matches_ref(self, b, k, n, rule):
        """Pallas interpret mode == jnp oracle (same kernel body as TPU)."""
        rng = np.random.default_rng(b * 1000 + k + n)
        case = _case(rng, b, k, n)
        r = jnp.asarray(0.5)
        got = ops.fused_stdp_step(
            case["s_pre"], case["x_pre"], case["s_post"], case["x_post"],
            case["w"], case["c"], case["elig"], r, rule=rule, **HYPERS)
        want = fused_stdp_step_ref(
            case["s_pre"], case["x_pre"], case["s_post"], case["x_post"],
            case["w"], case["c"], case["elig"], r, rule=rule, **HYPERS)
        for g, w_, name in zip(got, want, ("w", "elig", "x_pre", "x_post")):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w_), rtol=1e-6, atol=1e-6,
                err_msg=f"{rule}/{name} b={b} k={k} n={n}")

    @pytest.mark.parametrize("rule", ["stdp", "rstdp"])
    def test_unmasked_synapses_bit_identical(self, rule):
        """Where C == 0 the weight comes back bit-for-bit unchanged -- not
        even clipped (a frozen off-grid inhibitory block must survive)."""
        rng = np.random.default_rng(0)
        case = _case(rng, 4, 74, 74)
        # plant out-of-[w_min, w_max] values on masked synapses
        w = np.array(case["w"])
        w[np.asarray(case["c"]) == 0] = -127.0
        case["w"] = jnp.asarray(w)
        for backend in ("jnp", "pallas"):
            state = PlasticityState(
                x_pre=case["x_pre"], x_post=case["x_post"], elig=case["elig"])
            pp = PlasticityParams(rule=rule, **{
                k: v for k, v in HYPERS.items()})
            st2, w2 = plasticity_step(
                state, case["s_pre"], case["s_post"], case["w"], case["c"],
                pp, jnp.asarray(0.5), backend=backend)
            mask = np.asarray(case["c"]) == 0
            np.testing.assert_array_equal(
                np.asarray(w2)[mask], np.asarray(case["w"])[mask],
                err_msg=f"{backend}/{rule}")
            assert np.asarray(w2)[~mask].min() >= HYPERS["w_min"]
            assert np.asarray(w2)[~mask].max() <= HYPERS["w_max"]

    def test_state_level_backends_agree(self):
        rng = np.random.default_rng(1)
        case = _case(rng, 2, 40, 40)
        state = PlasticityState(
            x_pre=case["x_pre"], x_post=case["x_post"], elig=case["elig"])
        pp = PlasticityParams.make("rstdp", tau_pre=2.0, tau_post=3.0)
        outs = {}
        for backend in ("jnp", "pallas"):
            outs[backend] = plasticity_step(
                state, case["s_pre"], case["s_post"], case["w"], case["c"],
                pp, jnp.asarray(-1.0), backend=backend)
        for a, b in zip(jax.tree.leaves(outs["jnp"]),
                        jax.tree.leaves(outs["pallas"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)


class TestRuleSemantics:
    def test_trace_decay_law(self):
        x = jnp.asarray([1.0, 0.0])
        d = decay_from_tau(2.0)
        x1 = trace_step(x, jnp.zeros(2), d)
        np.testing.assert_allclose(np.asarray(x1), [d, 0.0], rtol=1e-6)
        x2 = trace_step(x1, jnp.ones(2), d)
        np.testing.assert_allclose(np.asarray(x2), [d * d + 1, 1.0], rtol=1e-6)

    def test_stdp_causal_potentiation_sign(self):
        """pre spike then post spike => that synapse potentiates."""
        pp = PlasticityParams.make("stdp", a_plus=1.0, a_minus=1.0)
        state = PlasticityState.zeros((), 2, 2)
        w = jnp.full((2, 2), 10.0)
        c = jnp.ones((2, 2))
        # tick 1: pre 0 spikes, no post
        state, w = plasticity_step(
            state, jnp.asarray([1.0, 0.0]), jnp.zeros(2), w, c, pp)
        # tick 2: post 1 spikes, no pre
        _, w = plasticity_step(
            state, jnp.zeros(2), jnp.asarray([0.0, 1.0]), w, c, pp)
        w = np.asarray(w)
        assert w[0, 1] > 10.0          # pre-0 -> post-1 causal pair: LTP
        assert w[1, 0] == 10.0         # nothing happened on that synapse

    def test_stdp_acausal_depression_sign(self):
        """post spike then pre spike => that synapse depresses."""
        pp = PlasticityParams.make("stdp", a_plus=1.0, a_minus=1.0)
        state = PlasticityState.zeros((), 2, 2)
        w = jnp.full((2, 2), 10.0)
        c = jnp.ones((2, 2))
        state, w = plasticity_step(
            state, jnp.zeros(2), jnp.asarray([0.0, 1.0]), w, c, pp)
        _, w = plasticity_step(
            state, jnp.asarray([1.0, 0.0]), jnp.zeros(2), w, c, pp)
        assert np.asarray(w)[0, 1] < 10.0   # acausal pair: LTD

    def test_rstdp_zero_reward_banks_eligibility(self):
        # asymmetric amplitudes: with a_plus == a_minus and zeroed traces,
        # one tick's LTP/LTD cancel exactly (coincident-pair convention)
        pp = PlasticityParams.make("rstdp", a_plus=1.0, a_minus=0.25)
        rng = np.random.default_rng(2)
        case = _case(rng, 2, 16, 16)
        state = PlasticityState.zeros((2,), 16)
        st2, w2 = plasticity_step(
            state, case["s_pre"], case["s_post"], case["w"], case["c"], pp)
        np.testing.assert_array_equal(np.asarray(w2), np.asarray(case["w"]))
        assert float(jnp.abs(st2.elig).max()) > 0

    def test_rstdp_reward_sign_flips_update(self):
        pp = PlasticityParams.make("rstdp", lr_reward=0.5)
        w = jnp.full((4, 4), 100.0)
        elig = jnp.asarray(np.random.default_rng(3).normal(size=(4, 4)),
                           jnp.float32)
        up = np.asarray(apply_reward(w, elig, 1.0, pp))
        down = np.asarray(apply_reward(w, elig, -1.0, pp))
        np.testing.assert_allclose(up - 100.0, -(down - 100.0), rtol=1e-5)

    def test_params_validation(self):
        with pytest.raises(ValueError):
            PlasticityParams(rule="hebbian")
        with pytest.raises(ValueError):
            PlasticityParams(w_min=-1.0)
        with pytest.raises(ValueError):
            PlasticityParams(w_max=300.0)


class TestLearningRollout:
    def _params(self, n, rng, v_th=1.5):
        c = connectivity.layered([n // 2, n - n // 2]).astype(np.float32)
        return SNNParams(
            w=jnp.asarray(rng.uniform(1, 3, (n, n)), jnp.float32),
            c=jnp.asarray(c),
            w_in=jnp.eye(n, dtype=jnp.float32) * 2.0,
            lif=LIFParams.make(n, v_th=v_th))

    def test_zero_amplitude_degenerates_to_rollout(self):
        rng = np.random.default_rng(4)
        n, ticks, b = 12, 6, 2
        params = self._params(n, rng)
        ext = jnp.asarray(
            np.tile((rng.random((b, n)) < 0.5) * (np.arange(n) < n // 2),
                    (ticks, 1, 1)).astype(np.float32))
        state = SNNState.zeros((b,), n)
        pstate = PlasticityState.zeros((b,), n)
        pp = PlasticityParams.make(a_plus=0.0, a_minus=0.0)
        (fin, _, w_fin), raster_l = learning_rollout(
            params, state, pstate, ext, ticks, plasticity=pp)
        fin_ref, raster = rollout(params, state, ext, ticks)
        np.testing.assert_array_equal(np.asarray(raster_l), np.asarray(raster))
        np.testing.assert_array_equal(np.asarray(w_fin), np.asarray(params.w))
        np.testing.assert_array_equal(np.asarray(fin.lif.v),
                                      np.asarray(fin_ref.lif.v))

    def test_updates_respect_connection_list(self):
        rng = np.random.default_rng(5)
        n, ticks, b = 12, 8, 2
        params = self._params(n, rng, v_th=1.0)
        ext = jnp.asarray(
            np.tile((rng.random((b, n)) < 0.7) * (np.arange(n) < n // 2),
                    (ticks, 1, 1)).astype(np.float32))
        pp = PlasticityParams.make(a_plus=0.5, a_minus=0.2)
        (_, _, w_fin), _ = learning_rollout(
            params, SNNState.zeros((b,), n), PlasticityState.zeros((b,), n),
            ext, ticks, plasticity=pp)
        dw = np.asarray(w_fin - params.w)
        off = np.asarray(params.c) == 0
        np.testing.assert_array_equal(dw[off], 0.0)
        assert np.abs(dw).max() > 0     # and something did learn

    def test_jnp_and_pallas_backends_agree(self):
        rng = np.random.default_rng(6)
        n, ticks, b = 10, 5, 2
        params = self._params(n, rng, v_th=1.0)
        ext = jnp.asarray(
            np.tile((rng.random((b, n)) < 0.7) * (np.arange(n) < n // 2),
                    (ticks, 1, 1)).astype(np.float32))
        pp = PlasticityParams.make(a_plus=0.5, a_minus=0.2)
        outs = {}
        for pb in ("jnp", "pallas"):
            outs[pb] = learning_rollout(
                params, SNNState.zeros((b,), n),
                PlasticityState.zeros((b,), n), ext, ticks, plasticity=pp,
                plasticity_backend=pb)
        (c_j, r_j), (c_p, r_p) = outs["jnp"], outs["pallas"]
        np.testing.assert_allclose(np.asarray(r_j), np.asarray(r_p))
        np.testing.assert_allclose(np.asarray(c_j[2]), np.asarray(c_p[2]),
                                   rtol=1e-5, atol=1e-5)

    def test_requires_unit_delay(self):
        rng = np.random.default_rng(7)
        n = 8
        params = self._params(n, rng)
        state = SNNState.zeros((), n, max_delay=3)
        with pytest.raises(ValueError, match="max_delay"):
            learning_rollout(params, state, PlasticityState.zeros((), n),
                             None, 4, plasticity=PlasticityParams.make())


class TestRegisterRoundTrip:
    def test_learned_weights_roundtrip_per_synapse(self):
        """STDP-learned weights -> u8 bank -> serialize -> load ->
        bit-identical registers and identical inference spikes."""
        rng = np.random.default_rng(8)
        n, ticks, b = 16, 8, 3
        c = connectivity.layered([8, 8]).astype(np.float32)
        params = SNNParams(
            w=jnp.asarray(rng.uniform(0, 64, (n, n)), jnp.float32),
            c=jnp.asarray(c),
            w_in=jnp.eye(n, dtype=jnp.float32) * 2.0,
            lif=LIFParams.make(n, v_th=40.0))
        ext = jnp.asarray(
            np.tile((rng.random((b, n)) < 0.7) * (np.arange(n) < 8),
                    (ticks, 1, 1)).astype(np.float32))
        pp = PlasticityParams.make(a_plus=3.0, a_minus=1.0, w_max=255.0)
        (_, _, w_learned), _ = learning_rollout(
            params, SNNState.zeros((b,), n), PlasticityState.zeros((b,), n),
            ext, ticks, plasticity=pp)

        bank = RegisterBank(n, weight_layout=WeightLayout.PER_SYNAPSE)
        bank.set_connection_list(c.astype(bool))
        bank.set_thresholds(np.full((n,), 40, np.uint8))
        w_u8 = weights_to_bank(bank, w_learned)

        bank_dev = RegisterBank(n, weight_layout=WeightLayout.PER_SYNAPSE)
        bank_dev.load_bytes(bank.serialize())
        assert bank_dev.serialize() == bank.serialize()
        np.testing.assert_array_equal(bank_dev.weights, w_u8)
        np.testing.assert_array_equal(
            bank_dev.get_connection_list(), bank.get_connection_list())

        def spikes(b_):
            from repro.core.network import params_from_registers
            p = params_from_registers(b_)
            p = dataclasses.replace(p, w_in=jnp.eye(n, dtype=jnp.float32) * 2.0)
            _, raster = rollout(p, SNNState.zeros((3,), n), ext, ticks)
            return np.asarray(raster)

        np.testing.assert_array_equal(spikes(bank), spikes(bank_dev))
        # and the readback path reproduces the quantized learning domain
        np.testing.assert_array_equal(
            np.asarray(weights_from_bank(bank_dev)),
            np.asarray(quantize_weights(w_learned), np.float32))

    def test_quantize_rejects_out_of_domain(self):
        with pytest.raises(ValueError, match="u8"):
            quantize_weights(jnp.asarray([[-3.0]]))
        with pytest.raises(ValueError, match="u8"):
            quantize_weights(jnp.asarray([[300.0]]))


if HAS_HYPOTHESIS:

    class TestProperties:
        @settings(deadline=None, max_examples=25)
        @given(st.integers(1, 6), st.integers(1, 90), st.integers(1, 90),
               st.sampled_from(["stdp", "rstdp"]),
               st.floats(-2.0, 2.0))
        def test_kernel_matches_oracle(self, b, k, n, rule, reward):
            rng = np.random.default_rng(b * 7 + k * 3 + n)
            case = _case(rng, b, k, n)
            r = jnp.asarray(reward, jnp.float32)
            got = ops.fused_stdp_step(
                case["s_pre"], case["x_pre"], case["s_post"], case["x_post"],
                case["w"], case["c"], case["elig"], r, rule=rule, **HYPERS)
            want = fused_stdp_step_ref(
                case["s_pre"], case["x_pre"], case["s_post"], case["x_post"],
                case["w"], case["c"], case["elig"], r, rule=rule, **HYPERS)
            for g, w_ in zip(got, want):
                np.testing.assert_allclose(np.asarray(g), np.asarray(w_),
                                           rtol=1e-6, atol=1e-6)

        @settings(deadline=None, max_examples=25)
        @given(st.floats(0.1, 50.0), st.integers(1, 30))
        def test_trace_bounded_by_steady_state(self, tau, ticks):
            from repro.plasticity.traces import trace_steady_state
            d = decay_from_tau(tau)
            x = jnp.zeros((1,))
            for _ in range(ticks):
                x = trace_step(x, jnp.ones(1), d)
            assert float(x[0]) <= trace_steady_state(1.0, d) + 1e-4
