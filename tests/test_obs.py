"""Observability layer: telemetry parity, HLO identity, metrics, serving.

Four pins (DESIGN.md §11):

* **Zero cost when off** -- the telemetry-off frozen jnp rollout lowers
  to HLO *byte-identical* to a pre-observability oracle scan written out
  verbatim in this file (module name normalized, nothing else), with a
  teeth check proving telemetry-on does perturb the lowered text.

* **Bit-exactness when on** -- for every backend (jnp / pallas /
  pallas_fused / event), telemetry-on rasters and final states equal
  telemetry-off bit-for-bit, and the accumulated spike count equals
  ``raster.sum()`` of the same rollout.

* **vmap transparency** -- per-row telemetry from a vmapped rollout
  equals the batched rollout's telemetry leaf-for-leaf (what the
  multi-tenant server's slot vmap relies on).

* **Host-side instruments** -- the dependency-free registry renders a
  valid Prometheus 0.0.4 text exposition and JSON dump; the SNN server
  reports requests/waves/TTFT/tenant activity through it, and its
  empty-queue / all-rejected paths return well-formed zero reports.
"""
import io
import json
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import EngineOptions, TickEngine
from repro.core.lif import LIFParams, lif_step
from repro.core.network import (
    SNNParams, SNNState, learning_rollout, rollout,
)
from repro.obs import (
    EventLog, MetricsRegistry, TickTelemetry, profile, span, trace_scope,
)
from repro.plasticity import PlasticityParams, PlasticityState

jax.config.update("jax_platform_name", "cpu")

BACKENDS = ("jnp", "pallas", "pallas_fused", "event")


def _params(n, *, density=0.5, seed=0, v_th=1.5, leak=0.25, r_ref=1):
    rng = np.random.default_rng(seed)
    c = (rng.random((n, n)) < density).astype(np.float32)
    np.fill_diagonal(c, 0.0)
    return SNNParams(
        w=jnp.asarray(rng.uniform(0, 2.0, (n, n)), jnp.float32),
        c=jnp.asarray(c, jnp.float32),
        w_in=jnp.eye(n, dtype=jnp.float32) * 2.0,
        lif=LIFParams.make(n, v_th=v_th, leak=leak, r_ref=r_ref))


def _ext(n, ticks, batch_shape=(), p=0.35, seed=1, mag=1.0):
    rng = np.random.default_rng(seed)
    shape = (ticks,) + tuple(batch_shape) + (n,)
    return jnp.asarray((rng.random(shape) < p) * mag, jnp.float32)


# ---------------------------------------------------------------------------
# Tier A: on-device telemetry
# ---------------------------------------------------------------------------

class TestTelemetryParity:
    """Telemetry on == telemetry off, bit for bit, on every backend."""

    N, T, D = 24, 12, 4

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_on_off_bit_exact_and_spikes_match_raster(self, backend):
        p = _params(self.N)
        st0 = SNNState.zeros((), self.N, max_delay=self.D)
        ext = _ext(self.N, self.T, seed=3)
        fs_off, r_off = rollout(p, st0, ext, self.T, backend=backend)
        fs_on, r_on, tel = rollout(p, st0, ext, self.T, backend=backend,
                                   telemetry=True)
        np.testing.assert_array_equal(np.asarray(r_off), np.asarray(r_on))
        for a, b in zip(jax.tree.leaves(fs_off), jax.tree.leaves(fs_on)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(r_on).sum() > 0, "dead network proves nothing"
        assert float(tel.ticks) == self.T
        assert float(tel.spikes) == float(np.asarray(r_on).sum())
        assert float(tel.overflow) == 0.0 or backend == "event"
        assert float(tel.dw_l1) == 0.0, "frozen rollout must report no dw"

    def test_summary_fields(self):
        p = _params(self.N)
        st0 = SNNState.zeros((), self.N, max_delay=self.D)
        ext = _ext(self.N, self.T, seed=3)
        _, raster, tel = rollout(p, st0, ext, self.T, telemetry=True)
        s = tel.summary(self.N)
        r = np.asarray(raster)
        assert s["ticks"] == self.T
        assert s["spikes"] == float(r.sum())
        assert s["spike_rate"] == pytest.approx(r.mean())
        assert 0.0 <= s["refractory_occupancy"] <= 1.0
        assert np.isfinite(s["v_max"]) and np.isfinite(s["v_mean"])
        assert s["dw_l1"] == 0.0 and s["dw_l2"] == 0.0

    def test_vmap_transparent(self):
        """Per-row vmapped telemetry == batched telemetry, leaf for leaf."""
        B = 3
        p = _params(self.N)
        ext_b = _ext(self.N, self.T, batch_shape=(B,), seed=5)

        def per_row(ext_row):
            st = SNNState.zeros((), self.N, max_delay=self.D)
            return rollout(p, st, ext_row, self.T, telemetry=True)[2]

        tel_v = jax.vmap(per_row, in_axes=1)(ext_b)
        st_b = SNNState.zeros((B,), self.N, max_delay=self.D)
        _, raster_b, tel_b = rollout(p, st_b, ext_b, self.T, telemetry=True)
        for a, b in zip(jax.tree.leaves(tel_v), jax.tree.leaves(tel_b)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)
        # and the per-row spike counts really are per-row
        per_row_spikes = np.asarray(raster_b).sum(axis=(0, 2))
        np.testing.assert_allclose(np.asarray(tel_b.spikes), per_row_spikes)

    def test_no_retrace_between_calls(self):
        """The static flag keys the jit cache; repeated calls don't trace."""
        p = _params(self.N)
        st0 = SNNState.zeros((), self.N, max_delay=self.D)
        traces = {"n": 0}

        @jax.jit
        def run(p, st, ext):
            traces["n"] += 1
            return rollout(p, st, ext, self.T, telemetry=True)

        run(p, st0, _ext(self.N, self.T, seed=1))
        run(p, st0, _ext(self.N, self.T, seed=2))
        assert traces["n"] == 1


class TestLearningTelemetry:
    N, T = 20, 16

    def _setup(self, seed=0):
        p = _params(self.N, v_th=1.0, seed=seed)
        st0 = SNNState.zeros((), self.N)  # STDP needs max_delay == 1
        pst0 = PlasticityState.zeros((), self.N)
        pp = PlasticityParams.make("stdp", a_plus=0.2, a_minus=0.1)
        ext = _ext(self.N, self.T, p=0.5, seed=seed + 1)
        return p, st0, pst0, pp, ext

    def test_dw_accumulates_and_stays_bit_exact(self):
        p, st0, pst0, pp, ext = self._setup()
        (fs_off, _, w_off), r_off = learning_rollout(
            p, st0, pst0, ext, self.T, plasticity=pp)
        (fs_on, _, w_on), r_on, tel = learning_rollout(
            p, st0, pst0, ext, self.T, plasticity=pp, telemetry=True)
        np.testing.assert_array_equal(np.asarray(r_off), np.asarray(r_on))
        np.testing.assert_array_equal(np.asarray(w_off), np.asarray(w_on))
        assert float(jnp.abs(w_on - p.w).sum()) > 0, "weights never moved"
        assert float(tel.dw_l1) > 0.0
        assert float(tel.dw_sq) > 0.0
        s = tel.summary(self.N)
        assert s["dw_l1"] > 0.0 and s["dw_l2"] > 0.0
        # L1 of the update stream >= L1 of the net displacement
        assert s["dw_l1"] >= float(jnp.abs(w_on - p.w).sum()) - 1e-4


class TestEventOverflowTelemetry:
    N, T = 24, 12

    def test_overflow_ticks_counted_and_exact(self):
        """k_active=2 + hot drive: nearly every tick overflows into the
        dense fallback; telemetry counts them and the raster stays exact."""
        p = _params(self.N, v_th=0.8)
        st0 = SNNState.zeros((), self.N)
        ext = _ext(self.N, self.T, p=0.8, seed=9, mag=2.0)
        _, r_ref = rollout(p, st0, ext, self.T, backend="jnp")
        eng = TickEngine(EngineOptions(backend="event", event_k_active=2,
                                       telemetry=True))
        _, r_ev, tel = eng.rollout(p, st0, ext, self.T)
        np.testing.assert_array_equal(np.asarray(r_ref), np.asarray(r_ev))
        assert np.asarray(r_ref).sum() > 2 * self.T, "drive too cold"
        assert float(tel.overflow) > 0
        assert float(tel.overflow) <= self.T

    def test_fan_in_gather_path_never_overflows(self):
        from repro.kernels.ops import EventFanIn

        p = _params(self.N, density=0.2, v_th=0.8)
        st0 = SNNState.zeros((), self.N)
        ext = _ext(self.N, self.T, p=0.8, seed=9, mag=2.0)
        fan_in = EventFanIn.from_dense(np.asarray(p.c))
        eng = TickEngine(EngineOptions(backend="event", event_k_active=2,
                                       telemetry=True))
        _, r_ev, tel = eng.rollout(p, st0, ext, self.T, neighbors=fan_in)
        _, r_ref = rollout(p, st0, ext, self.T, backend="jnp")
        np.testing.assert_allclose(np.asarray(r_ref), np.asarray(r_ev))
        assert float(tel.overflow) == 0.0, "gather path is exact by design"


class TestHLOIdentity:
    """telemetry=False ships the pre-observability program.

    Primary pin (structural, via :mod:`repro.analysis`): hoisted W*C,
    pure hot loop, no 64-bit types, no host calls -- the invariants the
    old byte-identity assertion was standing in for, asserted directly so
    the pin survives harmless lowering churn across jax versions.  ONE
    byte-compare against the inlined oracle remains as a canary; if it
    fails while the structural pin stays green, the lowering drifted
    cosmetically -- re-derive the oracle, don't add more byte pins.
    """

    N, T, D = 16, 8, 4

    def _args(self):
        p = _params(self.N)
        st0 = SNNState.zeros((), self.N, max_delay=self.D)
        ext = _ext(self.N, self.T, seed=7)
        return p, st0, ext

    @staticmethod
    def _oracle(params, state, ext_seq):
        """The frozen jnp rollout as it existed before the obs layer:
        hoisted W*C, delay read, matmul, LIF step, delay write -- no
        TickCarry, no telemetry slot, no named scopes."""
        wc = params.w * params.c.astype(params.w.dtype)
        max_delay = state.delay_buf.shape[-2]

        def body(st, ext):
            slot = jnp.mod(st.tick, max_delay)
            arriving = jax.lax.dynamic_index_in_dim(
                st.delay_buf, slot, axis=-2, keepdims=False
            ) if max_delay > 1 else st.lif.y
            syn = arriving @ wc
            if ext is not None:
                syn = syn + ext @ params.w_in
            lif_state = lif_step(st.lif, syn, params.lif)
            if max_delay > 1:
                write_slot = jnp.mod(st.tick + 1, max_delay)
                delay_buf = jax.lax.dynamic_update_index_in_dim(
                    st.delay_buf, lif_state.y, write_slot, axis=-2)
            else:
                delay_buf = st.delay_buf
            st2 = SNNState(lif=lif_state, delay_buf=delay_buf,
                           tick=st.tick + 1)
            return st2, lif_state.y

        return jax.lax.scan(body, state, ext_seq)

    @staticmethod
    def _lowered(fn, *args):
        txt = jax.jit(fn).lower(*args).as_text()
        return re.sub(r"module @\S+", "module @m", txt)

    def _engine_off(self, p, st, ext):
        return rollout(p, st, ext, self.T, backend="jnp")

    def _engine_on(self, p, st, ext):
        return rollout(p, st, ext, self.T, backend="jnp", telemetry=True)

    def _assert_structurally_clean(self, fn, p, st0, ext, tag):
        from repro.analysis import hlo_rules, jaxpr_rules

        cj = jaxpr_rules.closed_jaxpr_of(fn, p, st0, ext)
        assert jaxpr_rules.check_hot_loop_purity(cj, tag) == []
        assert jaxpr_rules.check_dtype_discipline(cj, tag) == []
        assert jaxpr_rules.check_hoist(
            cj, tag, n=self.N, expect=jaxpr_rules.HOIST_HOISTED) == []
        text = hlo_rules.lowered_text(fn, p, st0, ext)
        assert hlo_rules.check_no_f64_text(text, tag) == []
        assert hlo_rules.check_no_host_calls_text(text, tag) == []
        # Region-aware HLO count agrees with the jaxpr-level contract:
        # exactly one hoisted W*C product, zero per-tick ones.
        assert hlo_rules.wc_multiplies(text, self.N) == (0, 1)

    def test_telemetry_off_structural_pin(self):
        p, st0, ext = self._args()
        self._assert_structurally_clean(
            self._engine_off, p, st0, ext, "obs/telemetry-off")

    def test_telemetry_on_passes_the_same_structural_pin(self):
        """Telemetry adds carry leaves and reductions -- not impurity, not
        a hoist regression (false-positive resistance for the analyzer)."""
        p, st0, ext = self._args()
        self._assert_structurally_clean(
            self._engine_on, p, st0, ext, "obs/telemetry-on")

    def test_canary_telemetry_off_is_byte_identical_to_oracle(self):
        # The one remaining byte-compare (see class docstring).
        p, st0, ext = self._args()
        assert self._lowered(self._engine_off, p, st0, ext) \
            == self._lowered(self._oracle, p, st0, ext)

    def test_teeth_telemetry_on_perturbs_the_lowering(self):
        """Proves the byte-compare can fail: the telemetry-on program
        lowers differently (extra carry leaves + reductions)."""
        p, st0, ext = self._args()

        def engine_on(p, st, ext):
            return rollout(p, st, ext, self.T, backend="jnp",
                           telemetry=True)

        def engine_off(p, st, ext):
            return rollout(p, st, ext, self.T, backend="jnp")

        assert self._lowered(engine_on, p, st0, ext) \
            != self._lowered(engine_off, p, st0, ext)

    def test_oracle_matches_numerically_too(self):
        p, st0, ext = self._args()
        fs_o, r_o = self._oracle(p, st0, ext)
        fs_e, r_e = rollout(p, st0, ext, self.T, backend="jnp")
        np.testing.assert_array_equal(np.asarray(r_o), np.asarray(r_e))
        for a, b in zip(jax.tree.leaves(fs_o), jax.tree.leaves(fs_e)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Tier B: host-side instruments
# ---------------------------------------------------------------------------

class TestMetricsRegistry:
    def test_counter(self):
        reg = MetricsRegistry()
        c = reg.counter("reqs_total", "requests")
        c.inc()
        c.inc(2.5)
        assert c.value() == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_counter_labels(self):
        reg = MetricsRegistry()
        c = reg.counter("waves_total", labelnames=("backend",))
        c.inc(backend="jnp")
        c.inc(2, backend="event")
        assert c.value(backend="jnp") == 1
        assert c.value(backend="event") == 2
        assert c.value(backend="pallas") == 0
        with pytest.raises(ValueError):
            c.inc(nope="x")

    def test_gauge(self):
        reg = MetricsRegistry()
        g = reg.gauge("queue_depth")
        g.set(7)
        assert g.value() == 7
        g.set(3)
        assert g.value() == 3
        g.inc()
        assert g.value() == 4

    def test_histogram_buckets_cumulative(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", buckets=(0.1, 1.0, 10.0))
        for v in (0.05, 0.5, 0.5, 5.0, 50.0):
            h.observe(v)
        assert h.count() == 5
        assert h.sum() == pytest.approx(56.05)
        text = "\n".join(h.expose())
        assert 'lat_bucket{le="0.1"} 1' in text
        assert 'lat_bucket{le="1"} 3' in text
        assert 'lat_bucket{le="10"} 4' in text
        assert 'lat_bucket{le="+Inf"} 5' in text
        assert "lat_count 5" in text

    def test_idempotent_registration_and_kind_mismatch(self):
        reg = MetricsRegistry()
        a = reg.counter("x_total")
        b = reg.counter("x_total")
        assert a is b
        with pytest.raises(ValueError):
            reg.gauge("x_total")

    def test_prometheus_exposition_format(self):
        reg = MetricsRegistry()
        reg.counter("a_total", "things").inc(2)
        reg.gauge("b_depth").set(1)
        reg.histogram("c_seconds", "latency", buckets=(1.0,)).observe(0.5)
        text = reg.to_prometheus()
        assert "# HELP a_total things" in text
        assert "# TYPE a_total counter" in text
        assert "# TYPE b_depth gauge" in text
        assert "# TYPE c_seconds histogram" in text
        assert "a_total 2" in text
        assert 'c_seconds_bucket{le="+Inf"} 1' in text
        assert text.endswith("\n")

    def test_json_dump_round_trips(self):
        reg = MetricsRegistry()
        reg.counter("a_total", labelnames=("k",)).inc(3, k="v")
        reg.histogram("h_s", buckets=(1.0,)).observe(2.0)
        d = json.loads(json.dumps(reg.to_dict()))
        assert d["a_total"]["type"] == "counter"
        assert d["a_total"]["values"] == {'{k="v"}': 3.0}
        assert d["h_s"]["values"][""]["count"] == 1


class TestEventLog:
    def test_emit_filter_and_ring(self):
        log = EventLog(max_records=4)
        for i in range(6):
            log.emit("tick", i=i)
        log.emit("other")
        recs = log.events()
        assert len(recs) == 4  # ring capped
        assert [r["i"] for r in recs if r["event"] == "tick"] == [3, 4, 5]
        assert len(log.events("other")) == 1
        log.clear()
        assert log.events() == []

    def test_stream_mirror_is_json_lines(self):
        buf = io.StringIO()
        log = EventLog(stream=buf)
        log.emit("wave", backend="jnp", n=3)
        line = buf.getvalue().strip()
        rec = json.loads(line)
        assert rec["event"] == "wave" and rec["n"] == 3
        assert "ts" in rec


class TestTracing:
    def test_profile_none_is_noop(self):
        with profile(None):
            x = jnp.ones(3).sum()
        assert float(x) == 3.0

    def test_profile_bad_dir_degrades_to_logged_event(self, tmp_path):
        # Even if the profiler backend objects, serving must not crash.
        with profile(str(tmp_path / "trace")):
            jnp.ones(3).sum()

    def test_span_observes_into_histogram(self):
        reg = MetricsRegistry()
        h = reg.histogram("wave_seconds", labelnames=("backend",))
        with span("test/wave", histogram=h, backend="jnp"):
            pass
        assert h.count(backend="jnp") == 1
        assert h.sum(backend="jnp") >= 0.0

    def test_trace_scope_in_traced_code(self):
        @jax.jit
        def f(x):
            with trace_scope("test/scope"):
                return x * 2

        assert float(f(jnp.float32(3))) == 6.0


# ---------------------------------------------------------------------------
# Serving integration
# ---------------------------------------------------------------------------

class TestServeObservability:
    def _server(self, **kw):
        from repro.launch.serve import SNNServer

        kw.setdefault("n_max", 16)
        kw.setdefault("slots", 4)
        kw.setdefault("max_ticks", 8)
        return SNNServer(**kw)

    def test_registry_metrics_after_serve(self):
        from repro.launch.serve import make_demo_requests, make_demo_tenants

        server = self._server()
        names = make_demo_tenants(server, 4, seed=2)
        reqs = make_demo_requests(server, names, 8, seed=3)
        stats = server.serve(reqs)
        reg = server.registry
        assert reg.get("snn_requests_total").value() == stats["requests_served"] == 8
        assert reg.get("snn_ttft_seconds").count() == 8
        assert reg.get("snn_queue_depth").value() == 0.0
        assert reg.get("snn_slot_ticks_total").value() == \
            stats["waves"] * server.slots * server.max_ticks
        text = reg.to_prometheus()
        assert "# TYPE snn_requests_total counter" in text
        assert "# TYPE snn_ttft_seconds histogram" in text
        assert "snn_waves_total{backend=" in text

    def test_tenant_report(self):
        from repro.launch.serve import make_demo_requests, make_demo_tenants

        server = self._server()
        names = make_demo_tenants(server, 4, seed=2)
        stats = server.serve(make_demo_requests(server, names, 8, seed=3))
        report = server.tenant_report()
        assert set(report) == set(names)
        assert sum(r["requests"] for r in report.values()) \
            == stats["requests_served"]
        for r in report.values():
            assert 0.0 <= r["spike_rate"] <= 1.0
            assert 0.0 <= r["refractory_occupancy"] <= 1.0
        plastic = [n for n, r in report.items() if r["plastic"]]
        assert plastic, "demo tenants include one plastic network"
        assert report[plastic[0]]["dw_l1"] > 0, "plastic tenant never learned"
        frozen = [n for n in names if n not in plastic]
        assert all(report[n]["dw_l1"] == 0 for n in frozen)

    def test_empty_queue_zero_report(self):
        server = self._server()
        stats = server.serve([])
        assert stats["n_requests"] == 0
        assert stats["requests_served"] == 0
        assert stats["requests_rejected"] == 0
        assert stats["waves"] == 0
        assert stats["mean_ttft_s"] == 0.0

    def test_unknown_tenant_rejected_not_keyerror(self):
        from repro.launch.serve import ServeRequest

        server = self._server()
        bad = ServeRequest(rid=0, tenant="ghost",
                         ext=np.zeros((4, 4), np.float32), n_ticks=4)
        stats = server.serve([bad])
        assert stats["requests_served"] == 0
        assert stats["requests_rejected"] == 1
        assert server.registry.get("snn_requests_rejected_total").value() == 1

    def test_telemetry_off_server_still_serves(self):
        from repro.launch.serve import make_demo_requests, make_demo_tenants

        server = self._server(telemetry=False)
        names = make_demo_tenants(server, 4, seed=2)
        stats = server.serve(make_demo_requests(server, names, 4, seed=3))
        assert stats["requests_served"] == 4
        assert server.tenant_report() == {}

    def test_lm_serve_empty_queue(self):
        from repro.launch.serve import serve

        stats = serve(None, None, [])
        assert stats["n_requests"] == 0
        assert stats["requests_served"] == 0
        assert stats["mean_ttft_s"] == 0.0


class TestTickTelemetryUnit:
    def test_zeros_shapes(self):
        t = TickTelemetry.zeros((3,))
        assert t.spikes.shape == (3,)
        assert t.ticks.dtype == jnp.int32
        s = TickTelemetry.zeros(()).summary(8)
        assert s["ticks"] == 0.0 and s["spikes"] == 0.0

    def test_accumulate_matches_hand_reductions(self):
        from repro.core.lif import LIFState

        rng = np.random.default_rng(0)
        n = 8
        st = LIFState(
            v=jnp.asarray(rng.normal(size=(n,)), jnp.float32),
            y=jnp.asarray((rng.random(n) < 0.5).astype(np.float32)),
            r=jnp.asarray(rng.integers(0, 3, n), jnp.float32))
        t = TickTelemetry.zeros(()).accumulate(st)
        assert float(t.ticks) == 1
        assert float(t.spikes) == float(np.asarray(st.y).sum())
        assert float(t.v_sum) == pytest.approx(float(np.asarray(st.v).mean()))
        assert float(t.v_max) == pytest.approx(float(np.asarray(st.v).max()))
        assert float(t.ref_sum) == pytest.approx(
            float((np.asarray(st.r) > 0).mean()))
