"""Adaptive event dispatch: the crossover policy and the per-tick knee.

Three layers of pins:

* **Policy module** (:mod:`repro.core.dispatch_policy`) -- the single
  spike-budget trigger (:func:`resolve_k_active`), the cost-model
  strategy selection (fan_in below the gather knee, dense above,
  vmap_safe excluding topk), diagonal-``w_in`` detection, and the
  concrete-topology contract (tracers are rejected).

* **The knee itself** -- both arms of the adaptive ``lax.cond`` are
  bit-exact (the branch is pure speed policy, never semantics), the
  hysteresis band holds the dense arm until activity falls below
  ``hysteresis * knee`` (checked in both directions with engineered
  spike-count sequences), overflow ticks and policy ticks are counted
  in *separate* telemetry fields, and varying activity never retraces.

* **End-to-end** -- ``network.rollout(dispatch="auto")`` plans from the
  concrete topology and stays bit-compatible with the jnp reference.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity, dispatch_policy
from repro.core.dispatch_policy import (
    DispatchPlan, is_diagonal, knee_spikes, plan, resolve_k_active,
)
from repro.core.engine import EngineOptions, TickEngine
from repro.core.lif import LIFParams
from repro.core.network import SNNParams, SNNState, rollout

jax.config.update("jax_platform_name", "cpu")


def _params(n, c, *, seed=0, v_th=0.5, leak=0.25, r_ref=0, w_scale=0.0):
    """w_scale=0 kills the recurrent path so spike counts are purely
    ext-driven -- the hysteresis tests script them tick by tick."""
    rng = np.random.default_rng(seed)
    return SNNParams(
        w=jnp.asarray(rng.uniform(0, 1, (n, n)) * w_scale, jnp.float32),
        c=jnp.asarray(c, jnp.float32),
        w_in=jnp.eye(n, dtype=jnp.float32),
        lif=LIFParams.make(n, v_th=v_th, leak=leak, r_ref=r_ref))


def _scripted_ext(n, ranges):
    """One tick per (start, count): `count` disjoint neurons driven at 1.0
    (disjoint across consecutive ticks, so refractory never interferes and
    the arriving spike count at tick t+1 is exactly counts[t])."""
    ticks = []
    for start, count in ranges:
        e = np.zeros((n,), np.float32)
        e[start:start + count] = 1.0
        ticks.append(e)
    return jnp.asarray(np.stack(ticks))


def _ring(n, fan=4):
    """Circulant topology with exactly `fan` in-edges per neuron -- a cap
    the cost model can price deterministically."""
    c = np.zeros((n, n), np.float32)
    for j in range(1, fan + 1):
        c[np.arange(n), (np.arange(n) + j) % n] = 1.0
    return c


class TestResolveKActive:
    def test_default_budget(self):
        assert resolve_k_active(1024) == 128          # n // 8
        assert resolve_k_active(32) == 8              # floor 8
        assert resolve_k_active(4) == 4               # but never past n

    def test_explicit_clamped_to_n(self):
        assert resolve_k_active(64, 16) == 16
        assert resolve_k_active(64, 999) == 64

    def test_is_the_single_trigger(self):
        """ops.default_k_active must delegate here, not re-derive."""
        from repro.kernels import ops

        for n in (8, 64, 1024, 5000):
            assert ops.default_k_active(n) == resolve_k_active(n)


class TestKneeModel:
    def test_platform_penalties(self):
        assert knee_spikes(1024, platform="cpu") == 51    # n / 20
        assert knee_spikes(1024, platform="tpu") == 512   # n / 2
        assert knee_spikes(8, platform="cpu") == 1        # floored

    def test_is_diagonal(self):
        assert is_diagonal(np.eye(8))
        assert is_diagonal(np.diag(np.arange(1.0, 9.0)))
        assert not is_diagonal(np.ones((8, 8)))
        assert not is_diagonal(None)
        assert not is_diagonal(np.ones((4, 8)))


class TestPlan:
    def test_fan_in_below_gather_knee(self):
        """A 4-in-edge ring on CPU: 4 gathered elements cost ~80 dense
        MACs, far under the n=256 dense row -- fan_in wins."""
        p = plan(_ring(256, fan=4), platform="cpu")
        assert p.strategy == "fan_in"
        assert p.cap == 4
        assert p.neighbors is not None
        assert p.neighbors.idx.shape == (256, 4)
        assert p.knee is None                        # knee is topk-only

    def test_dense_above_gather_knee(self):
        """density 0.5 random on CPU: every event formulation pays more
        than the masked GEMM -- the plan says so."""
        c = np.asarray(connectivity.sparse_random(128, 0.5, seed=0))
        p = plan(c, platform="cpu")
        assert p.strategy == "dense"
        assert p.neighbors is None
        assert p.costs["dense"] < p.costs["fan_in"]
        assert p.costs["dense"] < p.costs["topk"]

    def test_topk_wins_on_tpu_and_arms_the_knee(self):
        """On TPU (gather penalty ~2) a tight spike budget beats both the
        dense product and a wide fan-in gather; the adaptive knee arms."""
        c = np.asarray(connectivity.sparse_random(128, 0.3, seed=1))
        p = plan(c, rate=0.05, platform="tpu")
        assert p.strategy == "topk"
        assert p.k_active == max(8, int(2 * 0.05 * 128))
        assert p.knee == min(knee_spikes(128, platform="tpu"), p.k_active)
        assert p.hysteresis == dispatch_policy.DEFAULT_HYSTERESIS

    def test_adaptive_false_disarms_knee(self):
        c = np.asarray(connectivity.sparse_random(128, 0.3, seed=1))
        p = plan(c, rate=0.05, platform="tpu", adaptive=False)
        assert p.strategy == "topk" and p.knee is None

    def test_vmap_safe_excludes_topk(self):
        """The server's contract: under vmap the knee cond lowers to a
        both-arms select, so topk must never be chosen."""
        c = np.asarray(connectivity.sparse_random(128, 0.3, seed=1))
        p = plan(c, rate=0.05, platform="tpu", vmap_safe=True)
        assert p.strategy != "topk"

    def test_forced_cap_too_small_disables_fan_in(self):
        """Never truncate: a fabric whose fan-in exceeds the forced cap
        simply cannot take the fan_in strategy."""
        p = plan(_ring(256, fan=4), cap=2, platform="cpu")
        assert p.cap is None
        assert p.strategy != "fan_in"
        assert "fan_in" not in p.costs

    def test_prefer_density_overrides_cost_model(self):
        """The operator knob: at/below the preferred density a fabric
        whose fan-in fits takes fan_in regardless of modeled cost."""
        c = np.asarray(connectivity.sparse_random(128, 0.5, seed=0))
        assert plan(c, platform="cpu").strategy == "dense"
        p = plan(c, platform="cpu", prefer_density=1.0)
        assert p.strategy == "fan_in"

    def test_diag_w_in_detected(self):
        c = _ring(64)
        assert plan(c, w_in=np.eye(64)).ext_diag
        assert not plan(c, w_in=np.ones((64, 64))).ext_diag
        assert not plan(c).ext_diag

    def test_tracer_rejected(self):
        """plan() is host-side by contract: topology statistics cannot be
        read off a tracer, and the error says to plan outside jit."""
        c = jnp.asarray(_ring(32))
        with pytest.raises(TypeError, match="concrete"):
            jax.jit(lambda a: plan(a))(c)

    def test_engine_kwargs_build_an_engine(self):
        p = plan(_ring(64, fan=4), w_in=np.eye(64))
        eng = TickEngine(EngineOptions(**p.engine_kwargs()))
        assert eng.backend == "event"
        assert eng.event_dispatch == p.strategy
        assert isinstance(p, DispatchPlan)


# -- the per-tick knee ------------------------------------------------------

# Scripted arrival counts (w=0, w_in=I, disjoint driven sets): arriving
# spike count at tick t+1 is exactly the tick-t ext count, tick 0 is 0.
#   knee hi = min(event_knee=40, k=60) = 40; lo = 0.75*40 = 30.
#   m per tick:    [0,   50,     35,      10,  35]
#   dense_mode:    [F,   T,      T(hyst), F,   F]   -> policy_dense == 2
#   with hysteresis=1.0 (lo=40), tick 2 releases:   -> policy_dense == 1
_RANGES = [(0, 50), (60, 35), (100, 10), (110, 35), (0, 0)]
_N = 160


def _knee_engine(**kw):
    base = dict(backend="event", event_dispatch="topk", event_k_active=60,
                event_knee=40, telemetry=True)
    base.update(kw)
    return TickEngine(EngineOptions(**base))


class TestAdaptiveKnee:
    def test_hysteresis_holds_dense_through_the_band(self):
        p = _params(_N, _ring(_N))
        ext = _scripted_ext(_N, _RANGES)
        st = SNNState.zeros((), _N)
        _, _, tel = _knee_engine().rollout(p, st, ext, len(_RANGES))
        assert int(tel.policy_dense) == 2            # ticks 1 and 2
        assert int(tel.overflow) == 0                # never past k=60

    def test_hysteresis_one_releases_at_the_knee(self):
        """Same activity, release threshold at the knee itself: the tick-2
        count (35 < 40) drops straight back to the spike-list arm."""
        p = _params(_N, _ring(_N))
        ext = _scripted_ext(_N, _RANGES)
        st = SNNState.zeros((), _N)
        eng = _knee_engine(event_hysteresis=1.0)
        _, _, tel = eng.rollout(p, st, ext, len(_RANGES))
        assert int(tel.policy_dense) == 1            # tick 1 only

    def test_overflow_counted_separately_from_policy(self):
        """k=12: the 50-spike tick is an *overflow* fallback (bits), the
        10-spike tick inside the hysteresis band a *policy* fallback
        (speed) -- disjoint fields, one tick each."""
        p = _params(_N, _ring(_N))
        ext = _scripted_ext(_N, [(0, 50), (60, 10), (100, 0), (0, 0)])
        st = SNNState.zeros((), _N)
        eng = _knee_engine(event_k_active=12)        # hi=min(40,12)=12, lo=9
        _, _, tel = eng.rollout(p, st, ext, 4)
        assert int(tel.overflow) == 1                # tick 1: m=50 > 12
        assert int(tel.policy_dense) == 1            # tick 2: 9 < m=10 <= 12

    def test_knee_requires_fallback_overflow(self):
        with pytest.raises(ValueError, match="event_knee requires"):
            EngineOptions(backend="event", event_dispatch="topk",
                          event_knee=4, event_overflow="strict")


class TestKneeParity:
    """Both arms are bit-exact: the cond is pure policy, never semantics."""

    def _case(self, n=96, density=0.3, seed=5):
        rng = np.random.default_rng(seed)
        c = connectivity.sparse_random(n, density, seed=seed)
        p = SNNParams(
            w=jnp.asarray(rng.uniform(0, 1, (n, n)), jnp.float32),
            c=jnp.asarray(c, jnp.float32),
            w_in=jnp.eye(n, dtype=jnp.float32),
            lif=LIFParams.make(n, v_th=0.8, leak=0.2, r_ref=1))
        return rng, p

    def test_dense_arm_bitexact_vs_jnp_backend(self):
        """Saturating drive keeps every tick above the knee: the whole
        rollout runs the dense arm, bit-identical to the jnp backend."""
        rng, p = self._case()
        n, ticks = p.w.shape[0], 6
        ext = jnp.asarray((rng.random((ticks, n)) < 0.9), jnp.float32)
        st = SNNState.zeros((), n)
        eng = TickEngine(EngineOptions(backend="event", event_dispatch="topk",
                         event_k_active=64, event_knee=8))
        _, got = eng.rollout(p, st, ext, ticks)
        _, want = rollout(p, SNNState.zeros((), n), ext, ticks, backend="jnp")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_event_arm_bitexact_vs_plain_event(self):
        """Low rate keeps every tick below the release threshold: the whole
        rollout runs the spike-list arm, bit-identical to the same engine
        without a knee (overflow fallback only)."""
        rng, p = self._case(seed=6)
        n, ticks = p.w.shape[0], 6
        ext = jnp.asarray((rng.random((ticks, n)) < 0.02), jnp.float32)
        st = SNNState.zeros((), n)
        eng = TickEngine(EngineOptions(backend="event", event_dispatch="topk",
                         event_k_active=64, event_knee=48))
        _, got = eng.rollout(p, st, ext, ticks)
        plain = TickEngine(EngineOptions(backend="event", event_dispatch="topk",
                           event_k_active=64))
        _, want = plain.rollout(p, SNNState.zeros((), n), ext, ticks)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_mixed_rates_match_jnp_backend(self):
        """Activity crossing the knee mid-rollout (both switch directions)
        stays exact vs the dense reference."""
        rng, p = self._case(seed=7)
        n, ticks = p.w.shape[0], 10
        rates = np.asarray([0.9, 0.9, 0.02, 0.02, 0.5,
                            0.02, 0.9, 0.02, 0.5, 0.02])
        ext = jnp.asarray(
            (rng.random((ticks, n)) < rates[:, None]), jnp.float32)
        st = SNNState.zeros((), n)
        eng = TickEngine(EngineOptions(backend="event", event_dispatch="topk",
                         event_k_active=64, event_knee=16, telemetry=True))
        _, got, tel = eng.rollout(p, st, ext, ticks)
        _, want = rollout(p, SNNState.zeros((), n), ext, ticks, backend="jnp")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        # The sequence really exercised both arms.
        assert 0 < int(tel.policy_dense) + int(tel.overflow) < ticks

    def test_ext_diag_bitexact_with_diagonal_w_in(self):
        """ext * diag(w_in) vs ext @ w_in: adding exact zeros is an f32
        no-op, so the eliminated GEMM changes no bits."""
        rng, p = self._case(seed=8)
        n, ticks = p.w.shape[0], 6
        ext = jnp.asarray((rng.random((ticks, n)) < 0.3), jnp.float32)
        out = {}
        for ed in (False, True):
            eng = TickEngine(EngineOptions(backend="event", event_dispatch="topk",
                             event_k_active=64, event_knee=16,
                             event_ext_diag=ed))
            _, out[ed] = eng.rollout(p, SNNState.zeros((), n), ext, ticks)
        np.testing.assert_array_equal(np.asarray(out[True]),
                                      np.asarray(out[False]))


class TestKneeRecompilePin:
    def test_one_trace_across_activity_levels(self):
        """The knee branches on a *runtime* spike count: rollouts at
        wildly different rates (both arms, overflow included) share one
        compiled program."""
        rng, p = TestKneeParity()._case(seed=9)
        n, ticks = p.w.shape[0], 5
        eng = TickEngine(EngineOptions(backend="event", event_dispatch="topk",
                         event_k_active=16, event_knee=8))
        traces = {"n": 0}

        def run(params, state, ext):
            traces["n"] += 1
            return eng.rollout(params, state, ext, ticks)

        jrun = jax.jit(run)
        st = SNNState.zeros((), n)
        for rate in (0.01, 0.3, 0.95):               # event / policy / overflow
            ext = jnp.asarray((rng.random((ticks, n)) < rate), jnp.float32)
            jrun(p, st, ext)
        assert traces["n"] == 1, f"activity level retraced {traces['n'] - 1}x"


class TestAutoDispatchEndToEnd:
    def test_rollout_auto_matches_jnp(self):
        """network.rollout(dispatch="auto"): plan from the concrete
        topology, run the event backend, match the dense reference."""
        rng = np.random.default_rng(11)
        n, ticks = 96, 6
        c = connectivity.sparse_random(n, 0.05, seed=11)
        p = SNNParams(
            w=jnp.asarray(rng.uniform(0, 1, (n, n)), jnp.float32),
            c=jnp.asarray(c, jnp.float32),
            w_in=jnp.eye(n, dtype=jnp.float32),
            lif=LIFParams.make(n, v_th=0.8, leak=0.2, r_ref=1))
        ext = jnp.asarray((rng.random((ticks, 2, n)) < 0.1), jnp.float32)
        st = SNNState.zeros((2,), n)
        _, got = rollout(p, st, ext, ticks, backend="event", dispatch="auto")
        _, want = rollout(p, SNNState.zeros((2,), n), ext, ticks,
                          backend="jnp")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_rollout_accepts_prebuilt_plan(self):
        rng = np.random.default_rng(12)
        n, ticks = 128, 4                            # 4*20 gathered < n dense
        c = _ring(n, fan=4)
        p = SNNParams(
            w=jnp.asarray(rng.uniform(0, 1, (n, n)), jnp.float32),
            c=jnp.asarray(c, jnp.float32),
            w_in=jnp.eye(n, dtype=jnp.float32),
            lif=LIFParams.make(n, v_th=0.8, leak=0.2, r_ref=1))
        dp = plan(np.asarray(c), w_in=np.eye(n))
        assert dp.strategy == "fan_in" and dp.ext_diag
        ext = jnp.asarray((rng.random((ticks, n)) < 0.2), jnp.float32)
        _, got = rollout(p, SNNState.zeros((), n), ext, ticks, dispatch=dp)
        _, want = rollout(p, SNNState.zeros((), n), ext, ticks, backend="jnp")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_plan_under_jit_raises_with_pointer(self):
        """dispatch="auto" inside jit cannot read the topology -- the
        error tells the caller to plan outside and pass the plan in."""
        n = 32
        p = _params(n, _ring(n))
        st = SNNState.zeros((), n)
        with pytest.raises(TypeError, match="outside jit"):
            jax.jit(lambda pp, ss: rollout(
                pp, ss, None, 2, backend="event", dispatch="auto"))(p, st)
