"""SNN network semantics: propagation, delays, reconfiguration, surrogate."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="tier-1 property tests need the 'test' extra")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import connectivity
from repro.core.lif import LIFParams
from repro.core.network import SNNParams, SNNState, forward_layered, params_from_registers, rollout
from repro.core.registers import RegisterBank, WeightLayout
from repro.core.surrogate import spike_surrogate

jax.config.update("jax_platform_name", "cpu")


def _params(n, c, *, v_th=0.5, w=None, w_in_scale=2.0, r_ref=0, leak=0.0):
    return SNNParams(
        w=jnp.asarray(w if w is not None else np.ones((n, n)), jnp.float32),
        c=jnp.asarray(c, jnp.float32),
        w_in=jnp.eye(n) * w_in_scale,
        lif=LIFParams.make(n, v_th=v_th, leak=leak, r_ref=r_ref))


class TestPropagation:
    def test_wavefront_crosses_one_layer_per_tick(self):
        """The tick semantics behind the paper's 2-cycles-per-layer model."""
        sizes = [3, 3, 3, 3]
        n = sum(sizes)
        p = _params(n, connectivity.layered(sizes))
        drive = jnp.zeros((n,)).at[:3].set(1.0)
        raster, _ = forward_layered(p, drive, sizes, n_ticks=5)
        out = np.asarray(raster)  # (T, n_out)
        first_out_tick = int(np.argmax(out.sum(1) > 0))
        assert first_out_tick == len(sizes) - 1  # depth-1 ticks to cross

    def test_ring_circulates(self):
        n = 5
        p = _params(n, connectivity.ring(n))
        st0 = SNNState.zeros((), n)
        ext = jnp.zeros((10, n)).at[0, 0].set(1.0)
        _, raster = rollout(p, st0, ext, 10)
        r = np.asarray(raster)
        # the single spike hops one neuron per tick around the ring
        for t in range(5):
            assert r[t, (t + 1) % n] == 1.0 or r[t].sum() >= 1.0

    def test_disconnected_stays_silent(self):
        n = 6
        p = _params(n, np.zeros((n, n), np.bool_))
        st0 = SNNState.zeros((), n)
        ext = jnp.zeros((4, n)).at[0, 0].set(1.0)
        _, raster = rollout(p, st0, ext, 4)
        # only neuron 0 (externally driven) ever spikes
        assert float(np.asarray(raster)[:, 1:].sum()) == 0.0


class TestDelays:
    def test_delay_2_doubles_hop_time(self):
        n = 4
        p = _params(n, connectivity.ring(n))
        st0 = SNNState.zeros((), n, max_delay=3)
        ext = jnp.zeros((8, n)).at[0, 0].set(1.0)
        delays = jnp.full((n, n), 2, jnp.int32)
        _, raster = rollout(p, st0, ext, 8, delays=delays)
        r = np.asarray(raster)
        assert r[0, 0] == 1.0     # external spike
        assert r[2, 1] == 1.0     # arrives after 2 ticks, not 1
        assert r[1].sum() == 0.0


class TestReconfiguration:
    def test_register_rewrite_changes_behaviour_same_shapes(self):
        bank = RegisterBank(6, weight_layout=WeightLayout.PER_SYNAPSE)
        w = np.zeros((6, 6), np.uint8)
        w[:3, 3:] = 50
        bank.set_weights(w)
        bank.set_thresholds(np.asarray([1, 1, 1, 10, 10, 10]))
        bank.set_connection_list(connectivity.layered([3, 3]))
        p1 = params_from_registers(bank)
        drive = jnp.zeros((6,)).at[:3].set(1.0)
        out1, _ = forward_layered(p1, drive, [3, 3], n_ticks=3)

        # rewrite: disconnect everything -- same shapes, silent output
        bank.set_connection_list(np.zeros((6, 6), np.bool_))
        p2 = params_from_registers(bank)
        out2, _ = forward_layered(p2, drive, [3, 3], n_ticks=3)
        assert jax.tree.map(lambda a: a.shape, p1) == jax.tree.map(lambda a: a.shape, p2)
        assert float(out1.sum()) > 0
        assert float(out2.sum()) == 0.0


class TestSurrogate:
    def test_forward_is_heaviside(self):
        x = jnp.asarray([-1.0, -1e-6, 0.0, 1e-6, 1.0])
        np.testing.assert_array_equal(spike_surrogate(x), [0, 0, 1, 1, 1])

    def test_gradient_peaks_at_threshold(self):
        g = jax.vmap(jax.grad(lambda x: spike_surrogate(x)))(
            jnp.asarray([-2.0, -0.1, 0.0, 0.1, 2.0]))
        g = np.asarray(g)
        assert g.argmax() == 2           # largest at the threshold
        assert (g > 0).all()             # nonzero everywhere (trainable)
        assert g[0] < g[1] < g[2]

    def test_training_through_rollout_reduces_loss(self):
        """Surrogate-gradient BPTT through the full scan rollout works."""
        n = 8
        rng = np.random.default_rng(0)
        c = jnp.asarray(connectivity.layered([4, 4]), jnp.float32)
        x = jnp.asarray((rng.random((16, 4)) > 0.5), jnp.float32)
        targets = jnp.asarray(x[:, [1, 0, 3, 2]])  # learn a permutation

        def loss_fn(w):
            p = SNNParams(w=jax.nn.softplus(w), c=c, w_in=jnp.eye(n) * 2.0,
                          lif=LIFParams.make(n, v_th=1.0))
            ext = jnp.zeros((4, 16, n)).at[:, :, :4].set(x[None])
            st0 = SNNState.zeros((16,), n)
            _, raster = rollout(p, st0, ext, 4, surrogate=True)
            rate = raster.mean(0)[:, 4:]
            return jnp.mean((rate - targets) ** 2)

        # init drives near threshold so the surrogate gradient is live
        w = jnp.asarray(rng.normal(size=(n, n)) * 0.3 - 0.5, jnp.float32)
        l0 = loss_fn(w)
        g = jax.jit(jax.grad(loss_fn))
        for _ in range(200):
            w = w - 1.0 * g(w)
        l1 = loss_fn(w)
        assert float(l1) < float(l0) * 0.6, (float(l0), float(l1))


@settings(deadline=None, max_examples=20)
@given(st.integers(2, 16), st.floats(0.1, 0.9), st.integers(0, 2**31 - 1))
def test_spikes_always_binary(n, density, seed):
    rng = np.random.default_rng(seed)
    p = _params(n, connectivity.sparse_random(n, density, seed=seed),
                w=rng.uniform(0, 2, (n, n)))
    st0 = SNNState.zeros((2,), n)
    ext = jnp.asarray((rng.random((5, 2, n)) < 0.3), jnp.float32)
    _, raster = rollout(p, st0, ext, 5)
    vals = set(np.unique(np.asarray(raster)))
    assert vals.issubset({0.0, 1.0})
