"""Checkpointing (atomic, rotated, async) + fault-tolerance runtime."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.runtime import elastic, fault_tolerance as ft, straggler

jax.config.update("jax_platform_name", "cpu")


def _tree(x=1.0):
    return {"a": jnp.full((4, 4), x), "nested": {"b": jnp.arange(6).reshape(2, 3)}}


class TestCheckpointer:
    def test_save_restore_roundtrip(self, tmp_path):
        d = str(tmp_path)
        t = _tree(3.0)
        ckpt.save(d, 7, t, extra_meta={"pipeline": {"step": 7}})
        restored, meta = ckpt.restore(d, t)
        np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(t["a"]))
        np.testing.assert_array_equal(np.asarray(restored["nested"]["b"]),
                                      np.asarray(t["nested"]["b"]))
        assert meta["step"] == 7
        assert meta["extra"]["pipeline"]["step"] == 7

    def test_rotation_keeps_last_k(self, tmp_path):
        d = str(tmp_path)
        for s in range(6):
            ckpt.save(d, s, _tree(s), keep=3)
        assert ckpt.all_steps(d) == [3, 4, 5]

    def test_latest_picks_newest_complete(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 1, _tree())
        ckpt.save(d, 5, _tree())
        # simulate a crashed partial write
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        assert ckpt.latest_step(d) == 5

    def test_async_checkpointer(self, tmp_path):
        d = str(tmp_path)
        ac = ckpt.AsyncCheckpointer(d, keep=2)
        for s in (1, 2, 3):
            ac.save_async(s, _tree(s))
        ac.wait()
        assert ckpt.all_steps(d) == [2, 3]

    def test_restore_missing_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            ckpt.restore(str(tmp_path), _tree())


class TestResilientLoop:
    def test_recovers_from_injected_failures(self, tmp_path):
        """Steps fail twice; the loop restores and the final state is exactly
        what an uninterrupted run would produce (counter-based pipeline)."""
        d = str(tmp_path)
        failures = {3: 2}  # step -> remaining failures to inject

        def step_fn(step, state):
            if failures.get(step, 0) > 0:
                failures[step] -= 1
                raise RuntimeError("injected preemption")
            return state + step

        def save_fn(step, state):
            ckpt.save(d, step, {"s": jnp.asarray(state)})

        def restore_fn():
            restored, meta = ckpt.restore(d, {"s": jnp.asarray(0)})
            return meta["step"], int(restored["s"])

        save_fn(0, 0)
        final_step, final_state = ft.run_resilient_loop(
            n_steps=6, start_step=0, step_fn=step_fn, state=0,
            save_fn=save_fn, restore_fn=restore_fn, checkpoint_every=2,
            policy=ft.RetryPolicy(max_failures=5))
        assert final_step == 6
        assert final_state == sum(range(6))

    def test_exhausted_retries_raise(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 0, {"s": jnp.asarray(0)})

        def bad_step(step, state):
            raise RuntimeError("permanent failure")

        with pytest.raises(ft.StepFailure):
            ft.run_resilient_loop(
                n_steps=3, start_step=0, step_fn=bad_step, state=0,
                save_fn=lambda s, st: None,
                restore_fn=lambda: (0, 0), checkpoint_every=10,
                policy=ft.RetryPolicy(max_failures=2))

    def test_heartbeat_ages(self):
        hb = ft.Heartbeat()
        hb.beat()
        assert hb.age() < 1.0


class TestStraggler:
    def test_flags_slow_host(self):
        mon = straggler.StragglerMonitor(z_threshold=2.0, min_steps=5)
        rng = np.random.default_rng(0)
        for step in range(20):
            for h in range(8):
                base = 1.0 + 0.01 * rng.standard_normal()
                mon.observe(f"host{h}", base * (5.0 if h == 3 else 1.0))
        assert mon.stragglers() == ["host3"]
        assert mon.exclusion_plan() == {"host3": "drain_and_replace"}

    def test_no_false_positives_on_uniform_fleet(self):
        mon = straggler.StragglerMonitor()
        for step in range(10):
            for h in range(8):
                mon.observe(f"host{h}", 1.0 + 0.001 * h)
        assert mon.stragglers() == []


class TestElastic:
    def test_plan_shrinks_data_axis_only(self):
        plan = elastic.plan_remesh(
            old_shape=(16, 16), axis_names=("data", "model"), n_lost_chips=16)
        assert plan.new_shape[1] == 16          # model preserved
        assert plan.new_shape[0] == 8           # data shrinks to pow2 fit
        assert plan.microbatch_multiplier == 2  # global batch preserved

    def test_plan_multipod(self):
        plan = elastic.plan_remesh(
            old_shape=(2, 16, 16), axis_names=("pod", "data", "model"),
            n_lost_chips=256)
        assert plan.new_shape[-1] == 16
        assert np.prod(plan.new_shape) <= 256

    def test_model_axis_unrecoverable(self):
        with pytest.raises(ValueError):
            elastic.plan_remesh(old_shape=(2, 16), axis_names=("data", "model"),
                                n_lost_chips=20)

    def test_checkpoint_reshard_roundtrip(self, tmp_path):
        """A checkpoint restores bit-exactly regardless of target sharding
        (single-device here; the 512-device path is the dry-run's job)."""
        d = str(tmp_path)
        t = _tree(2.5)
        ckpt.save(d, 1, t)
        sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        shardings = jax.tree.map(lambda _: sh, t)
        restored, _ = ckpt.restore(d, t, shardings=shardings)
        np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(t["a"]))
