"""TickEngine: bit-exactness vs the pre-refactor scans, delays, hoisting.

Three pins:

* **Oracle equivalence** -- the seed implementations of ``rollout`` /
  ``learning_rollout`` / ``forward_layered`` (three separate scan bodies,
  copied verbatim below) produce bit-identical rasters and final states
  to the TickEngine-backed wrappers, on the jnp backend, across frozen /
  delayed / learning paths.

* **Per-synapse delay round trip** -- a spike emitted at tick k arrives
  at tick k+delay, checked against a pure-python event-scheduling
  reference (no jnp in the reference path).

* **W*C hoisting** -- the frozen-weight rollout materializes the masked
  matrix once per rollout (outside the scanned while body), not once per
  tick; checked on the lowered StableHLO region structure, with a
  deliberately-unhoisted control proving the check has teeth.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity
from repro.core.engine import EngineOptions, TickCarry, TickEngine
from repro.core.lif import LIFParams, lif_step
from repro.core.network import (
    SNNParams, SNNState, forward_layered, learning_rollout, rollout,
    synaptic_input,
)
from repro.plasticity import PlasticityParams, PlasticityState

jax.config.update("jax_platform_name", "cpu")


def _params(n, c, *, seed=0, v_th=1.5, leak=0.25, r_ref=1, w_scale=2.0,
            w_in_scale=2.0):
    rng = np.random.default_rng(seed)
    return SNNParams(
        w=jnp.asarray(rng.uniform(0, w_scale, (n, n)), jnp.float32),
        c=jnp.asarray(c, jnp.float32),
        w_in=jnp.eye(n, dtype=jnp.float32) * w_in_scale,
        lif=LIFParams.make(n, v_th=v_th, leak=leak, r_ref=r_ref))


def _ext(n, ticks, batch_shape=(), p=0.35, seed=1, mag=1.0):
    rng = np.random.default_rng(seed)
    shape = (ticks,) + tuple(batch_shape) + (n,)
    return jnp.asarray((rng.random(shape) < p) * mag, jnp.float32)


# ---------------------------------------------------------------------------
# The SEED implementation, copied verbatim (pre-TickEngine git history):
# three independent scan bodies. These are the oracles.
# ---------------------------------------------------------------------------

def _seed_step(state, params, ext=None, *, mode="fixed_leak",
               surrogate=False, delays=None):
    max_delay = state.delay_buf.shape[-2]
    slot = jnp.mod(state.tick, max_delay)
    if delays is None:
        arriving = jax.lax.dynamic_index_in_dim(
            state.delay_buf, slot, axis=-2, keepdims=False
        ) if max_delay > 1 else state.lif.y
        syn = synaptic_input(arriving, params, ext)
        lif_state = lif_step(state.lif, syn, params.lif, mode=mode,
                             surrogate=surrogate)
    else:
        def gather_delay(d):
            idx = jnp.mod(slot - d, max_delay)
            return jax.lax.dynamic_index_in_dim(
                state.delay_buf, idx, axis=-2, keepdims=False)

        hist = jnp.stack([gather_delay(d) for d in range(max_delay)], axis=0)
        onehot = jax.nn.one_hot(delays - 1, max_delay, axis=0,
                                dtype=params.w.dtype)
        wc = params.w * params.c.astype(params.w.dtype)
        syn = jnp.einsum("d...p,dpq,pq->...q", hist, onehot, wc)
        if ext is not None:
            syn = syn + ext @ params.w_in
        lif_state = lif_step(state.lif, syn, params.lif, mode=mode,
                             surrogate=surrogate)
    if max_delay > 1:
        write_slot = jnp.mod(state.tick + 1, max_delay)
        delay_buf = jax.lax.dynamic_update_index_in_dim(
            state.delay_buf, lif_state.y, write_slot, axis=-2)
    else:
        delay_buf = state.delay_buf
    return SNNState(lif=lif_state, delay_buf=delay_buf, tick=state.tick + 1)


def _seed_rollout(params, state, ext_seq, n_ticks, *, mode="fixed_leak",
                  surrogate=False, delays=None):
    def body(st, ext):
        st2 = _seed_step(st, params, ext, mode=mode, surrogate=surrogate,
                         delays=delays)
        return st2, st2.lif.y

    if ext_seq is None:
        return jax.lax.scan(body, state, None, length=n_ticks)
    return jax.lax.scan(body, state, ext_seq)


def _seed_learning_rollout(params, state, plast_state, ext_seq, n_ticks, *,
                           plasticity, rewards=None, plastic_c=None,
                           mode="fixed_leak"):
    from repro.plasticity import rules as plasticity_rules

    if rewards is None:
        rewards = jnp.zeros((n_ticks,), jnp.float32)
    if plastic_c is None:
        plastic_c = params.c

    def body(carry, xs):
        st, pst, w = carry
        ext, reward = xs
        p = dataclasses.replace(params, w=w)
        s_pre = st.lif.y
        st2 = _seed_step(st, p, ext, mode=mode)
        pst2, w2 = plasticity_rules.plasticity_step(
            pst, s_pre, st2.lif.y, w, plastic_c, plasticity, reward,
            backend="jnp")
        return (st2, pst2, w2), st2.lif.y

    carry0 = (state, plast_state, params.w)
    if ext_seq is None:
        return jax.lax.scan(
            lambda c, r: body(c, (None, r)), carry0, rewards, length=n_ticks)
    return jax.lax.scan(body, carry0, (ext_seq, rewards))


def _seed_forward_layered(params, spikes_in, layer_sizes, n_ticks=None, *,
                          mode="fixed_leak"):
    n = params.w.shape[0]
    depth = len(layer_sizes)
    if n_ticks is None:
        n_ticks = depth + 1
    if spikes_in.ndim >= 2 and spikes_in.shape[0] == n_ticks and n_ticks > 1:
        ext_seq = spikes_in
        batch_shape = spikes_in.shape[1:-1]
    else:
        ext_seq = jnp.broadcast_to(spikes_in[None], (n_ticks,) + spikes_in.shape)
        batch_shape = spikes_in.shape[:-1]
    state = SNNState.zeros(batch_shape, n, dtype=params.w.dtype)
    final, raster = _seed_rollout(params, state, ext_seq, n_ticks, mode=mode)
    n_out = layer_sizes[-1]
    return raster[..., n - n_out:], final


def _assert_trees_bitexact(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Oracle equivalence: the engine wrappers ARE the seed scans, bit for bit.
# Parametrized over backends: "pallas_fused" (the whole-tick megakernel, in
# interpret mode on CPU -- same kernel body the TPU runs) must reproduce the
# seed oracles bit for bit too, including per-synapse delays and refractory
# masking.
# ---------------------------------------------------------------------------

BACKENDS = ["jnp", "pallas_fused", "event"]


class TestSeedEquivalence:
    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("mode", ["fixed_leak", "euler"])
    @pytest.mark.parametrize("batch_shape", [(), (3,)])
    def test_rollout_bitexact(self, mode, batch_shape, backend):
        n, ticks = 9, 12
        p = _params(n, connectivity.sparse_random(n, 0.5, seed=3))
        st0 = SNNState.zeros(batch_shape, n)
        ext = _ext(n, ticks, batch_shape)
        fin_o, ras_o = _seed_rollout(p, st0, ext, ticks, mode=mode)
        fin_e, ras_e = rollout(p, st0, ext, ticks, mode=mode, backend=backend)
        np.testing.assert_array_equal(np.asarray(ras_o), np.asarray(ras_e))
        _assert_trees_bitexact(fin_o, fin_e)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_rollout_autonomous_bitexact(self, backend):
        n = 6
        p = _params(n, connectivity.ring(n), v_th=0.5)
        st0 = SNNState.zeros((), n)
        st0 = dataclasses.replace(
            st0, lif=dataclasses.replace(st0.lif, y=jnp.ones((n,))))
        fin_o, ras_o = _seed_rollout(p, st0, None, 7)
        fin_e, ras_e = rollout(p, st0, None, 7, backend=backend)
        np.testing.assert_array_equal(np.asarray(ras_o), np.asarray(ras_e))
        _assert_trees_bitexact(fin_o, fin_e)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_rollout_with_delays_bitexact(self, backend):
        n, ticks, max_delay = 7, 14, 3
        rng = np.random.default_rng(5)
        c = connectivity.sparse_random(n, 0.6, seed=5)
        p = _params(n, c, v_th=0.8)
        delays = jnp.asarray(
            rng.integers(1, max_delay + 1, (n, n)), jnp.int32)
        st0 = SNNState.zeros((), n, max_delay=max_delay)
        ext = _ext(n, ticks, (), p=0.3, seed=6)
        fin_o, ras_o = _seed_rollout(p, st0, ext, ticks, delays=delays)
        fin_e, ras_e = rollout(p, st0, ext, ticks, delays=delays,
                               backend=backend)
        np.testing.assert_array_equal(np.asarray(ras_o), np.asarray(ras_e))
        _assert_trees_bitexact(fin_o, fin_e)

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("rule", ["stdp", "rstdp"])
    def test_learning_rollout_bitexact(self, rule, backend):
        n, ticks, b = 8, 10, 2
        c = connectivity.sparse_random(n, 0.6, seed=7)
        p = _params(n, c, v_th=1.0, w_scale=3.0)
        pp = PlasticityParams.make(rule, a_plus=0.3, a_minus=0.2, w_max=16.0)
        st0 = SNNState.zeros((b,), n)
        pst0 = PlasticityState.zeros((b,), n)
        ext = _ext(n, ticks, (b,), seed=8)
        rewards = jnp.asarray(
            np.random.default_rng(9).normal(size=(ticks,)), jnp.float32)
        # sub-mask: only the upper-triangular synapses learn
        plastic_c = p.c * jnp.triu(jnp.ones((n, n), jnp.float32))
        (f1, p1, w1), r1 = _seed_learning_rollout(
            p, st0, pst0, ext, ticks, plasticity=pp, rewards=rewards,
            plastic_c=plastic_c)
        (f2, p2, w2), r2 = learning_rollout(
            p, st0, pst0, ext, ticks, plasticity=pp, rewards=rewards,
            plastic_c=plastic_c, backend=backend,
            plasticity_backend="jnp")
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
        np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
        _assert_trees_bitexact((f1, p1), (f2, p2))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_forward_layered_bitexact(self, backend):
        sizes = [4, 5, 3]
        n = sum(sizes)
        p = _params(n, connectivity.layered(sizes), v_th=0.5)
        drive = jnp.asarray(
            (np.random.default_rng(2).random((2, n)) < 0.5), jnp.float32)
        ras_o, fin_o = _seed_forward_layered(p, drive, sizes, n_ticks=6)
        ras_e, fin_e = forward_layered(p, drive, sizes, n_ticks=6,
                                       time_major=False, backend=backend)
        np.testing.assert_array_equal(np.asarray(ras_o), np.asarray(ras_e))
        _assert_trees_bitexact(fin_o, fin_e)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_forward_layered_spike_train_bitexact(self, backend):
        sizes = [3, 3]
        n = sum(sizes)
        ticks = 5
        p = _params(n, connectivity.layered(sizes), v_th=0.5)
        train = _ext(n, ticks, (), p=0.5, seed=4)
        ras_o, _ = _seed_forward_layered(p, train, sizes, n_ticks=ticks)
        ras_e, _ = forward_layered(p, train, sizes, n_ticks=ticks,
                                   time_major=True, backend=backend)
        np.testing.assert_array_equal(np.asarray(ras_o), np.asarray(ras_e))


# ---------------------------------------------------------------------------
# Event backend specifics: uniform delay rings, ragged fan-out padding,
# overflow fallback, fan-in gather path -- all bit-exact vs the seed oracle
# ---------------------------------------------------------------------------


class TestEventBackend:
    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("max_delay", [2, 4])
    def test_uniform_delay_ring_bitexact(self, backend, max_delay):
        """delays=None but a live D-slot ring: every backend reads the slot
        arriving this tick, then dispatches -- bit-exact across backends."""
        n, ticks = 8, 13
        p = _params(n, connectivity.sparse_random(n, 0.5, seed=11), v_th=0.9)
        st0 = SNNState.zeros((2,), n, max_delay=max_delay)
        ext = _ext(n, ticks, (2,), p=0.3, seed=12)
        fin_o, ras_o = _seed_rollout(p, st0, ext, ticks)
        fin_e, ras_e = rollout(p, st0, ext, ticks, backend=backend)
        np.testing.assert_array_equal(np.asarray(ras_o), np.asarray(ras_e))
        _assert_trees_bitexact(fin_o, fin_e)

    def test_ragged_fanout_padding_bitexact(self):
        """A hub neuron with full fan-out/fan-in next to near-silent rows:
        the padded neighbor lists are maximally ragged, and both event
        dispatch strategies still reproduce the oracle bit for bit."""
        from repro.kernels.ops import EventFanIn

        n, ticks = 10, 12
        c = np.zeros((n, n), np.bool_)
        c[0, 1:] = True          # hub fan-out: n-1 targets
        c[1:, 0] = True          # hub fan-in: n-1 sources
        c[3, 4] = c[7, 2] = True  # a couple of skinny rows
        p = _params(n, c, v_th=0.8)
        st0 = SNNState.zeros((), n)
        ext = _ext(n, ticks, (), p=0.4, seed=13)
        fin_o, ras_o = _seed_rollout(p, st0, ext, ticks)
        _, ras_topk = rollout(p, st0, ext, ticks, backend="event")
        np.testing.assert_array_equal(np.asarray(ras_o), np.asarray(ras_topk))
        nbrs = EventFanIn.from_dense(c)
        assert nbrs.idx.shape == (n, n - 1)     # cap == the hub's in-degree
        _, ras_fi = rollout(p, st0, ext, ticks, backend="event",
                            neighbors=nbrs)
        np.testing.assert_array_equal(np.asarray(ras_o), np.asarray(ras_fi))

    def test_overflow_fallback_bitexact_at_high_rate(self):
        """k_active far below the spike count: the dense fallback keeps the
        event backend exact instead of silently dropping spikes."""
        n, ticks = 9, 10
        p = _params(n, connectivity.sparse_random(n, 0.7, seed=14), v_th=0.3)
        st0 = SNNState.zeros((), n)
        ext = _ext(n, ticks, (), p=0.9, seed=15)   # near-saturated drive
        fin_o, ras_o = _seed_rollout(p, st0, ext, ticks)
        eng = TickEngine(EngineOptions(backend="event", event_k_active=2))
        fin_e, ras_e = eng.rollout(p, st0, ext, ticks)
        assert float(np.asarray(ras_o).sum(-1).max()) > 2  # overflow happened
        np.testing.assert_array_equal(np.asarray(ras_o), np.asarray(ras_e))
        _assert_trees_bitexact(fin_o, fin_e)

    def test_fan_in_path_is_vmap_safe(self):
        """The gather path has no data-dependent control flow: vmapping the
        rollout over a leading axis (the server's slot axis) equals the
        per-element loop bit for bit."""
        from repro.kernels.ops import EventFanIn

        n, ticks, slots = 7, 8, 3
        c = connectivity.sparse_random(n, 0.4, seed=16)
        nbrs = EventFanIn.from_dense(c)
        ps = [_params(n, c, seed=20 + i, v_th=0.9) for i in range(slots)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
        ext = _ext(n, ticks, (slots,), p=0.3, seed=17)

        def one(p, e):
            eng = TickEngine(EngineOptions(backend="event"))
            st0 = SNNState.zeros((), n)
            return eng.rollout(p, st0, e, ticks, neighbors=nbrs)[1]

        ras_v = jax.vmap(one, in_axes=(0, 1))(stacked, ext)
        for i in range(slots):
            np.testing.assert_array_equal(
                np.asarray(ras_v[i]), np.asarray(one(ps[i], ext[:, i])))


# ---------------------------------------------------------------------------
# forward_layered time_major semantics (satellite: kill the shape heuristic)
# ---------------------------------------------------------------------------

class TestTimeMajor:
    def _setup(self, n_ticks):
        sizes = [4, 2]
        n = sum(sizes)
        p = _params(n, connectivity.layered(sizes), v_th=0.5)
        # batch size == n_ticks: the ambiguous case the heuristic misreads
        drive = jnp.asarray(
            (np.random.default_rng(0).random((n_ticks, n)) < 0.6), jnp.float32)
        return p, sizes, drive

    def test_heuristic_fallback_warns(self):
        p, sizes, drive = self._setup(4)
        with pytest.warns(DeprecationWarning, match="time_major"):
            forward_layered(p, drive, sizes, n_ticks=4)

    def test_explicit_false_treats_batch_as_batch(self):
        n_ticks = 4
        p, sizes, drive = self._setup(n_ticks)
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # explicit arg must not warn
            ras, _ = forward_layered(p, drive, sizes, n_ticks=n_ticks,
                                     time_major=False)
        # clamped drive: (T, B, n_out) -- the batch axis survives
        assert ras.shape == (n_ticks, n_ticks, sizes[-1])
        # and equals per-sample clamped runs (the heuristic would instead
        # have consumed axis 0 as time and produced (T, n_out))
        for b in range(n_ticks):
            ras_b, _ = forward_layered(p, drive[b], sizes, n_ticks=n_ticks,
                                       time_major=False)
            np.testing.assert_array_equal(np.asarray(ras[:, b]),
                                          np.asarray(ras_b))

    def test_explicit_true_requires_time_axis(self):
        p, sizes, drive = self._setup(4)
        with pytest.raises(ValueError, match="time axis"):
            forward_layered(p, drive, sizes, n_ticks=6, time_major=True)

    def test_explicit_true_matches_heuristic_train_path(self):
        n_ticks = 4
        p, sizes, drive = self._setup(n_ticks)
        ras_t, _ = forward_layered(p, drive, sizes, n_ticks=n_ticks,
                                   time_major=True)
        assert ras_t.shape == (n_ticks, sizes[-1])


# ---------------------------------------------------------------------------
# Per-synapse delays vs a pure-python event-scheduling reference
# ---------------------------------------------------------------------------

def _python_delay_reference(w, c, delays, v_th, leak, r_ref, ext_seq,
                            w_in_scale):
    """Spike emitted at tick k arrives at tick k + delays[pre, post].

    Plain-python fixed-leak LIF + explicit event scheduling; no delay
    ring buffer, no slot arithmetic -- the semantics, stated directly.
    """
    n = w.shape[0]
    T = ext_seq.shape[0]
    v = np.zeros(n)
    r = np.zeros(n, np.int64)
    emitted = []                               # emitted[k][i]: spike at tick k
    raster = np.zeros((T, n))
    for t in range(T):
        syn = np.zeros(n)
        for post in range(n):
            for pre in range(n):
                if c[pre, post]:
                    k = t - int(delays[pre, post])   # emission tick arriving now
                    if k >= 0:
                        syn[post] += w[pre, post] * emitted[k][pre]
        syn += ext_seq[t] * w_in_scale           # w_in = eye * scale
        active = (v != 0).astype(float)
        leak_step = np.minimum(leak * active, np.abs(v))
        v_tilde = v + syn - np.sign(v) * leak_step
        y = ((v_tilde >= v_th) & (r == 0)).astype(float)
        spiked = y > 0
        hold = spiked | (r > 0)
        v = np.where(hold, 0.0, v_tilde)
        r = np.where(spiked, r_ref, np.maximum(r - 1, 0))
        emitted.append(y)
        raster[t] = y
    return raster


class TestDelayRoundTrip:
    @pytest.mark.parametrize("max_delay", [2, 3, 4])
    def test_engine_matches_python_reference(self, max_delay):
        n, ticks = 6, 16
        rng = np.random.default_rng(max_delay)
        c = connectivity.sparse_random(n, 0.6, seed=max_delay).astype(np.float64)
        w = rng.uniform(0.5, 2.0, (n, n))
        delays = rng.integers(1, max_delay + 1, (n, n))
        v_th, leak, r_ref, w_in_scale = 1.2, 0.3, 1, 2.0
        ext = (rng.random((ticks, n)) < 0.25).astype(np.float64)

        ref = _python_delay_reference(w, c, delays, v_th, leak, r_ref, ext,
                                      w_in_scale)

        p = SNNParams(
            w=jnp.asarray(w, jnp.float32), c=jnp.asarray(c, jnp.float32),
            w_in=jnp.eye(n, dtype=jnp.float32) * w_in_scale,
            lif=LIFParams.make(n, v_th=v_th, leak=leak, r_ref=r_ref))
        st0 = SNNState.zeros((), n, max_delay=max_delay)
        _, raster = rollout(p, st0, jnp.asarray(ext, jnp.float32), ticks,
                            delays=jnp.asarray(delays, jnp.int32))
        np.testing.assert_array_equal(np.asarray(raster), ref)

    def test_slot_arithmetic_single_spike(self):
        """One spike emitted at tick k arrives exactly at k + d, for every d."""
        for d in (1, 2, 3, 4):
            n, max_delay = 2, 4
            c = np.zeros((n, n)); c[0, 1] = 1.0
            p = SNNParams(
                w=jnp.full((n, n), 5.0), c=jnp.asarray(c, jnp.float32),
                w_in=jnp.eye(n, dtype=jnp.float32) * 5.0,
                lif=LIFParams.make(n, v_th=1.0, leak=0.0, r_ref=0))
            delays = jnp.full((n, n), d, jnp.int32)
            ticks = d + 4
            ext = jnp.zeros((ticks, n)).at[0, 0].set(1.0)  # neuron 0 fires at k=0
            st0 = SNNState.zeros((), n, max_delay=max_delay)
            _, raster = rollout(p, st0, ext, ticks, delays=delays)
            r = np.asarray(raster)
            assert r[0, 0] == 1.0
            arrival = np.nonzero(r[:, 1])[0]
            assert arrival.size >= 1 and arrival[0] == d, (d, r[:, 1])


# ---------------------------------------------------------------------------
# Satellite: W*C materialized once per rollout, not once per tick (HLO pin)
# ---------------------------------------------------------------------------

_N_HLO = 9          # distinctive shape to grep for in the HLO


def _wc_multiplies(text):
    """Region-aware (N,N) multiply counter, shared with the analyzer
    (:mod:`repro.analysis.hlo_rules`) so this suite and the analysis gate
    can never drift apart."""
    from repro.analysis import hlo_rules

    return hlo_rules.wc_multiplies(text, _N_HLO)


def _while_spans(text):
    from repro.analysis import hlo_rules

    return hlo_rules.while_spans(text)


class TestMaskHoisting:
    def _lower(self, fn, *args):
        return jax.jit(fn).lower(*args).as_text()

    def test_frozen_rollout_hoists_wc(self):
        n, ticks = _N_HLO, 12
        p = _params(n, connectivity.sparse_random(n, 0.5, seed=0))
        st0 = SNNState.zeros((), n)
        ext = _ext(n, ticks)
        text = self._lower(
            lambda pp, ss, ee: rollout(pp, ss, ee, ticks), p, st0, ext)
        assert _while_spans(text), "scan did not lower to a while loop?"
        in_loop, hoisted = _wc_multiplies(text)
        assert in_loop == 0, "W*C is materialized inside the scan body"
        assert hoisted >= 1, "hoisted W*C multiply not found in the program"

    def test_forward_layered_hoists_wc(self):
        sizes = [5, 4]
        n = sum(sizes)
        assert n == _N_HLO
        p = _params(n, connectivity.layered(sizes))
        drive = jnp.zeros((n,)).at[:5].set(1.0)
        text = self._lower(
            lambda pp, dd: forward_layered(pp, dd, sizes, n_ticks=6,
                                           time_major=False)[0], p, drive)
        in_loop, hoisted = _wc_multiplies(text)
        assert in_loop == 0 and hoisted >= 1

    def test_control_unhoisted_scan_is_detected(self):
        """The check has teeth: a per-tick W*C recompute IS found in-body."""
        n, ticks = _N_HLO, 12
        p = _params(n, connectivity.sparse_random(n, 0.5, seed=0))
        st0 = SNNState.zeros((), n)
        ext = _ext(n, ticks)

        def unhoisted(pp, ss, ee):
            def body(st, e):
                syn = synaptic_input(st.lif.y, pp, e)   # W*C per tick
                lif2 = lif_step(st.lif, syn, pp.lif)
                return dataclasses.replace(ss, lif=lif2, tick=st.tick + 1), lif2.y
            return jax.lax.scan(body, ss, ee)

        text = self._lower(unhoisted, p, st0, ext)
        in_loop, _ = _wc_multiplies(text)
        assert in_loop >= 1

    def test_learning_rollout_keeps_wc_in_body(self):
        """Mutable weights make W*C loop-variant: it must stay in the body."""
        n, ticks = _N_HLO, 8
        p = _params(n, connectivity.sparse_random(n, 0.5, seed=0))
        pp = PlasticityParams.make("stdp", a_plus=0.1, a_minus=0.1)
        st0 = SNNState.zeros((), n)
        pst0 = PlasticityState.zeros((), n)
        ext = _ext(n, ticks)
        text = self._lower(
            lambda a, b, c, d: learning_rollout(a, b, c, d, ticks,
                                                plasticity=pp),
            p, st0, pst0, ext)
        in_loop, _ = _wc_multiplies(text)
        assert in_loop >= 1


# ---------------------------------------------------------------------------
# Engine surface
# ---------------------------------------------------------------------------

class TestEngineSurface:
    def test_step_wrapper_matches_engine_tick(self):
        from repro.core.network import step
        n = 7
        p = _params(n, connectivity.ring(n), v_th=0.5)
        st0 = SNNState.zeros((), n)
        ext = jnp.zeros((n,)).at[0].set(1.0)
        eng = TickEngine()
        _assert_trees_bitexact(step(st0, p, ext), eng.tick(st0, p, ext))

    def test_learning_requires_plasticity(self):
        n = 4
        p = _params(n, connectivity.ring(n))
        with pytest.raises(ValueError, match="plasticity"):
            TickEngine().learning_rollout(
                p, SNNState.zeros((), n), PlasticityState.zeros((), n),
                None, 3)

    def test_frozen_carry_has_no_learning_leaves(self):
        """Frozen carry pytree == seed SNNState carry (None leaves vanish)."""
        n = 4
        st = SNNState.zeros((), n)
        assert len(jax.tree.leaves(TickCarry(state=st))) == len(
            jax.tree.leaves(st))
