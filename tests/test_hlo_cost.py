"""The trip-count-aware HLO cost parser vs known ground truth.

Also documents the motivating fact: XLA's cost_analysis counts a while
body ONCE, so scanned programs need the corrected parse.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost

jax.config.update("jax_platform_name", "cpu")

D = 128


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


class TestKnownCounts:
    def test_single_matmul(self):
        x = jax.ShapeDtypeStruct((D, D), jnp.float32)
        c = _compile(lambda a, b: a @ b, x, x)
        s = hlo_cost.analyze(c.as_text())
        assert s.flops == pytest.approx(2 * D**3, rel=1e-6)

    def test_scan_multiplies_by_trip_count(self):
        n = 8
        x = jax.ShapeDtypeStruct((D, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((n, D, D), jnp.float32)

        def scanned(x, ws):
            return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

        c = _compile(scanned, x, ws)
        raw = hlo_cost.cost_dict(c.cost_analysis()).get("flops")
        s = hlo_cost.analyze(c.as_text())
        assert s.flops == pytest.approx(n * 2 * D**3, rel=1e-6)
        # the motivating discrepancy: raw counts the body once
        assert raw < s.flops / 2

    def test_nested_scan(self):
        x = jax.ShapeDtypeStruct((D, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((8, D, D), jnp.float32)

        def nested(x, ws):
            def outer(c, w3):
                return jax.lax.scan(lambda cc, w: (cc @ w, None), c, w3)[0], None
            return jax.lax.scan(outer, x, ws.reshape(2, 4, D, D))[0]

        c = _compile(nested, x, ws)
        s = hlo_cost.analyze(c.as_text())
        assert s.flops == pytest.approx(8 * 2 * D**3, rel=1e-6)

    def test_matches_unrolled(self):
        x = jax.ShapeDtypeStruct((D, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((4, D, D), jnp.float32)

        def unrolled(x, ws):
            for i in range(4):
                x = x @ ws[i]
            return x

        def scanned(x, ws):
            return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

        su = hlo_cost.analyze(_compile(unrolled, x, ws).as_text())
        ss = hlo_cost.analyze(_compile(scanned, x, ws).as_text())
        assert su.flops == pytest.approx(ss.flops, rel=1e-6)

    def test_grad_flops_about_3x(self):
        """Backward of y = sum(x @ w) costs ~2 extra matmuls."""
        x = jax.ShapeDtypeStruct((D, D), jnp.float32)

        def fwd(a, b):
            return jnp.sum(a @ b)

        sf = hlo_cost.analyze(_compile(fwd, x, x).as_text())
        sg = hlo_cost.analyze(_compile(jax.grad(fwd, argnums=(0, 1)), x, x).as_text())
        assert 1.9 <= sg.flops / sf.flops <= 3.1


class TestCollectives:
    def test_allgather_bytes_counted_with_trips(self):
        """The sharded tick engine's one collective per tick, scanned:
        the corrected parse must charge the gather once PER TRIP (raw
        cost_analysis counts the while body once -- the same bug the
        flops tests pin, on the bytes axis the roofline sums)."""
        if len(jax.devices()) < 8:
            pytest.skip("needs an 8-way mesh: 8 physical accelerators "
                        "(CPU hosts get 8 simulated devices from "
                        "tests/conftest.py)")
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import make_snn_mesh
        from repro.parallel.snn_sharding import shard_map_fn

        mesh = make_snn_mesh(8)
        width, n_trips = 1024, 16
        x = jax.ShapeDtypeStruct((width,), jnp.float32)

        def once(v):
            return v + jnp.sum(jax.lax.all_gather(v, "model", tiled=True))

        def looped(v):
            # The gather reads the CARRY, so it is loop-variant -- XLA
            # cannot hoist it out of the while body the way the tick
            # engine's hoisted W*C leaves the loop.
            def body(c, _):
                return c + jnp.sum(
                    jax.lax.all_gather(c, "model", tiled=True)), None
            return jax.lax.scan(body, v, None, length=n_trips)[0]

        specs = ((P("model"),), P("model"))
        s1 = hlo_cost.analyze(
            _compile(shard_map_fn(once, mesh, *specs), x).as_text())
        sn = hlo_cost.analyze(
            _compile(shard_map_fn(looped, mesh, *specs), x).as_text())
        per_gather = s1.collective_bytes.get("all-gather", 0.0)
        # operand accounting: each gather reads one per-shard f32 slice
        assert per_gather >= (width // 8) * 4
        assert sn.collective_bytes.get("all-gather", 0.0) == pytest.approx(
            n_trips * per_gather, rel=1e-6)
        assert sn.total_collective_bytes == pytest.approx(
            n_trips * s1.total_collective_bytes, rel=1e-6)

    def test_dot_bytes_positive(self):
        x = jax.ShapeDtypeStruct((D, D), jnp.float32)
        c = _compile(lambda a, b: a @ b, x, x)
        s = hlo_cost.analyze(c.as_text())
        assert s.dot_bytes == pytest.approx(3 * D * D * 4, rel=1e-6)
