"""Dry-run integration: one real cell compiles end-to-end in a subprocess.

The subprocess is required because the dry-run pins
XLA_FLAGS=--xla_force_host_platform_device_count=512 before jax init;
the main test process must keep its single CPU device.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, tmp):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args, "--out", tmp],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)


@pytest.mark.parametrize("multi_pod", [False, True], ids=["16x16", "2x16x16"])
def test_decode_cell_compiles(tmp_path, multi_pod):
    args = ["--arch", "qwen3-0.6b", "--shape", "decode_32k"]
    if multi_pod:
        args.append("--multi-pod")
    r = _run(args, str(tmp_path))
    assert r.returncode == 0, r.stdout + r.stderr
    mesh = "multipod" if multi_pod else "singlepod"
    path = tmp_path / f"qwen3-0.6b__decode_32k__{mesh}.json"
    rec = json.loads(path.read_text())
    assert rec["status"] == "ok"
    assert rec["n_chips"] == (512 if multi_pod else 256)
    assert rec["hlo_cost"]["flops_per_device"] > 0
    # decode must fit the 16 GB v5e budget
    mem = (rec["memory_analysis"]["temp_size_in_bytes"]
           + rec["memory_analysis"]["argument_size_in_bytes"])
    assert mem < 16 * 2**30, f"decode cell uses {mem/2**30:.1f} GB"


def test_rule_overrides_flow_through(tmp_path):
    """Hillclimb overrides reach the lowering (artifact records them)."""
    r = _run(["--arch", "smollm-135m", "--shape", "decode_32k",
              "--rule-overrides", '{"kv_seq": "data"}', "--tag", "t1"],
             str(tmp_path))
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(
        (tmp_path / "smollm-135m__decode_32k__singlepod.t1.json").read_text())
    assert rec["parallel"]["rule_overrides"] == {"kv_seq": "data"}
