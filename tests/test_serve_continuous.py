"""Continuous admission: per-slot refill equals the wave path exactly.

Pins the tentpole contract: chunked per-slot scheduling returns the
same per-request counts, predictions, and learned weights as wave
admission, bit for bit -- while never retracing across slot refills,
mixing dense and event tenants, and handling the admission edges
(zero-tick budgets, unknown tenants, feeder-streamed late arrivals).
"""
from collections import deque

import jax
import numpy as np
import pytest

from repro.launch.serve import (
    ServeRequest, ServeResult, SNNServer, make_demo_requests,
    make_demo_tenants,
)

jax.config.update("jax_platform_name", "cpu")


def _twin_servers(**kw):
    """Two identically-built servers (tenants, seeds, everything) so the
    wave and continuous paths start from the same learned state."""
    kw.setdefault("n_max", 24)
    kw.setdefault("slots", 4)
    kw.setdefault("max_ticks", 12)
    kw.setdefault("event_density", 0.2)
    a, b = SNNServer(**kw), SNNServer(**kw)
    names = make_demo_tenants(a, 8, seed=0)
    assert make_demo_tenants(b, 8, seed=0) == names
    return a, b, names


class TestWaveOracle:
    def test_counts_preds_weights_bit_exact_vs_wave(self):
        sw, sc, names = _twin_servers()
        reqs_w = make_demo_requests(sw, names, 16, seed=1)
        reqs_c = make_demo_requests(sc, names, 16, seed=1)
        sw.serve(reqs_w)
        sc.serve_continuous(reqs_c)
        for a, b in zip(reqs_w, reqs_c):
            assert a.pred == b.pred
            np.testing.assert_array_equal(a.counts, b.counts)
        # Plastic write-back: the learned registers match too.
        for n in names:
            np.testing.assert_array_equal(
                np.asarray(sw.tenants[n].params.w),
                np.asarray(sc.tenants[n].params.w))

    def test_exact_across_chunk_sizes(self):
        sw, _, names = _twin_servers()
        reqs_w = make_demo_requests(sw, names, 8, seed=3)
        sw.serve(reqs_w)
        for chunk in (1, 5, 12):
            sc = SNNServer(n_max=24, slots=4, max_ticks=12,
                           event_density=0.2)
            make_demo_tenants(sc, 8, seed=0)
            reqs_c = make_demo_requests(sc, names, 8, seed=3)
            sc.serve_continuous(reqs_c, chunk_ticks=chunk)
            for a, b in zip(reqs_w, reqs_c):
                assert a.pred == b.pred, f"chunk_ticks={chunk}"
                np.testing.assert_array_equal(a.counts, b.counts)

    def test_mixed_dense_and_event_tenants(self):
        _, sc, names = _twin_servers()
        backends = {sc.tenants[n].backend for n in names}
        assert backends == {"jnp", "event"}
        reqs = make_demo_requests(sc, names, 12, seed=2)
        stats = sc.serve_continuous(reqs)
        assert stats["requests_served"] == 12
        assert set(stats["backends"]) == {"jnp", "event"}


class TestZeroRecompile:
    def test_slot_refills_never_retrace(self):
        _, sc, names = _twin_servers()
        sc.serve_continuous(make_demo_requests(sc, names, 4, seed=9))
        warm = sc.compiles
        stats = sc.serve_continuous(make_demo_requests(sc, names, 20, seed=1))
        assert sc.compiles == warm, "slot refill retraced the chunk program"
        assert stats["recompiles_after_warmup"] == 0

    def test_second_batch_reuses_programs(self):
        _, sc, names = _twin_servers()
        sc.serve_continuous(make_demo_requests(sc, names, 8, seed=1))
        warm = sc.compiles
        sc.serve_continuous(make_demo_requests(sc, names, 8, seed=2))
        assert sc.compiles == warm


class TestAdmissionEdges:
    def test_zero_tick_budget_completes_without_running(self):
        _, sc, names = _twin_servers()
        t = sc.tenants[names[0]]
        r = ServeRequest(rid=0, tenant=names[0],
                         ext=np.zeros((1, t.n_in), np.float32), n_ticks=0)
        stats = sc.serve_continuous([r])
        assert stats["requests_served"] == 1
        assert r.t_done is not None
        np.testing.assert_array_equal(r.counts, np.zeros_like(r.counts))

    def test_unknown_tenant_rejected_and_counted(self):
        _, sc, names = _twin_servers()
        bad = ServeRequest(rid=0, tenant="ghost",
                           ext=np.zeros((2, 4), np.float32), n_ticks=2)
        ok = make_demo_requests(sc, names, 2, seed=1)
        stats = sc.serve_continuous([bad] + ok)
        assert stats["requests_rejected"] == 1
        assert stats["requests_served"] == 2
        assert sc.registry.get("snn_admission_rejections_total").value(
            reason="unknown_tenant") == 1

    def test_feeder_streams_late_arrivals(self):
        _, sc, names = _twin_servers()
        late = deque(make_demo_requests(sc, names, 6, seed=4))
        completed = []
        stats = sc.serve_continuous(
            make_demo_requests(sc, names, 2, seed=5),
            feeder=lambda: late.popleft() if late else None,
            on_complete=completed.append)
        assert stats["requests_served"] == 8
        assert len(completed) == 8
        assert not late

    def test_chunk_ticks_validated(self):
        _, sc, _ = _twin_servers()
        with pytest.raises(ValueError, match="chunk_ticks"):
            sc.serve_continuous([], chunk_ticks=0)
        with pytest.raises(ValueError, match="chunk_ticks"):
            sc.serve_continuous([], chunk_ticks=sc.max_ticks + 1)


class TestStatsSchema:
    def test_same_keys_wave_continuous_and_empty(self):
        sw, sc, names = _twin_servers()
        wave = sw.serve(make_demo_requests(sw, names, 4, seed=1))
        cont = sc.serve_continuous(make_demo_requests(sc, names, 4, seed=1))
        empty = sc.serve_continuous([])
        assert set(wave) == set(cont) == set(empty)
        assert wave["mode"] == "wave"
        assert cont["mode"] == "continuous"
        assert empty["requests_served"] == 0
        assert empty["p99_ttft_s"] == 0.0

    def test_ttft_measured_from_enqueue_not_wave_start(self):
        _, sc, names = _twin_servers()
        reqs = make_demo_requests(sc, names, 2, seed=1)
        t_early = 1.0   # an epoch stamp far in the past
        for r in reqs:
            r.t_submit = t_early
        stats = sc.serve_continuous(reqs)
        # If TTFT were re-stamped at wave/chunk start these would be
        # sub-second; from the caller's enqueue they are epoch-sized.
        assert stats["mean_ttft_s"] > 1e6

    def test_results_are_serve_results(self):
        _, sc, names = _twin_servers()
        stats = sc.serve_continuous(make_demo_requests(sc, names, 3, seed=1))
        assert len(stats["results"]) == 3
        for res in stats["results"]:
            assert isinstance(res, ServeResult)
            assert not res.rejected
            assert res.ttft_s >= 0.0


class TestDeprecatedShims:
    def test_snn_request_shim_warns_and_serves(self):
        from repro.launch.serve import SNNRequest

        _, sc, names = _twin_servers()
        t = sc.tenants[names[0]]
        with pytest.warns(DeprecationWarning, match="SNNRequest"):
            r = SNNRequest(rid=0, tenant=names[0],
                           ext=np.zeros((2, t.n_in), np.float32), n_ticks=2)
        stats = sc.serve_continuous([r])
        assert stats["requests_served"] == 1

    def test_lm_request_shim_warns(self):
        from repro.launch.serve import Request

        with pytest.warns(DeprecationWarning, match="Request"):
            Request(rid=0, prompt=np.zeros((4,), np.int32), max_new=2)
