"""Per-arch smoke tests (deliverable f) + serving consistency + family units.

Every assigned architecture instantiates its REDUCED config and runs one
forward/train step on CPU asserting output shapes + no NaNs, plus a
prefill+decode consistency check against the full forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_bundle
from repro.models import model as M

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B, S, key=KEY, with_targets=True):
    if cfg.family == "audio":
        toks = jax.random.randint(key, (B, S, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    out = {"inputs": toks}
    if with_targets:
        out["targets"] = jnp.roll(toks, -1, axis=1)
    if cfg.family == "vlm":
        out["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_vision), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_bundle(arch).smoke
    params = M.init(cfg, KEY)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    loss, metrics = M.loss_fn(params, cfg, batch, remat="none")
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    grads = jax.grad(lambda p: M.loss_fn(p, cfg, batch, remat="none")[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert jnp.isfinite(leaf.astype(jnp.float32)).all(), f"{arch}: NaN grad"
    logits, _, _ = M.forward(params, cfg, batch["inputs"], mode="train",
                             vision_embeds=batch.get("vision_embeds"), remat="none")
    if cfg.family == "audio":
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_remat_matches(arch):
    """Gradient checkpointing must not change the loss."""
    cfg = get_bundle(arch).smoke
    params = M.init(cfg, KEY)
    batch = _batch(cfg, 2, 8)
    l0, _ = M.loss_fn(params, cfg, batch, remat="none")
    l1, _ = M.loss_fn(params, cfg, batch, remat="block")
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_consistency(arch):
    """Serving path (prefill + decode w/ caches) == teacher-forced forward.

    capacity_factor is raised so MoE dispatch is dropless in both paths
    (capacity drops are a train-time batching artifact; serving headroom
    is the production default -- see ffn.DECODE_CAPACITY_FACTOR)."""
    cfg = dataclasses.replace(get_bundle(arch).smoke, capacity_factor=8.0)
    params = M.init(cfg, KEY)
    B, S = 2, 12
    batch = _batch(cfg, B, S, with_targets=False)
    toks = batch["inputs"]
    logits_full, _, _ = M.forward(
        params, cfg, toks, mode="train",
        vision_embeds=batch.get("vision_embeds"), remat="none")

    caches = M.init_cache(cfg, B, S)
    pre = {"inputs": toks[:, : S - 1]}
    if cfg.family == "vlm":
        pre["vision_embeds"] = batch["vision_embeds"]
    last_pre, caches = M.prefill_fn(params, cfg, pre, caches)
    np.testing.assert_allclose(
        np.asarray(last_pre, np.float32),
        np.asarray(logits_full[:, S - 2], np.float32), rtol=2e-3, atol=2e-3)

    dec = {"token": toks[:, S - 1 :][:, :1] if cfg.family != "audio" else toks[:, S - 1 : S],
           "pos": jnp.asarray(S - 1, jnp.int32)}
    dec["token"] = toks[:, S - 1 : S]
    dlog, _ = M.decode_fn(params, cfg, dec, caches)
    np.testing.assert_allclose(
        np.asarray(dlog, np.float32),
        np.asarray(logits_full[:, S - 1], np.float32), rtol=2e-3, atol=2e-3)


def test_chunked_attention_matches_direct(monkeypatch):
    """Flash-style q-chunked attention == direct attention."""
    from repro.models import attention as A

    cfg = get_bundle("qwen3-0.6b").smoke
    params = M.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size)
    ref, _, _ = M.forward(params, cfg, toks, mode="train", remat="none")
    monkeypatch.setattr(A, "Q_CHUNK", 16)  # force the chunked path
    got, _, _ = M.forward(params, cfg, toks, mode="train", remat="none")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_overflow():
    """Tokens beyond expert capacity are dropped (output = residual only)."""
    from repro.models import ffn as F

    cfg = dataclasses.replace(
        get_bundle("llama4-scout-17b-a16e").smoke,
        n_experts=2, top_k=1, capacity_factor=0.51, n_shared_experts=0)
    specs = F.moe_ffn_specs(cfg)
    from repro.models.common import init_params
    p = init_params(specs, KEY, jnp.float32)
    # Identical tokens route identically -> all 16 claim one expert.
    x = jnp.ones((1, 16, cfg.d_model), jnp.float32)
    y, aux = F.moe_ffn(x, p, cfg)
    # capacity = ceil(1 * 16 * 0.51 / 2) = 5 -> 11 of 16 tokens dropped:
    # their rows pass through unchanged (residual).
    delta = np.abs(np.asarray(y - x)).sum(axis=-1)[0]
    n_processed = int((delta > 1e-6).sum())
    assert n_processed == 5, f"expected 5 processed tokens, got {n_processed}"
    assert jnp.isfinite(aux)


def test_rwkv_decay_in_unit_interval():
    """The data-dependent decay (learned leak) stays in (0, 1)."""
    from repro.models import rwkv as R

    cfg = get_bundle("rwkv6-1.6b").smoke
    params = M.init(cfg, KEY)
    p = params["stages"][0]["layer0"]["mixer"]
    p0 = jax.tree.map(lambda a: a[0], p)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model), jnp.float32)
    # probe the decay computation through the public path: finite outputs
    y, _, _ = R.rwkv_time_mix(x, p0, cfg)
    assert jnp.isfinite(y).all()


def test_mamba_chunked_scan_matches_naive():
    """Nested chunked selective scan == plain per-step reference."""
    from repro.models import ssm as S

    rng = np.random.default_rng(0)
    b, s, di, n = 2, 32, 8, 4
    h0 = jnp.zeros((b, di, n), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (s, b, di)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(s, b, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(s, b, n)).astype(np.float32))
    xc = jnp.asarray(rng.normal(size=(s, b, di)).astype(np.float32))
    a = -jnp.asarray(rng.uniform(0.5, 2.0, (di, n)).astype(np.float32))

    ys, hT = S._selective_scan(h0, dt, bm, cm, xc, a)

    h = np.zeros((b, di, n), np.float32)
    for t in range(s):
        decay = np.exp(np.asarray(dt[t])[..., None] * np.asarray(a))
        h = decay * h + (np.asarray(dt[t]) * np.asarray(xc[t]))[..., None] * np.asarray(bm[t])[:, None, :]
        y_ref = np.einsum("ben,bn->be", h, np.asarray(cm[t]))
        np.testing.assert_allclose(np.asarray(ys[t]), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), h, rtol=1e-4, atol=1e-4)
