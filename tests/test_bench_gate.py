"""check_regression: the bench gate's comparison rules, unit-tested.

The CI job proves the gate end-to-end; these pin the rule semantics so a
refactor can't silently turn "any recompile increase fails" into a
tolerance check.
"""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.check_regression import check_one  # noqa: E402


BASE = {
    "slot_ticks_per_s": 1000.0,
    "recompiles": 0,
    "n256_pallas_fused_exact": True,
    "n256_batch": 16,          # ungated metadata
    "_wall_s": 3.0,            # underscore keys are never gated
}


def test_within_tolerance_passes():
    cur = dict(BASE, slot_ticks_per_s=800.0)
    assert check_one("b", BASE, cur, tolerance=0.25) == []


def test_rate_drop_beyond_tolerance_fails():
    cur = dict(BASE, slot_ticks_per_s=700.0)
    fails = check_one("b", BASE, cur, tolerance=0.25)
    assert len(fails) == 1 and "slot_ticks_per_s" in fails[0]


def test_rate_improvement_passes():
    cur = dict(BASE, slot_ticks_per_s=5000.0)
    assert check_one("b", BASE, cur, tolerance=0.25) == []


def test_any_recompile_increase_fails_regardless_of_tolerance():
    cur = dict(BASE, recompiles=1)
    fails = check_one("b", BASE, cur, tolerance=0.99)
    assert len(fails) == 1 and "recompiles" in fails[0]


def test_exactness_regression_fails():
    cur = dict(BASE, n256_pallas_fused_exact=False)
    fails = check_one("b", BASE, cur, tolerance=0.25)
    assert len(fails) == 1 and "exact" in fails[0]


def test_missing_metric_fails():
    cur = {k: v for k, v in BASE.items() if k != "recompiles"}
    fails = check_one("b", BASE, cur, tolerance=0.25)
    assert len(fails) == 1 and "missing" in fails[0]


def test_ungated_metadata_ignored():
    cur = dict(BASE, n256_batch=999)       # changed, but not a gated key
    del cur["_wall_s"]                     # underscore keys may vanish
    assert check_one("b", BASE, cur, tolerance=0.25) == []


def test_committed_baselines_parse_and_gate_something():
    """The repo's own baselines must stay loadable and non-trivial."""
    bdir = Path(__file__).resolve().parent.parent / "benchmarks" / "baselines"
    files = sorted(bdir.glob("BENCH_*.json"))
    assert files, "no committed baselines"
    for f in files:
        base = json.loads(f.read_text())
        gated = [k for k in base
                 if k.endswith("_per_s") or "recompile" in k
                 or k.endswith("compiles") or k.endswith("_exact")]
        assert gated, f"{f.name} gates nothing"
        recompile_keys = [k for k in base
                          if "recompile" in k or k.endswith("compiles")]
        assert recompile_keys, f"{f.name} has no recompile pin"
        assert all(base[k] == 0 for k in recompile_keys), (
            f"{f.name} baselines a nonzero recompile count")
