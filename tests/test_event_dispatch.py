"""Event-driven dispatch: overflow contract, fan-in gather, Pallas kernel.

No hypothesis dependency (unlike test_kernels.py) so these always run:
they pin the two correctness contracts the event backend lives by --
overflow can never silently drop spikes, and both dispatch strategies
plus the Pallas kernel (interpret mode -- the same body the TPU runs)
are bit-compatible with the dense reference.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity
from repro.core.lif import LIFParams, LIFState
from repro.core.network import SNNParams
from repro.kernels import ops
from repro.kernels.ops import EventFanIn
from repro.kernels.ref import fused_lif_step_ref, spike_matmul_ref

jax.config.update("jax_platform_name", "cpu")


class TestOverflowContract:
    def test_exact_past_k_active_via_dense_fallback(self):
        """Regression: rows spiking MORE than k_active used to be silently
        truncated by the top_k (a wrong synaptic input); the overflow now
        falls back to the dense product and stays exact at any rate."""
        rng = np.random.default_rng(0)
        b, n, k_active = 6, 64, 4
        w = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
        c = jnp.asarray((rng.random((n, n)) < 0.5).astype(np.float32))
        s = np.zeros((b, n), np.float32)
        s[0, : k_active + 3] = 1.0                   # one overflowing row
        s[1:] = (rng.random((b - 1, n)) < 0.8)      # high-rate rows
        got = ops.event_spike_matmul(jnp.asarray(s), w, c, k_active=k_active)
        want = spike_matmul_ref(jnp.asarray(s), w, c)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_unchecked_mode_documents_the_old_bug(self):
        """overflow="unchecked" reproduces the truncation (that is its
        contract); the default must NOT."""
        n, k_active = 32, 2
        w = jnp.ones((n, n))
        c = jnp.ones((n, n))
        s = jnp.ones((1, n))
        want = spike_matmul_ref(s, w, c)
        trunc = ops.event_synaptic_input(s, w * c, k_active=k_active,
                                         overflow="unchecked")
        assert float(trunc[0, 0]) == k_active        # dropped n-k real spikes
        safe = ops.event_synaptic_input(s, w * c, k_active=k_active)
        np.testing.assert_array_equal(np.asarray(safe), np.asarray(want))

    def test_strict_mode_raises_under_checkify(self):
        from jax.experimental import checkify

        rng = np.random.default_rng(0)
        n, k_active = 32, 4
        w = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
        c = jnp.asarray((rng.random((n, n)) < 0.5).astype(np.float32))
        fn = checkify.checkify(
            lambda s: ops.event_spike_matmul(s, w, c, k_active=k_active,
                                             overflow="strict"))
        ok = jnp.zeros((2, n)).at[:, :k_active].set(1.0)
        err, _ = fn(ok)
        err.throw()                                  # no error at low rate
        err, _ = fn(jnp.ones((2, n)))
        with pytest.raises(Exception, match="event dispatch overflow"):
            err.throw()

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError, match="overflow"):
            ops.event_synaptic_input(jnp.ones((1, 8)), jnp.ones((8, 8)),
                                     overflow="typo")


class TestFanInGather:
    def test_matches_dense(self):
        rng = np.random.default_rng(7)
        n = 48
        c_np = np.asarray(connectivity.sparse_random(n, 0.15, seed=7))
        s = jnp.asarray((rng.random((5, n)) < 0.3).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
        wc = w * jnp.asarray(c_np, jnp.float32)
        got = ops.event_synaptic_input(s, wc,
                                       fan_in=EventFanIn.from_dense(c_np))
        want = spike_matmul_ref(s, w, jnp.asarray(c_np, jnp.float32))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_rate_independent_no_overflow(self):
        """The gather path reads topology, not activity: saturating input
        needs no fallback and stays exact."""
        n = 24
        c_np = np.asarray(connectivity.sparse_random(n, 0.2, seed=9))
        rng = np.random.default_rng(9)
        w = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
        wc = w * jnp.asarray(c_np, jnp.float32)
        s = jnp.ones((3, n))
        got = ops.event_synaptic_input(s, wc,
                                       fan_in=EventFanIn.from_dense(c_np))
        want = spike_matmul_ref(s, w, jnp.asarray(c_np, jnp.float32))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def _db_kernel_supported():
    """Probe interpret-mode support for the double-buffered kernel's
    make_async_copy/DMA-semaphore idiom (older jaxlibs can't emulate it
    on CPU -- the TPU lowering is unaffected, so skipping is honest)."""
    try:
        n = 8
        wc = jnp.ones((n, n))
        lif0 = LIFState(v=jnp.zeros((1, n)), r=jnp.zeros((1, n), jnp.int32),
                        y=jnp.zeros((1, n)))
        params = SNNParams(w=wc, c=jnp.ones((n, n)),
                           w_in=jnp.eye(n, dtype=jnp.float32),
                           lif=LIFParams.make(n))
        s = jnp.zeros((1, n)).at[0, 0].set(1.0)
        ops.event_lif_step(lif0, s, params, None, wc, use_kernel=True,
                           kernel="db", interpret=True)
        return True
    except Exception:
        return False


_DB_OK = _db_kernel_supported()
needs_db = pytest.mark.skipif(
    not _DB_OK, reason="interpret-mode async-copy unsupported by this jaxlib")


def _case(b, n, *, density=0.3, seed=None):
    rng = np.random.default_rng(n + b if seed is None else seed)
    c = connectivity.sparse_random(n, density, seed=n)
    params = SNNParams(
        w=jnp.asarray(rng.uniform(0, 1, (n, n)), jnp.float32),
        c=jnp.asarray(c, jnp.float32),
        w_in=jnp.eye(n, dtype=jnp.float32),
        lif=LIFParams.make(n, v_th=0.8, leak=0.2, r_ref=1))
    lif0 = LIFState(
        v=jnp.asarray(rng.normal(size=(b, n)), jnp.float32),
        r=jnp.asarray(rng.integers(0, 2, (b, n)), jnp.int32),
        y=jnp.zeros((b, n), jnp.float32))
    return rng, params, params.w * params.c, lif0


class TestEventKernel:
    @pytest.mark.parametrize("mode", ["fixed_leak", "euler"])
    @pytest.mark.parametrize("b,n,with_ext", [(4, 74, True), (3, 139, False),
                                              (8, 256, True)])
    def test_kernel_matches_jnp_path(self, mode, b, n, with_ext):
        """The Pallas event kernel (interpret mode -- the same body the TPU
        runs) is bit-exact vs the pure-jnp event reference, ragged N incl."""
        rng, params, wc, lif0 = _case(b, n)
        s = jnp.asarray((rng.random((b, n)) < 0.1).astype(np.float32))
        ext = jnp.asarray((rng.random((b, n)) < 0.2).astype(np.float32)) \
            if with_ext else None
        # Both sides jitted: XLA's FMA contraction decisions must match
        # for a bitwise comparison (eager-vs-jit differs in the last ulp
        # of the euler multiply-add chain).
        want = jax.jit(lambda l, sp, e: ops.event_lif_step(
            l, sp, params, e, wc, mode=mode, use_kernel=False))(lif0, s, ext)
        got = jax.jit(lambda l, sp, e: ops.event_lif_step(
            l, sp, params, e, wc, mode=mode, use_kernel=True,
            interpret=True))(lif0, s, ext)
        for name in ("v", "r", "y"):
            np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                          np.asarray(getattr(want, name)),
                                          err_msg=name)

    def test_kernel_overflow_falls_back_dense(self):
        """Kernel path at saturating rate: the cond takes the dense fused
        kernel, so no spike is ever dropped."""
        b, n = 4, 64
        _, params, wc, _ = _case(b, n, density=0.5)
        lif0 = LIFState(v=jnp.zeros((b, n)), r=jnp.zeros((b, n), jnp.int32),
                        y=jnp.zeros((b, n)))
        s = jnp.ones((b, n))                 # every presynaptic neuron fires
        got = ops.event_lif_step(lif0, s, params, None, wc, k_active=4,
                                 use_kernel=True, interpret=True)
        want = fused_lif_step_ref(
            s, params.w, params.c, lif0.v, lif0.r, None,
            params.lif.v_th, params.lif.leak, params.lif.r_ref,
            params.lif.gain, params.lif.i_bias, params.lif.v_reset)
        np.testing.assert_allclose(np.asarray(got.v), np.asarray(want.v),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(got.y), np.asarray(want.y))

    def test_kernel_path_is_inference_only(self):
        b, n = 2, 16
        _, params, wc, lif0 = _case(b, n)
        with pytest.raises(ValueError, match="inference-only"):
            ops.event_lif_step(lif0, jnp.zeros((b, n)), params, None, wc,
                               surrogate=True, use_kernel=True,
                               interpret=True)

    def test_unknown_kernel_variant_rejected(self):
        b, n = 2, 16
        _, params, wc, lif0 = _case(b, n)
        with pytest.raises(ValueError, match="'db' or 'grid'"):
            ops.event_lif_step(lif0, jnp.zeros((b, n)), params, None, wc,
                               use_kernel=True, kernel="typo",
                               interpret=True)


@needs_db
class TestDoubleBufferedKernel:
    """The compact-spike-list kernel ("db"): per-row counts bound the DMA
    loop, a two-slot VMEM buffer overlaps row k+1's copy with row k's
    accumulate -- and none of that may change a single bit vs the grid
    kernel or the jnp reference."""

    @pytest.mark.parametrize("mode", ["fixed_leak", "euler"])
    @pytest.mark.parametrize("b,n,with_ext", [(4, 74, True), (3, 139, False),
                                              (8, 256, True)])
    def test_db_matches_jnp_path(self, mode, b, n, with_ext):
        rng, params, wc, lif0 = _case(b, n)
        s = jnp.asarray((rng.random((b, n)) < 0.1).astype(np.float32))
        ext = jnp.asarray((rng.random((b, n)) < 0.2).astype(np.float32)) \
            if with_ext else None
        want = jax.jit(lambda l, sp, e: ops.event_lif_step(
            l, sp, params, e, wc, mode=mode, use_kernel=False))(lif0, s, ext)
        got = jax.jit(lambda l, sp, e: ops.event_lif_step(
            l, sp, params, e, wc, mode=mode, use_kernel=True, kernel="db",
            interpret=True))(lif0, s, ext)
        for name in ("v", "r", "y"):
            np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                          np.asarray(getattr(want, name)),
                                          err_msg=name)

    def test_db_matches_grid_kernel(self):
        """Same spike list, two steering mechanisms (counts-bounded DMA
        loop vs sentinel-masked grid): bit-identical outputs."""
        b, n = 5, 96
        rng, params, wc, lif0 = _case(b, n)
        s = jnp.asarray((rng.random((b, n)) < 0.15).astype(np.float32))
        ext = jnp.asarray(rng.normal(size=(b, n)).astype(np.float32))
        outs = {}
        for kname in ("db", "grid"):
            outs[kname] = jax.jit(lambda l, sp, e, _k=kname: ops.event_lif_step(
                l, sp, params, e, wc, use_kernel=True, kernel=_k,
                interpret=True))(lif0, s, ext)
        for name in ("v", "r", "y"):
            np.testing.assert_array_equal(
                np.asarray(getattr(outs["db"], name)),
                np.asarray(getattr(outs["grid"], name)), err_msg=name)

    def test_db_zero_spike_rows(self):
        """Rows with count==0 must skip the DMA loop entirely and still
        run the LIF epilogue (leak/refractory continue on silent input)."""
        b, n = 4, 64
        rng, params, wc, lif0 = _case(b, n)
        s = np.zeros((b, n), np.float32)
        s[1, 3] = 1.0                        # rows 0, 2, 3 fully silent
        got = ops.event_lif_step(lif0, jnp.asarray(s), params, None, wc,
                                 use_kernel=True, kernel="db", interpret=True)
        want = ops.event_lif_step(lif0, jnp.asarray(s), params, None, wc,
                                  use_kernel=False)
        for name in ("v", "r", "y"):
            np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                          np.asarray(getattr(want, name)),
                                          err_msg=name)

    def test_db_ragged_counts(self):
        """Every row a different live count (0..k_active), sentinel tail
        untouched: the per-row bound is data, not shape."""
        b, n, k = 6, 80, 8
        rng, params, wc, lif0 = _case(b, n, seed=3)
        s = np.zeros((b, n), np.float32)
        for row in range(b):
            cols = rng.choice(n, size=row, replace=False)
            s[row, cols] = 1.0               # row r spikes exactly r rows
        got = ops.event_lif_step(lif0, jnp.asarray(s), params, None, wc,
                                 k_active=k, use_kernel=True, kernel="db",
                                 interpret=True)
        want = ops.event_lif_step(lif0, jnp.asarray(s), params, None, wc,
                                  k_active=k, use_kernel=False)
        for name in ("v", "r", "y"):
            np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                          np.asarray(getattr(want, name)),
                                          err_msg=name)

    def test_db_overflow_falls_back_dense(self):
        b, n = 3, 64
        _, params, wc, _ = _case(b, n, density=0.5)
        lif0 = LIFState(v=jnp.zeros((b, n)), r=jnp.zeros((b, n), jnp.int32),
                        y=jnp.zeros((b, n)))
        s = jnp.ones((b, n))
        got = ops.event_lif_step(lif0, s, params, None, wc, k_active=4,
                                 use_kernel=True, kernel="db", interpret=True)
        want = fused_lif_step_ref(
            s, params.w, params.c, lif0.v, lif0.r, None,
            params.lif.v_th, params.lif.leak, params.lif.r_ref,
            params.lif.gain, params.lif.i_bias, params.lif.v_reset)
        np.testing.assert_allclose(np.asarray(got.v), np.asarray(want.v),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(got.y), np.asarray(want.y))
