"""System-level invariants across the whole package."""

import jax

jax.config.update("jax_platform_name", "cpu")

from repro.configs import ASSIGNED_ARCHS, get_bundle
from repro.configs.base import applicable_shapes


def test_assigned_configs_match_spec():
    """Every assigned architecture carries the exact published dims."""
    spec = {
        "llama4-scout-17b-a16e": dict(n_layers=48, d_model=5120, n_heads=40,
                                      n_kv_heads=8, d_ff=8192, vocab_size=202048,
                                      n_experts=16, top_k=1),
        "moonshot-v1-16b-a3b": dict(n_layers=48, d_model=2048, n_heads=16,
                                    n_kv_heads=16, d_ff=1408, vocab_size=163840,
                                    n_experts=64, top_k=6),
        "qwen3-0.6b": dict(n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
                           d_ff=3072, vocab_size=151936, qk_norm=True),
        "starcoder2-15b": dict(n_layers=40, d_model=6144, n_heads=48,
                               n_kv_heads=4, d_ff=24576, vocab_size=49152),
        "smollm-135m": dict(n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
                            d_ff=1536, vocab_size=49152),
        "smollm-360m": dict(n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
                            d_ff=2560, vocab_size=49152),
        "jamba-1.5-large-398b": dict(n_layers=72, d_model=8192, n_heads=64,
                                     n_kv_heads=8, d_ff=24576, vocab_size=65536,
                                     n_experts=16, top_k=2),
        "llama-3.2-vision-90b": dict(n_layers=100, d_model=8192, n_heads=64,
                                     n_kv_heads=8, d_ff=28672, vocab_size=128256),
        "rwkv6-1.6b": dict(n_layers=24, d_model=2048, d_ff=7168, vocab_size=65536),
        "musicgen-large": dict(n_layers=48, d_model=2048, n_heads=32,
                               n_kv_heads=32, d_ff=8192, vocab_size=2048,
                               n_codebooks=4),
    }
    for arch, expect in spec.items():
        cfg = get_bundle(arch).model
        for k, v in expect.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


def test_param_counts_in_published_ballpark():
    """Total parameter counts land near the names on the tin."""
    from repro.models import model as M

    expectations = {  # (arch, low, high) in billions
        "llama4-scout-17b-a16e": (90, 120),   # 17B active / ~109B total
        "moonshot-v1-16b-a3b": (25, 32),  # assigned spec w/o MLA compression
        "qwen3-0.6b": (0.55, 0.65),
        "starcoder2-15b": (14, 17),
        "smollm-135m": (0.11, 0.18),
        "smollm-360m": (0.3, 0.45),
        "jamba-1.5-large-398b": (330, 440),
        "llama-3.2-vision-90b": (80, 100),
        "rwkv6-1.6b": (1.3, 2.0),
        "musicgen-large": (2.5, 4.0),
    }
    for arch, (lo, hi) in expectations.items():
        n = M.n_params(get_bundle(arch).model) / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo}, {hi}]"


def test_long_context_skip_rule():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §5)."""
    for arch in ASSIGNED_ARCHS:
        cfg = get_bundle(arch).model
        shapes = applicable_shapes(cfg)
        if arch in ("rwkv6-1.6b", "jamba-1.5-large-398b"):
            assert "long_500k" in shapes, arch
        else:
            assert "long_500k" not in shapes, arch
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)


def test_dryrun_matrix_has_32_baseline_cells():
    """8 full-attention archs x 3 shapes + 2 sub-quadratic x 4 = 32 LM cells
    per mesh (the assignment's 40-cell grid minus the 8 documented
    long_500k skips)."""
    total = sum(len(applicable_shapes(get_bundle(a).model)) for a in ASSIGNED_ARCHS)
    assert total == 32


def test_tp_divisibility_invariants():
    """Every model-axis-sharded parameter dim divides the 16-way TP width."""
    from repro.models import model as M
    from repro.models.common import is_spec
    from repro.parallel.sharding import BASE_RULES

    for arch in ASSIGNED_ARCHS:
        cfg = get_bundle(arch).model
        specs = jax.tree.leaves(M.specs(cfg), is_leaf=is_spec)
        for s in specs:
            for dim, ax in zip(s.shape, s.axes):
                if ax is None:
                    continue
                if BASE_RULES.get(ax) == "model":
                    assert dim % 16 == 0, f"{arch}: axis {ax} dim {dim} !% 16"
