"""End-to-end driver tests: training loop (ckpt/resume) + wave serving."""

import jax

jax.config.update("jax_platform_name", "cpu")


def test_train_driver_loss_decreases(tmp_path):
    from repro.launch import train as train_mod

    losses = train_mod.main([
        "--arch", "smollm-135m", "--smoke", "--steps", "30",
        "--seq-len", "32", "--global-batch", "4",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
        "--log-every", "100", "--peak-lr", "1e-3",
    ])
    assert len(losses) == 30
    assert losses[-1] < losses[0], f"{losses[0]} -> {losses[-1]}"


def test_train_driver_resumes_from_checkpoint(tmp_path):
    from repro import checkpoint as ckpt
    from repro.launch import train as train_mod

    args = ["--arch", "smollm-135m", "--smoke", "--steps", "10",
            "--seq-len", "16", "--global-batch", "2",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
            "--log-every", "100"]
    train_mod.main(args)
    assert ckpt.latest_step(str(tmp_path)) == 10
    # extending the run resumes from step 10 (3 more steps, not 13)
    args[args.index("10")] = "13"
    losses = train_mod.main(args)
    assert len(losses) == 3


def test_serve_driver_all_requests_complete():
    from repro.launch import serve as serve_mod

    stats = serve_mod.main([
        "--arch", "smollm-135m", "--smoke", "--requests", "5",
        "--max-new", "4", "--slots", "2", "--max-len", "32"])
    assert stats["n_requests"] == 5
    assert all(len(v) >= 4 for v in stats["outputs"].values())
    assert stats["tokens_per_s"] > 0


def test_serve_greedy_deterministic():
    from repro.launch import serve as serve_mod

    s1 = serve_mod.main(["--arch", "smollm-135m", "--smoke", "--requests", "2",
                         "--max-new", "4", "--slots", "2", "--max-len", "32"])
    s2 = serve_mod.main(["--arch", "smollm-135m", "--smoke", "--requests", "2",
                         "--max-new", "4", "--slots", "2", "--max-len", "32"])
    assert s1["outputs"] == s2["outputs"]
