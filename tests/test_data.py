"""Data pipeline: determinism, resumability, encoders' host-side pipeline."""
import numpy as np

from repro.configs import get_bundle
from repro.configs.base import ShapeConfig
from repro.data import iris, mnist, pipeline, synthetic


class TestSynthetic:
    def test_deterministic_per_step(self):
        a = synthetic.token_batch(7, 3, global_batch=4, seq_len=16, vocab_size=100)
        b = synthetic.token_batch(7, 3, global_batch=4, seq_len=16, vocab_size=100)
        np.testing.assert_array_equal(a["inputs"], b["inputs"])

    def test_steps_differ(self):
        a = synthetic.token_batch(7, 3, global_batch=4, seq_len=16, vocab_size=100)
        b = synthetic.token_batch(7, 4, global_batch=4, seq_len=16, vocab_size=100)
        assert not np.array_equal(a["inputs"], b["inputs"])

    def test_targets_are_shifted_inputs(self):
        a = synthetic.token_batch(0, 0, global_batch=2, seq_len=8, vocab_size=50)
        np.testing.assert_array_equal(a["inputs"][:, 1:], a["targets"][:, :-1])

    def test_vocab_range(self):
        a = synthetic.token_batch(1, 1, global_batch=8, seq_len=64, vocab_size=37)
        assert a["inputs"].min() >= 0 and a["inputs"].max() < 37

    def test_codebooks(self):
        a = synthetic.token_batch(0, 0, global_batch=2, seq_len=8,
                                  vocab_size=16, n_codebooks=4)
        assert a["inputs"].shape == (2, 8, 4)

    def test_resume_exactness(self):
        """Restart-from-step-k reproduces the exact same batch sequence."""
        st = pipeline.PipelineState(seed=5, step=0)
        batches = []
        cfg = get_bundle("smollm-135m").smoke
        shape = ShapeConfig("t", "train", 8, 4)
        for _ in range(5):
            batches.append(pipeline.make_batch(cfg, shape, st))
            st = pipeline.advance(st)
        st2 = pipeline.PipelineState.from_dict({"seed": 5, "step": 3})
        again = pipeline.make_batch(cfg, shape, st2)
        np.testing.assert_array_equal(again["inputs"], batches[3]["inputs"])


class TestIris:
    def test_shapes_and_classes(self):
        x, y = iris.load()
        assert x.shape == (150, 4) and y.shape == (150,)
        assert set(np.unique(y)) == {0, 1, 2}

    def test_normalize_range(self):
        x, _ = iris.load()
        xn = iris.normalize(x)
        assert xn.min() >= 0.0 and xn.max() <= 1.0

    def test_setosa_separable_by_petal_length(self):
        """The structure the paper's tiny net exploits must exist."""
        x, y = iris.load()
        setosa_pl = x[y == 0, 2]
        other_pl = x[y != 0, 2]
        assert setosa_pl.max() < other_pl.min() + 0.5


class TestMnist8x8:
    def test_shapes(self):
        x, y = mnist.load(n_per_class=10)
        assert x.shape == (100, 8, 8)
        assert set(np.unique(y)) == set(range(10))

    def test_binarize_spikes(self):
        x, _ = mnist.load(n_per_class=5)
        s = mnist.to_spikes(x)
        assert s.shape == (50, 64)
        assert set(np.unique(s)).issubset({0.0, 1.0})

    def test_templates_distinct(self):
        """Every pair of class templates differs in >= 6 pixels."""
        t = mnist.TEMPLATES.reshape(10, 64)
        for i in range(10):
            for j in range(i + 1, 10):
                assert np.abs(t[i] - t[j]).sum() >= 6, (i, j)
