"""repro.analysis: teeth + false-positive resistance.

Every rule class must FIRE on a deliberately broken fixture (a gate that
cannot fail is not a gate) and must PASS the sanctioned look-alikes
(register-boundary u8 decode, telemetry-on programs, the event knee's
``lax.cond`` arms) -- a gate that cries wolf gets disabled.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import check as check_mod
from repro.analysis import jaxpr_rules, pallas_rules, programs, static_rules
from repro.kernels.launch_spec import KernelLaunch, Operand

F32 = jnp.float32


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# Rule class 1: hot-loop purity
# ---------------------------------------------------------------------------

class TestPurityTeeth:
    def test_callback_inside_scan_body_fires(self):
        def prog(x):
            def body(c, _):
                jax.debug.print("tick {c}", c=c)
                return c + 1.0, c
            return jax.lax.scan(body, x, None, length=3)

        cj = jaxpr_rules.closed_jaxpr_of(prog, jnp.zeros(()))
        assert "purity.callback_in_loop" in _rules(
            jaxpr_rules.check_hot_loop_purity(cj, "fixture"))

    def test_pure_callback_outside_loop_fires(self):
        def prog(x):
            return jax.pure_callback(
                lambda a: np.asarray(a),
                jax.ShapeDtypeStruct((), F32), x)

        cj = jaxpr_rules.closed_jaxpr_of(prog, jnp.zeros((), F32))
        assert "purity.callback" in _rules(
            jaxpr_rules.check_hot_loop_purity(cj, "fixture"))

    def test_clean_scan_passes(self):
        def prog(x):
            def body(c, _):
                return c * 0.5 + 1.0, c
            return jax.lax.scan(body, x, None, length=3)

        cj = jaxpr_rules.closed_jaxpr_of(prog, jnp.zeros(()))
        assert jaxpr_rules.check_hot_loop_purity(cj, "fixture") == []


# ---------------------------------------------------------------------------
# Rule class 2: dtype discipline
# ---------------------------------------------------------------------------

class TestDtypeTeeth:
    def test_u8_upcast_outside_sanctioned_scope_fires(self):
        def prog(b):
            return b.astype(F32) * 2.0

        cj = jaxpr_rules.closed_jaxpr_of(prog, jnp.zeros((4,), jnp.uint8))
        assert "dtype.u8_upcast" in _rules(
            jaxpr_rules.check_dtype_discipline(cj, "fixture"))

    def test_u8_upcast_under_decode_scope_passes(self):
        """The register-decode boundary is exactly where u8 widens."""
        def prog(b):
            with jax.named_scope("decode_u8"):
                w = b.astype(F32)
            return w * 2.0

        cj = jaxpr_rules.closed_jaxpr_of(prog, jnp.zeros((4,), jnp.uint8))
        assert jaxpr_rules.check_dtype_discipline(cj, "fixture") == []

    def test_f64_fires_when_x64_enabled(self):
        with jax.experimental.enable_x64():
            cj = jaxpr_rules.closed_jaxpr_of(
                lambda x: x + 1.0, jnp.zeros((2,), jnp.float64))
        rules = _rules(jaxpr_rules.check_dtype_discipline(cj, "fixture"))
        assert rules & {"dtype.x64_input", "dtype.x64"}


# ---------------------------------------------------------------------------
# Rule class 3: hoist contract (both directions)
# ---------------------------------------------------------------------------

_N = 6


def _unhoisted(w, c, x):
    def body(carry, _):
        wc = w * c                       # (n, n) product per tick: the bug
        return carry @ wc, None
    return jax.lax.scan(body, x, None, length=3)


def _hoisted(w, c, x):
    wc = w * c                           # once per rollout
    def body(carry, _):
        return carry @ wc, None
    return jax.lax.scan(body, x, None, length=3)


class TestHoistTeeth:
    def _args(self):
        rng = np.random.default_rng(0)
        return (jnp.asarray(rng.random((_N, _N)), F32),
                jnp.asarray(rng.random((_N, _N)), F32),
                jnp.zeros((_N,), F32))

    def test_frozen_expectation_catches_in_loop_recompute(self):
        cj = jaxpr_rules.closed_jaxpr_of(_unhoisted, *self._args())
        rules = _rules(jaxpr_rules.check_hoist(
            cj, "fixture", n=_N, expect=jaxpr_rules.HOIST_HOISTED))
        assert "hoist.wc_in_loop" in rules
        assert "hoist.wc_missing" in rules   # nothing hoisted either

    def test_learning_expectation_catches_stale_hoist(self):
        cj = jaxpr_rules.closed_jaxpr_of(_hoisted, *self._args())
        assert "hoist.wc_not_in_loop" in _rules(jaxpr_rules.check_hoist(
            cj, "fixture", n=_N, expect=jaxpr_rules.HOIST_IN_LOOP))

    def test_matching_expectations_pass(self):
        args = self._args()
        cj_h = jaxpr_rules.closed_jaxpr_of(_hoisted, *args)
        cj_u = jaxpr_rules.closed_jaxpr_of(_unhoisted, *args)
        assert jaxpr_rules.check_hoist(
            cj_h, "fixture", n=_N, expect=jaxpr_rules.HOIST_HOISTED) == []
        assert jaxpr_rules.check_hoist(
            cj_u, "fixture", n=_N, expect=jaxpr_rules.HOIST_IN_LOOP) == []


# ---------------------------------------------------------------------------
# Rule class 4: recompile hazards (statics)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _MutableStatic:
    # Hashes (by identity) yet is freely mutable -- the sneaky case a
    # plain hash() probe cannot catch.
    knobs: object


class _UnstableHash:
    def __eq__(self, other):
        return isinstance(other, _UnstableHash)

    def __hash__(self):                  # id-derived: new instance, new hash
        return id(self)


@dataclasses.dataclass(frozen=True)
class _HashablePlanFixture:
    """A DispatchPlan look-alike that (wrongly) hashes."""
    strategy: str = "fan_in"

    def engine_kwargs(self):
        return {"backend": "event", "event_dispatch": self.strategy}


class TestStaticTeeth:
    def test_unhashable_static_fires(self):
        assert "static.unhashable" in _rules(
            static_rules.check_hashable_static(
                {"k": 1}, "fixture", name="opts"))

    def test_mutable_field_in_frozen_static_fires(self):
        class _Knobs:   # hashable by identity, mutable in every other way
            pass

        assert "static.mutable_field" in _rules(
            static_rules.check_hashable_static(
                _MutableStatic(knobs=_Knobs()), "fixture", name="opts"))

    def test_plainly_unhashable_static_fires(self):
        assert "static.unhashable" in _rules(
            static_rules.check_hashable_static(
                _MutableStatic(knobs=[1, 2]), "fixture", name="opts"))

    def test_unstable_hash_across_instances_fires(self):
        assert "static.unstable_hash" in _rules(
            static_rules.check_hash_stability(
                _UnstableHash, "fixture", name="opts"))

    def test_unknown_static_argname_fires(self):
        def fn(a, *, mode="x"):
            return a

        assert "static.unknown_argname" in _rules(
            static_rules.check_static_argnames(
                fn, ("mode", "nonexistent"), "fixture", name="fn"))

    def test_hashable_dispatch_plan_fires(self):
        """The plan carries arrays; a hashable plan would silently become
        a jit cache key and retrace per instance."""
        assert "static.plan_hashable" in _rules(
            static_rules.check_dispatch_plan(
                _HashablePlanFixture(), "fixture"))

    def test_engine_options_pass(self):
        from repro.core.engine import EngineOptions

        make = lambda: EngineOptions(backend="event", event_k_active=4)
        assert static_rules.check_hashable_static(
            make(), "fixture", name="EngineOptions") == []
        assert static_rules.check_hash_stability(
            make, "fixture", name="EngineOptions") == []

    def test_real_dispatch_plan_passes(self):
        assert static_rules.check_dispatch_plan(
            programs.demo_dispatch_plan(), "fixture") == []


# ---------------------------------------------------------------------------
# Rule class 5: Pallas kernel lint
# ---------------------------------------------------------------------------

def _tiny_launch(**overrides):
    base = dict(
        name="fixture",
        grid=(2,),
        inputs=(Operand("x", (256, 128), F32, (128, 128),
                        lambda i: (i, 0)),),
        outputs=(Operand("y", (256, 128), F32, (128, 128),
                         lambda i: (i, 0)),),
    )
    base.update(overrides)
    return KernelLaunch(**base)


class TestPallasTeeth:
    def test_oob_index_map_fires(self):
        # Block row i+1 walks one block past the operand's 256 rows.
        launch = _tiny_launch(inputs=(
            Operand("x", (256, 128), F32, (128, 128),
                    lambda i: (i + 1, 0)),))
        assert "pallas.oob" in _rules(
            pallas_rules.check_index_maps(launch, "fixture"))

    def test_sentinel_row_prefetch_is_in_bounds(self):
        """The event kernel's worst case -- every index the sentinel row
        K -- must lint clean (the (K+1, N) operand exists for it)."""
        launch = _tiny_launch(
            inputs=(Operand("w", (9, 128), F32, (1, 128),
                            lambda i, s: (s[i], 0)),),
            outputs=(Operand("y", (256, 128), F32, (128, 128),
                             lambda i, s: (i, 0)),),
            prefetch_example=(np.full((2,), 8, np.int32),),
            num_scalar_prefetch=1)
        assert pallas_rules.check_index_maps(launch, "fixture") == []

    def test_vmem_budget_fires(self):
        launch = _tiny_launch(inputs=(
            Operand("x", (8192, 8192), F32, (4096, 4096),
                    lambda i: (0, 0)),))
        assert "pallas.vmem" in _rules(
            pallas_rules.check_vmem(launch, "fixture"))

    def test_alias_shape_mismatch_fires(self):
        launch = _tiny_launch(
            inputs=(Operand("x", (256, 128), F32, (128, 128),
                            lambda i: (i, 0)),
                    Operand("z", (64, 64), F32, (64, 64),
                            lambda i: (0, 0))),
            input_output_aliases={1: 0})
        assert "pallas.alias" in _rules(
            pallas_rules.check_aliasing(launch, "fixture"))

    @pytest.mark.parametrize("ops,rule", [
        ([("start", 0, 0), ("use", 0, 0)], "pallas.dma.use_before_wait"),
        ([("wait", 0, 0)], "pallas.dma.wait_without_start"),
        ([("start", 0, 0), ("start", 0, 1)], "pallas.dma.start_busy"),
        ([("start", 0, 0)], "pallas.dma.dangling"),
    ])
    def test_dma_protocol_violations_fire(self, ops, rule):
        bad, _ = pallas_rules.simulate_dma_schedule(ops)
        assert rule in {r for r, _ in bad}

    def test_dropped_spike_fires(self):
        def schedule(nb):   # waits on every copy but never uses spike 1
            ops = []
            for k in range(nb):
                ops += [("start", k % 2, k), ("wait", k % 2, k)]
                if k != 1:
                    ops.append(("use", k % 2, k))
            return ops

        launch = _tiny_launch(dma_schedule=schedule)
        assert "pallas.dma.missing_spike" in _rules(
            pallas_rules.check_dma_schedule(launch, "fixture"))

    def test_quiet_row_dma_fires(self):
        def schedule(nb):   # unconditional warmup: DMA on silent rows
            ops = [("start", 0, 0), ("wait", 0, 0)]
            for k in range(nb):
                ops.append(("use", 0, k) if k == 0
                           else ("start", k % 2, k))
                if k > 0:
                    ops += [("wait", k % 2, k), ("use", k % 2, k)]
            return ops

        launch = _tiny_launch(dma_schedule=schedule)
        assert "pallas.dma.quiet_row" in _rules(
            pallas_rules.check_dma_schedule(launch, "fixture"))

    def test_shipped_db_schedule_passes(self):
        from repro.kernels.event_dispatch import db_dma_schedule

        launch = _tiny_launch(dma_schedule=db_dma_schedule)
        assert pallas_rules.check_dma_schedule(launch, "fixture") == []


# ---------------------------------------------------------------------------
# Rule class 6: sharding (no fabric-sized collective in the hot loop)
# ---------------------------------------------------------------------------

class TestShardingTeeth:
    """The sharded engine's per-tick collective moves spikes ((B, n)); a
    program that all-gathers the WEIGHT operand per tick must fire."""

    N = 8

    def _mesh(self):
        from repro.launch.mesh import make_snn_mesh

        return make_snn_mesh(1)

    def test_w_gather_in_loop_fires(self):
        from repro.analysis import sharding_rules
        from repro.parallel.snn_sharding import shard_map_fn

        n, mesh = self.N, self._mesh()
        from jax.sharding import PartitionSpec as P

        def body(w_local, s):
            def tick(c, _):
                # THE regression: replicate the whole weight matrix
                # every iteration instead of exchanging spikes.
                w_full = jax.lax.all_gather(
                    w_local, "model", axis=1, tiled=True)
                return c + s @ w_full, None
            out, _ = jax.lax.scan(tick, jnp.zeros((n,), F32), None, length=3)
            return out

        fn = shard_map_fn(body, mesh, (P(None, "model"), P()), P())
        cj = jaxpr_rules.closed_jaxpr_of(
            fn, jnp.zeros((n, n), F32), jnp.zeros((n,), F32))
        assert "sharding.w_gather_in_loop" in _rules(
            sharding_rules.check_no_w_gather_in_loop(cj, "fixture", n=n))

    def test_spike_gather_in_loop_passes(self):
        from repro.analysis import sharding_rules
        from repro.parallel.snn_sharding import shard_map_fn

        n, mesh = self.N, self._mesh()
        from jax.sharding import PartitionSpec as P

        def body(w_local, s_local):
            def tick(c, _):
                # The sanctioned exchange: (n,) spikes, n-fold smaller.
                s_full = jax.lax.all_gather(
                    s_local, "model", axis=0, tiled=True)
                return c + s_full @ w_local, None
            out, _ = jax.lax.scan(
                tick, jnp.zeros((w_local.shape[1],), F32), None, length=3)
            return out

        fn = shard_map_fn(body, mesh, (P(None, "model"), P("model")),
                          P("model"))
        cj = jaxpr_rules.closed_jaxpr_of(
            fn, jnp.zeros((n, n), F32), jnp.zeros((n,), F32))
        assert sharding_rules.check_no_w_gather_in_loop(
            cj, "fixture", n=n) == []

    def test_hoisted_w_gather_outside_loop_passes(self):
        from repro.analysis import sharding_rules
        from repro.parallel.snn_sharding import shard_map_fn

        n, mesh = self.N, self._mesh()
        from jax.sharding import PartitionSpec as P

        def body(w_local, s):
            # Once per rollout (e.g. a placement/premask step), not per
            # tick: outside every loop body, so it passes.
            w_full = jax.lax.all_gather(w_local, "model", axis=1, tiled=True)

            def tick(c, _):
                return c + s @ w_full, None
            out, _ = jax.lax.scan(tick, jnp.zeros((n,), F32), None, length=3)
            return out

        fn = shard_map_fn(body, mesh, (P(None, "model"), P()), P())
        cj = jaxpr_rules.closed_jaxpr_of(
            fn, jnp.zeros((n, n), F32), jnp.zeros((n,), F32))
        assert sharding_rules.check_no_w_gather_in_loop(
            cj, "fixture", n=n) == []

    def test_mesh_carrying_options_pass_static_rules(self):
        from repro.core.engine import EngineOptions

        make = lambda: EngineOptions(mesh=self._mesh())
        assert static_rules.check_hashable_static(make(), "fixture") == []
        assert static_rules.check_hash_stability(make, "fixture") == []


# ---------------------------------------------------------------------------
# False-positive resistance on the shipped registry + CLI plumbing
# ---------------------------------------------------------------------------

class TestShippedPrograms:
    def _check(self, name):
        report = check_mod.run([name], include_static=False)
        assert report.ok(), report.table()

    def test_event_knee_cond_arms_pass_clean(self):
        # tick/event/frozen/* carries event_knee: both lax.cond arms (the
        # dense fallback included) are part of the analyzed program.
        self._check("tick/event/frozen/notelem")

    def test_telemetry_on_program_passes(self):
        self._check("tick/jnp/frozen/telem")

    def test_learning_program_passes(self):
        self._check("tick/jnp/learning/notelem")

    def test_sharded_programs_pass(self):
        self._check("tick/sharded/frozen/notelem")
        self._check("tick/sharded/learning/telem")

    def test_kernel_lints_pass(self):
        for reg, _ in programs.kernel_launches():
            self._check(f"kernel/{reg}")

    def test_static_surface_passes(self):
        from repro.analysis.findings import Report

        report = Report()
        check_mod.check_static_surface(report)
        assert report.ok(), report.table()

    def test_cli_list_and_single_program(self, capsys):
        assert check_mod.main(["--list"]) == 0
        listed = capsys.readouterr().out.splitlines()
        assert "tick/jnp/frozen/notelem" in listed
        assert check_mod.main(["--program", "kernel/lif_step"]) == 0
        out = capsys.readouterr().out
        assert "PASS" in out

    def test_cli_rejects_unknown_program(self):
        with pytest.raises(SystemExit):
            check_mod.main(["--program", "no/such/program"])
