"""Whole-tick megakernel (`kernels/tick_fused.py`): parity + recompile pins.

Four pins:

* **Parity vs the jnp reference** on the hard cases: per-synapse delays
  > 1, refractory counters live mid-rollout, learning on/off -- all
  bit-exact (spikes, membrane, refractory counters AND the delay ring).

* **Premasked == per-tile masked**: the frozen path's hoisted ``W*C``
  operand and the learning path's in-VMEM ``w*c`` produce identical
  results.

* **One trace across tick counts**: the circular delay pointer is a
  scalar-prefetch *runtime value*; stepping the same jitted tick through
  an entire ring cycle (every slot value) must never retrace.

* **Padding is exact**: ragged n exercises every pad path (weights,
  delay ring, per-neuron params) and still matches the reference.

Kernels run in interpret mode on CPU -- the same kernel body the TPU
executes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity
from repro.core.engine import EngineOptions, TickCarry, TickEngine
from repro.core.lif import LIFParams
from repro.core.network import (
    SNNParams, SNNState, learning_rollout, rollout,
)
from repro.kernels import ops
from repro.plasticity import PlasticityParams, PlasticityState

jax.config.update("jax_platform_name", "cpu")


def _params(n, c, *, seed=0, v_th=1.0, leak=0.2, r_ref=0, w_scale=2.0):
    rng = np.random.default_rng(seed)
    return SNNParams(
        w=jnp.asarray(rng.uniform(0, w_scale, (n, n)), jnp.float32),
        c=jnp.asarray(c, jnp.float32),
        w_in=jnp.eye(n, dtype=jnp.float32) * 2.0,
        lif=LIFParams.make(n, v_th=v_th, leak=leak, r_ref=r_ref))


def _ext(n, ticks, batch_shape=(), p=0.35, seed=1):
    rng = np.random.default_rng(seed)
    shape = (ticks,) + tuple(batch_shape) + (n,)
    return jnp.asarray((rng.random(shape) < p) * 1.0, jnp.float32)


def _assert_trees_bitexact(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestFusedParity:
    @pytest.mark.parametrize("max_delay", [2, 3, 4])
    def test_uniform_delay_ring(self, max_delay):
        """Delay-line read AND write inside the kernel, whole ring cycled."""
        n, ticks = 11, 3 * max_delay + 2
        p = _params(n, connectivity.sparse_random(n, 0.5, seed=max_delay),
                    v_th=0.8)
        st0 = SNNState.zeros((), n, max_delay=max_delay)
        ext = _ext(n, ticks, seed=max_delay)
        fin_j, ras_j = rollout(p, st0, ext, ticks, backend="jnp")
        fin_f, ras_f = rollout(p, st0, ext, ticks, backend="pallas_fused")
        np.testing.assert_array_equal(np.asarray(ras_j), np.asarray(ras_f))
        _assert_trees_bitexact(fin_j, fin_f)

    @pytest.mark.parametrize("max_delay", [2, 4])
    def test_per_synapse_delays(self, max_delay):
        """The d-major flattened contraction matches the reference einsum."""
        n, ticks = 7, 4 * max_delay
        rng = np.random.default_rng(max_delay)
        p = _params(n, connectivity.sparse_random(n, 0.6, seed=5), v_th=0.8)
        delays = jnp.asarray(rng.integers(1, max_delay + 1, (n, n)), jnp.int32)
        st0 = SNNState.zeros((), n, max_delay=max_delay)
        ext = _ext(n, ticks, p=0.3, seed=6)
        fin_j, ras_j = rollout(p, st0, ext, ticks, delays=delays, backend="jnp")
        fin_f, ras_f = rollout(p, st0, ext, ticks, delays=delays,
                               backend="pallas_fused")
        np.testing.assert_array_equal(np.asarray(ras_j), np.asarray(ras_f))
        _assert_trees_bitexact(fin_j, fin_f)

    def test_refractory_active(self):
        """r_ref > 0 with dense firing: the epilogue's refractory mask must
        hold spikes AND count down identically to the reference."""
        n, ticks = 10, 16
        p = _params(n, connectivity.sparse_random(n, 0.8, seed=2),
                    v_th=0.6, r_ref=3, w_scale=3.0)
        st0 = SNNState.zeros((2,), n, max_delay=2)
        ext = _ext(n, ticks, (2,), p=0.6, seed=3)
        fin_j, ras_j = rollout(p, st0, ext, ticks, backend="jnp")
        fin_f, ras_f = rollout(p, st0, ext, ticks, backend="pallas_fused")
        assert float(np.asarray(fin_j.lif.r).max()) > 0, "refractory never engaged"
        np.testing.assert_array_equal(np.asarray(ras_j), np.asarray(ras_f))
        _assert_trees_bitexact(fin_j, fin_f)

    @pytest.mark.parametrize("learn", [False, True])
    def test_learning_on_off(self, learn):
        """Same network, learning on vs off: fused matches jnp either way,
        and learning actually changes the weights (the hook really ran)."""
        n, ticks, b = 8, 12, 2
        c = connectivity.sparse_random(n, 0.6, seed=7)
        p = _params(n, c, v_th=0.9, w_scale=3.0)
        ext = _ext(n, ticks, (b,), p=0.5, seed=8)
        if not learn:
            st0 = SNNState.zeros((b,), n)
            fin_j, ras_j = rollout(p, st0, ext, ticks, backend="jnp")
            fin_f, ras_f = rollout(p, st0, ext, ticks, backend="pallas_fused")
            np.testing.assert_array_equal(np.asarray(ras_j), np.asarray(ras_f))
            _assert_trees_bitexact(fin_j, fin_f)
            return
        pp = PlasticityParams.make("stdp", a_plus=0.4, a_minus=0.2, w_max=16.0)
        st0 = SNNState.zeros((b,), n)
        pst0 = PlasticityState.zeros((b,), n)
        (f1, p1, w1), r1 = learning_rollout(
            p, st0, pst0, ext, ticks, plasticity=pp, backend="jnp")
        (f2, p2, w2), r2 = learning_rollout(
            p, st0, pst0, ext, ticks, plasticity=pp,
            backend="pallas_fused", plasticity_backend="jnp")
        assert not np.array_equal(np.asarray(w1), np.asarray(p.w)), \
            "plasticity hook never changed the weights"
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
        np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
        _assert_trees_bitexact((f1, p1), (f2, p2))

    def test_premasked_equals_per_tile_mask(self):
        """Frozen path (hoisted W*C operand) == learning-style (w, c) path."""
        n = 9
        p = _params(n, connectivity.sparse_random(n, 0.5, seed=1), v_th=0.7)
        st = SNNState.zeros((), n, max_delay=3)
        st = dataclasses.replace(
            st, delay_buf=st.delay_buf.at[0].set(1.0), tick=jnp.int32(0))
        ext = jnp.ones((n,))
        wc = p.w * p.c
        lif_a, dly_a = ops.fused_tick(st, p, ext, wc=wc)
        lif_b, dly_b = ops.fused_tick(st, p, ext, wc=None)
        _assert_trees_bitexact(lif_a, lif_b)
        np.testing.assert_array_equal(np.asarray(dly_a), np.asarray(dly_b))

    def test_ragged_padding_exact(self):
        """n not a multiple of any block: padded neurons must stay silent."""
        n, ticks = 139, 9
        p = _params(n, connectivity.sparse_random(n, 0.3, seed=9), v_th=0.8)
        st0 = SNNState.zeros((5,), n, max_delay=2)
        ext = _ext(n, ticks, (5,), p=0.2, seed=10)
        fin_j, ras_j = rollout(p, st0, ext, ticks, backend="jnp")
        fin_f, ras_f = rollout(p, st0, ext, ticks, backend="pallas_fused")
        np.testing.assert_array_equal(np.asarray(ras_j), np.asarray(ras_f))
        _assert_trees_bitexact(fin_j, fin_f)

    def test_surrogate_rejected(self):
        n = 4
        p = _params(n, connectivity.ring(n))
        eng = TickEngine(EngineOptions(backend="pallas_fused", surrogate=True))
        with pytest.raises(ValueError, match="inference-only"):
            eng.tick(SNNState.zeros((), n), p, None)


class TestFusedRecompilePin:
    def test_one_trace_across_tick_counts(self):
        """Advancing the circular delay pointer through a full ring cycle --
        every (read, write) slot pair -- reuses ONE trace: the pointer is a
        runtime scalar (scalar prefetch), never a compiled constant."""
        n, max_delay = 8, 3
        p = _params(n, connectivity.sparse_random(n, 0.5, seed=4), v_th=0.7)
        eng = TickEngine(EngineOptions(backend="pallas_fused"))
        traces = {"n": 0}

        def tick(state, params, ext):
            traces["n"] += 1
            carry, _ = eng.tick_body(TickCarry(state=state), (ext, None),
                                     params=params)
            return carry.state

        jtick = jax.jit(tick)
        st = SNNState.zeros((), n, max_delay=max_delay)
        ext = jnp.ones((n,))
        for k in range(2 * max_delay + 1):  # tick = 0..2D: every slot, twice
            st = jtick(st, p, ext)
        assert int(st.tick) == 2 * max_delay + 1
        assert traces["n"] == 1, f"tick advance retraced {traces['n'] - 1}x"

    def test_one_trace_across_rollout_lengths_same_shape(self):
        """Rollouts launched from different tick offsets (same shapes) share
        the compiled program -- the scan body never bakes in the tick."""
        n, ticks, max_delay = 6, 5, 4
        p = _params(n, connectivity.sparse_random(n, 0.6, seed=3), v_th=0.7)
        traces = {"n": 0}

        def run(params, state, ext):
            traces["n"] += 1
            return rollout(params, state, ext, ticks, backend="pallas_fused")

        jrun = jax.jit(run)
        st = SNNState.zeros((), n, max_delay=max_delay)
        ext = _ext(n, ticks, seed=11)
        fin, _ = jrun(p, st, ext)
        for _ in range(3):  # restart from advanced (offset) states
            fin, _ = jrun(p, fin, ext)
        assert traces["n"] == 1, f"offset restart retraced {traces['n'] - 1}x"
